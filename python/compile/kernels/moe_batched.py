"""L1: statically batched MoE expert-GEMM Pallas kernel.

This is the TPU/Pallas embodiment of the paper's static batching framework
(Sections 3 and 4):

* One fused kernel (`pallas_call`) computes *all* expert GEMMs of an MoE
  layer.  The grid enumerates output tiles; each grid step is the analog of
  one CUDA thread block.
* The tile -> (task, tile-in-task) mapping is *compressed*: the kernel only
  receives ``tile_prefix`` (inclusive prefix sum of per-expert tile counts,
  Algorithm 1) and decompresses it per grid step with a vectorized
  compare-and-count, the SIMT warp-vote + popcount of Algorithm 2
  (``h = popcount(g >= TilePrefix)``).
* Empty experts are elided by the two-stage mapping of Algorithm 4: the
  prefix array is built over *non-empty* experts only and ``sigma`` maps the
  non-empty index back to the real expert index.
* Token rows are gathered directly from the original token sequence through
  per-expert token index arrays (Section 4.3) -- no pre-gathered contiguous
  copies of the token tensor exist anywhere.

Hardware adaptation (paper Section 4.4 is Hopper-specific, see
DESIGN.md Section 1): the WGMMA tile becomes an MXU-shaped ``jnp.dot`` with
``preferred_element_type=float32``; the cp.async shared-memory pipeline
becomes the Pallas HBM->VMEM block pipeline expressed through ``BlockSpec``
index maps (the expert weight block is selected per grid step from the
scalar-prefetched metadata, exactly the two-phase "host builds the plan,
device consumes it" split the paper advocates); the L2 tile-swizzle locality
trick becomes grid-order locality (tiles of one expert are consecutive, so
the weight block stays resident across them).

The kernel MUST run with ``interpret=True`` on this CPU-only image: real TPU
lowering emits a Mosaic custom-call that the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_M = 128


class MoeDims(NamedTuple):
    """Static problem dimensions baked into one compiled kernel variant."""

    seq: int          # S, tokens in the sequence
    d_model: int      # H, hidden size (GEMM K dim)
    d_ff: int         # D, expert output size (GEMM N dim)
    experts: int      # E, number of experts resident on this device
    top_k: int        # experts activated per token
    tile_m: int = DEFAULT_TILE_M

    @property
    def padded_rows(self) -> int:
        """Static bound on the packed, per-expert-padded row count.

        Every non-empty expert wastes at most ``tile_m - 1`` padding rows, so
        ``S * k`` real rows plus ``E`` partial tiles is a safe static bound
        (rounded up to a whole number of tiles).
        """
        raw = self.seq * self.top_k + self.experts * self.tile_m
        return (raw + self.tile_m - 1) // self.tile_m * self.tile_m

    @property
    def max_tiles(self) -> int:
        """Static grid size: upper bound on the total number of M-tiles."""
        return self.padded_rows // self.tile_m


def _mapping_decompress(tile_prefix, g):
    """Algorithm 2 on the grid index.

    ``tile_prefix`` is the inclusive prefix sum of tile counts over the
    non-empty experts, padded to a fixed length by repeating the total (the
    paper pads to warp size with the last element / max value).  The warp
    ballot + popcount of the SIMT formulation is exactly a vectorized
    ``g >= tile_prefix`` compare followed by a horizontal add.

    Returns ``(h, l)``: non-empty-task index and tile index inside the task.
    """
    votes = (g >= tile_prefix).astype(jnp.int32)
    h = jnp.sum(votes)
    base = jnp.where(h > 0, tile_prefix[jnp.maximum(h - 1, 0)], 0)
    l = g - base
    return h, l


def _moe_kernel(
    # scalar-prefetch style metadata (small int32 arrays, SMEM analog)
    tile_prefix_ref,    # [E] inclusive prefix of per-(non-empty)-expert tiles
    sigma_ref,          # [E] non-empty index -> real expert index
    token_ids_ref,      # [SP] gather indices into the token sequence
    num_tiles_ref,      # [1]  number of real (non-padding) tiles
    # tensor operands
    tokens_ref,         # [S, H]  original token sequence (never copied)
    weights_ref,        # [E, H, D] expert weights
    out_ref,            # [SP, D] packed per-expert outputs
    *,
    tile_m: int,
):
    g = pl.program_id(0)

    # --- stage 1+2 mapping: grid index -> non-empty task -> real expert ----
    h, _l = _mapping_decompress(tile_prefix_ref[...], g)
    h_safe = jnp.minimum(h, sigma_ref.shape[0] - 1)
    expert = sigma_ref[h_safe]

    # --- token index array gather (Section 4.3) ---------------------------
    row0 = g * tile_m
    ids = jax.lax.dynamic_slice(token_ids_ref[...], (row0,), (tile_m,))
    x_tile = tokens_ref[ids, :]                       # [tile_m, H] gather

    # --- MXU tile matmul (WGMMA analog) ------------------------------------
    w = weights_ref[expert, :, :]                     # [H, D]
    acc = jnp.dot(
        x_tile.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    # Padding grid steps (g >= num_tiles) still execute with a clamped
    # expert; their rows carry zero gate weight downstream, but we zero them
    # here too so the packed buffer is deterministic.
    valid = g < num_tiles_ref[0]
    acc = jnp.where(valid, acc, 0.0)
    out_ref[pl.ds(row0, tile_m), :] = acc.astype(out_ref.dtype)


def moe_batched_matmul(
    tokens: jax.Array,        # [S, H]
    weights: jax.Array,       # [E, H, D]
    tile_prefix: jax.Array,   # [E] int32
    sigma: jax.Array,         # [E] int32
    token_ids: jax.Array,     # [SP] int32
    num_tiles: jax.Array,     # [1] int32
    *,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = True,
) -> jax.Array:
    """Run the fused statically-batched MoE GEMM.

    Returns the packed per-expert output buffer ``[SP, D]`` where ``SP`` is
    ``token_ids.shape[0]`` (rows grouped by expert, each group padded to a
    multiple of ``tile_m``).  The caller (L2) scatters rows back to tokens
    with the gate weights; padding rows carry gate 0.
    """
    s, hdim = tokens.shape
    e, hdim2, d = weights.shape
    assert hdim == hdim2, (hdim, hdim2)
    sp = token_ids.shape[0]
    assert sp % tile_m == 0, (sp, tile_m)
    grid = sp // tile_m

    kernel = functools.partial(_moe_kernel, tile_m=tile_m)
    out = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(tile_prefix.shape, lambda g: (0,)),
            pl.BlockSpec(sigma.shape, lambda g: (0,)),
            pl.BlockSpec(token_ids.shape, lambda g: (0,)),
            pl.BlockSpec(num_tiles.shape, lambda g: (0,)),
            pl.BlockSpec((s, hdim), lambda g: (0, 0)),
            pl.BlockSpec((e, hdim, d), lambda g: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((sp, d), lambda g: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, d), tokens.dtype),
        interpret=interpret,
    )(tile_prefix, sigma, token_ids, num_tiles, tokens, weights)
    return out
