"""Pure-jnp correctness oracle for the MoE layer.

Dense one-hot dispatch: every (token, choice) pair is materialized against
every expert, so there is no routing-dependent control flow at all.  Slow but
unambiguous; the Pallas kernel and the whole packed-metadata path must match
this to a few ULP (fp32 accumulation in both).
"""

from __future__ import annotations

import jax.numpy as jnp


def one_hot(idx, num):
    """One-hot without jax.nn dependency: [..., num] float32."""
    return (idx[..., None] == jnp.arange(num, dtype=idx.dtype)).astype(jnp.float32)


def moe_ref(tokens, weights, expert_ids, gates):
    """Dense reference MoE.

    Args:
      tokens:     [S, H] float
      weights:    [E, H, D] float
      expert_ids: [S, K] int32, expert chosen per (token, slot)
      gates:      [S, K] float, combine weight per (token, slot)

    Returns:
      [S, D] combined expert outputs: ``sum_k gates[s,k] * tokens[s] @ W[e]``.
    """
    e = weights.shape[0]
    # per-token per-expert combined weight: [S, E]
    combine = jnp.sum(one_hot(expert_ids, e) * gates[..., None].astype(jnp.float32), axis=1)
    # all-experts outputs: [S, E, D]
    y = jnp.einsum(
        "sh,ehd->sed",
        tokens.astype(jnp.float32),
        weights.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    out = jnp.einsum("se,sed->sd", combine, y)
    return out.astype(tokens.dtype)


def expert_counts_ref(expert_ids, num_experts):
    """[E] number of (token, slot) pairs routed to each expert."""
    flat = expert_ids.reshape(-1)
    return jnp.sum(
        (flat[:, None] == jnp.arange(num_experts, dtype=flat.dtype)).astype(jnp.int32),
        axis=0,
    )
