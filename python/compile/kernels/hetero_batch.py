"""L1 demo of Algorithm 3 at the kernel level: heterogeneous tasks fused
into a SINGLE Pallas kernel.

The paper's framework batches *different operations* (e.g. GEMM and
reduction) into one kernel by compiling each as a device function and
switching on the task type after the mapping decompression.  In Pallas the
device functions become branches of ``jax.lax.switch`` selected by the
task-kind metadata, after the same compressed TilePrefix mapping used by
the MoE kernel.

Task types (fixed catalog, like ``taskFunc_1..K``):
  0: GEMM tile       out[tile] = A_rows @ B
  1: row reduce-sum  out[tile, 0] = sum(A_rows, axis=1)
  2: element-wise    out[tile] = 2 * A_rows + 1

All tasks read row-tiles of a shared operand buffer ``data [R, C]`` and
write row-tiles of ``out [R, C]`` — heterogeneity is in the *computation*,
exactly the paper's "some of the workloads are reduction, while others are
element-wise operations".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .moe_batched import _mapping_decompress

TILE_R = 8  # rows per tile


def _hetero_kernel(
    tile_prefix_ref,  # [N] inclusive prefix of per-task tile counts
    task_kind_ref,    # [N] task type id per task
    task_row0_ref,    # [N] first data row of each task
    num_tiles_ref,    # [1]
    data_ref,         # [R, C]
    b_ref,            # [C, C]  GEMM's B operand
    out_ref,          # [R, C]
):
    g = pl.program_id(0)
    h, l = _mapping_decompress(tile_prefix_ref[...], g)
    h = jnp.minimum(h, task_kind_ref.shape[0] - 1)
    kind = task_kind_ref[h]
    row0 = task_row0_ref[h] + l * TILE_R

    rows = jax.lax.dynamic_slice(
        data_ref[...], (row0, 0), (TILE_R, data_ref.shape[1])
    )

    def gemm(_):
        return jnp.dot(rows, b_ref[...], preferred_element_type=jnp.float32)

    def reduce_sum(_):
        s = jnp.sum(rows, axis=1, keepdims=True)
        return jnp.concatenate(
            [s, jnp.zeros((TILE_R, data_ref.shape[1] - 1), jnp.float32)], axis=1
        )

    def elementwise(_):
        return 2.0 * rows + 1.0

    result = jax.lax.switch(kind, [gemm, reduce_sum, elementwise], None)

    valid = g < num_tiles_ref[0]
    result = jnp.where(valid, result, 0.0)
    out_ref[pl.ds(row0, TILE_R), :] = result.astype(out_ref.dtype)


def hetero_batch(data, b, tile_prefix, task_kind, task_row0, num_tiles, grid):
    """Run the fused heterogeneous kernel.

    ``data [R, C]`` row-partitioned among tasks; ``task_row0[h]`` is task
    h's first row (tile-aligned); output has the same shape.
    """
    r, c = data.shape
    kernel = functools.partial(_hetero_kernel)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(tile_prefix.shape, lambda g: (0,)),
            pl.BlockSpec(task_kind.shape, lambda g: (0,)),
            pl.BlockSpec(task_row0.shape, lambda g: (0,)),
            pl.BlockSpec(num_tiles.shape, lambda g: (0,)),
            pl.BlockSpec((r, c), lambda g: (0, 0)),
            pl.BlockSpec(b.shape, lambda g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((r, c), lambda g: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), data.dtype),
        interpret=True,
    )(tile_prefix, task_kind, task_row0, num_tiles, data, b)


def build_hetero_metadata(task_rows, task_kinds):
    """Host-side Algorithm 1 for the heterogeneous batch.

    ``task_rows[h]``: row count of task h (must be TILE_R-aligned here for
    simplicity); ``task_kinds[h]``: its type id.  Returns the kernel's
    metadata arrays plus the total grid size.
    """
    assert len(task_rows) == len(task_kinds)
    tiles = [r // TILE_R for r in task_rows]
    prefix = []
    acc = 0
    row0 = []
    r_acc = 0
    for t, r in zip(tiles, task_rows):
        acc += t
        prefix.append(acc)
        row0.append(r_acc)
        r_acc += r
    return (
        jnp.array(prefix, jnp.int32),
        jnp.array(task_kinds, jnp.int32),
        jnp.array(row0, jnp.int32),
        jnp.array([acc], jnp.int32),
        acc,
    )
