"""L2: the MoE transformer in JAX, calling the L1 Pallas kernel.

Everything here is build-time Python: `aot.py` lowers the jitted entry points
to HLO text once, and the Rust coordinator executes the compiled artifacts on
the PJRT CPU client.  Nothing in this file runs on the request path.

The MoE FFN uses the statically batched kernel for BOTH expert GEMM stages:

  stage 1:  packed = gather(tokens)[rows] @ w_in[expert]     (token index arrays)
  act:      silu on the packed buffer
  stage 2:  packed2 = packed[rows identity] @ w_out[expert]  (already grouped)
  combine:  scatter-add with gate weights

Stage 2 reuses the same kernel with an identity token-index array because the
activation buffer is already grouped by expert -- the "no duplicate copies"
property of Section 4.3 holds end to end.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import metadata
from .kernels.moe_batched import MoeDims, moe_batched_matmul


class ModelConfig(NamedTuple):
    """Hyper-parameters of the tiny MoE transformer LM."""

    vocab: int = 1024
    d_model: int = 256
    d_ff: int = 512
    n_heads: int = 4
    n_layers: int = 4
    experts: int = 16
    top_k: int = 2
    tile_m: int = 32

    def dims(self, seq: int) -> MoeDims:
        return MoeDims(
            seq=seq,
            d_model=self.d_model,
            d_ff=self.d_ff,
            experts=self.experts,
            top_k=self.top_k,
            tile_m=self.tile_m,
        )

    def param_specs(self):
        """Ordered (name, shape) list -- the artifact manifest contract.

        The Rust side materializes parameters in exactly this order.
        """
        c = self
        specs = [("embedding", (c.vocab, c.d_model))]
        for i in range(c.n_layers):
            p = f"layer{i}."
            specs += [
                (p + "ln1", (c.d_model,)),
                (p + "wq", (c.d_model, c.d_model)),
                (p + "wk", (c.d_model, c.d_model)),
                (p + "wv", (c.d_model, c.d_model)),
                (p + "wo", (c.d_model, c.d_model)),
                (p + "ln2", (c.d_model,)),
                (p + "router", (c.d_model, c.experts)),
                (p + "w_in", (c.experts, c.d_model, c.d_ff)),
                (p + "w_out", (c.experts, c.d_ff, c.d_model)),
            ]
        specs += [("ln_f", (c.d_model,)), ("head", (c.d_model, c.vocab))]
        return specs

    def num_params(self) -> int:
        return sum(math.prod(s) for _, s in self.param_specs())


def init_params(cfg: ModelConfig, key) -> list:
    """Random init in manifest order (synthetic weights stand in for a real
    checkpoint: no network access on this image; DESIGN.md documents the
    substitution)."""
    params = []
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) > 1 else shape[-1]
            scale = 0.02 if name in ("embedding", "head") else 1.0 / math.sqrt(fan_in)
            params.append(jax.random.normal(sub, shape, jnp.float32) * scale)
    return params


def rms_norm(x, g, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * g).astype(x.dtype)


def route(x, router_w, top_k):
    """Top-k softmax router. Returns (expert_ids [S,K] i32, gates [S,K] f32).

    Implemented as iterative argmax + mask rather than ``jax.lax.top_k``:
    the TopK HLO op is newer than the xla_extension 0.5.1 parser on the
    runtime side, while argmax/gather/scatter lower to classic HLO that
    round-trips through the text format (see DESIGN.md Section 5 risks).
    """
    s = x.shape[0]
    logits = jnp.dot(x.astype(jnp.float32), router_w.astype(jnp.float32))
    work = logits
    ids, vals = [], []
    rows = jnp.arange(s)
    for _ in range(top_k):
        idx = jnp.argmax(work, axis=-1).astype(jnp.int32)
        val = work[rows, idx]
        ids.append(idx)
        vals.append(val)
        work = work.at[rows, idx].set(-jnp.inf)
    ids = jnp.stack(ids, axis=-1)
    vals = jnp.stack(vals, axis=-1)
    gates = jax.nn.softmax(vals, axis=-1)
    return ids.astype(jnp.int32), gates.astype(jnp.float32)


def moe_ffn(x, router_w, w_in, w_out, dims: MoeDims, *, interpret: bool = True):
    """The full MoE FFN layer via the statically batched kernel."""
    seq = x.shape[0]
    expert_ids, gates = route(x, router_w, dims.top_k)
    plan = metadata.build_plan(expert_ids, gates, dims)

    # Stage 1: gather token rows through the token index array, GEMM vs w_in.
    h1 = moe_batched_matmul(
        x, w_in, plan.tile_prefix, plan.sigma, plan.token_ids, plan.num_tiles,
        tile_m=dims.tile_m, interpret=interpret,
    )                                                     # [SP, F]
    h1 = jax.nn.silu(h1.astype(jnp.float32)).astype(x.dtype)

    # Stage 2: rows already grouped by expert -> identity index array.
    sp = plan.token_ids.shape[0]
    identity = jnp.arange(sp, dtype=jnp.int32)
    h2 = moe_batched_matmul(
        h1, w_out, plan.tile_prefix, plan.sigma, identity, plan.num_tiles,
        tile_m=dims.tile_m, interpret=interpret,
    )                                                     # [SP, H]

    return metadata.combine(h2, plan, seq), plan


def attention(x, wq, wk, wv, wo, n_heads):
    """Simple causal multi-head attention over the whole sequence."""
    s, h = x.shape
    dh = h // n_heads
    q = jnp.dot(x, wq).reshape(s, n_heads, dh)
    k = jnp.dot(x, wk).reshape(s, n_heads, dh)
    v = jnp.dot(x, wv).reshape(s, n_heads, dh)
    scores = jnp.einsum("qnd,knd->nqk", q, k) / jnp.float32(math.sqrt(dh))
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("nqk,knd->qnd", probs, v).reshape(s, h)
    return jnp.dot(out, wo)


def transformer_forward(token_ids, params, cfg: ModelConfig, *, interpret: bool = True):
    """Full forward pass: [S] int32 token ids -> [S, V] logits."""
    seq = token_ids.shape[0]
    dims = cfg.dims(seq)
    it = iter(params)
    emb = next(it)
    x = emb[token_ids]
    pos = jnp.arange(seq)[:, None] * jnp.exp(
        -jnp.arange(cfg.d_model)[None, :] / cfg.d_model
    )
    x = x + 0.01 * jnp.sin(pos).astype(x.dtype)
    for _layer in range(cfg.n_layers):
        ln1, wq, wk, wv, wo, ln2, router_w, w_in, w_out = (next(it) for _ in range(9))
        x = x + attention(rms_norm(x, ln1), wq, wk, wv, wo, cfg.n_heads)
        y, _plan = moe_ffn(rms_norm(x, ln2), router_w, w_in, w_out, dims, interpret=interpret)
        x = x + y
    ln_f, head = next(it), next(it)
    return jnp.dot(rms_norm(x, ln_f), head)


def moe_gemm_entry(tokens, weights, tile_prefix, sigma, token_ids, num_tiles, tile_m):
    """Raw single-stage batched MoE GEMM -- the paper's exact kernel shape.

    Exposed as its own AOT artifact so the Rust benches can drive the kernel
    with externally built plans (and cross-check the Rust planner against the
    jnp planner through the compiled artifact).
    """
    return moe_batched_matmul(
        tokens, weights, tile_prefix, sigma, token_ids, num_tiles, tile_m=tile_m
    )


def moe_ffn_entry(x, router_w, w_in, w_out, cfg: ModelConfig):
    """MoE FFN entry returning (output, expert counts) for coordinator stats."""
    dims = cfg.dims(x.shape[0])
    out, plan = moe_ffn(x, router_w, w_in, w_out, dims)
    return out, plan.counts
