"""Host-side static batch plan construction (the paper's Algorithms 1 & 4).

Everything here is shape-static jnp so it lowers into the same AOT HLO as the
kernel: the "host" of the paper is our L2 graph prologue (and, on the serving
path, the Rust planner produces the identical arrays -- property-tested
against each other through the artifact).

Produced arrays, for dims ``MoeDims(S, H, D, E, K, T)`` with
``SP = dims.padded_rows``:

* ``counts      [E]``  tokens routed to each expert (c_e)
* ``sigma       [E]``  non-empty-task index -> real expert (Algorithm 4's
                       injection, padded past M with the remaining/empty
                       expert ids so it stays a permutation)
* ``tile_prefix [E]``  inclusive prefix sum of per-non-empty-expert tile
                       counts (Algorithm 1), tail-padded by repetition
* ``num_tiles   [1]``  total real tiles
* ``token_ids   [SP]`` gather indices into the token sequence, grouped by
                       expert in sigma order, each group padded to a multiple
                       of tile_m (padding slots point at token 0)
* ``gates_pad   [SP]`` combine weight per packed row (0 on padding)
* ``row_token   [SP]`` == token_ids (scatter target for the combine)
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .kernels.moe_batched import MoeDims


class BatchPlan(NamedTuple):
    counts: jnp.ndarray       # [E] int32
    sigma: jnp.ndarray        # [E] int32
    tile_prefix: jnp.ndarray  # [E] int32
    num_tiles: jnp.ndarray    # [1] int32
    token_ids: jnp.ndarray    # [SP] int32
    gates_pad: jnp.ndarray    # [SP] float32


def build_plan(expert_ids, gates, dims: MoeDims) -> BatchPlan:
    """Build the packed static batch plan from routing decisions.

    ``expert_ids``: [S, K] int32, ``gates``: [S, K] float.  All ops are
    static-shape (argsort / cumsum / scatter), mirroring the atomic-scatter
    radix bucketing the paper uses on device (Section 4.3).
    """
    s, k = expert_ids.shape
    e, t = dims.experts, dims.tile_m
    sp = dims.padded_rows

    flat_e = expert_ids.reshape(-1).astype(jnp.int32)          # [S*K]
    flat_g = gates.reshape(-1).astype(jnp.float32)             # [S*K]
    flat_tok = (
        jnp.arange(s * k, dtype=jnp.int32) // jnp.int32(k)
    )                                                          # token of slot

    # --- per-expert counts (c_e) and tile counts --------------------------
    counts = jnp.sum(
        (flat_e[:, None] == jnp.arange(e, dtype=jnp.int32)).astype(jnp.int32),
        axis=0,
    )                                                          # [E]
    tiles = (counts + t - 1) // t                              # ceil, 0 if empty

    # --- Algorithm 4: sigma = non-empty experts first, stable --------------
    nonempty = counts > 0
    # argsort of (is_empty, index): stable ascending puts non-empty experts
    # (in index order) first -- exactly the injection sigma.
    sigma = jnp.argsort(jnp.where(nonempty, 0, 1), stable=True).astype(jnp.int32)

    # --- Algorithm 1: inclusive tile prefix over non-empty experts ---------
    tiles_sorted = tiles[sigma]                                # empties -> 0 tail
    tile_prefix = jnp.cumsum(tiles_sorted).astype(jnp.int32)   # tail repeats total
    num_tiles = tile_prefix[-1:].astype(jnp.int32)

    # --- packed row layout --------------------------------------------------
    # Group start (in packed rows) per expert, in sigma order, padded to T.
    padded_counts_sorted = tiles_sorted * t                    # [E]
    group_start_sorted = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded_counts_sorted)[:-1].astype(jnp.int32)]
    )                                                          # [E] exclusive
    # Map real expert -> its packed group start: invert sigma.
    inv_sigma = jnp.argsort(sigma, stable=True).astype(jnp.int32)
    group_start = group_start_sorted[inv_sigma]                # [E] by real id

    # Rank of each routed slot within its expert: sort slots by expert
    # (stable), then rank = position - start of that expert's run.
    order = jnp.argsort(flat_e, stable=True).astype(jnp.int32)  # [S*K]
    sorted_e = flat_e[order]
    run_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
    )                                                          # [E]
    pos = jnp.arange(s * k, dtype=jnp.int32)
    rank = pos - run_start[sorted_e]                           # [S*K]
    dest = group_start[sorted_e] + rank                        # packed row

    token_ids = jnp.zeros((sp,), jnp.int32).at[dest].set(flat_tok[order])
    gates_pad = jnp.zeros((sp,), jnp.float32).at[dest].set(flat_g[order])

    return BatchPlan(
        counts=counts,
        sigma=sigma,
        tile_prefix=tile_prefix,
        num_tiles=num_tiles,
        token_ids=token_ids,
        gates_pad=gates_pad,
    )


def combine(out_packed, plan: BatchPlan, seq: int):
    """Scatter packed expert outputs back to token order with gate weights.

    ``out_packed``: [SP, D].  Padding rows have gate 0 so scattering them to
    token 0 is a no-op.
    """
    weighted = out_packed.astype(jnp.float32) * plan.gates_pad[:, None]
    d = out_packed.shape[1]
    out = jnp.zeros((seq, d), jnp.float32).at[plan.token_ids].add(weighted)
    return out.astype(out_packed.dtype)
