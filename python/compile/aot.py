"""AOT compile path: lower the L2 entry points to HLO *text* artifacts.

Interchange format is HLO text, NOT the serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids so text round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Produces one ``<entry>.hlo.txt`` per entry point plus ``manifest.json``
describing every input/output (name, shape, dtype) and the model/kernel
hyper-parameters, which is the contract the Rust runtime loads.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import metadata  # noqa: F401  (re-exported for tests)
from .kernels.moe_batched import MoeDims
from . import model as M

# Sequence-length buckets the serving path compiles; the Rust batcher pads
# request batches into the smallest fitting bucket.
LM_BUCKETS = (16, 64, 256)
FFN_BUCKETS = (64, 256)

# The kernel-bench entry: a scaled-down analog of the paper's Section 5
# setting (seq 4096, weight [3584, 2560], E=64, k=8) that the CPU can
# execute in reasonable time.  The full-size setting is exercised by the
# Rust GPU simulator instead (see DESIGN.md experiment index).
BENCH_DIMS = MoeDims(seq=512, d_model=448, d_ff=320, experts=64, top_k=8, tile_m=64)

MODEL_CFG = M.ModelConfig()


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _record(avals):
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)}
        for a in avals
    ]


def build_entries():
    """Yield (name, jitted_fn, example_args, meta) for every artifact."""
    entries = []

    # --- raw batched MoE GEMM (paper's kernel) -----------------------------
    d = BENCH_DIMS
    sp = d.padded_rows

    def moe_gemm(tokens, weights, tile_prefix, sigma, token_ids, num_tiles):
        return M.moe_gemm_entry(
            tokens, weights, tile_prefix, sigma, token_ids, num_tiles, d.tile_m
        )

    entries.append(
        (
            "moe_gemm",
            moe_gemm,
            (
                _spec((d.seq, d.d_model)),
                _spec((d.experts, d.d_model, d.d_ff)),
                _spec((d.experts,), jnp.int32),
                _spec((d.experts,), jnp.int32),
                _spec((sp,), jnp.int32),
                _spec((1,), jnp.int32),
            ),
            {
                "kind": "moe_gemm",
                "dims": dict(d._asdict()),
                "padded_rows": sp,
                "max_tiles": d.max_tiles,
            },
        )
    )

    # --- MoE FFN layer per bucket ------------------------------------------
    cfg = MODEL_CFG
    for s in FFN_BUCKETS:
        def ffn(x, router_w, w_in, w_out, _cfg=cfg):
            return M.moe_ffn_entry(x, router_w, w_in, w_out, _cfg)

        entries.append(
            (
                f"moe_ffn_s{s}",
                ffn,
                (
                    _spec((s, cfg.d_model)),
                    _spec((cfg.d_model, cfg.experts)),
                    _spec((cfg.experts, cfg.d_model, cfg.d_ff)),
                    _spec((cfg.experts, cfg.d_ff, cfg.d_model)),
                ),
                {"kind": "moe_ffn", "seq": s, "config": dict(cfg._asdict())},
            )
        )

    # --- full LM forward per bucket -----------------------------------------
    pspecs = cfg.param_specs()
    for s in LM_BUCKETS:
        def lm(token_ids, *params, _cfg=cfg):
            return M.transformer_forward(token_ids, list(params), _cfg)

        args = (_spec((s,), jnp.int32),) + tuple(_spec(shape) for _, shape in pspecs)
        entries.append(
            (
                f"lm_forward_s{s}",
                lm,
                args,
                {
                    "kind": "lm_forward",
                    "seq": s,
                    "config": dict(cfg._asdict()),
                    "params": [
                        {"name": n, "shape": list(shape)} for n, shape in pspecs
                    ],
                    "num_params": cfg.num_params(),
                },
            )
        )

    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated entry names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"entries": {}}
    for name, fn, example_args, meta in build_entries():
        if only is not None and name not in only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *example_args)
        flat_outs = jax.tree_util.tree_leaves(out_avals)
        manifest["entries"][name] = {
            "file": fname,
            "inputs": _record(example_args),
            "outputs": _record(flat_outs),
            "meta": meta,
        }
        print(f"wrote {path} ({len(text)} chars, {len(example_args)} inputs, "
              f"{len(flat_outs)} outputs)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
