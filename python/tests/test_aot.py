"""AOT path tests: entries lower to parseable HLO text, manifest is sound,
and the lowered moe_gemm HLO executes to the same values as direct eval."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, metadata
from compile import model as M
from compile.kernels.moe_batched import MoeDims

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entries_have_unique_names():
    entries = aot.build_entries()
    names = [e[0] for e in entries]
    assert len(names) == len(set(names))
    assert "moe_gemm" in names
    for s in aot.LM_BUCKETS:
        assert f"lm_forward_s{s}" in names


def test_hlo_text_roundtrip_small():
    """Lower a small fn to HLO text and check it is actual HLO."""
    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_moe_gemm_lowered_matches_eval():
    """The exact bytes written to the artifact compute the right numbers."""
    d = MoeDims(seq=16, d_model=8, d_ff=8, experts=4, top_k=2, tile_m=4)
    sp = d.padded_rows

    def entry(tokens, weights, tile_prefix, sigma, token_ids, num_tiles):
        return M.moe_gemm_entry(
            tokens, weights, tile_prefix, sigma, token_ids, num_tiles, d.tile_m
        )

    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
    tokens = jax.random.normal(k1, (d.seq, d.d_model), jnp.float32)
    weights = jax.random.normal(k2, (d.experts, d.d_model, d.d_ff)) * 0.1
    ids = jax.random.randint(k3, (d.seq, d.top_k), 0, d.experts, jnp.int32)
    gates = jax.nn.softmax(jax.random.normal(k4, (d.seq, d.top_k)), axis=-1)
    plan = metadata.build_plan(ids, gates, d)
    args = (tokens, weights, plan.tile_prefix, plan.sigma, plan.token_ids, plan.num_tiles)

    want = entry(*args)
    text = aot.to_hlo_text(jax.jit(entry).lower(*args))
    assert "HloModule" in text

    # The HLO text must parse back into a module with the right program
    # shape.  (Numeric re-execution of the text artifact is covered by the
    # Rust integration test `runtime::tests` + `tests/integration.rs`, which
    # is the deployment path; jaxlib's in-process compile API for raw HLO
    # changed across versions and is not the path we ship.)
    from jax._src.lib import xla_client as xc
    module = xc._xla.hlo_module_from_text(text)
    assert module is not None
    reparsed = module.to_string()
    assert "fusion" in reparsed or "dot" in reparsed
    # direct eval stays the oracle
    np.testing.assert_allclose(
        np.array(want),
        np.array(entry(*args)),
        rtol=0, atol=0,
    )


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_consistent_with_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["entries"], "manifest has no entries"
    for name, ent in manifest["entries"].items():
        path = os.path.join(ART, ent["file"])
        assert os.path.exists(path), f"{name}: missing {ent['file']}"
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head
        assert ent["inputs"], name
        assert ent["outputs"], name
        for spec in ent["inputs"] + ent["outputs"]:
            assert spec["dtype"] in ("float32", "int32", "bfloat16")
            assert all(isinstance(x, int) and x > 0 for x in spec["shape"])


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_lm_params_match_config():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    ent = manifest["entries"].get(f"lm_forward_s{aot.LM_BUCKETS[0]}")
    assert ent is not None
    cfg = M.ModelConfig(**ent["meta"]["config"])
    specs = cfg.param_specs()
    assert len(ent["inputs"]) == 1 + len(specs)
    for spec, (pname, shape) in zip(ent["inputs"][1:], specs):
        assert tuple(spec["shape"]) == tuple(shape), pname
