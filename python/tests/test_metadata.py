"""Invariants of the host-side static batch plan (Algorithms 1 & 4).

These mirror the Rust planner's proptest suite: both sides must produce the
same packed layout for the same routing (cross-checked end-to-end through the
moe_gemm artifact by the Rust integration tests).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import metadata
from compile.kernels.moe_batched import MoeDims


def make_plan(dims, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    ids = jax.random.randint(k1, (dims.seq, dims.top_k), 0, dims.experts, jnp.int32)
    gates = jax.nn.softmax(jax.random.normal(k2, (dims.seq, dims.top_k)), axis=-1)
    return ids, gates, metadata.build_plan(ids, gates, dims)


DIMS = MoeDims(seq=48, d_model=8, d_ff=8, experts=8, top_k=2, tile_m=8)


def test_sigma_is_permutation():
    _, _, plan = make_plan(DIMS, 0)
    assert sorted(np.array(plan.sigma).tolist()) == list(range(DIMS.experts))


def test_sigma_nonempty_prefix():
    """sigma's first M entries are exactly the non-empty experts, ascending."""
    _, _, plan = make_plan(DIMS, 1)
    counts = np.array(plan.counts)
    sigma = np.array(plan.sigma)
    nonempty = [e for e in range(DIMS.experts) if counts[e] > 0]
    assert sigma[: len(nonempty)].tolist() == nonempty


def test_tile_prefix_is_inclusive_prefix_of_tiles():
    _, _, plan = make_plan(DIMS, 2)
    counts = np.array(plan.counts)
    sigma = np.array(plan.sigma)
    t = DIMS.tile_m
    tiles = [(counts[e] + t - 1) // t for e in sigma]
    assert np.array(plan.tile_prefix).tolist() == np.cumsum(tiles).tolist()


def test_every_slot_appears_exactly_once():
    ids, gates, plan = make_plan(DIMS, 3)
    counts = np.array(plan.counts)
    gp = np.array(plan.gates_pad)
    # number of real (gate-carrying) packed rows == S*K ... modulo zero gates,
    # so count by reconstructing dest rows instead: each expert's group holds
    # exactly counts[e] real rows.
    t = DIMS.tile_m
    sigma = np.array(plan.sigma)
    start = 0
    total_real = 0
    for e in sigma:
        c = int(counts[e])
        padded = (c + t - 1) // t * t
        total_real += c
        start += padded
    assert total_real == DIMS.seq * DIMS.top_k
    assert start <= plan.token_ids.shape[0]


def test_gate_mass_preserved():
    ids, gates, plan = make_plan(DIMS, 4)
    assert np.isclose(float(plan.gates_pad.sum()), float(gates.sum()), rtol=1e-5)


def test_padding_rows_have_zero_gate():
    """Rows past each expert's count (within its tile-padded group) carry 0."""
    ids, gates, plan = make_plan(DIMS, 5)
    counts = np.array(plan.counts)
    sigma = np.array(plan.sigma)
    gp = np.array(plan.gates_pad)
    t = DIMS.tile_m
    start = 0
    for e in sigma:
        c = int(counts[e])
        padded = (c + t - 1) // t * t
        pad_rows = gp[start + c : start + padded]
        assert (pad_rows == 0).all()
        start += padded
    assert (gp[start:] == 0).all()


@settings(max_examples=40, deadline=None)
@given(
    seq=st.integers(1, 96),
    experts=st.integers(1, 16),
    top_k=st.integers(1, 4),
    tile_m=st.sampled_from([2, 4, 8, 32]),
    seed=st.integers(0, 10_000),
)
def test_plan_invariants_hypothesis(seq, experts, top_k, tile_m, seed):
    dims = MoeDims(seq=seq, d_model=4, d_ff=4, experts=experts,
                   top_k=min(top_k, experts), tile_m=tile_m)
    ids, gates, plan = make_plan(dims, seed)
    counts = np.array(plan.counts)
    sigma = np.array(plan.sigma)
    tp = np.array(plan.tile_prefix)
    t = dims.tile_m

    # Alg 1: inclusive prefix over sigma-ordered tile counts
    tiles = np.ceil(counts[sigma] / t).astype(int)
    assert tp.tolist() == np.cumsum(tiles).tolist()
    # Alg 4: injection covers exactly the non-empty experts first
    m = int((counts > 0).sum())
    assert sorted(sigma[:m].tolist()) == [e for e in range(dims.experts) if counts[e] > 0]
    # mass conservation
    assert int(counts.sum()) == dims.seq * dims.top_k
    assert np.isclose(float(plan.gates_pad.sum()), float(gates.sum()), rtol=1e-4)
    # static bounds hold
    assert int(plan.num_tiles[0]) <= dims.max_tiles
    assert plan.token_ids.shape[0] == dims.padded_rows
    # token ids in range
    toks = np.array(plan.token_ids)
    assert ((toks >= 0) & (toks < dims.seq)).all()
