"""Heterogeneous fused-kernel tests (Algorithm 3 at L1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.hetero_batch import (
    TILE_R,
    build_hetero_metadata,
    hetero_batch,
)


def run(task_rows, task_kinds, seed=0, c=16):
    total_rows = sum(task_rows)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    data = jax.random.normal(k1, (total_rows, c), jnp.float32)
    b = jax.random.normal(k2, (c, c), jnp.float32) * 0.2
    prefix, kinds, row0, num_tiles, grid = build_hetero_metadata(task_rows, task_kinds)
    out = hetero_batch(data, b, prefix, kinds, row0, num_tiles, grid)
    return np.array(data), np.array(b), np.array(out), row0


def expected_for(kind, rows, b):
    if kind == 0:
        return rows @ b
    if kind == 1:
        e = np.zeros_like(rows)
        e[:, 0] = rows.sum(axis=1)
        return e
    return 2.0 * rows + 1.0


@pytest.mark.parametrize(
    "task_rows,task_kinds",
    [
        ([16, 8, 24], [0, 1, 2]),
        ([8, 8, 8, 8], [2, 0, 1, 0]),
        ([32], [1]),
        ([8, 16], [2, 2]),
    ],
)
def test_heterogeneous_fusion_matches_per_task_eval(task_rows, task_kinds):
    data, b, out, row0 = run(task_rows, task_kinds)
    r0 = 0
    for rows_n, kind in zip(task_rows, task_kinds):
        rows = data[r0 : r0 + rows_n]
        want = expected_for(kind, rows, b)
        got = out[r0 : r0 + rows_n]
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
        r0 += rows_n
    assert r0 == data.shape[0]


def test_mapping_consistency_with_tiles():
    # 3 tasks x tile counts 2,1,3 -> grid 6; every tile writes its slice
    task_rows = [2 * TILE_R, TILE_R, 3 * TILE_R]
    data, b, out, _ = run(task_rows, [2, 2, 2], seed=3)
    np.testing.assert_allclose(out, 2.0 * data + 1.0, rtol=1e-6)


def test_single_task_gemm_only():
    data, b, out, _ = run([4 * TILE_R], [0], seed=5)
    np.testing.assert_allclose(out, data @ b, rtol=2e-5, atol=2e-5)
