"""L2 model tests: MoE FFN against a dense reference, transformer shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.ref import moe_ref

CFG = M.ModelConfig(vocab=64, d_model=32, d_ff=48, n_heads=2, n_layers=2,
                    experts=4, top_k=2, tile_m=8)


def dense_moe_ffn_ref(x, router_w, w_in, w_out, cfg):
    """Dense (all-experts) reference of the full FFN, no packing anywhere."""
    ids, gates = M.route(x, router_w, cfg.top_k)
    h = jnp.einsum("sh,ehf->sef", x.astype(jnp.float32), w_in.astype(jnp.float32))
    h = jax.nn.silu(h)
    y = jnp.einsum("sef,efh->seh", h, w_out.astype(jnp.float32))
    onehot = (ids[..., None] == jnp.arange(cfg.experts))[..., :].astype(jnp.float32)
    combine = jnp.sum(onehot * gates[..., None], axis=1)       # [S, E]
    return jnp.einsum("se,seh->sh", combine, y).astype(x.dtype)


@pytest.mark.parametrize("seq", [16, 40])
def test_moe_ffn_matches_dense(seq):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (seq, CFG.d_model), jnp.float32)
    router_w = jax.random.normal(ks[1], (CFG.d_model, CFG.experts)) * 0.1
    w_in = jax.random.normal(ks[2], (CFG.experts, CFG.d_model, CFG.d_ff)) * 0.1
    w_out = jax.random.normal(ks[3], (CFG.experts, CFG.d_ff, CFG.d_model)) * 0.1
    got, plan = M.moe_ffn(x, router_w, w_in, w_out, CFG.dims(seq))
    want = dense_moe_ffn_ref(x, router_w, w_in, w_out, CFG)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)
    assert int(plan.counts.sum()) == seq * CFG.top_k


def test_route_topk_valid():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (24, CFG.d_model))
    rw = jax.random.normal(jax.random.PRNGKey(2), (CFG.d_model, CFG.experts))
    ids, gates = M.route(x, rw, CFG.top_k)
    assert ids.shape == (24, CFG.top_k)
    assert ((np.array(ids) >= 0) & (np.array(ids) < CFG.experts)).all()
    np.testing.assert_allclose(np.array(gates.sum(-1)), 1.0, rtol=1e-5)
    # top-k slots of one token are distinct experts
    for row in np.array(ids):
        assert len(set(row.tolist())) == CFG.top_k


def test_transformer_forward_shape_and_finite():
    params = M.init_params(CFG, jax.random.PRNGKey(3))
    ids = jax.random.randint(jax.random.PRNGKey(4), (16,), 0, CFG.vocab, jnp.int32)
    logits = M.transformer_forward(ids, params, CFG)
    assert logits.shape == (16, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_transformer_deterministic():
    params = M.init_params(CFG, jax.random.PRNGKey(5))
    ids = jax.random.randint(jax.random.PRNGKey(6), (16,), 0, CFG.vocab, jnp.int32)
    a = M.transformer_forward(ids, params, CFG)
    b = M.transformer_forward(ids, params, CFG)
    np.testing.assert_array_equal(np.array(a), np.array(b))


def test_param_specs_count():
    specs = CFG.param_specs()
    assert len(specs) == 1 + 9 * CFG.n_layers + 2
    params = M.init_params(CFG, jax.random.PRNGKey(7))
    assert len(params) == len(specs)
    for p, (_, shape) in zip(params, specs):
        assert p.shape == shape
    assert CFG.num_params() == sum(int(np.prod(s)) for _, s in specs)


def test_causality():
    """Changing a future token must not change past logits."""
    params = M.init_params(CFG, jax.random.PRNGKey(8))
    ids = jax.random.randint(jax.random.PRNGKey(9), (12,), 0, CFG.vocab, jnp.int32)
    base = M.transformer_forward(ids, params, CFG)
    ids2 = ids.at[-1].set((ids[-1] + 1) % CFG.vocab)
    pert = M.transformer_forward(ids2, params, CFG)
    np.testing.assert_allclose(
        np.array(base[:-1]), np.array(pert[:-1]), rtol=2e-4, atol=2e-4
    )
