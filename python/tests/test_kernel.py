"""Kernel vs oracle: the CORE correctness signal for L1.

The statically batched Pallas kernel (+ the packed metadata path around it)
must reproduce the dense one-hot reference for every routing distribution,
including the paper's named scenarios (balanced / best / worst, Section 5)
and adversarial corner cases (all experts empty but one, zero gates,
duplicate expert slots per token).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import metadata
from compile.kernels.moe_batched import MoeDims, moe_batched_matmul
from compile.kernels.ref import expert_counts_ref, moe_ref


def run_pair(dims, tokens, weights, expert_ids, gates):
    plan = metadata.build_plan(expert_ids, gates, dims)
    packed = moe_batched_matmul(
        tokens, weights, plan.tile_prefix, plan.sigma,
        plan.token_ids, plan.num_tiles, tile_m=dims.tile_m,
    )
    got = metadata.combine(packed, plan, dims.seq)
    want = moe_ref(tokens, weights, expert_ids, gates)
    return got, want, plan


def rand_case(dims, seed, ids_fn=None):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    tokens = jax.random.normal(k1, (dims.seq, dims.d_model), jnp.float32)
    weights = jax.random.normal(k2, (dims.experts, dims.d_model, dims.d_ff)) * 0.1
    if ids_fn is None:
        ids = jax.random.randint(k3, (dims.seq, dims.top_k), 0, dims.experts, jnp.int32)
    else:
        ids = ids_fn(k3)
    gates = jax.nn.softmax(jax.random.normal(k4, (dims.seq, dims.top_k)), axis=-1)
    return tokens, weights, ids, gates


BASE = MoeDims(seq=64, d_model=32, d_ff=48, experts=8, top_k=2, tile_m=16)


@pytest.mark.parametrize("seed", range(5))
def test_random_routing_matches_ref(seed):
    tokens, weights, ids, gates = rand_case(BASE, seed)
    got, want, _ = run_pair(BASE, tokens, weights, ids, gates)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize(
    "dims",
    [
        MoeDims(seq=32, d_model=16, d_ff=16, experts=4, top_k=1, tile_m=8),
        MoeDims(seq=48, d_model=24, d_ff=40, experts=6, top_k=3, tile_m=16),
        MoeDims(seq=128, d_model=64, d_ff=32, experts=16, top_k=4, tile_m=32),
        MoeDims(seq=16, d_model=8, d_ff=8, experts=2, top_k=2, tile_m=4),
        # tile_m larger than any expert's token count
        MoeDims(seq=8, d_model=8, d_ff=8, experts=8, top_k=1, tile_m=64),
    ],
    ids=lambda d: f"s{d.seq}e{d.experts}k{d.top_k}t{d.tile_m}",
)
def test_shape_sweep(dims):
    tokens, weights, ids, gates = rand_case(dims, 7)
    got, want, _ = run_pair(dims, tokens, weights, ids, gates)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=3e-5, atol=3e-5)


def scenario_ids(dims, scenario):
    """The paper's Section 5 load scenarios, scaled to the given dims."""
    s, k, e = dims.seq, dims.top_k, dims.experts
    if scenario == "balanced":
        # round-robin: token i -> experts (i*k .. i*k+k-1) mod E
        base = (jnp.arange(s, dtype=jnp.int32)[:, None] * k
                + jnp.arange(k, dtype=jnp.int32)[None, :])
        return base % e
    if scenario == "best":
        # all tokens -> the same first k experts
        return jnp.tile(jnp.arange(k, dtype=jnp.int32)[None, :], (s, 1))
    if scenario == "worst":
        # nearly all -> same k experts; remaining experts get 1 token each
        ids = jnp.tile(jnp.arange(k, dtype=jnp.int32)[None, :], (s, 1))
        others = [x for x in range(e) if x >= k]
        for row, ex in enumerate(others):
            ids = ids.at[row % s, row % k].set(ex)
        return ids
    raise ValueError(scenario)


@pytest.mark.parametrize("scenario", ["balanced", "best", "worst"])
def test_paper_scenarios(scenario):
    dims = MoeDims(seq=64, d_model=32, d_ff=32, experts=16, top_k=4, tile_m=16)
    tokens, weights, _, gates = rand_case(dims, 11)
    ids = scenario_ids(dims, scenario)
    got, want, plan = run_pair(dims, tokens, weights, ids, gates)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=3e-5, atol=3e-5)
    counts = np.array(plan.counts)
    if scenario == "best":
        assert (counts > 0).sum() == dims.top_k  # E - k experts are empty
    if scenario == "worst":
        assert (counts == 1).sum() == dims.experts - dims.top_k


def test_single_expert_everything():
    dims = MoeDims(seq=32, d_model=16, d_ff=16, experts=8, top_k=2, tile_m=8)
    tokens, weights, _, gates = rand_case(dims, 3)
    ids = jnp.zeros((dims.seq, dims.top_k), jnp.int32)
    got, want, plan = run_pair(dims, tokens, weights, ids, gates)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=3e-5, atol=3e-5)
    assert int(plan.counts[0]) == dims.seq * dims.top_k


def test_zero_gates_ignored():
    dims = BASE
    tokens, weights, ids, gates = rand_case(dims, 9)
    gates = gates.at[:, 1].set(0.0)
    got, want, _ = run_pair(dims, tokens, weights, ids, gates)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=3e-5, atol=3e-5)


def test_bf16_tokens():
    dims = MoeDims(seq=32, d_model=32, d_ff=32, experts=4, top_k=2, tile_m=16)
    tokens, weights, ids, gates = rand_case(dims, 5)
    tokens = tokens.astype(jnp.bfloat16)
    weights = weights.astype(jnp.bfloat16)
    got, want, _ = run_pair(dims, tokens, weights, ids, gates)
    np.testing.assert_allclose(
        np.array(got, np.float32), np.array(want, np.float32), rtol=3e-2, atol=3e-2
    )


def test_counts_match_ref():
    tokens, weights, ids, gates = rand_case(BASE, 13)
    plan = metadata.build_plan(ids, gates, BASE)
    want = expert_counts_ref(ids, BASE.experts)
    np.testing.assert_array_equal(np.array(plan.counts), np.array(want))


@settings(max_examples=25, deadline=None)
@given(
    seq=st.integers(4, 64),
    experts=st.integers(1, 12),
    top_k=st.integers(1, 4),
    tile_m=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(seq, experts, top_k, tile_m, seed):
    """Property: for ANY routing, kernel+metadata == dense reference."""
    dims = MoeDims(seq=seq, d_model=16, d_ff=24, experts=experts,
                   top_k=min(top_k, experts), tile_m=tile_m)
    tokens, weights, ids, gates = rand_case(dims, seed % 10_000)
    got, want, plan = run_pair(dims, tokens, weights, ids, gates)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=5e-5, atol=5e-5)
    # plan invariants
    assert int(plan.counts.sum()) == dims.seq * dims.top_k
    tp = np.array(plan.tile_prefix)
    assert (np.diff(tp) >= 0).all(), "prefix must be non-decreasing"
    assert int(plan.num_tiles[0]) == tp[-1]
    assert int(plan.num_tiles[0]) <= dims.max_tiles
