//! END-TO-END serving driver (the DESIGN.md "E2E" experiment).
//!
//! Boots the full stack — PJRT runtime loading the AOT transformer
//! artifacts, admission queue, continuous batcher, and the backend-generic
//! serving core (`staticbatch::serve::Server`) with the PJRT engine as its
//! step executor — then drives a synthetic multi-client workload through
//! it in-process and reports latency percentiles and throughput.  Nothing
//! Python runs here.  The GPU-free twin of this driver is the
//! `sim_serving` example (default features).
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example moe_serving
//!   cargo run --release --example moe_serving -- 200 8   # requests, clients

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use staticbatch::coordinator::engine::{Engine, EngineConfig};
use staticbatch::coordinator::request::Request;
use staticbatch::util::rng::Rng;

// (engine construction happens inside Engine::spawn — the PJRT client is
// pinned to its serving thread)

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(60);
    let n_clients: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }

    let t0 = Instant::now();
    let handle = Engine::spawn(EngineConfig { artifacts_dir: dir, ..Default::default() })
        .expect("engine spawn");
    let lm = handle.lm.clone();
    println!(
        "engine up in {:.1}s: buckets {:?}, vocab {}, {} experts, {} params tensors",
        t0.elapsed().as_secs_f64(),
        lm.buckets,
        lm.vocab,
        lm.experts,
        lm.param_shapes.len(),
    );

    let queue = Arc::clone(&handle.queue);
    let metrics = Arc::clone(&handle.metrics);

    // synthetic clients: mixed request lengths, Poisson-ish think time
    let t_load = Instant::now();
    let mut client_threads = Vec::new();
    for c in 0..n_clients {
        let queue = Arc::clone(&queue);
        let per_client = n_requests / n_clients + usize::from(c < n_requests % n_clients);
        client_threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64 + 1);
            let mut latencies = Vec::new();
            for i in 0..per_client {
                let len = match rng.below(3) {
                    0 => 4 + rng.usize_below(12),   // short
                    1 => 20 + rng.usize_below(40),  // medium
                    _ => 80 + rng.usize_below(170), // long
                };
                let tokens: Vec<i32> = (0..len).map(|_| rng.below(1000) as i32).collect();
                let (tx, rx) = channel();
                let req = Request {
                    id: (c * 1_000_000 + i) as u64,
                    tokens,
                    enqueued: Instant::now(),
                    respond: tx,
                };
                queue.push(req);
                let resp = rx.recv().expect("response");
                assert!(resp.error.is_none(), "request failed: {:?}", resp.error);
                assert_eq!(resp.argmax.len(), len);
                latencies.push(resp.latency_s);
            }
            latencies
        }));
    }
    for t in client_threads {
        t.join().unwrap();
    }
    let wall = t_load.elapsed().as_secs_f64();
    handle.shutdown();

    let snap = metrics.snapshot();
    println!("\n=== E2E serving results ({n_requests} requests, {n_clients} clients) ===");
    println!("{}", snap.render());
    println!("wall time {wall:.2}s -> {:.2} req/s end-to-end", snap.requests as f64 / wall);
    let rows: Vec<String> = snap
        .expert_rows
        .iter()
        .enumerate()
        .filter(|(_, &r)| r > 0)
        .take(8)
        .map(|(e, r)| format!("e{e}:{r}"))
        .collect();
    if !rows.is_empty() {
        println!("expert load head: {}", rows.join(" "));
    }
    println!("\nE2E experiment (DESIGN.md index) complete");
}
