//! EP/TP parallelism sweep (paper Section 2.2): how expert-load imbalance
//! becomes *device*-load imbalance under expert parallelism, and where
//! TP's finer-grained sharding + all-reduce wins or loses.
//!
//! Run: `cargo run --release --example multi_gpu`

use staticbatch::moe::config::MoeShape;
use staticbatch::moe::parallel::{simulate, ParallelConfig};
use staticbatch::moe::routing::LoadScenario;
use staticbatch::sim::specs::GpuSpec;
use staticbatch::util::bench::Table;

fn main() {
    let shape = MoeShape::paper_table1();
    let spec = GpuSpec::h800();
    let configs = [
        ("1 GPU", ParallelConfig::new(1, 1)),
        ("EP8", ParallelConfig::new(8, 1)),
        ("EP4xTP2", ParallelConfig::new(4, 2)),
        ("EP2xTP4", ParallelConfig::new(2, 4)),
        ("TP8", ParallelConfig::new(1, 8)),
    ];
    for sc in [LoadScenario::Balanced, LoadScenario::Zipf(1.2), LoadScenario::Best] {
        let load = sc.counts(&shape, 0);
        println!("=== {} (imbalance {:.2}) ===", sc.name(), load.imbalance());
        let mut t = Table::new(&[
            "config", "gpus", "step(ms)", "kernel(ms)", "a2a(us)", "allreduce(us)",
            "agg TFLOPS", "scaling eff%",
        ]);
        let base = simulate(&shape, &load, &ParallelConfig::new(1, 1), &spec).step_time_s;
        for (name, cfg) in &configs {
            let r = simulate(&shape, &load, cfg, &spec);
            let eff = base / r.step_time_s / cfg.gpus() as f64 * 100.0;
            t.row(&[
                name.to_string(),
                cfg.gpus().to_string(),
                format!("{:.3}", r.step_time_s * 1e3),
                format!("{:.3}", r.critical_kernel_s * 1e3),
                format!("{:.1}", r.all_to_all_s * 1e6),
                format!("{:.1}", r.all_reduce_s * 1e6),
                format!("{:.0}", r.total_tflops),
                format!("{eff:.0}"),
            ]);
        }
        t.print();
        println!();
    }
    println!("EP converts expert skew into idle GPUs (best case: 1 busy rank of 8);");
    println!("TP stays balanced but pays all-reduce and loses per-GEMM intensity.");
}
