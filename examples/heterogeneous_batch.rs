//! Heterogeneous batching demo (paper Section 3.2): GEMM tiles, reductions,
//! and element-wise tasks fused into ONE conceptual kernel, dispatched per
//! block through the compressed mapping — with real numerics on CPU.
//!
//! Device functions are registered on a `DispatchTableBuilder`; the batch
//! validates coverage of every task kind at construction, so a missing
//! `taskFunc_i` is an `Err` here, never a panic mid-launch.
//!
//! Run: `cargo run --release --example heterogeneous_batch`

use staticbatch::batching::dispatch::DispatchTableBuilder;
use staticbatch::batching::framework::StaticBatch;
use staticbatch::batching::task::{TaskDescriptor, TaskKind};
use staticbatch::util::rng::Rng;
use staticbatch::util::tensor::Tensor;

/// Shared context: the "device memory" all tasks operate on.
struct Ctx {
    gemm_a: Tensor,        // [256, 64]
    gemm_b: Tensor,        // [64, 128]
    gemm_c: Tensor,        // [256, 128]
    reduce_in: Tensor,     // [96, 256]
    reduce_out: Vec<f32>,  // [96]
    ew_buf: Vec<f32>,      // [5000]
    blocks_run: usize,
}

fn main() {
    let mut rng = Rng::new(42);
    let mut ctx = Ctx {
        gemm_a: Tensor::randn(&[256, 64], 1.0, &mut rng),
        gemm_b: Tensor::randn(&[64, 128], 1.0, &mut rng),
        gemm_c: Tensor::zeros(&[256, 128]),
        reduce_in: Tensor::randn(&[96, 256], 1.0, &mut rng),
        reduce_out: vec![0.0; 96],
        ew_buf: (0..5000).map(|i| i as f32).collect(),
        blocks_run: 0,
    };

    // Three heterogeneous tasks in one batch (different kinds AND tilings):
    let tasks = vec![
        TaskDescriptor {
            kind: TaskKind::Gemm { strategy: 0 },
            rows: 256,
            cols: 128,
            inner: 64,
            tile_rows: 64,
            tile_cols: 128,
        },
        TaskDescriptor {
            kind: TaskKind::ReduceSum,
            rows: 96,
            cols: 1,
            inner: 256,
            tile_rows: 32,
            tile_cols: 1,
        },
        TaskDescriptor {
            kind: TaskKind::ElementWise,
            rows: 5000,
            cols: 1,
            inner: 0,
            tile_rows: 1024,
            tile_cols: 1,
        },
    ];

    let table = DispatchTableBuilder::<Ctx>::new()
        // device function 1: GEMM tile
        .on(TaskKind::Gemm { strategy: 0 }, |c: &mut Ctx, desc, _task, tile| {
            c.blocks_run += 1;
            let tiles_n = desc.tiles_n() as u32;
            let (mi, ni) = (tile / tiles_n, tile % tiles_n);
            let (tm, tn) = (desc.tile_rows, desc.tile_cols);
            let (k, n) = (desc.inner, desc.cols);
            for r in 0..tm.min(desc.rows - mi as usize * tm) {
                let row = mi as usize * tm + r;
                for cc in 0..tn.min(n - ni as usize * tn) {
                    let col = ni as usize * tn + cc;
                    let mut acc = 0.0;
                    for kk in 0..k {
                        acc += c.gemm_a.data[row * k + kk] * c.gemm_b.data[kk * n + col];
                    }
                    c.gemm_c.data[row * n + col] = acc;
                }
            }
        })
        // device function 2: row-sum reduction tile
        .on(TaskKind::ReduceSum, |c: &mut Ctx, desc, _task, tile| {
            c.blocks_run += 1;
            let r0 = tile as usize * desc.tile_rows;
            for r in r0..(r0 + desc.tile_rows).min(desc.rows) {
                c.reduce_out[r] = c.reduce_in.row(r).iter().sum();
            }
        })
        // device function 3: element-wise x -> 2x+1 tile
        .on(TaskKind::ElementWise, |c: &mut Ctx, desc, _task, tile| {
            c.blocks_run += 1;
            let i0 = tile as usize * desc.tile_rows;
            for i in i0..(i0 + desc.tile_rows).min(desc.rows) {
                c.ew_buf[i] = 2.0 * c.ew_buf[i] + 1.0;
            }
        });

    // coverage of all three kinds is checked HERE, before any block runs
    let batch: StaticBatch<Ctx> =
        StaticBatch::try_new(tasks, table).expect("every task kind has a device function");

    let (blocks, warp_passes) = batch.run_simt(&mut ctx);
    println!(
        "fused kernel: {} blocks over {} heterogeneous tasks ({} warp passes for mapping)",
        blocks,
        batch.tasks().len(),
        warp_passes
    );

    // verify all three results
    let want_gemm = ctx.gemm_a.matmul(&ctx.gemm_b);
    let gemm_err = ctx.gemm_c.max_abs_diff(&want_gemm);
    let reduce_err = (0..96)
        .map(|r| (ctx.reduce_out[r] - ctx.reduce_in.row(r).iter().sum::<f32>()).abs())
        .fold(0.0f32, f32::max);
    let ew_err = (0..5000)
        .map(|i| (ctx.ew_buf[i] - (2.0 * i as f32 + 1.0)).abs())
        .fold(0.0f32, f32::max);
    println!("GEMM max err {gemm_err:.2e} | reduce max err {reduce_err:.2e} | elementwise max err {ew_err:.2e}");
    assert!(gemm_err < 1e-3 && reduce_err < 1e-3 && ew_err < 1e-6);
    println!("heterogeneous batch OK — one kernel, three task types, three tilings");
}
