//! Unbalanced-expert-load sweep: ours vs grouped GEMM vs naive loop as
//! routing skew grows (zipf alpha 0 -> 2), on H800 and H20.  Shows the
//! crossover structure the paper's motivation section describes: everyone
//! is fine when balanced; the gap opens with imbalance.  All four
//! executors run behind the one `Backend` trait.
//!
//! Run: `cargo run --release --example unbalanced_sweep`

use staticbatch::exec::{all_backends, ExecutionSession};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::routing::LoadScenario;
use staticbatch::sim::specs::GpuSpec;
use staticbatch::util::bench::Table;
use staticbatch::util::stats::geomean;

fn main() {
    let shape = MoeShape::paper_table1();
    let seeds = 3u64;
    for spec in [GpuSpec::h800(), GpuSpec::h20()] {
        println!("=== {} ===", spec.name);
        // one session per backend, reused across the whole sweep
        let mut sessions: Vec<ExecutionSession> = all_backends()
            .into_iter()
            .map(|b| ExecutionSession::new(shape).gpu(spec.clone()).boxed_backend(b))
            .collect();
        let mut table = Table::new(&["alpha", "imbalance", "ours(ms)", "grouped", "two-phase", "naive", "best speedup"]);
        for &alpha in &[0.0, 0.5, 1.0, 1.5, 2.0] {
            let mut times: Vec<Vec<f64>> = vec![Vec::new(); sessions.len()];
            let mut imb = 0.0;
            for seed in 0..seeds {
                let load = LoadScenario::Zipf(alpha).counts(&shape, seed);
                imb += load.imbalance() / seeds as f64;
                for (i, s) in sessions.iter_mut().enumerate() {
                    times[i].push(s.run(&load).expect("accounting backend").time_s());
                }
            }
            let mean: Vec<f64> =
                times.iter().map(|v| v.iter().sum::<f64>() / v.len() as f64).collect();
            let speedups: Vec<f64> = (1..4).map(|i| mean[i] / mean[0]).collect();
            table.row(&[
                format!("{alpha:.1}"),
                format!("{imb:.2}"),
                format!("{:.3}", mean[0] * 1e3),
                format!("{:.2}x", mean[1] / mean[0]),
                format!("{:.2}x", mean[2] / mean[0]),
                format!("{:.2}x", mean[3] / mean[0]),
                format!("{:.2}x", speedups.iter().cloned().fold(f64::MIN, f64::max)),
            ]);
        }
        table.print();
        let _ = geomean(&[1.0]);
        println!();
    }
    println!("(columns 4-6: slowdown of each baseline relative to ours; >1x means ours wins)");
}
