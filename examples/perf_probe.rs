//! Perf probe: measures the simulator and planner hot paths (the Section
//! Perf iteration log in DESIGN.md's experiment index), driven through the
//! unified `ExecutionSession`/`Backend` surface.
//!
//! Run: `cargo run --release --example perf_probe`

use staticbatch::exec::{ExecutionSession, SimBackend};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::routing::LoadScenario;
use staticbatch::sim::specs::GpuSpec;
use std::time::Instant;

fn main() {
    let shape = MoeShape::paper_table1();
    let load = LoadScenario::Worst.counts(&shape, 0);
    let mut session = ExecutionSession::new(shape)
        .backend(SimBackend::ours())
        .gpu(GpuSpec::h800());
    let plan = session.plan(&load);
    // warm
    for _ in 0..3 {
        std::hint::black_box(session.run_plan(&plan).unwrap());
    }
    let iters = 200;
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(session.run_plan(&plan).unwrap());
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    let blocks = plan.total_tiles() as f64;
    println!(
        "simulate_ours: {:.1} us/step, {:.2} M blocks/s ({} tiles)",
        dt * 1e6,
        blocks / dt / 1e6,
        blocks
    );
    // plan construction
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(session.plan(&load));
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    println!("plan: {:.1} us", dt * 1e6);
    // footnote shape (16384 tiles)
    let shape2 = MoeShape::paper_table1_best_h800();
    let load2 = LoadScenario::Best.counts(&shape2, 0);
    let mut session2 = ExecutionSession::new(shape2)
        .backend(SimBackend::ours())
        .gpu(GpuSpec::h800());
    let plan2 = session2.plan(&load2);
    let t0 = Instant::now();
    for _ in 0..20 {
        std::hint::black_box(session2.run_plan(&plan2).unwrap());
    }
    let dt = t0.elapsed().as_secs_f64() / 20.0;
    println!(
        "simulate big: {:.1} us/step, {:.2} M blocks/s ({} tiles)",
        dt * 1e6,
        plan2.total_tiles() as f64 / dt / 1e6,
        plan2.total_tiles()
    );
}
