//! Perf probe: measures the simulator and planner hot paths used by
//! the Section Perf iteration log in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example perf_probe`

use staticbatch::moe::config::MoeShape;
use staticbatch::moe::planner::Planner;
use staticbatch::moe::routing::LoadScenario;
use staticbatch::sim::{kernel_sim, specs::GpuSpec};
use std::time::Instant;
fn main() {
    let shape = MoeShape::paper_table1();
    let load = LoadScenario::Worst.counts(&shape, 0);
    let plan = Planner::new(shape).plan(&load);
    let spec = GpuSpec::h800();
    // warm
    for _ in 0..3 { std::hint::black_box(kernel_sim::simulate_ours(&plan, &spec)); }
    let iters = 200;
    let t0 = Instant::now();
    for _ in 0..iters { std::hint::black_box(kernel_sim::simulate_ours(&plan, &spec)); }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    let blocks = plan.total_tiles() as f64;
    println!("simulate_ours: {:.1} us/step, {:.2} M blocks/s ({} tiles)", dt*1e6, blocks/dt/1e6, blocks);
    // plan construction
    let t0 = Instant::now();
    for _ in 0..iters { std::hint::black_box(Planner::new(shape).plan(&load)); }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    println!("plan: {:.1} us", dt*1e6);
    // footnote shape (16384 tiles)
    let shape2 = MoeShape::paper_table1_best_h800();
    let plan2 = Planner::new(shape2).plan(&LoadScenario::Best.counts(&shape2, 0));
    let t0 = Instant::now();
    for _ in 0..20 { std::hint::black_box(kernel_sim::simulate_ours(&plan2, &spec)); }
    let dt = t0.elapsed().as_secs_f64() / 20.0;
    println!("simulate big: {:.1} us/step, {:.2} M blocks/s ({} tiles)", dt*1e6, plan2.total_tiles() as f64/dt/1e6, plan2.total_tiles());
}
