//! Quickstart: the paper's framework in ~40 lines.
//!
//! Builds the paper's Table 1 balanced scenario, plans it with the static
//! batching framework (compressed TilePrefix + σ + per-expert tiling +
//! half-interval ordering), and simulates it on H800 and H20 — everything
//! through the one `ExecutionSession` → `Backend` surface.
//!
//! Run: `cargo run --release --example quickstart`

use staticbatch::exec::{ExecutionSession, SimBackend};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::routing::LoadScenario;
use staticbatch::sim::specs::GpuSpec;

fn main() {
    // 1. the workload: 4096 tokens, top-8 of 64 experts, weight [3584,2560]
    let shape = MoeShape::paper_table1();

    // 2. a routing outcome (balanced here; try Worst or Zipf(1.2))
    let load = LoadScenario::Balanced.counts(&shape, 0);
    println!(
        "routing: {} rows over {} experts ({} empty), imbalance {:.2}",
        load.total(),
        shape.experts,
        load.num_empty(),
        load.imbalance()
    );

    // 3. the static batch plan: σ-compaction of empty experts (Alg. 4),
    //    per-expert tiling, half-interval ordering, TilePrefix (Alg. 1) —
    //    the session owns plan construction
    let session = ExecutionSession::new(shape);
    let plan = session.plan(&load);
    println!(
        "plan: {} non-empty tasks, {} tiles, {} B of metadata",
        plan.num_nonempty(),
        plan.total_tiles(),
        plan.metadata_bytes()
    );

    // 4. decompress a few mappings exactly like the kernel does (Alg. 2)
    for block in [0u32, 1, 100, plan.total_tiles() - 1] {
        let m = plan.two_stage.map(block);
        println!("  block {block:>5} -> expert {:>2}, tile {:>3}", m.task, m.tile);
    }

    // 5. simulate on both paper GPUs: same session shape, swap the GPU spec
    for spec in [GpuSpec::h20(), GpuSpec::h800()] {
        let name = spec.name;
        let outcome = ExecutionSession::new(shape)
            .backend(SimBackend::ours())
            .gpu(spec)
            .run(&load)
            .expect("sim backend");
        println!("{:>5}: {}", name, outcome.sim().summary());
    }
}
