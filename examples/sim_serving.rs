//! Sim-backed serving demo (default features — no GPU, artifacts, or XLA).
//!
//! The same backend-generic serving core the PJRT engine runs
//! (`queue → batcher → PlanCache → StepExecutor → metrics`), instantiated
//! with the sim/CPU MoE executor and driven by synthetic open-loop
//! traffic.  Run:
//!   cargo run --release --example sim_serving
//!   cargo run --release --example sim_serving -- 500 200   # requests, req/s

use staticbatch::coordinator::batcher::BatchPolicy;
use staticbatch::serve::{
    run_traffic, Server, ServerConfig, SimServeConfig, SimStepExecutor, StepExecutor,
    TrafficConfig,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let rate_hz: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(400.0);

    let sim_cfg = SimServeConfig::default();
    let max_tokens = sim_cfg.max_tokens;
    let executor = SimStepExecutor::new(sim_cfg);
    println!(
        "sim serving core up: shape {:?}, buckets {:?}",
        executor.shape(),
        executor.buckets()
    );

    let mut server = Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 16, max_tokens },
            queue_capacity: 512,
            ..ServerConfig::default()
        },
        executor,
    );

    let report = run_traffic(
        &mut server,
        TrafficConfig { requests, rate_hz, ..TrafficConfig::default() },
    );
    println!("\n=== sim serving results ({requests} requests @ {rate_hz} req/s) ===");
    print!("{}", report.render());
    println!(
        "\nexecutor ran {} packed steps for {} requests",
        server.executor().steps(),
        report.ok
    );
}
