//! A1: ours vs grouped GEMM / two-phase / naive loop; A5 token-copy table.
//!
//! All four executors are benched through the one
//! `ExecutionSession`/`Backend` harness: per scenario we report both the
//! *simulated* GPU time (the experiment) and the *host wallclock* of
//! plan construction + backend execution (the cost of running it).

use staticbatch::exec::{all_backends, bench::time_session, ExecutionSession};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::routing::LoadScenario;
use staticbatch::sim::specs::GpuSpec;
use staticbatch::util::bench::Table;

fn main() {
    println!("== A1: baselines across paper scenarios ==");
    print!("{}", staticbatch::reports::baselines_table());

    println!("\n== A1 harness: wallclock of plan+execute per backend (H800) ==");
    let shape = MoeShape::paper_table1();
    let mut t = Table::new(&[
        "backend", "scenario", "sim time(ms)", "host mean(us)", "host p95(us)", "blocks",
    ]);
    for b in all_backends() {
        let mut session = ExecutionSession::new(shape).gpu(GpuSpec::h800()).boxed_backend(b);
        for sc in [LoadScenario::Balanced, LoadScenario::Worst, LoadScenario::Zipf(1.2)] {
            let load = sc.counts(&shape, 0);
            let label = format!("{}/{}", session.backend_name(), sc.name());
            let (timing, out) =
                time_session(&label, &mut session, &load, 2, 15).expect("backend runs");
            t.row(&[
                out.backend.to_string(),
                sc.name(),
                format!("{:.3}", out.time_s() * 1e3),
                format!("{:.1}", timing.mean_us()),
                format!("{:.1}", timing.p95_ns / 1e3),
                out.blocks.to_string(),
            ]);
        }
    }
    t.print();

    println!("\n== A5: token copy elimination ==");
    print!("{}", staticbatch::reports::token_copy_table());
}
