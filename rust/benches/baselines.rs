//! A1: ours vs grouped GEMM / two-phase / naive loop; A5 token-copy table.
fn main() {
    println!("== A1: baselines across paper scenarios ==");
    print!("{}", staticbatch::reports::baselines_table());
    println!("\n== A5: token copy elimination ==");
    print!("{}", staticbatch::reports::token_copy_table());
}
