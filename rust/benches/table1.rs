//! Regenerates the paper's Table 1 plus the Section 4.4 tile-swizzle
//! ablation (see DESIGN.md experiment index).
//!
//! The harness section wallclock-benches the full Table-1 cell pipeline
//! (plan + simulate) per scenario/GPU through the unified
//! `ExecutionSession`/`Backend` surface.

use staticbatch::exec::{bench::time_session, ExecutionSession, SimBackend};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::routing::LoadScenario;
use staticbatch::sim::specs::GpuSpec;
use staticbatch::util::bench::Table;

fn main() {
    println!("== Table 1: MoE kernel on H20/H800 (simulated) vs paper ==");
    print!("{}", staticbatch::reports::table1());

    println!("\n== Table 1 harness: per-cell plan+simulate wallclock ==");
    let mut t = Table::new(&["case", "gpu", "peak%", "host mean(us)", "host p95(us)"]);
    for gpu in ["H20", "H800"] {
        for sc in [LoadScenario::Balanced, LoadScenario::Best, LoadScenario::Worst] {
            let shape = if sc == LoadScenario::Best && gpu == "H800" {
                MoeShape::paper_table1_best_h800()
            } else {
                MoeShape::paper_table1()
            };
            let load = sc.counts(&shape, 0);
            let mut session = ExecutionSession::new(shape)
                .backend(SimBackend::ours())
                .gpu(GpuSpec::by_name(gpu).unwrap());
            let label = format!("{}/{gpu}", sc.name());
            let (timing, out) =
                time_session(&label, &mut session, &load, 2, 20).expect("sim backend");
            t.row(&[
                sc.name(),
                gpu.into(),
                format!("{:.2}", out.sim().peak_frac * 100.0),
                format!("{:.1}", timing.mean_us()),
                format!("{:.1}", timing.p95_ns / 1e3),
            ]);
        }
    }
    t.print();

    println!("\n== A6: L2 tile swizzle ablation (footnote-1 workload, H800) ==");
    print!("{}", staticbatch::reports::swizzle_table());
}
