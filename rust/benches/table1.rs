//! Regenerates the paper's Table 1 plus the Section 4.4 tile-swizzle
//! ablation (see DESIGN.md experiment index).
fn main() {
    println!("== Table 1: MoE kernel on H20/H800 (simulated) vs paper ==");
    print!("{}", staticbatch::reports::table1());
    println!("\n== A6: L2 tile swizzle ablation (footnote-1 workload, H800) ==");
    print!("{}", staticbatch::reports::swizzle_table());
}
