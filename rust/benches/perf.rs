//! Perf trajectory bench (default features): parallel numeric throughput
//! and zero-alloc cache-hit planning, distilled to `BENCH_perf.json`.
//!
//! Measures, for both numeric workloads (MoE expert GEMMs and ragged
//! flash-decode attention):
//!
//! * tokens/s and steps/s through [`ExecutionSession`] +
//!   [`CpuBackend`] at 1/2/4/8 worker threads, with per-step p50/p99
//!   latency, asserting every parallel output is **bitwise-equal** to the
//!   serial one;
//! * allocations per plan-cache *hit* (via a counting global allocator) —
//!   the zero-alloc hot-path claim, checked unconditionally: a nonzero
//!   count fails the bench on any machine;
//! * allocations per *serving step* for the sim and fused executors (the
//!   reusable routing/index/embed buffers): steady-state steps must
//!   allocate strictly less than the cold first step, gated on any machine;
//! * whole-grid mapping decode throughput — the run-based
//!   `map_all_into` prefix scan against the per-block cursor walk it
//!   replaced, bitwise-checked and reported as blocks/s.
//!
//! With `--json <path>` (how `scripts/bench_distill` invokes it) the run
//! writes the machine-readable summary.  With `--enforce-speedup` the run
//! additionally fails unless MoE tokens/s at 4 threads reaches 1.5× the
//! serial rate — applied only when the host has at least 4 cores, so
//! single-core containers still run the bench for its numbers and the
//! alloc gate without a meaningless speedup failure.
//!
//! Unlike `BENCH_serving.json` (virtual clock, byte-deterministic), the
//! throughput numbers here are wall-clock and machine-dependent; the
//! committed artifact records the trajectory on the machine that produced
//! it, while the gates (bitwise equality, zero hit allocations, relative
//! speedup) are machine-independent.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use staticbatch::batching::mapping::{map_all_into, MapCursor, TileMapping};
use staticbatch::batching::tile_prefix::build_from_counts;
use staticbatch::exec::{CpuBackend, ExecutionSession, NumericInputs};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::routing::LoadScenario;
use staticbatch::serve::{
    FusedServeConfig, FusedStepExecutor, SimServeConfig, SimStepExecutor, StepExecutor, StepInput,
};
use staticbatch::util::json::Json;
use staticbatch::util::rng::Rng;
use staticbatch::util::stats::Samples;
use staticbatch::util::tensor::Tensor;
use staticbatch::workload::ragged::{RaggedAttentionWorkload, RaggedInputs, RaggedScenario};

/// Global allocator that counts allocation events (alloc + realloc), so
/// the bench can assert the plan-cache hit path performs none.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];
const STEPS: usize = 24;

/// One timed configuration of one workload.
struct Run {
    threads: usize,
    tokens_per_s: f64,
    steps_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    bitwise_equal_serial: bool,
}

/// Round to `digits` decimal places so the emitted JSON stays diffable.
fn round_to(x: f64, digits: i32) -> f64 {
    let p = 10f64.powi(digits);
    (x * p).round() / p
}

impl Run {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("threads", Json::num(self.threads as f64)),
            ("tokens_per_s", Json::num(round_to(self.tokens_per_s, 1))),
            ("steps_per_s", Json::num(round_to(self.steps_per_s, 2))),
            ("p50_ms", Json::num(round_to(self.p50_ms, 3))),
            ("p99_ms", Json::num(round_to(self.p99_ms, 3))),
            ("bitwise_equal_serial", Json::Bool(self.bitwise_equal_serial)),
        ])
    }
}

/// Time `steps` runs of `session.run(load)`, returning per-step stats and
/// the final numeric output for the bitwise cross-check.
fn time_steps<F>(mut run_step: F, steps: usize, tokens_per_step: usize) -> (Run, Tensor)
where
    F: FnMut() -> Tensor,
{
    // warmup: plan-cache miss, pool spin-up, allocator steady state
    let _ = run_step();
    let _ = run_step();
    let mut samples = Samples::new();
    let mut last = None;
    let t0 = Instant::now();
    for _ in 0..steps {
        let s0 = Instant::now();
        let out = run_step();
        samples.push(s0.elapsed().as_secs_f64() * 1e3);
        last = Some(out);
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-12);
    let run = Run {
        threads: 0, // caller fills in
        tokens_per_s: (tokens_per_step * steps) as f64 / secs,
        steps_per_s: steps as f64 / secs,
        p50_ms: samples.percentile(50.0),
        p99_ms: samples.percentile(99.0),
        bitwise_equal_serial: true, // caller fills in
    };
    (run, last.expect("at least one step"))
}

fn moe_shape() -> MoeShape {
    MoeShape { seq: 512, d_model: 48, d_ff: 128, experts: 32, top_k: 2, dtype_bytes: 4 }
}

/// MoE numeric throughput at one thread count.
fn bench_moe(threads: usize) -> (Run, Tensor) {
    let shape = moe_shape();
    let load = LoadScenario::Zipf(1.2).counts(&shape, 7);
    let numeric = NumericInputs::synthetic(shape, &load, 7);
    let mut s = ExecutionSession::new(shape)
        .backend(CpuBackend)
        .inputs(numeric)
        .plan_cache(8)
        .threads(threads);
    let (mut run, out) = time_steps(
        || s.run(&load).expect("cpu step").output.expect("numeric output"),
        STEPS,
        shape.seq,
    );
    run.threads = threads;
    (run, out)
}

/// Ragged-attention numeric throughput at one thread count.  One decode
/// token per sequence per step, so tokens/step = batch size.
fn bench_ragged(threads: usize) -> (Run, Tensor) {
    let w = RaggedAttentionWorkload { heads: 8, head_dim: 32, dtype_bytes: 4 };
    let load = RaggedScenario::Zipf(1.2, 2048).lens(64, 5);
    let inputs = RaggedInputs::synthetic(&w, &load, 5);
    let mut s = ExecutionSession::for_workload(w)
        .backend(CpuBackend)
        .inputs(inputs)
        .plan_cache(8)
        .threads(threads);
    let seqs = load.lens.len();
    let (mut run, out) = time_steps(
        || s.run(&load).expect("ragged step").output.expect("numeric output"),
        STEPS,
        seqs,
    );
    run.threads = threads;
    (run, out)
}

/// Allocations per plan-cache *hit* for the MoE planner (expected: 0).
fn moe_hit_allocs_per_lookup() -> f64 {
    let shape = moe_shape();
    let load = LoadScenario::Zipf(1.2).counts(&shape, 7);
    let mut s = ExecutionSession::new(shape).plan_cache(8);
    let _ = s.plan_shared(&load); // miss: builds and caches
    let _ = s.plan_shared(&load); // first hit settles scratch capacity
    const N: u64 = 100;
    let before = alloc_count();
    for _ in 0..N {
        let p = s.plan_shared(&load);
        std::hint::black_box(&p);
    }
    let after = alloc_count();
    (after - before) as f64 / N as f64
}

/// Allocations per plan-cache *hit* for the ragged planner (expected: 0).
fn ragged_hit_allocs_per_lookup() -> f64 {
    let w = RaggedAttentionWorkload { heads: 8, head_dim: 32, dtype_bytes: 4 };
    let load = RaggedScenario::Zipf(1.2, 2048).lens(64, 5);
    let mut s = ExecutionSession::for_workload(w).plan_cache(8);
    let _ = s.plan_shared(&load);
    let _ = s.plan_shared(&load);
    const N: u64 = 100;
    let before = alloc_count();
    for _ in 0..N {
        let p = s.plan_shared(&load);
        std::hint::black_box(&p);
    }
    let after = alloc_count();
    (after - before) as f64 / N as f64
}

/// Allocations for the cold first serving step (plan-cache miss, buffer
/// growth) and per steady-state step (cache hits, buffers reused in place)
/// through one executor.  Counts are deterministic: serial, no pool.
fn serve_allocs(mut ex: impl StepExecutor, bucket: usize, rows: usize) -> (u64, f64) {
    let tokens: Vec<i32> = (0..rows * bucket).map(|i| (i % 37) as i32).collect();
    let step = StepInput { bucket, rows, tokens: &tokens };
    let before = alloc_count();
    let out = ex.execute_step(&step).expect("cold step");
    std::hint::black_box(&out);
    let cold = alloc_count() - before;
    // one warm step settles allocator/buffer capacities before measuring
    let out = ex.execute_step(&step).expect("warm step");
    std::hint::black_box(&out);
    const N: u64 = 50;
    let before = alloc_count();
    for _ in 0..N {
        let out = ex.execute_step(&step).expect("steady step");
        std::hint::black_box(&out);
    }
    let steady = (alloc_count() - before) as f64 / N as f64;
    (cold, steady)
}

/// Whole-grid mapping decode throughput (wall clock): the run-based
/// `map_all_into` prefix scan against the per-block cursor walk it
/// replaced, over a large grid, bitwise-checked against each other.
struct MappingBench {
    tasks: usize,
    total_blocks: u64,
    cursor_blocks_per_s: f64,
    run_blocks_per_s: f64,
    bitwise_equal: bool,
}

fn bench_mapping() -> MappingBench {
    const TASKS: usize = 4096;
    const REPS: usize = 200;
    let mut rng = Rng::new(9);
    let tiles: Vec<u32> = (0..TASKS).map(|_| rng.below(6) as u32).collect();
    let prefix = build_from_counts(&tiles);
    let total: u32 = tiles.iter().sum();

    let mut cursor_out: Vec<TileMapping> = Vec::new();
    let mut run_out: Vec<TileMapping> = Vec::new();
    let cursor_walk = |out: &mut Vec<TileMapping>| {
        out.clear();
        out.reserve(total as usize);
        let mut c = MapCursor::new();
        for b in 0..total {
            out.push(c.map(&prefix, b));
        }
    };
    // warmup both paths (buffer growth, cache residency)
    cursor_walk(&mut cursor_out);
    map_all_into(&prefix, total, &mut run_out);
    let bitwise_equal = cursor_out == run_out;

    let t0 = Instant::now();
    for _ in 0..REPS {
        cursor_walk(&mut cursor_out);
        std::hint::black_box(&cursor_out);
    }
    let cursor_s = t0.elapsed().as_secs_f64().max(1e-12);
    let t0 = Instant::now();
    for _ in 0..REPS {
        map_all_into(&prefix, total, &mut run_out);
        std::hint::black_box(&run_out);
    }
    let run_s = t0.elapsed().as_secs_f64().max(1e-12);

    let blocks = total as u64 * REPS as u64;
    MappingBench {
        tasks: TASKS,
        total_blocks: total as u64,
        cursor_blocks_per_s: blocks as f64 / cursor_s,
        run_blocks_per_s: blocks as f64 / run_s,
        bitwise_equal,
    }
}

fn sweep(name: &str, bench: impl Fn(usize) -> (Run, Tensor)) -> Vec<Run> {
    let (serial, serial_out) = bench(1);
    let mut runs = vec![serial];
    for &t in &THREAD_COUNTS[1..] {
        let (mut run, out) = bench(t);
        run.bitwise_equal_serial = out.data == serial_out.data && out.shape == serial_out.shape;
        runs.push(run);
    }
    println!("== {name}: CPU numeric throughput (bitwise-checked against serial) ==");
    println!("{:>8} {:>14} {:>10} {:>9} {:>9} {:>8} {:>8}",
        "threads", "tokens/s", "steps/s", "p50 ms", "p99 ms", "speedup", "bitwise");
    let base = runs[0].tokens_per_s;
    for r in &runs {
        println!(
            "{:>8} {:>14.0} {:>10.2} {:>9.3} {:>9.3} {:>7.2}x {:>8}",
            r.threads,
            r.tokens_per_s,
            r.steps_per_s,
            r.p50_ms,
            r.p99_ms,
            r.tokens_per_s / base.max(1e-12),
            if r.bitwise_equal_serial { "ok" } else { "FAIL" },
        );
    }
    println!();
    runs
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_path = args.windows(2).find(|w| w[0] == "--json").map(|w| w[1].clone());
    let enforce_speedup = args.iter().any(|a| a == "--enforce-speedup");
    let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // alloc gate first, before any worker pools exist, so no other thread
    // can touch the counter mid-measurement
    let moe_hit_allocs = moe_hit_allocs_per_lookup();
    let ragged_hit_allocs = ragged_hit_allocs_per_lookup();
    println!("plan-cache hit allocs/lookup: moe {moe_hit_allocs}, ragged {ragged_hit_allocs}");

    // serve-path allocations per step (serial executors: no pool threads
    // can touch the counter mid-measurement)
    let (sim_cold, sim_steady) = serve_allocs(
        SimStepExecutor::new(SimServeConfig { threads: 1, ..SimServeConfig::default() }),
        16,
        8,
    );
    let (fused_cold, fused_steady) = serve_allocs(
        FusedStepExecutor::new(FusedServeConfig { threads: 1, ..FusedServeConfig::default() }),
        16,
        8,
    );
    println!(
        "serve allocs/step (cold -> steady): sim {sim_cold} -> {sim_steady}, \
         fused {fused_cold} -> {fused_steady}"
    );
    println!();

    let mapping = bench_mapping();
    println!(
        "mapping decode ({} tasks, {} blocks/grid): cursor {:.0} blocks/s, \
         run-based {:.0} blocks/s ({:.2}x), bitwise {}",
        mapping.tasks,
        mapping.total_blocks,
        mapping.cursor_blocks_per_s,
        mapping.run_blocks_per_s,
        mapping.run_blocks_per_s / mapping.cursor_blocks_per_s.max(1e-12),
        if mapping.bitwise_equal { "ok" } else { "FAIL" },
    );
    println!();

    let moe_runs = sweep("moe", bench_moe);
    let ragged_runs = sweep("ragged-attn", bench_ragged);

    let mut failures: Vec<String> = Vec::new();
    if moe_hit_allocs != 0.0 {
        failures.push(format!("moe plan-cache hit allocates ({moe_hit_allocs}/lookup)"));
    }
    if ragged_hit_allocs != 0.0 {
        failures.push(format!("ragged plan-cache hit allocates ({ragged_hit_allocs}/lookup)"));
    }
    if sim_steady >= sim_cold as f64 {
        failures.push(format!(
            "sim serve step does not reuse buffers ({sim_steady}/step steady vs {sim_cold} cold)"
        ));
    }
    if fused_steady >= fused_cold as f64 {
        failures.push(format!(
            "fused serve step does not reuse buffers ({fused_steady}/step steady vs {fused_cold} cold)"
        ));
    }
    if !mapping.bitwise_equal {
        failures.push("run-based map_all_into diverges from the cursor walk".to_string());
    }
    for (name, runs) in [("moe", &moe_runs), ("ragged", &ragged_runs)] {
        for r in runs {
            if !r.bitwise_equal_serial {
                failures.push(format!("{name} at {} threads diverges from serial", r.threads));
            }
        }
    }
    let speedup4 = moe_runs
        .iter()
        .find(|r| r.threads == 4)
        .map(|r| r.tokens_per_s / moe_runs[0].tokens_per_s.max(1e-12))
        .unwrap_or(0.0);
    if enforce_speedup {
        if host_cores < 4 {
            println!("speedup gate skipped: host has {host_cores} core(s), need >= 4");
        } else if speedup4 < 1.5 {
            failures.push(format!(
                "moe tokens/s at 4 threads only {speedup4:.2}x serial (need 1.5x)"
            ));
        }
    }

    if let Some(path) = &json_path {
        let doc = Json::obj(vec![
            ("bench", Json::str("perf")),
            ("host_cores", Json::num(host_cores as f64)),
            ("steps_per_config", Json::num(STEPS as f64)),
            (
                "moe",
                Json::obj(vec![
                    ("tokens_per_step", Json::num(moe_shape().seq as f64)),
                    ("speedup_at_4_threads", Json::num(round_to(speedup4, 2))),
                    ("runs", Json::arr(moe_runs.iter().map(Run::to_json))),
                ]),
            ),
            (
                "ragged",
                Json::obj(vec![
                    ("tokens_per_step", Json::num(64.0)),
                    ("runs", Json::arr(ragged_runs.iter().map(Run::to_json))),
                ]),
            ),
            (
                "plan_cache",
                Json::obj(vec![
                    ("moe_hit_allocs_per_lookup", Json::num(moe_hit_allocs)),
                    ("ragged_hit_allocs_per_lookup", Json::num(ragged_hit_allocs)),
                ]),
            ),
            (
                "serve_allocs_per_step",
                Json::obj(vec![
                    (
                        "sim",
                        Json::obj(vec![
                            ("cold", Json::num(sim_cold as f64)),
                            ("steady", Json::num(sim_steady)),
                        ]),
                    ),
                    (
                        "fused",
                        Json::obj(vec![
                            ("cold", Json::num(fused_cold as f64)),
                            ("steady", Json::num(fused_steady)),
                        ]),
                    ),
                ]),
            ),
            (
                "mapping_decode",
                Json::obj(vec![
                    ("tasks", Json::num(mapping.tasks as f64)),
                    ("blocks_per_grid", Json::num(mapping.total_blocks as f64)),
                    (
                        "cursor_blocks_per_s",
                        Json::num(round_to(mapping.cursor_blocks_per_s, 0)),
                    ),
                    (
                        "run_based_blocks_per_s",
                        Json::num(round_to(mapping.run_blocks_per_s, 0)),
                    ),
                    (
                        "speedup",
                        Json::num(round_to(
                            mapping.run_blocks_per_s / mapping.cursor_blocks_per_s.max(1e-12),
                            2,
                        )),
                    ),
                    ("bitwise_equal", Json::Bool(mapping.bitwise_equal)),
                ]),
            ),
        ]);
        std::fs::write(path, format!("{doc}\n")).expect("write bench json");
        println!("wrote {path}");
    }

    if !failures.is_empty() {
        for f in &failures {
            eprintln!("perf gate FAILED: {f}");
        }
        std::process::exit(1);
    }
}
