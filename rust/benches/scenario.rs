//! Scenario bench (default features): the pinned two-tenant burst + fault
//! scenario from the DESIGN.md experiment index entry "SCENARIO", on the
//! virtual clock — no GPU, artifacts, XLA, or wall-clock sleeps anywhere.
//!
//! With `--json <path>` (how `scripts/bench_distill` invokes it) the run
//! also writes a machine-readable summary — tokens/s, steps/s, latency
//! percentiles, and per-tenant SLO attainment — to `<path>`, including a
//! `chaos_goodput` row: the same scenario re-run under a seeded
//! [`ChaosStepExecutor`] injecting 10% transient step faults (absorbed by
//! a 4-attempt retry policy), with the goodput ratio against the clean
//! run — the FAULT experiment's headline number.  Every number is derived
//! from the virtual clock, so the file is deterministic: two runs on any
//! two machines produce identical bytes.

use staticbatch::serve::{
    run_scenario, ChaosConfig, ChaosStepExecutor, PlacementKind, RetryPolicy, ScenarioConfig,
    ScenarioReport, ShardedServeConfig, ShardedStepExecutor, SimServeConfig,
};
use staticbatch::util::json::Json;

fn sharded(seed: u64) -> ShardedStepExecutor {
    ShardedStepExecutor::new(ShardedServeConfig {
        base: SimServeConfig { numeric: false, seed, ..SimServeConfig::default() },
        ep: 4,
        placement: PlacementKind::Balanced,
        ..ShardedServeConfig::default()
    })
}

fn goodput(r: &ScenarioReport) -> f64 {
    r.ok as f64 / r.virtual_s.max(1e-12)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // scan for `--json <path>`, ignoring whatever else cargo bench passes
    let json_path = args.windows(2).find(|w| w[0] == "--json").map(|w| w[1].clone());

    let cfg = ScenarioConfig::default();
    let mut ex = sharded(cfg.seed);
    println!("== SCENARIO: pinned two-tenant burst + shard fault, virtual clock ==");
    let r = run_scenario(&mut ex, &cfg);
    println!("{}", r.render());
    println!();
    print!("{}", staticbatch::reports::scenario_table(cfg.seed));

    // the same scenario under 10% transient chaos, absorbed by retries —
    // virtual backoff time is charged, so goodput dips but requests hold
    let chaos_cfg = ScenarioConfig {
        retry: RetryPolicy {
            max_attempts: 4,
            backoff: std::time::Duration::from_millis(1),
        },
        ..ScenarioConfig::default()
    };
    let mut cex = ChaosStepExecutor::new(
        sharded(chaos_cfg.seed),
        ChaosConfig { transient_rate: 0.1, ..ChaosConfig::default() },
    );
    println!("\n== FAULT: the same scenario under 10% transient chaos + retry ==");
    let rc = run_scenario(&mut cex, &chaos_cfg);
    println!("{}", rc.render());
    println!(
        "\ngoodput: clean {:.1} req/s vs chaos {:.1} req/s (ratio {:.3})",
        goodput(&r),
        goodput(&rc),
        goodput(&rc) / goodput(&r).max(1e-12),
    );

    if let Some(path) = json_path {
        let v = r.virtual_s.max(1e-12);
        let tenants = Json::arr(r.tenants.iter().map(|t| {
            Json::obj(vec![
                ("name", Json::str(t.name.as_str())),
                ("priority", Json::num(f64::from(t.priority))),
                ("sent", Json::num(t.sent as f64)),
                ("ok", Json::num(t.ok as f64)),
                ("failed", Json::num(t.failed as f64)),
                ("shed", Json::num(t.shed as f64)),
                ("expired", Json::num(t.expired as f64)),
                ("p50_ms", Json::num(t.p50_ms)),
                ("p99_ms", Json::num(t.p99_ms)),
                ("slo_attainment", Json::num(t.slo_attainment)),
                ("goodput_rps", Json::num(t.goodput_rps)),
            ])
        }));
        let chaos_row = Json::obj(vec![
            (
                "clean",
                Json::obj(vec![
                    ("ok", Json::num(r.ok as f64)),
                    ("goodput_rps", Json::num(goodput(&r))),
                ]),
            ),
            (
                "chaos",
                Json::obj(vec![
                    ("ok", Json::num(rc.ok as f64)),
                    ("failed", Json::num(rc.failed as f64)),
                    ("expired", Json::num(rc.expired as f64)),
                    ("retries", Json::num(rc.retries as f64)),
                    ("goodput_rps", Json::num(goodput(&rc))),
                ]),
            ),
            ("ratio", Json::num(goodput(&rc) / goodput(&r).max(1e-12))),
        ]);
        let doc = Json::obj(vec![
            ("bench", Json::str("scenario")),
            ("virtual_s", Json::num(r.virtual_s)),
            ("sent", Json::num(r.sent as f64)),
            ("ok", Json::num(r.ok as f64)),
            ("failed", Json::num(r.failed as f64)),
            ("shed", Json::num(r.shed as f64)),
            ("expired", Json::num(r.expired as f64)),
            ("retries", Json::num(r.retries as f64)),
            ("steps", Json::num(r.steps as f64)),
            ("steps_per_s", Json::num(r.steps as f64 / v)),
            ("tokens_per_s", Json::num(r.snapshot.tokens as f64 / v)),
            ("p50_ms", Json::num(r.snapshot.latency_p50_ms)),
            ("p99_ms", Json::num(r.snapshot.latency_p99_ms)),
            ("reshards", Json::num(r.reshards as f64)),
            (
                "recovery_ms",
                match r.recovery_s {
                    Some(s) => Json::num(s * 1e3),
                    None => Json::Null,
                },
            ),
            ("chaos_goodput", chaos_row),
            ("tenants", tenants),
        ]);
        std::fs::write(&path, format!("{doc}\n")).expect("write bench json");
        println!("\nwrote {path}");
    }
}
