//! Scenario bench (default features): the pinned two-tenant burst + fault
//! scenario from the DESIGN.md experiment index entry "SCENARIO", on the
//! virtual clock — no GPU, artifacts, XLA, or wall-clock sleeps anywhere.
//!
//! With `--json <path>` (how `scripts/bench_distill` invokes it) the run
//! also writes a machine-readable summary — tokens/s, steps/s, latency
//! percentiles, and per-tenant SLO attainment — to `<path>`.  Every number
//! is derived from the virtual clock, so the file is deterministic: two
//! runs on any two machines produce identical bytes.

use staticbatch::serve::{
    run_scenario, PlacementKind, ScenarioConfig, ShardedServeConfig, ShardedStepExecutor,
    SimServeConfig,
};
use staticbatch::util::json::Json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // scan for `--json <path>`, ignoring whatever else cargo bench passes
    let json_path = args.windows(2).find(|w| w[0] == "--json").map(|w| w[1].clone());

    let cfg = ScenarioConfig::default();
    let mut ex = ShardedStepExecutor::new(ShardedServeConfig {
        base: SimServeConfig { numeric: false, seed: cfg.seed, ..SimServeConfig::default() },
        ep: 4,
        placement: PlacementKind::Balanced,
        ..ShardedServeConfig::default()
    });
    println!("== SCENARIO: pinned two-tenant burst + shard fault, virtual clock ==");
    let r = run_scenario(&mut ex, &cfg);
    println!("{}", r.render());
    println!();
    print!("{}", staticbatch::reports::scenario_table(cfg.seed));

    if let Some(path) = json_path {
        let v = r.virtual_s.max(1e-12);
        let tenants = Json::arr(r.tenants.iter().map(|t| {
            Json::obj(vec![
                ("name", Json::str(t.name.as_str())),
                ("priority", Json::num(f64::from(t.priority))),
                ("sent", Json::num(t.sent as f64)),
                ("ok", Json::num(t.ok as f64)),
                ("failed", Json::num(t.failed as f64)),
                ("shed", Json::num(t.shed as f64)),
                ("p50_ms", Json::num(t.p50_ms)),
                ("p99_ms", Json::num(t.p99_ms)),
                ("slo_attainment", Json::num(t.slo_attainment)),
                ("goodput_rps", Json::num(t.goodput_rps)),
            ])
        }));
        let doc = Json::obj(vec![
            ("bench", Json::str("scenario")),
            ("virtual_s", Json::num(r.virtual_s)),
            ("sent", Json::num(r.sent as f64)),
            ("ok", Json::num(r.ok as f64)),
            ("failed", Json::num(r.failed as f64)),
            ("shed", Json::num(r.shed as f64)),
            ("steps", Json::num(r.steps as f64)),
            ("steps_per_s", Json::num(r.steps as f64 / v)),
            ("tokens_per_s", Json::num(r.snapshot.tokens as f64 / v)),
            ("p50_ms", Json::num(r.snapshot.latency_p50_ms)),
            ("p99_ms", Json::num(r.snapshot.latency_p99_ms)),
            ("reshards", Json::num(r.reshards as f64)),
            (
                "recovery_ms",
                match r.recovery_s {
                    Some(s) => Json::num(s * 1e3),
                    None => Json::Null,
                },
            ),
            ("tenants", tenants),
        ]);
        std::fs::write(&path, format!("{doc}\n")).expect("write bench json");
        println!("\nwrote {path}");
    }
}
