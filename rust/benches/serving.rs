//! Serving-core bench (default features): burst traffic through the
//! sim/CPU-backed server, then the SERVE report table (accounting mode)
//! across prompt-pool skews.  No GPU, artifacts, or XLA — this is the
//! load-test half of the DESIGN.md experiment index entry "SERVE".

use staticbatch::coordinator::batcher::BatchPolicy;
use staticbatch::serve::{
    run_traffic, Server, ServerConfig, SimServeConfig, SimStepExecutor, TrafficConfig,
};

fn main() {
    println!("== serving core: 512-request burst, CPU numerics ==");
    let sim_cfg = SimServeConfig { seed: 1, ..SimServeConfig::default() };
    let max_tokens = sim_cfg.max_tokens;
    let mut server = Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 16, max_tokens },
            queue_capacity: 1024,
            poll: std::time::Duration::from_millis(1),
        },
        SimStepExecutor::new(sim_cfg),
    );
    let report = run_traffic(
        &mut server,
        TrafficConfig { requests: 512, rate_hz: 0.0, ..TrafficConfig::default() },
    );
    print!("{}", report.render());

    println!("\n== SERVE: plan-cache behavior across prompt-pool skews ==");
    print!("{}", staticbatch::reports::serving_sim_table(256, 1));
}
