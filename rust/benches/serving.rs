//! Serving-core bench (default features): the same 512-request Zipf burst
//! through the synchronous reference loop and the three-stage pipelined
//! front end (batcher → executor → responder), then the SERVE report table
//! (accounting mode) across prompt-pool skews.  No GPU, artifacts, or XLA —
//! this is the load-test half of the DESIGN.md experiment index entry
//! "SERVE".
//!
//! With `--json <path>` (how `scripts/bench_distill` invokes it) the run
//! merges a `pipelined_vs_sync` row into the summary the scenario bench
//! wrote at `<path>`.  Request/token counts are deterministic for the seed;
//! tokens/s and the latency percentiles are measured wall clock, so that
//! one row is machine-dependent by design — it is the headline overlap
//! number.

use staticbatch::coordinator::batcher::BatchPolicy;
use staticbatch::serve::{
    run_traffic, Server, ServerConfig, SimServeConfig, SimStepExecutor, TrafficConfig,
    TrafficReport,
};
use staticbatch::util::json::Json;

/// One burst run; returns the report and its end-to-end tokens/s.
fn run(pipeline: bool, requests: usize) -> (TrafficReport, f64) {
    let sim_cfg = SimServeConfig { seed: 1, ..SimServeConfig::default() };
    let max_tokens = sim_cfg.max_tokens;
    let mut server = Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 16, max_tokens },
            queue_capacity: requests.max(16),
            pipeline,
            ..ServerConfig::default()
        },
        SimStepExecutor::new(sim_cfg),
    );
    let report = run_traffic(
        &mut server,
        TrafficConfig { requests, rate_hz: 0.0, ..TrafficConfig::default() },
    );
    let tokens_per_s = report.snapshot.tokens as f64 / report.wall_s.max(1e-12);
    (report, tokens_per_s)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // scan for `--json <path>`, ignoring whatever else cargo bench passes
    let json_path = args.windows(2).find(|w| w[0] == "--json").map(|w| w[1].clone());

    println!("== serving core: 512-request burst, CPU numerics, sync vs pipelined ==");
    println!("-- sync reference loop --");
    let (sync_report, sync_tps) = run(false, 512);
    print!("{}", sync_report.render());
    println!("\n-- pipelined front end (batcher → executor → responder) --");
    let (pipe_report, pipe_tps) = run(true, 512);
    print!("{}", pipe_report.render());
    println!(
        "\npipelined vs sync: {:.0} vs {:.0} tokens/s ({:+.1}%), p99 {:.3} vs {:.3} ms",
        pipe_tps,
        sync_tps,
        (pipe_tps / sync_tps.max(1e-12) - 1.0) * 100.0,
        pipe_report.p99_ms,
        sync_report.p99_ms,
    );

    println!("\n== SERVE: plan-cache behavior across prompt-pool skews ==");
    print!("{}", staticbatch::reports::serving_sim_table(256, 1));

    if let Some(path) = json_path {
        let leg = |r: &TrafficReport, tps: f64| {
            Json::obj(vec![
                ("sent", Json::num(r.sent as f64)),
                ("ok", Json::num(r.ok as f64)),
                ("failed", Json::num(r.failed as f64)),
                ("rejected", Json::num(r.rejected as f64)),
                ("tokens_per_s", Json::num(tps)),
                ("p50_ms", Json::num(r.p50_ms)),
                ("p99_ms", Json::num(r.p99_ms)),
                ("max_in_flight", Json::num(r.snapshot.max_in_flight as f64)),
            ])
        };
        let row = Json::obj(vec![
            ("sync", leg(&sync_report, sync_tps)),
            ("pipelined", leg(&pipe_report, pipe_tps)),
            ("speedup", Json::num(pipe_tps / sync_tps.max(1e-12))),
        ]);
        // merge into the scenario bench's summary rather than clobbering it
        let mut doc = std::fs::read_to_string(&path)
            .ok()
            .and_then(|s| Json::parse(&s).ok())
            .unwrap_or_else(|| Json::obj(Vec::new()));
        if let Json::Obj(map) = &mut doc {
            map.insert("pipelined_vs_sync".to_string(), row);
        }
        std::fs::write(&path, format!("{doc}\n")).expect("write bench json");
        println!("\nwrote {path}");
    }
}
