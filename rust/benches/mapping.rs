//! A2: mapping mechanism microbench — plus a wallclock benchmark of the
//! three decode variants on this host CPU.
use staticbatch::batching::{mapping, tile_prefix};
use staticbatch::util::bench;
use staticbatch::util::rng::Rng;

fn main() {
    println!("== A2: mapping mechanism cost model (simulated H800) ==");
    print!("{}", staticbatch::reports::mapping_table());

    println!("\n== host wallclock: decode 1M blocks ==");
    let mut rng = Rng::new(1);
    for n_tasks in [8usize, 64, 512] {
        let tiles: Vec<u32> = (0..n_tasks).map(|_| rng.below(64) as u32 + 1).collect();
        let prefix = tile_prefix::build_from_counts(&tiles);
        let padded = tile_prefix::pad_to(&prefix, n_tasks.max(32));
        let total: u32 = tiles.iter().sum();
        let blocks: Vec<u32> = (0..1_000_000).map(|_| rng.below(total as u64) as u32).collect();
        let t_scalar = bench::time(&format!("scalar n={n_tasks}"), 1, 5, || {
            for &b in &blocks {
                std::hint::black_box(mapping::map_scalar(&prefix, b));
            }
        });
        let t_warp = bench::time(&format!("warp-sim n={n_tasks}"), 1, 5, || {
            for &b in &blocks {
                std::hint::black_box(mapping::map_warp(&padded, b));
            }
        });
        let t_bin = bench::time(&format!("binary n={n_tasks}"), 1, 5, || {
            for &b in &blocks {
                std::hint::black_box(mapping::map_binary_search(&prefix, b));
            }
        });
        println!(
            "n_tasks={n_tasks:>4}: scalar {:>8.2} ms  warp-emulated {:>8.2} ms  binary {:>8.2} ms (1M blocks)",
            t_scalar.mean_ms(), t_warp.mean_ms(), t_bin.mean_ms()
        );
    }
}
