//! E2E runtime bench: execute the AOT moe_gemm artifact through PJRT from
//! the Rust hot path, with plan construction on the host per step — the
//! deployment configuration.  Requires `make artifacts`.

use staticbatch::moe::kernel_meta;
use staticbatch::moe::ordering::OrderingStrategy;
use staticbatch::moe::token_index::TokenIndex;
use staticbatch::runtime::artifact::Manifest;
use staticbatch::runtime::client::Runtime;
use staticbatch::runtime::executor::{ExecutorPool, Value};
use staticbatch::util::bench;
use staticbatch::util::rng::Rng;

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("e2e_runtime: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    let rt = Runtime::cpu().expect("pjrt client");
    let manifest = Manifest::load(&dir).expect("manifest");
    let dims = manifest.kernel_dims("moe_gemm").expect("dims");
    let mut pool = ExecutorPool::new(rt, manifest);
    pool.prepare("moe_gemm").expect("compile");

    let mut rng = Rng::new(3);
    let tokens: Vec<f32> =
        (0..dims.seq * dims.d_model).map(|_| rng.normal() as f32 * 0.5).collect();
    let weights: Vec<f32> = (0..dims.experts * dims.d_model * dims.d_ff)
        .map(|_| rng.normal() as f32 * 0.05)
        .collect();

    for scenario in ["balanced", "skewed"] {
        // routing
        let mut pairs = Vec::new();
        for t in 0..dims.seq as u32 {
            for k in 0..dims.top_k as u32 {
                let e = match scenario {
                    "balanced" => (t * dims.top_k as u32 + k) % dims.experts as u32,
                    _ => (rng.below(8)) as u32, // heavy skew: 8 hot experts
                };
                pairs.push((t, e));
            }
        }
        let ti = TokenIndex::build(dims.experts, &pairs);
        let gates: Vec<Vec<f32>> =
            ti.index.iter().map(|v| v.iter().map(|_| 0.125f32).collect()).collect();

        // host plan time
        let t_plan = bench::time("plan", 2, 20, || {
            std::hint::black_box(kernel_meta::build(
                &dims,
                &ti,
                &gates,
                OrderingStrategy::HalfInterval,
            ));
        });
        let meta = kernel_meta::build(&dims, &ti, &gates, OrderingStrategy::HalfInterval);
        let sp = dims.padded_rows();
        // deployment pattern (§Perf): tokens + weights device-resident,
        // only the per-step metadata is uploaded on the hot path
        let tokens_buf = pool
            .upload(&Value::F32(tokens.clone(), vec![dims.seq, dims.d_model]))
            .expect("upload tokens");
        let weights_buf = pool
            .upload(&Value::F32(weights.clone(), vec![dims.experts, dims.d_model, dims.d_ff]))
            .expect("upload weights");
        let flops = 2.0 * (dims.seq * dims.top_k) as f64 * dims.d_model as f64 * dims.d_ff as f64;
        let (t_exec, _) = bench::time_throughput("exec", 1, 5, || {
            let m1 = pool.upload(&Value::I32(meta.tile_prefix.clone(), vec![dims.experts])).unwrap();
            let m2 = pool.upload(&Value::I32(meta.sigma.clone(), vec![dims.experts])).unwrap();
            let m3 = pool.upload(&Value::I32(meta.token_ids.clone(), vec![sp])).unwrap();
            let m4 = pool.upload(&Value::I32(meta.num_tiles.to_vec(), vec![1])).unwrap();
            let args = [&tokens_buf, &weights_buf, &m1, &m2, &m3, &m4];
            std::hint::black_box(pool.run_buffers("moe_gemm", &args).expect("run"));
            1
        });
        println!(
            "{scenario:>9}: plan {:>8.1} us | kernel exec {:>9.2} ms | {:.2} CPU-GFLOP/s | plan/exec = {:.4}%",
            t_plan.mean_us(),
            t_exec.mean_ms(),
            flops / t_exec.mean_ns,
            t_plan.mean_ns / t_exec.mean_ns * 100.0
        );
    }
    if let Some(s) = pool.stats("moe_gemm") {
        println!(
            "compile {:.2}s, {} calls, mean exec {:.2} ms",
            s.compile_s,
            s.calls,
            s.total_exec_s / s.calls.max(1) as f64 * 1e3
        );
    }
}
