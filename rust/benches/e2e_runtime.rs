//! E2E runtime bench: execute the AOT moe_gemm artifact through PJRT from
//! the Rust hot path, with plan construction on the host per step — the
//! deployment configuration, driven through the unified
//! `ExecutionSession` → `PjrtBackend` surface.  Requires `make artifacts`
//! and `--features pjrt`.

use staticbatch::exec::{Backend, ExecContext, ExecutionSession, NumericInputs};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::ordering::OrderingStrategy;
use staticbatch::moe::routing::ExpertLoad;
use staticbatch::moe::token_index::TokenIndex;
use staticbatch::runtime::artifact::Manifest;
use staticbatch::runtime::client::Runtime;
use staticbatch::runtime::executor::ExecutorPool;
use staticbatch::runtime::PjrtBackend;
use staticbatch::sim::specs::GpuSpec;
use staticbatch::util::bench;
use staticbatch::util::rng::Rng;
use staticbatch::util::tensor::Tensor;

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("e2e_runtime: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    let rt = Runtime::cpu().expect("pjrt client");
    let manifest = Manifest::load(&dir).expect("manifest");
    let dims = manifest.kernel_dims("moe_gemm").expect("dims");
    let mut pool = ExecutorPool::new(rt, manifest);
    let mut backend =
        PjrtBackend::new(&mut pool, OrderingStrategy::HalfInterval).expect("compile moe_gemm");

    let shape = MoeShape {
        seq: dims.seq,
        d_model: dims.d_model,
        d_ff: dims.d_ff,
        experts: dims.experts,
        top_k: dims.top_k,
        dtype_bytes: 4,
    };
    let mut rng = Rng::new(3);
    let tokens = Tensor::from_vec(
        &[dims.seq, dims.d_model],
        (0..dims.seq * dims.d_model).map(|_| rng.normal() as f32 * 0.5).collect(),
    );
    let weights = Tensor::from_vec(
        &[dims.experts, dims.d_model, dims.d_ff],
        (0..dims.experts * dims.d_model * dims.d_ff)
            .map(|_| rng.normal() as f32 * 0.05)
            .collect(),
    );

    for scenario in ["balanced", "skewed"] {
        // routing
        let mut pairs = Vec::new();
        for t in 0..dims.seq as u32 {
            for k in 0..dims.top_k as u32 {
                let e = match scenario {
                    "balanced" => (t * dims.top_k as u32 + k) % dims.experts as u32,
                    _ => (rng.below(8)) as u32, // heavy skew: 8 hot experts
                };
                pairs.push((t, e));
            }
        }
        let ti = TokenIndex::build(dims.experts, &pairs);
        let load = ExpertLoad { counts: ti.index.iter().map(Vec::len).collect() };
        let gates: Vec<Vec<f32>> =
            ti.index.iter().map(|v| v.iter().map(|_| 0.125f32).collect()).collect();
        let numeric = NumericInputs {
            tokens: tokens.clone(),
            weights: weights.clone(),
            token_index: ti,
            gates,
        };

        let session = ExecutionSession::new(shape)
            .ordering(OrderingStrategy::HalfInterval)
            .gpu(GpuSpec::h800());

        // host plan time (σ + ordering + tiling + TilePrefix)
        let t_plan = bench::time("plan", 2, 20, || {
            std::hint::black_box(session.plan(&load));
        });
        let plan = session.plan(&load);

        // deployment pattern (§Perf): tokens + weights device-resident; the
        // timed step below is the full per-step hot path — metadata build +
        // metadata upload + kernel execution (the standalone "plan" number
        // above isolates the host-side planning share)
        backend.warm(&numeric).expect("upload resident operands");
        let flops = 2.0 * (dims.seq * dims.top_k) as f64 * dims.d_model as f64 * dims.d_ff as f64;
        let (t_exec, _) = bench::time_throughput("exec", 1, 5, || {
            let mut ctx = ExecContext::new(GpuSpec::h800()).with_numeric(&numeric);
            std::hint::black_box(backend.execute(&plan, &mut ctx).expect("run"));
            1
        });
        println!(
            "{scenario:>9}: plan {:>8.1} us | step exec {:>9.2} ms | {:.2} CPU-GFLOP/s | plan/exec = {:.4}%",
            t_plan.mean_us(),
            t_exec.mean_ms(),
            flops / t_exec.mean_ns,
            t_plan.mean_ns / t_exec.mean_ns * 100.0
        );
    }
    if let Some(s) = pool.stats("moe_gemm") {
        println!(
            "compile {:.2}s, {} calls, mean exec {:.2} ms",
            s.compile_s,
            s.calls,
            s.total_exec_s / s.calls.max(1) as f64 * 1e3
        );
    }
}
