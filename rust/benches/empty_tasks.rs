//! A4: two-stage empty-task mapping vs dense mapping (Section 4.1).
//!
//! The simulated table is the experiment; the harness section benches the
//! three simulator mapping modes (ours / dense / padded-empty) through the
//! unified `ExecutionSession`/`Backend` surface as the number of active
//! experts shrinks.

use staticbatch::exec::{bench::time_session, ExecutionSession, SimBackend};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::routing::ExpertLoad;
use staticbatch::sim::specs::GpuSpec;
use staticbatch::util::bench::Table;

fn main() {
    println!("== A4: empty-task handling ==");
    print!("{}", staticbatch::reports::empty_tasks_table());

    println!("\n== A4 harness: plan+simulate wallclock per mapping mode (H800) ==");
    let shape = MoeShape::paper_table1();
    let mut t = Table::new(&[
        "active", "backend", "sim time(ms)", "host mean(us)", "blocks",
    ]);
    for active in [64usize, 8, 2] {
        let mut counts = vec![0usize; shape.experts];
        for i in 0..shape.total_rows() {
            counts[i % active] += 1;
        }
        let load = ExpertLoad { counts };
        for backend in
            [SimBackend::ours(), SimBackend::dense_mapping(), SimBackend::padded_empty()]
        {
            let mut session =
                ExecutionSession::new(shape).backend(backend).gpu(GpuSpec::h800());
            let label = format!("active{active}/{}", session.backend_name());
            let (timing, out) =
                time_session(&label, &mut session, &load, 2, 15).expect("sim backend");
            t.row(&[
                active.to_string(),
                out.backend.to_string(),
                format!("{:.3}", out.time_s() * 1e3),
                format!("{:.1}", timing.mean_us()),
                out.blocks.to_string(),
            ]);
        }
    }
    t.print();
}
