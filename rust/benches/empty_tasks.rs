//! A4: two-stage empty-task mapping vs dense mapping (Section 4.1).
fn main() {
    println!("== A4: empty-task handling ==");
    print!("{}", staticbatch::reports::empty_tasks_table());
}
