//! A3: expert ordering ablation (Section 4.2; half-interval should win).
//!
//! The simulated table is the experiment; the harness section below also
//! wallclock-benches plan construction + simulation per ordering through
//! the unified `ExecutionSession`/`Backend` surface, since ordering is
//! host-side work on the serving hot path.

use staticbatch::exec::{bench::time_session, ExecutionSession, SimBackend};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::ordering::OrderingStrategy;
use staticbatch::moe::routing::LoadScenario;
use staticbatch::sim::specs::GpuSpec;
use staticbatch::util::bench::Table;

fn main() {
    println!("== A3: expert ordering under skewed load ==");
    print!("{}", staticbatch::reports::ordering_table(0));

    println!("\n== A3 harness: host cost of plan+simulate per ordering (H800, worst case) ==");
    let shape = MoeShape::paper_table1();
    let load = LoadScenario::Worst.counts(&shape, 0);
    let mut t = Table::new(&["ordering", "sim time(ms)", "host mean(us)", "host p95(us)"]);
    for ord in [
        OrderingStrategy::HalfInterval,
        OrderingStrategy::Alternating,
        OrderingStrategy::Natural,
        OrderingStrategy::SortedDesc,
        OrderingStrategy::Random(0),
    ] {
        let mut session = ExecutionSession::new(shape)
            .ordering(ord)
            .backend(SimBackend::ours())
            .gpu(GpuSpec::h800());
        let (timing, out) =
            time_session(ord.name(), &mut session, &load, 3, 25).expect("sim backend");
        t.row(&[
            ord.name().to_string(),
            format!("{:.3}", out.time_s() * 1e3),
            format!("{:.1}", timing.mean_us()),
            format!("{:.1}", timing.p95_ns / 1e3),
        ]);
    }
    t.print();
}
