//! A3: expert ordering ablation (Section 4.2; half-interval should win).
fn main() {
    println!("== A3: expert ordering under skewed load ==");
    print!("{}", staticbatch::reports::ordering_table(0));
}
