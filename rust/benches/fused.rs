//! Fused transformer-layer bench (default features): the FUSED report
//! table — one heterogeneous plan (decode + prefill + expert FFN under a
//! single σ) against the sequential two-plan baseline and padded-dense —
//! plus a deterministic accounting drive of the fused serving executor.
//!
//! Every number here comes from the virtual clock and the planner, so the
//! whole output is byte-deterministic: the same commit produces identical
//! bytes on any machine.  With `--json <path>` (how `scripts/bench_distill`
//! invokes it) the run merges a `fused_vs_sequential` row into the summary
//! the scenario/serving benches wrote at `<path>`.

use staticbatch::exec::{ExecutionSession, SimBackend};
use staticbatch::moe::config::MoeShape;
use staticbatch::serve::{FusedServeConfig, FusedStepExecutor, StepExecutor, StepInput};
use staticbatch::sim::specs::GpuSpec;
use staticbatch::util::json::Json;
use staticbatch::workload::transformer::{FusedLayerWorkload, FusedLoad, PaddedDenseFused, SeqSpec};

const SEQS: usize = 64;
const SEED: u64 = 7;

/// One planned-and-simulated leg of the comparison.
struct Leg {
    plans: u64,
    launches: u64,
    tiles: u64,
    metadata_bytes: u64,
    host_us: f64,
    time_ms: f64,
}

impl Leg {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("plans", Json::num(self.plans as f64)),
            ("launches", Json::num(self.launches as f64)),
            ("tiles", Json::num(self.tiles as f64)),
            ("metadata_bytes", Json::num(self.metadata_bytes as f64)),
            ("host_us", Json::num(round_to(self.host_us, 3))),
            ("time_ms", Json::num(round_to(self.time_ms, 4))),
        ])
    }
}

/// Round to `digits` decimal places so the emitted JSON stays diffable.
fn round_to(x: f64, digits: i32) -> f64 {
    let p = 10f64.powi(digits);
    (x * p).round() / p
}

/// Re-derive the FUSED comparison on the report's shape: the fused load
/// planned once, then the same tasks as two single-phase plans, then the
/// padded-dense accounting baseline.
fn compare() -> (Leg, Leg, Leg) {
    let shape = MoeShape {
        seq: SEQS,
        d_model: 4096,
        d_ff: 2048,
        experts: 16,
        top_k: 2,
        dtype_bytes: 2,
    };
    let w = FusedLayerWorkload::new(32, shape);
    let spec = GpuSpec::h800();
    let load = FusedLoad::sample_mixed(&shape, SEED);
    let attn_only = FusedLoad { seqs: load.seqs.clone(), expert_counts: vec![0; shape.experts] };
    let ffn_only = FusedLoad {
        seqs: vec![SeqSpec::Empty; shape.seq],
        expert_counts: load.expert_counts.clone(),
    };

    let mut sess =
        ExecutionSession::for_workload(w).gpu(spec.clone()).backend(SimBackend::ours());
    let fused_plan = sess.plan(&load);
    let fused_out = sess.run(&load).expect("fused sim step");
    let attn_plan = sess.plan(&attn_only);
    let attn_out = sess.run(&attn_only).expect("attention sim step");
    let ffn_plan = sess.plan(&ffn_only);
    let ffn_out = sess.run(&ffn_only).expect("ffn sim step");
    let padded = ExecutionSession::for_workload(w)
        .gpu(spec)
        .backend(PaddedDenseFused)
        .run(&load)
        .expect("padded-dense step");

    let fused = Leg {
        plans: 1,
        launches: 1,
        tiles: fused_plan.total_tiles() as u64,
        metadata_bytes: fused_plan.two_stage.metadata_bytes() as u64,
        host_us: fused_out.sim().host_time_s * 1e6,
        time_ms: fused_out.time_s() * 1e3,
    };
    let sequential = Leg {
        plans: 2,
        launches: 2,
        tiles: (attn_plan.total_tiles() + ffn_plan.total_tiles()) as u64,
        metadata_bytes: (attn_plan.two_stage.metadata_bytes()
            + ffn_plan.two_stage.metadata_bytes()) as u64,
        host_us: (attn_out.sim().host_time_s + ffn_out.sim().host_time_s) * 1e6,
        time_ms: (attn_out.time_s() + ffn_out.time_s()) * 1e3,
    };
    let padded_dense = Leg {
        plans: 2,
        launches: 2,
        tiles: padded.blocks as u64,
        metadata_bytes: 0,
        host_us: padded.sim().host_time_s * 1e6,
        time_ms: padded.time_s() * 1e3,
    };
    (fused, sequential, padded_dense)
}

/// Drive the fused serving executor (accounting mode) through a cycle of
/// four distinct formed batches: deterministic step count, cache hit/miss
/// counts, and total simulated seconds.
fn serve_leg() -> Json {
    const STEPS: usize = 24;
    const PATTERNS: usize = 4;
    const BUCKET: usize = 16;
    const ROWS: usize = 8;
    let mut ex = FusedStepExecutor::new(FusedServeConfig {
        numeric: false,
        seed: SEED,
        ..FusedServeConfig::default()
    });
    let batches: Vec<Vec<i32>> = (0..PATTERNS)
        .map(|p| (0..ROWS * BUCKET).map(|i| ((p * 31 + i * 7) % 50) as i32).collect())
        .collect();
    let mut sim_s = 0.0;
    for i in 0..STEPS {
        let tokens: &[i32] = &batches[i % PATTERNS];
        let out = ex
            .execute_step(&StepInput { bucket: BUCKET, rows: ROWS, tokens })
            .expect("fused sim step");
        sim_s += out.sim_time_s.expect("accounting step is simulated");
    }
    let stats = ex.cache_stats().expect("fused executor caches plans");
    println!(
        "serve/fused accounting drive: {STEPS} steps over {PATTERNS} distinct loads, \
         cache {} hits / {} misses, {:.3} simulated ms",
        stats.hits,
        stats.misses,
        sim_s * 1e3,
    );
    Json::obj(vec![
        ("steps", Json::num(STEPS as f64)),
        ("distinct_loads", Json::num(PATTERNS as f64)),
        ("cache_hits", Json::num(stats.hits as f64)),
        ("cache_misses", Json::num(stats.misses as f64)),
        ("sim_time_ms_total", Json::num(round_to(sim_s * 1e3, 4))),
    ])
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // scan for `--json <path>`, ignoring whatever else cargo bench passes
    let json_path = args.windows(2).find(|w| w[0] == "--json").map(|w| w[1].clone());

    println!("== FUSED: one heterogeneous plan vs sequential two-plan ==");
    print!("{}", staticbatch::reports::fused_table(SEQS, SEED));

    let (fused, sequential, padded) = compare();
    println!(
        "\nfused vs sequential: {} vs {} launches, host {:.2} vs {:.2} us, \
         metadata {} vs {} B, time {:.3} vs {:.3} ms ({:.2}x)",
        fused.launches,
        sequential.launches,
        fused.host_us,
        sequential.host_us,
        fused.metadata_bytes,
        sequential.metadata_bytes,
        fused.time_ms,
        sequential.time_ms,
        sequential.time_ms / fused.time_ms.max(1e-12),
    );
    println!();
    let serve = serve_leg();

    assert!(
        fused.launches < sequential.launches,
        "fused step must plan strictly fewer launches than the two-plan baseline"
    );
    assert!(
        fused.host_us < sequential.host_us,
        "fused step must spend less host time than the two-plan baseline"
    );

    if let Some(path) = json_path {
        let row = Json::obj(vec![
            ("seqs", Json::num(SEQS as f64)),
            ("seed", Json::num(SEED as f64)),
            ("fused", fused.to_json()),
            ("sequential", sequential.to_json()),
            ("padded_dense", padded.to_json()),
            (
                "host_saving_us",
                Json::num(round_to(sequential.host_us - fused.host_us, 3)),
            ),
            (
                "time_speedup",
                Json::num(round_to(sequential.time_ms / fused.time_ms.max(1e-12), 4)),
            ),
            ("serve", serve),
        ]);
        // merge into the scenario/serving benches' summary, don't clobber it
        let mut doc = std::fs::read_to_string(&path)
            .ok()
            .and_then(|s| Json::parse(&s).ok())
            .unwrap_or_else(|| Json::obj(Vec::new()));
        if let Json::Obj(map) = &mut doc {
            map.insert("fused_vs_sequential".to_string(), row);
        }
        std::fs::write(&path, format!("{doc}\n")).expect("write bench json");
        println!("\nwrote {path}");
    }
}
