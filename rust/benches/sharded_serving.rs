//! Sharded-serving bench (default features): identical Zipf burst traffic
//! through the expert-parallel executor under both placement policies, then
//! the SHARD placement × EP-width sweep table.  No GPU, artifacts, or XLA —
//! this is the load-test half of the DESIGN.md experiment index entry
//! "SHARD".

use staticbatch::coordinator::batcher::BatchPolicy;
use staticbatch::serve::{
    run_traffic, PlacementKind, Server, ServerConfig, ShardedServeConfig, ShardedStepExecutor,
    SimServeConfig, TrafficConfig,
};

fn main() {
    for placement in [PlacementKind::Static, PlacementKind::Balanced] {
        println!(
            "== sharded serving: ep=4 {} placement, 256-request Zipf burst ==",
            placement.name()
        );
        let cfg = ShardedServeConfig {
            // serving-scale widths so shard kernel times track routed rows
            base: SimServeConfig {
                d_model: 1024,
                d_ff: 2048,
                numeric: false,
                seed: 1,
                ..SimServeConfig::default()
            },
            ep: 4,
            placement,
            rebalance_threshold: 1.1,
            ..ShardedServeConfig::default()
        };
        let max_tokens = cfg.base.max_tokens;
        let mut server = Server::new(
            ServerConfig {
                policy: BatchPolicy { buckets: Vec::new(), max_requests: 16, max_tokens },
                queue_capacity: 1024,
                ..ServerConfig::default()
            },
            ShardedStepExecutor::new(cfg),
        );
        let report = run_traffic(
            &mut server,
            TrafficConfig {
                requests: 256,
                rate_hz: 0.0,
                zipf_alpha: 1.4,
                ..TrafficConfig::default()
            },
        );
        print!("{}", report.render());
        println!();
    }

    println!("== SHARD: placement x EP-width sweep ==");
    print!("{}", staticbatch::reports::sharded_serving_table(256, 1));
}
