//! Scenario-layer integration under DEFAULT features: no PJRT, no
//! artifacts, no GPU, and no wall clock — every run here is virtual-time
//! and exactly reproducible from its seed.
//!
//! Pins the three promises the scenario layer makes:
//!
//! 1. **Acceptance** — the pinned default scenario (300-request burst +
//!    400 Hz Poisson second, premium over batch tenant, shard 1 killed at
//!    t=0.3s and recovered at t=0.6s) conserves requests
//!    (sent = ok + failed + shed), sheds under overload, re-shards after
//!    the kill, and keeps the premium tenant's SLO attainment at or above
//!    the batch tenant's.
//! 2. **Priority dominance (property)** — under *any* overloaded
//!    instantaneous burst with identical SLOs and prompt mixes, the
//!    higher-priority tenant's SLO attainment is at least the lower's.
//! 3. **Fault recovery** — killing a shard mid-run keeps `top_k = 1`
//!    numeric outputs bitwise-identical to a single-shard executor (the
//!    evacuation only re-masks token indices; every lane holds the full
//!    weights), increments the reshard counter, and — in the accounting
//!    model — brings the per-step simulated time back down after a
//!    slowed shard is evacuated.

use staticbatch::serve::{
    run_scenario, ArrivalTrace, FaultEvent, FaultKind, FaultPlan, PlacementKind, ScenarioConfig,
    ShardedServeConfig, ShardedStepExecutor, SimServeConfig, SimStepExecutor, StepExecutor,
    StepInput, TenantClass,
};
use staticbatch::util::prop::check;
use staticbatch::util::rng::{zipf_weights, Rng};

/// Zipf-valued token batches (`alpha` near 0 = near-uniform expert load).
fn zipf_steps(steps: usize, rows: usize, bucket: usize, alpha: f64, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    let w = zipf_weights(50, alpha);
    (0..steps)
        .map(|_| (0..rows * bucket).map(|_| rng.zipf(&w) as i32 + 1).collect())
        .collect()
}

#[test]
fn default_scenario_sheds_reshards_and_orders_attainment() {
    let cfg = ScenarioConfig::default();
    let mut ex = ShardedStepExecutor::new(ShardedServeConfig {
        base: SimServeConfig { numeric: false, seed: cfg.seed, ..SimServeConfig::default() },
        ep: 4,
        placement: PlacementKind::Balanced,
        ..ShardedServeConfig::default()
    });
    let r = run_scenario(&mut ex, &cfg);

    assert_eq!(r.ok + r.failed + r.shed, r.sent, "conservation");
    assert!(r.sent >= 300, "the opening burst alone is 300 requests");
    assert_eq!(r.failed, 0, "every admitted prompt fits a bucket");
    assert!(r.shed > 0, "a 300-burst must overflow the 64-slot queue");
    assert!(r.steps > 0);
    assert!(r.virtual_s > 0.0);

    // the kill at t=0.3s forces an evacuation, visible as a reshard
    assert!(r.reshards_after_fault >= 1, "kill must evacuate shard 1");
    assert!(r.recovery_s.is_some(), "re-shard after the fault = recovery");

    let hi = &r.tenants[0];
    let lo = &r.tenants[1];
    assert!(hi.priority > lo.priority);
    assert_eq!(hi.sent + lo.sent, r.sent);
    assert!(
        hi.slo_attainment >= lo.slo_attainment,
        "premium {} must dominate batch {}",
        hi.slo_attainment,
        lo.slo_attainment
    );
    // shed *fraction* ordering, cross-multiplied to avoid divide-by-zero
    assert!(
        hi.shed * lo.sent <= lo.shed * hi.sent,
        "premium shed share {}/{} above batch {}/{}",
        hi.shed,
        hi.sent,
        lo.shed,
        lo.sent
    );
    let rendered = r.render();
    assert!(rendered.contains("tenant premium (prio 2):"), "{rendered}");
    assert!(rendered.contains("reshards="), "{rendered}");
}

#[test]
fn property_higher_priority_attainment_dominates_any_overloaded_burst() {
    check(
        "priority-slo-dominance",
        25,
        |g| {
            let count = 80 + g.rng.usize_below(40 * g.size.min(4));
            let queue = 8 + g.rng.usize_below(24);
            let hi_share = 0.2 + 0.6 * g.rng.f64();
            let seed = g.rng.next_u64();
            (count, queue, hi_share, seed)
        },
        |&(count, queue, hi_share, seed)| {
            let cfg = ScenarioConfig {
                trace: ArrivalTrace::new().burst(count, 0.0),
                tenants: vec![
                    TenantClass::new("hi", 2, hi_share),
                    TenantClass::new("lo", 1, 1.0 - hi_share),
                ],
                faults: FaultPlan::default(),
                queue_capacity: queue,
                seed,
                ..ScenarioConfig::default()
            };
            let mut ex = SimStepExecutor::new(SimServeConfig {
                numeric: false,
                ..SimServeConfig::default()
            });
            let r = run_scenario(&mut ex, &cfg);
            if r.ok + r.failed + r.shed != r.sent {
                return Err(format!(
                    "conservation broke: sent={} ok={} failed={} shed={}",
                    r.sent, r.ok, r.failed, r.shed
                ));
            }
            let (hi, lo) = (&r.tenants[0], &r.tenants[1]);
            if hi.slo_attainment + 1e-12 < lo.slo_attainment {
                return Err(format!(
                    "hi attainment {} below lo {}",
                    hi.slo_attainment, lo.slo_attainment
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn mid_run_kill_keeps_argmax_bitwise_identical_at_top_k_1() {
    let base = SimServeConfig {
        buckets: vec![8, 16],
        max_tokens: 256,
        experts: 16,
        top_k: 1,
        d_model: 16,
        d_ff: 24,
        cache_capacity: 32,
        numeric: true,
        threads: 1,
        seed: 11,
    };
    let mut single = SimStepExecutor::new(base.clone());
    let mut sharded = ShardedStepExecutor::new(ShardedServeConfig {
        base,
        ep: 4,
        placement: PlacementKind::Static,
        ..ShardedServeConfig::default()
    });
    for (i, tokens) in zipf_steps(8, 4, 16, 1.3, 21).iter().enumerate() {
        if i == 4 {
            assert_eq!(sharded.reshards(), 0, "static placement before the fault");
            sharded.apply_fault(&FaultEvent { at_s: 0.0, shard: 1, kind: FaultKind::Kill });
            assert_eq!(sharded.reshards(), 1, "kill evacuation counts as a reshard");
            assert!(!sharded.live()[1]);
            assert!(
                sharded.assignment().iter().all(|&s| s != 1),
                "no expert may stay on the dead shard: {:?}",
                sharded.assignment()
            );
        }
        let step = StepInput { bucket: 16, rows: 4, tokens };
        let a = single.execute_step(&step).expect("single-shard step");
        let b = sharded.execute_step(&step).expect("sharded step");
        assert_eq!(a.argmax, b.argmax, "step {i} diverged (kill at step 4)");
        assert_eq!(a.expert_rows, b.expert_rows, "step {i} routed differently");
    }
}

#[test]
fn slow_fault_inflates_step_time_and_kill_evacuation_recovers_it() {
    // Serving-scale accounting shape, near-uniform routing: a shard's
    // simulated kernel time tracks its routed rows, so slowing one shard
    // stretches the critical path and evacuating it restores the floor.
    let base = SimServeConfig {
        buckets: vec![64],
        max_tokens: 2048,
        experts: 16,
        top_k: 2,
        d_model: 1024,
        d_ff: 2048,
        cache_capacity: 32,
        numeric: false,
        threads: 1,
        seed: 11,
    };
    let mut ex = ShardedStepExecutor::new(ShardedServeConfig {
        base,
        ep: 4,
        placement: PlacementKind::Static,
        ..ShardedServeConfig::default()
    });
    let steps = zipf_steps(8, 8, 64, 0.2, 9);
    fn step_time(ex: &mut ShardedStepExecutor, tokens: &[i32]) -> f64 {
        let out = ex.execute_step(&StepInput { bucket: 64, rows: 8, tokens }).expect("step");
        out.sim_time_s.expect("accounting mode reports simulated step time")
    }
    let mut t_pre = 0.0;
    for tokens in &steps[0..4] {
        t_pre = step_time(&mut ex, tokens);
    }
    ex.apply_fault(&FaultEvent { at_s: 0.0, shard: 0, kind: FaultKind::Slow { factor: 100.0 } });
    assert_eq!(ex.reshards(), 0, "a slowdown alone never moves experts");
    assert!((ex.speeds()[0] - 0.01).abs() < 1e-12);
    let t_slow = step_time(&mut ex, &steps[4]);
    assert!(
        t_slow > 3.0 * t_pre,
        "a 100x slower shard must stretch the step: pre={t_pre:.6}s slow={t_slow:.6}s"
    );
    ex.apply_fault(&FaultEvent { at_s: 0.0, shard: 0, kind: FaultKind::Kill });
    assert_eq!(ex.reshards(), 1, "evacuating the slow shard is a reshard");
    assert!(!ex.live()[0]);
    let t_post = step_time(&mut ex, &steps[5]);
    assert!(
        t_post < t_slow / 2.0,
        "evacuation must recover the step time: slow={t_slow:.6}s post={t_post:.6}s"
    );
}
