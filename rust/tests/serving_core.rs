//! End-to-end serving-core test under DEFAULT features: no PJRT, no
//! artifacts, no GPU.  Drives 64 mixed-length requests of Zipf-valued
//! prompts through the sim/CPU-backed server and checks the full
//! request → queue → batch → plan(+cache) → execute → respond pipeline:
//! every response arrives, metrics totals match the traffic, and repeated
//! load signatures hit the plan cache.

use std::sync::mpsc::{channel, Receiver};
use std::time::Instant;

use staticbatch::coordinator::batcher::BatchPolicy;
use staticbatch::coordinator::request::{Request, Response};
use staticbatch::serve::{Server, ServerConfig, SimServeConfig, SimStepExecutor, StepExecutor};
use staticbatch::util::rng::{zipf_weights, Rng};

fn zipf_prompt(len: usize, rng: &mut Rng, weights: &[f64]) -> Vec<i32> {
    (0..len).map(|_| rng.zipf(weights) as i32 + 1).collect()
}

#[test]
fn sim_server_serves_64_requests_end_to_end_with_cache_hits() {
    let executor = SimStepExecutor::new(SimServeConfig {
        buckets: vec![16, 64, 256],
        max_tokens: 2048,
        experts: 16,
        top_k: 2,
        d_model: 16,
        d_ff: 32,
        cache_capacity: 64,
        numeric: true,
        threads: 1,
        seed: 9,
    });
    let mut server = Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 8, max_tokens: 2048 },
            queue_capacity: 128,
            ..ServerConfig::default()
        },
        executor,
    );
    assert_eq!(server.policy().buckets, vec![16, 64, 256]);

    // Zipf-valued prompts, one distinct prompt per length class: popular
    // queries repeat in real serving traffic, so batches of equal
    // composition recur — and with them, load signatures the plan cache
    // can hit.
    let mut rng = Rng::new(3);
    let w = zipf_weights(500, 1.3);
    let short = zipf_prompt(12, &mut rng, &w); // bucket 16
    let medium = zipf_prompt(48, &mut rng, &w); // bucket 64
    let long = zipf_prompt(200, &mut rng, &w); // bucket 256

    // All 64 requests are admitted before the worker starts, so batch
    // formation is deterministic: each drain of 8 FIFO requests yields
    // (per 16-request cycle) one 8x short batch, one 5x medium batch, and
    // one 3x long batch — 12 batches, each shape repeated 4 times.
    let queue = server.queue();
    let mut receivers: Vec<(u64, usize, Receiver<Response>)> = Vec::new();
    let mut expected_tokens = 0u64;
    for i in 0..64u64 {
        let tokens = match i % 16 {
            0..=7 => short.clone(),
            8..=12 => medium.clone(),
            _ => long.clone(),
        };
        expected_tokens += tokens.len() as u64;
        let (tx, rx) = channel();
        let len = tokens.len();
        queue.try_push(Request {
            id: i,
            tenant: 0,
            tokens,
            enqueued: Instant::now(),
            deadline: None,
            respond: tx,
        });
        receivers.push((i, len, rx));
    }
    assert_eq!(queue.len(), 64, "all requests admitted up front");
    queue.close();
    server.serve(); // drains the closed queue and returns

    // every response arrives, in order, error-free, with full-length argmax
    let mut by_len: std::collections::BTreeMap<usize, Vec<i32>> = std::collections::BTreeMap::new();
    for (id, len, rx) in &receivers {
        let resp = rx.try_recv().unwrap_or_else(|_| panic!("response {id} missing"));
        assert_eq!(resp.id, *id);
        assert!(resp.error.is_none(), "request {id} failed: {:?}", resp.error);
        assert_eq!(resp.argmax.len(), *len);
        // identical prompts must produce identical outputs, regardless of
        // which batch they landed in (per-token numerics are independent)
        let prev = by_len.entry(*len).or_insert_with(|| resp.argmax.clone());
        assert_eq!(prev, &resp.argmax, "prompt of len {len} diverged across batches");
    }

    // metrics totals match the traffic exactly
    let snap = server.metrics().snapshot();
    assert_eq!(snap.requests, 64);
    assert_eq!(snap.tokens, expected_tokens);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.batches, 12, "deterministic formation: 12 executed batches");
    assert!((snap.mean_batch - 64.0 / 12.0).abs() < 1e-9);
    assert!(snap.latency_p99_ms >= snap.latency_p50_ms);
    let routed: u64 = snap.expert_rows.iter().sum();
    // every padded token of every batch routes to top_k experts
    assert_eq!(routed, (8 * 16 + 5 * 64 + 3 * 256) * 4 * 2);

    // plan-cache hits on repeated load signatures: 3 distinct batch
    // shapes, each seen 4 times -> 3 misses, 9 hits
    assert_eq!(snap.plan_cache_misses, 3);
    assert_eq!(snap.plan_cache_hits, 9);
    assert!((snap.plan_cache_hit_rate() - 0.75).abs() < 1e-12);
    let stats = server.executor().cache_stats().expect("sim executor caches plans");
    assert_eq!(stats.hits + stats.misses, snap.batches);
    assert_eq!(stats.entries, 3);
}

#[test]
fn plan_cache_under_capacity_pressure_evicts_and_keeps_counting() {
    // 3 distinct batch shapes cycle through a 2-entry cache: the LRU entry
    // is always the shape about to recur, so every step misses (sequential
    // scan thrash) while occupancy stays at the bound — the eviction path
    // the hit-path test above never reaches.
    let executor = SimStepExecutor::new(SimServeConfig {
        buckets: vec![16, 64, 256],
        max_tokens: 2048,
        experts: 16,
        top_k: 2,
        d_model: 16,
        d_ff: 32,
        cache_capacity: 2, // deliberately below the 3 distinct signatures
        numeric: false,
        threads: 1,
        seed: 9,
    });
    let mut server = Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 8, max_tokens: 2048 },
            queue_capacity: 128,
            ..ServerConfig::default()
        },
        executor,
    );
    let mut rng = Rng::new(3);
    let w = zipf_weights(500, 1.3);
    let short = zipf_prompt(12, &mut rng, &w);
    let medium = zipf_prompt(48, &mut rng, &w);
    let long = zipf_prompt(200, &mut rng, &w);
    let queue = server.queue();
    let mut receivers = Vec::new();
    for i in 0..64u64 {
        let tokens = match i % 16 {
            0..=7 => short.clone(),
            8..=12 => medium.clone(),
            _ => long.clone(),
        };
        let (tx, rx) = channel();
        queue.try_push(Request {
            id: i,
            tenant: 0,
            tokens,
            enqueued: Instant::now(),
            deadline: None,
            respond: tx,
        });
        receivers.push(rx);
    }
    queue.close();
    server.serve();
    for rx in &receivers {
        assert!(rx.try_recv().expect("response").error.is_none());
    }
    // same deterministic formation as above: 12 batches, 3 distinct load
    // signatures cycling short -> medium -> long
    let snap = server.metrics().snapshot();
    assert_eq!(snap.batches, 12);
    assert_eq!(snap.plan_cache_misses, 12, "every lookup thrashes the 2-entry LRU");
    assert_eq!(snap.plan_cache_hits, 0);
    let stats = server.executor().cache_stats().expect("sim executor caches plans");
    assert_eq!(stats.entries, 2, "occupancy pinned at capacity");
}

#[test]
fn mixed_valid_and_oversized_traffic_accounts_cleanly() {
    let executor = SimStepExecutor::new(SimServeConfig {
        buckets: vec![16],
        max_tokens: 256,
        numeric: false,
        ..SimServeConfig::default()
    });
    let mut server = Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 4, max_tokens: 256 },
            queue_capacity: 32,
            ..ServerConfig::default()
        },
        executor,
    );
    let queue = server.queue();
    let mut receivers = Vec::new();
    for i in 0..6u64 {
        // request 3 is longer than every compiled bucket
        let len = if i == 3 { 40 } else { 5 };
        let (tx, rx) = channel();
        queue.try_push(Request {
            id: i,
            tenant: 0,
            tokens: vec![1; len],
            enqueued: Instant::now(),
            deadline: None,
            respond: tx,
        });
        receivers.push((i, rx));
    }
    queue.close();
    server.serve();

    let mut ok = 0;
    let mut failed = 0;
    for (id, rx) in receivers {
        let resp = rx.try_recv().expect("every request gets an answer");
        if resp.error.is_some() {
            assert_eq!(id, 3);
            failed += 1;
        } else {
            assert_eq!(resp.bucket, 16);
            ok += 1;
        }
    }
    assert_eq!((ok, failed), (5, 1));
    let snap = server.metrics().snapshot();
    assert_eq!(snap.requests, 5);
    assert_eq!(snap.errors, 1);
}
