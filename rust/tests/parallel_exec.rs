//! Parallel execution + zero-alloc planning, under DEFAULT features.
//!
//! Pins the three promises the parallel CPU path makes:
//!
//! 1. **Bitwise determinism (property)** — for *any* MoE load scenario and
//!    *any* ragged length mix, executing through a worker pool produces
//!    output bitwise-identical to the serial path, at every thread count.
//!    Parallelism is purely a speed knob, never a numerics knob.
//! 2. **Zero-alloc cache hits (regression)** — a plan-cache *hit* performs
//!    no heap allocation: signature built into a reused scratch, probe by
//!    `Borrow<[u64]>`, `Arc` handout.  Measured with a counting global
//!    allocator using a thread-local counter, so concurrently running
//!    tests cannot pollute the measurement.
//! 3. **Panic containment** — a job panicking inside a pool worker
//!    surfaces as a typed [`ExecError::Backend`] instead of tearing down
//!    the caller, and the shared pool keeps serving later sessions.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

use staticbatch::exec::{CpuBackend, ExecError, ExecutionSession, NumericInputs};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::routing::{ExpertLoad, LoadScenario};
use staticbatch::serve::{SimServeConfig, SimStepExecutor, StepExecutor, StepInput};
use staticbatch::util::prop::check;
use staticbatch::util::tensor::Tensor;
use staticbatch::util::threadpool::ThreadPool;
use staticbatch::workload::ragged::{RaggedAttentionWorkload, RaggedInputs, RaggedLoad};

// ---- counting allocator (thread-local, so parallel tests don't bleed) ----

struct CountingAlloc;

thread_local! {
    static LOCAL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn bump() {
    // try_with: survive TLS teardown at thread exit
    let _ = LOCAL_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations made by *this thread* so far.
fn thread_allocs() -> u64 {
    LOCAL_ALLOCS.try_with(Cell::get).unwrap_or(0)
}

// ---- 1. parallel == serial, bitwise ----

fn run_moe(shape: MoeShape, load: &ExpertLoad, seed: u64, threads: usize) -> Tensor {
    let mut s = ExecutionSession::new(shape)
        .backend(CpuBackend)
        .inputs(NumericInputs::synthetic(shape, load, seed))
        .threads(threads);
    s.run(load).expect("cpu step").output.expect("numeric output")
}

#[test]
fn property_moe_parallel_is_bitwise_equal_to_serial() {
    check(
        "moe-parallel-bitwise",
        12,
        |g| {
            let seq = 16 + g.rng.usize_below(48 * g.size.min(4));
            let experts = 4 + g.rng.usize_below(9);
            let top_k = 1 + g.rng.usize_below(2);
            let scenario = g.rng.usize_below(4);
            let threads = 2 + g.rng.usize_below(3);
            let seed = g.rng.next_u64();
            (seq, experts, top_k, scenario, threads, seed)
        },
        |&(seq, experts, top_k, scenario, threads, seed)| {
            let shape =
                MoeShape { seq, d_model: 16, d_ff: 24, experts, top_k, dtype_bytes: 4 };
            let load = match scenario {
                0 => LoadScenario::Balanced,
                1 => LoadScenario::Best,
                2 => LoadScenario::Worst,
                _ => LoadScenario::Zipf(1.2),
            }
            .counts(&shape, seed);
            let serial = run_moe(shape, &load, seed, 1);
            let par = run_moe(shape, &load, seed, threads);
            if serial.data != par.data || serial.shape != par.shape {
                return Err(format!("{threads}-thread MoE output diverged from serial"));
            }
            Ok(())
        },
    );
}

fn run_ragged(w: RaggedAttentionWorkload, load: &RaggedLoad, seed: u64, threads: usize) -> Tensor {
    let mut s = ExecutionSession::for_workload(w)
        .backend(CpuBackend)
        .inputs(RaggedInputs::synthetic(&w, load, seed))
        .threads(threads);
    s.run(load).expect("ragged step").output.expect("numeric output")
}

#[test]
fn property_ragged_parallel_is_bitwise_equal_to_serial() {
    check(
        "ragged-parallel-bitwise",
        12,
        |g| {
            let n = 1 + g.rng.usize_below(12 * g.size.min(6));
            let lens: Vec<usize> = (0..n)
                .map(|_| match g.rng.usize_below(4) {
                    0 => 0, // empty sequences must stay inert in both paths
                    1 => 1 + g.rng.usize_below(8),
                    _ => 1 + g.rng.usize_below(600),
                })
                .collect();
            let heads = 1 + g.rng.usize_below(4);
            let head_dim = 4 + 4 * g.rng.usize_below(3);
            let threads = 2 + g.rng.usize_below(3);
            let seed = g.rng.next_u64();
            (lens, heads, head_dim, threads, seed)
        },
        |(lens, heads, head_dim, threads, seed)| {
            let w = RaggedAttentionWorkload {
                heads: *heads,
                head_dim: *head_dim,
                dtype_bytes: 4,
            };
            let load = RaggedLoad { lens: lens.clone() };
            let serial = run_ragged(w, &load, *seed, 1);
            let par = run_ragged(w, &load, *seed, *threads);
            if serial.data != par.data || serial.shape != par.shape {
                return Err(format!("{threads}-thread ragged output diverged from serial"));
            }
            Ok(())
        },
    );
}

// ---- 2. zero-alloc plan-cache hits ----

#[test]
fn moe_plan_cache_hit_allocates_nothing() {
    let shape = MoeShape { seq: 64, d_model: 16, d_ff: 24, experts: 8, top_k: 2, dtype_bytes: 4 };
    let load = LoadScenario::Zipf(1.2).counts(&shape, 7);
    let mut s = ExecutionSession::new(shape).plan_cache(8);
    let _ = s.plan_shared(&load); // miss: builds and caches
    let _ = s.plan_shared(&load); // first hit settles scratch capacity
    let before = thread_allocs();
    for _ in 0..100 {
        let p = s.plan_shared(&load);
        std::hint::black_box(&p);
    }
    let after = thread_allocs();
    assert_eq!(after - before, 0, "plan-cache hit must not touch the heap");
}

#[test]
fn ragged_plan_cache_hit_allocates_nothing() {
    let w = RaggedAttentionWorkload { heads: 4, head_dim: 16, dtype_bytes: 4 };
    let load = RaggedLoad { lens: vec![300, 0, 17, 64, 1, 512] };
    let mut s = ExecutionSession::for_workload(w).plan_cache(8);
    let _ = s.plan_shared(&load);
    let _ = s.plan_shared(&load);
    let before = thread_allocs();
    for _ in 0..100 {
        let p = s.plan_shared(&load);
        std::hint::black_box(&p);
    }
    let after = thread_allocs();
    assert_eq!(after - before, 0, "plan-cache hit must not touch the heap");
}

// ---- 3. worker panic -> typed error; pool survives ----

#[test]
fn worker_panic_surfaces_as_exec_error_and_pool_survives() {
    let shape = MoeShape { seq: 32, d_model: 16, d_ff: 24, experts: 8, top_k: 2, dtype_bytes: 4 };
    let load = LoadScenario::Worst.counts(&shape, 3);
    let pool = Arc::new(ThreadPool::new(2));

    // empty token storage: every gather in every worker indexes out of
    // bounds, so each pool job panics
    let mut bad = NumericInputs::synthetic(shape, &load, 3);
    bad.tokens.data.clear();
    let mut broken = ExecutionSession::new(shape)
        .backend(CpuBackend)
        .inputs(bad)
        .thread_pool(Arc::clone(&pool));
    match broken.run(&load) {
        Err(ExecError::Backend { backend, detail, .. }) => {
            assert_eq!(backend, "cpu");
            assert!(detail.contains("worker pool"), "unexpected detail: {detail}");
        }
        Err(e) => panic!("expected a backend error, got: {e}"),
        Ok(_) => panic!("corrupt inputs must not execute"),
    }

    // the same pool keeps working afterwards, and still matches serial
    let mut good = ExecutionSession::new(shape)
        .backend(CpuBackend)
        .inputs(NumericInputs::synthetic(shape, &load, 3))
        .thread_pool(pool);
    let par = good.run(&load).expect("pool survived").output.expect("numeric output");
    let serial = run_moe(shape, &load, 3, 1);
    assert_eq!(par.data, serial.data, "recovered pool must still match serial");
}

// ---- serving inherits the pool ----

#[test]
fn sim_executor_outputs_are_thread_count_invariant() {
    let base = SimServeConfig {
        buckets: vec![16],
        max_tokens: 256,
        experts: 8,
        top_k: 2,
        d_model: 16,
        d_ff: 24,
        cache_capacity: 8,
        numeric: true,
        threads: 1,
        seed: 5,
    };
    let mut serial = SimStepExecutor::new(base.clone());
    let mut parallel = SimStepExecutor::new(SimServeConfig { threads: 4, ..base });
    for step in 0..6 {
        let tokens: Vec<i32> = (0..64).map(|i| (i * 7 + step * 13) % 50 + 1).collect();
        let input = StepInput { bucket: 16, rows: 4, tokens: &tokens };
        let a = serial.execute_step(&input).expect("serial step");
        let b = parallel.execute_step(&input).expect("parallel step");
        assert_eq!(a.argmax, b.argmax, "step {step}: 4-thread argmax diverged");
        assert_eq!(a.expert_rows, b.expert_rows, "step {step}: routing diverged");
    }
}
