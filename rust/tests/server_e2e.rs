//! Full-stack serving tests.
//!
//! Two halves: a default-features shutdown-latency bound on the pipelined
//! serving core (closing the queue must end the loop promptly — the old
//! poll-loop design bounded this only by the poll interval), and — under
//! `--features pjrt` — the deployment path the `serve` subcommand runs:
//! requests over a real TCP connection as JSON lines, through the
//! admission queue and batcher, executing the AOT LM artifact on PJRT.
//! The PJRT half requires `make artifacts`; it skips if absent.

use std::time::{Duration, Instant};

use staticbatch::coordinator::batcher::BatchPolicy;
use staticbatch::serve::{Server, ServerConfig, SimServeConfig, SimStepExecutor};

/// Close queue → loop exit must be wakeup-driven, not polled: bound it
/// well under the old 50 ms poll interval.  The server (its executor is
/// not `Send`) lives on a spawned thread; the handle comes back over a
/// channel so the test can drive shutdown from outside.
#[test]
fn shutdown_latency_is_bounded_after_close() {
    let (handle_tx, handle_rx) = std::sync::mpsc::channel();
    let serving = std::thread::spawn(move || {
        let ex = SimStepExecutor::new(SimServeConfig {
            numeric: false,
            ..SimServeConfig::default()
        });
        let mut server = Server::new(
            ServerConfig {
                policy: BatchPolicy { buckets: Vec::new(), max_requests: 8, max_tokens: 2048 },
                queue_capacity: 64,
                ..ServerConfig::default()
            },
            ex,
        );
        handle_tx.send(server.handle()).expect("test thread alive");
        server.serve();
    });
    let handle = handle_rx.recv().expect("serving thread started");
    // a little in-flight work so shutdown actually drains something
    let tickets: Vec<_> = (0..8).map(|_| handle.submit(&[1, 2, 3]).expect("open")).collect();

    let t0 = Instant::now();
    handle.close();
    serving.join().expect("serving thread");
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_millis(500),
        "close → loop exit took {elapsed:?}; wakeup-driven shutdown must not wait out a poll"
    );
    for t in tickets {
        assert!(t.wait().error.is_none(), "drained, not dropped, on close");
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_e2e {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::Arc;

    use staticbatch::coordinator::engine::{Engine, EngineConfig};
    use staticbatch::coordinator::server;
    use staticbatch::util::json::Json;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn tcp_serving_roundtrip() {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let handle = Engine::spawn(EngineConfig {
            artifacts_dir: artifacts_dir(),
            ..Default::default()
        })
        .expect("engine");
        let vocab = {
            // discover vocab from the engine's manifest-derived config
            handle.lm.vocab
        };

        // bind an ephemeral port by racing ports (std has no port-0
        // inspection through our listen() helper, so bind port 0 directly)
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let queue = Arc::clone(&handle.queue);
        let metrics = Arc::clone(&handle.metrics);
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let stream = stream.unwrap();
                let q = Arc::clone(&queue);
                let m = Arc::clone(&metrics);
                std::thread::spawn(move || {
                    let _ = server::handle_conn(stream, q, m);
                });
            }
        });

        // two concurrent clients, a few requests each
        let mut clients = Vec::new();
        for c in 0..2u64 {
            clients.push(std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut w = stream.try_clone().unwrap();
                let mut r = BufReader::new(stream);
                for i in 0..3u64 {
                    let id = c * 100 + i;
                    let toks: Vec<String> = (0..5 + i as usize)
                        .map(|t| ((t * 7 + c as usize) % 100).to_string())
                        .collect();
                    writeln!(w, "{{\"id\":{id},\"tokens\":[{}]}}", toks.join(",")).unwrap();
                    let mut line = String::new();
                    r.read_line(&mut line).unwrap();
                    let j = Json::parse(line.trim()).unwrap();
                    assert_eq!(j.get("id").unwrap().as_i64().unwrap() as u64, id);
                    assert!(j.get("error").is_none(), "error: {line}");
                    let argmax = j.get("argmax").unwrap().as_arr().unwrap();
                    assert_eq!(argmax.len(), 5 + i as usize);
                    for t in argmax {
                        let v = t.as_i64().unwrap();
                        assert!((0..100_000).contains(&v));
                    }
                    assert_eq!(j.get("bucket").unwrap().as_usize().unwrap(), 16);
                }
                // stats line works
                writeln!(w, "stats").unwrap();
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                assert!(line.contains("requests="), "{line}");
                writeln!(w, "quit").unwrap();
            }));
        }
        for c in clients {
            c.join().unwrap();
        }

        // failure injection over the same socket path: oversized request
        // (no compiled bucket fits) and malformed JSON both return error
        // lines without killing the connection or the engine
        {
            let stream = TcpStream::connect(addr).unwrap();
            let mut w = stream.try_clone().unwrap();
            let mut r = BufReader::new(stream);
            let toks: Vec<String> = (0..5000).map(|t| (t % 50).to_string()).collect();
            writeln!(w, "{{\"id\":999,\"tokens\":[{}]}}", toks.join(",")).unwrap();
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let j = Json::parse(line.trim()).unwrap();
            assert!(j.get("error").is_some(), "oversized must fail: {line}");

            writeln!(w, "this is not json").unwrap();
            line.clear();
            r.read_line(&mut line).unwrap();
            assert!(line.contains("error"));

            // the connection still works afterwards
            writeln!(w, "{{\"id\":1000,\"tokens\":[1,2,3]}}").unwrap();
            line.clear();
            r.read_line(&mut line).unwrap();
            let j = Json::parse(line.trim()).unwrap();
            assert!(j.get("error").is_none(), "{line}");
            writeln!(w, "quit").unwrap();
        }

        let snap = handle.metrics.snapshot();
        assert_eq!(snap.requests, 7);
        assert_eq!(snap.errors, 1); // the oversized rejection
        assert!(snap.latency_p50_ms > 0.0);
        let _ = vocab;
        handle.shutdown();
    }

    #[test]
    fn engine_spawn_fails_cleanly_without_artifacts() {
        let bogus = std::path::PathBuf::from("/nonexistent/artifacts");
        let err = Engine::spawn(EngineConfig { artifacts_dir: bogus, ..Default::default() });
        assert!(err.is_err());
        let msg = format!("{}", err.err().unwrap());
        assert!(msg.contains("engine init"), "{msg}");
    }
}
