//! Cross-backend agreement: property tests over random `ExpertLoad`s
//! asserting that the simulator backend and the CPU numeric backend
//! dispatch *identical* `(task, tile, kind)` sequences for the same plan —
//! the simulator decodes the two-stage mapping, the CPU executor actually
//! runs `StaticBatch` dispatch, so agreement pins the whole Algorithm
//! 1/2/4 pipeline across two independent code paths.
//!
//! Also covers the construction-time dispatch guarantee: a batch
//! containing an unregistered `TaskKind` is rejected by
//! `DispatchTable::build` with a typed error instead of panicking at
//! launch.

use staticbatch::batching::dispatch::{DispatchError, DispatchTableBuilder};
use staticbatch::batching::task::{TaskDescriptor, TaskKind};
use staticbatch::exec::{CpuBackend, ExecutionSession, NumericInputs, SimBackend};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::ordering::OrderingStrategy;
use staticbatch::moe::routing::ExpertLoad;
use staticbatch::util::prop;

/// Random routing outcome + the shape it belongs to.
fn gen_case(g: &mut prop::GenCtx) -> (MoeShape, ExpertLoad, u64) {
    let experts = 2 + g.rng.usize_below(14);
    let mut counts = vec![0usize; experts];
    let rows = g.rng.usize_below(g.size * 24 + 2);
    for _ in 0..rows {
        let e = g.rng.usize_below(experts);
        counts[e] += 1;
    }
    let shape = MoeShape {
        seq: rows.max(1),
        d_model: 8 + g.rng.usize_below(3) * 8,
        d_ff: 16 + g.rng.usize_below(3) * 16,
        experts,
        top_k: 1,
        dtype_bytes: 4,
    };
    let seed = g.rng.below(u32::MAX as u64);
    (shape, ExpertLoad { counts }, seed)
}

#[test]
fn sim_and_cpu_backends_dispatch_identical_sequences() {
    prop::check(
        "sim-cpu-dispatch-agreement",
        60,
        gen_case,
        |&(shape, ref load, seed)| {
            for ordering in [
                OrderingStrategy::Natural,
                OrderingStrategy::HalfInterval,
                OrderingStrategy::SortedDesc,
            ] {
                let sim_trace = ExecutionSession::new(shape)
                    .ordering(ordering)
                    .backend(SimBackend::ours())
                    .record_dispatch()
                    .run(load)
                    .map_err(|e| format!("sim backend: {e}"))?
                    .trace
                    .ok_or("sim backend returned no trace")?;
                let cpu_trace = ExecutionSession::new(shape)
                    .ordering(ordering)
                    .backend(CpuBackend)
                    .inputs(NumericInputs::synthetic(shape, load, seed))
                    .record_dispatch()
                    .run(load)
                    .map_err(|e| format!("cpu backend: {e}"))?
                    .trace
                    .ok_or("cpu backend returned no trace")?;
                if sim_trace != cpu_trace {
                    let first = sim_trace
                        .iter()
                        .zip(&cpu_trace)
                        .position(|(a, b)| a != b)
                        .unwrap_or(sim_trace.len().min(cpu_trace.len()));
                    return Err(format!(
                        "dispatch traces diverge under {ordering:?}: lens {}/{}, first diff at block {first}",
                        sim_trace.len(),
                        cpu_trace.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn cpu_backend_numerics_survive_random_loads() {
    // agreement on *where* blocks go is necessary but not sufficient — the
    // gathered numbers must also match the dense reference
    prop::check("cpu-vs-reference", 25, gen_case, |&(shape, ref load, seed)| {
        let numeric = NumericInputs::synthetic(shape, load, seed);
        let want = {
            let inputs = staticbatch::moe::cpu_exec::MoeInputs {
                tokens: &numeric.tokens,
                weights: &numeric.weights,
                token_index: &numeric.token_index,
                gates: &numeric.gates,
            };
            staticbatch::moe::cpu_exec::reference(&inputs, shape.seq, shape.d_model, shape.d_ff)
        };
        let got = ExecutionSession::new(shape)
            .backend(CpuBackend)
            .inputs(numeric)
            .run(load)
            .map_err(|e| format!("cpu backend: {e}"))?
            .output
            .ok_or("cpu backend returned no tensor")?;
        let err = got.max_abs_diff(&want);
        if err < 1e-3 {
            Ok(())
        } else {
            Err(format!("max abs err {err}"))
        }
    });
}

#[test]
fn dispatch_table_rejects_unregistered_kind_in_batch() {
    // a batch mixing GEMM strategies where only strategy 0 is registered
    let tasks: Vec<TaskDescriptor> = [0usize, 0, 3]
        .iter()
        .map(|&s| TaskDescriptor {
            kind: TaskKind::Gemm { strategy: s },
            rows: 32,
            cols: 64,
            inner: 16,
            tile_rows: 16,
            tile_cols: 64,
        })
        .collect();
    let err = DispatchTableBuilder::<()>::new()
        .on(TaskKind::Gemm { strategy: 0 }, |_, _, _, _| {})
        .build(&tasks)
        .unwrap_err();
    assert_eq!(
        err,
        DispatchError::Unregistered { kind: TaskKind::Gemm { strategy: 3 }, task_index: 2 }
    );
}
