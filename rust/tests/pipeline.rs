//! Concurrency suite for the pipelined serving front end (default
//! features: no PJRT, artifacts, or GPU).
//!
//! The pipeline is exactly the kind of change that is wrong until proven
//! right, so this suite attacks it from every side: a multi-producer
//! overload soak that must conserve every request (`sent == ok + failed +
//! shed`) and drain cleanly on shutdown, a property test that the
//! pipelined loop produces bitwise-identical responses to the synchronous
//! reference loop over recorded arrival traces, backpressure semantics at
//! exact queue capacity, a gated-executor proof that formation really
//! overlaps execution, and driver-vs-metrics shed reconciliation.  Every
//! test runs under a watchdog that aborts the process on deadlock instead
//! of hanging CI.
//!
//! CI runs the soak repeatedly (`PIPELINE_SOAK_REPEAT=10`) so interleaving
//! bugs cannot hide behind a single lucky run.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

use staticbatch::coordinator::batcher::BatchPolicy;
use staticbatch::exec::ExecError;
use staticbatch::serve::{
    run_traffic, Server, ServerConfig, SimServeConfig, SimStepExecutor, StepExecutor, StepInput,
    StepOutput, SubmitError, Ticket, TrafficConfig,
};
use staticbatch::util::prop::check;

/// Aborts the whole process if the test runs past `limit` — a deadlocked
/// pipeline must fail CI loudly, not hang it.  Disarmed on drop (including
/// ordinary test panics).
struct Watchdog {
    done: Arc<AtomicBool>,
}

impl Watchdog {
    fn arm(limit: Duration) -> Watchdog {
        let done = Arc::new(AtomicBool::new(false));
        let seen = Arc::clone(&done);
        std::thread::spawn(move || {
            let t0 = Instant::now();
            while t0.elapsed() < limit {
                if seen.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
            eprintln!("watchdog: test exceeded {limit:?} — aborting (likely pipeline deadlock)");
            std::process::abort();
        });
        Watchdog { done }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.done.store(true, Ordering::Relaxed);
    }
}

fn accounting_server(
    queue_capacity: usize,
    max_requests: usize,
    seed: u64,
) -> Server<SimStepExecutor> {
    let ex = SimStepExecutor::new(SimServeConfig {
        numeric: false,
        seed,
        ..SimServeConfig::default()
    });
    Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests, max_tokens: 2048 },
            queue_capacity,
            ..ServerConfig::default()
        },
        ex,
    )
}

/// One soak round: 8 open-loop producers hammer a deliberately small queue
/// while the pipeline serves, the stream closes only after every producer
/// has finished, and every request must be accounted for exactly once.
fn soak_once(seed: u64) {
    const PRODUCERS: usize = 8;
    const PER_PRODUCER: usize = 200;
    let mut server = accounting_server(32, 8, seed);
    let handle = server.handle();

    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut tickets: Vec<Ticket> = Vec::new();
                let mut shed = 0usize;
                for i in 0..PER_PRODUCER {
                    // mixed lengths across all three buckets, deterministic
                    // per (producer, index) so rounds are reproducible
                    let len = 1 + (p * 37 + i * 13 + seed as usize) % 200;
                    match h.try_submit(&vec![1i32; len]) {
                        Ok(t) => tickets.push(t),
                        Err(SubmitError::Backpressure) => shed += 1,
                        Err(SubmitError::Closed) => {
                            panic!("queue closed while producers still running")
                        }
                    }
                }
                (tickets, shed)
            })
        })
        .collect();

    // close only after the last producer finishes, from its own thread, so
    // the serving loop below sees a live stream the whole time
    let closer = std::thread::spawn(move || {
        let mut tickets = Vec::new();
        let mut shed = 0usize;
        for p in producers {
            let (t, s) = p.join().expect("producer thread");
            tickets.extend(t);
            shed += s;
        }
        handle.close();
        (tickets, shed)
    });

    server.serve(); // returns once closed and drained

    let (tickets, shed) = closer.join().expect("closer thread");
    let sent = PRODUCERS * PER_PRODUCER;
    assert_eq!(tickets.len() + shed, sent);

    let mut ok = 0usize;
    let mut failed = 0usize;
    for t in tickets {
        // serve() has returned: every admitted ticket must already be
        // resolved (clean drain), so wait() cannot block
        if t.wait().error.is_none() {
            ok += 1;
        } else {
            failed += 1;
        }
    }
    assert_eq!(ok + failed + shed, sent, "conservation: sent == ok + failed + shed");

    // the server's own counters reconcile with driver-side accounting
    let snap = server.metrics().snapshot();
    assert_eq!(snap.requests as usize, ok);
    assert_eq!(snap.errors as usize, failed);
    assert_eq!(snap.rejected as usize, shed);
    assert_eq!(failed, 0, "no request may fail under clean overload");
    assert!(shed > 0, "a 32-slot queue under a 1600-request hammer must shed");
}

#[test]
fn multi_producer_soak_conserves_every_request() {
    let _wd = Watchdog::arm(Duration::from_secs(120));
    // CI stress mode repeats the soak to shake out rare interleavings
    let repeat: usize = std::env::var("PIPELINE_SOAK_REPEAT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    for round in 0..repeat.max(1) {
        soak_once(0x50AC + round as u64);
    }
}

/// Replay one recorded arrival trace and collect `(id, bucket, argmax,
/// error)` per ticket in submission order — everything a caller can
/// observe except timing.
fn run_trace(
    prompts: &[Vec<i32>],
    pipeline: bool,
) -> Vec<(u64, usize, Vec<i32>, Option<String>)> {
    let ex = SimStepExecutor::new(SimServeConfig {
        d_model: 16,
        d_ff: 32,
        seed: 11,
        ..SimServeConfig::default()
    });
    let mut server = Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 4, max_tokens: 2048 },
            queue_capacity: prompts.len().max(1),
            pipeline,
            ..ServerConfig::default()
        },
        ex,
    );
    let handle = server.handle();
    let tickets: Vec<Ticket> = prompts
        .iter()
        .map(|p| handle.submit(p).expect("queue sized to the trace"))
        .collect();
    handle.close();
    server.serve();
    tickets
        .into_iter()
        .map(|t| {
            let r = t.wait();
            (r.id, r.bucket, r.argmax, r.error)
        })
        .collect()
}

#[test]
fn pipelined_responses_match_the_synchronous_loop_bitwise() {
    let _wd = Watchdog::arm(Duration::from_secs(300));
    // Property: over recorded arrival traces (mixed lengths, including
    // oversized rejects), the pipelined server with CPU numerics produces
    // exactly the per-request argmax rows (and errors) of the synchronous
    // reference loop.  Pipelining changes timing, never results.
    check(
        "pipelined-matches-sync",
        16,
        |g| {
            let n = 1 + g.rng.below(8 * g.size as u64) as usize;
            (0..n)
                .map(|_| {
                    // up to 300 tokens: lengths past the largest bucket
                    // (256) must be rejected identically in both modes
                    let len = 1 + g.rng.below(300) as usize;
                    (0..len).map(|_| g.rng.below(1000) as i32 + 1).collect::<Vec<i32>>()
                })
                .collect::<Vec<_>>()
        },
        |prompts| {
            let sync = run_trace(prompts, false);
            let pipelined = run_trace(prompts, true);
            if sync == pipelined {
                Ok(())
            } else {
                Err(format!(
                    "responses diverged: sync {:?} vs pipelined {:?}",
                    sync, pipelined
                ))
            }
        },
    );
}

#[test]
fn blocking_submit_unblocks_once_a_step_completes() {
    let _wd = Watchdog::arm(Duration::from_secs(60));
    let mut server = accounting_server(1, 1, 7);
    let handle = server.handle();
    // fill the 1-slot queue before the server runs
    let t0 = handle.try_submit(&[1, 2, 3]).expect("first submission fits");
    assert_eq!(handle.try_submit(&[4]).unwrap_err(), SubmitError::Backpressure);

    let h2 = handle.clone();
    let blocked = std::thread::spawn(move || {
        // blocks on the full queue; only a completing step frees the slot
        let t = h2.submit(&[4, 5]).expect("unblocked by a completing step");
        h2.close();
        t.wait()
    });
    // nothing pops before serve(): the producer must still be blocked
    std::thread::sleep(Duration::from_millis(50));
    assert!(!blocked.is_finished(), "submit returned while the queue was still full");

    server.serve();
    let second = blocked.join().expect("blocked producer");
    assert!(second.error.is_none());
    assert!(t0.wait().error.is_none());
    assert_eq!(server.metrics().snapshot().requests, 2);
}

#[test]
fn formation_overlaps_execution_in_the_pipelined_loop() {
    let _wd = Watchdog::arm(Duration::from_secs(60));

    /// Holds its first step inside `execute_step` until released, so the
    /// test can observe the batcher forming the next step *during*
    /// execution — deterministic proof of overlap, no timing luck.
    struct Gated {
        release: Receiver<()>,
        first: bool,
    }

    impl StepExecutor for Gated {
        fn name(&self) -> &'static str {
            "gated"
        }

        fn buckets(&self) -> Vec<usize> {
            vec![4]
        }

        fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
            if self.first {
                self.first = false;
                let _ = self.release.recv();
            }
            Ok(StepOutput {
                argmax: vec![0; step.rows * step.bucket],
                expert_rows: Vec::new(),
                failed: Vec::new(),
                sim_time_s: None,
            })
        }
    }

    let (release_tx, release_rx) = channel();
    let mut server = Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 1, max_tokens: 64 },
            queue_capacity: 8,
            ..ServerConfig::default()
        },
        Gated { release: release_rx, first: true },
    );
    let handle = server.handle();
    let tickets: Vec<Ticket> =
        (0..3).map(|_| handle.try_submit(&[1]).expect("capacity 8")).collect();
    handle.close();

    let metrics = server.metrics();
    let monitor = std::thread::spawn(move || {
        // step 1 is held inside execute_step, yet the in-flight gauge must
        // climb past 1: the batcher sealed step 2 while step 1 executed
        while metrics.snapshot().in_flight < 2 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let _ = release_tx.send(());
    });

    server.serve();
    monitor.join().expect("monitor thread");
    for t in tickets {
        assert!(t.wait().error.is_none());
    }
    let snap = server.metrics().snapshot();
    assert!(
        snap.max_in_flight >= 2,
        "no overlap observed: max_in_flight = {}",
        snap.max_in_flight
    );
    assert_eq!(snap.in_flight, 0, "pipeline drained back to empty");
}

#[test]
fn driver_shed_counts_reconcile_with_server_metrics() {
    let _wd = Watchdog::arm(Duration::from_secs(120));
    // burst 512 requests into a 16-slot queue: the driver counts its own
    // sheds; the server's rejected counter must agree exactly
    let mut server = accounting_server(16, 8, 3);
    let report = run_traffic(
        &mut server,
        TrafficConfig { requests: 512, rate_hz: 0.0, ..TrafficConfig::default() },
    );
    assert_eq!(report.ok + report.failed + report.rejected, report.sent);
    assert_eq!(report.snapshot.rejected as usize, report.rejected);
    assert_eq!(report.snapshot.requests as usize, report.ok);
    assert_eq!(report.snapshot.errors as usize, report.failed);
}
