//! Fused transformer-layer integration suite: one heterogeneous
//! `Plan<FusedLayerWorkload>` — decode attention, chunked prefill, and
//! routed expert GEMMs under a single σ/TilePrefix — executed through the
//! *unchanged* mapping machinery on both the simulator and the CPU
//! backend.
//!
//! Covers, from the public API only:
//! * a property test that the simulator's Algorithm-4 mapping decode and
//!   the CPU `StaticBatch` dispatch produce identical `(task, tile, kind)`
//!   sequences over random mixed loads and every ordering strategy;
//! * bitwise equality of the fused CPU output against the sequential
//!   reference (standalone ragged attention, then standalone MoE over its
//!   output) on decode+FFN loads, and close agreement when chunked
//!   prefill joins the batch (prefill tiles chunk by their own catalog, so
//!   the merge order differs from the decode catalog's);
//! * plan-cache behavior of the composite signature: repeats hit, any
//!   change to either phase — including swapping a slot between decode and
//!   prefill at the same KV span — misses;
//! * the accounting claim: on skewed prompt lengths a prefill+decode mix
//!   under one fused plan beats the padded-dense two-kernel scheme.

use staticbatch::exec::{CpuBackend, ExecutionSession, NumericInputs, SimBackend};
use staticbatch::moe::ordering::OrderingStrategy;
use staticbatch::util::prop;
use staticbatch::workload::ragged::RaggedInputs;
use staticbatch::workload::transformer::{
    FusedInputs, FusedLayerWorkload, FusedLoad, PaddedDenseFused, SeqSpec,
};

/// Random mixed load for the tiny fused workload: 64 slots cycling through
/// empty / prefill / decode with random spans, experts with random rows.
fn gen_case(g: &mut prop::GenCtx) -> (FusedLoad, u64) {
    let w = FusedLayerWorkload::tiny();
    let seqs: Vec<SeqSpec> = (0..w.shape.seq)
        .map(|_| match g.rng.below(4) {
            0 => SeqSpec::Empty,
            1 => SeqSpec::Prefill { len: 1 + g.rng.usize_below(300) },
            _ => SeqSpec::Decode { kv_len: 1 + g.rng.usize_below(600) },
        })
        .collect();
    let mut expert_counts = vec![0usize; w.shape.experts];
    for _ in 0..g.rng.usize_below(g.size * 8 + 8) {
        let e = g.rng.usize_below(w.shape.experts);
        expert_counts[e] += 1;
    }
    let load = FusedLoad { seqs, expert_counts };
    let seed = g.rng.below(u32::MAX as u64);
    (load, seed)
}

/// A fixed decode+FFN load (no prefill) whose chunking is identical under
/// the fused and the standalone ragged planners.
fn decode_load() -> FusedLoad {
    let w = FusedLayerWorkload::tiny();
    FusedLoad {
        seqs: (0..w.shape.seq)
            .map(|i| match i % 4 {
                0 => SeqSpec::Empty,
                _ => SeqSpec::Decode { kv_len: 1 + 19 * i },
            })
            .collect(),
        expert_counts: (0..w.shape.experts).map(|e| if e == 2 { 0 } else { 6 * e + 3 }).collect(),
    }
}

#[test]
fn sim_and_cpu_dispatch_identical_sequences_over_mixed_kinds() {
    let w = FusedLayerWorkload::tiny();
    prop::check("fused-sim-cpu-dispatch-agreement", 40, gen_case, |(load, seed)| {
        for ordering in [
            OrderingStrategy::Natural,
            OrderingStrategy::HalfInterval,
            OrderingStrategy::SortedDesc,
        ] {
            let sim_trace = ExecutionSession::for_workload(w)
                .ordering(ordering)
                .backend(SimBackend::ours())
                .record_dispatch()
                .run(load)
                .map_err(|e| format!("sim backend: {e}"))?
                .trace
                .ok_or("sim backend returned no trace")?;
            let cpu_trace = ExecutionSession::for_workload(w)
                .ordering(ordering)
                .backend(CpuBackend)
                .inputs(FusedInputs::synthetic(&w, load, *seed))
                .record_dispatch()
                .run(load)
                .map_err(|e| format!("cpu backend: {e}"))?
                .trace
                .ok_or("cpu backend returned no trace")?;
            if sim_trace != cpu_trace {
                let first = sim_trace
                    .iter()
                    .zip(&cpu_trace)
                    .position(|(a, b)| a != b)
                    .unwrap_or(sim_trace.len().min(cpu_trace.len()));
                return Err(format!(
                    "dispatch traces diverge under {ordering:?}: lens {}/{}, first diff at block {first}",
                    sim_trace.len(),
                    cpu_trace.len()
                ));
            }
        }
        Ok(())
    });
}

/// Run the sequential two-plan reference with the SAME tensors the fused
/// inputs hold: standalone ragged attention over the load's KV spans, then
/// standalone MoE over the attention output.
fn sequential_reference(w: &FusedLayerWorkload, load: &FusedLoad, seed: u64) -> Vec<f32> {
    // same seed => RaggedInputs::synthetic inside FusedInputs::synthetic
    // produced bitwise these q/keys/values
    let attn_out = ExecutionSession::for_workload(w.attn)
        .backend(CpuBackend)
        .inputs(RaggedInputs::synthetic(&w.attn, &load.ragged(), seed))
        .run(&load.ragged())
        .expect("ragged cpu step")
        .output
        .expect("ragged numeric output");
    let fused_inputs = FusedInputs::synthetic(w, load, seed);
    ExecutionSession::new(w.shape)
        .backend(CpuBackend)
        .inputs(NumericInputs {
            tokens: attn_out,
            weights: fused_inputs.expert_weights,
            token_index: fused_inputs.token_index,
            gates: fused_inputs.gates,
        })
        .run(&load.expert_load())
        .expect("moe cpu step")
        .output
        .expect("moe numeric output")
        .data
}

#[test]
fn fused_output_is_bitwise_equal_to_sequential_ragged_then_moe() {
    let w = FusedLayerWorkload::tiny();
    let load = decode_load();
    let seed = 29;
    let mut session = ExecutionSession::for_workload(w)
        .backend(CpuBackend)
        .inputs(FusedInputs::synthetic(&w, &load, seed));
    // one plan, two task kinds, through the unchanged machinery
    let plan = session.plan(&load);
    let kinds: std::collections::BTreeSet<usize> =
        plan.descriptors().iter().map(|d| d.kind.dispatch_id()).collect();
    assert!(kinds.len() >= 2, "fused plan must mix task kinds, got {kinds:?}");
    let fused = session
        .run(&load)
        .expect("fused cpu step")
        .output
        .expect("fused numeric output");
    let sequential = sequential_reference(&w, &load, seed);
    assert_eq!(fused.data.len(), sequential.len());
    assert_eq!(fused.data, sequential, "fused output must be bitwise the sequential reference");
}

#[test]
fn prefill_in_the_mix_stays_close_to_the_sequential_reference() {
    // prefill slots chunk by PREFILL_CATALOG while the standalone ragged
    // planner chunks the same spans by KV_CATALOG, so the online-softmax
    // merge order differs: equality here is numeric, not bitwise
    let w = FusedLayerWorkload::tiny();
    prop::check("fused-vs-sequential-with-prefill", 10, gen_case, |(load, seed)| {
        let fused = ExecutionSession::for_workload(w)
            .backend(CpuBackend)
            .inputs(FusedInputs::synthetic(&w, load, *seed))
            .run(load)
            .map_err(|e| format!("fused cpu step: {e}"))?
            .output
            .ok_or("fused backend returned no tensor")?;
        let sequential = sequential_reference(&w, load, *seed);
        let err = fused
            .data
            .iter()
            .zip(&sequential)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        if err < 1e-3 {
            Ok(())
        } else {
            Err(format!("max abs err {err}"))
        }
    });
}

#[test]
fn composite_signature_drives_plan_cache_hits_and_misses() {
    let w = FusedLayerWorkload::tiny();
    let mut session =
        ExecutionSession::for_workload(w).backend(SimBackend::ours()).plan_cache(16);
    let load = decode_load();
    session.run(&load).expect("first step");
    session.run(&load).expect("repeat step");
    let stats = session.cache_stats().expect("cache enabled");
    assert_eq!((stats.hits, stats.misses), (1, 1), "identical composite load must hit");

    // FFN-side change alone misses
    let mut ffn_changed = load.clone();
    ffn_changed.expert_counts[0] += 1;
    session.run(&ffn_changed).expect("ffn-changed step");
    let stats = session.cache_stats().expect("cache enabled");
    assert_eq!((stats.hits, stats.misses), (1, 2));

    // same KV span, decode -> prefill: the signature keys the kind too
    let mut kind_changed = load.clone();
    let slot = kind_changed
        .seqs
        .iter()
        .position(|s| matches!(s, SeqSpec::Decode { .. }))
        .expect("decode slot exists");
    let span = kind_changed.seqs[slot].kv_len();
    kind_changed.seqs[slot] = SeqSpec::Prefill { len: span };
    session.run(&kind_changed).expect("kind-changed step");
    let stats = session.cache_stats().expect("cache enabled");
    assert_eq!((stats.hits, stats.misses), (1, 3));

    // and each distinct load now hits on repeat
    session.run(&ffn_changed).expect("ffn-changed repeat");
    session.run(&kind_changed).expect("kind-changed repeat");
    let stats = session.cache_stats().expect("cache enabled");
    assert_eq!((stats.hits, stats.misses), (3, 3));
    assert_eq!(stats.entries, 3);
}

#[test]
fn skewed_prefill_decode_mix_beats_padded_dense() {
    // one long freshly admitted prompt in a batch of short decodes: the
    // dense scheme pads every slot's attention to the prompt's span and
    // every expert to the busiest expert's rows, in two launches
    let w = FusedLayerWorkload::tiny();
    let load = FusedLoad {
        seqs: (0..w.shape.seq)
            .map(|i| match i {
                0 => SeqSpec::Prefill { len: 3000 },
                _ if i % 8 == 7 => SeqSpec::Empty,
                _ => SeqSpec::Decode { kv_len: 8 + i % 24 },
            })
            .collect(),
        expert_counts: (0..w.shape.experts).map(|e| if e == 0 { 40 } else { 2 }).collect(),
    };
    let fused = ExecutionSession::for_workload(w)
        .backend(SimBackend::ours())
        .run(&load)
        .expect("fused sim step");
    let padded = ExecutionSession::for_workload(w)
        .backend(PaddedDenseFused)
        .run(&load)
        .expect("padded-dense step");
    // total time only: the fused plan ships mapping metadata the dense
    // scheme doesn't, so its host time is not the axis it wins on here —
    // the padding occupancy (every slot streamed at the prompt's span) is
    assert!(
        fused.time_s() < padded.time_s(),
        "fused {:.3e}s must beat padded-dense {:.3e}s on skewed prompts",
        fused.time_s(),
        padded.time_s()
    );
}
