//! Cross-layer integration tests: the Rust planner drives the AOT-compiled
//! Pallas kernel through PJRT — via the unified `Backend` surface — and
//! the numbers must match the Rust CPU reference.  This is the deployment
//! path end to end: if the Rust metadata layout disagreed with the Python
//! kernel's expectations in any way (σ order, tile prefix, row padding),
//! these tests would produce garbage numerics, not just a failed assert on
//! metadata.
//!
//! Requires `make artifacts` and `--features pjrt`; tests skip (with a
//! note) if artifacts are absent.

use staticbatch::exec::{ExecutionSession, NumericInputs};
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::kernel_meta::{self, KernelDims};
use staticbatch::moe::ordering::OrderingStrategy;
use staticbatch::moe::routing::ExpertLoad;
use staticbatch::moe::token_index::TokenIndex;
use staticbatch::runtime::artifact::Manifest;
use staticbatch::runtime::client::Runtime;
use staticbatch::runtime::executor::{ExecutorPool, Value};
use staticbatch::runtime::PjrtBackend;
use staticbatch::util::rng::Rng;
use staticbatch::util::tensor::Tensor;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
    }
    ok
}

/// Shared state so the (expensive) PJRT client + compilation happen once.
struct Ctx {
    pool: ExecutorPool,
    dims: KernelDims,
}

fn ctx() -> Ctx {
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let manifest = Manifest::load(artifacts_dir()).expect("manifest");
    let dims = manifest.kernel_dims("moe_gemm").expect("kernel dims");
    let pool = ExecutorPool::new(rt, manifest);
    Ctx { pool, dims }
}

fn shape_of(dims: &KernelDims) -> MoeShape {
    MoeShape {
        seq: dims.seq,
        d_model: dims.d_model,
        d_ff: dims.d_ff,
        experts: dims.experts,
        top_k: dims.top_k,
        dtype_bytes: 4,
    }
}

/// Expected packed output computed in Rust directly from the metadata:
/// row r of the packed buffer = tokens[token_ids[r]] @ W[row_expert[r]].
fn expected_packed(
    dims: &KernelDims,
    meta: &kernel_meta::KernelMeta,
    tokens: &[f32],
    weights: &[f32],
) -> Vec<f32> {
    let (h, d) = (dims.d_model, dims.d_ff);
    let sp = dims.padded_rows();
    let mut out = vec![0f32; sp * d];
    let valid_tiles = meta.num_tiles[0] as usize;
    for r in 0..valid_tiles * dims.tile_m {
        let e = meta.row_expert[r];
        if e < 0 {
            continue;
        }
        let tok = meta.token_ids[r] as usize;
        let x = &tokens[tok * h..(tok + 1) * h];
        let w = &weights[e as usize * h * d..(e as usize + 1) * h * d];
        let dst = &mut out[r * d..(r + 1) * d];
        for (kk, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * d..(kk + 1) * d];
            for j in 0..d {
                dst[j] += xv * wrow[j];
            }
        }
    }
    out
}

fn run_case(ctxx: &mut Ctx, counts: &[usize], ordering: OrderingStrategy, seed: u64) {
    let dims = ctxx.dims;
    assert_eq!(counts.len(), dims.experts);
    let mut rng = Rng::new(seed);
    let tokens: Vec<f32> =
        (0..dims.seq * dims.d_model).map(|_| rng.normal() as f32 * 0.3).collect();
    let weights: Vec<f32> = (0..dims.experts * dims.d_model * dims.d_ff)
        .map(|_| rng.normal() as f32 * 0.05)
        .collect();
    let mut pairs = Vec::new();
    for (e, &c) in counts.iter().enumerate() {
        for _ in 0..c {
            pairs.push((rng.usize_below(dims.seq) as u32, e as u32));
        }
    }
    let ti = TokenIndex::build(dims.experts, &pairs);
    let gates: Vec<Vec<f32>> =
        ti.index.iter().map(|v| v.iter().map(|_| 1.0f32).collect()).collect();
    // twin of the metadata the backend will lower the plan to — used for
    // the host-side verification below
    let meta = kernel_meta::build(&dims, &ti, &gates, ordering);

    let numeric = NumericInputs {
        tokens: Tensor::from_vec(&[dims.seq, dims.d_model], tokens.clone()),
        weights: Tensor::from_vec(&[dims.experts, dims.d_model, dims.d_ff], weights.clone()),
        token_index: ti,
        gates,
    };
    let load = ExpertLoad { counts: counts.to_vec() };

    // the deployment path: session plans, PjrtBackend executes the plan on
    // the AOT kernel
    let mut backend = PjrtBackend::new(&mut ctxx.pool, ordering).expect("compile moe_gemm");
    let mut session = ExecutionSession::new(shape_of(&dims)).ordering(ordering).inputs(numeric);
    let out = session.run_on(&mut backend, &load).expect("execute moe_gemm");

    let sp = dims.padded_rows();
    assert_eq!(out.blocks as usize, meta.num_tiles[0] as usize);
    let packed = out.output.expect("packed rows");
    assert_eq!(packed.shape, vec![sp, dims.d_ff]);
    let got = &packed.data;

    let want = expected_packed(&dims, &meta, &tokens, &weights);
    let mut max_err = 0f32;
    let valid_rows = meta.num_tiles[0] as usize * dims.tile_m;
    for r in 0..valid_rows {
        if meta.row_expert[r] < 0 {
            continue;
        }
        // padding rows inside a group: the kernel computes tokens[0] @ W —
        // only compare rows that carry real tokens (gate > 0 downstream)
        let is_pad = meta.gates_pad[r] == 0.0;
        if is_pad {
            continue;
        }
        for j in 0..dims.d_ff {
            let d = (got[r * dims.d_ff + j] - want[r * dims.d_ff + j]).abs();
            max_err = max_err.max(d);
        }
    }
    assert!(max_err < 2e-3, "max err {max_err} (ordering {ordering:?})");
}

#[test]
fn pjrt_kernel_matches_rust_reference() {
    if !have_artifacts() {
        return;
    }
    let mut c = ctx();
    let dims = c.dims;

    // balanced routing
    let per = dims.seq * dims.top_k / dims.experts;
    let counts = vec![per; dims.experts];
    run_case(&mut c, &counts, OrderingStrategy::Natural, 1);

    // best case: all rows on the first top_k experts (most experts empty)
    let mut best = vec![0usize; dims.experts];
    let total = dims.seq * dims.top_k;
    for i in 0..total {
        best[i % dims.top_k] += 1;
    }
    run_case(&mut c, &best, OrderingStrategy::Natural, 2);

    // worst case: hot experts + 1-token experts, half-interval ordering
    let mut worst = vec![1usize; dims.experts];
    let rest = total - (dims.experts - dims.top_k);
    for (e, w) in worst.iter_mut().enumerate().take(dims.top_k) {
        *w = rest / dims.top_k + usize::from(e < rest % dims.top_k);
    }
    run_case(&mut c, &worst, OrderingStrategy::HalfInterval, 3);

    // random skew + random ordering: metadata contract holds for any order
    let mut rng = Rng::new(9);
    let mut skew = vec![0usize; dims.experts];
    for _ in 0..total {
        skew[(rng.below(dims.experts as u64 / 4) * 3 % dims.experts as u64) as usize] += 1;
    }
    run_case(&mut c, &skew, OrderingStrategy::Random(7), 4);
}

#[test]
fn moe_ffn_artifact_runs_and_routes() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::cpu().expect("client");
    let manifest = Manifest::load(artifacts_dir()).expect("manifest");
    let entry = manifest.entry("moe_ffn_s64").expect("ffn entry").clone();
    let mut pool = ExecutorPool::new(rt, manifest);
    let mut rng = Rng::new(5);
    let mk = |shape: &[usize], scale: f32, rng: &mut Rng| {
        let n: usize = shape.iter().product();
        Value::F32((0..n).map(|_| rng.normal() as f32 * scale).collect(), shape.to_vec())
    };
    let inputs: Vec<Value> = entry
        .inputs
        .iter()
        .map(|spec| mk(&spec.shape, 0.2, &mut rng))
        .collect();
    let outs = pool.run("moe_ffn_s64", &inputs).expect("run ffn");
    // output 0: [64, d_model]; output 1: counts per expert
    let y = outs[0].as_f32().unwrap();
    assert!(y.iter().all(|v| v.is_finite()));
    let counts = outs[1].as_i32().unwrap();
    let total: i32 = counts.iter().sum();
    let meta_cfg = entry.meta.get("config").unwrap();
    let top_k = meta_cfg.get("top_k").unwrap().as_usize().unwrap();
    assert_eq!(total as usize, 64 * top_k, "router must place every slot");
    assert!(counts.iter().all(|&c| c >= 0));
}

#[test]
fn lm_forward_artifact_produces_logits() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::cpu().expect("client");
    let manifest = Manifest::load(artifacts_dir()).expect("manifest");
    let entry = manifest.entry("lm_forward_s16").expect("lm entry").clone();
    let mut pool = ExecutorPool::new(rt, manifest);
    let mut rng = Rng::new(11);
    let mut inputs = Vec::with_capacity(entry.inputs.len());
    // input 0: token ids
    let vocab = entry.meta.get("config").unwrap().get("vocab").unwrap().as_usize().unwrap();
    inputs.push(Value::I32(
        (0..16).map(|_| rng.below(vocab as u64) as i32).collect(),
        vec![16],
    ));
    for spec in &entry.inputs[1..] {
        let n: usize = spec.shape.iter().product();
        let data = if spec.shape.len() == 1 {
            vec![1.0f32; n]
        } else {
            let fan = spec.shape[spec.shape.len() - 2] as f32;
            (0..n).map(|_| rng.normal() as f32 / fan.sqrt()).collect()
        };
        inputs.push(Value::F32(data, spec.shape.clone()));
    }
    let outs = pool.run("lm_forward_s16", &inputs).expect("run lm");
    let logits = outs[0].as_f32().unwrap();
    assert_eq!(logits.len(), 16 * vocab);
    assert!(logits.iter().all(|v| v.is_finite()));
    // determinism: same inputs, same logits
    let outs2 = pool.run("lm_forward_s16", &inputs).expect("rerun");
    assert_eq!(outs[0], outs2[0]);
}
