//! The framework-generality pin: ragged batched attention decode — the
//! second irregular workload — runs through the *identical*
//! TwoStageMap/σ/TilePrefix machinery as MoE.
//!
//! * Dispatch agreement: for random ragged loads, the simulator's decode
//!   of the two-stage mapping and the CPU executor's actual `StaticBatch`
//!   dispatch must produce identical `(task, tile, kind)` sequences — the
//!   same cross-backend property `backend_agreement` pins for MoE, now on
//!   a workload the framework has never special-cased.
//! * Numerics: the chunked flash-decode executed through the framework
//!   dispatch must match the dense softmax reference.
//! * The payoff: static batching beats the padded-dense baseline on
//!   skewed KV lengths.

use staticbatch::exec::{CpuBackend, ExecutionSession, SimBackend};
use staticbatch::moe::ordering::OrderingStrategy;
use staticbatch::util::prop;
use staticbatch::workload::ragged::{
    reference, PaddedDenseAttention, RaggedAttentionWorkload, RaggedInputs, RaggedLoad,
    RaggedScenario,
};

/// Random ragged decode batch + the workload it belongs to.
fn gen_case(g: &mut prop::GenCtx) -> (RaggedAttentionWorkload, RaggedLoad, u64) {
    let workload = RaggedAttentionWorkload {
        heads: 1 + g.rng.usize_below(4),
        head_dim: 4 + g.rng.usize_below(3) * 4,
        dtype_bytes: 4,
    };
    let seqs = 1 + g.rng.usize_below(12);
    // lengths spanning every KV-chunk strategy, with ~1/4 empty caches
    let lens = (0..seqs)
        .map(|_| {
            if g.rng.below(4) == 0 {
                0
            } else {
                1 + g.rng.usize_below(g.size * 60 + 1)
            }
        })
        .collect();
    let seed = g.rng.below(u32::MAX as u64);
    (workload, RaggedLoad { lens }, seed)
}

#[test]
fn sim_and_cpu_backends_dispatch_identical_sequences_for_ragged_loads() {
    prop::check(
        "ragged-sim-cpu-dispatch-agreement",
        50,
        gen_case,
        |&(workload, ref load, seed)| {
            for ordering in [
                OrderingStrategy::Natural,
                OrderingStrategy::HalfInterval,
                OrderingStrategy::SortedDesc,
            ] {
                let sim_trace = ExecutionSession::for_workload(workload)
                    .ordering(ordering)
                    .backend(SimBackend::ours())
                    .record_dispatch()
                    .run(load)
                    .map_err(|e| format!("sim backend: {e}"))?
                    .trace
                    .ok_or("sim backend returned no trace")?;
                let cpu_trace = ExecutionSession::for_workload(workload)
                    .ordering(ordering)
                    .backend(CpuBackend)
                    .inputs(RaggedInputs::synthetic(&workload, load, seed))
                    .record_dispatch()
                    .run(load)
                    .map_err(|e| format!("cpu backend: {e}"))?
                    .trace
                    .ok_or("cpu backend returned no trace")?;
                if sim_trace != cpu_trace {
                    let first = sim_trace
                        .iter()
                        .zip(&cpu_trace)
                        .position(|(a, b)| a != b)
                        .unwrap_or(sim_trace.len().min(cpu_trace.len()));
                    return Err(format!(
                        "dispatch traces diverge under {ordering:?}: lens {}/{}, first diff at block {first}",
                        sim_trace.len(),
                        cpu_trace.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn cpu_ragged_numerics_match_the_dense_softmax_reference() {
    prop::check("ragged-cpu-vs-reference", 30, gen_case, |&(workload, ref load, seed)| {
        let inputs = RaggedInputs::synthetic(&workload, load, seed);
        let want = reference(&workload, load, &inputs);
        let got = ExecutionSession::for_workload(workload)
            .backend(CpuBackend)
            .inputs(inputs)
            .run(load)
            .map_err(|e| format!("cpu backend: {e}"))?
            .output
            .ok_or("cpu backend returned no tensor")?;
        let err = got.max_abs_diff(&want);
        if err < 1e-3 {
            Ok(())
        } else {
            Err(format!("max abs err {err}"))
        }
    });
}

#[test]
fn static_batching_beats_padded_dense_on_skewed_kv_lengths() {
    let workload = RaggedAttentionWorkload { heads: 32, head_dim: 128, dtype_bytes: 2 };
    for seed in 0..3 {
        let load = RaggedScenario::Zipf(1.4, 8192).lens(256, seed);
        let ours = ExecutionSession::for_workload(workload)
            .backend(SimBackend::ours())
            .run(&load)
            .expect("sim runs")
            .time_s();
        let padded = ExecutionSession::for_workload(workload)
            .backend(PaddedDenseAttention)
            .run(&load)
            .expect("padded-dense runs")
            .time_s();
        assert!(
            padded > ours * 1.5,
            "seed {seed}: static batching must clearly beat padded-dense on skew: \
             {ours:.6}s vs {padded:.6}s (pad frac {:.2})",
            load.padding_frac()
        );
    }
}

#[test]
fn ragged_plan_cache_hits_on_repeated_length_signatures() {
    let workload = RaggedAttentionWorkload { heads: 2, head_dim: 8, dtype_bytes: 4 };
    let a = RaggedScenario::Uniform(300).lens(24, 3);
    let b = RaggedScenario::Uniform(300).lens(24, 4); // distinct lengths
    let mut s = ExecutionSession::for_workload(workload).plan_cache(8);
    s.run(&a).expect("run a");
    s.run(&b).expect("run b");
    s.run(&a).expect("run a again");
    let stats = s.cache_stats().expect("cache enabled");
    assert_eq!((stats.hits, stats.misses, stats.entries), (1, 2, 2));
}

#[test]
fn empty_and_mixed_caches_still_cover_every_tile_exactly_once() {
    // the Algorithm-4 pin on the new workload: σ elides empty sequences
    // and the mapping covers each non-empty sequence's tiles exactly once
    let workload = RaggedAttentionWorkload { heads: 3, head_dim: 8, dtype_bytes: 4 };
    let load = RaggedLoad { lens: vec![0, 513, 0, 1, 32, 0, 129] };
    let session = ExecutionSession::for_workload(workload);
    let plan = session.plan(&load);
    assert_eq!(plan.num_nonempty(), 4);
    let descs = plan.descriptors();
    let mut per_task = vec![0u32; descs.len()];
    for b in 0..plan.total_tiles() {
        per_task[plan.two_stage.map(b).task as usize] += 1;
    }
    for (i, d) in descs.iter().enumerate() {
        assert_eq!(per_task[i], d.num_tiles() as u32, "task {i}");
    }
}
