//! Expert-parallel sharded serving under DEFAULT features: no PJRT, no
//! artifacts, no GPU.
//!
//! Pins the two properties the sharded executor promises:
//!
//! 1. **Output equivalence** — with `top_k = 1` every output row has
//!    exactly one expert contribution, so the EP combine has a single term
//!    per row and the sharded executor's numeric outputs are *identical*
//!    to the single-shard executor's, step for step, regardless of the
//!    placement.  (With `top_k > 1` the combine order differs, which only
//!    permits float-reordering noise; the exact check uses `top_k = 1`.)
//! 2. **Placement quality** — on a Zipf-skewed prompt pool, the balanced
//!    (load-aware, GEM-style) placement strictly lowers the mean per-step
//!    device imbalance versus static round-robin on identical traffic.

use staticbatch::coordinator::batcher::BatchPolicy;
use staticbatch::serve::{
    run_traffic, PlacementKind, Server, ServerConfig, ShardedServeConfig, ShardedStepExecutor,
    SimServeConfig, SimStepExecutor, StepExecutor, StepInput, TrafficConfig,
};
use staticbatch::util::rng::{zipf_weights, Rng};

fn base_cfg(numeric: bool, top_k: usize) -> SimServeConfig {
    SimServeConfig {
        buckets: vec![8, 16],
        max_tokens: 256,
        experts: 16,
        top_k,
        d_model: 16,
        d_ff: 24,
        cache_capacity: 32,
        numeric,
        threads: 1,
        seed: 11,
    }
}

/// Zipf-valued token batches: a few token values dominate, so a few
/// experts dominate — the skew the placement policies disagree about.
fn zipf_steps(steps: usize, rows: usize, bucket: usize, alpha: f64, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    let w = zipf_weights(50, alpha);
    (0..steps)
        .map(|_| (0..rows * bucket).map(|_| rng.zipf(&w) as i32 + 1).collect())
        .collect()
}

#[test]
fn sharded_outputs_are_identical_to_single_shard_with_top_k_1() {
    let cfg = base_cfg(true, 1);
    let mut single = SimStepExecutor::new(cfg.clone());
    for placement in [PlacementKind::Static, PlacementKind::Balanced] {
        let mut sharded = ShardedStepExecutor::new(ShardedServeConfig {
            base: cfg.clone(),
            ep: 4,
            placement,
            rebalance_threshold: 1.1,
            ..ShardedServeConfig::default()
        });
        for (i, tokens) in zipf_steps(6, 4, 16, 1.3, 21).iter().enumerate() {
            let step = StepInput { bucket: 16, rows: 4, tokens };
            let a = single.execute_step(&step).expect("single-shard step");
            let b = sharded.execute_step(&step).expect("sharded step");
            assert_eq!(
                a.argmax, b.argmax,
                "step {i} diverged under {} placement",
                placement.name()
            );
            // the global route is shared, so per-expert loads agree too
            assert_eq!(a.expert_rows, b.expert_rows, "step {i} routed differently");
        }
    }
}

#[test]
fn balanced_placement_lowers_step_time_imbalance_on_zipf_traffic() {
    // Serving-scale accounting shape: big enough that a shard's simulated
    // kernel time genuinely tracks its routed rows (at toy widths the
    // 132-SM wave model is latency-flat and every placement looks equal).
    let accounting_base = SimServeConfig {
        buckets: vec![64],
        max_tokens: 2048,
        experts: 16,
        top_k: 2,
        d_model: 1024,
        d_ff: 2048,
        cache_capacity: 32,
        numeric: false,
        threads: 1,
        seed: 11,
    };
    let steps = zipf_steps(24, 8, 64, 1.5, 33);
    let run = |placement: PlacementKind| {
        let mut ex = ShardedStepExecutor::new(ShardedServeConfig {
            base: accounting_base.clone(),
            ep: 4,
            placement,
            rebalance_threshold: 1.1,
            decay: 0.5,
            ..ShardedServeConfig::default()
        });
        for tokens in &steps {
            ex.execute_step(&StepInput { bucket: 64, rows: 8, tokens })
                .expect("sharded step");
        }
        ex.stats().clone()
    };
    let st = run(PlacementKind::Static);
    let bal = run(PlacementKind::Balanced);
    assert_eq!(st.reshards, 0, "static placement never re-shards");
    assert!(bal.reshards >= 1, "balanced placement must have re-sharded");
    assert!(
        st.imbalance_ratio() > 1.1,
        "zipf traffic must skew the static placement: {:.3}",
        st.imbalance_ratio()
    );
    assert!(
        bal.imbalance_ratio() < st.imbalance_ratio(),
        "balanced {:.3} must be strictly below static {:.3}",
        bal.imbalance_ratio(),
        st.imbalance_ratio()
    );
    // collectives are charged either way (ep = 4 pays all-to-all per step)
    assert!(st.collective_s > 0.0 && bal.collective_s > 0.0);
}

#[test]
fn sharded_server_serves_traffic_and_reports_shard_metrics() {
    let cfg = ShardedServeConfig {
        base: SimServeConfig { numeric: false, seed: 5, ..SimServeConfig::default() },
        ep: 2,
        placement: PlacementKind::Balanced,
        rebalance_threshold: 1.1,
        ..ShardedServeConfig::default()
    };
    let max_tokens = cfg.base.max_tokens;
    let mut server = Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 8, max_tokens },
            queue_capacity: 128,
            ..ServerConfig::default()
        },
        ShardedStepExecutor::new(cfg),
    );
    let report = run_traffic(
        &mut server,
        TrafficConfig { requests: 64, rate_hz: 0.0, zipf_alpha: 1.4, ..TrafficConfig::default() },
    );
    assert_eq!(report.sent, 64);
    assert_eq!(report.failed, 0, "every request answered without error");
    assert_eq!(report.rejected, 0);

    // the server mirrored the executor's shard accounting into its metrics
    let sh = report.snapshot.sharding.as_ref().expect("sharding stats mirrored");
    assert_eq!((sh.ep, sh.tp), (2, 1));
    assert_eq!(sh.steps, report.snapshot.batches, "one sharded step per formed batch");
    assert_eq!(sh.utilization().len(), 2);
    assert!(sh.imbalance_ratio() >= 1.0);
    assert!(sh.collective_share() > 0.0);

    // per-shard plan-cache lanes were exercised and surfaced
    assert_eq!(sh.shard_cache.len(), 2);
    let lookups: u64 = sh.shard_cache.iter().map(|c| c.hits + c.misses).sum();
    assert!(lookups > 0, "shard lanes must have planned through their caches");
    let agg = report.cache.expect("aggregate cache stats");
    assert_eq!(agg.hits + agg.misses, lookups);

    // the rendered report carries the per-shard section end to end
    let rendered = report.render();
    assert!(rendered.contains("sharded ep=2 tp=1"), "render:\n{rendered}");
    assert!(rendered.contains("shard util"), "render:\n{rendered}");
}
