//! Fault-tolerance suite (default features: no PJRT, artifacts, or GPU).
//!
//! Serving under injected failure is exactly the kind of behavior that is
//! wrong until proven right, so this suite drives the whole stack —
//! error taxonomy, per-request deadlines, bounded step retry, per-shard
//! circuit breakers, and the seeded chaos injector — end to end:
//!
//! - taxonomy pins: a worker panic stays permanent (never retried) with
//!   its structured [`PoolError`] source intact; timeouts and shard
//!   deaths are transient,
//! - deadline shedding: expired requests are answered (`expired` set),
//!   counted separately from errors, and never executed,
//! - retry: transient step failures are absorbed up to `max_attempts`
//!   with no lost or duplicated requests; permanent failures fail the
//!   batch on the first attempt,
//! - breaker lifecycle: a bounded shard-death window trips the breaker
//!   (quarantine + evacuation), half-open probes re-admit the shard, a
//!   failed probe re-quarantines without a new trip, and a clean probe
//!   closes the breaker — asserted through a live `Server` run,
//! - the FAULT acceptance scenario: the pinned two-tenant scenario under
//!   10% transient chaos plus a persistent shard death must conserve
//!   every request exactly, trip and probe breakers, end fully restored,
//!   and keep goodput at >= 80% of the clean run,
//! - a property test: under random chaos schedules, conservation holds
//!   exactly and every request the chaos run completes is bitwise
//!   identical to the undisturbed run.
//!
//! CI re-runs the acceptance scenario under derived seeds
//! (`CHAOS_SOAK_REPEAT=10`) so retry/breaker interleavings cannot hide
//! behind one lucky schedule.  Every test runs under a watchdog that
//! aborts the process instead of hanging CI.

use std::error::Error;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use staticbatch::coordinator::batcher::BatchPolicy;
use staticbatch::exec::ExecError;
use staticbatch::serve::{
    run_scenario, ChaosConfig, ChaosStepExecutor, PlacementKind, RetryPolicy, ScenarioConfig,
    Server, ServerConfig, ShardDeath, ShardedServeConfig, ShardedStepExecutor, SimServeConfig,
    SimStepExecutor, StepExecutor, StepInput, StepOutput, Ticket,
};
use staticbatch::util::prop::check;
use staticbatch::util::threadpool::PoolError;

/// Aborts the whole process if the test runs past `limit` — a wedged
/// retry loop must fail CI loudly, not hang it.  Disarmed on drop
/// (including ordinary test panics).
struct Watchdog {
    done: Arc<AtomicBool>,
}

impl Watchdog {
    fn arm(limit: Duration) -> Watchdog {
        let done = Arc::new(AtomicBool::new(false));
        let seen = Arc::clone(&done);
        std::thread::spawn(move || {
            let t0 = Instant::now();
            while t0.elapsed() < limit {
                if seen.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
            eprintln!("watchdog: test exceeded {limit:?} — aborting (likely retry/breaker hang)");
            std::process::abort();
        });
        Watchdog { done }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.done.store(true, Ordering::Relaxed);
    }
}

/// Minimal executor: echoes every token incremented, optionally failing
/// its first `fail_first` calls transiently or every call permanently,
/// and counts calls and executed rows so tests can prove what ran.
struct Echo {
    calls: u32,
    rows_executed: usize,
    fail_first: u32,
    permanent: bool,
}

impl Echo {
    fn ok() -> Echo {
        Echo { calls: 0, rows_executed: 0, fail_first: 0, permanent: false }
    }

    fn flaky(fail_first: u32) -> Echo {
        Echo { fail_first, ..Echo::ok() }
    }

    fn panicking() -> Echo {
        Echo { permanent: true, ..Echo::ok() }
    }
}

impl StepExecutor for Echo {
    fn name(&self) -> &'static str {
        "echo"
    }

    fn buckets(&self) -> Vec<usize> {
        vec![4, 8]
    }

    fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
        self.calls += 1;
        if self.permanent {
            return Err(ExecError::backend_caused(
                "echo",
                "worker pool failure",
                PoolError::WorkerPanicked,
            ));
        }
        if self.calls <= self.fail_first {
            return Err(ExecError::Timeout { backend: "echo", detail: "injected stall".into() });
        }
        self.rows_executed += step.rows;
        Ok(StepOutput {
            argmax: step.tokens.iter().map(|&t| t + 1).collect(),
            expert_rows: Vec::new(),
            failed: Vec::new(),
            sim_time_s: None,
        })
    }
}

fn echo_server(echo: Echo, retry: RetryPolicy) -> Server<Echo> {
    Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 64, max_tokens: 2048 },
            pipeline: false,
            retry,
            ..ServerConfig::default()
        },
        echo,
    )
}

// ---------------------------------------------------------------- taxonomy

/// The injector's worker panic must stay permanent end to end: classified
/// non-transient (never retried) with the structured [`PoolError`] cause
/// reachable through `source()` — not flattened into a string.  Timeouts
/// and shard deaths stay transient and shard-attributable.
#[test]
fn injected_worker_panic_is_permanent_and_structured() {
    let _wd = Watchdog::arm(Duration::from_secs(60));
    let mut chaos = ChaosStepExecutor::new(
        Echo::ok(),
        ChaosConfig { panic_calls: vec![0], ..ChaosConfig::default() },
    );
    let step = StepInput { bucket: 4, rows: 1, tokens: &[1, 2, 3, 0] };
    let err = chaos.execute_step(&step).expect_err("call 0 panics");
    assert!(!err.is_transient(), "a worker panic is permanent: never retry it");
    assert!(err.shard().is_none(), "a panic is not attributable to a shard");
    let src = err.source().expect("structured cause preserved through injection");
    assert_eq!(
        *src.downcast_ref::<PoolError>().expect("source downcasts to PoolError"),
        PoolError::WorkerPanicked
    );
    assert_eq!(chaos.stats().panics_injected, 1);
    // the injected transient taxonomy: timeouts retryable, unattributed
    let timeout = ExecError::Timeout { backend: "chaos", detail: "stall".into() };
    assert!(timeout.is_transient() && timeout.shard().is_none());
    // shard deaths retryable AND shard-attributed (they feed breakers)
    let down = ExecError::ShardDown { backend: "chaos", shard: 2, detail: "dead".into() };
    assert!(down.is_transient());
    assert_eq!(down.shard(), Some(2));
}

// ---------------------------------------------------------------- deadlines

/// An already-expired request is shed before execution — answered with
/// `expired` set, counted as `expired` (not `errors`), and never run —
/// while a live request in the same accumulation proceeds normally.
/// `wait_timeout` probes without consuming: a timed-out wait still leaves
/// the ticket completable.
#[test]
fn expired_requests_are_shed_before_execution() {
    let _wd = Watchdog::arm(Duration::from_secs(60));
    let mut server = echo_server(Echo::ok(), RetryPolicy::default());
    let handle = server.handle();

    let dead = handle
        .submit_with_deadline(&[1, 2, 3], Duration::ZERO)
        .expect("queue open");
    let live = handle.submit(&[5, 6]).expect("queue open");

    // the server is not running yet: a bounded wait times out cleanly...
    assert!(live.wait_timeout(Duration::from_millis(20)).is_none());

    handle.close();
    server.serve();

    // ...and the same ticket still completes afterwards (no double-take)
    let resp = live.wait_timeout(Duration::from_secs(5)).expect("live request answered");
    assert!(resp.error.is_none() && !resp.expired);
    assert_eq!(resp.argmax, vec![6, 7], "echo executed the live request");

    let dead = dead.wait();
    assert!(dead.expired, "expired request answered with the expired flag");
    assert!(dead.error.is_some(), "an expired response still carries its reason");

    let snap = server.metrics().snapshot();
    assert_eq!(snap.requests, 1);
    assert_eq!(snap.expired, 1, "deadline shed counted as expired");
    assert_eq!(snap.errors, 0, "expiry is not an error");
    assert_eq!(server.executor().rows_executed, 1, "the dead request never executed");
}

// ------------------------------------------------------------------- retry

/// Transient step failures are absorbed by the retry policy: every
/// request completes, retries are counted, and nothing is duplicated.
#[test]
fn transient_step_failures_retry_to_success() {
    let _wd = Watchdog::arm(Duration::from_secs(60));
    let retry = RetryPolicy { max_attempts: 4, backoff: Duration::ZERO };
    let mut server = echo_server(Echo::flaky(2), retry);
    let handle = server.handle();
    let tickets: Vec<Ticket> =
        (0..3).map(|i| handle.submit(&[i, i + 1]).expect("queue open")).collect();
    handle.close();
    server.serve();

    for (i, t) in tickets.into_iter().enumerate() {
        let resp = t.wait();
        assert!(resp.error.is_none(), "request {i} succeeded after retries");
        let i = i as i32;
        assert_eq!(resp.argmax, vec![i + 1, i + 2]);
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.requests, 3);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.retries, 2, "both injected failures retried, none wasted");
    assert_eq!(server.executor().calls, 3, "2 failed attempts + 1 success");
}

/// A permanent failure (worker panic) fails the batch on the very first
/// attempt — a generous retry budget must not spend a single extra call.
#[test]
fn permanent_failures_are_never_retried() {
    let _wd = Watchdog::arm(Duration::from_secs(60));
    let retry = RetryPolicy { max_attempts: 5, backoff: Duration::from_millis(50) };
    let mut server = echo_server(Echo::panicking(), retry);
    let handle = server.handle();
    let a = handle.submit(&[1]).expect("queue open");
    let b = handle.submit(&[2]).expect("queue open");
    handle.close();
    server.serve();

    for t in [a, b] {
        let resp = t.wait();
        let err = resp.error.expect("permanent failure answered as an error");
        assert!(err.contains("worker pool failure"));
        assert!(!resp.expired);
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.errors, 2);
    assert_eq!(snap.retries, 0, "permanent failures are never retried");
    assert_eq!(server.executor().calls, 1, "one batch, one attempt, no backoff spent");
}

// ---------------------------------------------------------- breaker lifecycle

/// The full circuit-breaker lifecycle through a live server: a bounded
/// shard-death window trips shard 1's breaker (consecutive shard-attributed
/// failures → quarantine + evacuation, after which the injector goes
/// silent because the shard is out of placement), a half-open probe
/// restores it *inside* the window and fails (re-quarantine, not a new
/// trip), and a probe after the window closes the breaker — with every
/// request served and zero errors surfacing to callers.
#[test]
fn breaker_trips_probes_and_recovers_through_the_server() {
    let _wd = Watchdog::arm(Duration::from_secs(120));
    let sharded = ShardedStepExecutor::new(ShardedServeConfig {
        base: SimServeConfig { numeric: false, seed: 7, ..SimServeConfig::default() },
        ep: 4,
        placement: PlacementKind::Balanced,
        breaker_threshold: 3,
        breaker_probe_after: 2,
        ..ShardedServeConfig::default()
    });
    let chaos = ChaosStepExecutor::new(
        sharded,
        ChaosConfig {
            shard_deaths: vec![ShardDeath { shard: 1, from_call: 0, until_call: 8 }],
            ..ChaosConfig::default()
        },
    );
    let mut server = Server::new(
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 2, max_tokens: 2048 },
            pipeline: false,
            retry: RetryPolicy { max_attempts: 5, backoff: Duration::ZERO },
            ..ServerConfig::default()
        },
        chaos,
    );
    let handle = server.handle();
    let tickets: Vec<Ticket> =
        (0..40).map(|i| handle.submit(&[i, i + 1, i + 2, i + 3]).expect("queue open")).collect();
    handle.close();
    server.serve();

    let sent = tickets.len();
    let ok = tickets.into_iter().filter(|t| t.try_wait().expect("drained").error.is_none()).count();
    assert_eq!(ok, sent, "retries + breaker absorbed the whole death window");

    let stats = server.executor().inner().stats();
    assert_eq!(stats.breaker_trips, 1, "one quarantine; a failed probe is not a new trip");
    assert!(stats.breaker_probes >= 2, "an in-window probe failed, a later one succeeded");
    assert!(stats.degraded_steps >= 1, "steps ran with the shard quarantined");
    assert!(
        server.executor().inner().breaker_engaged().iter().all(|&b| !b),
        "breaker closed once the death window passed"
    );
    assert!(
        server.executor().inner().live().iter().all(|&l| l),
        "the probed shard is live and back in placement"
    );
    let snap = server.metrics().snapshot();
    assert_eq!(snap.errors, 0);
    assert!(snap.retries >= 3, "the trip itself consumed shard-down retries");
    assert!(server.executor().stats().shard_down_injected >= 3);
}

// ---------------------------------------------------------- FAULT acceptance

fn sharded(seed: u64) -> ShardedStepExecutor {
    ShardedStepExecutor::new(ShardedServeConfig {
        base: SimServeConfig { numeric: false, seed, ..SimServeConfig::default() },
        ep: 4,
        placement: PlacementKind::Balanced,
        ..ShardedServeConfig::default()
    })
}

/// One acceptance round: the pinned two-tenant scenario clean, then again
/// under 10% transient chaos plus a shard-death window, with a 4-attempt
/// retry policy.  Conservation must hold exactly in both runs; `strict`
/// additionally gates the breaker lifecycle and the goodput floor (only
/// meaningful on the pinned seed the thresholds were chosen for).
fn chaos_acceptance(seed: u64, strict: bool) {
    let clean_cfg = ScenarioConfig { seed, ..ScenarioConfig::default() };
    let mut ex = sharded(seed);
    let r = run_scenario(&mut ex, &clean_cfg);
    assert_eq!(r.sent, r.ok + r.failed + r.shed + r.expired, "clean conservation");

    let chaos_cfg = ScenarioConfig {
        seed,
        retry: RetryPolicy { max_attempts: 4, backoff: Duration::ZERO },
        ..ScenarioConfig::default()
    };
    let mut cex = ChaosStepExecutor::new(
        sharded(seed),
        ChaosConfig {
            seed: seed ^ 0xC4A0,
            transient_rate: 0.1,
            shard_deaths: vec![ShardDeath { shard: 2, from_call: 40, until_call: 160 }],
            ..ChaosConfig::default()
        },
    );
    let rc = run_scenario(&mut cex, &chaos_cfg);

    // zero lost requests: every arrival accounted for exactly once, in
    // both the top-line and the per-tenant view
    assert_eq!(rc.sent, rc.ok + rc.failed + rc.shed + rc.expired, "chaos conservation");
    for t in &rc.tenants {
        assert_eq!(t.sent, t.ok + t.failed + t.shed + t.expired, "tenant {} conservation", t.name);
    }
    assert!(rc.ok > 0, "chaos must not starve the scenario");
    assert!(cex.stats().transient_injected > 0, "the injector actually fired");

    if strict {
        assert!(rc.retries >= 3, "the shard-death window alone costs >= 3 retried attempts");
        assert!(rc.breaker_trips >= 1, "consecutive shard-down failures tripped a breaker");
        assert!(rc.breaker_probes >= 1, "a half-open probe was issued");
        assert!(rc.degraded_steps >= 1, "steps ran with the shard quarantined");
        // the window is bounded: by the end of the run a probe has passed,
        // the breaker is closed, and the shard is back in placement
        assert!(
            cex.inner().breaker_engaged().iter().all(|&b| !b),
            "breaker closed after the death window: probe restore succeeded"
        );
        assert!(cex.inner().live().iter().all(|&l| l), "every shard live at the end");
        // the FAULT headline: chaos goodput >= 80% of the clean run
        let clean = r.ok as f64 / r.virtual_s.max(1e-12);
        let chaos = rc.ok as f64 / rc.virtual_s.max(1e-12);
        assert!(
            chaos >= 0.8 * clean,
            "chaos goodput {chaos:.1} req/s fell below 80% of clean {clean:.1} req/s"
        );
    }
}

/// The FAULT acceptance gate on the pinned seed (the same configuration
/// `benches/scenario.rs` distills into BENCH_serving.json).
#[test]
fn seeded_chaos_scenario_meets_acceptance() {
    let _wd = Watchdog::arm(Duration::from_secs(120));
    chaos_acceptance(1, true);
}

/// CI soak (`CHAOS_SOAK_REPEAT=10`): the acceptance scenario re-runs
/// under derived seeds — different chaos schedules, same conservation
/// guarantee.  Goodput/breaker thresholds are pinned-seed properties, so
/// derived rounds check the invariants that must hold for *every* seed.
#[test]
fn chaos_soak_conserves_requests_under_derived_seeds() {
    let _wd = Watchdog::arm(Duration::from_secs(300));
    let repeat: usize = std::env::var("CHAOS_SOAK_REPEAT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    for round in 0..repeat {
        chaos_acceptance(0xFA17 + round as u64 * 7, false);
    }
}

// ------------------------------------------------------------- determinism

/// Run `prompts` through a sync-loop server over `ex` and collect every
/// response in submission order.
fn run_with<E: StepExecutor>(
    ex: E,
    retry: RetryPolicy,
    prompts: &[Vec<i32>],
) -> Vec<(u64, Vec<i32>, Option<String>)> {
    let mut server = Server::new(
        ServerConfig {
            queue_capacity: prompts.len().max(1),
            pipeline: false,
            retry,
            ..ServerConfig::default()
        },
        ex,
    );
    let handle = server.handle();
    let tickets: Vec<Ticket> =
        prompts.iter().map(|p| handle.submit(p).expect("queue open")).collect();
    handle.close();
    server.serve();
    tickets
        .into_iter()
        .map(|t| {
            let r = t.try_wait().expect("serve returned: every ticket resolved");
            (r.id, r.argmax, r.error)
        })
        .collect()
}

/// Property: under a random chaos schedule (random seed, burst length,
/// and transient rate) with a retry budget, the chaos run conserves every
/// request exactly, and every request it completes is bitwise identical
/// to the undisturbed run — a retried batch re-executes to the same
/// output, never a subtly different one.
#[test]
fn chaos_with_retry_is_bitwise_identical_to_the_undisturbed_run() {
    let _wd = Watchdog::arm(Duration::from_secs(300));
    let sim = || {
        SimStepExecutor::new(SimServeConfig {
            numeric: false,
            seed: 11,
            ..SimServeConfig::default()
        })
    };
    check(
        "chaos-retry-bitwise-identical",
        16,
        |g| {
            let n = 1 + g.rng.usize_below(4 + 2 * g.size);
            let prompts: Vec<Vec<i32>> = (0..n)
                .map(|_| {
                    let len = 1 + g.rng.usize_below(200);
                    (0..len).map(|_| g.rng.range(0, 1000) as i32).collect()
                })
                .collect();
            let chaos = ChaosConfig {
                seed: g.rng.next_u64(),
                transient_rate: 0.4 * g.rng.f64(),
                burst_len: 1 + g.rng.below(3) as u32,
                ..ChaosConfig::default()
            };
            (prompts, chaos)
        },
        |(prompts, chaos)| {
            let base = run_with(sim(), RetryPolicy::default(), prompts);
            let retry = RetryPolicy { max_attempts: 8, backoff: Duration::ZERO };
            let hit = run_with(ChaosStepExecutor::new(sim(), chaos.clone()), retry, prompts);
            if base.len() != hit.len() {
                return Err(format!("{} base vs {} chaos responses", base.len(), hit.len()));
            }
            for ((bid, bargmax, berr), (cid, cargmax, cerr)) in base.iter().zip(hit.iter()) {
                if bid != cid {
                    return Err(format!("response order diverged: {bid} vs {cid}"));
                }
                if berr.is_some() {
                    return Err(format!("undisturbed run failed request {bid}: {berr:?}"));
                }
                // a chaos failure (retry budget exhausted) is allowed —
                // but a completed request must match bit for bit
                if cerr.is_none() && bargmax != cargmax {
                    return Err(format!("request {bid}: chaos argmax diverged after retries"));
                }
            }
            Ok(())
        },
    );
}
