//! Compiled-executable cache + typed execution helpers.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::artifact::{DType, Entry, Manifest, TensorSpec};
use crate::runtime::client::Runtime;

/// A host-side tensor value fed to / read from an executable.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(_, s) | Value::I32(_, s) => s,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(v, _) => Ok(v),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(v, _) => Ok(v),
            _ => bail!("expected i32 value"),
        }
    }

    fn matches(&self, spec: &TensorSpec) -> bool {
        let dtype_ok = matches!(
            (self, spec.dtype),
            (Value::F32(..), DType::F32) | (Value::I32(..), DType::I32)
        );
        dtype_ok && self.shape() == spec.shape.as_slice()
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(v, _) => xla::Literal::vec1(v),
            Value::I32(v, _) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Value::F32(lit.to_vec::<f32>()?, dims)),
            xla::ElementType::S32 => Ok(Value::I32(lit.to_vec::<i32>()?, dims)),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

/// Stats for one executable.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_exec_s: f64,
    pub compile_s: f64,
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    entry: Entry,
    stats: ExecStats,
}

/// Lazily compiles manifest entries and executes them with shape/dtype
/// checking against the manifest contract.
pub struct ExecutorPool {
    rt: Runtime,
    manifest: Manifest,
    compiled: BTreeMap<String, Compiled>,
}

impl ExecutorPool {
    pub fn new(rt: Runtime, manifest: Manifest) -> Self {
        ExecutorPool { rt, manifest, compiled: BTreeMap::new() }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch the cached) entry.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.entry(name)?.clone();
        let t0 = Instant::now();
        let exe = self.rt.compile_file(&entry.file)?;
        let compile_s = t0.elapsed().as_secs_f64();
        log::info!("compiled {name} in {compile_s:.2}s");
        self.compiled.insert(
            name.to_string(),
            Compiled { exe, entry, stats: ExecStats { compile_s, ..Default::default() } },
        );
        Ok(())
    }

    /// Execute an entry with typed inputs; returns outputs in manifest order.
    pub fn run(&mut self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.prepare(name)?;
        let c = self.compiled.get_mut(name).unwrap();
        // validate against the manifest contract
        if inputs.len() != c.entry.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                c.entry.inputs.len(),
                inputs.len()
            );
        }
        for (i, (v, spec)) in inputs.iter().zip(&c.entry.inputs).enumerate() {
            if !v.matches(spec) {
                bail!(
                    "{name}: input {i} mismatch: got {:?} want {:?} {:?}",
                    v.shape(),
                    spec.dtype,
                    spec.shape
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = c.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        c.stats.calls += 1;
        c.stats.total_exec_s += t0.elapsed().as_secs_f64();
        // aot.py lowers with return_tuple=True: output is always a tuple
        let parts = result.to_tuple()?;
        let outs: Vec<Value> = parts.iter().map(Value::from_literal).collect::<Result<_>>()?;
        if outs.len() != c.entry.outputs.len() {
            bail!("{name}: {} outputs, manifest says {}", outs.len(), c.entry.outputs.len());
        }
        Ok(outs)
    }

    /// Upload a host value to a device-resident buffer once.  The serving
    /// hot path keeps model parameters resident and per-request uploads
    /// only the small activations (§Perf optimization: avoids re-staging
    /// ~76 MB of weights per call).
    pub fn upload(&self, v: &Value) -> Result<xla::PjRtBuffer> {
        let buf = match v {
            Value::F32(data, shape) => {
                self.rt.client().buffer_from_host_buffer(data, shape, None)?
            }
            Value::I32(data, shape) => {
                self.rt.client().buffer_from_host_buffer(data, shape, None)?
            }
        };
        Ok(buf)
    }

    /// Execute with pre-uploaded device buffers (no per-call host staging).
    /// Input count is checked; shapes were fixed at upload time.
    pub fn run_buffers(&mut self, name: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<Value>> {
        self.prepare(name)?;
        let c = self.compiled.get_mut(name).unwrap();
        if args.len() != c.entry.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", c.entry.inputs.len(), args.len());
        }
        let t0 = Instant::now();
        let result = c.exe.execute_b(args)?[0][0].to_literal_sync()?;
        c.stats.calls += 1;
        c.stats.total_exec_s += t0.elapsed().as_secs_f64();
        let parts = result.to_tuple()?;
        let outs: Vec<Value> = parts.iter().map(Value::from_literal).collect::<Result<_>>()?;
        if outs.len() != c.entry.outputs.len() {
            bail!("{name}: {} outputs, manifest says {}", outs.len(), c.entry.outputs.len());
        }
        Ok(outs)
    }

    pub fn stats(&self, name: &str) -> Option<ExecStats> {
        self.compiled.get(name).map(|c| c.stats)
    }

    pub fn loaded_entries(&self) -> Vec<&str> {
        self.compiled.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shape_checks() {
        let v = Value::F32(vec![0.0; 6], vec![2, 3]);
        assert!(v.matches(&TensorSpec { shape: vec![2, 3], dtype: DType::F32 }));
        assert!(!v.matches(&TensorSpec { shape: vec![3, 2], dtype: DType::F32 }));
        assert!(!v.matches(&TensorSpec { shape: vec![2, 3], dtype: DType::I32 }));
    }

    #[test]
    fn value_accessors() {
        let v = Value::I32(vec![1, 2], vec![2]);
        assert!(v.as_i32().is_ok());
        assert!(v.as_f32().is_err());
        assert_eq!(v.numel(), 2);
    }
}
