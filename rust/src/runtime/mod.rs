//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! PJRT client.  Python never runs here — artifacts are produced once by
//! `make artifacts` and this module is the only consumer.

pub mod artifact;
pub mod backend;
pub mod client;
pub mod executor;

pub use backend::PjrtBackend;
