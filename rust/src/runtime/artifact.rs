//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  Parsed with the in-crate JSON parser.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element type of a tensor in the manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    Bf16,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            "bfloat16" => Ok(DType::Bf16),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// Shape + dtype of one input/output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            j.get("dtype").and_then(|d| d.as_str()).ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT entry point.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, Entry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let mut entries = BTreeMap::new();
        let ents = json
            .get("entries")
            .and_then(|e| e.as_obj())
            .ok_or_else(|| anyhow!("manifest missing entries"))?;
        for (name, ent) in ents {
            let file = dir.join(
                ent.get("file").and_then(|f| f.as_str()).ok_or_else(|| anyhow!("missing file"))?,
            );
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                ent.get(key)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("{name}: missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            entries.insert(
                name.clone(),
                Entry {
                    name: name.clone(),
                    file,
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    meta: ent.get("meta").cloned().unwrap_or(Json::Null),
                },
            );
        }
        Ok(Manifest { dir, entries })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("no entry '{name}' in manifest (have: {:?})",
                self.entries.keys().collect::<Vec<_>>()))
    }

    /// Kernel dims recorded in the moe_gemm entry's meta.
    pub fn kernel_dims(&self, name: &str) -> Result<crate::moe::kernel_meta::KernelDims> {
        let meta = &self.entry(name)?.meta;
        let dims = meta.get("dims").ok_or_else(|| anyhow!("{name}: meta.dims missing"))?;
        let get = |k: &str| -> Result<usize> {
            dims.get(k).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("meta.dims.{k}"))
        };
        Ok(crate::moe::kernel_meta::KernelDims {
            seq: get("seq")?,
            d_model: get("d_model")?,
            d_ff: get("d_ff")?,
            experts: get("experts")?,
            top_k: get("top_k")?,
            tile_m: get("tile_m")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("float64").is_err());
    }

    #[test]
    fn manifest_loads_if_built() {
        let dir = art_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let e = m.entry("moe_gemm").unwrap();
        assert_eq!(e.inputs.len(), 6);
        assert!(e.file.exists());
        let dims = m.kernel_dims("moe_gemm").unwrap();
        assert_eq!(dims.experts, 64);
        // SP input matches the dims formula
        assert_eq!(e.inputs[4].shape[0], dims.padded_rows());
    }

    #[test]
    fn missing_entry_is_error() {
        let dir = art_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entry("nope").is_err());
    }
}
