//! PJRT CPU client wrapper.

use anyhow::Result;

/// Owns the PJRT client; create once per process (client startup is
/// expensive and the underlying runtime registers global state).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact into a loaded executable.
    pub fn compile_file(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        assert!(rt.client().device_count() >= 1);
    }
}
