//! [`PjrtBackend`]: the deployment path behind the unified [`Backend`]
//! trait — executes a static batch plan on the AOT-compiled Pallas
//! `moe_gemm` artifact through PJRT.
//!
//! The backend lowers the plan's routing (via the token index in
//! [`crate::exec::NumericInputs`]) to the four metadata tensors the kernel
//! consumes (`tile_prefix`, `sigma`, `token_ids`, `num_tiles`) and runs the
//! compiled executable.  With [`PjrtBackend::warm`], tokens and weights
//! stay device-resident and the hot path uploads only the per-step
//! metadata — the §Perf deployment pattern, now reachable through the same
//! `Backend::execute` call every other executor uses.
//!
//! On the serving path this backend is reached through
//! `coordinator::engine::Engine::moe_backend`; the engine itself is a
//! [`crate::serve::StepExecutor`] instantiation of the backend-generic
//! serving core, so the queue → batcher → plan → execute loop around it is
//! the same one the default-features sim executor runs.  Plans fed here
//! may come from an [`crate::exec::ExecutionSession`] plan cache — the
//! execute path treats the plan as read-only, so cached (`Arc`-shared)
//! plans are safe.

use anyhow::Result;

use crate::exec::{Backend, ExecContext, ExecError, NumericInputs, Outcome};
use crate::moe::kernel_meta::{self, KernelDims};
use crate::moe::ordering::OrderingStrategy;
use crate::moe::planner::ExecutionPlan;
use crate::runtime::executor::{ExecutorPool, Value};
use crate::util::tensor::Tensor;

const ENTRY: &str = "moe_gemm";
const NAME: &str = "pjrt/moe_gemm";

/// Device-resident operands uploaded once by [`PjrtBackend::warm`], plus
/// the identity (allocation pointer + length) of the host tensors they
/// were staged from, so the hot path can refuse to pair stale resident
/// buffers with different inputs.
struct Resident {
    tokens: xla::PjRtBuffer,
    weights: xla::PjRtBuffer,
    tokens_id: (*const f32, usize),
    weights_id: (*const f32, usize),
}

fn tensor_id(t: &Tensor) -> (*const f32, usize) {
    (t.data.as_ptr(), t.data.len())
}

/// The AOT Pallas kernel as a [`Backend`].  Borrows the caller's
/// [`ExecutorPool`], so it composes with the serving engine (which owns a
/// pool of its own) and with standalone benches.
pub struct PjrtBackend<'p> {
    pool: &'p mut ExecutorPool,
    dims: KernelDims,
    ordering: OrderingStrategy,
    resident: Option<Resident>,
}

impl<'p> PjrtBackend<'p> {
    /// Compile the `moe_gemm` entry (cached in the pool) and wrap it.
    /// `ordering` must match the session's: the kernel metadata re-derives
    /// σ from the token index with this strategy.
    pub fn new(pool: &'p mut ExecutorPool, ordering: OrderingStrategy) -> Result<Self> {
        let dims = pool.manifest().kernel_dims(ENTRY)?;
        pool.prepare(ENTRY)?;
        Ok(PjrtBackend { pool, dims, ordering, resident: None })
    }

    pub fn dims(&self) -> KernelDims {
        self.dims
    }

    /// Upload tokens and weights to device buffers once; subsequent
    /// `execute` calls upload only the per-step metadata (§Perf).  The
    /// hot path checks (by allocation identity) that later calls still
    /// carry the same tensors — pass the new inputs here again to re-warm.
    pub fn warm(&mut self, numeric: &NumericInputs) -> Result<()> {
        let d = self.dims;
        anyhow::ensure!(
            numeric.tokens.data.len() == d.seq * d.d_model,
            "tokens tensor has {} elements, kernel dims need {}",
            numeric.tokens.data.len(),
            d.seq * d.d_model
        );
        anyhow::ensure!(
            numeric.weights.data.len() == d.experts * d.d_model * d.d_ff,
            "weights tensor has {} elements, kernel dims need {}",
            numeric.weights.data.len(),
            d.experts * d.d_model * d.d_ff
        );
        let tokens = self.pool.upload(&Value::F32(
            numeric.tokens.data.clone(),
            vec![d.seq, d.d_model],
        ))?;
        let weights = self.pool.upload(&Value::F32(
            numeric.weights.data.clone(),
            vec![d.experts, d.d_model, d.d_ff],
        ))?;
        self.resident = Some(Resident {
            tokens,
            weights,
            tokens_id: tensor_id(&numeric.tokens),
            weights_id: tensor_id(&numeric.weights),
        });
        Ok(())
    }

    fn check_plan(&self, plan: &ExecutionPlan) -> Result<(), ExecError> {
        let d = self.dims;
        let s = plan.shape();
        if s.seq != d.seq || s.d_model != d.d_model || s.d_ff != d.d_ff || s.experts != d.experts
        {
            return Err(ExecError::PlanMismatch {
                backend: NAME,
                detail: format!(
                    "plan shape {}x{}x{} ({} experts) vs compiled dims {}x{}x{} ({} experts)",
                    s.seq, s.d_model, s.d_ff, s.experts, d.seq, d.d_model, d.d_ff, d.experts
                ),
            });
        }
        Ok(())
    }

    fn exec_err(e: anyhow::Error) -> ExecError {
        ExecError::backend(NAME, e.to_string())
    }
}

impl Backend for PjrtBackend<'_> {
    fn name(&self) -> &'static str {
        NAME
    }

    fn execute(
        &mut self,
        plan: &ExecutionPlan,
        ctx: &mut ExecContext<'_>,
    ) -> Result<Outcome, ExecError> {
        self.check_plan(plan)?;
        let numeric = ctx.numeric.ok_or(ExecError::MissingInputs {
            backend: NAME,
            what: "numeric inputs (token index + gates + tensors)",
        })?;
        let d = self.dims;
        let meta = kernel_meta::build(&d, &numeric.token_index, &numeric.gates, self.ordering);
        let sp = d.padded_rows();

        // the metadata is re-derived from the token index, so enforce that
        // it describes the *same schedule* as the plan we were handed: same
        // non-empty experts in the same grid order, same row counts.  A
        // session/backend ordering mismatch is an error, not a silent
        // different schedule.
        let nonempty = plan.num_nonempty();
        for (i, task) in plan.tasks[..nonempty].iter().enumerate() {
            if meta.sigma[i] != task.expert as i32 {
                return Err(ExecError::PlanMismatch {
                    backend: NAME,
                    detail: format!(
                        "grid slot {i}: plan schedules expert {} but the backend's \
                         {:?}-ordered metadata schedules expert {} — construct \
                         PjrtBackend with the session's ordering",
                        task.expert, self.ordering, meta.sigma[i]
                    ),
                });
            }
            let rows = numeric.token_index.index[task.expert as usize].len();
            if rows != task.rows {
                return Err(ExecError::PlanMismatch {
                    backend: NAME,
                    detail: format!(
                        "expert {}: plan has {} rows but the token index has {rows} — \
                         plan and numeric inputs come from different routings",
                        task.expert, task.rows
                    ),
                });
            }
        }

        let m1 = Value::I32(meta.tile_prefix.clone(), vec![d.experts]);
        let m2 = Value::I32(meta.sigma.clone(), vec![d.experts]);
        let m3 = Value::I32(meta.token_ids.clone(), vec![sp]);
        let m4 = Value::I32(meta.num_tiles.to_vec(), vec![1]);

        let outs = match &self.resident {
            // hot path: operands device-resident, metadata-only upload.
            // Refuse to run if the caller's tensors are not the ones the
            // resident buffers were staged from (stale-warm guard).
            Some(r)
                if r.tokens_id != tensor_id(&numeric.tokens)
                    || r.weights_id != tensor_id(&numeric.weights) =>
            {
                return Err(ExecError::backend(
                    NAME,
                    "resident operands were warmed from different tensors than the \
                     current inputs — call warm() again with these inputs",
                ));
            }
            Some(r) => {
                let bufs: Result<Vec<xla::PjRtBuffer>> =
                    [&m1, &m2, &m3, &m4].iter().map(|v| self.pool.upload(v)).collect();
                let bufs = bufs.map_err(Self::exec_err)?;
                let mut args: Vec<&xla::PjRtBuffer> = vec![&r.tokens, &r.weights];
                args.extend(bufs.iter());
                self.pool.run_buffers(ENTRY, &args).map_err(Self::exec_err)?
            }
            // cold path: stage everything per call
            None => {
                let inputs = vec![
                    Value::F32(numeric.tokens.data.clone(), vec![d.seq, d.d_model]),
                    Value::F32(numeric.weights.data.clone(), vec![d.experts, d.d_model, d.d_ff]),
                    m1,
                    m2,
                    m3,
                    m4,
                ];
                self.pool.run(ENTRY, &inputs).map_err(Self::exec_err)?
            }
        };
        let packed = outs[0].as_f32().map_err(Self::exec_err)?;
        Ok(Outcome {
            backend: NAME,
            blocks: meta.num_tiles[0] as u32,
            sim: None,
            output: Some(Tensor::from_vec(&[sp, d.d_ff], packed.to_vec())),
            trace: None,
        })
    }
}
