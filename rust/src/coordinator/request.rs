//! Request/response types on the serving path.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// An inference request: score a token sequence with the LM and return the
/// next-token argmax for each position (enough to drive generation loops).
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// Tenant class id; `0` is the untenanted single-class default, so
    /// pre-scenario traffic keeps working unchanged.  Scenario traffic
    /// (`serve::scenario`) assigns class ids and the metrics layer breaks
    /// latency/SLO accounting out per tenant.
    pub tenant: u32,
    pub tokens: Vec<i32>,
    pub enqueued: Instant,
    /// Absolute deadline.  A request past its deadline is shed *before*
    /// execution (never planned) and answered with an expired response —
    /// `None` means the request waits indefinitely.
    pub deadline: Option<Instant>,
    pub respond: Sender<Response>,
}

impl Request {
    /// Whether the deadline has passed as of `now`.
    pub fn is_expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// The engine's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Tenant class id, echoed from the request (`0` = untenanted).
    pub tenant: u32,
    /// Next-token argmax per input position (length = original request len).
    pub argmax: Vec<i32>,
    /// Wall time spent queued + executing.
    pub latency_s: f64,
    /// Which artifact bucket served it.
    pub bucket: usize,
    /// Error message if the request failed.
    pub error: Option<String>,
    /// The request's deadline passed before it executed (a deadline shed,
    /// distinct from backpressure sheds and execution failures).
    pub expired: bool,
}

impl Response {
    pub fn failed(id: u64, err: impl Into<String>) -> Self {
        Response {
            id,
            tenant: 0,
            argmax: Vec::new(),
            latency_s: 0.0,
            bucket: 0,
            error: Some(err.into()),
            expired: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    #[test]
    fn request_roundtrip_through_channel() {
        let (tx, rx) = channel();
        let req = Request {
            id: 7,
            tenant: 0,
            tokens: vec![1, 2, 3],
            enqueued: Instant::now(),
            deadline: None,
            respond: tx,
        };
        req.respond
            .send(Response {
                id: req.id,
                tenant: req.tenant,
                argmax: vec![2, 3, 4],
                latency_s: 0.001,
                bucket: 16,
                error: None,
                expired: false,
            })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.argmax.len(), 3);
        assert!(resp.error.is_none());
    }

    #[test]
    fn failed_response() {
        let r = Response::failed(1, "too long");
        assert!(r.error.is_some());
        assert!(!r.expired);
    }

    #[test]
    fn deadline_expiry_is_exact() {
        let (tx, _rx) = channel();
        let now = Instant::now();
        let mut req = Request {
            id: 1,
            tenant: 0,
            tokens: vec![1],
            enqueued: now,
            deadline: None,
            respond: tx,
        };
        assert!(!req.is_expired(now + Duration::from_secs(3600)), "no deadline never expires");
        req.deadline = Some(now + Duration::from_millis(5));
        assert!(!req.is_expired(now));
        assert!(req.is_expired(now + Duration::from_millis(5)), "deadline instant itself expires");
        assert!(req.is_expired(now + Duration::from_millis(6)));
    }
}
