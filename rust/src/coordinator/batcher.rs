//! Continuous batcher: groups admitted requests into executable batches.
//!
//! The AOT artifacts are compiled at fixed sequence buckets (the static
//! shapes PJRT requires), so the batcher (a) pads each request's token
//! sequence into the smallest fitting bucket, and (b) forms multi-request
//! batches under a token budget so one engine dispatch amortizes executor
//! overhead across requests — the serving-level mirror of the kernel-level
//! batching thesis.

use crate::coordinator::request::Request;

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Available sequence buckets, ascending (from the artifact manifest).
    pub buckets: Vec<usize>,
    /// Max requests per formed batch.
    pub max_requests: usize,
    /// Max total (padded) tokens per formed batch.
    pub max_tokens: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { buckets: vec![16, 64, 256], max_requests: 16, max_tokens: 2048 }
    }
}

/// One formed batch: requests sharing a bucket.
#[derive(Debug)]
pub struct FormedBatch {
    pub bucket: usize,
    pub requests: Vec<Request>,
}

impl BatchPolicy {
    /// Smallest bucket that fits `len` tokens; `None` if the request is too
    /// long for every compiled bucket (rejected with an error upstream).
    pub fn bucket_for(&self, len: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= len)
    }

    /// Pad token ids to the bucket with the pad id (0).
    pub fn pad(&self, tokens: &[i32], bucket: usize) -> Vec<i32> {
        let mut v = tokens.to_vec();
        v.resize(bucket, 0);
        v
    }

    /// Form batches from pending requests: group by bucket, respect request
    /// and token budgets, preserve FIFO inside each bucket.  Requests that
    /// fit no bucket are returned separately for rejection.
    pub fn form(&self, pending: Vec<Request>) -> (Vec<FormedBatch>, Vec<Request>) {
        let mut rejected = Vec::new();
        let mut per_bucket: Vec<Vec<Request>> = self.buckets.iter().map(|_| Vec::new()).collect();
        for r in pending {
            match self.bucket_for(r.tokens.len()) {
                Some(b) => {
                    let bi = self.buckets.iter().position(|&x| x == b).unwrap();
                    per_bucket[bi].push(r);
                }
                None => rejected.push(r),
            }
        }
        let mut out = Vec::new();
        for (bi, reqs) in per_bucket.into_iter().enumerate() {
            let bucket = self.buckets[bi];
            let mut cur: Vec<Request> = Vec::new();
            for r in reqs {
                let would_tokens = (cur.len() + 1) * bucket;
                if cur.len() + 1 > self.max_requests || would_tokens > self.max_tokens {
                    if !cur.is_empty() {
                        out.push(FormedBatch { bucket, requests: std::mem::take(&mut cur) });
                    }
                }
                cur.push(r);
            }
            if !cur.is_empty() {
                out.push(FormedBatch { bucket, requests: cur });
            }
        }
        (out, rejected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Response;
    use std::sync::mpsc::{channel, Receiver};
    use std::time::Instant;

    fn req(id: u64, len: usize) -> (Request, Receiver<Response>) {
        let (tx, rx) = channel();
        (
            Request {
                id,
                tenant: 0,
                tokens: vec![1; len],
                enqueued: Instant::now(),
                deadline: None,
                respond: tx,
            },
            rx,
        )
    }

    fn policy() -> BatchPolicy {
        BatchPolicy { buckets: vec![16, 64, 256], max_requests: 4, max_tokens: 256 }
    }

    #[test]
    fn bucket_selection() {
        let p = policy();
        assert_eq!(p.bucket_for(1), Some(16));
        assert_eq!(p.bucket_for(16), Some(16));
        assert_eq!(p.bucket_for(17), Some(64));
        assert_eq!(p.bucket_for(256), Some(256));
        assert_eq!(p.bucket_for(257), None);
    }

    #[test]
    fn padding_preserves_prefix() {
        let p = policy();
        let padded = p.pad(&[5, 6, 7], 16);
        assert_eq!(padded.len(), 16);
        assert_eq!(&padded[..3], &[5, 6, 7]);
        assert!(padded[3..].iter().all(|&t| t == 0));
    }

    #[test]
    fn groups_by_bucket_fifo() {
        let p = policy();
        let reqs = vec![req(0, 10).0, req(1, 60).0, req(2, 12).0];
        let (batches, rejected) = p.form(reqs);
        assert!(rejected.is_empty());
        let b16 = batches.iter().find(|b| b.bucket == 16).unwrap();
        assert_eq!(b16.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(batches.iter().find(|b| b.bucket == 64).unwrap().requests[0].id, 1);
    }

    #[test]
    fn token_budget_splits_batches() {
        let p = policy(); // max_tokens 256 => at most 4 x 64-token requests? 4*64=256 ok
        let reqs: Vec<Request> = (0..6).map(|i| req(i, 60).0).collect();
        let (batches, _) = p.form(reqs);
        let sizes: Vec<usize> = batches.iter().map(|b| b.requests.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 6);
        assert!(sizes.iter().all(|&s| s * 64 <= 256 && s <= 4), "{sizes:?}");
    }

    #[test]
    fn oversize_rejected() {
        let p = policy();
        let (batches, rejected) = p.form(vec![req(0, 1000).0]);
        assert!(batches.is_empty());
        assert_eq!(rejected.len(), 1);
    }
}
