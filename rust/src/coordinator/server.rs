//! TCP line-protocol server (std::net, one thread per connection).
//!
//! Wire format: one JSON object per line.
//!   request:  {"id": 1, "tokens": [3, 14, 15]}
//!   response: {"id": 1, "argmax": [...], "latency_ms": 1.2, "bucket": 16}
//!   error:    {"id": 1, "error": "..."}
//! The literal line "stats" returns a metrics snapshot; "quit" closes the
//! connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::queue::{AdmissionQueue, PushResult};
use crate::coordinator::request::Request;
use crate::util::json::Json;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Parse one request line into (id, tokens).
pub fn parse_request(line: &str) -> Result<(u64, Vec<i32>), String> {
    let j = Json::parse(line).map_err(|e| e.to_string())?;
    let id = j
        .get("id")
        .and_then(|v| v.as_i64())
        .map(|v| v as u64)
        .unwrap_or_else(|| NEXT_ID.fetch_add(1, Ordering::Relaxed));
    let tokens = j
        .get("tokens")
        .and_then(|v| v.as_arr())
        .ok_or("missing tokens array")?
        .iter()
        .map(|t| t.as_i64().map(|x| x as i32).ok_or("non-integer token"))
        .collect::<Result<Vec<i32>, &str>>()?;
    if tokens.is_empty() {
        return Err("empty token list".into());
    }
    Ok((id, tokens))
}

/// Render a response line.
pub fn render_response(resp: &crate::coordinator::request::Response) -> String {
    match &resp.error {
        Some(e) => Json::obj(vec![
            ("id", Json::num(resp.id as f64)),
            ("error", Json::str(e.clone())),
        ])
        .to_string(),
        None => Json::obj(vec![
            ("id", Json::num(resp.id as f64)),
            (
                "argmax",
                Json::arr(resp.argmax.iter().map(|&x| Json::num(x as f64))),
            ),
            ("latency_ms", Json::num(resp.latency_s * 1e3)),
            ("bucket", Json::num(resp.bucket as f64)),
        ])
        .to_string(),
    }
}

/// Serve one connection (public so integration tests can drive a real
/// socket against an in-process engine).
pub fn handle_conn(
    stream: TcpStream,
    queue: Arc<AdmissionQueue>,
    metrics: Arc<Metrics>,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    log::debug!("connection from {peer}");
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "quit" {
            break;
        }
        if line == "stats" {
            writeln!(writer, "{}", metrics.snapshot().render())?;
            continue;
        }
        match parse_request(line) {
            Ok((id, tokens)) => {
                let (tx, rx) = channel();
                let req = Request {
                    id,
                    tenant: 0,
                    tokens,
                    enqueued: Instant::now(),
                    deadline: None,
                    respond: tx,
                };
                match queue.try_push(req) {
                    PushResult::Ok => {
                        // block this connection until its answer arrives
                        match rx.recv() {
                            Ok(resp) => writeln!(writer, "{}", render_response(&resp))?,
                            Err(_) => writeln!(writer, "{{\"id\":{id},\"error\":\"engine gone\"}}")?,
                        }
                    }
                    PushResult::Full => {
                        writeln!(writer, "{{\"id\":{id},\"error\":\"queue full\"}}")?
                    }
                    PushResult::Closed => {
                        writeln!(writer, "{{\"id\":{id},\"error\":\"shutting down\"}}")?;
                        break;
                    }
                }
            }
            Err(e) => writeln!(writer, "{{\"error\":{}}}", Json::str(e))?,
        }
    }
    Ok(())
}

/// Accept loop: one thread per connection. Blocks forever (Ctrl-C to stop).
pub fn listen(addr: &str, queue: Arc<AdmissionQueue>, metrics: Arc<Metrics>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    log::info!("listening on {addr}");
    for stream in listener.incoming() {
        let stream = stream?;
        let q = Arc::clone(&queue);
        let m = Arc::clone(&metrics);
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, q, m) {
                log::warn!("connection error: {e}");
            }
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Response;

    #[test]
    fn parses_valid_request() {
        let (id, tokens) = parse_request(r#"{"id": 5, "tokens": [1, 2, 3]}"#).unwrap();
        assert_eq!(id, 5);
        assert_eq!(tokens, vec![1, 2, 3]);
    }

    #[test]
    fn assigns_id_when_missing() {
        let (id, _) = parse_request(r#"{"tokens": [9]}"#).unwrap();
        assert!(id >= 1);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"tokens": []}"#).is_err());
        assert!(parse_request(r#"{"tokens": "nope"}"#).is_err());
    }

    #[test]
    fn renders_success_and_error() {
        let ok = Response {
            id: 1,
            tenant: 0,
            argmax: vec![4, 2],
            latency_s: 0.0015,
            bucket: 16,
            error: None,
            expired: false,
        };
        let s = render_response(&ok);
        assert!(s.contains("\"argmax\":[4,2]"));
        assert!(s.contains("\"bucket\":16"));
        let err = Response::failed(2, "boom");
        assert!(render_response(&err).contains("boom"));
    }
}
