//! L3 serving coordinator: the request-path building blocks.
//!
//! Owns the pieces of the request path — admission queue with
//! backpressure, continuous batcher (sequence-bucket padding), metrics,
//! request/response types, and the TCP line-protocol front end.  The loop
//! that wires them together is the backend-generic serving core in
//! [`crate::serve`]; the PJRT engine here (`engine`, feature `pjrt`) is
//! one [`crate::serve::StepExecutor`] instantiation of that core, the
//! default-features sim/CPU path is the other.
//!
//! The MoE layer has no cross-token interaction, so the batcher may pack
//! tokens from *different* requests into one execution step — the serving
//! analog of the paper's intra-kernel batching across tokens.  The full LM
//! path batches at request granularity into per-sequence buckets.

pub mod batcher;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod server;
