//! L3 serving coordinator.
//!
//! Owns the request path end to end: admission queue → continuous batcher
//! (sequence-bucket padding; MoE-layer token batching) → engine workers
//! executing AOT artifacts on the PJRT runtime → metrics.  Python is never
//! on this path; the artifacts were compiled once at build time.
//!
//! The MoE layer has no cross-token interaction, so the batcher may pack
//! tokens from *different* requests into one `moe_ffn` call — the serving
//! analog of the paper's intra-kernel batching across tokens. The full LM
//! path batches at request granularity into per-sequence buckets.

pub mod batcher;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod server;
