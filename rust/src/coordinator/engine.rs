//! The PJRT serving engine: AOT LM artifacts as a
//! [`StepExecutor`](crate::serve::StepExecutor) for the backend-generic
//! serving core.
//!
//! The engine owns the executor pool (PJRT executables are not Sync in the
//! `xla` crate, so execution is serialized through a dedicated dispatch
//! thread) and the model parameters (generated once from a deterministic
//! seed, uploaded to device buffers at warmup).  The queue → batcher →
//! execute → respond loop is [`crate::serve::Server`] — the same core the
//! default-features sim path runs under `cargo test`, instantiated here
//! with this executor.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::queue::AdmissionQueue;
use crate::exec::ExecError;
use crate::runtime::artifact::Manifest;
use crate::runtime::client::Runtime;
use crate::runtime::executor::{ExecutorPool, Value};
use crate::serve::{Server, ServerConfig, StepExecutor, StepInput, StepOutput, Stopper};
use crate::util::rng::Rng;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub policy: BatchPolicy,
    pub queue_capacity: usize,
    pub param_seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts_dir: std::path::PathBuf::from("artifacts"),
            policy: BatchPolicy::default(),
            queue_capacity: 256,
            param_seed: 0xC0FFEE,
        }
    }
}

/// Model hyper-parameters read from the manifest (mirror of Python
/// `ModelConfig`; the manifest is the source of truth).
#[derive(Clone, Debug)]
pub struct LmConfig {
    pub vocab: usize,
    pub buckets: Vec<usize>,
    pub param_shapes: Vec<Vec<usize>>,
    pub experts: usize,
}

/// The PJRT execution step.  Construct with [`Engine::new`], or let
/// [`Engine::spawn`] wrap it in a [`Server`] on a dedicated thread.
pub struct Engine {
    cfg: EngineConfig,
    pool: ExecutorPool,
    lm: LmConfig,
    params: Vec<Value>,
    /// Device-resident parameter buffers, uploaded once at warmup
    /// (§Perf: the request path must not re-stage ~76 MB of weights).
    param_buffers: Vec<xla::PjRtBuffer>,
}

/// Handles returned by [`Engine::spawn`]: everything the request side needs.
pub struct EngineHandle {
    pub queue: Arc<AdmissionQueue>,
    pub metrics: Arc<Metrics>,
    pub lm: LmConfig,
    pub stop: Stopper,
    join: std::thread::JoinHandle<()>,
}

impl EngineHandle {
    /// Close the queue and wait for the serving thread to drain and exit.
    pub fn shutdown(self) {
        self.queue.close();
        let _ = self.join.join();
    }
}

impl Engine {
    /// Construct the engine inside a dedicated thread (the PJRT client is
    /// not `Send`, so it must live where it serves), wrap it in the
    /// generic [`Server`], and return the request-side handles.  Blocks
    /// until warmup completes or fails.
    pub fn spawn(cfg: EngineConfig) -> Result<EngineHandle> {
        let (tx, rx) = std::sync::mpsc::channel();
        let join = std::thread::Builder::new()
            .name("sb-engine".into())
            .spawn(move || {
                let mut engine = match Engine::new(cfg) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = tx.send(Err(anyhow!("engine init: {e}")));
                        return;
                    }
                };
                if let Err(e) = engine.warmup() {
                    let _ = tx.send(Err(anyhow!("warmup: {e}")));
                    return;
                }
                let lm = engine.lm.clone();
                let server_cfg = ServerConfig {
                    policy: engine.cfg.policy.clone(),
                    queue_capacity: engine.cfg.queue_capacity,
                    ..ServerConfig::default()
                };
                let mut server = Server::new(server_cfg, engine);
                let _ = tx.send(Ok((
                    server.queue(),
                    server.metrics(),
                    lm,
                    server.stopper(),
                )));
                server.serve();
            })?;
        match rx.recv() {
            Ok(Ok((queue, metrics, lm, stop))) => {
                Ok(EngineHandle { queue, metrics, lm, stop, join })
            }
            Ok(Err(e)) => {
                let _ = join.join();
                Err(e)
            }
            Err(_) => {
                let _ = join.join();
                Err(anyhow!("engine thread died during init"))
            }
        }
    }

    pub fn new(cfg: EngineConfig) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let lm = Self::lm_config(&manifest)?;
        let params = Self::materialize_params(&lm, cfg.param_seed);
        Ok(Engine {
            cfg,
            pool: ExecutorPool::new(rt, manifest),
            lm,
            params,
            param_buffers: Vec::new(),
        })
    }

    pub fn lm_info(&self) -> &LmConfig {
        &self.lm
    }

    fn lm_config(manifest: &Manifest) -> Result<LmConfig> {
        // discover lm_forward buckets from entry names
        let mut buckets = Vec::new();
        for name in manifest.entries.keys() {
            if let Some(s) = name.strip_prefix("lm_forward_s") {
                if let Ok(b) = s.parse::<usize>() {
                    buckets.push(b);
                }
            }
        }
        buckets.sort_unstable();
        if buckets.is_empty() {
            return Err(anyhow!("no lm_forward_s* entries in manifest"));
        }
        let e0 = manifest.entry(&format!("lm_forward_s{}", buckets[0]))?;
        let cfgj = e0.meta.get("config").ok_or_else(|| anyhow!("meta.config missing"))?;
        let vocab = cfgj.get("vocab").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("vocab"))?;
        let experts =
            cfgj.get("experts").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("experts"))?;
        let param_shapes: Vec<Vec<usize>> =
            e0.inputs[1..].iter().map(|s| s.shape.clone()).collect();
        Ok(LmConfig { vocab, buckets, param_shapes, experts })
    }

    /// Deterministic synthetic weights (documented substitution for a real
    /// checkpoint; see DESIGN.md) — must match Python `init_params` in
    /// *shape contract* only, not values: the engine is self-consistent.
    fn materialize_params(lm: &LmConfig, seed: u64) -> Vec<Value> {
        let mut rng = Rng::new(seed);
        lm.param_shapes
            .iter()
            .map(|shape| {
                let n: usize = shape.iter().product();
                // LN-style vectors get ones, everything else scaled normals
                let data: Vec<f32> = if shape.len() == 1 {
                    vec![1.0; n]
                } else {
                    let fan_in = shape[shape.len() - 2] as f32;
                    let scale = 1.0 / fan_in.sqrt();
                    (0..n).map(|_| rng.normal() as f32 * scale).collect()
                };
                Value::F32(data, shape.clone())
            })
            .collect()
    }

    /// Pre-compile all LM buckets and upload the parameters to device
    /// buffers once (avoids first-request latency spikes and per-request
    /// weight staging).
    pub fn warmup(&mut self) -> Result<()> {
        let buckets = self.lm.buckets.clone();
        for b in buckets {
            self.pool.prepare(&format!("lm_forward_s{b}"))?;
        }
        if self.param_buffers.is_empty() {
            self.param_buffers = self
                .params
                .iter()
                .map(|p| self.pool.upload(p))
                .collect::<Result<Vec<_>>>()?;
        }
        Ok(())
    }

    /// Run one padded sequence through the bucketed LM; returns per-position
    /// argmax.
    fn run_lm(&mut self, bucket: usize, padded: &[i32]) -> Result<Vec<i32>> {
        let entry = format!("lm_forward_s{bucket}");
        // hot path: only the token ids are uploaded per request; parameters
        // are device-resident (see warmup)
        let ids_buf = self.pool.upload(&Value::I32(padded.to_vec(), vec![bucket]))?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.param_buffers.len());
        args.push(&ids_buf);
        args.extend(self.param_buffers.iter());
        let outs = self.pool.run_buffers(&entry, &args)?;
        let logits = outs[0].as_f32()?;
        let vocab = self.lm.vocab;
        let argmax: Vec<i32> = (0..bucket)
            .map(|pos| {
                let row = &logits[pos * vocab..(pos + 1) * vocab];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect();
        Ok(argmax)
    }

    /// The engine's MoE batch path on the unified execution surface: wraps
    /// the engine's executor pool as a [`crate::runtime::PjrtBackend`], so callers execute
    /// plans through `Backend::execute` / `ExecutionSession::run_on` exactly
    /// like the simulator, CPU, and baseline backends.
    pub fn moe_backend(
        &mut self,
        ordering: crate::moe::ordering::OrderingStrategy,
    ) -> Result<crate::runtime::PjrtBackend<'_>> {
        crate::runtime::PjrtBackend::new(&mut self.pool, ordering)
    }

    /// Direct MoE-layer execution (the moe_ffn artifact): tokens from many
    /// requests packed into one call.  Returns (output, expert counts);
    /// the caller records the counts into its metrics sink.
    pub fn run_moe_ffn(&mut self, seq_bucket: usize, x: &[f32]) -> Result<(Vec<f32>, Vec<i32>)> {
        let entry_name = format!("moe_ffn_s{seq_bucket}");
        let entry = self.pool.manifest().entry(&entry_name)?.clone();
        let d_model = entry.inputs[0].shape[1];
        anyhow::ensure!(x.len() == seq_bucket * d_model, "bad activation size");
        let mut rng = Rng::new(self.cfg.param_seed ^ 0xFFF);
        let mk = |spec: &crate::runtime::artifact::TensorSpec, rng: &mut Rng| {
            let n = spec.numel();
            let fan_in = spec.shape[spec.shape.len() - 2] as f32;
            Value::F32(
                (0..n).map(|_| rng.normal() as f32 / fan_in.sqrt()).collect(),
                spec.shape.clone(),
            )
        };
        let router = mk(&entry.inputs[1], &mut rng);
        let w_in = mk(&entry.inputs[2], &mut rng);
        let w_out = mk(&entry.inputs[3], &mut rng);
        let inputs = vec![
            Value::F32(x.to_vec(), vec![seq_bucket, d_model]),
            router,
            w_in,
            w_out,
        ];
        let outs = self.pool.run(&entry_name, &inputs)?;
        let counts = outs[1].as_i32()?.to_vec();
        Ok((outs[0].as_f32()?.to_vec(), counts))
    }
}

impl StepExecutor for Engine {
    fn name(&self) -> &'static str {
        "pjrt/lm"
    }

    fn buckets(&self) -> Vec<usize> {
        self.lm.buckets.clone()
    }

    /// Execute one formed batch.  The `lm_forward_s{bucket}` artifacts are
    /// compiled for ONE padded sequence (`[bucket]` token ids — PJRT
    /// requires static shapes and the AOT set carries no request
    /// dimension), so a formed batch necessarily executes as `rows`
    /// sequential kernel dispatches; the batch still amortizes queue/
    /// batcher overhead, and the server records one per-batch exec metric
    /// around this whole call.  Per-row MoE token packing happens inside
    /// the artifact.  A failing row is reported in [`StepOutput::failed`]
    /// (placeholder argmax) rather than failing the whole batch, so
    /// per-request error isolation is preserved.
    fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
        let mut argmax = Vec::with_capacity(step.rows * step.bucket);
        let mut failed = Vec::new();
        for r in 0..step.rows {
            let padded = &step.tokens[r * step.bucket..(r + 1) * step.bucket];
            match self.run_lm(step.bucket, padded) {
                Ok(out) => argmax.extend(out),
                Err(e) => {
                    argmax.extend(std::iter::repeat(0).take(step.bucket));
                    failed.push((r, e.to_string()));
                }
            }
        }
        Ok(StepOutput { argmax, expert_rows: Vec::new(), failed, sim_time_s: None })
    }
}
