//! Bounded admission queue with backpressure.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::request::Request;

/// MPMC bounded queue: producers block-or-reject when full (backpressure),
/// workers block on pop with a timeout so they can observe shutdown.
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct Inner {
    q: VecDeque<Request>,
    closed: bool,
}

/// Result of a non-blocking push.
#[derive(Debug, PartialEq, Eq)]
pub enum PushResult {
    Ok,
    Full,
    Closed,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> Self {
        AdmissionQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking admission: reject when full (the caller surfaces 429).
    pub fn try_push(&self, req: Request) -> PushResult {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return PushResult::Closed;
        }
        if g.q.len() >= self.capacity {
            return PushResult::Full;
        }
        g.q.push_back(req);
        drop(g);
        self.not_empty.notify_one();
        PushResult::Ok
    }

    /// Blocking admission with backpressure.
    pub fn push(&self, req: Request) -> PushResult {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return PushResult::Closed;
            }
            if g.q.len() < self.capacity {
                g.q.push_back(req);
                drop(g);
                self.not_empty.notify_one();
                return PushResult::Ok;
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Pop one request; `None` on timeout or when closed-and-drained.
    pub fn pop(&self, timeout: Duration) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.q.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(r);
            }
            if g.closed {
                return None;
            }
            let (g2, res) = self.not_empty.wait_timeout(g, timeout).unwrap();
            g = g2;
            if res.timed_out() {
                return g.q.pop_front();
            }
        }
    }

    /// Drain up to `max` requests without blocking (batch formation).
    pub fn drain_up_to(&self, max: usize) -> Vec<Request> {
        let mut g = self.inner.lock().unwrap();
        let n = g.q.len().min(max);
        let out: Vec<Request> = g.q.drain(..n).collect();
        drop(g);
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: producers get `Closed`, workers drain the remainder.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Response;
    use std::sync::mpsc::channel;
    use std::sync::Arc;
    use std::time::Instant;

    fn req(id: u64) -> (Request, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (Request { id, tokens: vec![1], enqueued: Instant::now(), respond: tx }, rx)
    }

    #[test]
    fn fifo_order() {
        let q = AdmissionQueue::new(8);
        for i in 0..3 {
            assert_eq!(q.try_push(req(i).0), PushResult::Ok);
        }
        for i in 0..3 {
            assert_eq!(q.pop(Duration::from_millis(1)).unwrap().id, i);
        }
    }

    #[test]
    fn rejects_when_full() {
        let q = AdmissionQueue::new(2);
        assert_eq!(q.try_push(req(0).0), PushResult::Ok);
        assert_eq!(q.try_push(req(1).0), PushResult::Ok);
        assert_eq!(q.try_push(req(2).0), PushResult::Full);
    }

    #[test]
    fn closed_queue_rejects_producers_drains_consumers() {
        let q = AdmissionQueue::new(4);
        q.try_push(req(0).0);
        q.close();
        assert_eq!(q.try_push(req(1).0), PushResult::Closed);
        assert!(q.pop(Duration::from_millis(1)).is_some());
        assert!(q.pop(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn drain_up_to_takes_prefix() {
        let q = AdmissionQueue::new(8);
        for i in 0..5 {
            q.try_push(req(i).0);
        }
        let batch = q.drain_up_to(3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn blocking_push_wakes_on_pop() {
        let q = Arc::new(AdmissionQueue::new(1));
        q.try_push(req(0).0);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push(req(1).0));
        std::thread::sleep(Duration::from_millis(20));
        assert!(q.pop(Duration::from_millis(10)).is_some());
        assert_eq!(h.join().unwrap(), PushResult::Ok);
    }
}
