//! Bounded admission queues: the MPMC [`AdmissionQueue`] the serving loop
//! drains, and the priority-aware [`PriorityAdmission`] layer the scenario
//! runner puts in front of it — bounded per-class lanes with
//! lowest-priority-first load shedding under overload.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::request::Request;

/// Backstop for the wakeup-driven waits: a lost notification (which the
/// locking discipline should make impossible — see [`AdmissionQueue::wake_all`])
/// degrades to a bounded re-check instead of a hang.
const WAIT_BACKSTOP: Duration = Duration::from_millis(50);

/// MPMC bounded queue: producers block-or-reject when full (backpressure),
/// workers block on pop with a timeout so they can observe shutdown.
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct Inner {
    q: VecDeque<Request>,
    closed: bool,
}

/// Result of a non-blocking push.
#[derive(Debug, PartialEq, Eq)]
pub enum PushResult {
    Ok,
    Full,
    Closed,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> Self {
        AdmissionQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking admission: reject when full (the caller surfaces 429).
    pub fn try_push(&self, req: Request) -> PushResult {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return PushResult::Closed;
        }
        if g.q.len() >= self.capacity {
            return PushResult::Full;
        }
        g.q.push_back(req);
        drop(g);
        self.not_empty.notify_one();
        PushResult::Ok
    }

    /// Blocking admission with backpressure.
    pub fn push(&self, req: Request) -> PushResult {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return PushResult::Closed;
            }
            if g.q.len() < self.capacity {
                g.q.push_back(req);
                drop(g);
                self.not_empty.notify_one();
                return PushResult::Ok;
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Pop one request; `None` on timeout or when closed-and-drained.
    pub fn pop(&self, timeout: Duration) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.q.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(r);
            }
            if g.closed {
                return None;
            }
            let (g2, res) = self.not_empty.wait_timeout(g, timeout).unwrap();
            g = g2;
            if res.timed_out() {
                return g.q.pop_front();
            }
        }
    }

    /// Block until a request is available, returning `None` only when the
    /// queue is closed-and-drained or `stop` is set — the wakeup-driven
    /// replacement for polling [`AdmissionQueue::pop`] with a timeout.
    ///
    /// The wait is notification-driven: producers and [`AdmissionQueue::close`]
    /// / [`AdmissionQueue::wake_all`] wake it.  `stop` is re-checked on every
    /// wakeup (and on a coarse backstop tick), so a [`crate::serve::Stopper`]-style
    /// flag ends the wait promptly.
    pub fn pop_wait(&self, stop: &AtomicBool) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if stop.load(Ordering::Relaxed) {
                return None;
            }
            if let Some(r) = g.q.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(r);
            }
            if g.closed {
                return None;
            }
            let (g2, _) = self.not_empty.wait_timeout(g, WAIT_BACKSTOP).unwrap();
            g = g2;
        }
    }

    /// Block until a request is available or `deadline` passes; `None` on
    /// deadline expiry, closed-and-drained, or `stop`.  The batch-formation
    /// wait: "accumulate more riders until the batch deadline".
    pub fn pop_until(&self, deadline: Instant, stop: &AtomicBool) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if stop.load(Ordering::Relaxed) {
                return None;
            }
            if let Some(r) = g.q.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(r);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let wait = (deadline - now).min(WAIT_BACKSTOP);
            let (g2, _) = self.not_empty.wait_timeout(g, wait).unwrap();
            g = g2;
        }
    }

    /// Wake every blocked producer and consumer so they re-check their stop
    /// conditions.  Taking the mutex before notifying closes the lost-wakeup
    /// window: a waiter is either still holding the lock (it will observe
    /// the caller's stop flag before waiting) or already parked (the
    /// notification reaches it).
    pub fn wake_all(&self) {
        drop(self.inner.lock().unwrap());
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Drain up to `max` requests without blocking (batch formation).
    pub fn drain_up_to(&self, max: usize) -> Vec<Request> {
        let mut g = self.inner.lock().unwrap();
        let n = g.q.len().min(max);
        let out: Vec<Request> = g.q.drain(..n).collect();
        drop(g);
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: producers get `Closed`, workers drain the remainder.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

/// Outcome of offering one item to a [`PriorityAdmission`] layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Admit {
    /// Queued within bounds.
    Admitted,
    /// Queued by evicting the newest item of the named strictly
    /// lower-priority class (the system was at its global bound).
    Evicted {
        /// Class index the evicted item belonged to.
        victim: usize,
    },
    /// Dropped: the item's own class lane was full, or the system was full
    /// of equal-or-higher-priority work.
    Shed,
}

/// One tenant class lane inside [`PriorityAdmission`].
struct ClassLane<T> {
    priority: u32,
    capacity: usize,
    q: VecDeque<T>,
    shed: u64,
}

/// Priority-aware admission with bounded per-class lanes, a global bound,
/// and lowest-priority-first load shedding.
///
/// Under overload the layer degrades *in priority order*: an arriving item
/// whose class still has lane headroom is admitted while the system has
/// global headroom; once the global bound is hit, admitting a
/// higher-priority item evicts the newest queued item of the strictly
/// lowest-priority non-empty class — so low-priority work is shed first and
/// high-priority SLO attainment degrades last.  Draining is also
/// priority-ordered ([`PriorityAdmission::pop_front`]), FIFO within a
/// class.
///
/// Single-threaded by design: the scenario runner
/// ([`crate::serve::scenario::run_scenario`]) owns it on a virtual clock.
/// For the wall-clock serving loop, feed admitted items onward into an
/// [`AdmissionQueue`].
pub struct PriorityAdmission<T> {
    classes: Vec<ClassLane<T>>,
    capacity: usize,
    len: usize,
}

impl<T> PriorityAdmission<T> {
    /// Build the layer: `classes[i] = (priority, lane_capacity)` for class
    /// index `i` (higher priority = more important), `capacity` bounds the
    /// total queued across all lanes.
    pub fn new(capacity: usize, classes: &[(u32, usize)]) -> Self {
        let classes = classes
            .iter()
            .map(|&(priority, cap)| ClassLane {
                priority,
                capacity: cap,
                q: VecDeque::new(),
                shed: 0,
            })
            .collect();
        PriorityAdmission { classes, capacity, len: 0 }
    }

    /// Offer one item for class `class`.  Returns the admission outcome
    /// plus the item that fell out of the system, if any: the incoming item
    /// itself on [`Admit::Shed`], the displaced victim on
    /// [`Admit::Evicted`], `None` on [`Admit::Admitted`].
    pub fn offer(&mut self, class: usize, item: T) -> (Admit, Option<T>) {
        let lane = &self.classes[class];
        if lane.q.len() >= lane.capacity {
            self.classes[class].shed += 1;
            return (Admit::Shed, Some(item));
        }
        if self.len < self.capacity {
            self.classes[class].q.push_back(item);
            self.len += 1;
            return (Admit::Admitted, None);
        }
        // global bound hit: evict from the strictly lowest-priority
        // non-empty lane, newest first (its oldest work keeps its place)
        let incoming = self.classes[class].priority;
        let victim = self
            .classes
            .iter()
            .enumerate()
            .filter(|(_, l)| !l.q.is_empty() && l.priority < incoming)
            .min_by_key(|(i, l)| (l.priority, usize::MAX - i))
            .map(|(i, _)| i);
        match victim {
            Some(v) => {
                let evicted = self.classes[v].q.pop_back();
                self.classes[v].shed += 1;
                self.classes[class].q.push_back(item);
                (Admit::Evicted { victim: v }, evicted)
            }
            None => {
                self.classes[class].shed += 1;
                (Admit::Shed, Some(item))
            }
        }
    }

    /// Pop the oldest item of the highest-priority non-empty class
    /// (priority ties broken by class index, lower first).
    pub fn pop_front(&mut self) -> Option<(usize, T)> {
        let best = self
            .classes
            .iter()
            .enumerate()
            .filter(|(_, l)| !l.q.is_empty())
            .max_by_key(|(i, l)| (l.priority, usize::MAX - i))
            .map(|(i, _)| i)?;
        let item = self.classes[best].q.pop_front()?;
        self.len -= 1;
        Some((best, item))
    }

    /// Pop the first item satisfying `pred`, scanning classes in priority
    /// order and FIFO within each class — batch riders that fit the chosen
    /// bucket, without disturbing queued items that do not.
    pub fn pop_front_if(&mut self, pred: impl Fn(&T) -> bool) -> Option<(usize, T)> {
        let mut order: Vec<usize> = (0..self.classes.len()).collect();
        order.sort_by_key(|&i| (u32::MAX - self.classes[i].priority, i));
        for c in order {
            if let Some(pos) = self.classes[c].q.iter().position(&pred) {
                let item = self.classes[c].q.remove(pos)?;
                self.len -= 1;
                return Some((c, item));
            }
        }
        None
    }

    /// Total items currently queued across all lanes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Items currently queued in one class lane.
    pub fn class_len(&self, class: usize) -> usize {
        self.classes[class].q.len()
    }

    /// Cumulative items dropped (lane-full rejections + evictions) for one
    /// class.
    pub fn shed(&self, class: usize) -> u64 {
        self.classes[class].shed
    }

    /// Cumulative drops across all classes.
    pub fn shed_total(&self) -> u64 {
        self.classes.iter().map(|l| l.shed).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Response;
    use std::sync::mpsc::channel;
    use std::sync::Arc;
    use std::time::Instant;

    fn req(id: u64) -> (Request, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (
            Request {
                id,
                tenant: 0,
                tokens: vec![1],
                enqueued: Instant::now(),
                deadline: None,
                respond: tx,
            },
            rx,
        )
    }

    #[test]
    fn fifo_order() {
        let q = AdmissionQueue::new(8);
        for i in 0..3 {
            assert_eq!(q.try_push(req(i).0), PushResult::Ok);
        }
        for i in 0..3 {
            assert_eq!(q.pop(Duration::from_millis(1)).unwrap().id, i);
        }
    }

    #[test]
    fn rejects_when_full() {
        let q = AdmissionQueue::new(2);
        assert_eq!(q.try_push(req(0).0), PushResult::Ok);
        assert_eq!(q.try_push(req(1).0), PushResult::Ok);
        assert_eq!(q.try_push(req(2).0), PushResult::Full);
    }

    #[test]
    fn closed_queue_rejects_producers_drains_consumers() {
        let q = AdmissionQueue::new(4);
        q.try_push(req(0).0);
        q.close();
        assert_eq!(q.try_push(req(1).0), PushResult::Closed);
        assert!(q.pop(Duration::from_millis(1)).is_some());
        assert!(q.pop(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn drain_up_to_takes_prefix() {
        let q = AdmissionQueue::new(8);
        for i in 0..5 {
            q.try_push(req(i).0);
        }
        let batch = q.drain_up_to(3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn pop_wait_blocks_until_a_push_arrives() {
        let q = Arc::new(AdmissionQueue::new(4));
        let stop = Arc::new(AtomicBool::new(false));
        let q2 = Arc::clone(&q);
        let s2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || q2.pop_wait(&s2).map(|r| r.id));
        std::thread::sleep(Duration::from_millis(20));
        q.try_push(req(7).0);
        assert_eq!(h.join().unwrap(), Some(7));
    }

    #[test]
    fn pop_wait_returns_none_on_close_and_on_stop() {
        let q = Arc::new(AdmissionQueue::new(4));
        let stop = AtomicBool::new(false);
        // closed-and-drained ends the wait
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.close();
        });
        assert!(q.pop_wait(&stop).is_none());
        h.join().unwrap();
        // a pre-set stop flag wins even over queued work
        let q = AdmissionQueue::new(4);
        q.try_push(req(0).0);
        stop.store(true, Ordering::Relaxed);
        assert!(q.pop_wait(&stop).is_none());
    }

    #[test]
    fn pop_until_expires_at_the_deadline_but_takes_earlier_arrivals() {
        let q = Arc::new(AdmissionQueue::new(4));
        let stop = AtomicBool::new(false);
        // nothing arrives: deadline expiry returns None
        let t0 = Instant::now();
        assert!(q.pop_until(t0 + Duration::from_millis(10), &stop).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(10));
        // an arrival before the deadline is returned without waiting it out
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.try_push(req(3).0);
        });
        let got = q.pop_until(Instant::now() + Duration::from_secs(5), &stop);
        assert_eq!(got.map(|r| r.id), Some(3));
        h.join().unwrap();
    }

    #[test]
    fn wake_all_lets_a_waiter_observe_a_stop_flag() {
        let q = Arc::new(AdmissionQueue::new(4));
        let stop = Arc::new(AtomicBool::new(false));
        let q2 = Arc::clone(&q);
        let s2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || q2.pop_wait(&s2));
        std::thread::sleep(Duration::from_millis(20));
        stop.store(true, Ordering::Relaxed);
        q.wake_all();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn blocking_push_wakes_on_pop() {
        let q = Arc::new(AdmissionQueue::new(1));
        q.try_push(req(0).0);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push(req(1).0));
        std::thread::sleep(Duration::from_millis(20));
        assert!(q.pop(Duration::from_millis(10)).is_some());
        assert_eq!(h.join().unwrap(), PushResult::Ok);
    }

    /// Two classes: 0 = low (priority 1), 1 = high (priority 2).
    fn two_class(capacity: usize, lane: usize) -> PriorityAdmission<u64> {
        PriorityAdmission::new(capacity, &[(1, lane), (2, lane)])
    }

    #[test]
    fn priority_pop_drains_high_class_first_fifo_within() {
        let mut pa = two_class(8, 8);
        assert_eq!(pa.offer(0, 10).0, Admit::Admitted);
        assert_eq!(pa.offer(1, 20).0, Admit::Admitted);
        assert_eq!(pa.offer(0, 11).0, Admit::Admitted);
        assert_eq!(pa.offer(1, 21).0, Admit::Admitted);
        let order: Vec<(usize, u64)> = std::iter::from_fn(|| pa.pop_front()).collect();
        assert_eq!(order, vec![(1, 20), (1, 21), (0, 10), (0, 11)]);
        assert!(pa.is_empty());
    }

    #[test]
    fn overload_evicts_lowest_priority_newest_first() {
        let mut pa = two_class(2, 2);
        assert_eq!(pa.offer(0, 10).0, Admit::Admitted);
        assert_eq!(pa.offer(0, 11).0, Admit::Admitted);
        // global bound hit: a high-priority arrival displaces the NEWEST
        // low-priority item; the oldest low item keeps its place
        let (admit, out) = pa.offer(1, 20);
        assert_eq!(admit, Admit::Evicted { victim: 0 });
        assert_eq!(out, Some(11));
        assert_eq!(pa.shed(0), 1);
        assert_eq!(pa.pop_front(), Some((1, 20)));
        assert_eq!(pa.pop_front(), Some((0, 10)));
    }

    #[test]
    fn low_priority_never_evicts_equal_or_higher() {
        let mut pa = two_class(2, 2);
        pa.offer(1, 20);
        pa.offer(1, 21);
        // system full of high-priority work: low arrivals are shed ...
        let (admit, out) = pa.offer(0, 10);
        assert_eq!((admit, out), (Admit::Shed, Some(10)));
        // ... and so are further high arrivals (equal priority never evicts)
        assert_eq!(pa.offer(1, 22).0, Admit::Shed);
        assert_eq!((pa.shed(0), pa.shed(1)), (1, 1));
        assert_eq!(pa.shed_total(), 2);
    }

    #[test]
    fn lane_bound_binds_before_global_bound() {
        let mut pa = two_class(8, 1);
        assert_eq!(pa.offer(1, 20).0, Admit::Admitted);
        // global headroom remains, but the class lane is full
        assert_eq!(pa.offer(1, 21).0, Admit::Shed);
        assert_eq!(pa.class_len(1), 1);
        assert_eq!(pa.len(), 1);
    }

    #[test]
    fn pop_front_if_skips_non_matching_items_in_priority_order() {
        let mut pa = two_class(8, 8);
        pa.offer(0, 4);
        pa.offer(1, 9);
        pa.offer(1, 6);
        // first even value, scanning high class first
        assert_eq!(pa.pop_front_if(|&v| v % 2 == 0), Some((1, 6)));
        assert_eq!(pa.pop_front_if(|&v| v % 2 == 0), Some((0, 4)));
        assert_eq!(pa.pop_front_if(|&v| v % 2 == 0), None);
        assert_eq!(pa.len(), 1, "odd item 9 stays queued");
    }
}
