//! Serving metrics: latency percentiles, throughput, expert-load tracking,
//! and per-tenant latency/goodput/SLO-attainment breakdowns.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::moe::plan_cache::CacheStats;
use crate::util::stats::{Samples, Welford};

/// Cumulative multi-shard (EP/TP) accounting for one sharded executor:
/// filled per step by [`crate::serve::ShardedStepExecutor`] and mirrored
/// into [`Metrics`] by the serving loop, like the plan-cache counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardingStats {
    /// Expert-parallel ways (shard lanes).
    pub ep: usize,
    /// Tensor-parallel ways.
    pub tp: usize,
    /// Sharded steps executed.
    pub steps: u64,
    /// Cumulative simulated kernel seconds per shard lane.
    pub busy_s: Vec<f64>,
    /// Cumulative critical-path kernel seconds (Σ per-step max over shards).
    pub critical_s: f64,
    /// Cumulative collective seconds (EP all-to-all + TP all-reduce).
    pub collective_s: f64,
    /// Cumulative simulated step seconds (critical path + collectives).
    pub step_s: f64,
    /// Σ of per-step device-load imbalance ratios (max/mean over shards,
    /// idle shards included).
    pub imbalance_sum: f64,
    /// Times the placement policy moved experts between shards.
    pub reshards: u64,
    /// Circuit-breaker trips: a shard quarantined after consecutive
    /// transient failures.
    pub breaker_trips: u64,
    /// Half-open probes issued to quarantined shards (successful or not).
    pub breaker_probes: u64,
    /// Steps executed while at least one shard was quarantined or probing
    /// (the executor ran degraded).
    pub degraded_steps: u64,
    /// Plan-cache counters of each shard lane.
    pub shard_cache: Vec<CacheStats>,
}

impl ShardingStats {
    /// Mean per-step device-load imbalance: 1.0 is perfectly balanced,
    /// `ep` is one shard doing all the work; 0.0 before any step.
    pub fn imbalance_ratio(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.imbalance_sum / self.steps as f64
        }
    }

    /// Fraction of simulated step time spent in collectives.
    pub fn collective_share(&self) -> f64 {
        if self.step_s > 0.0 {
            self.collective_s / self.step_s
        } else {
            0.0
        }
    }

    /// Per-shard utilization: shard busy time over the critical-path time
    /// (1.0 = that shard is the bottleneck every step).
    pub fn utilization(&self) -> Vec<f64> {
        self.busy_s
            .iter()
            .map(|&b| if self.critical_s > 0.0 { b / self.critical_s } else { 0.0 })
            .collect()
    }
}

/// Per-tenant serving accounting: completed/errored/shed counts, latency
/// percentiles, and SLO attainment for one tenant class.  Tenant `0` is
/// the untenanted default and is never broken out.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TenantStats {
    /// Tenant class id (from [`crate::coordinator::request::Request::tenant`]).
    pub tenant: u32,
    /// Requests completed without error.
    pub requests: u64,
    /// Requests answered with an error.
    pub errors: u64,
    /// Requests dropped by admission control before execution.
    pub shed: u64,
    /// Requests whose deadline passed before execution (deadline sheds,
    /// distinct from backpressure `shed`).
    pub expired: u64,
    /// Completed requests that were measured against a latency SLO.
    pub slo_checked: u64,
    /// Measured requests that met their SLO.
    pub slo_ok: u64,
    /// Median end-to-end latency of completed requests, milliseconds.
    pub latency_p50_ms: f64,
    /// 99th-percentile end-to-end latency, milliseconds.
    pub latency_p99_ms: f64,
}

impl TenantStats {
    /// Fraction of this tenant's finished-or-dropped requests that met
    /// their latency SLO.  Sheds, expiries, and errors count as misses (a
    /// dropped request certainly did not meet its deadline); 1.0 when
    /// nothing was measured against an SLO, so an idle tenant reads as
    /// unharmed.
    pub fn slo_attainment(&self) -> f64 {
        let denom = self.slo_checked + self.errors + self.shed + self.expired;
        if denom == 0 {
            1.0
        } else {
            self.slo_ok as f64 / denom as f64
        }
    }

    /// Goodput: SLO-meeting completions per second over `elapsed_s`
    /// (0.0 when no time has elapsed).
    pub fn goodput(&self, elapsed_s: f64) -> f64 {
        if elapsed_s > 0.0 {
            self.slo_ok as f64 / elapsed_s
        } else {
            0.0
        }
    }
}

/// Per-tenant running state behind the [`Metrics`] mutex.
#[derive(Default)]
struct TenantInner {
    requests: u64,
    errors: u64,
    shed: u64,
    expired: u64,
    slo_checked: u64,
    slo_ok: u64,
    latency: Samples,
}

/// Thread-safe metrics sink shared by engine workers.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    latency: Samples,
    exec: Samples,
    batch_size: Welford,
    requests: u64,
    tokens: u64,
    errors: u64,
    /// requests refused at admission (bounded-queue backpressure or a
    /// closed queue), counted by [`crate::serve::ServeHandle`]
    rejected: u64,
    /// requests shed because their deadline passed before execution
    expired: u64,
    /// step retries attempted after transient execution failures
    retries: u64,
    /// per-request admission-to-formation wait, milliseconds
    queue_wait: Samples,
    /// per-batch accumulation time (first pop to seal), milliseconds
    form_wait: Samples,
    /// steps currently between batch formation and response fan-out
    in_flight: u64,
    /// high-water mark of `in_flight` (>1 proves formation/execution overlap)
    max_in_flight: u64,
    started: Option<Instant>,
    /// cumulative per-expert routed-row counts (from the moe_ffn artifact's
    /// counts output) — drives load-aware ordering decisions
    expert_rows: Vec<u64>,
    /// plan-cache lookup counters, mirrored from the step executor
    plan_hits: u64,
    plan_misses: u64,
    /// multi-shard accounting, mirrored from a sharded step executor
    sharding: Option<ShardingStats>,
    /// per-tenant breakdowns, keyed by tenant class id (never holds 0)
    tenants: BTreeMap<u32, TenantInner>,
}

/// A snapshot for reporting.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub tokens: u64,
    pub errors: u64,
    pub elapsed_s: f64,
    pub req_per_s: f64,
    pub tokens_per_s: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub exec_p50_ms: f64,
    pub mean_batch: f64,
    /// Executor dispatches (formed batches executed).
    pub batches: u64,
    /// Requests refused at admission (backpressure or closed queue).
    pub rejected: u64,
    /// Requests shed because their deadline passed before execution
    /// (distinct from `rejected`: these were admitted, then timed out).
    pub expired: u64,
    /// Step retries attempted after transient execution failures.
    pub retries: u64,
    /// Median admission-to-formation wait, milliseconds (0.0 when the
    /// serving loop does not record it).
    pub queue_wait_p50_ms: f64,
    /// Median per-batch accumulation time, milliseconds.
    pub form_wait_p50_ms: f64,
    /// Steps currently in flight between formation and response fan-out.
    pub in_flight: u64,
    /// High-water mark of `in_flight`; >1 proves the pipelined front end
    /// overlapped formation with execution.
    pub max_in_flight: u64,
    pub expert_rows: Vec<u64>,
    /// Plan-cache lookups that skipped re-planning.
    pub plan_cache_hits: u64,
    /// Plan-cache lookups that built a fresh plan.
    pub plan_cache_misses: u64,
    /// Multi-shard accounting, when a sharded executor is serving.
    pub sharding: Option<ShardingStats>,
    /// Per-tenant breakdowns, ascending by tenant id (empty for
    /// untenanted traffic).
    pub tenants: Vec<TenantStats>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, latency_s: f64, tokens: usize) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
        g.latency.push(latency_s * 1e3);
        g.requests += 1;
        g.tokens += tokens as u64;
    }

    pub fn record_exec(&self, exec_s: f64, batch_size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.exec.push(exec_s * 1e3);
        g.batch_size.push(batch_size as f64);
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Count one request refused at admission (backpressure shed or closed
    /// queue) — the counter driver-side shed accounting reconciles against.
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Count one admitted request shed because its deadline passed before
    /// execution (never planned).
    pub fn record_expired(&self) {
        self.inner.lock().unwrap().expired += 1;
    }

    /// Count one step retry after a transient execution failure.
    pub fn record_retry(&self) {
        self.inner.lock().unwrap().retries += 1;
    }

    /// Record one request's admission-to-formation wait.
    pub fn record_queue_wait(&self, wait_s: f64) {
        self.inner.lock().unwrap().queue_wait.push(wait_s * 1e3);
    }

    /// Record one batch's accumulation time (first pop to seal).
    pub fn record_form_wait(&self, wait_s: f64) {
        self.inner.lock().unwrap().form_wait.push(wait_s * 1e3);
    }

    /// A formed batch entered the pipeline (batcher sealed it).
    pub fn pipeline_enter(&self) {
        let mut g = self.inner.lock().unwrap();
        g.in_flight += 1;
        g.max_in_flight = g.max_in_flight.max(g.in_flight);
    }

    /// A step left the pipeline (responses fanned out).
    pub fn pipeline_exit(&self) {
        let mut g = self.inner.lock().unwrap();
        g.in_flight = g.in_flight.saturating_sub(1);
    }

    /// Mirror the executor's plan-cache counters (absolute values; the
    /// cache owns the counting, metrics only surface it).
    pub fn set_plan_cache(&self, hits: u64, misses: u64) {
        let mut g = self.inner.lock().unwrap();
        g.plan_hits = hits;
        g.plan_misses = misses;
    }

    /// Mirror a sharded executor's cumulative multi-shard accounting
    /// (absolute values; the executor owns the counting).
    pub fn set_sharding(&self, stats: ShardingStats) {
        self.inner.lock().unwrap().sharding = Some(stats);
    }

    /// Record one completed request for a tenant class.  `slo_ok` is
    /// `Some(met)` when the caller knows the tenant's latency SLO (the
    /// scenario runner does), `None` when it does not (the plain serving
    /// loop).  Tenant `0` — the untenanted default — is not broken out.
    pub fn record_tenant_request(&self, tenant: u32, latency_s: f64, slo_ok: Option<bool>) {
        if tenant == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let t = g.tenants.entry(tenant).or_default();
        t.requests += 1;
        t.latency.push(latency_s * 1e3);
        if let Some(met) = slo_ok {
            t.slo_checked += 1;
            if met {
                t.slo_ok += 1;
            }
        }
    }

    /// Record one errored request for a tenant class (`0` ignored).
    pub fn record_tenant_error(&self, tenant: u32) {
        if tenant == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.tenants.entry(tenant).or_default().errors += 1;
    }

    /// Record one request shed by admission control for a tenant class
    /// (`0` ignored).
    pub fn record_tenant_shed(&self, tenant: u32) {
        if tenant == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.tenants.entry(tenant).or_default().shed += 1;
    }

    /// Record one deadline-expired request for a tenant class (`0` ignored).
    pub fn record_tenant_expired(&self, tenant: u32) {
        if tenant == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.tenants.entry(tenant).or_default().expired += 1;
    }

    pub fn record_expert_rows(&self, counts: &[i32]) {
        let mut g = self.inner.lock().unwrap();
        if g.expert_rows.len() < counts.len() {
            g.expert_rows.resize(counts.len(), 0);
        }
        for (acc, &c) in g.expert_rows.iter_mut().zip(counts) {
            *acc += c.max(0) as u64;
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let mut g = self.inner.lock().unwrap();
        let elapsed = g.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        let (p50, p95, p99) = if g.latency.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            (
                g.latency.percentile(50.0),
                g.latency.percentile(95.0),
                g.latency.percentile(99.0),
            )
        };
        let exec_p50 = if g.exec.is_empty() { 0.0 } else { g.exec.percentile(50.0) };
        let queue_wait_p50 =
            if g.queue_wait.is_empty() { 0.0 } else { g.queue_wait.percentile(50.0) };
        let form_wait_p50 =
            if g.form_wait.is_empty() { 0.0 } else { g.form_wait.percentile(50.0) };
        let tenants: Vec<TenantStats> = g
            .tenants
            .iter_mut()
            .map(|(&tenant, t)| {
                let (p50, p99) = if t.latency.is_empty() {
                    (0.0, 0.0)
                } else {
                    (t.latency.percentile(50.0), t.latency.percentile(99.0))
                };
                TenantStats {
                    tenant,
                    requests: t.requests,
                    errors: t.errors,
                    shed: t.shed,
                    expired: t.expired,
                    slo_checked: t.slo_checked,
                    slo_ok: t.slo_ok,
                    latency_p50_ms: p50,
                    latency_p99_ms: p99,
                }
            })
            .collect();
        Snapshot {
            requests: g.requests,
            tokens: g.tokens,
            errors: g.errors,
            elapsed_s: elapsed,
            req_per_s: if elapsed > 0.0 { g.requests as f64 / elapsed } else { 0.0 },
            tokens_per_s: if elapsed > 0.0 { g.tokens as f64 / elapsed } else { 0.0 },
            latency_p50_ms: p50,
            latency_p95_ms: p95,
            latency_p99_ms: p99,
            exec_p50_ms: exec_p50,
            mean_batch: g.batch_size.mean(),
            batches: g.batch_size.count(),
            rejected: g.rejected,
            expired: g.expired,
            retries: g.retries,
            queue_wait_p50_ms: queue_wait_p50,
            form_wait_p50_ms: form_wait_p50,
            in_flight: g.in_flight,
            max_in_flight: g.max_in_flight,
            expert_rows: g.expert_rows.clone(),
            plan_cache_hits: g.plan_hits,
            plan_cache_misses: g.plan_misses,
            sharding: g.sharding.clone(),
            tenants,
        }
    }
}

impl Snapshot {
    /// Hits over total plan-cache lookups; 0.0 before any lookup.
    pub fn plan_cache_hit_rate(&self) -> f64 {
        let total = self.plan_cache_hits + self.plan_cache_misses;
        if total == 0 {
            0.0
        } else {
            self.plan_cache_hits as f64 / total as f64
        }
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "requests={} tokens={} errors={} elapsed={:.2}s  {:.1} req/s  {:.0} tok/s\n\
             latency p50={:.2}ms p95={:.2}ms p99={:.2}ms  exec p50={:.2}ms  mean batch={:.2}",
            self.requests,
            self.tokens,
            self.errors,
            self.elapsed_s,
            self.req_per_s,
            self.tokens_per_s,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.latency_p99_ms,
            self.exec_p50_ms,
            self.mean_batch,
        );
        if self.rejected > 0 {
            s.push_str(&format!("  rejected={}", self.rejected));
        }
        if self.expired > 0 {
            s.push_str(&format!("  expired={}", self.expired));
        }
        if self.retries > 0 {
            s.push_str(&format!("  retries={}", self.retries));
        }
        if self.max_in_flight > 0 {
            s.push_str(&format!(
                "\npipeline: in-flight {}/{} (now/max)  queue wait p50={:.2}ms  \
                 form wait p50={:.2}ms",
                self.in_flight, self.max_in_flight, self.queue_wait_p50_ms, self.form_wait_p50_ms,
            ));
        }
        if self.plan_cache_hits + self.plan_cache_misses > 0 {
            s.push_str(&format!(
                "\nplan cache: {} hits / {} misses ({:.1}% hit rate)",
                self.plan_cache_hits,
                self.plan_cache_misses,
                self.plan_cache_hit_rate() * 100.0,
            ));
        }
        if let Some(sh) = &self.sharding {
            if sh.steps > 0 {
                let util: Vec<String> = sh
                    .utilization()
                    .iter()
                    .map(|u| format!("{:.0}%", u * 100.0))
                    .collect();
                let cache: Vec<String> = sh
                    .shard_cache
                    .iter()
                    .map(|c| format!("{}/{}", c.hits, c.misses))
                    .collect();
                s.push_str(&format!(
                    "\nsharded ep={} tp={}: {} steps  imbalance {:.2}  \
                     collectives {:.1}%  reshards {}\nshard util [{}]  \
                     shard cache h/m [{}]",
                    sh.ep,
                    sh.tp,
                    sh.steps,
                    sh.imbalance_ratio(),
                    sh.collective_share() * 100.0,
                    sh.reshards,
                    util.join(" "),
                    cache.join(" "),
                ));
                if sh.breaker_trips + sh.breaker_probes + sh.degraded_steps > 0 {
                    s.push_str(&format!(
                        "\nbreakers: {} trips  {} probes  {} degraded steps",
                        sh.breaker_trips, sh.breaker_probes, sh.degraded_steps,
                    ));
                }
            }
        }
        for t in &self.tenants {
            s.push_str(&format!(
                "\ntenant {}: ok={} err={} shed={}  p50={:.2}ms p99={:.2}ms  \
                 slo {:.1}%  goodput {:.1} req/s",
                t.tenant,
                t.requests,
                t.errors,
                t.shed,
                t.latency_p50_ms,
                t.latency_p99_ms,
                t.slo_attainment() * 100.0,
                t.goodput(self.elapsed_s),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        for i in 0..100 {
            m.record_request(0.001 * (i + 1) as f64, 10);
        }
        m.record_exec(0.005, 4);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.tokens, 1000);
        assert_eq!(s.errors, 1);
        assert!(s.latency_p50_ms > 0.0);
        assert!(s.latency_p99_ms >= s.latency_p50_ms);
        assert!((s.mean_batch - 4.0).abs() < 1e-9);
    }

    #[test]
    fn expert_rows_accumulate() {
        let m = Metrics::new();
        m.record_expert_rows(&[1, 2, 3]);
        m.record_expert_rows(&[4, 0, 1]);
        assert_eq!(m.snapshot().expert_rows, vec![5, 2, 4]);
    }

    #[test]
    fn render_contains_throughput() {
        let m = Metrics::new();
        m.record_request(0.01, 5);
        assert!(m.snapshot().render().contains("req/s"));
    }

    #[test]
    fn plan_cache_counters_surface_in_snapshot_and_render() {
        let m = Metrics::new();
        let before = m.snapshot();
        assert_eq!((before.plan_cache_hits, before.plan_cache_misses), (0, 0));
        assert!(!before.render().contains("plan cache"));
        m.set_plan_cache(6, 2);
        let s = m.snapshot();
        assert_eq!((s.plan_cache_hits, s.plan_cache_misses), (6, 2));
        assert!((s.plan_cache_hit_rate() - 0.75).abs() < 1e-12);
        assert!(s.render().contains("plan cache: 6 hits / 2 misses"));
    }

    #[test]
    fn batches_counts_exec_dispatches() {
        let m = Metrics::new();
        m.record_exec(0.001, 4);
        m.record_exec(0.002, 2);
        assert_eq!(m.snapshot().batches, 2);
    }

    #[test]
    fn pipeline_gauge_tracks_in_flight_and_high_water() {
        let m = Metrics::new();
        let before = m.snapshot();
        assert_eq!((before.in_flight, before.max_in_flight), (0, 0));
        assert!(!before.render().contains("pipeline:"), "idle render stays quiet");
        m.pipeline_enter();
        m.pipeline_enter();
        m.pipeline_exit();
        m.record_queue_wait(0.004);
        m.record_form_wait(0.002);
        let s = m.snapshot();
        assert_eq!((s.in_flight, s.max_in_flight), (1, 2));
        assert!((s.queue_wait_p50_ms - 4.0).abs() < 1e-9);
        assert!((s.form_wait_p50_ms - 2.0).abs() < 1e-9);
        assert!(s.render().contains("pipeline: in-flight 1/2"), "{}", s.render());
        // exit below zero saturates rather than wrapping
        m.pipeline_exit();
        m.pipeline_exit();
        assert_eq!(m.snapshot().in_flight, 0);
    }

    #[test]
    fn rejected_counter_surfaces_in_snapshot_and_render() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().rejected, 0);
        m.record_request(0.01, 5);
        m.record_rejected();
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.rejected, 2);
        assert!(s.render().contains("rejected=2"), "{}", s.render());
    }

    #[test]
    fn tenant_accounting_breaks_out_per_class() {
        let m = Metrics::new();
        // tenant 0 is the untenanted default: never broken out
        m.record_tenant_request(0, 0.001, None);
        m.record_tenant_error(0);
        m.record_tenant_shed(0);
        assert!(m.snapshot().tenants.is_empty());

        m.record_tenant_request(1, 0.010, Some(true));
        m.record_tenant_request(1, 0.020, Some(true));
        m.record_tenant_request(2, 0.050, Some(false));
        m.record_tenant_shed(2);
        m.record_tenant_error(2);
        let s = m.snapshot();
        assert_eq!(s.tenants.len(), 2);
        let t1 = &s.tenants[0];
        let t2 = &s.tenants[1];
        assert_eq!((t1.tenant, t1.requests, t1.slo_ok), (1, 2, 2));
        assert!((t1.slo_attainment() - 1.0).abs() < 1e-12);
        assert!(t1.latency_p99_ms >= t1.latency_p50_ms);
        // tenant 2: one measured miss, one shed, one error -> 0/3 attained
        assert_eq!((t2.tenant, t2.requests, t2.errors, t2.shed), (2, 1, 1, 1));
        assert_eq!(t2.slo_attainment(), 0.0);
        let r = s.render();
        assert!(r.contains("tenant 1:"), "render:\n{r}");
        assert!(r.contains("tenant 2:"), "render:\n{r}");
    }

    #[test]
    fn idle_tenant_attainment_is_vacuously_full() {
        assert_eq!(TenantStats::default().slo_attainment(), 1.0);
        assert_eq!(TenantStats::default().goodput(0.0), 0.0);
    }

    #[test]
    fn sharding_stats_derive_ratios() {
        let s = ShardingStats {
            ep: 2,
            tp: 1,
            steps: 4,
            busy_s: vec![0.8, 1.0],
            critical_s: 1.0,
            collective_s: 0.5,
            step_s: 2.0,
            imbalance_sum: 5.0,
            reshards: 1,
            shard_cache: vec![CacheStats::default(); 2],
            ..ShardingStats::default()
        };
        assert!((s.imbalance_ratio() - 1.25).abs() < 1e-12);
        assert!((s.collective_share() - 0.25).abs() < 1e-12);
        assert_eq!(s.utilization(), vec![0.8, 1.0]);
        // empty stats stay finite
        let z = ShardingStats::default();
        assert_eq!(z.imbalance_ratio(), 0.0);
        assert_eq!(z.collective_share(), 0.0);
        assert!(z.utilization().is_empty());
    }

    #[test]
    fn sharding_surfaces_in_snapshot_and_render() {
        let m = Metrics::new();
        m.record_request(0.01, 5);
        assert!(m.snapshot().sharding.is_none());
        assert!(!m.snapshot().render().contains("sharded"));
        m.set_sharding(ShardingStats {
            ep: 4,
            tp: 2,
            steps: 3,
            busy_s: vec![0.1; 4],
            critical_s: 0.1,
            collective_s: 0.02,
            step_s: 0.12,
            imbalance_sum: 3.9,
            reshards: 2,
            breaker_trips: 1,
            breaker_probes: 2,
            degraded_steps: 3,
            shard_cache: vec![CacheStats { hits: 2, misses: 1, entries: 1 }; 4],
        });
        let snap = m.snapshot();
        let sh = snap.sharding.as_ref().expect("mirrored");
        assert_eq!((sh.ep, sh.tp, sh.steps), (4, 2, 3));
        let r = snap.render();
        assert!(r.contains("sharded ep=4 tp=2"));
        assert!(r.contains("imbalance 1.30"));
        assert!(r.contains("reshards 2"));
        assert!(r.contains("2/1"));
        assert!(r.contains("breakers: 1 trips  2 probes  3 degraded steps"), "{r}");
    }

    #[test]
    fn expired_and_retry_counters_surface_in_snapshot_and_render() {
        let m = Metrics::new();
        let before = m.snapshot();
        assert_eq!((before.expired, before.retries), (0, 0));
        let quiet = before.render();
        assert!(!quiet.contains("expired="), "idle render stays quiet");
        assert!(!quiet.contains("retries="), "idle render stays quiet");
        m.record_request(0.01, 5);
        m.record_expired();
        m.record_expired();
        m.record_expired();
        m.record_retry();
        let s = m.snapshot();
        assert_eq!((s.expired, s.retries), (3, 1));
        let r = s.render();
        assert!(r.contains("expired=3"), "{r}");
        assert!(r.contains("retries=1"), "{r}");
    }

    #[test]
    fn tenant_expiry_counts_as_an_slo_miss() {
        let m = Metrics::new();
        m.record_tenant_expired(0); // untenanted: ignored
        assert!(m.snapshot().tenants.is_empty());
        m.record_tenant_request(3, 0.010, Some(true));
        m.record_tenant_expired(3);
        let s = m.snapshot();
        let t = &s.tenants[0];
        assert_eq!((t.tenant, t.requests, t.expired), (3, 1, 1));
        // one measured hit + one expiry -> 50% attainment
        assert!((t.slo_attainment() - 0.5).abs() < 1e-12);
    }
}
