//! # staticbatch — static batching of irregular workloads
//!
//! Production-quality reproduction of *"Static Batching of Irregular
//! Workloads on GPUs: Framework and Application to Efficient MoE Model
//! Inference"* (Alibaba Group, CS.DC 2025) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L1** — a Pallas kernel (`python/compile/kernels/moe_batched.py`)
//!   implementing the paper's fused, statically batched MoE expert GEMM with
//!   the compressed TilePrefix task mapping.
//! * **L2** — a JAX MoE transformer (`python/compile/model.py`) lowered
//!   ahead-of-time to HLO text artifacts.
//! * **L3** — this crate: the serving coordinator, the batching framework
//!   algorithms themselves ([`batching`]), a calibrated GPU execution
//!   simulator ([`sim`]) used to regenerate the paper's evaluation on
//!   H20/H800, baseline implementations ([`baselines`]), and — behind the
//!   `pjrt` feature — the PJRT runtime ([`runtime`]) that executes the AOT
//!   artifacts with Python nowhere on the request path.
//!
//! ## One workload abstraction
//!
//! The framework's generality is an API, not a slogan: the
//! [`workload::Workload`] trait describes how a domain decomposes a load
//! into tasks, and the planner, plan cache, execution surface, and
//! session are generic over it.  MoE
//! ([`moe::planner::MoeWorkload`]) and ragged batched attention decode
//! ([`workload::ragged::RaggedAttentionWorkload`]) both run through the
//! identical σ / ordering / TilePrefix machinery — `staticbatch ragged`
//! tabulates the second workload against its padded-dense baseline.
//!
//! ## One execution surface
//!
//! Everything that can run a static batch plan implements the
//! [`exec::Backend`] trait (generic over the workload, defaulting to
//! MoE), and every call site builds and executes plans through the
//! [`exec::ExecutionSession`] builder:
//!
//! ```
//! use staticbatch::exec::{ExecutionSession, SimBackend};
//! use staticbatch::moe::config::MoeShape;
//! use staticbatch::moe::routing::LoadScenario;
//! use staticbatch::sim::specs::GpuSpec;
//!
//! let shape = MoeShape::paper_table1();
//! let load = LoadScenario::Zipf(1.2).counts(&shape, 0);
//! // simulate on H800 ...
//! let sim = ExecutionSession::new(shape)
//!     .gpu(GpuSpec::h800())
//!     .backend(SimBackend::ours())
//!     .run(&load)
//!     .unwrap();
//! // ... or run real numerics on CPU: same session shape, one call changed
//! // (CpuBackend additionally needs `.inputs(...)` tensors).
//! assert!(sim.time_s() > 0.0);
//! println!("{}", sim.summary());
//! ```
//!
//! Available backends: [`exec::SimBackend`] (four mapping modes),
//! [`exec::CpuBackend`], the three baselines in [`baselines`], and
//! `runtime::PjrtBackend` (feature `pjrt`).  Device-function dispatch is
//! validated at construction by [`batching::dispatch::DispatchTable`]: a
//! task kind without a registered function is a build-time `Err`, exactly
//! like a missing `taskFunc_i` symbol at CUDA link time.
//!
//! ## One serving core
//!
//! The request path — admission queue → continuous batcher → plan cache →
//! execution → metrics → responses — is the backend-generic
//! [`serve::Server`], driven by a small [`serve::StepExecutor`] trait with
//! three instantiations: [`serve::SimStepExecutor`] (default features; CPU
//! numerics or accounting simulation through one [`exec::ExecutionSession`]
//! with an LRU [`serve::PlanCache`]), the expert-parallel
//! [`serve::ShardedStepExecutor`] (per-shard sessions and plan-cache lanes,
//! EP all-to-all / TP all-reduce accounting from [`moe::parallel`], and a
//! pluggable [`serve::PlacementKind`]), and the PJRT engine
//! (`coordinator::engine::Engine`, feature `pjrt`).  Explore it without a
//! GPU via `staticbatch serve-sim` (add `--ep 4 --placement balanced` for
//! the sharded path).
//!
//! Serving one batch through the single-shard executor, end to end:
//!
//! ```
//! use staticbatch::serve::{SimServeConfig, SimStepExecutor, StepExecutor, StepInput};
//!
//! let mut executor = SimStepExecutor::new(SimServeConfig {
//!     buckets: vec![8],
//!     max_tokens: 64,
//!     experts: 8,
//!     top_k: 2,
//!     d_model: 8,
//!     d_ff: 12,
//!     cache_capacity: 8,
//!     numeric: true,
//!     threads: 1,
//!     seed: 1,
//! });
//! let tokens: Vec<i32> = (0..16).collect(); // two requests padded to bucket 8
//! let step = StepInput { bucket: 8, rows: 2, tokens: &tokens };
//! let out = executor.execute_step(&step).unwrap();
//! assert_eq!(out.argmax.len(), 16);
//! // repeated load signatures hit the plan cache
//! executor.execute_step(&step).unwrap();
//! assert_eq!(executor.cache_stats().unwrap().hits, 1);
//! ```
//!
//! See `DESIGN.md` at the repository root for the architecture inventory
//! and the experiment index, and `README.md` for the quickstart.
//!
//! ## Feature flags
//!
//! * `pjrt` — enables the [`runtime`] module, the serving engine
//!   ([`coordinator::engine`]), and the XLA/PJRT-backed tests, benches and
//!   examples.  Off by default so the tier-1 suite builds and passes on
//!   machines without artifacts or a GPU.

pub mod baselines;
pub mod batching;
pub mod coordinator;
pub mod exec;
pub mod moe;
pub mod reports;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate version, reported by the CLI and the serving handshake.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
