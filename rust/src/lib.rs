//! # staticbatch — static batching of irregular workloads
//!
//! Production-quality reproduction of *"Static Batching of Irregular
//! Workloads on GPUs: Framework and Application to Efficient MoE Model
//! Inference"* (Alibaba Group, CS.DC 2025) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L1** — a Pallas kernel (`python/compile/kernels/moe_batched.py`)
//!   implementing the paper's fused, statically batched MoE expert GEMM with
//!   the compressed TilePrefix task mapping.
//! * **L2** — a JAX MoE transformer (`python/compile/model.py`) lowered
//!   ahead-of-time to HLO text artifacts.
//! * **L3** — this crate: the serving coordinator, the batching framework
//!   algorithms themselves ([`batching`]), a calibrated GPU execution
//!   simulator ([`sim`]) used to regenerate the paper's evaluation on
//!   H20/H800, baseline implementations ([`baselines`]), and the PJRT
//!   runtime ([`runtime`]) that executes the AOT artifacts with Python
//!   nowhere on the request path.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod baselines;
pub mod batching;
pub mod coordinator;
pub mod moe;
pub mod reports;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate version, reported by the CLI and the serving handshake.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
