//! The one bench harness all `cargo bench` targets drive backends through:
//! wallclock-times `ExecutionSession::run` (plan construction + backend
//! execution) with the shared warmup/percentile machinery in
//! [`crate::util::bench`], and returns the last [`Outcome`] so simulated
//! metrics can be reported next to host-side cost.

use crate::exec::backend::Outcome;
use crate::exec::error::ExecError;
use crate::exec::session::ExecutionSession;
use crate::util::bench::{self, Timing};
use crate::workload::Workload;

/// Wallclock-time `session.run(load)` (`warmup` + `iters` runs) for any
/// workload.  Returns the timing stats and the outcome of the final run.
pub fn time_session<W: Workload>(
    label: &str,
    session: &mut ExecutionSession<W>,
    load: &W::Load,
    warmup: usize,
    iters: usize,
) -> Result<(Timing, Outcome), ExecError> {
    // surface errors once, eagerly, instead of panicking inside the timer
    let mut last = session.run(load)?;
    let timing = bench::time(label, warmup, iters, || {
        last = session.run(load).expect("backend failed mid-bench after a successful probe run");
    });
    Ok((timing, last))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::config::MoeShape;
    use crate::moe::routing::LoadScenario;

    #[test]
    fn times_a_sim_session_and_returns_its_outcome() {
        let shape = MoeShape::tiny();
        let load = LoadScenario::Balanced.counts(&shape, 0);
        let mut s = ExecutionSession::new(shape);
        let (t, out) = time_session("tiny", &mut s, &load, 1, 3).expect("runs");
        assert_eq!(t.iters, 3);
        assert!(t.mean_ns > 0.0);
        assert_eq!(out.backend, "sim/ours");
    }
}
