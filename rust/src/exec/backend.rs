//! The [`Backend`] trait and its execution context / outcome types.
//!
//! Both are generic over the [`Workload`] being executed (defaulting to
//! [`MoeWorkload`] so MoE call sites read as before): an accounting
//! backend like [`crate::exec::SimBackend`] implements `Backend<W>` for
//! every workload, while numeric backends implement it per workload they
//! know how to compute — [`crate::exec::CpuBackend`] for MoE here and for
//! ragged attention in [`crate::workload::ragged`], the PJRT deployment
//! backend for MoE only.

use crate::batching::dispatch::DispatchRecord;
use crate::exec::error::ExecError;
use crate::moe::config::MoeShape;
use crate::moe::planner::MoeWorkload;
use crate::moe::routing::ExpertLoad;
use crate::moe::token_index::TokenIndex;
use crate::sim::specs::GpuSpec;
use crate::sim::trace::SimResult;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;
use crate::workload::plan::Plan;
use crate::workload::Workload;

/// Real tensors for one MoE step — required by numeric backends (CPU,
/// PJRT), ignored by accounting-only backends (simulator, baselines).
/// This is [`MoeWorkload`]'s `Inputs` type; ragged attention has its own
/// ([`crate::workload::ragged::RaggedInputs`]).
pub struct NumericInputs {
    /// `[seq, d_model]` original token sequence.
    pub tokens: Tensor,
    /// `[experts, d_model, d_ff]` expert weights.
    pub weights: Tensor,
    /// Token index arrays per expert (Section 4.3).
    pub token_index: TokenIndex,
    /// Combine gate per (expert, position) — aligned with `token_index`.
    pub gates: Vec<Vec<f32>>,
}

impl NumericInputs {
    /// Deterministic synthetic inputs for a routing outcome: random tokens
    /// and weights, token-index arrays consistent with `load`, and gates in
    /// `[0.25, 0.75)`.  Shared by the selftest and the cross-backend test
    /// suites so every numeric check runs the same input distribution.
    pub fn synthetic(shape: MoeShape, load: &ExpertLoad, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let tokens = Tensor::randn(&[shape.seq, shape.d_model], 1.0, &mut rng);
        let weights = Tensor::randn(&[shape.experts, shape.d_model, shape.d_ff], 0.1, &mut rng);
        let mut pairs = Vec::new();
        for (e, &c) in load.counts.iter().enumerate() {
            for _ in 0..c {
                pairs.push((rng.usize_below(shape.seq) as u32, e as u32));
            }
        }
        let token_index = TokenIndex::build(shape.experts, &pairs);
        let gates = token_index
            .index
            .iter()
            .map(|rows| rows.iter().map(|_| rng.f32() * 0.5 + 0.25).collect())
            .collect();
        NumericInputs { tokens, weights, token_index, gates }
    }
}

/// Everything a backend may need beyond the plan itself.
///
/// The same context type serves all backends of a workload; each consumes
/// the parts it needs and errors with [`ExecError::MissingInputs`] when a
/// required part is absent — so call sites wire up *one* structure
/// regardless of which backend runs.
pub struct ExecContext<'a, W: Workload = MoeWorkload> {
    /// Hardware model the accounting backends charge costs against.
    pub spec: GpuSpec,
    /// Real tensors for numeric backends (the workload's `Inputs` type).
    pub numeric: Option<&'a W::Inputs>,
    /// When set, backends that execute the plan's grid (sim, CPU,
    /// two-phase) record their per-block dispatch sequence in
    /// [`Outcome::trace`] (used by cross-backend agreement tests).
    /// Backends that re-schedule the work under their own tiling
    /// (grouped GEMM, naive loop, padded-dense) have no plan-shaped
    /// sequence to record and return `None`.
    pub record_dispatch: bool,
    /// Worker pool for numeric backends that can partition a plan's tasks
    /// across threads ([`crate::exec::CpuBackend`]).  `None` (or a 1-worker
    /// pool) means serial execution; parallel output is bitwise-equal to
    /// serial, so this is purely a speed knob.
    pub pool: Option<std::sync::Arc<crate::util::threadpool::ThreadPool>>,
}

impl<'a, W: Workload> ExecContext<'a, W> {
    /// A context with only a hardware model (accounting backends).
    pub fn new(spec: GpuSpec) -> Self {
        ExecContext { spec, numeric: None, record_dispatch: false, pool: None }
    }

    /// Attach real tensors (numeric backends).
    pub fn with_numeric(mut self, numeric: &'a W::Inputs) -> Self {
        self.numeric = Some(numeric);
        self
    }

    /// Ask the backend to record its per-block dispatch sequence.
    pub fn recording(mut self) -> Self {
        self.record_dispatch = true;
        self
    }

    /// Attach a worker pool for parallel numeric execution.
    pub fn with_pool(mut self, pool: std::sync::Arc<crate::util::threadpool::ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }
}

/// What one execution produced.  Fields are optional because backends are
/// heterogeneous: the simulator yields timings, numeric executors yield
/// tensors, and either may record a dispatch trace.
pub struct Outcome {
    /// Name of the backend that produced this outcome.
    pub backend: &'static str,
    /// Thread blocks (tiles) the backend launched for this plan.
    pub blocks: u32,
    /// Simulated timing/throughput (accounting backends).
    pub sim: Option<SimResult>,
    /// Numeric output (CPU: combined rows; PJRT: packed rows).
    pub output: Option<Tensor>,
    /// Per-block dispatch sequence, when requested via
    /// [`ExecContext::record_dispatch`].
    pub trace: Option<Vec<DispatchRecord>>,
}

impl Outcome {
    /// Simulated end-to-end seconds; panics if this backend is numeric-only.
    pub fn time_s(&self) -> f64 {
        self.sim.as_ref().expect("backend produced no simulated timing").time_s
    }

    /// The simulation result; panics if absent (numeric-only backends).
    pub fn sim(&self) -> &SimResult {
        self.sim.as_ref().expect("backend produced no simulated timing")
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        match &self.sim {
            Some(r) => format!("{}: {} ({} blocks)", self.backend, r.summary(), self.blocks),
            None => format!(
                "{}: {} blocks{}",
                self.backend,
                self.blocks,
                if self.output.is_some() { ", numeric output" } else { "" }
            ),
        }
    }
}

/// One typed execution surface for every way this crate can run a static
/// batch plan of workload `W`: roofline simulation, CPU numerics, the
/// paper's baselines, and (behind the `pjrt` feature) the AOT Pallas
/// kernel.
///
/// Backends are intentionally `&mut self`: real runtimes hold compiled
/// executables and device-resident buffers.
pub trait Backend<W: Workload = MoeWorkload> {
    /// Stable display name (`sim/ours`, `cpu`, `baseline/grouped-gemm`, ...).
    fn name(&self) -> &'static str;

    /// Execute `plan` and report what happened.
    fn execute(
        &mut self,
        plan: &Plan<W>,
        ctx: &mut ExecContext<'_, W>,
    ) -> Result<Outcome, ExecError>;
}

/// The dispatch sequence the fused kernel performs for `plan`: block index
/// → Algorithm 4 two-stage decode → (task, tile, kind).  This is the
/// ground truth accounting backends report when tracing is requested, for
/// any workload.
pub fn mapping_trace<W: Workload>(plan: &Plan<W>) -> Vec<DispatchRecord> {
    let descs = plan.descriptors();
    let mut mappings = Vec::new();
    plan.two_stage.map_all_into(&mut mappings);
    mappings
        .into_iter()
        .map(|m| DispatchRecord { task: m.task, tile: m.tile, kind: descs[m.task as usize].kind })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::config::MoeShape;
    use crate::moe::planner::Planner;
    use crate::moe::routing::LoadScenario;
    use crate::workload::ragged::{RaggedAttentionWorkload, RaggedLoad};

    #[test]
    fn mapping_trace_covers_every_block_in_order() {
        let shape = MoeShape::tiny();
        let load = LoadScenario::Worst.counts(&shape, 0);
        let plan = Planner::new(shape).plan(&load);
        let trace = mapping_trace(&plan);
        assert_eq!(trace.len() as u32, plan.total_tiles());
        // tiles within one task are consecutive and start at 0
        let mut seen_tiles = vec![0u32; plan.tasks.len()];
        for r in &trace {
            assert_eq!(r.tile, seen_tiles[r.task as usize]);
            seen_tiles[r.task as usize] += 1;
        }
    }

    #[test]
    fn mapping_trace_is_workload_generic() {
        let w = RaggedAttentionWorkload { heads: 2, head_dim: 8, dtype_bytes: 4 };
        let plan = crate::workload::plan::Planner::for_workload(w)
            .plan(&RaggedLoad { lens: vec![40, 0, 7] });
        let trace = mapping_trace(&plan);
        assert_eq!(trace.len() as u32, plan.total_tiles());
        let descs = plan.descriptors();
        for r in &trace {
            assert_eq!(r.kind, descs[r.task as usize].kind);
        }
    }

    #[test]
    fn outcome_summary_mentions_backend() {
        let o = Outcome { backend: "cpu", blocks: 7, sim: None, output: None, trace: None };
        assert!(o.summary().contains("cpu"));
        assert!(o.summary().contains('7'));
    }
}
