//! [`ExecutionSession`]: the one builder every call site uses to go from a
//! load to an executed plan — for any [`Workload`].
//!
//! ```text
//! ExecutionSession::new(shape)                 // MoE (the default workload)
//!     .ordering(OrderingStrategy::HalfInterval)
//!     .backend(SimBackend::ours())
//!     .gpu(GpuSpec::h800())
//!     .run(&load)?
//!
//! ExecutionSession::for_workload(ragged)       // any other Workload
//!     .backend(SimBackend::ours())
//!     .run(&ragged_load)?
//! ```
//!
//! The session owns plan construction (ordering + tiling policy → the
//! [`Planner`]) and the backend; `run` builds the plan (through the plan
//! cache when one is enabled) and an [`ExecContext`] and hands both to the
//! backend.  Swapping the executor — simulator, CPU numerics, a baseline,
//! the PJRT deployment path — is one builder call, with no other changes
//! at the call site.

use std::sync::Arc;

use crate::exec::backend::{Backend, ExecContext, Outcome};
use crate::exec::backends::SimBackend;
use crate::exec::error::ExecError;
use crate::moe::config::MoeShape;
use crate::moe::ordering::OrderingStrategy;
use crate::moe::planner::MoeWorkload;
use crate::moe::tiling::StrategyId;
use crate::sim::specs::GpuSpec;
use crate::util::threadpool::ThreadPool;
use crate::workload::cache::{CacheStats, PlanCache};
use crate::workload::plan::{Plan, Planner};
use crate::workload::Workload;

/// The one place a session's configuration becomes an [`ExecContext`] —
/// all run paths (owned backend, caller-owned backend) go through here.
fn make_ctx<'a, W: Workload>(
    spec: &GpuSpec,
    numeric: Option<&'a W::Inputs>,
    record_dispatch: bool,
    pool: Option<&Arc<ThreadPool>>,
) -> ExecContext<'a, W> {
    ExecContext { spec: spec.clone(), numeric, record_dispatch, pool: pool.cloned() }
}

/// Builder + runner for plan execution. See module docs.
pub struct ExecutionSession<W: Workload = MoeWorkload> {
    planner: Planner<W>,
    spec: GpuSpec,
    numeric: Option<W::Inputs>,
    record_dispatch: bool,
    backend: Box<dyn Backend<W>>,
    /// Optional LRU plan cache between routing and the planner; entries are
    /// valid for exactly this session's planner configuration, so any
    /// ordering/tiling change clears it.
    cache: Option<PlanCache<W>>,
    /// Optional worker pool threaded into every [`ExecContext`] so numeric
    /// backends partition tasks across threads (bitwise-equal to serial).
    pool: Option<Arc<ThreadPool>>,
}

impl ExecutionSession<MoeWorkload> {
    /// New MoE session for a problem shape. Defaults: half-interval
    /// ordering, per-task tiling, [`SimBackend::ours`] on H800, no plan
    /// cache.
    pub fn new(shape: MoeShape) -> Self {
        Self::for_workload(MoeWorkload::new(shape))
    }

    /// The MoE problem shape this session plans for.
    pub fn shape(&self) -> MoeShape {
        self.planner.workload().shape
    }
}

impl<W: Workload> ExecutionSession<W> {
    /// New session for any workload, same defaults as
    /// [`ExecutionSession::new`].
    pub fn for_workload(workload: W) -> Self {
        ExecutionSession {
            planner: Planner::for_workload(workload),
            spec: GpuSpec::h800(),
            numeric: None,
            record_dispatch: false,
            backend: Box::new(SimBackend::ours()),
            cache: None,
            pool: None,
        }
    }

    /// The workload this session plans for.
    pub fn workload(&self) -> &W {
        self.planner.workload()
    }

    /// Task ordering strategy (paper Section 4.2).  Clears the plan cache:
    /// cached plans are valid for exactly one planner configuration.
    pub fn ordering(mut self, ordering: OrderingStrategy) -> Self {
        self.planner.set_ordering(ordering);
        if let Some(c) = &mut self.cache {
            c.clear();
        }
        self
    }

    /// Force one tiling strategy for every task (grouped-GEMM style);
    /// default is per-task selection from the catalog.  Clears the plan
    /// cache, like [`Self::ordering`].
    pub fn tiling(mut self, strategy: StrategyId) -> Self {
        self.planner.set_force_strategy(Some(strategy));
        if let Some(c) = &mut self.cache {
            c.clear();
        }
        self
    }

    /// Cache built plans in an LRU of `capacity` entries keyed by the
    /// workload's load signature (per-expert counts for MoE, KV lengths
    /// for ragged attention), so repeated load shapes skip the σ /
    /// ordering / tiling / TilePrefix reconstruction on the hot path.
    pub fn plan_cache(mut self, capacity: usize) -> Self {
        self.cache = Some(PlanCache::new(capacity));
        self
    }

    /// Hit/miss counters of the plan cache, when one is enabled.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// The backend that will execute plans.
    pub fn backend(self, backend: impl Backend<W> + 'static) -> Self {
        self.boxed_backend(Box::new(backend))
    }

    /// Like [`Self::backend`], for already-boxed backends (registry loops).
    pub fn boxed_backend(mut self, backend: Box<dyn Backend<W>>) -> Self {
        self.backend = backend;
        self
    }

    /// GPU spec for accounting backends.
    pub fn gpu(mut self, spec: GpuSpec) -> Self {
        self.spec = spec;
        self
    }

    /// Attach real tensors for numeric backends (CPU, PJRT).
    pub fn inputs(mut self, numeric: W::Inputs) -> Self {
        self.numeric = Some(numeric);
        self
    }

    /// Replace (or drop) the numeric inputs on an already-built session —
    /// the per-step path for serving executors that stream new tensors
    /// through one long-lived session.
    pub fn set_inputs(&mut self, numeric: Option<W::Inputs>) {
        self.numeric = numeric;
    }

    /// Mutable access to the numeric inputs, when set.  The in-place
    /// alternative to [`Self::set_inputs`] for executors that stream new
    /// activations per step while the parts that never change (the serving
    /// analog of device-resident weights) stay put uncopied.
    pub fn inputs_mut(&mut self) -> Option<&mut W::Inputs> {
        self.numeric.as_mut()
    }

    /// Ask the backend to record its per-block dispatch sequence.
    pub fn record_dispatch(mut self) -> Self {
        self.record_dispatch = true;
        self
    }

    /// Execute numeric backends on `n` worker threads.  `n <= 1` keeps the
    /// serial path (no pool is spawned); parallel output is bitwise-equal
    /// to serial, so this only changes speed.
    pub fn threads(mut self, n: usize) -> Self {
        self.pool = if n > 1 { Some(Arc::new(ThreadPool::new(n))) } else { None };
        self
    }

    /// Share an existing worker pool (e.g. one pool across the per-shard
    /// sessions of a sharded executor) instead of spawning a fresh one.
    pub fn thread_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// The worker pool this session threads into execution, when set.
    pub fn pool(&self) -> Option<&Arc<ThreadPool>> {
        self.pool.as_ref()
    }

    /// Display name of the session's backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Build the static batch plan for a load (host-side work: σ,
    /// ordering, per-task tiling, compressed TilePrefix).  Always plans
    /// fresh; the cached path is [`Self::plan_shared`].
    pub fn plan(&self, load: &W::Load) -> Plan<W> {
        self.planner.plan(load)
    }

    /// Plan through the cache when one is enabled (shared `Arc` on hits),
    /// falling back to a fresh build otherwise.
    pub fn plan_shared(&mut self, load: &W::Load) -> Arc<Plan<W>> {
        match &mut self.cache {
            Some(c) => c.get_or_plan(&self.planner, load),
            None => Arc::new(self.planner.plan(load)),
        }
    }

    /// Plan + execute one load on the session's backend.
    pub fn run(&mut self, load: &W::Load) -> Result<Outcome, ExecError> {
        let plan = self.plan_shared(load);
        self.run_plan(plan.as_ref())
    }

    /// Execute an already-built plan on the session's backend.
    pub fn run_plan(&mut self, plan: &Plan<W>) -> Result<Outcome, ExecError> {
        // field-level borrows: ctx borrows `numeric`, execute borrows `backend`
        let mut ctx =
            make_ctx(&self.spec, self.numeric.as_ref(), self.record_dispatch, self.pool.as_ref());
        self.backend.execute(plan, &mut ctx)
    }

    /// Execute through a caller-owned backend (for backends that borrow
    /// non-`'static` state, e.g. a PJRT executor pool).  Plans through the
    /// session's plan cache exactly like [`Self::run`] — this path used to
    /// bypass it, replanning fresh on every call even with a cache
    /// enabled.
    pub fn run_on(
        &mut self,
        backend: &mut dyn Backend<W>,
        load: &W::Load,
    ) -> Result<Outcome, ExecError> {
        let plan = self.plan_shared(load);
        let mut ctx =
            make_ctx(&self.spec, self.numeric.as_ref(), self.record_dispatch, self.pool.as_ref());
        backend.execute(plan.as_ref(), &mut ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::backends::CpuBackend;
    use crate::exec::backend::NumericInputs;
    use crate::moe::routing::LoadScenario;

    #[test]
    fn default_session_simulates() {
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Balanced.counts(&shape, 0);
        let mut s = ExecutionSession::new(shape);
        assert_eq!(s.backend_name(), "sim/ours");
        let out = s.run(&load).expect("sim runs");
        assert!(out.time_s() > 0.0);
        assert_eq!(out.blocks, s.plan(&load).total_tiles());
    }

    #[test]
    fn session_drives_cpu_backend_with_inputs() {
        let shape = MoeShape::tiny();
        let load = LoadScenario::Dirichlet(1.0).counts(&shape, 3);
        let numeric = NumericInputs::synthetic(shape, &load, 1);
        let mut s = ExecutionSession::new(shape).backend(CpuBackend).inputs(numeric);
        let out = s.run(&load).expect("cpu runs");
        let t = out.output.expect("numeric output");
        assert_eq!(t.shape, vec![shape.seq, shape.d_ff]);
    }

    #[test]
    fn cached_session_skips_replanning_on_repeated_loads() {
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Zipf(1.1).counts(&shape, 2);
        let mut s = ExecutionSession::new(shape).plan_cache(4);
        let a = s.run(&load).expect("run 1");
        let b = s.run(&load).expect("run 2");
        assert_eq!(a.blocks, b.blocks);
        let stats = s.cache_stats().expect("cache enabled");
        assert_eq!((stats.hits, stats.misses), (1, 1));
        // the cached plan is exactly what a fresh build produces
        let cached = s.plan_shared(&load);
        assert_eq!(*cached, s.plan(&load));
    }

    #[test]
    fn run_on_routes_through_the_plan_cache() {
        // regression: run_on used to always plan fresh, so a caller-owned
        // backend never benefited from an enabled cache
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Zipf(1.3).counts(&shape, 4);
        let mut s = ExecutionSession::new(shape).plan_cache(4);
        let mut backend = SimBackend::per_block_array();
        s.run_on(&mut backend, &load).expect("run_on 1");
        s.run_on(&mut backend, &load).expect("run_on 2");
        let stats = s.cache_stats().expect("cache enabled");
        assert_eq!(
            (stats.hits, stats.misses),
            (1, 1),
            "second run_on must hit the cache, not replan"
        );
        // and the owned-backend path shares the same cache lane
        s.run(&load).expect("run 3");
        assert_eq!(s.cache_stats().unwrap().hits, 2);
    }

    #[test]
    fn session_ordering_and_tiling_flow_into_the_plan() {
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Worst.counts(&shape, 0);
        let s = ExecutionSession::new(shape)
            .ordering(OrderingStrategy::Natural)
            .tiling(0);
        let plan = s.plan(&load);
        assert!(plan.tasks.iter().all(|t| t.strategy == 0));
        // natural ordering: non-empty experts ascend
        let nonempty: Vec<u32> =
            plan.tasks.iter().filter(|t| t.rows > 0).map(|t| t.expert).collect();
        let mut sorted = nonempty.clone();
        sorted.sort_unstable();
        assert_eq!(nonempty, sorted);
    }

    #[test]
    fn reconfiguring_a_cached_session_invalidates_entries() {
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Zipf(1.2).counts(&shape, 7);
        let mut s = ExecutionSession::new(shape).plan_cache(4);
        s.run(&load).expect("warm the cache");
        // ordering change must clear the cache (same signature, different plan)
        let mut s = s.ordering(OrderingStrategy::Natural);
        s.run(&load).expect("replan after reconfigure");
        let stats = s.cache_stats().expect("cache enabled");
        assert_eq!(
            (stats.hits, stats.misses),
            (0, 2),
            "a reconfigured session must never serve a stale plan"
        );
    }
}
