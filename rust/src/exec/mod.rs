//! The unified execution API: one typed surface for every executor.
//!
//! The paper's central claim is that *one* static batching framework
//! (two-stage mapping + per-task dispatch) drives heterogeneous workloads
//! through a single kernel entry point.  This module is the Rust-side
//! mirror of that claim: every way the crate can execute a
//! [`Plan`](crate::workload::plan::Plan) of any
//! [`Workload`](crate::workload::Workload) — the calibrated roofline
//! simulator, the CPU numeric executors, the three paper baselines, and
//! (behind the `pjrt` feature) the AOT Pallas kernel — sits behind the
//! same [`Backend`] trait, and every call site builds and runs plans
//! through one [`ExecutionSession`] builder.  `Backend`, `ExecContext`,
//! and `ExecutionSession` default their workload parameter to
//! [`MoeWorkload`](crate::moe::planner::MoeWorkload), so the MoE surface
//! reads exactly as before; `ExecutionSession::for_workload` opens the
//! same builder for any other workload (e.g.
//! [`crate::workload::ragged::RaggedAttentionWorkload`]):
//!
//! ```
//! use staticbatch::exec::{ExecutionSession, SimBackend};
//! use staticbatch::moe::config::MoeShape;
//! use staticbatch::moe::ordering::OrderingStrategy;
//! use staticbatch::moe::routing::LoadScenario;
//! use staticbatch::sim::specs::GpuSpec;
//!
//! let shape = MoeShape::paper_table1();
//! let load = LoadScenario::Worst.counts(&shape, 0);
//! let outcome = ExecutionSession::new(shape)
//!     .ordering(OrderingStrategy::HalfInterval)
//!     .backend(SimBackend::ours())
//!     .gpu(GpuSpec::h800())
//!     .run(&load)
//!     .unwrap();
//! assert!(outcome.time_s() > 0.0);
//! println!("{}", outcome.summary());
//! ```
//!
//! Errors are typed ([`ExecError`]); in particular a batch whose task kind
//! has no registered device function fails at *construction* (the
//! [`crate::batching::dispatch::DispatchTable`] build step), mirroring a
//! missing `taskFunc_i` symbol at CUDA link time — not mid-launch.

pub mod backend;
pub mod backends;
pub mod bench;
pub mod error;
pub mod session;

pub use backend::{Backend, ExecContext, mapping_trace, NumericInputs, Outcome};
pub use backends::{CpuBackend, SimBackend, SimMode};
pub use error::ExecError;
pub use session::ExecutionSession;

// plan-cache types, re-exported for `ExecutionSession::plan_cache` callers
// (the MoE instantiation; the generic cache is `crate::workload::cache`)
pub use crate::moe::plan_cache::{CacheStats, PlanCache};

use crate::baselines::{GroupedGemm, NaiveLoop, TwoPhase};

/// The comparison registry: our kernel (simulated) first, then the three
/// baselines — everything the A1/sweep experiments iterate over, behind
/// one trait.
pub fn all_backends() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(SimBackend::ours()),
        Box::new(GroupedGemm),
        Box::new(TwoPhase),
        Box::new(NaiveLoop),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::config::MoeShape;
    use crate::moe::routing::LoadScenario;

    #[test]
    fn registry_has_four_backends_with_unique_names() {
        let names: Vec<&str> = all_backends().iter().map(|b| b.name()).collect();
        assert_eq!(names.len(), 4);
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 4, "names must be unique: {names:?}");
        assert_eq!(names[0], "sim/ours");
    }

    #[test]
    fn every_registry_backend_executes_the_same_plan() {
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Worst.counts(&shape, 0);
        for b in all_backends() {
            let mut s = ExecutionSession::new(shape).boxed_backend(b);
            let out = s.run(&load).expect("accounting backends need no inputs");
            assert!(out.time_s() > 0.0, "{}", out.backend);
        }
    }
}
