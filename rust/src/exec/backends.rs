//! First-party backends: the roofline simulator (four mapping modes,
//! workload-generic) and the CPU numeric executor.  The paper's baselines
//! implement [`Backend`] in [`crate::baselines`]; the PJRT deployment
//! backend lives in [`crate::runtime`] behind the `pjrt` feature.

use crate::exec::backend::{mapping_trace, Backend, ExecContext, Outcome};
use crate::exec::error::ExecError;
use crate::moe::cpu_exec;
use crate::moe::planner::MoeWorkload;
use crate::sim::kernel_sim;
use crate::workload::plan::Plan;
use crate::workload::Workload;

/// Which mapping mechanism the simulator charges for (experiments A2/A4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimMode {
    /// Compressed TilePrefix + σ, warp-vote decode (Algorithms 1/2/4).
    Ours,
    /// Full per-block mapping array (PPoPP'19 [10] style decode).
    PerBlockArray,
    /// Dense mapping over all N tasks — no σ compaction (ablation A4).
    DenseMapping,
    /// Empty tasks padded to one dummy tile each (the no-Algorithm-4
    /// strawman; ablation A4).
    PaddedEmpty,
}

/// The calibrated GPU execution simulator as a [`Backend`].  Purely
/// accounting, so one implementation serves *every* [`Workload`] — the
/// workload supplies its tile cost stream via
/// [`Workload::tiles`](crate::workload::Workload::tiles).
pub struct SimBackend {
    mode: SimMode,
}

impl SimBackend {
    /// A simulator charging the given mapping mechanism's overheads.
    pub fn new(mode: SimMode) -> Self {
        SimBackend { mode }
    }

    /// The paper's mechanism: compressed TilePrefix + σ ([`SimMode::Ours`]).
    pub fn ours() -> Self {
        Self::new(SimMode::Ours)
    }

    /// Per-block mapping array ablation ([`SimMode::PerBlockArray`]).
    pub fn per_block_array() -> Self {
        Self::new(SimMode::PerBlockArray)
    }

    /// No-σ dense mapping ablation ([`SimMode::DenseMapping`]).
    pub fn dense_mapping() -> Self {
        Self::new(SimMode::DenseMapping)
    }

    /// Padded-empty-task ablation ([`SimMode::PaddedEmpty`]).
    pub fn padded_empty() -> Self {
        Self::new(SimMode::PaddedEmpty)
    }

    /// The mapping mode this simulator charges for.
    pub fn mode(&self) -> SimMode {
        self.mode
    }
}

impl<W: Workload> Backend<W> for SimBackend {
    fn name(&self) -> &'static str {
        match self.mode {
            SimMode::Ours => "sim/ours",
            SimMode::PerBlockArray => "sim/per-block-array",
            SimMode::DenseMapping => "sim/dense-mapping",
            SimMode::PaddedEmpty => "sim/padded-empty",
        }
    }

    fn execute(
        &mut self,
        plan: &Plan<W>,
        ctx: &mut ExecContext<'_, W>,
    ) -> Result<Outcome, ExecError> {
        let sim = match self.mode {
            SimMode::Ours => kernel_sim::simulate_ours(plan, &ctx.spec),
            SimMode::PerBlockArray => kernel_sim::simulate_per_block_array(plan, &ctx.spec),
            SimMode::DenseMapping => kernel_sim::simulate_dense_mapping(plan, &ctx.spec),
            SimMode::PaddedEmpty => kernel_sim::simulate_padded_empty(plan, &ctx.spec),
        };
        let trace = ctx.record_dispatch.then(|| mapping_trace(plan));
        Ok(Outcome {
            backend: <Self as Backend<W>>::name(self),
            blocks: plan.total_tiles(),
            sim: Some(sim),
            output: None,
            trace,
        })
    }
}

/// The CPU numeric executor as a [`Backend`]: runs the plan *through the
/// framework dispatch* on real tensors and returns combined outputs.
/// Implemented per workload it can compute — for MoE here (expert GEMMs +
/// gated combine; requires [`ExecContext::numeric`]) and for ragged
/// attention in [`crate::workload::ragged`] (flash-decode numerics).
pub struct CpuBackend;

impl Backend<MoeWorkload> for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn execute(
        &mut self,
        plan: &Plan<MoeWorkload>,
        ctx: &mut ExecContext<'_, MoeWorkload>,
    ) -> Result<Outcome, ExecError> {
        let n = ctx
            .numeric
            .ok_or(ExecError::MissingInputs { backend: "cpu", what: "numeric inputs" })?;
        let inputs = cpu_exec::MoeInputs {
            tokens: &n.tokens,
            weights: &n.weights,
            token_index: &n.token_index,
            gates: &n.gates,
        };
        // Parallel when a multi-worker pool is attached and no dispatch
        // trace was requested (the trace is inherently a serial grid walk).
        // Output is bitwise-equal either way.
        let (output, trace) = match &ctx.pool {
            Some(pool) if pool.workers() > 1 && !ctx.record_dispatch => {
                (cpu_exec::execute_parallel(plan, &inputs, pool)?, None)
            }
            _ => cpu_exec::execute_traced(plan, &inputs, ctx.record_dispatch)?,
        };
        Ok(Outcome {
            backend: "cpu",
            blocks: plan.total_tiles(),
            sim: None,
            output: Some(output),
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::config::MoeShape;
    use crate::moe::planner::Planner;
    use crate::moe::routing::LoadScenario;
    use crate::sim::specs::GpuSpec;
    use crate::workload::ragged::{RaggedAttentionWorkload, RaggedLoad};

    #[test]
    fn sim_backend_matches_direct_kernel_sim() {
        let shape = MoeShape::paper_table1();
        let plan = Planner::new(shape).plan(&LoadScenario::Worst.counts(&shape, 0));
        let direct = kernel_sim::simulate_ours(&plan, &GpuSpec::h800());
        let mut ctx = ExecContext::new(GpuSpec::h800());
        let out = SimBackend::ours().execute(&plan, &mut ctx).unwrap();
        assert_eq!(out.time_s(), direct.time_s);
        assert_eq!(out.blocks, plan.total_tiles());
        assert!(out.trace.is_none());
    }

    #[test]
    fn sim_backend_records_trace_when_asked() {
        let shape = MoeShape::tiny();
        let plan = Planner::new(shape).plan(&LoadScenario::Balanced.counts(&shape, 0));
        let mut ctx = ExecContext::new(GpuSpec::h20()).recording();
        let out = SimBackend::ours().execute(&plan, &mut ctx).unwrap();
        let trace = out.trace.expect("trace requested");
        assert_eq!(trace.len() as u32, plan.total_tiles());
    }

    #[test]
    fn sim_backend_is_workload_generic() {
        // the same SimBackend value type executes a ragged-attention plan
        let w = RaggedAttentionWorkload { heads: 4, head_dim: 16, dtype_bytes: 2 };
        let plan = crate::workload::plan::Planner::for_workload(w)
            .plan(&RaggedLoad { lens: vec![600, 0, 31, 4] });
        let mut ctx = ExecContext::new(GpuSpec::h800());
        let out = SimBackend::ours().execute(&plan, &mut ctx).unwrap();
        assert_eq!(out.blocks, plan.total_tiles());
        assert!(out.time_s() > 0.0);
    }

    #[test]
    fn cpu_backend_without_inputs_is_typed_error() {
        let shape = MoeShape::tiny();
        let plan = Planner::new(shape).plan(&LoadScenario::Balanced.counts(&shape, 0));
        let mut ctx = ExecContext::new(GpuSpec::h20());
        let err = CpuBackend.execute(&plan, &mut ctx).unwrap_err();
        assert!(matches!(err, ExecError::MissingInputs { backend: "cpu", .. }));
    }
}
