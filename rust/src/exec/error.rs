//! Typed errors for the unified execution surface.

use crate::batching::dispatch::DispatchError;

/// Why a backend could not execute a plan.
#[derive(Debug)]
pub enum ExecError {
    /// Dispatch-table construction failed (unregistered kind / duplicate).
    Dispatch(DispatchError),
    /// The backend needs inputs the [`crate::exec::ExecContext`] does not
    /// carry (e.g. the CPU executor without tensors).
    MissingInputs { backend: &'static str, what: &'static str },
    /// The plan is incompatible with the backend's compiled configuration
    /// (e.g. a PJRT artifact built for different static dims).
    PlanMismatch { backend: &'static str, detail: String },
    /// Backend-internal failure (runtime errors, artifact I/O, ...).
    Backend { backend: &'static str, detail: String },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Dispatch(e) => write!(f, "dispatch table: {e}"),
            ExecError::MissingInputs { backend, what } => {
                write!(f, "{backend}: execution context is missing {what}")
            }
            ExecError::PlanMismatch { backend, detail } => {
                write!(f, "{backend}: plan incompatible with backend: {detail}")
            }
            ExecError::Backend { backend, detail } => write!(f, "{backend}: {detail}"),
        }
    }
}

impl std::error::Error for ExecError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ExecError::Dispatch(e) => Some(e),
            _ => None,
        }
    }
}

impl From<DispatchError> for ExecError {
    fn from(e: DispatchError) -> Self {
        ExecError::Dispatch(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::task::TaskKind;

    #[test]
    fn display_carries_backend_and_cause() {
        let e = ExecError::MissingInputs { backend: "cpu", what: "numeric inputs" };
        assert!(e.to_string().contains("cpu"));
        let d: ExecError =
            DispatchError::Unregistered { kind: TaskKind::ReduceSum, task_index: 3 }.into();
        assert!(d.to_string().contains("no device function registered"));
    }
}
