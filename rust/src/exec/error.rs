//! Typed errors for the unified execution surface.
//!
//! Every error carries a transient/permanent classification
//! ([`ExecError::is_transient`]): transient failures ([`ExecError::Timeout`],
//! [`ExecError::ShardDown`]) are worth retrying — the condition can clear on
//! its own or through placement action (shard evacuation) — while permanent
//! failures (bad plan, missing inputs, a panicked worker) will fail the same
//! way again and must be surfaced, not retried.  The serving layer's retry
//! policy and the sharded executor's circuit breakers are driven entirely by
//! this classification.

use crate::batching::dispatch::DispatchError;

/// Why a backend could not execute a plan.
#[derive(Debug)]
pub enum ExecError {
    /// Dispatch-table construction failed (unregistered kind / duplicate).
    Dispatch(DispatchError),
    /// The backend needs inputs the [`crate::exec::ExecContext`] does not
    /// carry (e.g. the CPU executor without tensors).
    MissingInputs { backend: &'static str, what: &'static str },
    /// The plan is incompatible with the backend's compiled configuration
    /// (e.g. a PJRT artifact built for different static dims).
    PlanMismatch { backend: &'static str, detail: String },
    /// Backend-internal failure (runtime errors, artifact I/O, ...).
    /// `source` preserves the structured cause when one exists (e.g. a
    /// [`crate::util::threadpool::PoolError`] from a panicked worker), so
    /// callers can classify by downcast instead of string-matching.
    Backend {
        backend: &'static str,
        detail: String,
        source: Option<Box<dyn std::error::Error + Send + Sync>>,
    },
    /// The step ran out of time.  Transient: the same batch can succeed on
    /// a retry once the stall clears.
    Timeout { backend: &'static str, detail: String },
    /// One shard failed mid-step.  Transient: a retry can succeed after the
    /// placement layer evacuates the shard (circuit breaker / fault plan).
    ShardDown { backend: &'static str, shard: usize, detail: String },
}

impl ExecError {
    /// A [`ExecError::Backend`] with no structured cause.
    pub fn backend(backend: &'static str, detail: impl Into<String>) -> Self {
        ExecError::Backend { backend, detail: detail.into(), source: None }
    }

    /// A [`ExecError::Backend`] preserving its structured cause, reachable
    /// through [`std::error::Error::source`].
    pub fn backend_caused(
        backend: &'static str,
        detail: impl Into<String>,
        cause: impl std::error::Error + Send + Sync + 'static,
    ) -> Self {
        ExecError::Backend { backend, detail: detail.into(), source: Some(Box::new(cause)) }
    }

    /// Whether a retry of the same step is worth attempting.  Timeouts and
    /// shard failures are transient (the condition can clear, or placement
    /// can route around it); everything else — including worker panics,
    /// which surface as [`ExecError::Backend`] with a
    /// [`crate::util::threadpool::PoolError`] source — is permanent and
    /// must not be retried.
    pub fn is_transient(&self) -> bool {
        matches!(self, ExecError::Timeout { .. } | ExecError::ShardDown { .. })
    }

    /// The shard a failure is attributable to, when it names one.  Drives
    /// the sharded executor's per-shard circuit breakers.
    pub fn shard(&self) -> Option<usize> {
        match self {
            ExecError::ShardDown { shard, .. } => Some(*shard),
            _ => None,
        }
    }
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Dispatch(e) => write!(f, "dispatch table: {e}"),
            ExecError::MissingInputs { backend, what } => {
                write!(f, "{backend}: execution context is missing {what}")
            }
            ExecError::PlanMismatch { backend, detail } => {
                write!(f, "{backend}: plan incompatible with backend: {detail}")
            }
            ExecError::Backend { backend, detail, .. } => write!(f, "{backend}: {detail}"),
            ExecError::Timeout { backend, detail } => {
                write!(f, "{backend}: step timed out: {detail}")
            }
            ExecError::ShardDown { backend, shard, detail } => {
                write!(f, "{backend}: shard {shard} down: {detail}")
            }
        }
    }
}

impl std::error::Error for ExecError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ExecError::Dispatch(e) => Some(e),
            ExecError::Backend { source: Some(s), .. } => {
                Some(s.as_ref() as &(dyn std::error::Error + 'static))
            }
            _ => None,
        }
    }
}

impl From<DispatchError> for ExecError {
    fn from(e: DispatchError) -> Self {
        ExecError::Dispatch(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::task::TaskKind;
    use crate::util::threadpool::PoolError;

    #[test]
    fn display_carries_backend_and_cause() {
        let e = ExecError::MissingInputs { backend: "cpu", what: "numeric inputs" };
        assert!(e.to_string().contains("cpu"));
        let d: ExecError =
            DispatchError::Unregistered { kind: TaskKind::ReduceSum, task_index: 3 }.into();
        assert!(d.to_string().contains("no device function registered"));
    }

    #[test]
    fn taxonomy_splits_transient_from_permanent() {
        assert!(ExecError::Timeout { backend: "sim", detail: "stall".into() }.is_transient());
        let down = ExecError::ShardDown { backend: "sim", shard: 2, detail: "nic".into() };
        assert!(down.is_transient());
        assert_eq!(down.shard(), Some(2));
        assert!(!ExecError::backend("cpu", "boom").is_transient());
        assert!(
            !ExecError::PlanMismatch { backend: "cpu", detail: "dims".into() }.is_transient()
        );
        assert!(
            !ExecError::MissingInputs { backend: "cpu", what: "tensors" }.is_transient()
        );
        assert_eq!(ExecError::backend("cpu", "boom").shard(), None);
    }

    #[test]
    fn worker_panic_keeps_its_structured_source_and_stays_permanent() {
        use std::error::Error;
        // the satellite pin: a panicked pool worker must never be
        // classified transient, and the PoolError cause must survive as a
        // downcastable source instead of being flattened into the string
        let e = ExecError::backend_caused("cpu", "worker pool failure", PoolError::WorkerPanicked);
        assert!(!e.is_transient(), "a worker panic is permanent: never retry it");
        let src = e.source().expect("structured cause preserved");
        let pool = src.downcast_ref::<PoolError>().expect("source downcasts to PoolError");
        assert_eq!(*pool, PoolError::WorkerPanicked);
        assert!(e.to_string().contains("worker pool failure"));
    }
}
