//! Simulate a statically batched fused kernel on a GPU spec — for any
//! [`Workload`].
//!
//! Converts a [`Plan`] into the tile stream the fused kernel would launch
//! (grid order = plan order; each workload expands its own tasks via
//! [`Workload::tiles`]) and runs it through the wave model with the chosen
//! mapping mode's overheads.  MoE plans reproduce the paper's performance
//! experiments; ragged-attention plans run through the *same* four mapping
//! modes, because the mapping mechanism never looks inside a task.

use crate::sim::cost::TileWork;
use crate::sim::overhead::MappingMode;
use crate::sim::specs::GpuSpec;
use crate::sim::trace::SimResult;
use crate::sim::wave;
use crate::workload::plan::Plan;
use crate::workload::Workload;

/// Warp passes Algorithm 2 needs for the tile of the `h`-th non-empty task.
fn warp_passes_for_task(h: usize) -> usize {
    h / crate::batching::warp::WARP_SIZE + 1
}

/// Expand the plan into its tile stream. `decode_ns_for_task(h)` supplies
/// the per-block decode overhead (h = position among non-empty tasks).
pub fn tiles_for_plan<W: Workload, F: Fn(usize) -> f64>(
    plan: &Plan<W>,
    decode_ns_for_task: F,
) -> Vec<TileWork> {
    let mut tiles = Vec::new();
    let mut h = 0usize;
    for (ti, task) in plan.tasks.iter().enumerate() {
        if plan.workload.descriptor(task).num_tiles() == 0 {
            continue;
        }
        tiles.extend(plan.workload.tiles(task, ti as u32, decode_ns_for_task(h)));
        h += 1;
    }
    tiles
}

/// Total operand bytes (used as L2 pressure for the cache models).
pub fn operand_bytes<W: Workload>(plan: &Plan<W>) -> f64 {
    plan.workload.operand_bytes(&plan.tasks)
}

/// Our kernel: compressed TilePrefix + σ, warp-vote decode (Alg. 2/4).
pub fn simulate_ours<W: Workload>(plan: &Plan<W>, spec: &GpuSpec) -> SimResult {
    let metadata_len = plan.two_stage.tile_prefix.len() + plan.two_stage.sigma.len();
    let mode = MappingMode::CompressedPrefix { metadata_len, warp_passes: 1 };
    let warp_ns = spec.warp_pass_ns;
    let tiles = tiles_for_plan(plan, |h| warp_ns * warp_passes_for_task(h) as f64);
    let host = mode.host_time_s(spec) + mode.launch_time_s(spec);
    wave::run_waves(&tiles, spec, host)
}

/// Our kernel but decoded through a full per-block mapping array
/// (PPoPP'19 [10] style) — isolates the mapping mechanism (experiment A2).
pub fn simulate_per_block_array<W: Workload>(plan: &Plan<W>, spec: &GpuSpec) -> SimResult {
    let blocks = plan.total_tiles() as usize;
    let mode = MappingMode::PerBlockArray { blocks };
    let pressure = operand_bytes(plan);
    let decode = mode.decode_ns(spec, pressure);
    let tiles = tiles_for_plan(plan, |_| decode);
    let host = mode.host_time_s(spec) + mode.launch_time_s(spec);
    wave::run_waves(&tiles, spec, host)
}

/// A "no-elision" variant: empty tasks keep a mapping slot (the dense
/// Algorithm 2 over all N tasks). Decode scans all N, and σ is skipped.
/// Used by the empty-task ablation (A4).
pub fn simulate_dense_mapping<W: Workload>(plan: &Plan<W>, spec: &GpuSpec) -> SimResult {
    let n = plan.tasks.len(); // all tasks, empty included
    let warp_ns = spec.warp_pass_ns;
    // every block scans the full N-entry prefix (no early-out benefit of
    // compaction); passes = ceil(N/32) in the worst case — charge the mean
    // position like the compressed variant for fairness
    let tiles = tiles_for_plan(plan, |h| {
        let _ = h;
        warp_ns * (n as f64 / crate::batching::warp::WARP_SIZE as f64).ceil()
    });
    let mode = MappingMode::CompressedPrefix { metadata_len: n, warp_passes: 1 };
    let host = mode.host_time_s(spec) + mode.launch_time_s(spec);
    wave::run_waves(&tiles, spec, host)
}

/// The no-Algorithm-4 strawman a static scheme needs without σ: every empty
/// task is padded to one tile so the dense mapping stays invertible.  The
/// padding tiles compute nothing but still stage their operand slice from
/// HBM and occupy block slots — the waste Section 4.1 eliminates.  The
/// padding tile's cost derives from the task's descriptor (tile shape ×
/// inner dim), which for MoE is exactly one dummy GEMM tile.
pub fn simulate_padded_empty<W: Workload>(plan: &Plan<W>, spec: &GpuSpec) -> SimResult {
    let n = plan.tasks.len();
    let warp_ns = spec.warp_pass_ns;
    let passes = (n as f64 / crate::batching::warp::WARP_SIZE as f64).ceil();
    let mut tiles = tiles_for_plan(plan, |_| warp_ns * passes);
    let ds = plan.workload.dtype().bytes() as f64;
    for (ti, task) in plan.tasks.iter().enumerate() {
        let d = plan.workload.descriptor(task);
        if d.num_tiles() > 0 {
            continue;
        }
        tiles.push(TileWork {
            task: ti as u32,
            m_tile: 0,
            n_tile: 0,
            useful_flops: 0.0,
            // the compute units still cycle through the padded tile
            occupied_flops: 2.0 * d.tile_rows as f64 * d.tile_cols as f64 * d.inner as f64,
            weight_bytes: d.inner as f64 * d.tile_cols as f64 * ds,
            token_bytes: d.tile_rows as f64 * d.inner as f64 * ds,
            out_bytes: 0.0,
            decode_ns: warp_ns * passes,
        });
    }
    let mode = MappingMode::CompressedPrefix { metadata_len: n, warp_passes: 1 };
    let host = mode.host_time_s(spec) + mode.launch_time_s(spec);
    wave::run_waves(&tiles, spec, host)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::config::MoeShape;
    use crate::moe::planner::{ExecutionPlan, Planner};
    use crate::moe::routing::LoadScenario;

    #[test]
    fn padded_empty_never_faster_and_wasteful_with_many_empties() {
        let shape = MoeShape::paper_table1();
        let plan = Planner::new(shape).plan(&LoadScenario::Best.counts(&shape, 0));
        let ours = simulate_ours(&plan, &GpuSpec::h800());
        let padded = simulate_padded_empty(&plan, &GpuSpec::h800());
        assert!(padded.time_s >= ours.time_s);
        assert!(padded.padding_waste() > ours.padding_waste());
    }

    fn plan_for(sc: LoadScenario) -> ExecutionPlan {
        Planner::new(MoeShape::paper_table1()).plan(&sc.counts(&MoeShape::paper_table1(), 0))
    }

    #[test]
    fn tile_stream_matches_mapping_block_count() {
        let plan = plan_for(LoadScenario::Worst);
        let tiles = tiles_for_plan(&plan, |_| 0.0);
        assert_eq!(tiles.len() as u32, plan.total_tiles());
    }

    #[test]
    fn h20_balanced_hits_paper_ballpark() {
        // Paper Table 1: H20 balanced = 94.67% of peak.
        let r = simulate_ours(&plan_for(LoadScenario::Balanced), &GpuSpec::h20());
        assert!(
            r.peak_frac > 0.88 && r.peak_frac < 1.0,
            "H20 balanced peak% = {:.2}",
            r.peak_frac * 100.0
        );
    }

    #[test]
    fn h800_balanced_above_three_quarters() {
        // Paper: 84.82%.
        let r = simulate_ours(&plan_for(LoadScenario::Balanced), &GpuSpec::h800());
        assert!(
            r.peak_frac > 0.70 && r.peak_frac < 0.98,
            "H800 balanced peak% = {:.2}",
            r.peak_frac * 100.0
        );
    }

    #[test]
    fn h800_worst_degrades_much_more_than_h20() {
        // Paper: H800 drops to 59%, H20 only to 90%.
        let worst_h800 = simulate_ours(&plan_for(LoadScenario::Worst), &GpuSpec::h800());
        let worst_h20 = simulate_ours(&plan_for(LoadScenario::Worst), &GpuSpec::h20());
        let bal_h800 = simulate_ours(&plan_for(LoadScenario::Balanced), &GpuSpec::h800());
        let bal_h20 = simulate_ours(&plan_for(LoadScenario::Balanced), &GpuSpec::h20());
        let drop_h800 = worst_h800.peak_frac / bal_h800.peak_frac;
        let drop_h20 = worst_h20.peak_frac / bal_h20.peak_frac;
        assert!(drop_h800 < drop_h20, "H800 must degrade more: {drop_h800} vs {drop_h20}");
        assert!(drop_h20 > 0.85, "H20 worst should stay near balanced: {drop_h20}");
    }

    #[test]
    fn per_block_array_never_faster() {
        for sc in [LoadScenario::Balanced, LoadScenario::Worst] {
            let plan = plan_for(sc);
            let ours = simulate_ours(&plan, &GpuSpec::h800());
            let arr = simulate_per_block_array(&plan, &GpuSpec::h800());
            assert!(arr.time_s >= ours.time_s, "{sc:?}");
        }
    }

    #[test]
    fn dense_mapping_never_faster_with_many_empties() {
        let plan = plan_for(LoadScenario::Best); // 56 empty experts
        let ours = simulate_ours(&plan, &GpuSpec::h800());
        let dense = simulate_dense_mapping(&plan, &GpuSpec::h800());
        assert!(dense.time_s >= ours.time_s);
    }

    #[test]
    fn operand_bytes_sane() {
        let plan = plan_for(LoadScenario::Balanced);
        let b = operand_bytes(&plan);
        // 64 weights x 18.35 MB + tokens + outputs ~ 1.5 GB
        assert!(b > 1.0e9 && b < 3.0e9, "bytes = {b}");
    }
}
