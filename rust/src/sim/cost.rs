//! Per-tile cost primitives: the unit of work the wave scheduler consumes.

use crate::sim::specs::GpuSpec;

/// Element width in bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    Bf16,
}

impl Dtype {
    pub fn bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 => 2,
        }
    }
}

/// One schedulable tile ("thread block") of work.
///
/// `task` identifies the source task (expert) so the wave model can dedupe
/// operand bytes shared through L2; `m_tile`/`n_tile` identify which operand
/// slices this tile touches.
#[derive(Clone, Debug)]
pub struct TileWork {
    pub task: u32,
    pub m_tile: u32,
    pub n_tile: u32,
    /// Useful FLOPs: only real (non-padding) rows count toward achieved
    /// throughput.
    pub useful_flops: f64,
    /// Occupied FLOPs: the padded tile shape the tensor core actually
    /// computes. occupied >= useful; the gap is the single-tiling waste.
    pub occupied_flops: f64,
    /// Bytes of the weight slice this tile reads (dedupable per task+n_tile
    /// within a wave).
    pub weight_bytes: f64,
    /// Bytes of the token rows this tile reads (dedupable per task+m_tile).
    pub token_bytes: f64,
    /// Bytes this tile writes (never deduped).
    pub out_bytes: f64,
    /// Per-block decode/scheduling overhead in ns (mapping decompression,
    /// dynamic ticket, or per-block array read — set by the mapping mode).
    pub decode_ns: f64,
}

impl TileWork {
    /// Total bytes if nothing were reused.
    pub fn private_bytes(&self) -> f64 {
        self.weight_bytes + self.token_bytes + self.out_bytes
    }

    /// Time the tensor core needs for the padded tile on one SM.
    pub fn compute_time_s(&self, spec: &GpuSpec) -> f64 {
        self.occupied_flops / spec.flops_per_sm()
    }

    /// Time this block needs for its private memory traffic given the
    /// per-block bandwidth cap (latency-bound single blocks cannot saturate
    /// chip bandwidth).
    pub fn private_mem_time_s(&self, spec: &GpuSpec) -> f64 {
        self.private_bytes() / (spec.bw_block_gbps * 1e9)
    }

    /// Standalone duration of this tile on an otherwise idle device:
    /// roofline of compute vs private memory plus fixed overheads.
    pub fn standalone_time_s(&self, spec: &GpuSpec) -> f64 {
        self.compute_time_s(spec).max(self.private_mem_time_s(spec))
            + (self.decode_ns + spec.tile_overhead_ns) * 1e-9
    }
}

/// Build the tile list for one GEMM-like task.
///
/// `m` = real rows (tokens routed to the expert), `n`/`k` = GEMM dims,
/// `(tm, tn)` = the tiling strategy assigned to this task.  Partial edge
/// tiles have fewer useful rows/cols but still occupy the full tile on the
/// tensor core.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tiles(
    task: u32,
    m: usize,
    n: usize,
    k: usize,
    tm: usize,
    tn: usize,
    dtype: Dtype,
    decode_ns: f64,
) -> Vec<TileWork> {
    if m == 0 || n == 0 {
        return Vec::new();
    }
    gemm_tiles_with_group(task, m, n, k, tm, tn, dtype, decode_ns, SWIZZLE_G)
}

/// [`gemm_tiles`] with an explicit swizzle super-block height.
/// `group == 1` disables the swizzle (plain m-outer / n-inner order) —
/// used by the swizzle ablation to quantify Section 4.4's claim.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tiles_with_group(
    task: u32,
    m: usize,
    n: usize,
    k: usize,
    tm: usize,
    tn: usize,
    dtype: Dtype,
    decode_ns: f64,
    group: usize,
) -> Vec<TileWork> {
    if m == 0 || n == 0 {
        return Vec::new();
    }
    let group = group.max(1);
    let ds = dtype.bytes() as f64;
    let tiles_m = m.div_ceil(tm);
    let tiles_n = n.div_ceil(tn);
    let mut out = Vec::with_capacity(tiles_m * tiles_n);
    // Tile swizzle (paper Section 4.4): emit tiles in super-blocks of
    // `group` m-rows — within a super-block, iterate n outer, m inner.
    // The live working set is then G token slices + 1 weight slice instead
    // of all `tiles_n` weight slices, which keeps big-K expert GEMMs inside
    // L2 (the footnote-1 best-case shape thrashes without this).
    for mg in (0..tiles_m).step_by(group) {
        let g_end = (mg + group).min(tiles_m);
        for ni in 0..tiles_n {
            let cols = (n - ni * tn).min(tn);
            for mi in mg..g_end {
                let rows = (m - mi * tm).min(tm);
                out.push(TileWork {
                    task,
                    m_tile: mi as u32,
                    n_tile: ni as u32,
                    useful_flops: 2.0 * rows as f64 * cols as f64 * k as f64,
                    occupied_flops: 2.0 * tm as f64 * tn as f64 * k as f64,
                    weight_bytes: k as f64 * cols as f64 * ds,
                    token_bytes: rows as f64 * k as f64 * ds,
                    out_bytes: rows as f64 * cols as f64 * ds,
                    decode_ns,
                });
            }
        }
    }
    out
}

/// Super-block height (in m-tiles) of the L2 tile swizzle.
pub const SWIZZLE_G: usize = 8;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_counts_and_flops() {
        let tiles = gemm_tiles(0, 256, 512, 128, 128, 256, Dtype::Bf16, 0.0);
        assert_eq!(tiles.len(), 2 * 2);
        let useful: f64 = tiles.iter().map(|t| t.useful_flops).sum();
        assert_eq!(useful, 2.0 * 256.0 * 512.0 * 128.0);
        // exact division: occupied == useful
        let occupied: f64 = tiles.iter().map(|t| t.occupied_flops).sum();
        assert_eq!(occupied, useful);
    }

    #[test]
    fn partial_tiles_waste_compute() {
        // 1 row in a 128-row tile: occupied is 128x the useful work
        let tiles = gemm_tiles(0, 1, 256, 64, 128, 256, Dtype::Bf16, 0.0);
        assert_eq!(tiles.len(), 1);
        assert_eq!(tiles[0].useful_flops * 128.0, tiles[0].occupied_flops);
    }

    #[test]
    fn empty_task_no_tiles() {
        assert!(gemm_tiles(0, 0, 256, 64, 128, 256, Dtype::Bf16, 0.0).is_empty());
    }

    #[test]
    fn bytes_accounting() {
        let t = &gemm_tiles(3, 64, 128, 32, 64, 128, Dtype::F32, 0.0)[0];
        assert_eq!(t.weight_bytes, 32.0 * 128.0 * 4.0);
        assert_eq!(t.token_bytes, 64.0 * 32.0 * 4.0);
        assert_eq!(t.out_bytes, 64.0 * 128.0 * 4.0);
        assert_eq!(t.private_bytes(), t.weight_bytes + t.token_bytes + t.out_bytes);
    }

    #[test]
    fn standalone_time_positive_and_roofline() {
        let spec = crate::sim::specs::GpuSpec::h800();
        let t = &gemm_tiles(0, 128, 256, 3584, 128, 256, Dtype::Bf16, 12.0)[0];
        let ts = t.standalone_time_s(&spec);
        // a lone cold tile is bounded below by both rooflines
        assert!(ts >= t.compute_time_s(&spec));
        assert!(ts >= t.private_mem_time_s(&spec));
        assert!(ts < (t.compute_time_s(&spec) + t.private_mem_time_s(&spec)) * 1.5);
    }
}
