//! Host-side and scheduling overhead models shared by the kernel simulator
//! and the baselines.

use crate::sim::cache::ArrayAccessModel;
use crate::sim::specs::GpuSpec;

/// How a kernel learns which tile a block owns — the axis the paper's
/// Section 3.1 compares.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MappingMode {
    /// Ours: compressed TilePrefix + σ, decoded with warp passes (Alg. 2/4).
    CompressedPrefix {
        /// Number of metadata elements shipped per step (prefix + σ).
        metadata_len: usize,
        /// Warp passes the decode needs per block (1 for N ≤ 32, etc.).
        warp_passes: usize,
    },
    /// PPoPP'19 [10]: a host-built array with one entry per thread block.
    PerBlockArray {
        blocks: usize,
    },
    /// Grouped GEMM: no host metadata, but on-device dynamic scheduling —
    /// every tile pays an atomic ticket + problem-descriptor fetch.
    DynamicOnDevice {
        /// Group-count problem descriptors loaded inside the kernel.
        groups: usize,
    },
}

impl MappingMode {
    /// Serial host-side time before the kernel can launch (H2D copies), s.
    pub fn host_time_s(&self, spec: &GpuSpec) -> f64 {
        match *self {
            MappingMode::CompressedPrefix { metadata_len, .. } => {
                ArrayAccessModel { len: metadata_len, elem_bytes: 4 }.h2d_time_s(spec)
            }
            MappingMode::PerBlockArray { blocks } => {
                // 8 bytes per entry: (task idx, tile idx)
                ArrayAccessModel { len: blocks, elem_bytes: 8 }.h2d_time_s(spec)
            }
            MappingMode::DynamicOnDevice { groups } => {
                // problem descriptors: ~32 B per group (shapes + pointers)
                ArrayAccessModel { len: groups, elem_bytes: 32 }.h2d_time_s(spec)
            }
        }
    }

    /// Per-block decode/scheduling cost inside the kernel, ns.
    /// `competing_bytes`: operand traffic contending for L2 during the run.
    pub fn decode_ns(&self, spec: &GpuSpec, competing_bytes: f64) -> f64 {
        match *self {
            MappingMode::CompressedPrefix { warp_passes, .. } => {
                spec.warp_pass_ns * warp_passes as f64
            }
            MappingMode::PerBlockArray { blocks } => {
                ArrayAccessModel { len: blocks, elem_bytes: 8 }.read_ns(spec, competing_bytes)
            }
            MappingMode::DynamicOnDevice { groups } => {
                // atomic ticket serialization + descriptor scan cost grows
                // mildly with group count (the kernel re-reads shapes)
                spec.dyn_sched_ns + 2.0 * groups as f64
            }
        }
    }

    /// Launch-time cost: single fused kernel for all modes here; the naive
    /// loop uses `wave::run_serial_launches` instead.
    pub fn launch_time_s(&self, spec: &GpuSpec) -> f64 {
        spec.launch_us * 1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compressed_metadata_ships_cheaper_than_per_block() {
        let spec = GpuSpec::h800();
        let ours = MappingMode::CompressedPrefix { metadata_len: 128, warp_passes: 2 };
        let theirs = MappingMode::PerBlockArray { blocks: 1 << 20 };
        assert!(ours.host_time_s(&spec) < theirs.host_time_s(&spec) / 10.0);
    }

    #[test]
    fn decode_cost_ordering() {
        let spec = GpuSpec::h800();
        let pressure = 100e6;
        let ours = MappingMode::CompressedPrefix { metadata_len: 128, warp_passes: 2 }
            .decode_ns(&spec, pressure);
        let array = MappingMode::PerBlockArray { blocks: 1 << 20 }.decode_ns(&spec, pressure);
        let dynamic = MappingMode::DynamicOnDevice { groups: 64 }.decode_ns(&spec, pressure);
        assert!(ours < array, "ours {ours} vs array {array}");
        assert!(ours < dynamic, "ours {ours} vs dynamic {dynamic}");
    }

    #[test]
    fn dynamic_cost_grows_with_groups() {
        let spec = GpuSpec::h20();
        let few = MappingMode::DynamicOnDevice { groups: 8 }.decode_ns(&spec, 0.0);
        let many = MappingMode::DynamicOnDevice { groups: 512 }.decode_ns(&spec, 0.0);
        assert!(many > few);
    }
}
