//! GPU execution simulator.
//!
//! The paper evaluates a CUDA kernel on H20/H800 hardware we do not have
//! (repro band 0/5), so this module provides the calibrated substitute: a
//! wave-quantized roofline simulator that executes *the same static plans*
//! the real kernel would (same TilePrefix, same σ, same tile lists, same
//! ordering) and charges costs from published hardware characteristics.
//!
//! What it models — each effect maps to a claim in the paper:
//!
//! * **wave quantization + tail** (Section 4.2): blocks are scheduled in
//!   waves of `sms * blocks_per_sm`; the last wave of a task mix is partially
//!   full.
//! * **padded-tile compute vs useful FLOPs** (Section 2.1): a tile's compute
//!   time uses the *padded* tile shape (the tensor core computes the whole
//!   tile), while achieved TFLOPS only counts useful rows — this is exactly
//!   the "too large tiling wastes computing power" defect of single-strategy
//!   grouped GEMM.
//! * **wave-level bandwidth sharing + per-block bandwidth cap**
//!   (Section 4.2): a wave's memory time is `bytes / HBM_BW`, and a single
//!   block cannot pull more than `bw_block_gbps` — so memory-bound tiles
//!   (non-busy experts) only hide under compute-bound tiles (busy experts)
//!   when the ordering mixes them into the same wave.
//! * **L2 reuse within a wave**: weight/token slices are charged once per
//!   (task, slice, wave) — consecutive tiles of one expert share their
//!   operands through L2, the locality the paper's grid ordering creates.
//! * **metadata + decode overheads** (Section 3.1): H2D copy of the mapping
//!   metadata, per-block decode cost (warp passes for ours, array reads with
//!   an L2 hit model for the per-block-array baseline, atomic ticket +
//!   problem-descriptor loads for dynamic grouped GEMM), and per-kernel
//!   launch latency (the naive per-expert loop pays it per task).

pub mod cache;
pub mod cost;
pub mod kernel_sim;
pub mod overhead;
pub mod specs;
pub mod trace;
pub mod wave;
