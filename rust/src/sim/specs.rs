//! Hardware specifications for the simulated GPUs.
//!
//! Numbers are public datasheet values (peak dense FP16/BF16 Tensor Core
//! throughput without sparsity, HBM bandwidth, SM count, L2 size) plus a
//! small set of microarchitectural cost constants documented per field.
//! The paper's Section 5 names the two peaks we must match: H20 = 146
//! TFLOPS, H800 = 989 TFLOPS.

/// Static description of one GPU model.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Number of streaming multiprocessors.
    pub sms: usize,
    /// Thread blocks resident per SM for our register-heavy GEMM blocks.
    /// Hopper WGMMA kernels run 1–2 big blocks per SM; we use 1.
    pub blocks_per_sm: usize,
    /// Peak dense FP16/BF16 Tensor Core throughput, TFLOPS.
    pub tc_tflops: f64,
    /// HBM bandwidth, GB/s.
    pub hbm_gbps: f64,
    /// L2 cache size, MiB.
    pub l2_mib: f64,
    /// Sustained HBM bandwidth one thread block can pull on its own for
    /// bulk (TMA / cp.async.bulk) tile loads, GB/s.  A single block's
    /// in-flight transactions cap well below chip bandwidth; Hopper TMA
    /// sustains a few hundred GB/s per SM, Ampere cp.async less.
    pub bw_block_gbps: f64,
    /// Kernel launch latency, microseconds (driver + grid setup).
    pub launch_us: f64,
    /// Host-to-device copy bandwidth (PCIe/NVLink effective), GB/s.
    pub h2d_gbps: f64,
    /// H2D copy fixed latency per transfer, microseconds.
    pub h2d_latency_us: f64,
    /// Cost of one warp pass of Algorithm 2 (SMEM reads + ballot + popc), ns.
    pub warp_pass_ns: f64,
    /// Cost of one atomic ticket + problem-descriptor fetch for dynamic
    /// (grouped-GEMM style) on-device scheduling, ns per tile.
    pub dyn_sched_ns: f64,
    /// Latency of one mapping-array read that hits in L2, ns.
    pub l2_hit_ns: f64,
    /// Latency of one mapping-array read that misses to HBM, ns.
    pub hbm_miss_ns: f64,
    /// Fixed per-tile pipeline fill/drain + epilogue overhead, ns.
    /// Calibrated so a long compute-bound run lands near the paper's
    /// balanced-case peak fractions (94.7% H20 / 84.8% H800).
    pub tile_overhead_ns: f64,
}

impl GpuSpec {
    /// NVIDIA H800 (Hopper, SXM): 132 SMs, 989 TF dense BF16, 3.35 TB/s.
    pub fn h800() -> Self {
        GpuSpec {
            name: "H800",
            sms: 132,
            blocks_per_sm: 1,
            tc_tflops: 989.0,
            hbm_gbps: 3350.0,
            l2_mib: 50.0,
            bw_block_gbps: 256.0,
            launch_us: 4.0,
            h2d_gbps: 50.0,
            h2d_latency_us: 8.0,
            warp_pass_ns: 12.0,
            dyn_sched_ns: 450.0,
            l2_hit_ns: 40.0,
            hbm_miss_ns: 500.0,
            tile_overhead_ns: 2600.0,
        }
    }

    /// NVIDIA H20 (Hopper, inference part): 78 SMs, 146 TF dense BF16,
    /// 4.0 TB/s HBM3 — low compute, huge bandwidth, hence the paper's
    /// near-perfect peak fractions.
    pub fn h20() -> Self {
        GpuSpec {
            name: "H20",
            sms: 78,
            blocks_per_sm: 1,
            tc_tflops: 146.0,
            hbm_gbps: 4000.0,
            l2_mib: 60.0,
            bw_block_gbps: 256.0,
            launch_us: 4.0,
            h2d_gbps: 50.0,
            h2d_latency_us: 8.0,
            warp_pass_ns: 12.0,
            dyn_sched_ns: 450.0,
            l2_hit_ns: 40.0,
            hbm_miss_ns: 500.0,
            tile_overhead_ns: 2600.0,
        }
    }

    /// NVIDIA A100 SXM (Ampere): for the cross-generation sweep example.
    pub fn a100() -> Self {
        GpuSpec {
            name: "A100",
            sms: 108,
            blocks_per_sm: 1,
            tc_tflops: 312.0,
            hbm_gbps: 2039.0,
            l2_mib: 40.0,
            bw_block_gbps: 160.0,
            launch_us: 4.5,
            h2d_gbps: 25.0,
            h2d_latency_us: 10.0,
            warp_pass_ns: 15.0,
            dyn_sched_ns: 500.0,
            l2_hit_ns: 45.0,
            hbm_miss_ns: 550.0,
            tile_overhead_ns: 2600.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "h800" => Some(Self::h800()),
            "h20" => Some(Self::h20()),
            "a100" => Some(Self::a100()),
            _ => None,
        }
    }

    /// Blocks per wave (one wave = one full residency of the device).
    pub fn wave_size(&self) -> usize {
        self.sms * self.blocks_per_sm
    }

    /// Peak throughput of a single SM, FLOP/s.
    pub fn flops_per_sm(&self) -> f64 {
        self.tc_tflops * 1e12 / self.sms as f64
    }

    pub fn l2_bytes(&self) -> f64 {
        self.l2_mib * 1024.0 * 1024.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_peaks_match_section5() {
        assert_eq!(GpuSpec::h20().tc_tflops, 146.0);
        assert_eq!(GpuSpec::h800().tc_tflops, 989.0);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(GpuSpec::by_name("H800").unwrap().name, "H800");
        assert_eq!(GpuSpec::by_name("h20").unwrap().name, "H20");
        assert!(GpuSpec::by_name("b200").is_none());
    }

    #[test]
    fn derived_quantities() {
        let s = GpuSpec::h800();
        assert_eq!(s.wave_size(), 132);
        assert!((s.flops_per_sm() - 989.0e12 / 132.0).abs() < 1.0);
        assert!((s.l2_bytes() - 50.0 * 1048576.0).abs() < 1.0);
    }

    #[test]
    fn h20_is_bandwidth_rich_compute_poor_vs_h800() {
        let (h20, h800) = (GpuSpec::h20(), GpuSpec::h800());
        assert!(h20.tc_tflops < h800.tc_tflops / 5.0);
        assert!(h20.hbm_gbps > h800.hbm_gbps);
    }
}
