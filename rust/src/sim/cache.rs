//! L2 cache model for mapping-metadata reads.
//!
//! Section 3.1's critique of the per-block mapping array (PPoPP'19 [10]):
//! the array is as long as the grid, so when every block reads its own
//! entry the accesses stream through L2 with poor locality; the compressed
//! TilePrefix (length = #tasks) instead stays L2/L1-resident for the whole
//! kernel.  This model turns that argument into numbers the mapping
//! microbench (experiment A2) reports.

use crate::sim::specs::GpuSpec;

/// Access-cost model for one auxiliary array read per thread block.
#[derive(Clone, Copy, Debug)]
pub struct ArrayAccessModel {
    /// Array length in elements.
    pub len: usize,
    /// Element size in bytes.
    pub elem_bytes: usize,
}

impl ArrayAccessModel {
    pub fn bytes(&self) -> f64 {
        (self.len * self.elem_bytes) as f64
    }

    /// Expected hit rate when `blocks` reads with hardware-linear block ids
    /// stream through the array while the rest of the kernel's working set
    /// (`competing_bytes`) also contends for L2.
    ///
    /// The array competes for the L2 share left over by operand traffic;
    /// a 128-byte line serves `line/elem` consecutive block ids, so even a
    /// streaming pass hits `1 - elem/line` of the time *if* the line is not
    /// evicted between neighboring blocks' reads — the eviction probability
    /// grows with working-set pressure.
    pub fn hit_rate(&self, spec: &GpuSpec, competing_bytes: f64) -> f64 {
        let line = 128.0;
        let spatial = 1.0 - self.elem_bytes as f64 / line; // same-line hits
        let l2 = spec.l2_bytes();
        let resident = (l2 / (competing_bytes + self.bytes())).min(1.0);
        // lines survive between neighbor reads with prob ~ resident share
        spatial * resident + (1.0 - spatial) * (l2 / (competing_bytes + l2)).min(1.0) * 0.0
    }

    /// Mean latency of one block's metadata read, ns.
    pub fn read_ns(&self, spec: &GpuSpec, competing_bytes: f64) -> f64 {
        let h = self.hit_rate(spec, competing_bytes);
        h * spec.l2_hit_ns + (1.0 - h) * spec.hbm_miss_ns
    }

    /// H2D copy time for shipping this array to the device each step, s.
    pub fn h2d_time_s(&self, spec: &GpuSpec) -> f64 {
        spec.h2d_latency_us * 1e-6 + self.bytes() / (spec.h2d_gbps * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_array_mostly_hits() {
        let spec = GpuSpec::h800();
        // 64-entry TilePrefix: trivially resident
        let m = ArrayAccessModel { len: 64, elem_bytes: 4 };
        assert!(m.hit_rate(&spec, 0.0) > 0.9);
        assert!(m.read_ns(&spec, 0.0) < 100.0);
    }

    #[test]
    fn giant_array_under_pressure_misses_more() {
        let spec = GpuSpec::h800();
        let small = ArrayAccessModel { len: 64, elem_bytes: 4 };
        let big = ArrayAccessModel { len: 1 << 20, elem_bytes: 8 };
        let pressure = 200.0 * 1024.0 * 1024.0; // 200 MB of operand traffic
        assert!(big.hit_rate(&spec, pressure) < small.hit_rate(&spec, pressure));
        assert!(big.read_ns(&spec, pressure) > small.read_ns(&spec, pressure));
    }

    #[test]
    fn h2d_scales_with_length() {
        let spec = GpuSpec::h20();
        let small = ArrayAccessModel { len: 64, elem_bytes: 4 };
        let big = ArrayAccessModel { len: 1 << 22, elem_bytes: 8 };
        assert!(big.h2d_time_s(&spec) > small.h2d_time_s(&spec) * 10.0);
        // latency floor dominates tiny copies
        assert!(small.h2d_time_s(&spec) >= spec.h2d_latency_us * 1e-6);
    }
}
