//! Execution-time model: event-driven block scheduling + bandwidth windows.
//!
//! The GPU block scheduler is *not* wave-synchronous: an SM picks up the
//! next block the moment its current one retires, so short memory-bound
//! blocks backfill around long compute-bound ones.  We model exactly that
//! with greedy list scheduling over `spec.wave_size()` block slots, plus:
//!
//! * **L2 reuse (FIFO capacity model)**: weight slices `(task, n_tile)` and
//!   token slices `(task, m_tile)` hit in L2 if still resident; misses
//!   charge HBM traffic *and* the block's private load time.  Grid-order
//!   locality (tiles of one expert adjacent) is what makes these hit — the
//!   same locality argument as the paper's tile swizzle.
//! * **Per-block bandwidth cap**: a lone block pulls at most
//!   `bw_block_gbps`, so a cold single-token expert tile is latency-bound
//!   even on an idle device (why the paper's worst case hurts on H800).
//! * **Windowed HBM roofline**: total traffic is binned over the schedule;
//!   windows whose demand exceeds `hbm_gbps` are stretched.  Clustering
//!   memory-bound tiles (bad expert ordering) concentrates demand and
//!   stretches more — the Section 4.2 mixing effect.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::sim::cost::TileWork;
use crate::sim::specs::GpuSpec;
use crate::sim::trace::{SimResult, WaveTrace};

/// FIFO capacity cache over operand slices.
struct L2Tracker {
    cap: f64,
    used: f64,
    resident: HashMap<(u32, u8, u32), f64>,
    fifo: VecDeque<(u32, u8, u32)>,
}

impl L2Tracker {
    fn new(cap: f64) -> Self {
        L2Tracker { cap, used: 0.0, resident: HashMap::new(), fifo: VecDeque::new() }
    }

    /// Returns true on hit; on miss, inserts and evicts FIFO to capacity.
    fn access(&mut self, key: (u32, u8, u32), bytes: f64) -> bool {
        if self.resident.contains_key(&key) {
            return true;
        }
        self.resident.insert(key, bytes);
        self.fifo.push_back(key);
        self.used += bytes;
        while self.used > self.cap {
            let Some(old) = self.fifo.pop_front() else { break };
            if let Some(b) = self.resident.remove(&old) {
                self.used -= b;
            }
        }
        false
    }
}

/// Simulate one fused kernel launch executing `tiles` in grid order.
/// `extra_time_s` adds serial host-side time (H2D copies, launch latency).
pub fn run_waves(tiles: &[TileWork], spec: &GpuSpec, extra_time_s: f64) -> SimResult {
    if tiles.is_empty() {
        return SimResult::new(extra_time_s, extra_time_s, 0.0, 0.0, spec, Vec::new());
    }
    let slots = spec.wave_size();
    // min-heap of slot free times in integer picoseconds
    let mut free: BinaryHeap<Reverse<u64>> = (0..slots).map(|_| Reverse(0u64)).collect();
    let mut l2 = L2Tracker::new(spec.l2_bytes());

    let mut schedule: Vec<(f64, f64, f64)> = Vec::with_capacity(tiles.len()); // start, dur, hbm bytes
    let mut useful = 0.0;
    let mut occupied = 0.0;
    let mut makespan = 0u64;

    for t in tiles {
        useful += t.useful_flops;
        occupied += t.occupied_flops;
        // operand residency
        let w_hit = l2.access((t.task, 0, t.n_tile), t.weight_bytes);
        let x_hit = l2.access((t.task, 1, t.m_tile), t.token_bytes);
        let cold = if w_hit { 0.0 } else { t.weight_bytes }
            + if x_hit { 0.0 } else { t.token_bytes };
        let hbm_bytes = cold + t.out_bytes;

        let t_compute = t.compute_time_s(spec);
        let t_load = cold / (spec.bw_block_gbps * 1e9);
        let dur = t_compute.max(t_load) + (t.decode_ns + spec.tile_overhead_ns) * 1e-9;

        let Reverse(start_ps) = free.pop().unwrap();
        let end_ps = start_ps + (dur * 1e12) as u64;
        free.push(Reverse(end_ps));
        makespan = makespan.max(end_ps);
        schedule.push((start_ps as f64 * 1e-12, dur, hbm_bytes));
    }
    let makespan_s = makespan as f64 * 1e-12;

    // --- windowed bandwidth roofline ---------------------------------------
    let n_windows = tiles.len().clamp(32, 512);
    let dt = makespan_s / n_windows as f64;
    let mut win_bytes = vec![0.0f64; n_windows];
    let mut win_blocks = vec![0usize; n_windows];
    let mut win_longest = vec![0.0f64; n_windows];
    for &(start, dur, bytes) in &schedule {
        let w0 = ((start / dt) as usize).min(n_windows - 1);
        let w1 = (((start + dur) / dt) as usize).min(n_windows - 1);
        let span = w1 - w0 + 1;
        for w in w0..=w1 {
            win_bytes[w] += bytes / span as f64;
        }
        win_blocks[w0] += 1;
        win_longest[w0] = win_longest[w0].max(dur);
    }
    let bw = spec.hbm_gbps * 1e9;
    let mut total = 0.0;
    let mut traces = Vec::with_capacity(n_windows);
    for w in 0..n_windows {
        let mem_time = win_bytes[w] / bw;
        let wtime = dt.max(mem_time);
        total += wtime;
        traces.push(WaveTrace {
            wave: w,
            blocks: win_blocks[w],
            time_s: wtime,
            mem_time_s: mem_time,
            longest_tile_s: win_longest[w].max(dt),
            bytes: win_bytes[w],
        });
    }

    SimResult::new(extra_time_s + total, extra_time_s, useful, occupied, spec, traces)
}

/// Simulate a sequence of separate kernel launches (the naive per-task
/// loop): each launch pays `spec.launch_us` and cannot overlap others.
pub fn run_serial_launches(
    launches: &[Vec<TileWork>],
    spec: &GpuSpec,
    extra_time_s: f64,
) -> SimResult {
    let mut total_time = extra_time_s;
    let mut useful = 0.0;
    let mut occupied = 0.0;
    let mut traces = Vec::new();
    for tiles in launches {
        if tiles.is_empty() {
            continue;
        }
        let r = run_waves(tiles, spec, spec.launch_us * 1e-6);
        total_time += r.time_s;
        useful += r.useful_flops;
        occupied += r.occupied_flops;
        traces.extend(r.waves);
    }
    SimResult::new(total_time, extra_time_s, useful, occupied, spec, traces)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost::{gemm_tiles, Dtype};

    fn spec() -> GpuSpec {
        GpuSpec::h800()
    }

    #[test]
    fn empty_grid_costs_only_extra() {
        let r = run_waves(&[], &spec(), 1e-4);
        assert_eq!(r.time_s, 1e-4);
        assert_eq!(r.useful_flops, 0.0);
    }

    #[test]
    fn big_balanced_gemm_hits_high_utilization() {
        let tiles = gemm_tiles(0, 16384, 2560, 3584, 128, 256, Dtype::Bf16, 12.0);
        let r = run_waves(&tiles, &spec(), 0.0);
        assert!(
            r.peak_frac > 0.80 && r.peak_frac <= 1.0,
            "peak_frac = {}",
            r.peak_frac
        );
    }

    #[test]
    fn single_token_tasks_are_memory_bound() {
        let mut tiles = Vec::new();
        for e in 0..56 {
            tiles.extend(gemm_tiles(e, 1, 2560, 3584, 16, 128, Dtype::Bf16, 12.0));
        }
        let r = run_waves(&tiles, &spec(), 0.0);
        assert!(r.peak_frac < 0.05, "peak_frac = {}", r.peak_frac);
        // elapsed at least the chip-bandwidth bound for the weight traffic
        let total_bytes: f64 = 56.0 * 3584.0 * 2560.0 * 2.0;
        let bw_bound = total_bytes / (spec().hbm_gbps * 1e9);
        assert!(r.time_s >= bw_bound * 0.5, "{} vs {}", r.time_s, bw_bound);
    }

    #[test]
    fn short_blocks_backfill_around_long_ones() {
        // one huge compute task + many tiny ones: the tiny tiles must hide
        // almost completely inside the big task's schedule
        let busy = gemm_tiles(0, 16384, 2560, 3584, 128, 256, Dtype::Bf16, 12.0);
        let alone = run_waves(&busy, &spec(), 0.0);
        let mut mixed = Vec::new();
        // interleave: every 16 busy tiles, one tiny tile
        let mut skinny = Vec::new();
        for e in 1..57 {
            skinny.extend(gemm_tiles(e, 1, 2560, 3584, 16, 128, Dtype::Bf16, 12.0));
        }
        let mut si = 0;
        for t in busy.iter() {
            mixed.push(t.clone());
            if si < skinny.len() {
                mixed.push(skinny[si].clone());
                si += 1;
            }
        }
        mixed.extend(skinny[si..].iter().cloned());
        let both = run_waves(&mixed, &spec(), 0.0);
        // the skinny tiles' latency hides: the added cost is bounded by
        // their bandwidth footprint, strictly below serial execution
        let skinny_bytes: f64 = skinny.iter().map(|t| t.private_bytes()).sum();
        let bw_cost = skinny_bytes / (spec().hbm_gbps * 1e9);
        assert!(
            both.time_s < alone.time_s + bw_cost,
            "{} vs {} + {}",
            both.time_s,
            alone.time_s,
            bw_cost
        );
        // and far below the skinny tiles run serially after the busy ones
        let serial = alone.time_s + run_waves(&skinny, &spec(), 0.0).time_s;
        assert!(both.time_s <= serial * 1.01, "{} vs serial {}", both.time_s, serial);
    }

    #[test]
    fn mixing_not_worse_than_segregating() {
        let busy = gemm_tiles(0, 8192, 2560, 3584, 128, 256, Dtype::Bf16, 12.0);
        let mut skinny = Vec::new();
        for e in 1..57 {
            skinny.extend(gemm_tiles(e, 1, 2560, 3584, 16, 128, Dtype::Bf16, 12.0));
        }
        let mut seg = busy.clone();
        seg.extend(skinny.iter().cloned());
        let mut mix = Vec::new();
        let (mut bi, mut si) = (0usize, 0usize);
        while bi < busy.len() || si < skinny.len() {
            for _ in 0..8 {
                if bi < busy.len() {
                    mix.push(busy[bi].clone());
                    bi += 1;
                }
            }
            if si < skinny.len() {
                mix.push(skinny[si].clone());
                si += 1;
            }
        }
        let r_seg = run_waves(&seg, &spec(), 0.0);
        let r_mix = run_waves(&mix, &spec(), 0.0);
        assert!(
            r_mix.time_s <= r_seg.time_s * 1.01,
            "mix {} vs seg {}",
            r_mix.time_s,
            r_seg.time_s
        );
    }

    #[test]
    fn serial_launches_pay_per_launch() {
        let one = gemm_tiles(0, 512, 2560, 3584, 128, 256, Dtype::Bf16, 0.0);
        let eight: Vec<TileWork> = (0..8).flat_map(|_| one.iter().cloned()).collect();
        let fused = run_waves(&eight, &spec(), 0.0);
        let launches: Vec<_> = (0..8).map(|_| one.clone()).collect();
        let serial = run_serial_launches(&launches, &spec(), 0.0);
        assert!(serial.time_s > fused.time_s);
    }

    #[test]
    fn trace_covers_all_blocks() {
        let tiles = gemm_tiles(0, 4096, 2560, 3584, 128, 256, Dtype::Bf16, 12.0);
        let r = run_waves(&tiles, &spec(), 0.0);
        let total: usize = r.waves.iter().map(|w| w.blocks).sum();
        assert_eq!(total, tiles.len());
        // sum of window times equals the reported total minus host extras
        let t: f64 = r.waves.iter().map(|w| w.time_s).sum();
        assert!((t - r.time_s).abs() < 1e-9);
    }

    #[test]
    fn l2_tracker_hits_and_evicts() {
        let mut l2 = L2Tracker::new(100.0);
        assert!(!l2.access((0, 0, 0), 60.0)); // miss
        assert!(l2.access((0, 0, 0), 60.0)); // hit
        assert!(!l2.access((0, 0, 1), 60.0)); // miss, evicts first
        assert!(!l2.access((0, 0, 0), 60.0)); // miss again (evicted)
    }
}
