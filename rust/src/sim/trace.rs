//! Simulation results and per-wave traces.

use crate::sim::specs::GpuSpec;

/// Timing of one wave of thread blocks.
#[derive(Clone, Debug)]
pub struct WaveTrace {
    pub wave: usize,
    pub blocks: usize,
    pub time_s: f64,
    pub mem_time_s: f64,
    pub longest_tile_s: f64,
    pub bytes: f64,
}

impl WaveTrace {
    /// True if this wave was limited by the memory roofline rather than its
    /// slowest block.
    pub fn memory_bound(&self) -> bool {
        self.mem_time_s >= self.longest_tile_s
    }
}

/// Outcome of simulating one kernel (or a sequence of launches).
#[derive(Clone, Debug)]
pub struct SimResult {
    /// End-to-end time including host-side extras.
    pub time_s: f64,
    /// Host-side serial extras (H2D copies, launch latency) included above.
    pub host_time_s: f64,
    /// FLOPs that produced real output rows.
    pub useful_flops: f64,
    /// FLOPs the tensor cores actually cycled through (>= useful).
    pub occupied_flops: f64,
    /// Achieved useful throughput, TFLOPS.
    pub tflops: f64,
    /// `tflops / spec.tc_tflops` — the paper's "peak%" metric.
    pub peak_frac: f64,
    /// Per-wave timeline.
    pub waves: Vec<WaveTrace>,
}

impl SimResult {
    pub fn new(
        time_s: f64,
        host_time_s: f64,
        useful_flops: f64,
        occupied_flops: f64,
        spec: &GpuSpec,
        waves: Vec<WaveTrace>,
    ) -> Self {
        let tflops = if time_s > 0.0 { useful_flops / time_s / 1e12 } else { 0.0 };
        SimResult {
            time_s,
            host_time_s,
            useful_flops,
            occupied_flops,
            tflops,
            peak_frac: tflops / spec.tc_tflops,
            waves,
        }
    }

    /// Fraction of tensor-core cycles wasted on padding rows/cols.
    pub fn padding_waste(&self) -> f64 {
        if self.occupied_flops == 0.0 {
            0.0
        } else {
            1.0 - self.useful_flops / self.occupied_flops
        }
    }

    /// Compact one-line summary used by the benches.
    pub fn summary(&self) -> String {
        format!(
            "{:.3} ms  {:.2} TFLOPS  {:.2}% peak  ({} waves, {:.1}% padding waste)",
            self.time_s * 1e3,
            self.tflops,
            self.peak_frac * 100.0,
            self.waves.len(),
            self.padding_waste() * 100.0
        )
    }

    /// Render an ASCII timeline of the first `max` waves (debug aid).
    pub fn render_trace(&self, max: usize) -> String {
        let mut s = String::new();
        s.push_str("wave  blocks  time(us)  bound\n");
        for w in self.waves.iter().take(max) {
            s.push_str(&format!(
                "{:>4}  {:>6}  {:>8.2}  {}\n",
                w.wave,
                w.blocks,
                w.time_s * 1e6,
                if w.memory_bound() { "mem" } else { "compute" }
            ));
        }
        if self.waves.len() > max {
            s.push_str(&format!("... ({} more waves)\n", self.waves.len() - max));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tflops_and_peak_frac() {
        let spec = GpuSpec::h800();
        let r = SimResult::new(1e-3, 0.0, 989.0e9, 989.0e9, &spec, vec![]);
        assert!((r.tflops - 989.0).abs() < 1e-9);
        assert!((r.peak_frac - 1.0).abs() < 1e-12);
    }

    #[test]
    fn padding_waste_computed() {
        let spec = GpuSpec::h20();
        let r = SimResult::new(1.0, 0.0, 50.0, 100.0, &spec, vec![]);
        assert!((r.padding_waste() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn wave_boundedness() {
        let w = WaveTrace {
            wave: 0,
            blocks: 10,
            time_s: 2.0,
            mem_time_s: 2.0,
            longest_tile_s: 1.0,
            bytes: 0.0,
        };
        assert!(w.memory_bound());
    }

    #[test]
    fn summary_contains_key_numbers() {
        let spec = GpuSpec::h20();
        let r = SimResult::new(2e-3, 0.0, 146.0e9, 146.0e9, &spec, vec![]);
        let s = r.summary();
        assert!(s.contains("TFLOPS"));
        assert!(s.contains("peak"));
    }
}
