//! `staticbatch` CLI — leader entrypoint.
//!
//! Subcommands:
//!   table1        regenerate the paper's Table 1 on the GPU simulator
//!   baselines     ours vs grouped GEMM / two-phase / naive loop (A1)
//!   mapping       mapping-mechanism microbench table (A2)
//!   ordering      expert-ordering ablation (A3)
//!   empty-tasks   empty-task two-stage mapping ablation (A4)
//!   token-copy    token-copy elimination accounting (A5)
//!   ragged        ragged-attention decode (second workload) vs padded-dense
//!   fused         fused transformer-layer step (attention + prefill + routed
//!                 FFN under one σ) vs the two-plan sequential baseline
//!   sweep         zipf imbalance sweep, ours vs grouped GEMM
//!   simulate      one scenario end to end with the wave trace
//!   plan          print the static batch plan for a scenario
//!   serve         start the TCP serving coordinator (needs artifacts)
//!   serve-sim     drive synthetic open-loop traffic through the sim-backed
//!                 serving core (no GPU, no artifacts); --ep/--tp/--placement
//!                 run it expert-parallel sharded
//!   scenario      trace-driven multi-tenant scenario on the virtual clock:
//!                 burst + Poisson arrivals, tenant priorities and SLOs,
//!                 overload shedding, and a mid-run shard kill/recover
//!   client        send synthetic requests to a running server
//!   selftest      quick numeric self-check (CPU executor vs reference)

use staticbatch::exec::ExecutionSession;
use staticbatch::moe::config::MoeShape;
use staticbatch::moe::routing::LoadScenario;
use staticbatch::reports;
use staticbatch::sim::specs::GpuSpec;
use staticbatch::util::cli::Command;
use staticbatch::util::logging;

fn scenario_from(name: &str, alpha: f64) -> LoadScenario {
    match name {
        "balanced" => LoadScenario::Balanced,
        "best" => LoadScenario::Best,
        "worst" => LoadScenario::Worst,
        "zipf" => LoadScenario::Zipf(alpha),
        "dirichlet" => LoadScenario::Dirichlet(alpha),
        other => {
            eprintln!("unknown scenario '{other}', using balanced");
            LoadScenario::Balanced
        }
    }
}

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sub = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    let code = match sub {
        "table1" => {
            print!("{}", reports::table1());
            0
        }
        "baselines" => {
            print!("{}", reports::baselines_table());
            0
        }
        "mapping" => {
            print!("{}", reports::mapping_table());
            0
        }
        "ordering" => {
            print!("{}", reports::ordering_table(0));
            0
        }
        "empty-tasks" => {
            print!("{}", reports::empty_tasks_table());
            0
        }
        "token-copy" => {
            print!("{}", reports::token_copy_table());
            0
        }
        "swizzle" => {
            print!("{}", reports::swizzle_table());
            0
        }
        "ragged" => cmd_ragged(rest),
        "fused" => cmd_fused(rest),
        "sweep" => cmd_sweep(rest),
        "simulate" => cmd_simulate(rest),
        "plan" => cmd_plan(rest),
        "serve" => cmd_serve(rest),
        "serve-sim" => cmd_serve_sim(rest),
        "scenario" => cmd_scenario(rest),
        "client" => cmd_client(rest),
        "selftest" => cmd_selftest(),
        _ => {
            eprintln!(
                "staticbatch {} — static batching of irregular workloads\n\n\
                 usage: staticbatch <table1|baselines|mapping|ordering|empty-tasks|swizzle|\n\
                        token-copy|ragged|fused|sweep|simulate|plan|serve|serve-sim|scenario|\n\
                        client|selftest> [flags]\n\
                 run a subcommand with --help for its flags",
                staticbatch::VERSION
            );
            if sub == "help" { 0 } else { 2 }
        }
    };
    std::process::exit(code);
}

/// The second irregular workload through the same planning stack: ragged
/// batched attention decode (per-sequence KV lengths) statically batched
/// via σ/TilePrefix vs the padded-dense grid, on the GPU simulator.
fn cmd_ragged(args: &[String]) -> i32 {
    let cmd = Command::new("ragged", "ragged-attention decode vs padded-dense baseline")
        .flag("seqs", Some("256"), "decode sequences in the batch")
        .flag("seed", Some("0"), "KV-length sampling seed");
    match cmd.parse(args) {
        Ok(p) => {
            print!(
                "{}",
                reports::ragged_table(p.usize("seqs").unwrap_or(256).max(1), p.u64("seed").unwrap_or(0))
            );
            0
        }
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

/// The fused transformer-layer step (heterogeneous task kinds under one σ)
/// vs running ragged attention and the routed FFN as two sequential plans,
/// and vs the two-launch padded-dense scheme, on the GPU simulator.
fn cmd_fused(args: &[String]) -> i32 {
    let cmd = Command::new("fused", "fused transformer-layer step vs sequential / padded-dense")
        .flag("seqs", Some("64"), "sequence slots in the formed batch")
        .flag("seed", Some("0"), "traffic sampling seed");
    match cmd.parse(args) {
        Ok(p) => {
            print!(
                "{}",
                reports::fused_table(p.usize("seqs").unwrap_or(64).max(4), p.u64("seed").unwrap_or(0))
            );
            0
        }
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

fn cmd_sweep(args: &[String]) -> i32 {
    let cmd = Command::new("sweep", "zipf imbalance sweep, ours vs grouped GEMM")
        .flag("gpu", Some("h800"), "gpu spec (h20|h800|a100)")
        .flag("seeds", Some("3"), "seeds to average");
    match cmd.parse(args) {
        Ok(p) => {
            print!("{}", reports::sweep_table(&p.str("gpu"), p.u64("seeds").unwrap_or(3)));
            0
        }
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

fn cmd_simulate(args: &[String]) -> i32 {
    let cmd = Command::new("simulate", "simulate one MoE step on a GPU spec")
        .flag("gpu", Some("h800"), "gpu spec (h20|h800|a100)")
        .flag("scenario", Some("balanced"), "balanced|best|worst|zipf|dirichlet")
        .flag("alpha", Some("1.2"), "skew parameter for zipf/dirichlet")
        .flag("seed", Some("0"), "routing seed")
        .switch("trace", "print the wave timeline");
    let p = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let spec = match GpuSpec::by_name(&p.str("gpu")) {
        Some(s) => s,
        None => {
            eprintln!("unknown gpu '{}'", p.str("gpu"));
            return 2;
        }
    };
    let sc = scenario_from(&p.str("scenario"), p.f64("alpha").unwrap_or(1.2));
    let shape = MoeShape::paper_table1();
    let load = sc.counts(&shape, p.u64("seed").unwrap_or(0));
    let spec_name = spec.name;
    let mut session = ExecutionSession::new(shape).gpu(spec);
    let plan = session.plan(&load);
    let out = session.run_plan(&plan).expect("sim backend");
    let r = out.sim();
    println!(
        "{} / {} on {}: {}",
        sc.name(),
        "paper_table1 shape",
        spec_name,
        r.summary()
    );
    println!(
        "experts: {} non-empty, {} empty; {} tiles; imbalance {:.2}",
        plan.num_nonempty(),
        shape.experts - plan.num_nonempty(),
        plan.total_tiles(),
        load.imbalance()
    );
    if p.bool("trace") {
        print!("{}", r.render_trace(40));
    }
    0
}

fn cmd_plan(args: &[String]) -> i32 {
    let cmd = Command::new("plan", "print the static batch plan for a scenario")
        .flag("scenario", Some("worst"), "balanced|best|worst|zipf|dirichlet")
        .flag("alpha", Some("1.2"), "skew parameter")
        .flag("seed", Some("0"), "routing seed");
    let p = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let sc = scenario_from(&p.str("scenario"), p.f64("alpha").unwrap_or(1.2));
    let shape = MoeShape::paper_table1();
    let load = sc.counts(&shape, p.u64("seed").unwrap_or(0));
    let plan = ExecutionSession::new(shape).plan(&load);
    println!("plan for {} ({} experts, {} tiles):", sc.name(), shape.experts, plan.total_tiles());
    println!("  sigma (grid order -> expert): {:?}", &plan.two_stage.sigma);
    println!(
        "  tile_prefix: {:?}",
        &plan.two_stage.tile_prefix[..plan.num_nonempty().min(plan.two_stage.tile_prefix.len())]
    );
    for t in plan.tasks.iter().filter(|t| t.rows > 0).take(16) {
        let s = staticbatch::moe::tiling::CATALOG[t.strategy];
        println!("  expert {:>2}: {:>5} rows, tile {}x{}", t.expert, t.rows, s.tm, s.tn);
    }
    if plan.num_nonempty() > 16 {
        println!("  ... ({} more tasks)", plan.num_nonempty() - 16);
    }
    println!("  metadata: {} bytes", plan.metadata_bytes());
    0
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &[String]) -> i32 {
    use staticbatch::coordinator::engine::{Engine, EngineConfig};
    use staticbatch::coordinator::server;
    use std::sync::Arc;

    let cmd = Command::new("serve", "start the serving coordinator")
        .flag("addr", Some("127.0.0.1:7433"), "listen address")
        .flag("artifacts", Some("artifacts"), "artifacts directory");
    let p = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cfg = EngineConfig {
        artifacts_dir: p.str("artifacts").into(),
        ..EngineConfig::default()
    };
    let handle = match Engine::spawn(cfg) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("engine start failed: {e}");
            return 1;
        }
    };
    let addr = p.str("addr");
    if let Err(e) = server::listen(&addr, Arc::clone(&handle.queue), Arc::clone(&handle.metrics)) {
        eprintln!("server error: {e}");
        return 1;
    }
    0
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &[String]) -> i32 {
    eprintln!("serve requires the `pjrt` feature: cargo run --features pjrt -- serve");
    2
}

/// Synthetic open-loop traffic against the sim-backed serving core: the
/// full queue → batcher → PlanCache → execute → respond pipeline with no
/// GPU, artifacts, or XLA anywhere.  With `--ep`/`--tp` above 1 the batches
/// run through the expert-parallel sharded executor instead (per-shard plan
/// caches, EP all-to-all / TP all-reduce accounting, pluggable placement).
fn cmd_serve_sim(args: &[String]) -> i32 {
    use staticbatch::coordinator::batcher::BatchPolicy;
    use staticbatch::serve::{
        run_traffic, ChaosConfig, ChaosStepExecutor, FusedServeConfig, FusedStepExecutor,
        PlacementKind, RetryPolicy, Server, ServerConfig, ShardedServeConfig, ShardedStepExecutor,
        SimServeConfig, SimStepExecutor, StepExecutor, TrafficConfig,
    };

    let cmd = Command::new("serve-sim", "synthetic traffic through the sim serving core")
        .flag(
            "workload",
            Some("moe"),
            "per-step workload: moe (expert FFN only) | fused (whole transformer \
             layer: ragged attention + chunked prefill + routed FFN as one plan)",
        )
        .flag("requests", Some("256"), "requests to send")
        .flag("rate", Some("500"), "open-loop request rate (req/s); 0 = burst")
        .flag("alpha", Some("1.2"), "zipf exponent for tokens and prompt popularity")
        .flag("distinct", Some("8"), "distinct prompts in the pool")
        .flag("experts", Some("16"), "experts in the sim MoE layer")
        .flag("topk", Some("2"), "experts per token")
        .flag("cache", Some("128"), "plan cache capacity (LRU entries) per lane")
        .flag("max-requests", Some("16"), "max requests per formed batch")
        .flag("seed", Some("1"), "traffic + weight seed")
        .flag("ep", Some("1"), "expert-parallel shards (>1 = sharded executor)")
        .flag("tp", Some("1"), "tensor-parallel ways (must divide d_ff)")
        .flag("placement", Some("static"), "expert placement: static|balanced")
        .flag("rebalance", Some("1.25"), "re-shard imbalance threshold (balanced)")
        .flag("threads", Some("1"), "worker threads for CPU numerics (1 = serial)")
        .flag("deadline-ms", Some("2"), "batch deadline in ms (max-batch OR deadline)")
        .flag("depth", Some("2"), "pipeline depth between batcher/executor/responder")
        .flag("retry", Some("1"), "max step attempts for transient failures (1 = no retry)")
        .flag("backoff-ms", Some("0"), "linear retry backoff between attempts, ms")
        .flag("request-deadline-ms", Some("0"), "per-request deadline in ms; 0 = none")
        .flag("chaos-rate", Some("0.1"), "transient-fault probability per step under --chaos")
        .switch("chaos", "inject seeded transient faults at the executor boundary")
        .switch("sync", "single-threaded reference loop (no pipelining)")
        .switch("accounting", "skip CPU numerics (roofline accounting only)");
    let p = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let sim_cfg = SimServeConfig {
        experts: p.usize("experts").unwrap_or(16).max(1),
        top_k: p.usize("topk").unwrap_or(2).max(1),
        cache_capacity: p.usize("cache").unwrap_or(128),
        numeric: !p.bool("accounting"),
        threads: p.usize("threads").unwrap_or(1).max(1),
        seed: p.u64("seed").unwrap_or(1),
        ..SimServeConfig::default()
    };
    let max_tokens = sim_cfg.max_tokens;
    let server_cfg = ServerConfig {
        policy: BatchPolicy {
            buckets: Vec::new(), // adopted from the executor
            max_requests: p.usize("max-requests").unwrap_or(16).max(1),
            max_tokens,
        },
        queue_capacity: 512,
        deadline: std::time::Duration::from_secs_f64(
            p.f64("deadline-ms").unwrap_or(2.0).max(0.0) / 1e3,
        ),
        depth: p.usize("depth").unwrap_or(2).max(1),
        pipeline: !p.bool("sync"),
        request_deadline: {
            let ms = p.f64("request-deadline-ms").unwrap_or(0.0);
            (ms > 0.0).then(|| std::time::Duration::from_secs_f64(ms / 1e3))
        },
        retry: RetryPolicy {
            max_attempts: p.usize("retry").unwrap_or(1).max(1) as u32,
            backoff: std::time::Duration::from_secs_f64(
                p.f64("backoff-ms").unwrap_or(0.0).max(0.0) / 1e3,
            ),
        },
    };
    let chaos = p.bool("chaos").then(|| ChaosConfig {
        seed: p.u64("seed").unwrap_or(1) ^ 0xC4A0,
        transient_rate: p.f64("chaos-rate").unwrap_or(0.1).clamp(0.0, 1.0),
        ..ChaosConfig::default()
    });
    let traffic = TrafficConfig {
        requests: p.usize("requests").unwrap_or(256),
        rate_hz: p.f64("rate").unwrap_or(500.0),
        zipf_alpha: p.f64("alpha").unwrap_or(1.2),
        distinct: p.usize("distinct").unwrap_or(8).max(1),
        seed: p.u64("seed").unwrap_or(1),
        ..TrafficConfig::default()
    };
    let ep = p.usize("ep").unwrap_or(1).max(1);
    let tp = p.usize("tp").unwrap_or(1).max(1);
    let workload = p.str("workload");
    if workload != "moe" && workload != "fused" {
        eprintln!("unknown workload '{workload}' (moe|fused)");
        return 2;
    }

    fn drive<E: StepExecutor>(
        executor: E,
        server_cfg: ServerConfig,
        traffic: TrafficConfig,
    ) -> i32 {
        println!(
            "serve-sim [{}]: {} requests at {} req/s, {} distinct prompts, zipf {:.2}",
            executor.name(),
            traffic.requests,
            if traffic.rate_hz > 0.0 { traffic.rate_hz.to_string() } else { "burst".into() },
            traffic.distinct,
            traffic.zipf_alpha
        );
        let mut server = Server::new(server_cfg, executor);
        let report = run_traffic(&mut server, traffic);
        print!("{}", report.render());
        if report.failed > 0 {
            1
        } else {
            0
        }
    }

    if workload == "fused" {
        if ep > 1 || tp > 1 {
            eprintln!("--workload fused is single-lane; drop --ep/--tp (use top_k=1 routing for shard-equivalent behavior)");
            return 2;
        }
        let fused_cfg = FusedServeConfig {
            experts: sim_cfg.experts,
            top_k: sim_cfg.top_k,
            cache_capacity: sim_cfg.cache_capacity,
            numeric: sim_cfg.numeric,
            threads: sim_cfg.threads,
            seed: sim_cfg.seed,
            ..FusedServeConfig::default()
        };
        let executor = FusedStepExecutor::new(fused_cfg);
        return match chaos {
            Some(c) => drive(ChaosStepExecutor::new(executor, c), server_cfg, traffic),
            None => drive(executor, server_cfg, traffic),
        };
    }
    if ep > 1 || tp > 1 {
        let placement = match PlacementKind::from_name(&p.str("placement")) {
            Some(k) => k,
            None => {
                eprintln!("unknown placement '{}' (static|balanced)", p.str("placement"));
                return 2;
            }
        };
        if sim_cfg.d_ff % tp != 0 {
            eprintln!("--tp {tp} does not divide d_ff {}", sim_cfg.d_ff);
            return 2;
        }
        let cfg = ShardedServeConfig {
            base: sim_cfg,
            ep,
            tp,
            placement,
            rebalance_threshold: p.f64("rebalance").unwrap_or(1.25),
            ..ShardedServeConfig::default()
        };
        let executor = ShardedStepExecutor::new(cfg);
        match chaos {
            Some(c) => drive(ChaosStepExecutor::new(executor, c), server_cfg, traffic),
            None => drive(executor, server_cfg, traffic),
        }
    } else {
        let executor = SimStepExecutor::new(sim_cfg);
        match chaos {
            Some(c) => drive(ChaosStepExecutor::new(executor, c), server_cfg, traffic),
            None => drive(executor, server_cfg, traffic),
        }
    }
}

/// Trace-driven multi-tenant scenario on the virtual clock: a burst +
/// Poisson arrival trace split across a premium and a batch tenant,
/// priority admission shedding the batch tenant first under overload, and
/// a scheduled shard kill/recover forcing the expert-parallel executor to
/// re-shard mid-run.  Fully deterministic for a seed — nothing sleeps.
fn cmd_scenario(args: &[String]) -> i32 {
    use staticbatch::serve::{
        run_scenario, ArrivalTrace, ChaosConfig, ChaosStepExecutor, FaultEvent, FaultKind,
        FaultPlan, PlacementKind, RetryPolicy, ScenarioConfig, ShardedServeConfig,
        ShardedStepExecutor, SimServeConfig, SimStepExecutor,
    };

    let cmd = Command::new("scenario", "trace-driven multi-tenant scenario on the virtual clock")
        .flag("burst", Some("300"), "opening-burst request count")
        .flag("rate", Some("400"), "steady Poisson rate after the burst (req/s)")
        .flag("duration", Some("1"), "Poisson segment length (virtual seconds)")
        .flag("requests", Some("0"), "cap on total arrivals; 0 = the full trace")
        .flag("queue", Some("64"), "global admission bound across tenant lanes")
        .flag("ep", Some("4"), "expert-parallel shards (1 = unsharded executor)")
        .flag("placement", Some("balanced"), "expert placement: static|balanced")
        .flag("kill-at", Some("0.3"), "virtual time the shard dies; negative = never")
        .flag("recover-at", Some("0.6"), "virtual time it returns; negative = never")
        .flag("shard", Some("1"), "shard the fault plan targets")
        .flag("retry", Some("1"), "max step attempts for transient failures (1 = no retry)")
        .flag("backoff-ms", Some("0"), "virtual retry backoff between attempts, ms")
        .flag("deadline-ms", Some("0"), "per-request deadline in virtual ms; 0 = none")
        .flag("chaos-rate", Some("0.1"), "transient-fault probability per step under --chaos")
        .switch("chaos", "inject seeded transient faults at the executor boundary")
        .flag("seed", Some("1"), "arrival / tenant-assignment / prompt seed");
    let p = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let seed = p.u64("seed").unwrap_or(1);
    let mut faults = Vec::new();
    let shard = p.usize("shard").unwrap_or(1);
    let kill_at = p.f64("kill-at").unwrap_or(0.3);
    let recover_at = p.f64("recover-at").unwrap_or(0.6);
    if kill_at >= 0.0 {
        faults.push(FaultEvent { at_s: kill_at, shard, kind: FaultKind::Kill });
        if recover_at >= 0.0 {
            faults.push(FaultEvent { at_s: recover_at, shard, kind: FaultKind::Recover });
        }
    }
    let cfg = ScenarioConfig {
        trace: ArrivalTrace::new()
            .burst(p.usize("burst").unwrap_or(300), 0.0)
            .poisson(p.f64("rate").unwrap_or(400.0), p.f64("duration").unwrap_or(1.0)),
        faults: FaultPlan::new(faults),
        queue_capacity: p.usize("queue").unwrap_or(64).max(1),
        max_requests: p.usize("requests").unwrap_or(0),
        retry: RetryPolicy {
            max_attempts: p.usize("retry").unwrap_or(1).max(1) as u32,
            backoff: std::time::Duration::from_secs_f64(
                p.f64("backoff-ms").unwrap_or(0.0).max(0.0) / 1e3,
            ),
        },
        request_deadline_s: p.f64("deadline-ms").unwrap_or(0.0).max(0.0) / 1e3,
        seed,
        ..ScenarioConfig::default()
    };
    let chaos = p.bool("chaos").then(|| ChaosConfig {
        seed: seed ^ 0xC4A0,
        transient_rate: p.f64("chaos-rate").unwrap_or(0.1).clamp(0.0, 1.0),
        ..ChaosConfig::default()
    });
    let ep = p.usize("ep").unwrap_or(4).max(1);
    let report = if ep > 1 {
        let placement = match PlacementKind::from_name(&p.str("placement")) {
            Some(k) => k,
            None => {
                eprintln!("unknown placement '{}' (static|balanced)", p.str("placement"));
                return 2;
            }
        };
        let ex = ShardedStepExecutor::new(ShardedServeConfig {
            base: SimServeConfig { numeric: false, seed, ..SimServeConfig::default() },
            ep,
            placement,
            ..ShardedServeConfig::default()
        });
        match chaos {
            Some(c) => run_scenario(&mut ChaosStepExecutor::new(ex, c), &cfg),
            None => {
                let mut ex = ex;
                run_scenario(&mut ex, &cfg)
            }
        }
    } else {
        let ex = SimStepExecutor::new(SimServeConfig {
            numeric: false,
            seed,
            ..SimServeConfig::default()
        });
        match chaos {
            Some(c) => run_scenario(&mut ChaosStepExecutor::new(ex, c), &cfg),
            None => {
                let mut ex = ex;
                run_scenario(&mut ex, &cfg)
            }
        }
    };
    println!("{}", report.render());
    if report.failed > 0 {
        1
    } else {
        0
    }
}

fn cmd_client(args: &[String]) -> i32 {
    use std::io::{BufRead, BufReader, Write};
    let cmd = Command::new("client", "send synthetic requests to a server")
        .flag("addr", Some("127.0.0.1:7433"), "server address")
        .flag("requests", Some("20"), "number of requests")
        .flag("len", Some("12"), "tokens per request");
    let p = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let n = p.usize("requests").unwrap_or(20);
    let len = p.usize("len").unwrap_or(12);
    let stream = match std::net::TcpStream::connect(p.str("addr")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("connect: {e}");
            return 1;
        }
    };
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let mut rng = staticbatch::util::rng::Rng::new(1);
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let toks: Vec<String> =
            (0..len).map(|_| rng.below(1000).to_string()).collect();
        writeln!(w, "{{\"id\":{i},\"tokens\":[{}]}}", toks.join(",")).unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        print!("{line}");
    }
    println!(
        "{n} requests in {:.2}s ({:.1} req/s)",
        t0.elapsed().as_secs_f64(),
        n as f64 / t0.elapsed().as_secs_f64()
    );
    let _ = writeln!(w, "quit");
    0
}

fn cmd_selftest() -> i32 {
    use staticbatch::exec::{CpuBackend, NumericInputs};
    use staticbatch::moe::cpu_exec;
    use staticbatch::moe::token_index::TokenIndex;
    use staticbatch::util::rng::Rng;
    use staticbatch::util::tensor::Tensor;

    let shape = MoeShape::tiny();
    let load = LoadScenario::Dirichlet(0.5).counts(&shape, 1);
    let mut rng = Rng::new(7);
    let tokens = Tensor::randn(&[shape.seq, shape.d_model], 1.0, &mut rng);
    let weights = Tensor::randn(&[shape.experts, shape.d_model, shape.d_ff], 0.1, &mut rng);
    let mut pairs = Vec::new();
    for (e, &c) in load.counts.iter().enumerate() {
        for _ in 0..c {
            pairs.push((rng.usize_below(shape.seq) as u32, e as u32));
        }
    }
    let ti = TokenIndex::build(shape.experts, &pairs);
    let gates: Vec<Vec<f32>> =
        ti.index.iter().map(|v| v.iter().map(|_| 0.5f32).collect()).collect();
    let want = {
        let inputs = cpu_exec::MoeInputs {
            tokens: &tokens,
            weights: &weights,
            token_index: &ti,
            gates: &gates,
        };
        cpu_exec::reference(&inputs, shape.seq, shape.d_model, shape.d_ff)
    };
    let mut session = ExecutionSession::new(shape)
        .backend(CpuBackend)
        .inputs(NumericInputs { tokens, weights, token_index: ti, gates });
    let out = match session.run(&load) {
        Ok(o) => o,
        Err(e) => {
            println!("selftest FAILED: {e}");
            return 1;
        }
    };
    let got = out.output.expect("cpu backend returns a tensor");
    let err = got.max_abs_diff(&want);
    println!("selftest: plan tiles={} max abs err={err:.2e}", out.blocks);
    if err < 1e-3 {
        println!("selftest OK");
        0
    } else {
        println!("selftest FAILED");
        1
    }
}
