//! Ragged batched attention decode: the second irregular workload.
//!
//! One decode step serves a batch of sequences whose KV caches have wildly
//! different lengths — the per-sequence work `ν(T_i) ∝ len_i` is exactly
//! the irregularity of paper Section 3, with σ handling sequences whose
//! cache is empty (fresh requests, evicted pages).  Each sequence is one
//! task; its tiles are (KV-chunk × head) pairs, with the chunk size chosen
//! per task from [`KV_CATALOG`] the way MoE picks GEMM tiles per expert —
//! long caches take big chunks, short ones small, and both kinds coexist
//! in one fused grid.
//!
//! The whole planning stack is shared with MoE: [`RaggedAttentionWorkload`]
//! implements [`Workload`], so the generic
//! [`Planner`](crate::workload::plan::Planner) runs the identical σ /
//! ordering / TilePrefix machinery, the generic
//! [`PlanCache`](crate::workload::cache::PlanCache) keys on the
//! per-sequence KV lengths, and the same
//! [`SimBackend`](crate::exec::SimBackend) /
//! [`CpuBackend`](crate::exec::CpuBackend) execute the plans — the CPU
//! path running real flash-decode-style numerics (online softmax per
//! chunk) *through the framework dispatch*, checked against a dense
//! softmax reference.
//!
//! The baseline a dense scheme is stuck with is [`PaddedDenseAttention`]:
//! every sequence padded to the batch max so the rectangular grid stays
//! trivially invertible — the padding reads and occupancy the σ machinery
//! deletes.  `staticbatch ragged` tabulates the comparison.

use crate::batching::dispatch::{DispatchError, DispatchRecord, DispatchTableBuilder};
use crate::batching::framework::StaticBatch;
use crate::batching::task::{TaskDescriptor, TaskKind};
use crate::exec::backend::{Backend, ExecContext, Outcome};
use crate::exec::backends::CpuBackend;
use crate::exec::error::ExecError;
use crate::moe::tiling::StrategyId;
use crate::sim::cost::{Dtype, TileWork};
use crate::sim::wave;
use crate::util::rng::{zipf_weights, Rng};
use crate::util::tensor::Tensor;
use crate::util::threadpool::ThreadPool;
use crate::workload::plan::Plan;
use crate::workload::Workload;

/// KV-chunk sizes (rows of K/V one tile covers), largest to smallest —
/// the attention analog of the GEMM tiling catalog.
pub const KV_CATALOG: &[usize] = &[512, 128, 32, 8];

/// Pick the KV chunk for a cache of `len` rows: the largest chunk that is
/// at least half-filled, falling back to the smallest (same rule as
/// [`crate::moe::tiling::select`]).
pub fn select_chunk(len: usize) -> StrategyId {
    for (i, &c) in KV_CATALOG.iter().enumerate() {
        if len >= c || len * 2 >= c {
            return i;
        }
    }
    KV_CATALOG.len() - 1
}

/// One decode step's load: the KV-cache length of every sequence in the
/// batch (0 = empty cache, an empty task).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RaggedLoad {
    pub lens: Vec<usize>,
}

impl RaggedLoad {
    /// Total KV rows across the batch.
    pub fn total(&self) -> usize {
        self.lens.iter().sum()
    }

    /// Longest cache in the batch (what padded-dense pads everyone to).
    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    /// Fraction of a padded `[seqs, max_len]` layout that is padding.
    pub fn padding_frac(&self) -> f64 {
        let dense = self.lens.len() * self.max_len();
        if dense == 0 {
            return 0.0;
        }
        1.0 - self.total() as f64 / dense as f64
    }
}

/// KV-length distributions for the sweep experiments.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RaggedScenario {
    /// Lengths uniform in `[1, max]`.
    Uniform(usize),
    /// Zipf-bucketed lengths with exponent `alpha`: most sequences short,
    /// a heavy tail up to `max` — steady-state decode traffic.
    Zipf(f64, usize),
}

impl RaggedScenario {
    /// Generate per-sequence KV lengths. Deterministic in `seed`.
    pub fn lens(&self, seqs: usize, seed: u64) -> RaggedLoad {
        let mut rng = Rng::new(seed);
        let lens = match *self {
            RaggedScenario::Uniform(max) => {
                (0..seqs).map(|_| 1 + rng.usize_below(max.max(1))).collect()
            }
            RaggedScenario::Zipf(alpha, max) => {
                let buckets = 64.min(max.max(1));
                let w = zipf_weights(buckets, alpha);
                (0..seqs)
                    .map(|_| ((rng.zipf(&w) + 1) * max.max(1)) / buckets)
                    .collect()
            }
        };
        RaggedLoad { lens }
    }

    pub fn name(&self) -> String {
        match self {
            RaggedScenario::Uniform(m) => format!("uniform(max {m})"),
            RaggedScenario::Zipf(a, m) => format!("zipf({a}, max {m})"),
        }
    }
}

/// One sequence's decode-attention task in the plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqTask {
    /// Sequence index in the batch.
    pub seq: u32,
    /// KV-cache rows this sequence attends over. 0 = empty.
    pub kv_len: usize,
    /// Index into [`KV_CATALOG`].
    pub strategy: StrategyId,
}

/// Ragged batched attention decode as a [`Workload`].  One query vector
/// per sequence per head attends over that sequence's KV cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RaggedAttentionWorkload {
    /// Attention heads (each head of each sequence gets its own tiles).
    pub heads: usize,
    /// Per-head feature width.
    pub head_dim: usize,
    /// Element width of Q/K/V (cost accounting).
    pub dtype_bytes: usize,
}

impl Workload for RaggedAttentionWorkload {
    type Load = RaggedLoad;
    type Task = SeqTask;
    type Inputs = RaggedInputs;

    fn name(&self) -> &'static str {
        "ragged-attn"
    }

    fn tasks(&self, load: &RaggedLoad, force_strategy: Option<StrategyId>) -> Vec<SeqTask> {
        load.lens
            .iter()
            .enumerate()
            .map(|(s, &len)| SeqTask {
                seq: s as u32,
                kv_len: len,
                strategy: force_strategy
                    .map(|f| f.min(KV_CATALOG.len() - 1))
                    .unwrap_or_else(|| select_chunk(len)),
            })
            .collect()
    }

    fn descriptor(&self, task: &SeqTask) -> TaskDescriptor {
        TaskDescriptor {
            kind: TaskKind::AttentionDecode { strategy: task.strategy },
            rows: task.kv_len,
            cols: self.heads,
            inner: self.head_dim,
            tile_rows: KV_CATALOG[task.strategy],
            tile_cols: 1,
        }
    }

    fn weight(&self, task: &SeqTask) -> usize {
        task.kv_len
    }

    fn signature_into(&self, load: &RaggedLoad, out: &mut Vec<u64>) {
        out.clear();
        out.extend(load.lens.iter().map(|&l| l as u64));
    }

    fn dtype(&self) -> Dtype {
        if self.dtype_bytes == 2 {
            Dtype::Bf16
        } else {
            Dtype::F32
        }
    }

    /// Flash-decode cost shape: one tile reads a `chunk × head_dim` K
    /// slice and V slice, dots them against the resident query vector, and
    /// writes one partial accumulator.  Heavily memory-bound — the KV
    /// traffic is the roofline, which is why padding it is so expensive.
    fn tiles(&self, task: &SeqTask, index: u32, decode_ns: f64) -> Vec<TileWork> {
        let d = self.head_dim;
        let ds = self.dtype().bytes() as f64;
        let chunk = KV_CATALOG[task.strategy];
        let chunks = task.kv_len.div_ceil(chunk);
        let mut out = Vec::with_capacity(chunks * self.heads);
        for mi in 0..chunks {
            let rows = (task.kv_len - mi * chunk).min(chunk);
            for h in 0..self.heads {
                out.push(TileWork {
                    task: index,
                    // L2 keys: the query vector (task, 1, m_tile=head) is
                    // reused across a head's chunks; each (chunk, head) KV
                    // slice (task, 0, n_tile) is read exactly once.
                    m_tile: h as u32,
                    n_tile: (mi * self.heads + h) as u32,
                    useful_flops: 4.0 * rows as f64 * d as f64,
                    occupied_flops: 4.0 * rows as f64 * d as f64,
                    weight_bytes: 2.0 * rows as f64 * d as f64 * ds,
                    token_bytes: d as f64 * ds,
                    out_bytes: d as f64 * ds,
                    decode_ns,
                });
            }
        }
        out
    }

    fn operand_bytes(&self, tasks: &[SeqTask]) -> f64 {
        let ds = self.dtype().bytes() as f64;
        let per_vec = (self.heads * self.head_dim) as f64 * ds;
        tasks
            .iter()
            // σ-elided empty caches touch no operands, not even their q/out
            // vectors (same zero-tile rule as the trait default)
            .filter(|t| t.kv_len > 0)
            .map(|t| 2.0 * t.kv_len as f64 * per_vec + 2.0 * per_vec)
            .sum()
    }
}

/// Real tensors of one ragged decode step, for the CPU numeric path.
pub struct RaggedInputs {
    /// `[seqs, heads * head_dim]` query vectors (one decode token each).
    pub q: Tensor,
    /// Per-sequence `[kv_len, heads * head_dim]` key cache.
    pub keys: Vec<Tensor>,
    /// Per-sequence `[kv_len, heads * head_dim]` value cache.
    pub values: Vec<Tensor>,
}

impl RaggedInputs {
    /// Deterministic synthetic Q/K/V consistent with a load.
    pub fn synthetic(w: &RaggedAttentionWorkload, load: &RaggedLoad, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let width = w.heads * w.head_dim;
        let q = Tensor::randn(&[load.lens.len(), width], 1.0, &mut rng);
        let keys = load
            .lens
            .iter()
            .map(|&l| Tensor::randn(&[l, width], 0.5, &mut rng))
            .collect();
        let values = load
            .lens
            .iter()
            .map(|&l| Tensor::randn(&[l, width], 1.0, &mut rng))
            .collect();
        RaggedInputs { q, keys, values }
    }
}

/// Online-softmax accumulator of one (task, head) pair.
#[derive(Clone)]
pub(crate) struct HeadState {
    pub(crate) m: f32,
    pub(crate) l: f32,
    pub(crate) acc: Vec<f32>,
}

impl HeadState {
    /// A fresh accumulator for a head of width `d` (m = −inf, l = 0,
    /// zeroed acc) — what every executor starts each (task, head) from.
    pub(crate) fn fresh(d: usize) -> Self {
        HeadState { m: f32::NEG_INFINITY, l: 0.0, acc: vec![0.0; d] }
    }
}

struct RaggedCtx<'a> {
    plan: &'a Plan<RaggedAttentionWorkload>,
    inputs: &'a RaggedInputs,
    /// `state[grid_task][head]` — merged across that pair's KV chunks.
    state: Vec<Vec<HeadState>>,
    trace: Option<Vec<DispatchRecord>>,
    /// chunk-local score scratch, reused across tiles
    scores: Vec<f32>,
}

/// Run one (KV-chunk, head) tile of `task`, folding the chunk into that
/// head's online-softmax accumulator in `state`.  The single numeric tile
/// body shared by the serial framework dispatch and [`execute_parallel`]:
/// both visit a task's tiles in ascending order, so the merge sequence —
/// and therefore every float — is identical on either path.  `scores` is
/// caller scratch, cleared and fully overwritten here.
pub(crate) fn run_decode_tile(
    inputs: &RaggedInputs,
    task: &SeqTask,
    desc: &TaskDescriptor,
    scale: f32,
    tile_idx: u32,
    state: &mut [HeadState],
    scores: &mut Vec<f32>,
) {
    let heads = desc.tiles_n() as u32;
    let (mi, h) = (tile_idx / heads, (tile_idx % heads) as usize);
    let chunk = desc.tile_rows;
    let row0 = mi as usize * chunk;
    let rows = (task.kv_len - row0).min(chunk);
    let seq = task.seq as usize;
    let q = &inputs.q.row(seq)[h * desc.inner..(h + 1) * desc.inner];
    let kt = &inputs.keys[seq];
    let vt = &inputs.values[seq];

    // chunk-local scores and max
    scores.clear();
    scores.resize(rows, 0.0);
    let mut local_max = f32::NEG_INFINITY;
    for (r, s) in scores.iter_mut().enumerate() {
        let krow = &kt.row(row0 + r)[h * desc.inner..(h + 1) * desc.inner];
        let dot: f32 = q.iter().zip(krow).map(|(a, b)| a * b).sum();
        *s = dot * scale;
        local_max = local_max.max(*s);
    }

    // online-softmax merge into the (task, head) accumulator
    let st = &mut state[h];
    let new_max = st.m.max(local_max);
    let corr = (st.m - new_max).exp(); // 0.0 on the first chunk (m = -inf)
    st.l *= corr;
    for a in st.acc.iter_mut() {
        *a *= corr;
    }
    for (r, &s) in scores.iter().enumerate() {
        let p = (s - new_max).exp();
        st.l += p;
        let vrow = &vt.row(row0 + r)[h * desc.inner..(h + 1) * desc.inner];
        for (a, &v) in st.acc.iter_mut().zip(vrow) {
            *a += p * v;
        }
    }
    st.m = new_max;
}

/// Final flash-decode normalize: `out[seq, h·d + j] = acc / l`, tasks in
/// grid order, empty caches left zero.  Shared by both executors.
fn normalize(plan: &Plan<RaggedAttentionWorkload>, states: &[Vec<HeadState>]) -> Tensor {
    let w = plan.workload;
    let d = w.head_dim;
    let seqs = plan.tasks.len();
    let mut out = Tensor::zeros(&[seqs, w.heads * d]);
    for (ti, task) in plan.tasks.iter().enumerate() {
        if task.kv_len == 0 {
            continue;
        }
        let row = out.row_mut(task.seq as usize);
        for (h, st) in states[ti].iter().enumerate() {
            for (j, &a) in st.acc.iter().enumerate() {
                row[h * d + j] = a / st.l;
            }
        }
    }
    out
}

/// Execute a ragged plan numerically *through the framework dispatch*:
/// every (KV-chunk, head) tile goes `block index → Algorithm 4 mapping →
/// strategy-specific device function`, each tile folds its chunk into the
/// (sequence, head) accumulator with the online-softmax merge, and the
/// final normalize produces `[seqs, heads * head_dim]` outputs.  Returns
/// the dispatch trace too when requested (cross-backend agreement tests).
pub fn execute_traced(
    plan: &Plan<RaggedAttentionWorkload>,
    inputs: &RaggedInputs,
    record_dispatch: bool,
) -> Result<(Tensor, Option<Vec<DispatchRecord>>), DispatchError> {
    let w = plan.workload;
    let d = w.head_dim;
    let scale = 1.0 / (d as f32).sqrt();

    let mut builder: DispatchTableBuilder<RaggedCtx> = DispatchTableBuilder::new();
    for sid in 0..KV_CATALOG.len() {
        let kind = TaskKind::AttentionDecode { strategy: sid };
        builder = builder.on(kind, move |ctx: &mut RaggedCtx, desc, task_idx, tile_idx| {
            if let Some(trace) = ctx.trace.as_mut() {
                trace.push(DispatchRecord { task: task_idx, tile: tile_idx, kind: desc.kind });
            }
            let task = ctx.plan.tasks[task_idx as usize];
            run_decode_tile(
                ctx.inputs,
                &task,
                desc,
                scale,
                tile_idx,
                &mut ctx.state[task_idx as usize],
                &mut ctx.scores,
            );
        });
    }
    let batch = StaticBatch::try_new(plan.descriptors(), builder)?;

    let fresh = HeadState::fresh(d);
    let mut ctx = RaggedCtx {
        plan,
        inputs,
        state: vec![vec![fresh; w.heads]; plan.tasks.len()],
        trace: record_dispatch.then(Vec::new),
        scores: Vec::new(),
    };
    let blocks = batch.run(&mut ctx);
    debug_assert_eq!(blocks, plan.total_tiles());

    let out = normalize(plan, &ctx.state);
    Ok((out, ctx.trace))
}

/// Execute a ragged plan with per-task fan-out across `pool`'s workers.
///
/// Each worker job runs one chunk of sequences, folding every sequence's
/// (KV-chunk, head) tiles in ascending tile order — the order the serial
/// grid walk visits them — into owned per-task accumulators; the normalize
/// then walks tasks in grid order on the calling thread.  Same tile body
/// ([`run_decode_tile`]), same merge order, same normalize: the output is
/// **bitwise-equal** to the serial path.
///
/// A worker panic or pool shutdown surfaces as [`ExecError::Backend`] with
/// the [`crate::util::threadpool::PoolError`] preserved as the structured
/// error source (downcastable, never mis-bucketed as transient).
pub fn execute_parallel(
    plan: &Plan<RaggedAttentionWorkload>,
    inputs: &RaggedInputs,
    pool: &ThreadPool,
) -> Result<Tensor, ExecError> {
    let w = plan.workload;
    let d = w.head_dim;
    let heads = w.heads;
    let scale = 1.0 / (d as f32).sqrt();
    let descs = plan.descriptors();
    let tasks = &plan.tasks;
    let descs_ref = &descs;
    let job = move |ti: usize| -> Vec<HeadState> {
        let task = tasks[ti];
        let desc = &descs_ref[ti];
        let fresh = HeadState::fresh(d);
        let mut state = vec![fresh; heads];
        let mut scores = Vec::new();
        for tile in 0..desc.num_tiles() as u32 {
            run_decode_tile(inputs, &task, desc, scale, tile, &mut state, &mut scores);
        }
        state
    };
    let indices: Vec<usize> = (0..plan.tasks.len()).collect();
    let chunk = pool.default_chunk(indices.len());
    let states = pool
        .scoped_map_chunks(indices, chunk, job)
        .map_err(|e| ExecError::backend_caused("cpu", format!("worker pool: {e}"), e))?;
    Ok(normalize(plan, &states))
}

/// Dense reference: full softmax attention per (sequence, head) with no
/// chunking, tiling, or mapping — the unambiguous oracle.
pub fn reference(w: &RaggedAttentionWorkload, load: &RaggedLoad, inputs: &RaggedInputs) -> Tensor {
    let d = w.head_dim;
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Tensor::zeros(&[load.lens.len(), w.heads * d]);
    for (s, &len) in load.lens.iter().enumerate() {
        if len == 0 {
            continue;
        }
        for h in 0..w.heads {
            let q = &inputs.q.row(s)[h * d..(h + 1) * d];
            let scores: Vec<f32> = (0..len)
                .map(|r| {
                    let krow = &inputs.keys[s].row(r)[h * d..(h + 1) * d];
                    q.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale
                })
                .collect();
            let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|&x| (x - max).exp()).collect();
            let denom: f32 = exps.iter().sum();
            let row = out.row_mut(s);
            for (r, &e) in exps.iter().enumerate() {
                let vrow = &inputs.values[s].row(r)[h * d..(h + 1) * d];
                for (j, &v) in vrow.iter().enumerate() {
                    row[h * d + j] += e * v / denom;
                }
            }
        }
    }
    out
}

impl Backend<RaggedAttentionWorkload> for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn execute(
        &mut self,
        plan: &Plan<RaggedAttentionWorkload>,
        ctx: &mut ExecContext<'_, RaggedAttentionWorkload>,
    ) -> Result<Outcome, ExecError> {
        let inputs = ctx.numeric.ok_or(ExecError::MissingInputs {
            backend: "cpu",
            what: "ragged numeric inputs (q / keys / values)",
        })?;
        // Parallel when a multi-worker pool is attached and no dispatch
        // trace was requested; bitwise-equal output either way.
        let (output, trace) = match &ctx.pool {
            Some(pool) if pool.workers() > 1 && !ctx.record_dispatch => {
                (execute_parallel(plan, inputs, pool)?, None)
            }
            _ => execute_traced(plan, inputs, ctx.record_dispatch)?,
        };
        Ok(Outcome {
            backend: "cpu",
            blocks: plan.total_tiles(),
            sim: None,
            output: Some(output),
            trace,
        })
    }
}

/// The dense baseline: every sequence padded to the batch's longest KV
/// cache, so the rectangular `(seq, chunk, head)` grid needs no mapping
/// metadata at all — and stages every padded KV row from HBM while its
/// lanes idle.  This is what a static scheme without σ/TilePrefix must do;
/// the `staticbatch ragged` table quantifies the gap.
pub struct PaddedDenseAttention;

impl Backend<RaggedAttentionWorkload> for PaddedDenseAttention {
    fn name(&self) -> &'static str {
        "ragged/padded-dense"
    }

    fn execute(
        &mut self,
        plan: &Plan<RaggedAttentionWorkload>,
        ctx: &mut ExecContext<'_, RaggedAttentionWorkload>,
    ) -> Result<Outcome, ExecError> {
        let w = plan.workload;
        let d = w.head_dim as f64;
        let ds = w.dtype().bytes() as f64;
        let max_len = plan.tasks.iter().map(|t| t.kv_len).max().unwrap_or(0);
        let host = ctx.spec.launch_us * 1e-6; // dense grid: launch only
        if max_len == 0 {
            let sim = wave::run_waves(&[], &ctx.spec, host);
            return Ok(Outcome { backend: self.name(), blocks: 0, sim: Some(sim), output: None, trace: None });
        }
        let chunk = KV_CATALOG[select_chunk(max_len)];
        let chunks = max_len.div_ceil(chunk);
        let mut tiles = Vec::with_capacity(plan.tasks.len() * chunks * w.heads);
        for (ti, task) in plan.tasks.iter().enumerate() {
            for mi in 0..chunks {
                // real rows of this padded chunk (0 for fully-padded ones)
                let real = task.kv_len.saturating_sub(mi * chunk).min(chunk);
                for h in 0..w.heads {
                    tiles.push(TileWork {
                        task: ti as u32,
                        m_tile: h as u32,
                        n_tile: (mi * w.heads + h) as u32,
                        useful_flops: 4.0 * real as f64 * d,
                        // the lanes sweep the whole padded chunk
                        occupied_flops: 4.0 * chunk as f64 * d,
                        // the padded KV layout is materialized densely, so
                        // padding rows are staged from HBM like real ones
                        weight_bytes: 2.0 * chunk as f64 * d * ds,
                        token_bytes: d * ds,
                        out_bytes: d * ds,
                        decode_ns: 0.0,
                    });
                }
            }
        }
        let blocks = tiles.len() as u32;
        let sim = wave::run_waves(&tiles, &ctx.spec, host);
        Ok(Outcome { backend: self.name(), blocks, sim: Some(sim), output: None, trace: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::backends::SimBackend;
    use crate::exec::session::ExecutionSession;
    use crate::sim::specs::GpuSpec;

    fn workload() -> RaggedAttentionWorkload {
        RaggedAttentionWorkload { heads: 2, head_dim: 8, dtype_bytes: 4 }
    }

    #[test]
    fn chunk_selection_mirrors_the_tiling_rule() {
        assert_eq!(KV_CATALOG[select_chunk(4096)], 512);
        assert_eq!(KV_CATALOG[select_chunk(512)], 512);
        // half-full rule: 256 rows half-fill a 512 chunk
        assert_eq!(KV_CATALOG[select_chunk(256)], 512);
        assert_eq!(KV_CATALOG[select_chunk(255)], 128);
        assert_eq!(KV_CATALOG[select_chunk(9)], 8);
        assert_eq!(KV_CATALOG[select_chunk(1)], 8);
    }

    #[test]
    fn descriptor_tile_count_is_chunks_times_heads() {
        let w = workload();
        let tasks = w.tasks(&RaggedLoad { lens: vec![700, 9, 0] }, None);
        let d0 = w.descriptor(&tasks[0]);
        assert_eq!(d0.num_tiles(), 700usize.div_ceil(512) * 2);
        assert_eq!(w.descriptor(&tasks[1]).num_tiles(), 2 * 2);
        assert_eq!(w.descriptor(&tasks[2]).num_tiles(), 0);
        // the simulator tile stream covers exactly the descriptor count
        assert_eq!(w.tiles(&tasks[0], 0, 0.0).len(), d0.num_tiles());
    }

    #[test]
    fn cpu_numerics_match_dense_reference() {
        let w = workload();
        let load = RaggedLoad { lens: vec![70, 1, 0, 513, 33] };
        let inputs = RaggedInputs::synthetic(&w, &load, 7);
        let plan = crate::workload::plan::Planner::for_workload(w).plan(&load);
        let (got, _) = execute_traced(&plan, &inputs, false).expect("dispatch covered");
        let want = reference(&w, &load, &inputs);
        let err = got.max_abs_diff(&want);
        assert!(err < 1e-4, "max abs err {err}");
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let w = workload();
        let load = RaggedLoad { lens: vec![700, 1, 0, 513, 33, 8, 0, 129] };
        let inputs = RaggedInputs::synthetic(&w, &load, 11);
        let plan = crate::workload::plan::Planner::for_workload(w).plan(&load);
        let (serial, _) = execute_traced(&plan, &inputs, false).expect("dispatch covered");
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            let par = execute_parallel(&plan, &inputs, &pool).unwrap();
            assert_eq!(serial.shape, par.shape);
            assert_eq!(serial.data, par.data, "threads={threads}");
        }
    }

    #[test]
    fn empty_caches_produce_zero_rows() {
        let w = workload();
        let load = RaggedLoad { lens: vec![0, 12, 0] };
        let inputs = RaggedInputs::synthetic(&w, &load, 3);
        let plan = crate::workload::plan::Planner::for_workload(w).plan(&load);
        let (out, _) = execute_traced(&plan, &inputs, false).expect("runs");
        assert!(out.row(0).iter().all(|&x| x == 0.0));
        assert!(out.row(2).iter().all(|&x| x == 0.0));
        assert!(out.row(1).iter().any(|&x| x != 0.0));
    }

    #[test]
    fn padded_dense_never_faster_and_much_worse_under_skew() {
        let w = RaggedAttentionWorkload { heads: 32, head_dim: 128, dtype_bytes: 2 };
        let load = RaggedScenario::Zipf(1.4, 8192).lens(256, 1);
        assert!(load.padding_frac() > 0.5, "skewed lengths pad heavily");
        let ours = ExecutionSession::for_workload(w)
            .gpu(GpuSpec::h800())
            .backend(SimBackend::ours())
            .run(&load)
            .unwrap();
        let mut padded_session = ExecutionSession::for_workload(w)
            .gpu(GpuSpec::h800())
            .backend(PaddedDenseAttention);
        let padded = padded_session.run(&load).unwrap();
        assert!(padded.time_s() >= ours.time_s());
        assert!(
            padded.time_s() > ours.time_s() * 1.5,
            "padding waste must dominate under skew: {} vs {}",
            padded.time_s(),
            ours.time_s()
        );
        assert!(padded.sim().padding_waste() > ours.sim().padding_waste());
    }

    #[test]
    fn ragged_session_caches_plans_by_length_signature() {
        let w = workload();
        let load = RaggedScenario::Uniform(256).lens(16, 5);
        let mut s = ExecutionSession::for_workload(w).plan_cache(4);
        let a = s.run(&load).unwrap();
        let b = s.run(&load).unwrap();
        assert_eq!(a.blocks, b.blocks);
        let stats = s.cache_stats().expect("cache enabled");
        assert_eq!((stats.hits, stats.misses), (1, 1));
    }
}
