//! The fused transformer-layer super-workload: heterogeneous task kinds in
//! one static batch.
//!
//! The paper's framework claims generality — any set of tasks whose tile
//! counts ν(T) are known up front can share one fused kernel, one σ, one
//! TilePrefix.  The MoE and ragged-attention workloads each exercised the
//! machinery with a *single* task kind per plan; this module composes them:
//! a [`FusedLayerWorkload`] plans a whole transformer-layer step — ragged
//! decode attention, chunked causal prefill, and routed expert-FFN GEMMs —
//! as **one** `Plan` with three task kinds under a single σ.  Nothing in
//! `batching/`, `workload/plan.rs`, or the simulator changes for this: the
//! descriptors carry per-kind tile geometry, the dispatch table routes each
//! block to its kind's device function (Algorithm 3), and the two-stage map
//! elides empty sequences and idle experts alike (Algorithm 4).
//!
//! Layout and data flow: the planner groups non-empty tasks by
//! [`Workload::phase`] — attention (decode + prefill) first, expert GEMMs
//! second — ordering *within* each phase with the configured strategy.  The
//! CPU executor walks the grid in block order, so the first expert tile is a
//! natural barrier: it finalizes the online-softmax accumulators into the
//! activation matrix that the expert GEMMs then gather from (attention
//! output feeds routing feeds expert FFN).  Because ordering strategies are
//! pure functions of `(canonical index, weight)` pairs, each phase's
//! permutation matches what the standalone workload's planner would emit,
//! and the fused output is **bitwise-equal** to running ragged attention
//! then MoE as two separate plans — the property `tests/fused_transformer`
//! pins.
//!
//! Mixed prefill+decode is the classic continuous-batching irregularity: a
//! freshly admitted prompt needs O(P²) causal attention while its neighbors
//! decode one token each.  [`SeqSpec::Prefill`] models it as a third task
//! kind ([`TaskKind::PrefillChunk`]) with its own chunk catalog and cost
//! shape; a padded-dense scheme must pad every sequence to the longest
//! prompt's span ([`PaddedDenseFused`] quantifies the waste).

use crate::batching::dispatch::{DispatchError, DispatchRecord, DispatchTableBuilder};
use crate::batching::framework::StaticBatch;
use crate::batching::task::{TaskDescriptor, TaskKind};
use crate::exec::backend::{Backend, ExecContext, Outcome};
use crate::exec::backends::CpuBackend;
use crate::exec::error::ExecError;
use crate::moe::config::MoeShape;
use crate::moe::cpu_exec::{combine_task_regions, run_gemm_tile, GemmScratch, MoeInputs};
use crate::moe::planner::ExpertTask;
use crate::moe::tiling::{self, StrategyId, CATALOG};
use crate::moe::token_index::TokenIndex;
use crate::sim::cost::{gemm_tiles, Dtype, TileWork};
use crate::sim::wave;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;
use crate::util::threadpool::ThreadPool;
use crate::workload::plan::Plan;
use crate::workload::ragged::{
    run_decode_tile, select_chunk, HeadState, RaggedAttentionWorkload, RaggedInputs, RaggedLoad,
    SeqTask, KV_CATALOG,
};
use crate::workload::Workload;

/// Prefill chunk sizes (query rows one tile covers), largest to smallest —
/// prompts are long, so the catalog sits above [`KV_CATALOG`].
pub const PREFILL_CATALOG: &[usize] = &[1024, 256, 64, 16];

/// Pick the prefill chunk for a prompt of `len` rows: largest chunk at
/// least half-filled, falling back to the smallest (the same rule as
/// [`select_chunk`] and [`crate::moe::tiling::select`]).
pub fn select_prefill_chunk(len: usize) -> StrategyId {
    for (i, &c) in PREFILL_CATALOG.iter().enumerate() {
        if len >= c || len * 2 >= c {
            return i;
        }
    }
    PREFILL_CATALOG.len() - 1
}

/// What one sequence slot of the formed batch is doing this step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqSpec {
    /// Idle slot (no request, or a request with no KV yet) — σ elides it.
    Empty,
    /// One decode token attending over `kv_len` cached rows.
    Decode { kv_len: usize },
    /// A freshly admitted prompt of `len` tokens in chunked causal prefill.
    Prefill { len: usize },
}

impl SeqSpec {
    /// KV rows this slot's attention spans (0 for an empty slot).
    pub fn kv_len(&self) -> usize {
        match *self {
            SeqSpec::Empty => 0,
            SeqSpec::Decode { kv_len } => kv_len,
            SeqSpec::Prefill { len } => len,
        }
    }

    fn tag(&self) -> u64 {
        match self {
            SeqSpec::Empty => 0,
            SeqSpec::Decode { .. } => 1,
            SeqSpec::Prefill { .. } => 2,
        }
    }
}

/// One fused step's load: the attention side (per-slot sequence specs) and
/// the FFN side (rows routed per expert) of the *same* formed batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FusedLoad {
    /// One entry per sequence slot; length must equal the workload's
    /// `shape.seq` capacity.
    pub seqs: Vec<SeqSpec>,
    /// Rows routed to each expert (length = `shape.experts`).
    pub expert_counts: Vec<usize>,
}

impl FusedLoad {
    /// The attention phase viewed as a standalone ragged load (the
    /// sequential baseline plans from this).
    pub fn ragged(&self) -> RaggedLoad {
        RaggedLoad { lens: self.seqs.iter().map(|s| s.kv_len()).collect() }
    }

    /// The FFN phase viewed as a standalone MoE load.
    pub fn expert_load(&self) -> crate::moe::routing::ExpertLoad {
        crate::moe::routing::ExpertLoad { counts: self.expert_counts.clone() }
    }

    /// A deterministic mixed serving moment for reports and benches:
    /// roughly 1/8 of the slots idle, 1/4 freshly admitted prompts in
    /// chunked prefill, the rest decoding over wide-ranging KV spans; the
    /// active slots' routed rows land on experts with quadratic skew (the
    /// popular-expert regime the σ machinery exists for).
    pub fn sample_mixed(shape: &MoeShape, seed: u64) -> FusedLoad {
        let mut rng = Rng::new(seed ^ 0xF05E);
        let seqs: Vec<SeqSpec> = (0..shape.seq)
            .map(|_| match rng.below(8) {
                0 => SeqSpec::Empty,
                1 | 2 => SeqSpec::Prefill { len: 64 + rng.usize_below(1985) },
                _ => SeqSpec::Decode { kv_len: 1 + rng.usize_below(8192) },
            })
            .collect();
        let active = seqs.iter().filter(|s| s.kv_len() > 0).count();
        let mut expert_counts = vec![0usize; shape.experts];
        for _ in 0..active * shape.top_k {
            let r = rng.f32();
            let e = ((r * r) * shape.experts as f32) as usize;
            expert_counts[e.min(shape.experts - 1)] += 1;
        }
        FusedLoad { seqs, expert_counts }
    }
}

/// One task of the fused grid.  The attention-side payloads reuse
/// [`SeqTask`] (for prefill, `kv_len` is the prompt length and `strategy`
/// indexes [`PREFILL_CATALOG`]); the FFN side reuses [`ExpertTask`] — the
/// task bodies are literally the standalone workloads' tile bodies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FusedTask {
    /// Phase 0: decode attention for one sequence slot.
    Attention(SeqTask),
    /// Phase 0: chunked causal prefill for one sequence slot.
    Prefill(SeqTask),
    /// Phase 1: routed-token GEMM of one expert.
    Expert(ExpertTask),
}

/// A whole transformer-layer step (attention + routed FFN) as one
/// heterogeneous [`Workload`].  `shape.d_model` must equal
/// `heads · head_dim`: the attention output rows are exactly the
/// activations the expert GEMMs gather.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FusedLayerWorkload {
    /// Attention geometry (heads, head width, KV dtype).
    pub attn: RaggedAttentionWorkload,
    /// Expert-FFN geometry (`seq` = sequence-slot capacity).
    pub shape: MoeShape,
}

impl FusedLayerWorkload {
    /// A fused layer over `shape` with `heads` attention heads; the head
    /// width is derived so attention output width equals `d_model`.
    ///
    /// # Panics
    /// If `heads` does not divide `shape.d_model`.
    pub fn new(heads: usize, shape: MoeShape) -> Self {
        assert_eq!(
            shape.d_model % heads,
            0,
            "heads ({heads}) must divide d_model ({})",
            shape.d_model
        );
        let attn = RaggedAttentionWorkload {
            heads,
            head_dim: shape.d_model / heads,
            dtype_bytes: shape.dtype_bytes,
        };
        FusedLayerWorkload { attn, shape }
    }

    /// A small shape for tests and quickstarts.
    pub fn tiny() -> Self {
        FusedLayerWorkload::new(4, MoeShape::tiny())
    }
}

impl Workload for FusedLayerWorkload {
    type Load = FusedLoad;
    type Task = FusedTask;
    type Inputs = FusedInputs;

    fn name(&self) -> &'static str {
        "fused-layer"
    }

    fn tasks(&self, load: &FusedLoad, force_strategy: Option<StrategyId>) -> Vec<FusedTask> {
        assert_eq!(load.seqs.len(), self.shape.seq, "sequence slots must match shape.seq");
        assert_eq!(load.expert_counts.len(), self.shape.experts);
        let mut out = Vec::with_capacity(load.seqs.len() + load.expert_counts.len());
        for (s, spec) in load.seqs.iter().enumerate() {
            let seq = s as u32;
            match *spec {
                SeqSpec::Prefill { len } => out.push(FusedTask::Prefill(SeqTask {
                    seq,
                    kv_len: len,
                    strategy: force_strategy
                        .map(|f| f.min(PREFILL_CATALOG.len() - 1))
                        .unwrap_or_else(|| select_prefill_chunk(len)),
                })),
                // empty slots become zero-length decode tasks: weight 0,
                // zero tiles, σ-elided — identical to the ragged planner
                SeqSpec::Empty | SeqSpec::Decode { .. } => {
                    let kv_len = spec.kv_len();
                    out.push(FusedTask::Attention(SeqTask {
                        seq,
                        kv_len,
                        strategy: force_strategy
                            .map(|f| f.min(KV_CATALOG.len() - 1))
                            .unwrap_or_else(|| select_chunk(kv_len)),
                    }));
                }
            }
        }
        for (e, &rows) in load.expert_counts.iter().enumerate() {
            out.push(FusedTask::Expert(ExpertTask {
                expert: e as u32,
                rows,
                strategy: force_strategy.map(|f| f.min(CATALOG.len() - 1)).unwrap_or_else(|| {
                    if rows > 0 {
                        tiling::select(rows)
                    } else {
                        CATALOG.len() - 1
                    }
                }),
            }));
        }
        out
    }

    fn descriptor(&self, task: &FusedTask) -> TaskDescriptor {
        match *task {
            FusedTask::Attention(t) => self.attn.descriptor(&t),
            FusedTask::Prefill(t) => TaskDescriptor {
                kind: TaskKind::PrefillChunk { strategy: t.strategy },
                rows: t.kv_len,
                cols: self.attn.heads,
                inner: self.attn.head_dim,
                tile_rows: PREFILL_CATALOG[t.strategy],
                tile_cols: 1,
            },
            FusedTask::Expert(t) => t.descriptor(&self.shape),
        }
    }

    fn weight(&self, task: &FusedTask) -> usize {
        match task {
            FusedTask::Attention(t) | FusedTask::Prefill(t) => t.kv_len,
            FusedTask::Expert(t) => t.rows,
        }
    }

    fn signature_into(&self, load: &FusedLoad, out: &mut Vec<u64>) {
        out.clear();
        // slot count first so the seq / expert sections can't alias across
        // loads of different slot capacity
        out.push(load.seqs.len() as u64);
        out.extend(load.seqs.iter().map(|s| ((s.kv_len() as u64) << 2) | s.tag()));
        out.extend(load.expert_counts.iter().map(|&c| c as u64));
    }

    fn dtype(&self) -> Dtype {
        self.shape.dtype()
    }

    fn task_dtype(&self, task: &FusedTask) -> Dtype {
        match task {
            FusedTask::Attention(_) | FusedTask::Prefill(_) => self.attn.dtype(),
            FusedTask::Expert(_) => self.shape.dtype(),
        }
    }

    fn phase(&self, task: &FusedTask) -> usize {
        match task {
            FusedTask::Attention(_) | FusedTask::Prefill(_) => 0,
            FusedTask::Expert(_) => 1,
        }
    }

    /// Per-kind cost shapes: decode tiles reuse the ragged stream, prefill
    /// tiles charge chunked *causal* attention (each query chunk re-streams
    /// the KV prefix up to its own end), expert tiles reuse the MoE GEMM
    /// stream.  One heterogeneous tile stream through all four mapping
    /// modes.
    fn tiles(&self, task: &FusedTask, index: u32, decode_ns: f64) -> Vec<TileWork> {
        match *task {
            FusedTask::Attention(t) => self.attn.tiles(&t, index, decode_ns),
            FusedTask::Prefill(t) => {
                let d = self.attn.head_dim as f64;
                let ds = self.attn.dtype().bytes() as f64;
                let chunk = PREFILL_CATALOG[t.strategy];
                let chunks = t.kv_len.div_ceil(chunk);
                let mut out = Vec::with_capacity(chunks * self.attn.heads);
                for mi in 0..chunks {
                    let r0 = mi * chunk;
                    let rows = (t.kv_len - r0).min(chunk);
                    // causal pairs this query chunk covers: row r0+i
                    // attends r0+i+1 keys
                    let pairs = (rows * r0 + rows * (rows + 1) / 2) as f64;
                    for h in 0..self.attn.heads {
                        out.push(TileWork {
                            task: index,
                            m_tile: h as u32,
                            n_tile: (mi * self.attn.heads + h) as u32,
                            useful_flops: 4.0 * pairs * d,
                            occupied_flops: 4.0 * pairs * d,
                            // K + V prefix up to this chunk's end
                            weight_bytes: 2.0 * (r0 + rows) as f64 * d * ds,
                            token_bytes: rows as f64 * d * ds,
                            out_bytes: rows as f64 * d * ds,
                            decode_ns,
                        });
                    }
                }
                out
            }
            FusedTask::Expert(t) => {
                let s = CATALOG[t.strategy];
                gemm_tiles(
                    index,
                    t.rows,
                    self.shape.d_ff,
                    self.shape.d_model,
                    s.tm,
                    s.tn,
                    self.shape.dtype(),
                    decode_ns,
                )
            }
        }
    }
}

/// Real tensors of one fused step: the attention side's Q/K/V plus the FFN
/// side's expert weights and routing.  `attn.q` holds one query row per
/// sequence slot — for a prefill slot that is the *last* prompt position
/// (the one whose output the step actually routes onward); the cost model
/// still charges the full causal prefill.
pub struct FusedInputs {
    /// Q/K/V per sequence slot (`keys[s]` spans that slot's KV rows).
    pub attn: RaggedInputs,
    /// `[experts, d_model, d_ff]` expert weights.
    pub expert_weights: Tensor,
    /// Token index arrays per expert over sequence-slot rows.
    pub token_index: TokenIndex,
    /// Combine gate per (expert, position) — aligned with `token_index`.
    pub gates: Vec<Vec<f32>>,
}

impl FusedInputs {
    /// Deterministic synthetic inputs consistent with a load.
    pub fn synthetic(w: &FusedLayerWorkload, load: &FusedLoad, seed: u64) -> Self {
        let attn = RaggedInputs::synthetic(&w.attn, &load.ragged(), seed);
        let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
        let expert_weights =
            Tensor::randn(&[w.shape.experts, w.shape.d_model, w.shape.d_ff], 0.1, &mut rng);
        let mut pairs = Vec::new();
        for (e, &c) in load.expert_counts.iter().enumerate() {
            for _ in 0..c {
                pairs.push((rng.usize_below(load.seqs.len()) as u32, e as u32));
            }
        }
        let token_index = TokenIndex::build(w.shape.experts, &pairs);
        let gates = token_index
            .index
            .iter()
            .map(|rows| rows.iter().map(|_| rng.f32() * 0.5 + 0.25).collect())
            .collect();
        FusedInputs { attn, expert_weights, token_index, gates }
    }
}

struct FusedCtx<'a> {
    plan: &'a Plan<FusedLayerWorkload>,
    inputs: &'a FusedInputs,
    /// online-softmax state per grid task (attention-phase entries only).
    state: Vec<Vec<HeadState>>,
    /// `[seq_slots, d_model]` attention output; written at the barrier.
    activations: Tensor,
    barrier_crossed: bool,
    /// packed expert output rows, grid order, no tile padding.
    packed: Vec<f32>,
    /// packed-row offset per grid task (expert entries only).
    offsets: Vec<usize>,
    scores: Vec<f32>,
    scratch: GemmScratch,
    trace: Option<Vec<DispatchRecord>>,
}

/// Normalize the attention accumulators into the activation matrix:
/// `activations[seq, h·d + j] = acc / l`.  Same arithmetic per row as the
/// ragged normalize, so each sequence's activation row is bitwise what the
/// standalone ragged executor outputs.
fn finalize_attention(
    tasks: &[FusedTask],
    states: &[Vec<HeadState>],
    head_dim: usize,
    activations: &mut Tensor,
) {
    for (ti, task) in tasks.iter().enumerate() {
        let (FusedTask::Attention(t) | FusedTask::Prefill(t)) = task else { continue };
        if t.kv_len == 0 {
            continue;
        }
        let row = activations.row_mut(t.seq as usize);
        for (h, st) in states[ti].iter().enumerate() {
            for (j, &a) in st.acc.iter().enumerate() {
                row[h * head_dim + j] = a / st.l;
            }
        }
    }
}

fn attention_block(ctx: &mut FusedCtx, desc: &TaskDescriptor, task_idx: u32, tile_idx: u32, scale: f32) {
    if let Some(trace) = ctx.trace.as_mut() {
        trace.push(DispatchRecord { task: task_idx, tile: tile_idx, kind: desc.kind });
    }
    let (FusedTask::Attention(t) | FusedTask::Prefill(t)) = ctx.plan.tasks[task_idx as usize]
    else {
        unreachable!("attention kinds only dispatch to attention-phase tasks")
    };
    run_decode_tile(
        &ctx.inputs.attn,
        &t,
        desc,
        scale,
        tile_idx,
        &mut ctx.state[task_idx as usize],
        &mut ctx.scores,
    );
}

fn expert_block(ctx: &mut FusedCtx, desc: &TaskDescriptor, task_idx: u32, tile_idx: u32) {
    if let Some(trace) = ctx.trace.as_mut() {
        trace.push(DispatchRecord { task: task_idx, tile: tile_idx, kind: desc.kind });
    }
    // The first expert tile in block order is the phase barrier: every
    // attention tile already ran (phase-0 tasks precede phase-1 tasks in
    // the grid and the serial walk is block-ascending), so the activation
    // matrix the GEMMs gather from is complete.
    if !ctx.barrier_crossed {
        finalize_attention(
            &ctx.plan.tasks,
            &ctx.state,
            ctx.plan.workload.attn.head_dim,
            &mut ctx.activations,
        );
        ctx.barrier_crossed = true;
    }
    let FusedTask::Expert(task) = ctx.plan.tasks[task_idx as usize] else {
        unreachable!("GEMM kinds only dispatch to expert-phase tasks")
    };
    let d_ff = ctx.plan.workload.shape.d_ff;
    let base = ctx.offsets[task_idx as usize];
    let region = &mut ctx.packed[base * d_ff..(base + task.rows) * d_ff];
    let view = MoeInputs {
        tokens: &ctx.activations,
        weights: &ctx.inputs.expert_weights,
        token_index: &ctx.inputs.token_index,
        gates: &ctx.inputs.gates,
    };
    run_gemm_tile(&view, &task, desc, tile_idx, region, &mut ctx.scratch);
}

/// Execute a fused plan numerically *through the framework dispatch*: one
/// block-ascending walk over the heterogeneous grid, attention tiles fold
/// online-softmax accumulators, the first expert tile finalizes them into
/// the activation matrix, expert tiles gather-GEMM from it, and the gated
/// combine produces the `[seq_slots, d_ff]` layer output.  Returns the
/// dispatch trace too when requested (cross-backend agreement tests).
pub fn execute_traced(
    plan: &Plan<FusedLayerWorkload>,
    inputs: &FusedInputs,
    record_dispatch: bool,
) -> Result<(Tensor, Option<Vec<DispatchRecord>>), DispatchError> {
    let w = plan.workload;
    let d_ff = w.shape.d_ff;
    let scale = 1.0 / (w.attn.head_dim as f32).sqrt();

    let mut offsets = vec![0usize; plan.tasks.len()];
    let mut packed_rows = 0usize;
    for (ti, t) in plan.tasks.iter().enumerate() {
        if let FusedTask::Expert(e) = t {
            offsets[ti] = packed_rows;
            packed_rows += e.rows;
        }
    }

    let mut builder: DispatchTableBuilder<FusedCtx> = DispatchTableBuilder::new();
    for sid in 0..KV_CATALOG.len() {
        builder = builder.on(TaskKind::AttentionDecode { strategy: sid }, move |ctx, d, a, b| {
            attention_block(ctx, d, a, b, scale)
        });
    }
    for sid in 0..PREFILL_CATALOG.len() {
        builder = builder.on(TaskKind::PrefillChunk { strategy: sid }, move |ctx, d, a, b| {
            attention_block(ctx, d, a, b, scale)
        });
    }
    for sid in 0..CATALOG.len() {
        builder = builder.on(TaskKind::Gemm { strategy: sid }, expert_block);
    }
    let batch = StaticBatch::try_new(plan.descriptors(), builder)?;

    let fresh = HeadState::fresh(w.attn.head_dim);
    let mut ctx = FusedCtx {
        plan,
        inputs,
        state: vec![vec![fresh; w.attn.heads]; plan.tasks.len()],
        activations: Tensor::zeros(&[w.shape.seq, w.shape.d_model]),
        barrier_crossed: false,
        packed: vec![0.0; packed_rows * d_ff],
        offsets,
        scores: Vec::new(),
        scratch: GemmScratch::default(),
        trace: record_dispatch.then(Vec::new),
    };
    let blocks = batch.run(&mut ctx);
    debug_assert_eq!(blocks, plan.total_tiles());

    // grid-order expert tasks + their packed regions, for the gated combine
    let expert_tasks: Vec<ExpertTask> = plan
        .tasks
        .iter()
        .filter_map(|t| if let FusedTask::Expert(e) = t { Some(*e) } else { None })
        .collect();
    let regions: Vec<&[f32]> = plan
        .tasks
        .iter()
        .enumerate()
        .filter_map(|(ti, t)| {
            if let FusedTask::Expert(e) = t {
                Some(&ctx.packed[ctx.offsets[ti] * d_ff..(ctx.offsets[ti] + e.rows) * d_ff])
            } else {
                None
            }
        })
        .collect();
    let view = MoeInputs {
        tokens: &ctx.activations,
        weights: &inputs.expert_weights,
        token_index: &inputs.token_index,
        gates: &inputs.gates,
    };
    let out = combine_task_regions(&expert_tasks, w.shape.seq, d_ff, &view, &regions);
    Ok((out, ctx.trace))
}

/// Execute a fused plan with per-task fan-out across `pool`'s workers: the
/// attention phase fans out per sequence, a normalize barrier builds the
/// activation matrix, the expert phase fans out per expert, and the gated
/// combine runs on the calling thread in grid order.  Same tile bodies,
/// same per-task tile order, same normalize and combine order as the serial
/// path — the output is **bitwise-equal** to [`execute_traced`].
pub fn execute_parallel(
    plan: &Plan<FusedLayerWorkload>,
    inputs: &FusedInputs,
    pool: &ThreadPool,
) -> Result<Tensor, ExecError> {
    let w = plan.workload;
    let d = w.attn.head_dim;
    let heads = w.attn.heads;
    let d_ff = w.shape.d_ff;
    let scale = 1.0 / (d as f32).sqrt();
    let descs = plan.descriptors();
    let descs_ref = &descs;
    let tasks = &plan.tasks;

    // phase 0: attention fan-out per sequence task
    let attn_indices: Vec<usize> = tasks
        .iter()
        .enumerate()
        .filter(|(_, t)| matches!(t, FusedTask::Attention(_) | FusedTask::Prefill(_)))
        .map(|(i, _)| i)
        .collect();
    let attn_job = move |ti: usize| -> Vec<HeadState> {
        let (FusedTask::Attention(task) | FusedTask::Prefill(task)) = tasks[ti] else {
            unreachable!("attention indices filter attention tasks")
        };
        let desc = &descs_ref[ti];
        let mut state = vec![HeadState::fresh(d); heads];
        let mut scores = Vec::new();
        for tile in 0..desc.num_tiles() as u32 {
            run_decode_tile(&inputs.attn, &task, desc, scale, tile, &mut state, &mut scores);
        }
        state
    };
    let chunk = pool.default_chunk(attn_indices.len());
    let states = pool
        .scoped_map_chunks(attn_indices.clone(), chunk, attn_job)
        .map_err(|e| ExecError::backend_caused("cpu", format!("worker pool: {e}"), e))?;
    let mut all_states = vec![Vec::new(); plan.tasks.len()];
    for (ti, st) in attn_indices.into_iter().zip(states) {
        all_states[ti] = st;
    }
    let mut activations = Tensor::zeros(&[w.shape.seq, w.shape.d_model]);
    finalize_attention(&plan.tasks, &all_states, d, &mut activations);

    // phase 1: expert fan-out per expert task
    let expert_indices: Vec<usize> = tasks
        .iter()
        .enumerate()
        .filter(|(_, t)| matches!(t, FusedTask::Expert(_)))
        .map(|(i, _)| i)
        .collect();
    let activations_ref = &activations;
    let expert_job = move |ti: usize| -> Vec<f32> {
        let FusedTask::Expert(task) = tasks[ti] else {
            unreachable!("expert indices filter expert tasks")
        };
        let desc = &descs_ref[ti];
        let view = MoeInputs {
            tokens: activations_ref,
            weights: &inputs.expert_weights,
            token_index: &inputs.token_index,
            gates: &inputs.gates,
        };
        let mut region = vec![0.0f32; task.rows * d_ff];
        let mut scratch = GemmScratch::default();
        for tile in 0..desc.num_tiles() as u32 {
            run_gemm_tile(&view, &task, desc, tile, &mut region, &mut scratch);
        }
        region
    };
    let chunk = pool.default_chunk(expert_indices.len());
    let regions = pool
        .scoped_map_chunks(expert_indices.clone(), chunk, expert_job)
        .map_err(|e| ExecError::backend_caused("cpu", format!("worker pool: {e}"), e))?;

    let expert_tasks: Vec<ExpertTask> = expert_indices
        .iter()
        .map(|&ti| {
            let FusedTask::Expert(e) = tasks[ti] else { unreachable!() };
            e
        })
        .collect();
    let views: Vec<&[f32]> = regions.iter().map(|r| r.as_slice()).collect();
    let view = MoeInputs {
        tokens: &activations,
        weights: &inputs.expert_weights,
        token_index: &inputs.token_index,
        gates: &inputs.gates,
    };
    Ok(combine_task_regions(&expert_tasks, w.shape.seq, d_ff, &view, &views))
}

impl Backend<FusedLayerWorkload> for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn execute(
        &mut self,
        plan: &Plan<FusedLayerWorkload>,
        ctx: &mut ExecContext<'_, FusedLayerWorkload>,
    ) -> Result<Outcome, ExecError> {
        let inputs = ctx.numeric.ok_or(ExecError::MissingInputs {
            backend: "cpu",
            what: "fused layer inputs (q/kv, expert weights, routing)",
        })?;
        let (output, trace) = match &ctx.pool {
            Some(pool) if pool.workers() > 1 && !ctx.record_dispatch => {
                (execute_parallel(plan, inputs, pool)?, None)
            }
            _ => execute_traced(plan, inputs, ctx.record_dispatch)?,
        };
        Ok(Outcome {
            backend: "cpu",
            blocks: plan.total_tiles(),
            sim: None,
            output: Some(output),
            trace,
        })
    }
}

/// The dense baseline for the fused step: a scheme without σ/TilePrefix
/// pads the attention phase to the batch's longest KV span (prefill
/// prompts pad *everyone*) and the FFN phase to the busiest expert's row
/// count, each as its own rectangular kernel — two launches and all the
/// padding occupancy and HBM traffic the fused single-plan grid deletes.
pub struct PaddedDenseFused;

impl Backend<FusedLayerWorkload> for PaddedDenseFused {
    fn name(&self) -> &'static str {
        "fused/padded-dense"
    }

    fn execute(
        &mut self,
        plan: &Plan<FusedLayerWorkload>,
        ctx: &mut ExecContext<'_, FusedLayerWorkload>,
    ) -> Result<Outcome, ExecError> {
        let w = plan.workload;
        let d = w.attn.head_dim as f64;
        let ds = w.attn.dtype().bytes() as f64;
        let mut tiles: Vec<TileWork> = Vec::new();

        // attention: every slot padded to the longest KV span in the batch
        let max_len = plan
            .tasks
            .iter()
            .filter_map(|t| match t {
                FusedTask::Attention(s) | FusedTask::Prefill(s) => Some(s.kv_len),
                FusedTask::Expert(_) => None,
            })
            .max()
            .unwrap_or(0);
        if max_len > 0 {
            let chunk = KV_CATALOG[select_chunk(max_len)];
            let chunks = max_len.div_ceil(chunk);
            for (ti, task) in plan.tasks.iter().enumerate() {
                let (FusedTask::Attention(s) | FusedTask::Prefill(s)) = task else { continue };
                for mi in 0..chunks {
                    let real = s.kv_len.saturating_sub(mi * chunk).min(chunk);
                    for h in 0..w.attn.heads {
                        tiles.push(TileWork {
                            task: ti as u32,
                            m_tile: h as u32,
                            n_tile: (mi * w.attn.heads + h) as u32,
                            useful_flops: 4.0 * real as f64 * d,
                            occupied_flops: 4.0 * chunk as f64 * d,
                            weight_bytes: 2.0 * chunk as f64 * d * ds,
                            token_bytes: d * ds,
                            out_bytes: d * ds,
                            decode_ns: 0.0,
                        });
                    }
                }
            }
        }

        // FFN: every expert padded to the busiest expert's row count
        let max_rows = plan
            .tasks
            .iter()
            .filter_map(|t| if let FusedTask::Expert(e) = t { Some(e.rows) } else { None })
            .max()
            .unwrap_or(0);
        if max_rows > 0 {
            let s = CATALOG[tiling::select(max_rows)];
            let (d_ff, d_model) = (w.shape.d_ff, w.shape.d_model);
            let dsg = w.shape.dtype().bytes() as f64;
            let tiles_m = max_rows.div_ceil(s.tm);
            let tiles_n = d_ff.div_ceil(s.tn);
            for (ti, task) in plan.tasks.iter().enumerate() {
                let FusedTask::Expert(e) = task else { continue };
                for mi in 0..tiles_m {
                    let real = e.rows.saturating_sub(mi * s.tm).min(s.tm);
                    for ni in 0..tiles_n {
                        let cols = (d_ff - ni * s.tn).min(s.tn);
                        tiles.push(TileWork {
                            task: ti as u32,
                            m_tile: mi as u32,
                            n_tile: ni as u32,
                            useful_flops: 2.0 * real as f64 * cols as f64 * d_model as f64,
                            occupied_flops: 2.0 * s.tm as f64 * cols as f64 * d_model as f64,
                            weight_bytes: d_model as f64 * cols as f64 * dsg,
                            token_bytes: s.tm as f64 * d_model as f64 * dsg,
                            out_bytes: s.tm as f64 * cols as f64 * dsg,
                            decode_ns: 0.0,
                        });
                    }
                }
            }
        }

        // two rectangular kernels: two launches, no mapping metadata
        let host = 2.0 * ctx.spec.launch_us * 1e-6;
        let blocks = tiles.len() as u32;
        let sim = wave::run_waves(&tiles, &ctx.spec, host);
        Ok(Outcome { backend: self.name(), blocks, sim: Some(sim), output: None, trace: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::plan::Planner;

    fn small_load() -> FusedLoad {
        FusedLoad {
            seqs: (0..64)
                .map(|i| match i % 5 {
                    0 => SeqSpec::Empty,
                    1 => SeqSpec::Prefill { len: 30 + 7 * i },
                    _ => SeqSpec::Decode { kv_len: 1 + 13 * i },
                })
                .collect(),
            expert_counts: (0..8).map(|e| if e == 3 { 0 } else { 8 * e + 4 }).collect(),
        }
    }

    #[test]
    fn plan_mixes_three_kinds_under_one_sigma() {
        let w = FusedLayerWorkload::tiny();
        let plan = Planner::for_workload(w).plan(&small_load());
        let descs = plan.descriptors();
        let mut kinds = [false; 3];
        for d in &descs {
            match d.kind {
                TaskKind::AttentionDecode { .. } => kinds[0] = true,
                TaskKind::PrefillChunk { .. } => kinds[1] = true,
                TaskKind::Gemm { .. } => kinds[2] = true,
                _ => {}
            }
        }
        assert_eq!(kinds, [true, true, true]);
        // σ covers exactly the non-empty tiles
        let tiles: usize = descs.iter().map(|d| d.num_tiles()).sum();
        assert_eq!(plan.total_tiles() as usize, tiles);
    }

    #[test]
    fn attention_phase_precedes_expert_phase_in_the_grid() {
        let w = FusedLayerWorkload::tiny();
        let plan = Planner::for_workload(w).plan(&small_load());
        let nonempty = plan.num_nonempty();
        let first_expert = plan.tasks[..nonempty]
            .iter()
            .position(|t| matches!(t, FusedTask::Expert(_)))
            .expect("non-empty expert tasks exist");
        assert!(plan.tasks[..first_expert]
            .iter()
            .all(|t| matches!(t, FusedTask::Attention(_) | FusedTask::Prefill(_))));
        assert!(plan.tasks[first_expert..nonempty]
            .iter()
            .all(|t| matches!(t, FusedTask::Expert(_))));
    }

    #[test]
    fn serial_and_parallel_agree_bitwise() {
        let w = FusedLayerWorkload::tiny();
        let load = small_load();
        let inputs = FusedInputs::synthetic(&w, &load, 17);
        let plan = Planner::for_workload(w).plan(&load);
        let (serial, _) = execute_traced(&plan, &inputs, false).expect("dispatch covered");
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            let par = execute_parallel(&plan, &inputs, &pool).unwrap();
            assert_eq!(serial.shape, par.shape);
            assert_eq!(serial.data, par.data, "threads={threads}");
        }
    }

    #[test]
    fn trace_matches_mapping_decode_across_kinds() {
        let w = FusedLayerWorkload::tiny();
        let load = small_load();
        let inputs = FusedInputs::synthetic(&w, &load, 23);
        let plan = Planner::for_workload(w).plan(&load);
        let (_, trace) = execute_traced(&plan, &inputs, true).unwrap();
        let trace = trace.expect("requested");
        assert_eq!(trace.len() as u32, plan.total_tiles());
        let descs = plan.descriptors();
        for (block, r) in trace.iter().enumerate() {
            let m = plan.two_stage.map(block as u32);
            assert_eq!((r.task, r.tile), (m.task, m.tile));
            assert_eq!(r.kind, descs[m.task as usize].kind);
        }
    }

    #[test]
    fn prefill_chunk_selection_uses_its_own_catalog() {
        assert_eq!(PREFILL_CATALOG[select_prefill_chunk(2000)], 1024);
        assert_eq!(PREFILL_CATALOG[select_prefill_chunk(512)], 1024);
        assert_eq!(PREFILL_CATALOG[select_prefill_chunk(100)], 256);
        assert_eq!(PREFILL_CATALOG[select_prefill_chunk(5)], 16);
    }

    #[test]
    fn prefill_tile_stream_covers_the_descriptor_grid() {
        let w = FusedLayerWorkload::tiny();
        let t = FusedTask::Prefill(SeqTask { seq: 0, kv_len: 700, strategy: 1 });
        let d = w.descriptor(&t);
        assert_eq!(w.tiles(&t, 0, 0.0).len(), d.num_tiles());
        // causal pairs across the stream sum to P(P+1)/2 per head (4·d each)
        let total: f64 = w.tiles(&t, 0, 0.0).iter().map(|x| x.useful_flops).sum();
        let expect = 4.0 * (700.0 * 701.0 / 2.0) * w.attn.head_dim as f64 * w.attn.heads as f64;
        assert!((total - expect).abs() < 1e-6 * expect);
    }

    #[test]
    fn signature_distinguishes_prefill_from_decode() {
        let w = FusedLayerWorkload::tiny();
        let mut a = small_load();
        let mut sig_a = Vec::new();
        w.signature_into(&a, &mut sig_a);
        // same kv span, different kind → different signature
        a.seqs[1] = SeqSpec::Decode { kv_len: a.seqs[1].kv_len() };
        let mut sig_b = Vec::new();
        w.signature_into(&a, &mut sig_b);
        assert_ne!(sig_a, sig_b);
    }
}
