//! The workload-generic planner: a load → a static batch [`Plan`].
//!
//! This is the host-side step the paper performs each iteration for *any*
//! irregular workload: ask the [`Workload`] for its tasks, find the
//! non-empty ones (σ), order them (Section 4.2), and build the compressed
//! TilePrefix (Algorithm 1) over the resulting grid.  The MoE instance
//! ([`crate::moe::planner::MoeWorkload`]) and the ragged-attention
//! instance ([`crate::workload::ragged::RaggedAttentionWorkload`]) flow
//! through this exact code — there is no per-workload planner.

use crate::batching::task::TaskDescriptor;
use crate::batching::two_stage::TwoStageMap;
use crate::moe::ordering::OrderingStrategy;
use crate::moe::tiling::StrategyId;
use crate::workload::{PlanKey, Workload};

/// The static batch plan for one step of workload `W`.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan<W: Workload> {
    /// The workload this plan batches.
    pub workload: W,
    /// Tasks in grid order: ordered non-empty tasks first, then empty
    /// tasks (which receive no tiles).
    pub tasks: Vec<W::Task>,
    /// σ + compressed TilePrefix over the non-empty prefix of `tasks`.
    pub two_stage: TwoStageMap,
}

impl<W: Workload> Plan<W> {
    /// The workload this plan was built for.
    pub fn workload(&self) -> &W {
        &self.workload
    }

    /// Task descriptors in grid order (including empty tasks).
    pub fn descriptors(&self) -> Vec<TaskDescriptor> {
        self.tasks.iter().map(|t| self.workload.descriptor(t)).collect()
    }

    /// Total thread blocks the fused kernel launches.
    pub fn total_tiles(&self) -> u32 {
        self.two_stage.total_tiles
    }

    /// Number of non-empty tasks (the σ domain).
    pub fn num_nonempty(&self) -> usize {
        self.two_stage.num_nonempty
    }
}

/// Plan builder; configurable ordering and tiling policy.
///
/// The configuration fields are private on purpose: a
/// [`crate::workload::cache::PlanCache`] is valid for exactly one planner
/// configuration, so every mutation must go through [`Planner::set_ordering`]
/// / [`Planner::set_force_strategy`] — which the owning
/// [`crate::exec::ExecutionSession`] pairs with a cache clear.  Direct field
/// writes (the pre-0.3 stale-cache hole) are no longer possible.
#[derive(Clone, Debug)]
pub struct Planner<W: Workload> {
    workload: W,
    ordering: OrderingStrategy,
    /// Force one strategy for every task (used by the grouped-GEMM
    /// baseline); `None` = per-task selection.
    force_strategy: Option<StrategyId>,
}

impl<W: Workload> Planner<W> {
    /// A planner for `workload` with the defaults the paper found best:
    /// half-interval ordering, per-task tiling.
    pub fn for_workload(workload: W) -> Self {
        Planner { workload, ordering: OrderingStrategy::HalfInterval, force_strategy: None }
    }

    /// The workload this planner plans for.
    pub fn workload(&self) -> &W {
        &self.workload
    }

    /// The configured ordering strategy.
    pub fn ordering(&self) -> OrderingStrategy {
        self.ordering
    }

    /// The forced tiling strategy, when one is set.
    pub fn force_strategy(&self) -> Option<StrategyId> {
        self.force_strategy
    }

    /// Builder form of [`Planner::set_ordering`].
    pub fn with_ordering(mut self, ordering: OrderingStrategy) -> Self {
        self.ordering = ordering;
        self
    }

    /// Builder form of [`Planner::set_force_strategy`] (forces `s`).
    pub fn with_single_strategy(mut self, s: StrategyId) -> Self {
        self.force_strategy = Some(s);
        self
    }

    /// Change the ordering strategy.  Callers holding a plan cache for
    /// this planner must clear it (the session does).
    pub fn set_ordering(&mut self, ordering: OrderingStrategy) {
        self.ordering = ordering;
    }

    /// Change the tiling policy (`Some(s)` = force `s` everywhere, `None`
    /// = per-task selection).  Same cache-invalidation contract as
    /// [`Planner::set_ordering`].
    pub fn set_force_strategy(&mut self, s: Option<StrategyId>) {
        self.force_strategy = s;
    }

    /// The plan-cache key of a load under this planner's workload.
    pub fn signature(&self, load: &W::Load) -> PlanKey {
        self.workload.signature(load)
    }

    /// Write the plan-cache key into a reusable scratch buffer — the
    /// allocation-free form [`crate::workload::cache::PlanCache`] uses on
    /// every lookup.
    pub fn signature_into(&self, load: &W::Load, out: &mut Vec<u64>) {
        self.workload.signature_into(load, out);
    }

    /// Build the plan for one load: σ over non-empty tasks, ordering,
    /// per-task tiling, compressed TilePrefix.
    ///
    /// Non-empty tasks are grouped by ascending [`Workload::phase`], and the
    /// ordering strategy permutes tasks *within* each phase.  Ordering
    /// strategies are pure functions of `(canonical index, weight)` pairs,
    /// so a phase's internal permutation is identical to what a standalone
    /// plan over just that phase's tasks would produce — the property the
    /// fused-vs-sequential bitwise equivalence tests rely on.  Single-phase
    /// workloads (every instance before the fused transformer layer) see
    /// exactly the old behaviour.
    pub fn plan(&self, load: &W::Load) -> Plan<W> {
        let canonical = self.workload.tasks(load, self.force_strategy);
        let weights: Vec<usize> = canonical.iter().map(|t| self.workload.weight(t)).collect();
        // non-empty tasks with their ordering weights (canonical index as
        // id), grouped by phase, ordered within each phase
        let mut phases: Vec<usize> = canonical
            .iter()
            .zip(&weights)
            .filter(|&(_, &w)| w > 0)
            .map(|(t, _)| self.workload.phase(t))
            .collect();
        phases.sort_unstable();
        phases.dedup();
        let mut ordered: Vec<u32> = Vec::new();
        for ph in phases {
            let nonempty: Vec<(u32, usize)> = canonical
                .iter()
                .enumerate()
                .filter(|&(i, t)| weights[i] > 0 && self.workload.phase(t) == ph)
                .map(|(i, _)| (i as u32, weights[i]))
                .collect();
            ordered.extend(self.ordering.order(&nonempty));
        }

        // materialize the grid without cloning tasks: move each one out of
        // its canonical slot exactly once — ordered non-empty prefix, then
        // the empty tasks (zero tiles; the σ stage elides them)
        let mut slots: Vec<Option<W::Task>> = canonical.into_iter().map(Some).collect();
        let mut tasks: Vec<W::Task> = Vec::with_capacity(slots.len());
        for &i in &ordered {
            let t = slots[i as usize].take().expect("ordering emits each nonempty index once");
            tasks.push(t);
        }
        for (i, &w) in weights.iter().enumerate() {
            if w == 0 {
                tasks.push(slots[i].take().expect("empty task appended once"));
            }
        }

        let descriptors: Vec<TaskDescriptor> =
            tasks.iter().map(|t| self.workload.descriptor(t)).collect();
        let two_stage = TwoStageMap::from_tasks(&descriptors);
        Plan { workload: self.workload.clone(), tasks, two_stage }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ragged::{RaggedAttentionWorkload, RaggedLoad};

    fn workload() -> RaggedAttentionWorkload {
        RaggedAttentionWorkload { heads: 2, head_dim: 8, dtype_bytes: 4 }
    }

    #[test]
    fn nonempty_tasks_lead_the_grid_and_empty_trail() {
        let load = RaggedLoad { lens: vec![0, 40, 0, 3, 900] };
        let plan = Planner::for_workload(workload()).plan(&load);
        assert_eq!(plan.tasks.len(), 5);
        assert_eq!(plan.num_nonempty(), 3);
        let w = plan.workload().clone();
        assert!(plan.tasks[..3].iter().all(|t| w.weight(t) > 0));
        assert!(plan.tasks[3..].iter().all(|t| w.weight(t) == 0));
    }

    #[test]
    fn ordering_permutes_but_preserves_task_content() {
        let load = RaggedLoad { lens: vec![5, 100, 7, 64, 1, 300] };
        let a = Planner::for_workload(workload())
            .with_ordering(OrderingStrategy::Natural)
            .plan(&load);
        let b = Planner::for_workload(workload())
            .with_ordering(OrderingStrategy::HalfInterval)
            .plan(&load);
        assert_eq!(a.total_tiles(), b.total_tiles());
        let mut la: Vec<usize> = a.tasks.iter().map(|t| t.kv_len).collect();
        let mut lb: Vec<usize> = b.tasks.iter().map(|t| t.kv_len).collect();
        la.sort_unstable();
        lb.sort_unstable();
        assert_eq!(la, lb);
    }

    #[test]
    fn setters_change_the_next_plan() {
        let load = RaggedLoad { lens: vec![5, 100, 7, 64] };
        let mut p = Planner::for_workload(workload());
        let before = p.plan(&load);
        p.set_ordering(OrderingStrategy::SortedDesc);
        p.set_force_strategy(Some(3));
        assert_eq!(p.ordering(), OrderingStrategy::SortedDesc);
        assert_eq!(p.force_strategy(), Some(3));
        let after = p.plan(&load);
        // forcing the smallest KV chunk everywhere multiplies tile counts
        assert!(after.total_tiles() > before.total_tiles());
        // sorted-desc puts the longest sequence first
        assert_eq!(after.tasks[0].kv_len, 100);
    }

    #[test]
    fn all_empty_load_plans_zero_tiles() {
        let load = RaggedLoad { lens: vec![0, 0, 0] };
        let plan = Planner::for_workload(workload()).plan(&load);
        assert_eq!(plan.total_tiles(), 0);
        assert_eq!(plan.num_nonempty(), 0);
        assert_eq!(plan.tasks.len(), 3);
    }
}
