//! Workload-generic LRU plan cache: skip σ/ordering/tiling/TilePrefix
//! reconstruction when a load signature repeats.
//!
//! The paper's framework builds a fresh plan every inference iteration, but
//! serving traffic repeats load shapes constantly — popular prompts, padded
//! batches of equal composition, steady-state balanced routing.  The cache
//! sits between routing and [`Planner::plan`]: the key is the
//! workload-provided [`PlanKey`] (per-expert row counts for MoE,
//! per-sequence KV lengths for ragged attention — the canonical form of a
//! load, under which equal keys plan identically for a fixed planner
//! configuration), and the value is the finished [`Plan`] behind an
//! [`Arc`] so hits are O(key) with no plan clone.
//!
//! A cache is valid for exactly one planner configuration (ordering +
//! tiling policy): [`crate::exec::ExecutionSession`] owns one of each and
//! clears the cache whenever the planner changes.

use std::collections::HashMap;
use std::sync::Arc;

use crate::workload::plan::{Plan, Planner};
use crate::workload::{PlanKey, Workload};

/// Hit/miss counters plus current occupancy, for metrics surfaces.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl CacheStats {
    /// Hits over total lookups; 0.0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry<W: Workload> {
    plan: Arc<Plan<W>>,
    /// Logical timestamp of the last lookup that returned this entry.
    last_used: u64,
}

/// Bounded LRU cache from load signature to built plan.
pub struct PlanCache<W: Workload> {
    capacity: usize,
    map: HashMap<PlanKey, Entry<W>>,
    /// Reused key buffer: lookups write the signature here and probe the
    /// map by `&[u64]` (via `PlanKey: Borrow<[u64]>`), so a hit performs
    /// no allocation at all.
    key_scratch: Vec<u64>,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl<W: Workload> PlanCache<W> {
    /// A cache holding at most `capacity` plans (at least one).
    pub fn new(capacity: usize) -> Self {
        PlanCache {
            capacity: capacity.max(1),
            map: HashMap::new(),
            key_scratch: Vec::new(),
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats { hits: self.hits, misses: self.misses, entries: self.map.len() }
    }

    /// Drop every entry (the planner configuration changed); counters keep
    /// accumulating across clears.
    pub fn clear(&mut self) {
        self.map.clear();
    }

    /// Return the cached plan for this load's signature, or build it with
    /// `planner` and cache it, evicting the least-recently-used entry when
    /// full.
    pub fn get_or_plan(&mut self, planner: &Planner<W>, load: &W::Load) -> Arc<Plan<W>> {
        self.tick += 1;
        let tick = self.tick;
        // key build goes into the reused scratch buffer and the map is
        // probed by slice (`PlanKey: Borrow<[u64]>`): a hit allocates
        // nothing — no key Vec, no plan clone (the entry is an Arc)
        let (map, scratch) = (&mut self.map, &mut self.key_scratch);
        planner.signature_into(load, scratch);
        if let Some(entry) = map.get_mut(scratch.as_slice()) {
            entry.last_used = tick;
            self.hits += 1;
            return Arc::clone(&entry.plan);
        }
        self.misses += 1;
        let plan = Arc::new(planner.plan(load));
        if self.map.len() >= self.capacity {
            let evict = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            if let Some(k) = evict {
                self.map.remove(&k);
            }
        }
        let key = PlanKey(self.key_scratch.clone());
        self.map.insert(key, Entry { plan: Arc::clone(&plan), last_used: tick });
        plan
    }
}
