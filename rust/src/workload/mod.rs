//! The workload layer: what makes the batching framework *general*.
//!
//! The paper's central claim is that one static batching scheme —
//! TilePrefix (Algorithm 1), warp-vote decompression (Algorithm 2), the
//! fused dispatch loop (Algorithm 3), and the σ two-stage mapping over
//! empty tasks (Algorithm 4) — serves *any* irregular workload whose
//! per-task tile counts are known before launch; MoE expert GEMMs are one
//! application.  This module is that claim as an API: the [`Workload`]
//! trait describes how a domain decomposes a routing/load outcome into
//! tasks, and everything downstream — [`plan::Planner`], [`plan::Plan`],
//! [`cache::PlanCache`], the [`crate::exec::Backend`] surface, and
//! [`crate::exec::ExecutionSession`] — is generic over it.
//!
//! Three instances ship:
//!
//! * [`crate::moe::planner::MoeWorkload`] — per-expert GEMMs of one MoE
//!   layer (the paper's application; [`crate::moe`] owns its load
//!   scenarios, tiling catalog, and CPU numerics).
//! * [`ragged::RaggedAttentionWorkload`] — a decode-step batch of
//!   attention reads over per-sequence KV caches of wildly different
//!   lengths (the second irregular workload; defined in [`ragged`]).
//! * [`transformer::FusedLayerWorkload`] — a whole transformer-layer step
//!   as *one* heterogeneous static batch: ragged attention (decode and
//!   chunked prefill) plus routed expert FFN GEMMs, three task kinds under
//!   a single σ (defined in [`transformer`]).
//!
//! All run through the *same* σ / ordering / TilePrefix machinery; the
//! cross-workload agreement tests pin that the dispatch sequences decoded
//! by the simulator match the sequences the CPU executors actually run.
//!
//! Planning a ragged-attention decode step looks exactly like planning an
//! MoE step — only the workload value changes:
//!
//! ```
//! use staticbatch::workload::plan::Planner;
//! use staticbatch::workload::ragged::{RaggedAttentionWorkload, RaggedLoad};
//!
//! let workload = RaggedAttentionWorkload { heads: 4, head_dim: 16, dtype_bytes: 2 };
//! // four decode sequences; one has an empty KV cache (σ elides it)
//! let load = RaggedLoad { lens: vec![700, 9, 0, 120] };
//! let plan = Planner::for_workload(workload).plan(&load);
//! assert_eq!(plan.num_nonempty(), 3);
//! // every tile of every non-empty sequence is covered, empty ones launch nothing
//! let tiles: usize = plan.descriptors().iter().map(|d| d.num_tiles()).sum();
//! assert_eq!(plan.total_tiles() as usize, tiles);
//! ```

pub mod cache;
pub mod plan;
pub mod ragged;
pub mod transformer;

use crate::batching::task::{TaskDescriptor, TaskKind};
use crate::moe::tiling::StrategyId;
use crate::sim::cost::{gemm_tiles, Dtype, TileWork};

/// The cache key a workload derives from a load: two loads with equal keys
/// must plan identically under a fixed planner configuration.  (For MoE
/// this is the per-expert row counts; for ragged attention the per-sequence
/// KV lengths.)
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey(pub Vec<u64>);

/// Lets [`cache::PlanCache`] probe its map with a borrowed `&[u64]` scratch
/// buffer — the zero-allocation hit path.  Hash-consistent with the derived
/// `PlanKey` hash because `Vec<u64>`'s `Hash` defers to the slice impl.
impl std::borrow::Borrow<[u64]> for PlanKey {
    fn borrow(&self) -> &[u64] {
        &self.0
    }
}

/// One irregular workload the framework can statically batch.
///
/// A workload knows how to decompose its `Load` (a routing outcome, a
/// batch of KV lengths, ...) into tasks, and how to describe each task to
/// the framework: its [`TaskDescriptor`] (kind + tile geometry, from which
/// ν(T) derives), its ordering weight (paper Section 4.2 interleaves heavy
/// and light tasks), and its cache signature.  The generic
/// [`plan::Planner`] does the rest — σ over non-empty tasks, ordering,
/// compressed TilePrefix — identically for every instance.
pub trait Workload: Clone + PartialEq + std::fmt::Debug + 'static {
    /// The per-step load this workload plans from.
    type Load;
    /// The workload-specific task payload kept in the plan (grid order).
    type Task: Clone + PartialEq + std::fmt::Debug;
    /// Real tensors numeric backends need to execute a plan of this
    /// workload (accounting backends ignore them).
    type Inputs;

    /// Stable display name (`moe`, `ragged-attn`, ...).
    fn name(&self) -> &'static str;

    /// Decompose a load into tasks in *canonical* order (one per expert /
    /// sequence / ...), empty tasks included.  `force_strategy` pins one
    /// tiling strategy for every task (the grouped-GEMM-style control);
    /// `None` selects per task.
    fn tasks(&self, load: &Self::Load, force_strategy: Option<StrategyId>) -> Vec<Self::Task>;

    /// The framework descriptor of one task (kind, dims, tile shape).
    fn descriptor(&self, task: &Self::Task) -> TaskDescriptor;

    /// Ordering weight (Section 4.2): how "busy" this task is.  Zero means
    /// empty — the task is appended after the non-empty prefix and elided
    /// by σ.
    fn weight(&self, task: &Self::Task) -> usize;

    /// Write the plan-cache key of a load into `out` (cleared first).
    /// This is the form the cache calls on every lookup — with a reused
    /// scratch buffer, a cache *hit* allocates nothing.
    fn signature_into(&self, load: &Self::Load, out: &mut Vec<u64>);

    /// The plan-cache key of a load (see [`PlanKey`]), as an owned key.
    fn signature(&self, load: &Self::Load) -> PlanKey {
        let mut out = Vec::new();
        self.signature_into(load, &mut out);
        PlanKey(out)
    }

    /// Element type of the workload's operands (cost accounting).
    fn dtype(&self) -> Dtype;

    /// Element type of *one task's* operands.  Heterogeneous workloads can
    /// mix dtypes across task kinds (e.g. bf16 KV reads next to fp32 expert
    /// weights); the default is the workload-wide [`Workload::dtype`].
    fn task_dtype(&self, _task: &Self::Task) -> Dtype {
        self.dtype()
    }

    /// Grid phase of a task.  The planner lays out non-empty tasks grouped
    /// by ascending phase, ordering *within* each phase with the configured
    /// strategy, so a later phase's first tile is a natural barrier point
    /// for executors with cross-phase data dependencies (attention output
    /// feeding expert FFN).  Single-kind workloads keep the default single
    /// phase and planner behaviour is unchanged.
    fn phase(&self, _task: &Self::Task) -> usize {
        0
    }

    /// Expand one task into the simulator's tile stream.  `decode_ns` is
    /// the per-block mapping-decode overhead the active mapping mode
    /// charges.  The default handles GEMM-shaped tasks exactly like the
    /// MoE kernel simulation; other kinds get a uniform flops/bytes split
    /// across their tiles.  Override for workload-specific cost shapes.
    fn tiles(&self, task: &Self::Task, index: u32, decode_ns: f64) -> Vec<TileWork> {
        let d = self.descriptor(task);
        match d.kind {
            TaskKind::Gemm { .. } => gemm_tiles(
                index,
                d.rows,
                d.cols,
                d.inner,
                d.tile_rows,
                d.tile_cols,
                self.task_dtype(task),
                decode_ns,
            ),
            _ => {
                let nt = d.num_tiles();
                if nt == 0 {
                    return Vec::new();
                }
                let flops = d.flops() as f64 / nt as f64;
                let bytes = d.elems_moved() as f64 * self.task_dtype(task).bytes() as f64 / nt as f64;
                let tiles_n = d.tiles_n() as u32;
                (0..nt as u32)
                    .map(|t| TileWork {
                        task: index,
                        m_tile: t / tiles_n,
                        n_tile: t % tiles_n,
                        useful_flops: flops,
                        occupied_flops: flops,
                        weight_bytes: bytes,
                        token_bytes: 0.0,
                        out_bytes: 0.0,
                        decode_ns,
                    })
                    .collect()
            }
        }
    }

    /// Total operand bytes of a plan's tasks — the L2-pressure proxy the
    /// per-block-array mapping modes charge decode costs against.
    fn operand_bytes(&self, tasks: &[Self::Task]) -> f64 {
        tasks
            .iter()
            .map(|t| {
                let d = self.descriptor(t);
                if d.num_tiles() == 0 {
                    0.0
                } else {
                    d.elems_moved() as f64 * self.task_dtype(t).bytes() as f64
                }
            })
            .sum()
    }
}
