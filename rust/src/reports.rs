//! Report generators shared by the CLI subcommands and the `cargo bench`
//! targets: each function regenerates one experiment from DESIGN.md's
//! index and returns the rendered table.
//!
//! Every plan executed here goes through [`crate::exec::ExecutionSession`]
//! — the tables differ only in which backend / ordering / scenario they
//! sweep.

use crate::exec::{all_backends, ExecutionSession, SimBackend};
use crate::moe::config::MoeShape;
use crate::moe::ordering::OrderingStrategy;
use crate::moe::routing::LoadScenario;
use crate::sim::overhead::MappingMode;
use crate::sim::specs::GpuSpec;
use crate::util::bench::Table;

/// **Table 1**: our kernel, balanced/best/worst on H20 and H800.
/// The best-H800 row uses the footnote-1 larger workload, like the paper.
pub fn table1() -> String {
    let mut t = Table::new(&["case", "gpu", "TFLOPS", "peak%", "paper TFLOPS", "paper peak%"]);
    let paper: &[(&str, &str, f64, f64)] = &[
        ("balanced", "H20", 138.23, 94.67),
        ("best", "H20", 138.55, 94.89),
        ("worst", "H20", 131.57, 90.11),
        ("balanced", "H800", 838.87, 84.82),
        ("best", "H800", 897.03, 90.70),
        ("worst", "H800", 587.20, 59.37),
    ];
    for &(case, gpu, p_tf, p_pct) in paper {
        let spec = GpuSpec::by_name(gpu).unwrap();
        let (scenario, shape) = match case {
            "balanced" => (LoadScenario::Balanced, MoeShape::paper_table1()),
            "best" if gpu == "H800" => {
                (LoadScenario::Best, MoeShape::paper_table1_best_h800())
            }
            "best" => (LoadScenario::Best, MoeShape::paper_table1()),
            "worst" => (LoadScenario::Worst, MoeShape::paper_table1()),
            _ => unreachable!(),
        };
        let load = scenario.counts(&shape, 0);
        let r = ExecutionSession::new(shape).gpu(spec).run(&load).unwrap();
        t.row(&[
            case.into(),
            gpu.into(),
            format!("{:.2}", r.sim().tflops),
            format!("{:.2}", r.sim().peak_frac * 100.0),
            format!("{p_tf:.2}"),
            format!("{p_pct:.2}"),
        ]);
    }
    t.render()
}

/// **A1**: ours vs the three baselines across the paper's scenarios.
pub fn baselines_table() -> String {
    let mut t = Table::new(&["gpu", "case", "impl", "time(ms)", "TFLOPS", "peak%", "vs ours"]);
    let shape = MoeShape::paper_table1();
    for gpu in ["H20", "H800"] {
        let spec = GpuSpec::by_name(gpu).unwrap();
        for sc in [LoadScenario::Balanced, LoadScenario::Best, LoadScenario::Worst] {
            let load = sc.counts(&shape, 0);
            let ours_time = ExecutionSession::new(shape)
                .gpu(spec.clone())
                .run(&load)
                .unwrap()
                .time_s();
            for b in all_backends() {
                let mut s = ExecutionSession::new(shape).gpu(spec.clone()).boxed_backend(b);
                let r = s.run(&load).unwrap();
                t.row(&[
                    gpu.into(),
                    sc.name(),
                    r.backend.into(),
                    format!("{:.3}", r.time_s() * 1e3),
                    format!("{:.1}", r.sim().tflops),
                    format!("{:.1}", r.sim().peak_frac * 100.0),
                    format!("{:.2}x", r.time_s() / ours_time),
                ]);
            }
        }
    }
    t.render()
}

/// **A2**: mapping mechanism microbench — metadata H2D + per-block decode
/// cost for compressed prefix vs per-block array vs dynamic scheduling, as
/// the grid grows.
pub fn mapping_table() -> String {
    let spec = GpuSpec::h800();
    let mut t = Table::new(&[
        "tasks", "blocks", "mechanism", "H2D(us)", "decode/blk(ns)", "total(us)",
    ]);
    for &(tasks, blocks) in
        &[(8usize, 1_024usize), (64, 2_560), (64, 65_536), (512, 262_144), (4096, 1_048_576)]
    {
        let pressure = 500e6; // typical operand traffic
        // 2-level prefix: group size ~ sqrt(N) (the paper's omitted
        // multi-level extension, implemented in batching::tile_prefix)
        let group = ((tasks as f64).sqrt().ceil() as usize).next_multiple_of(32);
        let two_level_passes =
            tasks.div_ceil(group).div_ceil(32) + group.min(tasks).div_ceil(32);
        let modes: Vec<(&str, MappingMode)> = vec![
            (
                "flat prefix (ours)",
                MappingMode::CompressedPrefix {
                    metadata_len: 2 * tasks,
                    warp_passes: tasks.div_ceil(32),
                },
            ),
            (
                "2-level prefix (ours)",
                MappingMode::CompressedPrefix {
                    metadata_len: 2 * tasks + tasks.div_ceil(group),
                    warp_passes: two_level_passes,
                },
            ),
            ("per-block array [10]", MappingMode::PerBlockArray { blocks }),
            ("dynamic (grouped)", MappingMode::DynamicOnDevice { groups: tasks }),
        ];
        for (name, mode) in modes {
            let h2d = mode.host_time_s(&spec) * 1e6;
            let dec = mode.decode_ns(&spec, pressure);
            let total = h2d + dec * blocks as f64 * 1e-3 / spec.sms as f64;
            t.row(&[
                tasks.to_string(),
                blocks.to_string(),
                name.into(),
                format!("{h2d:.2}"),
                format!("{dec:.1}"),
                format!("{total:.2}"),
            ]);
        }
    }
    t.render()
}

/// **A3**: expert ordering ablation under skewed load.
pub fn ordering_table(seed: u64) -> String {
    let shape = MoeShape::paper_table1();
    let mut t = Table::new(&["gpu", "load", "ordering", "time(ms)", "peak%", "vs half-interval"]);
    let orderings = [
        OrderingStrategy::HalfInterval,
        OrderingStrategy::Alternating,
        OrderingStrategy::Natural,
        OrderingStrategy::Random(seed),
        OrderingStrategy::SortedDesc,
    ];
    for gpu in ["H800", "H20"] {
        let spec = GpuSpec::by_name(gpu).unwrap();
        for sc in [LoadScenario::Worst, LoadScenario::Zipf(1.2), LoadScenario::Dirichlet(0.3)] {
            let load = sc.counts(&shape, seed);
            let base = ExecutionSession::new(shape)
                .ordering(OrderingStrategy::HalfInterval)
                .gpu(spec.clone())
                .run(&load)
                .unwrap()
                .time_s();
            for ord in orderings {
                let r = ExecutionSession::new(shape)
                    .ordering(ord)
                    .gpu(spec.clone())
                    .run(&load)
                    .unwrap();
                t.row(&[
                    gpu.into(),
                    sc.name(),
                    ord.name().into(),
                    format!("{:.3}", r.time_s() * 1e3),
                    format!("{:.1}", r.sim().peak_frac * 100.0),
                    format!("{:.3}x", r.time_s() / base),
                ]);
            }
        }
    }
    t.render()
}

/// **A4**: empty-task handling — two-stage σ mapping (Alg. 4) vs the two
/// no-σ alternatives: dense decode over all N tasks, and padding every
/// empty task with a dummy tile (what a static scheme without the
/// extension must do to keep the mapping invertible).
pub fn empty_tasks_table() -> String {
    let shape = MoeShape::paper_table1();
    let spec = GpuSpec::h800();
    let mut t = Table::new(&[
        "active experts", "empty", "two-stage(ms)", "dense-map(ms)", "padded-empty(ms)",
        "padded waste%", "speedup vs padded",
    ]);
    for active in [64usize, 32, 16, 8, 4, 2] {
        // all rows spread over `active` experts; the rest empty
        let mut counts = vec![0usize; shape.experts];
        let total = shape.total_rows();
        for i in 0..total {
            counts[i % active] += 1;
        }
        let load = crate::moe::routing::ExpertLoad { counts };
        let run = |b: SimBackend| {
            ExecutionSession::new(shape).gpu(spec.clone()).backend(b).run(&load).unwrap()
        };
        let ours = run(SimBackend::ours());
        let dense = run(SimBackend::dense_mapping());
        let padded = run(SimBackend::padded_empty());
        t.row(&[
            active.to_string(),
            (shape.experts - active).to_string(),
            format!("{:.3}", ours.time_s() * 1e3),
            format!("{:.3}", dense.time_s() * 1e3),
            format!("{:.3}", padded.time_s() * 1e3),
            format!("{:.2}", padded.sim().padding_waste() * 100.0),
            format!("{:.3}x", padded.time_s() / ours.time_s()),
        ]);
    }
    t.render()
}

/// **A5**: token-copy elimination — bytes moved and host time of the
/// gather-copy a grouped-GEMM implementation needs, vs the index arrays.
pub fn token_copy_table() -> String {
    let spec = GpuSpec::h800();
    let mut t = Table::new(&[
        "top_k", "rows", "copy bytes(MB)", "copy time(us)", "index bytes(KB)",
    ]);
    for k in [1usize, 2, 4, 8] {
        let shape = MoeShape { top_k: k, ..MoeShape::paper_table1() };
        let load = LoadScenario::Balanced.counts(&shape, 0);
        let copy_t =
            crate::baselines::grouped_gemm::GroupedGemm::gather_copy_time_s(&shape, &load, &spec);
        let rows = shape.total_rows();
        let copy_bytes = 2.0 * (rows * shape.d_model * shape.dtype_bytes) as f64;
        t.row(&[
            k.to_string(),
            rows.to_string(),
            format!("{:.1}", copy_bytes / 1e6),
            format!("{:.1}", copy_t * 1e6),
            format!("{:.1}", (4 * rows) as f64 / 1e3),
        ]);
    }
    t.render()
}

/// **A6**: L2 tile-swizzle ablation (paper Section 4.4) on the footnote-1
/// best-case workload, whose 58 MB weight working set thrashes L2 without
/// swizzling.  `group` is the super-block height in m-tiles; 1 = off.
/// (Cost-model ablation: builds custom tile streams below the Backend
/// surface on purpose.)
pub fn swizzle_table() -> String {
    use crate::moe::planner::Planner;
    use crate::moe::tiling::CATALOG;
    use crate::sim::cost::gemm_tiles_with_group;
    use crate::sim::wave;

    let shape = MoeShape::paper_table1_best_h800();
    let spec = GpuSpec::h800();
    let load = LoadScenario::Best.counts(&shape, 0);
    let plan = Planner::new(shape).plan(&load);
    let s = CATALOG[plan.tasks[0].strategy];
    let mut t = Table::new(&["swizzle G", "time(ms)", "TFLOPS", "peak%", "HBM GB moved"]);
    for group in [1usize, 2, 4, 8, 32, usize::MAX] {
        let mut tiles = Vec::new();
        for (ti, task) in plan.tasks.iter().enumerate() {
            if task.rows == 0 {
                continue;
            }
            tiles.extend(gemm_tiles_with_group(
                ti as u32, task.rows, shape.d_ff, shape.d_model,
                s.tm, s.tn, shape.dtype(), spec.warp_pass_ns, group,
            ));
        }
        let r = wave::run_waves(&tiles, &spec, 0.0);
        let gb: f64 = r.waves.iter().map(|w| w.bytes).sum::<f64>() / 1e9;
        let label = if group == usize::MAX { "all (col-major)".to_string() } else { group.to_string() };
        t.row(&[
            label,
            format!("{:.3}", r.time_s * 1e3),
            format!("{:.1}", r.tflops),
            format!("{:.1}", r.peak_frac * 100.0),
            format!("{gb:.1}"),
        ]);
    }
    t.render()
}

/// **SERVE**: the sim-serving load test — burst traffic from prompt pools
/// of varying popularity skew through the backend-generic serving core
/// (queue → batcher → PlanCache → executor → metrics), reporting
/// throughput shape, admission drops and errors, and plan-cache behavior.
/// Accounting backend, so the table regenerates in milliseconds.
pub fn serving_sim_table(requests: usize, seed: u64) -> String {
    use crate::coordinator::batcher::BatchPolicy;
    use crate::serve::{
        run_traffic, Server, ServerConfig, SimServeConfig, SimStepExecutor, TrafficConfig,
    };

    let mut t = Table::new(&[
        "traffic", "requests", "rejected", "errors", "expired", "retries", "batches",
        "mean batch", "cache hits", "cache misses", "hit rate",
    ]);
    for (name, distinct, alpha) in
        [("hot pool", 4usize, 1.6), ("mixed pool", 8, 1.2), ("wide pool", 32, 0.8)]
    {
        let sim_cfg = SimServeConfig { numeric: false, seed, ..SimServeConfig::default() };
        let max_tokens = sim_cfg.max_tokens;
        let mut server = Server::new(
            ServerConfig {
                policy: BatchPolicy {
                    buckets: Vec::new(),
                    max_requests: 16,
                    max_tokens,
                },
                queue_capacity: requests.max(16),
                ..ServerConfig::default()
            },
            SimStepExecutor::new(sim_cfg),
        );
        let report = run_traffic(
            &mut server,
            TrafficConfig {
                requests,
                rate_hz: 0.0,
                zipf_alpha: alpha,
                distinct,
                seed,
                ..TrafficConfig::default()
            },
        );
        let c = report.cache.unwrap_or_default();
        t.row(&[
            name.into(),
            format!("{}", report.ok),
            format!("{}", report.rejected),
            format!("{}", report.failed),
            format!("{}", report.expired),
            format!("{}", report.snapshot.retries),
            format!("{}", report.snapshot.batches),
            format!("{:.2}", report.snapshot.mean_batch),
            format!("{}", c.hits),
            format!("{}", c.misses),
            format!("{:.1}%", c.hit_rate() * 100.0),
        ]);
    }
    t.render()
}

/// **SHARD**: expert-parallel sharded serving — identical Zipf burst
/// traffic through [`crate::serve::ShardedStepExecutor`] per EP width,
/// static vs load-balanced placement.  Reports the mean per-step device
/// imbalance (max/mean shard kernel time), the collective share of step
/// time, the aggregate plan-cache hit rate across shard lanes, and how
/// often the balanced policy re-sharded.  Accounting backend, so the table
/// regenerates in milliseconds.
pub fn sharded_serving_table(requests: usize, seed: u64) -> String {
    use crate::coordinator::batcher::BatchPolicy;
    use crate::serve::{
        run_traffic, PlacementKind, Server, ServerConfig, ShardedServeConfig,
        ShardedStepExecutor, SimServeConfig, TrafficConfig,
    };

    let mut t = Table::new(&[
        "placement", "ep", "steps", "imbalance", "collective%", "hit rate", "reshards",
    ]);
    for ep in [2usize, 4] {
        for placement in [PlacementKind::Static, PlacementKind::Balanced] {
            let cfg = ShardedServeConfig {
                // serving-scale widths so shard kernel time tracks routed
                // rows (toy widths are latency-flat on a 132-SM device)
                base: SimServeConfig {
                    d_model: 1024,
                    d_ff: 2048,
                    numeric: false,
                    seed,
                    ..SimServeConfig::default()
                },
                ep,
                placement,
                rebalance_threshold: 1.1,
                ..ShardedServeConfig::default()
            };
            let max_tokens = cfg.base.max_tokens;
            let mut server = Server::new(
                ServerConfig {
                    policy: BatchPolicy { buckets: Vec::new(), max_requests: 16, max_tokens },
                    queue_capacity: requests.max(16),
                    ..ServerConfig::default()
                },
                ShardedStepExecutor::new(cfg),
            );
            let report = run_traffic(
                &mut server,
                TrafficConfig {
                    requests,
                    rate_hz: 0.0,
                    zipf_alpha: 1.4,
                    distinct: 8,
                    seed,
                    ..TrafficConfig::default()
                },
            );
            let sh = report.snapshot.sharding.clone().unwrap_or_default();
            let c = report.cache.unwrap_or_default();
            t.row(&[
                placement.name().into(),
                ep.to_string(),
                sh.steps.to_string(),
                format!("{:.2}", sh.imbalance_ratio()),
                format!("{:.1}%", sh.collective_share() * 100.0),
                format!("{:.1}%", c.hit_rate() * 100.0),
                sh.reshards.to_string(),
            ]);
        }
    }
    t.render()
}

/// **SCENARIO**: the pinned multi-tenant fault scenario — a 300-request
/// opening burst plus a second of 400 Hz Poisson traffic, split between a
/// premium tenant (priority 2, 30% share) and a batch tenant (priority 1,
/// 70%), with shard 1 of the EP=4 balanced executor killed at t=0.3s and
/// recovered at t=0.6s.  One row per tenant: what was sent, what finished,
/// what admission shed, and the latency/SLO/goodput outcome — all on the
/// virtual clock, so the table is deterministic and regenerates in
/// milliseconds.
pub fn scenario_table(seed: u64) -> String {
    use crate::serve::{
        run_scenario, PlacementKind, ScenarioConfig, ShardedServeConfig, ShardedStepExecutor,
        SimServeConfig,
    };

    let cfg = ScenarioConfig { seed, ..ScenarioConfig::default() };
    let mut ex = ShardedStepExecutor::new(ShardedServeConfig {
        base: SimServeConfig { numeric: false, seed, ..SimServeConfig::default() },
        ep: 4,
        placement: PlacementKind::Balanced,
        ..ShardedServeConfig::default()
    });
    let r = run_scenario(&mut ex, &cfg);
    let mut t = Table::new(&[
        "tenant", "prio", "sent", "ok", "failed", "shed", "expired", "p50(ms)", "p99(ms)",
        "slo%", "goodput(req/s)",
    ]);
    for tr in &r.tenants {
        t.row(&[
            tr.name.clone(),
            tr.priority.to_string(),
            tr.sent.to_string(),
            tr.ok.to_string(),
            tr.failed.to_string(),
            tr.shed.to_string(),
            tr.expired.to_string(),
            format!("{:.3}", tr.p50_ms),
            format!("{:.3}", tr.p99_ms),
            format!("{:.1}", tr.slo_attainment * 100.0),
            format!("{:.1}", tr.goodput_rps),
        ]);
    }
    t.render()
}

/// **RAGGED**: the second irregular workload — a decode-step batch of
/// ragged attention reads (per-sequence KV lengths Zipf/uniform
/// distributed) planned through the *same* σ / ordering / TilePrefix
/// machinery as MoE and simulated on the same wave model, against the
/// padded-dense baseline a scheme without σ is stuck with (every sequence
/// padded to the batch max).  Accounting backend, so the table
/// regenerates in milliseconds.
pub fn ragged_table(seqs: usize, seed: u64) -> String {
    use crate::workload::ragged::{PaddedDenseAttention, RaggedAttentionWorkload, RaggedScenario};

    let w = RaggedAttentionWorkload { heads: 32, head_dim: 128, dtype_bytes: 2 };
    let spec = GpuSpec::h800();
    let mut t = Table::new(&[
        "kv lengths", "seqs", "pad%", "static(ms)", "padded-dense(ms)", "padded waste%",
        "speedup",
    ]);
    for sc in [
        RaggedScenario::Uniform(4096),
        RaggedScenario::Zipf(1.0, 8192),
        RaggedScenario::Zipf(1.4, 8192),
    ] {
        let load = sc.lens(seqs, seed);
        let ours = ExecutionSession::for_workload(w)
            .gpu(spec.clone())
            .backend(SimBackend::ours())
            .run(&load)
            .unwrap();
        let padded = ExecutionSession::for_workload(w)
            .gpu(spec.clone())
            .backend(PaddedDenseAttention)
            .run(&load)
            .unwrap();
        t.row(&[
            sc.name(),
            seqs.to_string(),
            format!("{:.1}", load.padding_frac() * 100.0),
            format!("{:.3}", ours.time_s() * 1e3),
            format!("{:.3}", padded.time_s() * 1e3),
            format!("{:.1}", padded.sim().padding_waste() * 100.0),
            format!("{:.2}x", padded.time_s() / ours.time_s()),
        ]);
    }
    t.render()
}

/// **FUSED**: the fused transformer-layer super-workload — ragged decode
/// attention, chunked causal prefill, and routed expert-FFN GEMMs planned
/// as **one** static batch under a single σ — against (a) the same tasks
/// split into two sequential plans (attention plan then FFN plan: two
/// launches, two metadata ships, two host-overhead charges) and (b) the
/// two-launch padded-dense scheme.  The sequential rows run the *same*
/// fused workload with one phase blanked, so the per-tile work is identical
/// by construction and the delta is pure launch + mapping overhead.
pub fn fused_table(seqs: usize, seed: u64) -> String {
    use crate::workload::transformer::{FusedLayerWorkload, FusedLoad, PaddedDenseFused, SeqSpec};

    let shape = MoeShape {
        seq: seqs,
        d_model: 4096,
        d_ff: 2048,
        experts: 16,
        top_k: 2,
        dtype_bytes: 2,
    };
    let w = FusedLayerWorkload::new(32, shape);
    let spec = GpuSpec::h800();
    let load = FusedLoad::sample_mixed(&shape, seed);
    // the same tasks as two sequential single-phase plans
    let attn_only =
        FusedLoad { seqs: load.seqs.clone(), expert_counts: vec![0; shape.experts] };
    let ffn_only = FusedLoad {
        seqs: vec![SeqSpec::Empty; shape.seq],
        expert_counts: load.expert_counts.clone(),
    };

    let mut sess =
        ExecutionSession::for_workload(w).gpu(spec.clone()).backend(SimBackend::ours());
    let fused_plan = sess.plan(&load);
    let fused = sess.run(&load).unwrap();
    let attn_plan = sess.plan(&attn_only);
    let attn = sess.run(&attn_only).unwrap();
    let ffn_plan = sess.plan(&ffn_only);
    let ffn = sess.run(&ffn_only).unwrap();
    let padded = ExecutionSession::for_workload(w)
        .gpu(spec)
        .backend(PaddedDenseFused)
        .run(&load)
        .unwrap();

    let seq_time = attn.time_s() + ffn.time_s();
    let seq_host = attn.sim().host_time_s + ffn.sim().host_time_s;
    let seq_meta =
        attn_plan.two_stage.metadata_bytes() + ffn_plan.two_stage.metadata_bytes();

    let mut t = Table::new(&[
        "impl", "plans", "launches", "tiles", "metadata(B)", "host(us)", "time(ms)", "vs fused",
    ]);
    t.row(&[
        "fused one-plan".into(),
        "1".into(),
        "1".into(),
        fused_plan.total_tiles().to_string(),
        fused_plan.two_stage.metadata_bytes().to_string(),
        format!("{:.2}", fused.sim().host_time_s * 1e6),
        format!("{:.3}", fused.time_s() * 1e3),
        "1.00x".into(),
    ]);
    t.row(&[
        "sequential two-plan".into(),
        "2".into(),
        "2".into(),
        (attn_plan.total_tiles() + ffn_plan.total_tiles()).to_string(),
        seq_meta.to_string(),
        format!("{:.2}", seq_host * 1e6),
        format!("{:.3}", seq_time * 1e3),
        format!("{:.2}x", seq_time / fused.time_s()),
    ]);
    t.row(&[
        "padded-dense".into(),
        "2".into(),
        "2".into(),
        padded.blocks.to_string(),
        "0".into(),
        format!("{:.2}", padded.sim().host_time_s * 1e6),
        format!("{:.3}", padded.time_s() * 1e3),
        format!("{:.2}x", padded.time_s() / fused.time_s()),
    ]);
    t.render()
}

/// Zipf-imbalance sweep: ours vs grouped GEMM crossover analysis.
pub fn sweep_table(gpu: &str, seeds: u64) -> String {
    let spec = GpuSpec::by_name(gpu).unwrap_or_else(GpuSpec::h800);
    let shape = MoeShape::paper_table1();
    let mut ours_sess = ExecutionSession::new(shape).gpu(spec.clone());
    let mut grouped_sess = ExecutionSession::new(shape)
        .gpu(spec)
        .backend(crate::baselines::GroupedGemm);
    let mut t = Table::new(&["alpha", "imbalance", "ours(ms)", "grouped(ms)", "speedup"]);
    for &alpha in &[0.0, 0.4, 0.8, 1.2, 1.6, 2.0] {
        let mut ours_acc = 0.0;
        let mut grouped_acc = 0.0;
        let mut imb = 0.0;
        for seed in 0..seeds {
            let load = LoadScenario::Zipf(alpha).counts(&shape, seed);
            imb += load.imbalance();
            ours_acc += ours_sess.run(&load).unwrap().time_s();
            grouped_acc += grouped_sess.run(&load).unwrap().time_s();
        }
        let n = seeds as f64;
        t.row(&[
            format!("{alpha:.1}"),
            format!("{:.2}", imb / n),
            format!("{:.3}", ours_acc / n * 1e3),
            format!("{:.3}", grouped_acc / n * 1e3),
            format!("{:.2}x", grouped_acc / ours_acc),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    #[test]
    fn table1_renders_all_rows() {
        let s = super::table1();
        assert_eq!(s.lines().count(), 2 + 6);
        assert!(s.contains("balanced"));
        assert!(s.contains("H800"));
    }

    #[test]
    fn empty_tasks_table_speedups_at_least_one() {
        let s = super::empty_tasks_table();
        for line in s.lines().skip(2) {
            let speedup: f64 = line
                .split('|')
                .nth(7)
                .unwrap()
                .trim()
                .trim_end_matches('x')
                .parse()
                .unwrap();
            assert!(speedup >= 0.99, "line: {line}");
        }
    }

    #[test]
    fn baselines_table_names_all_backends() {
        let s = super::baselines_table();
        for name in ["sim/ours", "grouped GEMM", "two-phase", "naive per-expert loop"] {
            assert!(s.contains(name), "missing {name} in:\n{s}");
        }
    }

    #[test]
    fn serving_sim_table_reports_cache_behavior() {
        let s = super::serving_sim_table(48, 7);
        assert_eq!(s.lines().count(), 2 + 3, "header + 3 traffic rows:\n{s}");
        for name in [
            "hot pool", "mixed pool", "wide pool", "rejected", "errors", "expired", "retries",
            "hit rate",
        ] {
            assert!(s.contains(name), "missing {name} in:\n{s}");
        }
    }

    #[test]
    fn scenario_table_orders_slo_attainment_by_priority() {
        let s = super::scenario_table(7);
        assert_eq!(s.lines().count(), 2 + 2, "header + 2 tenant rows:\n{s}");
        assert!(s.contains("premium") && s.contains("batch"), "{s}");
        let slo: Vec<f64> = s
            .lines()
            .skip(2)
            .map(|l| l.split('|').nth(10).unwrap().trim().parse().unwrap())
            .collect();
        assert!(slo[0] >= slo[1], "premium {} < batch {}:\n{s}", slo[0], slo[1]);
    }

    #[test]
    fn ragged_table_shows_static_beating_padded_dense() {
        let s = super::ragged_table(128, 7);
        assert_eq!(s.lines().count(), 2 + 3, "header + 3 length distributions:\n{s}");
        for (i, line) in s.lines().skip(2).enumerate() {
            let speedup: f64 = line
                .split('|')
                .nth(7)
                .unwrap()
                .trim()
                .trim_end_matches('x')
                .parse()
                .unwrap();
            assert!(speedup >= 1.0, "row {i} regressed: {line}");
            // the skewed rows (zipf) must show a clear win for static batching
            if line.contains("zipf(1.4") {
                assert!(speedup > 1.5, "skewed lengths must pad heavily: {line}");
            }
        }
    }

    #[test]
    fn fused_table_plans_once_and_beats_sequential_on_overhead() {
        let s = super::fused_table(64, 7);
        assert_eq!(s.lines().count(), 2 + 3, "header + fused/sequential/padded rows:\n{s}");
        let cell = |line: &str, i: usize| line.split('|').nth(i).unwrap().trim().to_string();
        let rows: Vec<&str> = s.lines().skip(2).collect();
        // strictly fewer launches than the two-plan baseline
        assert_eq!(cell(rows[0], 3), "1");
        assert_eq!(cell(rows[1], 3), "2");
        // and strictly less host (launch + metadata) overhead
        let host: Vec<f64> = rows.iter().map(|r| cell(r, 6).parse().unwrap()).collect();
        assert!(host[0] < host[1], "fused host {} !< sequential {}:\n{s}", host[0], host[1]);
        // sequential row is slower overall (vs-fused ratio above 1)
        let ratio: f64 =
            cell(rows[1], 8).trim_end_matches('x').parse().unwrap();
        assert!(ratio > 1.0, "sequential must cost more than fused:\n{s}");
    }

    #[test]
    fn sharded_serving_table_covers_placements_and_widths() {
        let s = super::sharded_serving_table(48, 7);
        assert_eq!(s.lines().count(), 2 + 4, "header + 2 placements x 2 EP widths:\n{s}");
        for name in ["static", "balanced", "imbalance", "reshards"] {
            assert!(s.contains(name), "missing {name} in:\n{s}");
        }
    }
}
