//! Exact 32-lane SIMT warp emulation.
//!
//! Algorithm 2 is specified as warp-level SIMT code (ballot vote +
//! population count + broadcast).  To keep the reproduction faithful we run
//! it *as written* over this emulation: each lane computes its predicate,
//! `ballot` packs them into a 32-bit mask exactly like `__ballot_sync`, and
//! `popc` is `u32::count_ones` — bit-for-bit what the GPU does.

/// Warp width of every NVIDIA GPU the paper targets.
pub const WARP_SIZE: usize = 32;

/// A warp executing one SIMT step at a time.
#[derive(Clone, Copy, Debug, Default)]
pub struct Warp;

impl Warp {
    /// `__ballot_sync(0xffffffff, pred(lane))`: bit *i* of the result is the
    /// predicate of lane *i*.
    pub fn ballot<F: FnMut(usize) -> bool>(mut pred: F) -> u32 {
        let mut mask = 0u32;
        for lane in 0..WARP_SIZE {
            if pred(lane) {
                mask |= 1 << lane;
            }
        }
        mask
    }

    /// `__popc(mask)`.
    pub fn popc(mask: u32) -> u32 {
        mask.count_ones()
    }

    /// `__shfl_sync`: broadcast lane `src`'s value to the whole warp.
    /// In the emulation this is just returning the value; the signature
    /// stays to keep the algorithm body isomorphic to the CUDA text.
    pub fn shfl<T: Copy>(values: &[T; WARP_SIZE], src: usize) -> T {
        values[src]
    }

    /// Lane-parallel map: evaluates `f` for each lane, like one SIMT
    /// instruction over the warp.
    pub fn lanes<T, F: FnMut(usize) -> T>(mut f: F) -> Vec<T> {
        (0..WARP_SIZE).map(|lane| f(lane)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ballot_packs_lane_bits() {
        let mask = Warp::ballot(|lane| lane % 2 == 0);
        assert_eq!(mask, 0x5555_5555);
    }

    #[test]
    fn ballot_all_and_none() {
        assert_eq!(Warp::ballot(|_| true), u32::MAX);
        assert_eq!(Warp::ballot(|_| false), 0);
    }

    #[test]
    fn popc_counts_bits() {
        assert_eq!(Warp::popc(0), 0);
        assert_eq!(Warp::popc(u32::MAX), 32);
        assert_eq!(Warp::popc(0b1011), 3);
    }

    #[test]
    fn ballot_popc_composition() {
        // the exact composition Algorithm 2 relies on: the number of lanes
        // whose prefix value is <= B
        let prefix = [3u32, 5, 9, 9, 12];
        let b = 8;
        let mask = Warp::ballot(|lane| lane < prefix.len() && b >= prefix[lane]);
        assert_eq!(Warp::popc(mask), 2); // 3 and 5 are <= 8
    }

    #[test]
    fn shfl_broadcasts() {
        let vals: [u32; WARP_SIZE] = std::array::from_fn(|i| i as u32 * 10);
        assert_eq!(Warp::shfl(&vals, 7), 70);
    }
}
