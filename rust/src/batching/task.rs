//! Task descriptors for the batching framework.
//!
//! A *task* is one irregular workload inside a batch (paper Section 3).
//! Tasks are heterogeneous: different operation kinds and different tiling
//! strategies can coexist in one fused kernel.  The only thing the framework
//! requires is that ν(T) — the number of tiles a task needs — is known
//! before launch.

/// Operation kind of a task. GEMM tiles carry their tiling strategy index so
/// two GEMM tasks with different strategies dispatch to different device
/// functions, exactly like the paper's `taskFunc_1..K`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// GEMM with tiling strategy `strategy` (index into a tiling catalog).
    Gemm { strategy: usize },
    /// Row-wise reduction (sum) — memory bound.
    ReduceSum,
    /// Element-wise map — memory bound, trivially tileable.
    ElementWise,
    /// Decode-step attention over one sequence's KV cache, chunked by the
    /// KV-tiling strategy `strategy` (index into
    /// [`crate::workload::ragged::KV_CATALOG`]).  `rows` is the KV length,
    /// `cols` the head count, `inner` the head dim.
    AttentionDecode { strategy: usize },
    /// One chunk of causal prefill attention over a prompt, chunked by the
    /// prefill tiling strategy `strategy` (index into
    /// [`crate::workload::transformer::PREFILL_CATALOG`]).  `rows` is the
    /// prompt length, `cols` the head count, `inner` the head dim.  Cost
    /// model charges full chunked causal attention; see the transformer
    /// module for the numerics it stands for.
    PrefillChunk { strategy: usize },
}

impl TaskKind {
    /// Stable small integer id used by dispatch tables (the `i` in Alg. 3).
    pub fn dispatch_id(&self) -> usize {
        match self {
            TaskKind::Gemm { strategy } => 16 + strategy,
            TaskKind::ReduceSum => 0,
            TaskKind::ElementWise => 1,
            // ids 4.. stay clear of the GEMM range (16..) for any
            // realistically sized KV catalog
            TaskKind::AttentionDecode { strategy } => 4 + strategy,
            // ids 8.. sit between the KV catalog (4..8) and the GEMM
            // range (16..)
            TaskKind::PrefillChunk { strategy } => 8 + strategy,
        }
    }
}

/// A task inside a batch: kind + the geometry the tile count derives from.
#[derive(Clone, Debug)]
pub struct TaskDescriptor {
    pub kind: TaskKind,
    /// Rows of the task's output (M for GEMM, elements for 1-D ops).
    pub rows: usize,
    /// Columns of the task's output (N for GEMM, 1 for reductions).
    pub cols: usize,
    /// Inner/K extent (GEMM reduction dim; reduction length for ReduceSum).
    pub inner: usize,
    /// Tile shape this task was assigned (rows per tile, cols per tile).
    pub tile_rows: usize,
    pub tile_cols: usize,
}

impl TaskDescriptor {
    /// ν(T): number of tiles (thread blocks) this task requires.
    /// Zero for empty tasks — the case Algorithm 4 exists for.
    pub fn num_tiles(&self) -> usize {
        if self.rows == 0 || self.cols == 0 {
            return 0;
        }
        self.rows.div_ceil(self.tile_rows) * self.cols.div_ceil(self.tile_cols)
    }

    /// Tiles along the row dimension (used to split a linear tile index).
    pub fn tiles_m(&self) -> usize {
        self.rows.div_ceil(self.tile_rows)
    }

    pub fn tiles_n(&self) -> usize {
        self.cols.div_ceil(self.tile_cols)
    }

    /// FLOPs this task performs (2·M·N·K for GEMM; reads for mem-bound ops).
    pub fn flops(&self) -> u64 {
        match self.kind {
            TaskKind::Gemm { .. } => 2 * self.rows as u64 * self.cols as u64 * self.inner as u64,
            TaskKind::ReduceSum => (self.rows as u64) * (self.inner as u64),
            TaskKind::ElementWise => (self.rows as u64) * (self.cols as u64),
            // per head: QKᵀ (2·L·D) + PV (2·L·D)
            TaskKind::AttentionDecode { .. } => {
                4 * self.rows as u64 * self.cols as u64 * self.inner as u64
            }
            // causal prefill per head: QKᵀ + PV over all P·(P+1)/2 causal
            // pairs → 4·D·P(P+1)/2 = 2·P·(P+1)·D
            TaskKind::PrefillChunk { .. } => {
                2 * self.rows as u64 * (self.rows as u64 + 1) * self.cols as u64 * self.inner as u64
            }
        }
    }

    /// Bytes moved from/to HBM (fp32/bf16-agnostic: caller scales by dtype).
    pub fn elems_moved(&self) -> u64 {
        match self.kind {
            TaskKind::Gemm { .. } => {
                // A (M·K) + B (K·N, read once per tile wave under L2 reuse
                // approximation) + C (M·N)
                self.rows as u64 * self.inner as u64
                    + self.inner as u64 * self.cols as u64
                    + self.rows as u64 * self.cols as u64
            }
            TaskKind::ReduceSum => self.rows as u64 * self.inner as u64 + self.rows as u64,
            TaskKind::ElementWise => 2 * self.rows as u64 * self.cols as u64,
            TaskKind::AttentionDecode { .. } => {
                // K + V reads per head, plus the query and output vectors
                2 * self.rows as u64 * self.cols as u64 * self.inner as u64
                    + 2 * self.cols as u64 * self.inner as u64
            }
            TaskKind::PrefillChunk { .. } => {
                // causal chunked prefill: every query chunk re-streams the
                // KV prefix (≈ half the prompt on average), plus the Q and
                // O blocks once per head
                let chunks = self.rows.div_ceil(self.tile_rows) as u64;
                (chunks + 2) * self.rows as u64 * self.cols as u64 * self.inner as u64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm(rows: usize, cols: usize, tile: (usize, usize)) -> TaskDescriptor {
        TaskDescriptor {
            kind: TaskKind::Gemm { strategy: 0 },
            rows,
            cols,
            inner: 64,
            tile_rows: tile.0,
            tile_cols: tile.1,
        }
    }

    #[test]
    fn tile_count_exact_division() {
        assert_eq!(gemm(256, 256, (128, 128)).num_tiles(), 4);
    }

    #[test]
    fn tile_count_rounds_up() {
        assert_eq!(gemm(129, 1, (128, 128)).num_tiles(), 2);
        assert_eq!(gemm(1, 1, (128, 128)).num_tiles(), 1);
    }

    #[test]
    fn empty_task_has_zero_tiles() {
        assert_eq!(gemm(0, 256, (128, 128)).num_tiles(), 0);
    }

    #[test]
    fn dispatch_ids_unique_across_kinds() {
        let ids = [
            TaskKind::ReduceSum.dispatch_id(),
            TaskKind::ElementWise.dispatch_id(),
            TaskKind::AttentionDecode { strategy: 0 }.dispatch_id(),
            TaskKind::AttentionDecode { strategy: 3 }.dispatch_id(),
            TaskKind::PrefillChunk { strategy: 0 }.dispatch_id(),
            TaskKind::PrefillChunk { strategy: 3 }.dispatch_id(),
            TaskKind::Gemm { strategy: 0 }.dispatch_id(),
            TaskKind::Gemm { strategy: 1 }.dispatch_id(),
        ];
        let mut sorted = ids.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ids.len());
    }

    #[test]
    fn flops_gemm() {
        assert_eq!(gemm(128, 128, (128, 128)).flops(), 2 * 128 * 128 * 64);
    }
}
