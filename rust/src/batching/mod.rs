//! The paper's static batching framework (Sections 3 and 4.1).
//!
//! * [`task`] — task descriptors and the tile-count function ν(T).
//! * [`tile_prefix`] — Algorithm 1: the compressed `TilePrefix` array.
//! * [`warp`] — an exact 32-lane SIMT warp emulation (ballot vote,
//!   population count, broadcast) so Algorithm 2 runs *as written*.
//! * [`mapping`] — Algorithm 2: warp-vote decompression of the mapping,
//!   plus the multi-pass loop for N > 32 and the 2-level prefix the paper
//!   mentions but omits (N ≥ 512).
//! * [`two_stage`] — Algorithm 4: the σ injection that elides empty tasks.
//! * [`dispatch`] — the typed `DispatchTable`: per-kind device functions
//!   with construction-time coverage validation (a missing `taskFunc_i` is
//!   a build error, not a launch panic).
//! * [`framework`] — Algorithm 3: the batch builder + per-block dispatch of
//!   heterogeneous "device functions".

pub mod dispatch;
pub mod framework;
pub mod mapping;
pub mod task;
pub mod tile_prefix;
pub mod two_stage;
pub mod warp;
