//! Algorithm 1: the compressed `TilePrefix` auxiliary array.
//!
//! `TilePrefix[i] = Σ_{j<=i} ν(T_j)` — the inclusive prefix sum of per-task
//! tile counts.  Its length equals the number of *tasks*, not the number of
//! thread blocks, which is the whole point: the prior art (PPoPP'19 [10])
//! ships a per-block array whose H2D copy and cache behaviour the paper's
//! Section 3.1 measures as the bottleneck.

use crate::batching::task::TaskDescriptor;

/// Sentinel used to pad the array up to warp size (paper: "padding with the
/// maximum possible value or repeating its last element").
pub const PAD_MAX: u32 = u32::MAX;

/// Build the inclusive prefix sum of tile counts (serial version).
pub fn build(tasks: &[TaskDescriptor]) -> Vec<u32> {
    let mut out = Vec::with_capacity(tasks.len());
    let mut acc = 0u32;
    for t in tasks {
        acc += t.num_tiles() as u32;
        out.push(acc);
    }
    out
}

/// Build from raw tile counts.
pub fn build_from_counts(tiles: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(tiles.len());
    let mut acc = 0u32;
    for &t in tiles {
        acc += t;
        out.push(acc);
    }
    out
}

/// Work-efficient parallel prefix sum (Blelloch scan) — the paper notes the
/// prefix "can be computed with parallel implementation"; this is the
/// host-side analog, chunked across a thread pool for large N.
pub fn build_parallel(tiles: &[u32], pool: &crate::util::threadpool::ThreadPool) -> Vec<u32> {
    let n = tiles.len();
    if n < 4096 {
        return build_from_counts(tiles);
    }
    let chunks = pool.workers().max(1);
    let chunk = n.div_ceil(chunks);
    // phase 1: per-chunk local inclusive scans (fall back to the serial
    // scan if the pool is unusable — the sum is pure, so this is safe)
    let parts: Vec<Vec<u32>> = match pool.map(
        tiles
            .chunks(chunk)
            .map(|c| c.to_vec())
            .collect::<Vec<_>>(),
        |c| {
            let mut acc = 0u32;
            c.iter()
                .map(|&x| {
                    acc += x;
                    acc
                })
                .collect::<Vec<u32>>()
        },
    ) {
        Ok(p) => p,
        Err(_) => return build_from_counts(tiles),
    };
    // phase 2: carry chunk totals across
    let mut out = Vec::with_capacity(n);
    let mut carry = 0u32;
    for part in parts {
        let total = part.last().copied().unwrap_or(0);
        out.extend(part.into_iter().map(|x| x + carry));
        carry += total;
    }
    out
}

/// Pad to `width` (usually the warp size, 32) by repeating the last element.
/// An empty prefix pads with 0 (no tasks → every vote fails).
pub fn pad_to(prefix: &[u32], width: usize) -> Vec<u32> {
    let mut out = prefix.to_vec();
    let last = out.last().copied().unwrap_or(0);
    while out.len() < width {
        out.push(last);
    }
    out
}

/// Pad with the sentinel instead (the alternative the paper names).
pub fn pad_to_max(prefix: &[u32], width: usize) -> Vec<u32> {
    let mut out = prefix.to_vec();
    while out.len() < width {
        out.push(PAD_MAX);
    }
    out
}

/// Total number of tiles (thread blocks) a prefix describes.
pub fn total_tiles(prefix: &[u32]) -> u32 {
    prefix.iter().rev().find(|&&x| x != PAD_MAX).copied().unwrap_or(0)
}

/// Two-level prefix for very large N (the paper's "2-level or multi-level
/// TilePrefix arrays, which is omitted in this paper" — implemented here).
///
/// Level-1 entries summarize fixed-width groups of level-0 entries:
/// `l1[g] = l0[min((g+1)*group, n) - 1]` (inclusive).  Lookup first scans
/// l1 to find the group, then scans only that group's l0 slice — two warp
/// passes instead of ⌈N/32⌉.
#[derive(Clone, Debug)]
pub struct TwoLevelPrefix {
    pub l0: Vec<u32>,
    pub l1: Vec<u32>,
    pub group: usize,
}

impl TwoLevelPrefix {
    pub fn build(tiles: &[u32], group: usize) -> Self {
        assert!(group > 0);
        let l0 = build_from_counts(tiles);
        let l1 = l0
            .chunks(group)
            .map(|c| *c.last().unwrap())
            .collect();
        TwoLevelPrefix { l0, l1, group }
    }

    pub fn total_tiles(&self) -> u32 {
        self.l0.last().copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::task::{TaskDescriptor, TaskKind};
    use crate::util::rng::Rng;
    use crate::util::threadpool::ThreadPool;

    fn gemm_rows(rows: usize) -> TaskDescriptor {
        TaskDescriptor {
            kind: TaskKind::Gemm { strategy: 0 },
            rows,
            cols: 128,
            inner: 64,
            tile_rows: 128,
            tile_cols: 128,
        }
    }

    #[test]
    fn matches_manual_sum() {
        let tasks: Vec<_> = [256, 128, 384].iter().map(|&r| gemm_rows(r)).collect();
        assert_eq!(build(&tasks), vec![2, 3, 6]);
    }

    #[test]
    fn empty_tasks_contribute_zero() {
        let tasks: Vec<_> = [128, 0, 128].iter().map(|&r| gemm_rows(r)).collect();
        assert_eq!(build(&tasks), vec![1, 1, 2]);
    }

    #[test]
    fn pad_repeats_last() {
        assert_eq!(pad_to(&[2, 5], 4), vec![2, 5, 5, 5]);
        assert_eq!(pad_to(&[], 3), vec![0, 0, 0]);
    }

    #[test]
    fn pad_max_uses_sentinel() {
        let p = pad_to_max(&[2, 5], 4);
        assert_eq!(p, vec![2, 5, PAD_MAX, PAD_MAX]);
        assert_eq!(total_tiles(&p), 5);
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(7);
        let tiles: Vec<u32> = (0..10_000).map(|_| rng.below(8) as u32).collect();
        let pool = ThreadPool::new(4);
        assert_eq!(build_parallel(&tiles, &pool), build_from_counts(&tiles));
    }

    #[test]
    fn two_level_consistent() {
        let mut rng = Rng::new(3);
        let tiles: Vec<u32> = (0..512).map(|_| rng.below(5) as u32).collect();
        let tl = TwoLevelPrefix::build(&tiles, 32);
        assert_eq!(tl.l1.len(), 16);
        assert_eq!(tl.total_tiles(), tiles.iter().sum::<u32>());
        // each l1 entry equals the last l0 entry of its group
        for (g, &v) in tl.l1.iter().enumerate() {
            let end = ((g + 1) * 32).min(tl.l0.len()) - 1;
            assert_eq!(v, tl.l0[end]);
        }
    }
}
