//! Algorithm 4: the two-stage mapping for batches with empty tasks.
//!
//! Stage 1 (Algorithm 2) maps `block -> non-empty task index h`; stage 2
//! applies the injection `σ: [M] -> [N]` mapping the non-empty index back to
//! the real task index.  The `TilePrefix` array is built over non-empty
//! tasks only, so empty tasks cost nothing at decode time — the paper's fix
//! for MoE steps where some experts receive no tokens.

use crate::batching::mapping::{map_scalar, map_warp, MapCursor, TileMapping};
use crate::batching::task::TaskDescriptor;
use crate::batching::tile_prefix;
use crate::batching::warp::WARP_SIZE;

/// The σ injection plus the compressed prefix over non-empty tasks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TwoStageMap {
    /// `sigma[i]` = real task index of the i-th non-empty task.
    pub sigma: Vec<u32>,
    /// Inclusive tile prefix over non-empty tasks, padded to warp width.
    pub tile_prefix: Vec<u32>,
    /// Number of non-empty tasks (M).
    pub num_nonempty: usize,
    /// Total tiles (thread blocks) to launch.
    pub total_tiles: u32,
}

impl TwoStageMap {
    /// Build σ and the compressed prefix from per-task tile counts.
    pub fn from_tile_counts(tiles: &[u32]) -> Self {
        let mut sigma = Vec::new();
        let mut nonempty_tiles = Vec::new();
        for (i, &t) in tiles.iter().enumerate() {
            if t > 0 {
                sigma.push(i as u32);
                nonempty_tiles.push(t);
            }
        }
        let prefix = tile_prefix::build_from_counts(&nonempty_tiles);
        let total = prefix.last().copied().unwrap_or(0);
        let width = WARP_SIZE.max(prefix.len());
        TwoStageMap {
            sigma,
            tile_prefix: tile_prefix::pad_to(&prefix, width),
            num_nonempty: nonempty_tiles.len(),
            total_tiles: total,
        }
    }

    pub fn from_tasks(tasks: &[TaskDescriptor]) -> Self {
        let tiles: Vec<u32> = tasks.iter().map(|t| t.num_tiles() as u32).collect();
        Self::from_tile_counts(&tiles)
    }

    /// Algorithm 4 for one block: `(h, l) <- mapping(...); h̃ <- σ(h)`.
    pub fn map(&self, block: u32) -> TileMapping {
        debug_assert!(block < self.total_tiles);
        let m = map_scalar(&self.tile_prefix, block);
        TileMapping { task: self.sigma[m.task as usize], tile: m.tile }
    }

    /// Algorithm 4 through a [`MapCursor`]: bitwise-equal to
    /// [`TwoStageMap::map`] when blocks arrive in non-decreasing order, but
    /// amortized O(1) per block — the grid-walk hot path.
    pub fn map_with_cursor(&self, cursor: &mut MapCursor, block: u32) -> TileMapping {
        debug_assert!(block < self.total_tiles);
        let m = cursor.map(&self.tile_prefix, block);
        TileMapping { task: self.sigma[m.task as usize], tile: m.tile }
    }

    /// Decode the whole grid (σ applied) into a caller-provided buffer,
    /// cleared first — zero allocations once the buffer reaches the
    /// steady-state grid size, O(total + M) total work.
    ///
    /// Run-based like [`crate::batching::mapping::map_all_into`]: each
    /// non-empty task's contiguous block run is emitted in one inner loop
    /// with σ applied *once per task* instead of once per block — the
    /// whole-grid decode the mapping-throughput bench row measures.
    pub fn map_all_into(&self, out: &mut Vec<TileMapping>) {
        out.clear();
        out.reserve(self.total_tiles as usize);
        let mut base = 0u32;
        for (h, &p) in self.tile_prefix.iter().enumerate() {
            if base >= self.total_tiles {
                break;
            }
            let end = p.min(self.total_tiles);
            if end > base {
                let task = self.sigma[h];
                for tile in 0..end - base {
                    out.push(TileMapping { task, tile });
                }
                base = end;
            }
        }
    }

    /// Same through the warp-emulated Algorithm 2 (returns warp passes too).
    pub fn map_simt(&self, block: u32) -> (TileMapping, usize) {
        let (m, passes) = map_warp(&self.tile_prefix, block);
        (
            TileMapping { task: self.sigma[m.task as usize], tile: m.tile },
            passes,
        )
    }

    /// Bytes of metadata shipped to the device per step: σ + prefix.
    /// The per-block-array baseline ships `4 * total_tiles` instead — the
    /// comparison the mapping microbench (A2) quantifies.
    pub fn metadata_bytes(&self) -> usize {
        4 * (self.sigma.len() + self.tile_prefix.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn no_empty_tasks_is_identity_sigma() {
        let m = TwoStageMap::from_tile_counts(&[2, 1, 3]);
        assert_eq!(m.sigma, vec![0, 1, 2]);
        assert_eq!(m.num_nonempty, 3);
        assert_eq!(m.total_tiles, 6);
        assert_eq!(m.map(2).task, 1);
    }

    #[test]
    fn empty_tasks_elided() {
        // tasks: [0, 2, 0, 0, 3, 0] -> non-empty {1, 4}
        let m = TwoStageMap::from_tile_counts(&[0, 2, 0, 0, 3, 0]);
        assert_eq!(m.sigma, vec![1, 4]);
        assert_eq!(m.total_tiles, 5);
        assert_eq!(m.map(0), TileMapping { task: 1, tile: 0 });
        assert_eq!(m.map(1), TileMapping { task: 1, tile: 1 });
        assert_eq!(m.map(2), TileMapping { task: 4, tile: 0 });
        assert_eq!(m.map(4), TileMapping { task: 4, tile: 2 });
    }

    #[test]
    fn all_empty_launches_nothing() {
        let m = TwoStageMap::from_tile_counts(&[0, 0, 0]);
        assert_eq!(m.total_tiles, 0);
        assert_eq!(m.num_nonempty, 0);
    }

    #[test]
    fn simt_variant_agrees() {
        let m = TwoStageMap::from_tile_counts(&[0, 1, 0, 4, 2, 0, 1]);
        for b in 0..m.total_tiles {
            let (simt, _) = m.map_simt(b);
            assert_eq!(simt, m.map(b), "block {b}");
        }
    }

    #[test]
    fn cursor_walk_matches_per_block_map() {
        let m = TwoStageMap::from_tile_counts(&[0, 2, 0, 7, 1, 0, 3]);
        let mut cursor = MapCursor::new();
        let mut buf = Vec::new();
        m.map_all_into(&mut buf);
        assert_eq!(buf.len(), m.total_tiles as usize);
        for b in 0..m.total_tiles {
            assert_eq!(m.map_with_cursor(&mut cursor, b), m.map(b), "block {b}");
            assert_eq!(buf[b as usize], m.map(b), "block {b}");
        }
    }

    #[test]
    fn metadata_is_compressed() {
        // 64 tasks, one tile each, huge grid from big tasks: metadata stays
        // proportional to tasks, not tiles.
        let tiles = vec![1000u32; 64];
        let m = TwoStageMap::from_tile_counts(&tiles);
        assert_eq!(m.total_tiles, 64_000);
        assert!(m.metadata_bytes() <= 4 * (64 + 64));
    }

    #[test]
    fn property_two_stage_covers_exactly_nonempty_tiles() {
        prop::check(
            "two-stage-coverage",
            150,
            |g| {
                let n = 1 + g.rng.usize_below(g.size * 2 + 1);
                // ~half the tasks empty
                (0..n)
                    .map(|_| if g.rng.below(2) == 0 { 0 } else { g.rng.below(5) as u32 + 1 })
                    .collect::<Vec<u32>>()
            },
            |tiles| {
                let m = TwoStageMap::from_tile_counts(tiles);
                let mut seen = vec![0u32; tiles.len()];
                for b in 0..m.total_tiles {
                    let tm = m.map(b);
                    let (simt, _) = m.map_simt(b);
                    if tm != simt {
                        return Err(format!("scalar/simt disagree at {b}"));
                    }
                    seen[tm.task as usize] += 1;
                    if tiles[tm.task as usize] == 0 {
                        return Err(format!("block {b} mapped to empty task {}", tm.task));
                    }
                }
                if seen != *tiles {
                    return Err(format!("coverage {seen:?} != {tiles:?}"));
                }
                Ok(())
            },
        );
    }
}
