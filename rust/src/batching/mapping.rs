//! Algorithm 2: decompress the task mapping with a warp.
//!
//! Given the inclusive `TilePrefix` array and a thread block index `B`, find
//! `(h, l)`: the task this block belongs to and the tile index inside it.
//! The SIMT formulation: every lane `t` votes `B >= TilePrefix[t]`; the
//! number of set bits in the ballot is `h`; `l = B - TilePrefix[h-1]`.
//!
//! Three variants, all verified against each other:
//! * [`map_warp`]   — the paper's Algorithm 2, run on the exact 32-lane
//!   warp emulation; multi-pass loop for N > 32 ("let each warp loop this
//!   algorithm several times to scan the whole TilePrefix array").
//! * [`map_two_level`] — the 2-level variant for very large N the paper
//!   mentions and omits.
//! * [`map_scalar`] — branchless scalar reference (also the production path
//!   on CPU, and what a single thread would do).

use crate::batching::tile_prefix::{TwoLevelPrefix, PAD_MAX};
use crate::batching::warp::{Warp, WARP_SIZE};

/// The decompressed mapping for one thread block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileMapping {
    /// Task index `h` (index into whatever task list the prefix was built over).
    pub task: u32,
    /// Tile index `l` inside the task.
    pub tile: u32,
}

/// Scalar reference: first task whose inclusive prefix exceeds `block`.
pub fn map_scalar(prefix: &[u32], block: u32) -> TileMapping {
    let mut h = 0u32;
    for &p in prefix {
        if p != PAD_MAX && block >= p {
            h += 1;
        } else {
            break;
        }
    }
    let base = if h > 0 { prefix[(h - 1) as usize] } else { 0 };
    TileMapping { task: h, tile: block - base }
}

/// Binary-search variant (what a "smart" baseline would do per thread; used
/// by the mapping microbench to compare against the warp-vote cost model).
pub fn map_binary_search(prefix: &[u32], block: u32) -> TileMapping {
    // partition_point over the real (non-sentinel) prefix
    let n = prefix.iter().position(|&x| x == PAD_MAX).unwrap_or(prefix.len());
    let h = prefix[..n].partition_point(|&p| block >= p) as u32;
    let base = if h > 0 { prefix[(h - 1) as usize] } else { 0 };
    TileMapping { task: h, tile: block - base }
}

/// Algorithm 2, verbatim over the warp emulation, with the multi-pass loop
/// for N > WARP_SIZE.  Returns the mapping plus the number of warp passes
/// executed (the simulator charges decode cost per pass).
pub fn map_warp(prefix: &[u32], block: u32) -> (TileMapping, usize) {
    let mut passes = 0usize;
    let mut h_total = 0u32;
    for chunk in prefix.chunks(WARP_SIZE) {
        passes += 1;
        // p <- B >= TilePrefix[t]  (lane t; sentinel/pad lanes vote false)
        let mask = Warp::ballot(|lane| {
            lane < chunk.len() && chunk[lane] != PAD_MAX && block >= chunk[lane]
        });
        let h = Warp::popc(mask);
        h_total += h;
        // if any lane in this chunk voted false, the boundary is here: stop.
        if (h as usize) < chunk.len().min(WARP_SIZE) {
            break;
        }
    }
    let base = if h_total > 0 { prefix[(h_total - 1) as usize] } else { 0 };
    (TileMapping { task: h_total, tile: block - base }, passes)
}

/// 2-level lookup: one warp pass over L1 finds the group, one pass over the
/// group's L0 slice finds the task.  Returns (mapping, passes).
pub fn map_two_level(tl: &TwoLevelPrefix, block: u32) -> (TileMapping, usize) {
    let mut passes = 0usize;
    // pass(es) over L1 — groups whose *total* is <= block are fully below us
    let mut group = 0u32;
    for chunk in tl.l1.chunks(WARP_SIZE) {
        passes += 1;
        let mask = Warp::ballot(|lane| lane < chunk.len() && block >= chunk[lane]);
        let g = Warp::popc(mask);
        group += g;
        if (g as usize) < chunk.len().min(WARP_SIZE) {
            break;
        }
    }
    let group = group as usize;
    let start = group * tl.group;
    let end = ((group + 1) * tl.group).min(tl.l0.len());
    // pass over the selected L0 slice
    let slice = &tl.l0[start..end];
    let mut h_local = 0u32;
    for chunk in slice.chunks(WARP_SIZE) {
        passes += 1;
        let mask = Warp::ballot(|lane| lane < chunk.len() && block >= chunk[lane]);
        let h = Warp::popc(mask);
        h_local += h;
        if (h as usize) < chunk.len().min(WARP_SIZE) {
            break;
        }
    }
    let h = start as u32 + h_local;
    let base = if h > 0 { tl.l0[(h - 1) as usize] } else { 0 };
    (TileMapping { task: h, tile: block - base }, passes)
}

/// Sequential-decode cursor: amortized-O(1) mapping for ascending blocks.
///
/// [`map_scalar`] rescans the prefix from index 0 for every block, making a
/// full-grid decode O(total × N).  But the grid is walked in ascending
/// block order and the inclusive prefix is non-decreasing, so the task
/// index `h` never moves backwards — the cursor resumes the scan where the
/// previous block stopped, and a whole-grid decode touches each prefix
/// entry once: O(total + N).
///
/// Contract: blocks must be presented in non-decreasing order (a fresh
/// cursor per grid walk).  [`MapCursor::map`] is bitwise-equal to
/// [`map_scalar`] under that contract.
#[derive(Clone, Copy, Debug, Default)]
pub struct MapCursor {
    h: u32,
}

impl MapCursor {
    pub fn new() -> Self {
        MapCursor { h: 0 }
    }

    /// Decode `block` (≥ every block previously decoded through this
    /// cursor) against `prefix`.
    pub fn map(&mut self, prefix: &[u32], block: u32) -> TileMapping {
        let mut h = self.h as usize;
        while h < prefix.len() {
            let p = prefix[h];
            if p != PAD_MAX && block >= p {
                h += 1;
            } else {
                break;
            }
        }
        self.h = h as u32;
        let base = if h > 0 { prefix[h - 1] } else { 0 };
        TileMapping { task: h as u32, tile: block - base }
    }
}

/// Decompress the whole grid: mapping for every block `0..total`.
/// This is what the CPU executor iterates; the simulator charges per-block
/// decode costs from the pass counts instead.
pub fn map_all(prefix: &[u32], total: u32) -> Vec<TileMapping> {
    let mut out = Vec::new();
    map_all_into(prefix, total, &mut out);
    out
}

/// [`map_all`] into a caller-provided buffer (cleared first) — no per-step
/// allocation once the buffer has grown to the steady-state grid size.
///
/// Chunked prefix scan: instead of walking a [`MapCursor`] per block (one
/// prefix comparison *per block*), each prefix entry emits its whole
/// contiguous block run `[prefix[h-1], prefix[h])` at once as tiles
/// `0..count` — one pass over the prefix, one branch per *task*, and a
/// straight sequential fill of `out`.  O(total + N) like the cursor walk,
/// but with the per-block compare/branch traffic deleted; bitwise-equal to
/// the cursor (the tests pin it), including PAD_MAX sentinels, repeat-last
/// padding, and `total` short of or beyond the prefix coverage.
pub fn map_all_into(prefix: &[u32], total: u32, out: &mut Vec<TileMapping>) {
    out.clear();
    out.reserve(total as usize);
    let mut base = 0u32; // first block of task `tasks_done`'s run
    let mut tasks_done = 0u32;
    for &p in prefix {
        if p == PAD_MAX || base >= total {
            break;
        }
        let end = p.min(total);
        for tile in 0..end.saturating_sub(base) {
            out.push(TileMapping { task: tasks_done, tile });
        }
        base = base.max(end);
        tasks_done += 1;
    }
    // blocks past the scanned prefix (sentinel hit, or total beyond the
    // coverage) — exactly where a cursor's scan would have stopped
    for b in base..total {
        out.push(TileMapping { task: tasks_done, tile: b - base });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::tile_prefix::{build_from_counts, pad_to, pad_to_max};
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn paper_example_small() {
        // tasks with 2, 1, 3 tiles -> prefix [2, 3, 6]
        let prefix = build_from_counts(&[2, 1, 3]);
        let expect = [
            (0, 0, 0),
            (1, 0, 1),
            (2, 1, 0),
            (3, 2, 0),
            (4, 2, 1),
            (5, 2, 2),
        ];
        for (b, task, tile) in expect {
            let m = map_scalar(&prefix, b);
            assert_eq!((m.task, m.tile), (task, tile), "block {b}");
        }
    }

    #[test]
    fn warp_matches_scalar_padded() {
        let prefix = pad_to(&build_from_counts(&[2, 1, 3]), WARP_SIZE);
        for b in 0..6 {
            let (m, passes) = map_warp(&prefix, b);
            assert_eq!(m, map_scalar(&prefix, b));
            assert_eq!(passes, 1);
        }
    }

    #[test]
    fn warp_matches_scalar_sentinel_pad() {
        let prefix = pad_to_max(&build_from_counts(&[4, 4]), WARP_SIZE);
        for b in 0..8 {
            let (m, _) = map_warp(&prefix, b);
            assert_eq!(m, map_scalar(&prefix, b));
        }
    }

    #[test]
    fn multi_pass_for_large_n() {
        // 100 tasks, 1 tile each: block 75 -> task 75; needs 3 warp passes
        let tiles = vec![1u32; 100];
        let prefix = build_from_counts(&tiles);
        let (m, passes) = map_warp(&prefix, 75);
        assert_eq!(m, TileMapping { task: 75, tile: 0 });
        assert_eq!(passes, 3);
        // block 5 stops after the first pass
        let (_, p2) = map_warp(&prefix, 5);
        assert_eq!(p2, 1);
    }

    #[test]
    fn cursor_matches_scalar_over_every_grid() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n = 1 + rng.usize_below(300);
            let tiles: Vec<u32> = (0..n).map(|_| rng.below(5) as u32).collect();
            let prefix = build_from_counts(&tiles);
            let total: u32 = tiles.iter().sum();
            let mut cursor = MapCursor::new();
            for b in 0..total {
                assert_eq!(cursor.map(&prefix, b), map_scalar(&prefix, b), "b={b}");
            }
            let all = map_all(&prefix, total);
            assert_eq!(all.len(), total as usize);
            let mut reused = vec![TileMapping { task: 9, tile: 9 }; 7];
            map_all_into(&prefix, total, &mut reused);
            assert_eq!(all, reused);
        }
    }

    #[test]
    fn cursor_handles_padded_prefixes() {
        let prefix = pad_to(&build_from_counts(&[2, 0, 3]), WARP_SIZE);
        let sentinel = pad_to_max(&build_from_counts(&[2, 0, 3]), WARP_SIZE);
        let mut c1 = MapCursor::new();
        let mut c2 = MapCursor::new();
        for b in 0..5 {
            assert_eq!(c1.map(&prefix, b), map_scalar(&prefix, b));
            assert_eq!(c2.map(&sentinel, b), map_scalar(&sentinel, b));
        }
    }

    #[test]
    fn binary_search_matches_scalar() {
        let prefix = build_from_counts(&[3, 0, 0, 5, 1, 0, 2]);
        let total = *prefix.last().unwrap();
        for b in 0..total {
            assert_eq!(map_binary_search(&prefix, b), map_scalar(&prefix, b), "b={b}");
        }
    }

    #[test]
    fn two_level_matches_scalar() {
        let mut rng = Rng::new(5);
        let tiles: Vec<u32> = (0..512).map(|_| rng.below(4) as u32).collect();
        let tl = TwoLevelPrefix::build(&tiles, 32);
        let prefix = build_from_counts(&tiles);
        let total = tl.total_tiles();
        for b in (0..total).step_by(7) {
            let (m, passes) = map_two_level(&tl, b);
            assert_eq!(m, map_scalar(&prefix, b), "b={b}");
            // 512 tasks: <= 1 L1 pass (16 entries) + 1 L0 pass (32 entries)
            assert!(passes <= 2, "passes={passes}");
        }
    }

    #[test]
    fn zero_tile_tasks_are_skipped() {
        // middle task is empty: prefix [2, 2, 4] — block 2 must map to task 2
        let prefix = build_from_counts(&[2, 0, 2]);
        let m = map_scalar(&prefix, 2);
        assert_eq!(m, TileMapping { task: 2, tile: 0 });
        let (mw, _) = map_warp(&pad_to(&prefix, WARP_SIZE), 2);
        assert_eq!(mw, m);
    }

    #[test]
    fn property_two_level_and_warp_match_scalar_at_scale() {
        // N ≫ 32 tasks — the regime the flat prefix needs multiple warp
        // passes for and the 2-level prefix exists for — with ~half the
        // tasks empty, under both padding schemes (repeat-last and the
        // PAD_MAX sentinel).
        prop::check(
            "two-level-at-scale",
            40,
            |g| {
                let n = 33 + g.rng.usize_below(g.size * 30 + 200);
                let tiles: Vec<u32> = (0..n)
                    .map(|_| if g.rng.below(2) == 0 { 0 } else { g.rng.below(4) as u32 + 1 })
                    .collect();
                let group = 8 + g.rng.usize_below(64);
                (tiles, group)
            },
            |(tiles, group)| {
                let prefix = build_from_counts(tiles);
                let total: u32 = tiles.iter().sum();
                let width = prefix.len().div_ceil(WARP_SIZE) * WARP_SIZE;
                let padded = pad_to(&prefix, width);
                let sentinel = pad_to_max(&prefix, width);
                let tl = TwoLevelPrefix::build(tiles, *group);
                if tl.total_tiles() != total {
                    return Err(format!("two-level total {} != {total}", tl.total_tiles()));
                }
                if total == 0 {
                    // all-empty prefix: nothing to decode, nothing to launch
                    return Ok(());
                }
                // sample the grid (always including the boundary blocks)
                let step = (total as usize / 97).max(1);
                let blocks = (0..total).step_by(step).chain([total - 1]);
                for b in blocks {
                    let want = map_scalar(&prefix, b);
                    let (w1, p1) = map_warp(&padded, b);
                    let (w2, p2) = map_warp(&sentinel, b);
                    let (t, pt) = map_two_level(&tl, b);
                    if w1 != want || w2 != want {
                        return Err(format!("warp decode diverges at block {b}"));
                    }
                    if t != want {
                        return Err(format!("two-level decode diverges at block {b}"));
                    }
                    // pass-count sanity: never more than a full scan
                    let max_flat = prefix.len().div_ceil(WARP_SIZE);
                    if p1 > max_flat || p2 > max_flat {
                        return Err(format!("flat passes {p1}/{p2} exceed scan bound"));
                    }
                    let max_two = tl.l1.len().div_ceil(WARP_SIZE)
                        + (*group).min(tl.l0.len()).div_ceil(WARP_SIZE);
                    if pt > max_two {
                        return Err(format!("two-level passes {pt} exceed bound {max_two}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn warp_pass_counts_monotone_in_task_count() {
        // decoding the LAST block is the worst case: the flat prefix scans
        // ⌈N/32⌉ chunks, so passes must grow monotonically with N, while
        // the 2-level prefix stays at 2 passes until N outgrows 32 groups
        let mut last_flat = 0usize;
        let mut last_two = 0usize;
        for n in [32usize, 64, 128, 256, 512, 1024] {
            let tiles = vec![1u32; n];
            let prefix = build_from_counts(&tiles);
            let last_block = (n - 1) as u32;
            let (m, flat) = map_warp(&prefix, last_block);
            assert_eq!(m, TileMapping { task: last_block, tile: 0 });
            assert_eq!(flat, n.div_ceil(32), "flat passes scan the whole prefix");
            assert!(flat >= last_flat, "flat passes must be monotone in N");
            let tl = TwoLevelPrefix::build(&tiles, 32);
            let (m2, two) = map_two_level(&tl, last_block);
            assert_eq!(m2, map_scalar(&prefix, last_block));
            assert!(two >= last_two, "two-level passes must be monotone in N");
            if n > 64 {
                assert!(
                    two < flat,
                    "two-level must beat the flat scan for N={n}: {two} vs {flat}"
                );
            }
            last_flat = flat;
            last_two = two;
        }
        // the whole point of the 2-level prefix: 1024 tasks in 2 passes
        assert_eq!(last_two, 2);
        assert_eq!(last_flat, 32);
    }

    #[test]
    fn all_empty_prefix_decodes_nothing_under_every_variant() {
        // every task empty: total is 0, and the padded/sentinel arrays
        // must report 0 launchable tiles rather than decoding garbage
        let tiles = vec![0u32; 100];
        let prefix = build_from_counts(&tiles);
        assert_eq!(*prefix.last().unwrap(), 0);
        let sentinel = pad_to_max(&prefix, 128);
        assert_eq!(crate::batching::tile_prefix::total_tiles(&sentinel), 0);
        let tl = TwoLevelPrefix::build(&tiles, 32);
        assert_eq!(tl.total_tiles(), 0);
    }

    #[test]
    fn property_all_variants_agree_and_invert() {
        prop::check(
            "mapping-inverts-prefix",
            200,
            |g| {
                let n = 1 + g.rng.usize_below(g.size * 4 + 1);
                let tiles: Vec<u32> = (0..n).map(|_| g.rng.below(6) as u32).collect();
                tiles
            },
            |tiles| {
                let prefix = build_from_counts(tiles);
                let total: u32 = tiles.iter().sum();
                let padded = pad_to(&prefix, WARP_SIZE.max(prefix.len()));
                // reconstruct per-task tile counts from the mapping
                let mut seen = vec![0u32; tiles.len()];
                for b in 0..total {
                    let m = map_scalar(&prefix, b);
                    let (mw, _) = map_warp(&padded, b);
                    let mb = map_binary_search(&prefix, b);
                    if m != mw || m != mb {
                        return Err(format!("variants disagree at block {b}: {m:?} {mw:?} {mb:?}"));
                    }
                    if m.task as usize >= tiles.len() {
                        return Err(format!("task OOB at block {b}: {m:?}"));
                    }
                    if m.tile != seen[m.task as usize] {
                        return Err(format!("tile order broken at block {b}: {m:?}"));
                    }
                    seen[m.task as usize] += 1;
                }
                if seen != *tiles {
                    return Err(format!("coverage mismatch: {seen:?} vs {tiles:?}"));
                }
                Ok(())
            },
        );
    }
}
