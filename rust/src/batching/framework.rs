//! Algorithm 3: the batching framework itself.
//!
//! A [`StaticBatch`] owns N heterogeneous task descriptors and the
//! two-stage mapping built over them.  `run` launches the conceptual grid:
//! for every thread block it decompresses the mapping and dispatches to the
//! task's "device function" — a Rust closure registered per [`TaskKind`]
//! dispatch id, mirroring the `taskFunc_1..K` switch in the paper.
//!
//! The framework is generic over the execution context `C`, so the same
//! dispatch structure drives (a) the CPU numeric executor in
//! [`crate::moe::cpu_exec`] and (b) pure accounting runs in the simulator.

use std::collections::BTreeMap;

use crate::batching::mapping::TileMapping;
use crate::batching::task::TaskDescriptor;
use crate::batching::two_stage::TwoStageMap;

/// A "device function": handles one tile of one task.
/// Arguments: context, task descriptor, task index, tile index within task.
pub type TaskFunc<C> = Box<dyn Fn(&mut C, &TaskDescriptor, u32, u32)>;

/// A statically batched set of heterogeneous tasks, ready to "launch".
pub struct StaticBatch<C> {
    tasks: Vec<TaskDescriptor>,
    map: TwoStageMap,
    funcs: BTreeMap<usize, TaskFunc<C>>,
}

impl<C> StaticBatch<C> {
    /// Build the batch: computes ν(T) per task, σ over non-empty tasks, and
    /// the compressed TilePrefix — everything Algorithm 1 does on the host.
    pub fn new(tasks: Vec<TaskDescriptor>) -> Self {
        let map = TwoStageMap::from_tasks(&tasks);
        StaticBatch { tasks, map, funcs: BTreeMap::new() }
    }

    /// Register the device function for a dispatch id (`taskFunc_i`).
    pub fn register(&mut self, dispatch_id: usize, f: TaskFunc<C>) -> &mut Self {
        self.funcs.insert(dispatch_id, f);
        self
    }

    pub fn tasks(&self) -> &[TaskDescriptor] {
        &self.tasks
    }

    pub fn mapping(&self) -> &TwoStageMap {
        &self.map
    }

    /// Total thread blocks the fused kernel launches.
    pub fn total_tiles(&self) -> u32 {
        self.map.total_tiles
    }

    /// Decompress the mapping for one block (Algorithm 4).
    pub fn map_block(&self, block: u32) -> TileMapping {
        self.map.map(block)
    }

    /// "Launch" the fused kernel: every block decodes its mapping and runs
    /// its task's device function (Algorithm 3 body). Returns the number of
    /// blocks executed.
    ///
    /// Panics if a task kind has no registered function — a batch with an
    /// unhandled kind is a build error, same as a missing `taskFunc_i`
    /// symbol at CUDA link time.
    pub fn run(&self, ctx: &mut C) -> u32 {
        for block in 0..self.map.total_tiles {
            let m = self.map.map(block);
            let task = &self.tasks[m.task as usize];
            let f = self
                .funcs
                .get(&task.kind.dispatch_id())
                .unwrap_or_else(|| panic!("no device function for {:?}", task.kind));
            f(ctx, task, m.task, m.tile);
        }
        self.map.total_tiles
    }

    /// Like `run`, but through the warp-emulated SIMT mapping; returns the
    /// total number of warp passes (decode cost) alongside the block count.
    pub fn run_simt(&self, ctx: &mut C) -> (u32, usize) {
        let mut passes = 0;
        for block in 0..self.map.total_tiles {
            let (m, p) = self.map.map_simt(block);
            passes += p;
            let task = &self.tasks[m.task as usize];
            let f = self
                .funcs
                .get(&task.kind.dispatch_id())
                .unwrap_or_else(|| panic!("no device function for {:?}", task.kind));
            f(ctx, task, m.task, m.tile);
        }
        (self.map.total_tiles, passes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::task::TaskKind;

    fn gemm(rows: usize, strategy: usize) -> TaskDescriptor {
        TaskDescriptor {
            kind: TaskKind::Gemm { strategy },
            rows,
            cols: 128,
            inner: 32,
            tile_rows: 64,
            tile_cols: 128,
        }
    }

    fn reduce(rows: usize) -> TaskDescriptor {
        TaskDescriptor {
            kind: TaskKind::ReduceSum,
            rows,
            cols: 1,
            inner: 256,
            tile_rows: 32,
            tile_cols: 1,
        }
    }

    /// Context that records which (task, tile, kind) tuples executed.
    #[derive(Default)]
    struct Recorder {
        calls: Vec<(u32, u32, usize)>,
    }

    fn build_batch(tasks: Vec<TaskDescriptor>) -> StaticBatch<Recorder> {
        let mut b = StaticBatch::new(tasks);
        for id in [
            TaskKind::ReduceSum.dispatch_id(),
            TaskKind::ElementWise.dispatch_id(),
            TaskKind::Gemm { strategy: 0 }.dispatch_id(),
            TaskKind::Gemm { strategy: 1 }.dispatch_id(),
        ] {
            b.register(
                id,
                Box::new(move |c: &mut Recorder, _t, task, tile| {
                    c.calls.push((task, tile, id));
                }),
            );
        }
        b
    }

    #[test]
    fn heterogeneous_batch_dispatches_by_kind() {
        // GEMM(128 rows, strat 0) = 2 tiles; reduce(64 rows) = 2 tiles;
        // GEMM(64 rows, strat 1) = 1 tile
        let batch = build_batch(vec![gemm(128, 0), reduce(64), gemm(64, 1)]);
        let mut ctx = Recorder::default();
        let blocks = batch.run(&mut ctx);
        assert_eq!(blocks, 5);
        let g0 = TaskKind::Gemm { strategy: 0 }.dispatch_id();
        let g1 = TaskKind::Gemm { strategy: 1 }.dispatch_id();
        let rs = TaskKind::ReduceSum.dispatch_id();
        assert_eq!(
            ctx.calls,
            vec![(0, 0, g0), (0, 1, g0), (1, 0, rs), (1, 1, rs), (2, 0, g1)]
        );
    }

    #[test]
    fn empty_tasks_never_dispatch() {
        let batch = build_batch(vec![gemm(0, 0), reduce(32), gemm(0, 1)]);
        let mut ctx = Recorder::default();
        batch.run(&mut ctx);
        assert!(ctx.calls.iter().all(|&(task, _, _)| task == 1));
        assert_eq!(ctx.calls.len(), 1);
    }

    #[test]
    fn simt_run_agrees_with_scalar_run() {
        let batch = build_batch(vec![gemm(300, 0), reduce(100), gemm(64, 1), reduce(0)]);
        let mut a = Recorder::default();
        let mut b = Recorder::default();
        batch.run(&mut a);
        let (_, passes) = batch.run_simt(&mut b);
        assert_eq!(a.calls, b.calls);
        assert!(passes >= b.calls.len()); // at least one pass per block
    }

    #[test]
    #[should_panic(expected = "no device function")]
    fn unregistered_kind_panics() {
        let mut batch: StaticBatch<Recorder> = StaticBatch::new(vec![gemm(64, 7)]);
        batch.register(
            TaskKind::ReduceSum.dispatch_id(),
            Box::new(|_, _, _, _| {}),
        );
        let mut ctx = Recorder::default();
        batch.run(&mut ctx);
    }
}
