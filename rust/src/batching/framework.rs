//! Algorithm 3: the batching framework itself.
//!
//! A [`StaticBatch`] owns N heterogeneous task descriptors, the two-stage
//! mapping built over them, and a validated [`DispatchTable`].  `run`
//! launches the conceptual grid: for every thread block it decompresses
//! the mapping and dispatches to the task's "device function" — a Rust
//! closure registered per [`crate::batching::task::TaskKind`], mirroring
//! the `taskFunc_1..K` switch in the paper.
//!
//! Construction goes through [`StaticBatch::try_new`] with a
//! [`DispatchTableBuilder`]: coverage of every task kind in the batch is
//! checked *before* launch, so an unhandled kind is a build error (like a
//! missing `taskFunc_i` symbol at CUDA link time) rather than a panic in
//! the middle of the grid.  (The pre-0.2 panic-at-launch `new`/`register`
//! shim served its one-release deprecation window and is gone.)
//!
//! The framework is generic over the execution context `C`, so the same
//! dispatch structure drives (a) the CPU numeric executor in
//! [`crate::moe::cpu_exec`] and (b) pure accounting runs in the simulator.

use crate::batching::dispatch::{DispatchError, DispatchTable, DispatchTableBuilder};
use crate::batching::mapping::TileMapping;
use crate::batching::task::TaskDescriptor;
use crate::batching::two_stage::TwoStageMap;

/// A statically batched set of heterogeneous tasks, ready to "launch".
pub struct StaticBatch<C> {
    tasks: Vec<TaskDescriptor>,
    map: TwoStageMap,
    table: DispatchTable<C>,
}

impl<C> StaticBatch<C> {
    /// Build the batch: computes ν(T) per task, σ over non-empty tasks, the
    /// compressed TilePrefix — everything Algorithm 1 does on the host —
    /// and validates that `builder` covers every task kind in the batch.
    pub fn try_new(
        tasks: Vec<TaskDescriptor>,
        builder: DispatchTableBuilder<C>,
    ) -> Result<Self, DispatchError> {
        let table = builder.build(&tasks)?;
        let map = TwoStageMap::from_tasks(&tasks);
        Ok(StaticBatch { tasks, map, table })
    }

    /// The batch's task descriptors, grid order.
    pub fn tasks(&self) -> &[TaskDescriptor] {
        &self.tasks
    }

    /// The two-stage mapping built over the tasks (Algorithms 1/2/4).
    pub fn mapping(&self) -> &TwoStageMap {
        &self.map
    }

    /// The validated kind → device-function table.
    pub fn dispatch_table(&self) -> &DispatchTable<C> {
        &self.table
    }

    /// Total thread blocks the fused kernel launches.
    pub fn total_tiles(&self) -> u32 {
        self.map.total_tiles
    }

    /// Decompress the mapping for one block (Algorithm 4).
    pub fn map_block(&self, block: u32) -> TileMapping {
        self.map.map(block)
    }

    /// The single dispatch site both launch modes funnel through: resolve
    /// the block's task, look up its device function, run the tile.  The
    /// lookup cannot miss — [`StaticBatch::try_new`] validated coverage of
    /// every task kind at construction.
    fn dispatch_block(&self, ctx: &mut C, m: TileMapping) {
        let task = &self.tasks[m.task as usize];
        let f = self
            .table
            .get(&task.kind)
            .expect("DispatchTable coverage validated at construction");
        f(ctx, task, m.task, m.tile);
    }

    /// "Launch" the fused kernel: every block decodes its mapping and runs
    /// its task's device function (Algorithm 3 body). Returns the number of
    /// blocks executed.  Blocks ascend, so the decode runs through a
    /// [`crate::batching::mapping::MapCursor`]: O(total + M) for the whole
    /// grid instead of O(total × M) rescans, bit-identical mappings.
    pub fn run(&self, ctx: &mut C) -> u32 {
        let mut cursor = crate::batching::mapping::MapCursor::new();
        for block in 0..self.map.total_tiles {
            self.dispatch_block(ctx, self.map.map_with_cursor(&mut cursor, block));
        }
        self.map.total_tiles
    }

    /// Like `run`, but through the warp-emulated SIMT mapping; returns the
    /// total number of warp passes (decode cost) alongside the block count.
    pub fn run_simt(&self, ctx: &mut C) -> (u32, usize) {
        let mut passes = 0;
        for block in 0..self.map.total_tiles {
            let (m, p) = self.map.map_simt(block);
            passes += p;
            self.dispatch_block(ctx, m);
        }
        (self.map.total_tiles, passes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::task::TaskKind;

    fn gemm(rows: usize, strategy: usize) -> TaskDescriptor {
        TaskDescriptor {
            kind: TaskKind::Gemm { strategy },
            rows,
            cols: 128,
            inner: 32,
            tile_rows: 64,
            tile_cols: 128,
        }
    }

    fn reduce(rows: usize) -> TaskDescriptor {
        TaskDescriptor {
            kind: TaskKind::ReduceSum,
            rows,
            cols: 1,
            inner: 256,
            tile_rows: 32,
            tile_cols: 1,
        }
    }

    /// Context that records which (task, tile, kind) tuples executed.
    #[derive(Default)]
    struct Recorder {
        calls: Vec<(u32, u32, usize)>,
    }

    fn build_batch(tasks: Vec<TaskDescriptor>) -> StaticBatch<Recorder> {
        let mut builder = DispatchTableBuilder::new();
        for id in [
            TaskKind::ReduceSum.dispatch_id(),
            TaskKind::ElementWise.dispatch_id(),
            TaskKind::Gemm { strategy: 0 }.dispatch_id(),
            TaskKind::Gemm { strategy: 1 }.dispatch_id(),
        ] {
            builder = builder.on_id(id, move |c: &mut Recorder, _t, task, tile| {
                c.calls.push((task, tile, id));
            });
        }
        StaticBatch::try_new(tasks, builder).expect("all kinds covered")
    }

    #[test]
    fn heterogeneous_batch_dispatches_by_kind() {
        // GEMM(128 rows, strat 0) = 2 tiles; reduce(64 rows) = 2 tiles;
        // GEMM(64 rows, strat 1) = 1 tile
        let batch = build_batch(vec![gemm(128, 0), reduce(64), gemm(64, 1)]);
        let mut ctx = Recorder::default();
        let blocks = batch.run(&mut ctx);
        assert_eq!(blocks, 5);
        let g0 = TaskKind::Gemm { strategy: 0 }.dispatch_id();
        let g1 = TaskKind::Gemm { strategy: 1 }.dispatch_id();
        let rs = TaskKind::ReduceSum.dispatch_id();
        assert_eq!(
            ctx.calls,
            vec![(0, 0, g0), (0, 1, g0), (1, 0, rs), (1, 1, rs), (2, 0, g1)]
        );
    }

    #[test]
    fn empty_tasks_never_dispatch() {
        let batch = build_batch(vec![gemm(0, 0), reduce(32), gemm(0, 1)]);
        let mut ctx = Recorder::default();
        batch.run(&mut ctx);
        assert!(ctx.calls.iter().all(|&(task, _, _)| task == 1));
        assert_eq!(ctx.calls.len(), 1);
    }

    #[test]
    fn simt_run_agrees_with_scalar_run() {
        let batch = build_batch(vec![gemm(300, 0), reduce(100), gemm(64, 1), reduce(0)]);
        let mut a = Recorder::default();
        let mut b = Recorder::default();
        batch.run(&mut a);
        let (_, passes) = batch.run_simt(&mut b);
        assert_eq!(a.calls, b.calls);
        assert!(passes >= b.calls.len()); // at least one pass per block
    }

    #[test]
    fn unregistered_kind_is_a_build_error() {
        let builder: DispatchTableBuilder<Recorder> = DispatchTableBuilder::new()
            .on(TaskKind::ReduceSum, |_, _, _, _| {});
        let err = StaticBatch::try_new(vec![gemm(64, 7)], builder).unwrap_err();
        assert!(matches!(
            err,
            crate::batching::dispatch::DispatchError::Unregistered {
                kind: TaskKind::Gemm { strategy: 7 },
                task_index: 0,
            }
        ));
    }
}
