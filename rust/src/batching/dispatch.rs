//! Typed dispatch tables: the construction-time-checked replacement for
//! the old panic-on-missing closure registry.
//!
//! In the paper's Algorithm 3 every task kind `i` must have a compiled
//! `taskFunc_i` or the fused kernel fails to *link*; the analogous Rust
//! guarantee is that a [`DispatchTable`] can only be built against a batch
//! whose every [`TaskKind`] has a registered device function.  A missing
//! registration is a [`DispatchError::Unregistered`] at `build()` time —
//! never a mid-launch panic.

use std::collections::BTreeMap;

use crate::batching::task::{TaskDescriptor, TaskKind};

/// A "device function": handles one tile of one task.
/// Arguments: context, task descriptor, task index, tile index within task.
pub type DeviceFn<C> = Box<dyn Fn(&mut C, &TaskDescriptor, u32, u32)>;

/// One dispatch event: which device function ran, for which task and tile.
/// Backends record these when asked so cross-backend agreement can be
/// asserted (the sim and CPU executors must dispatch identical sequences).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DispatchRecord {
    /// Index of the task within the batch (grid order).
    pub task: u32,
    /// Tile index within the task.
    pub tile: u32,
    /// The kind the dispatch resolved to.
    pub kind: TaskKind,
}

/// Why a dispatch table could not be built.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DispatchError {
    /// A task in the batch has no registered device function — the Rust
    /// analog of a missing `taskFunc_i` symbol at CUDA link time.
    Unregistered { kind: TaskKind, task_index: usize },
    /// Two registrations collided on one dispatch id.
    Duplicate { dispatch_id: usize },
}

impl std::fmt::Display for DispatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DispatchError::Unregistered { kind, task_index } => write!(
                f,
                "no device function registered for {kind:?} (task {task_index} in the batch)"
            ),
            DispatchError::Duplicate { dispatch_id } => {
                write!(f, "device function registered twice for dispatch id {dispatch_id}")
            }
        }
    }
}

impl std::error::Error for DispatchError {}

/// Builder for a [`DispatchTable`]: register device functions by kind (or
/// raw dispatch id), then `build()` against the batch's task list.
pub struct DispatchTableBuilder<C> {
    entries: BTreeMap<usize, DeviceFn<C>>,
    duplicates: Vec<usize>,
}

impl<C> Default for DispatchTableBuilder<C> {
    fn default() -> Self {
        Self::new()
    }
}

impl<C> DispatchTableBuilder<C> {
    pub fn new() -> Self {
        DispatchTableBuilder { entries: BTreeMap::new(), duplicates: Vec::new() }
    }

    /// Register the device function for a task kind (`taskFunc_i`).
    pub fn on<F>(self, kind: TaskKind, f: F) -> Self
    where
        F: Fn(&mut C, &TaskDescriptor, u32, u32) + 'static,
    {
        self.on_id(kind.dispatch_id(), f)
    }

    /// Register by raw dispatch id (for closed-over generated ids).
    pub fn on_id<F>(mut self, dispatch_id: usize, f: F) -> Self
    where
        F: Fn(&mut C, &TaskDescriptor, u32, u32) + 'static,
    {
        if self.entries.insert(dispatch_id, Box::new(f)).is_some() {
            self.duplicates.push(dispatch_id);
        }
        self
    }

    /// Validate coverage: every kind appearing in `tasks` must have a
    /// registered function.  Duplicate registrations are also rejected —
    /// silently shadowing a device function is a build error too.
    pub fn build(self, tasks: &[TaskDescriptor]) -> Result<DispatchTable<C>, DispatchError> {
        if let Some(&dispatch_id) = self.duplicates.first() {
            return Err(DispatchError::Duplicate { dispatch_id });
        }
        for (task_index, t) in tasks.iter().enumerate() {
            if !self.entries.contains_key(&t.kind.dispatch_id()) {
                return Err(DispatchError::Unregistered { kind: t.kind, task_index });
            }
        }
        Ok(DispatchTable { entries: self.entries })
    }
}

/// A validated kind → device-function table.  Constructing one proves the
/// batch is fully dispatchable; lookups during the launch cannot miss.
pub struct DispatchTable<C> {
    entries: BTreeMap<usize, DeviceFn<C>>,
}

impl<C> DispatchTable<C> {
    /// The device function for a task kind, if registered.
    pub fn get(&self, kind: &TaskKind) -> Option<&DeviceFn<C>> {
        self.entries.get(&kind.dispatch_id())
    }

    /// Whether this table has a device function for `kind`.
    pub fn covers(&self, kind: &TaskKind) -> bool {
        self.entries.contains_key(&kind.dispatch_id())
    }

    /// Registered device functions.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no device function is registered (empty batches only).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm(strategy: usize) -> TaskDescriptor {
        TaskDescriptor {
            kind: TaskKind::Gemm { strategy },
            rows: 64,
            cols: 128,
            inner: 32,
            tile_rows: 64,
            tile_cols: 128,
        }
    }

    #[test]
    fn build_accepts_full_coverage() {
        let tasks = vec![gemm(0), gemm(1)];
        let table: DispatchTable<()> = DispatchTableBuilder::new()
            .on(TaskKind::Gemm { strategy: 0 }, |_, _, _, _| {})
            .on(TaskKind::Gemm { strategy: 1 }, |_, _, _, _| {})
            .build(&tasks)
            .expect("covered");
        assert_eq!(table.len(), 2);
        assert!(table.covers(&TaskKind::Gemm { strategy: 0 }));
        assert!(!table.covers(&TaskKind::ReduceSum));
    }

    #[test]
    fn build_rejects_unregistered_kind() {
        let tasks = vec![gemm(0), gemm(7)];
        let err = DispatchTableBuilder::<()>::new()
            .on(TaskKind::Gemm { strategy: 0 }, |_, _, _, _| {})
            .build(&tasks)
            .unwrap_err();
        assert_eq!(
            err,
            DispatchError::Unregistered { kind: TaskKind::Gemm { strategy: 7 }, task_index: 1 }
        );
        assert!(err.to_string().contains("no device function registered"));
    }

    #[test]
    fn build_rejects_duplicate_registration() {
        let err = DispatchTableBuilder::<()>::new()
            .on(TaskKind::ReduceSum, |_, _, _, _| {})
            .on(TaskKind::ReduceSum, |_, _, _, _| {})
            .build(&[])
            .unwrap_err();
        assert_eq!(err, DispatchError::Duplicate { dispatch_id: TaskKind::ReduceSum.dispatch_id() });
    }

    #[test]
    fn empty_batch_builds_with_empty_table() {
        let table: DispatchTable<()> = DispatchTableBuilder::new().build(&[]).expect("empty ok");
        assert!(table.is_empty());
    }
}
