//! Grouped GEMM baseline — the SOTA the paper improves on (Section 2.1/2.2).
//!
//! Three defects, all modeled:
//! 1. **One shared tiling strategy** for the whole group, sized for the mean
//!    task: big tasks lose intensity or small tasks waste tensor-core rows
//!    on padding (`occupied_flops > useful_flops`).
//! 2. **Dynamic on-device scheduling**: every tile pays an atomic ticket +
//!    problem-descriptor fetch; the descriptor table grows with the group
//!    count (empty groups still occupy descriptor slots).
//! 3. **Input gather copies**: the grouped API needs contiguous per-expert
//!    inputs, so every routed row is copied once before the kernel runs
//!    (bandwidth time + a small launch for the gather kernel).

use crate::exec::{Backend, ExecContext, ExecError, Outcome};
use crate::moe::config::MoeShape;
use crate::moe::planner::ExecutionPlan;
use crate::moe::routing::ExpertLoad;
use crate::moe::tiling::{self, CATALOG};
use crate::sim::cost::gemm_tiles;
use crate::sim::overhead::MappingMode;
use crate::sim::specs::GpuSpec;
use crate::sim::trace::SimResult;
use crate::sim::wave;

pub struct GroupedGemm;

impl GroupedGemm {
    /// Time to build the contiguous input copies (the Section 4.3 overhead):
    /// read + write every routed row once, plus one extra kernel launch.
    pub fn gather_copy_time_s(shape: &MoeShape, load: &ExpertLoad, spec: &GpuSpec) -> f64 {
        let rows: usize = load.counts.iter().sum();
        let bytes = 2.0 * (rows * shape.d_model * shape.dtype_bytes) as f64; // rd + wr
        spec.launch_us * 1e-6 + bytes / (spec.hbm_gbps * 1e9)
    }

    fn simulate_load(shape: &MoeShape, load: &ExpertLoad, spec: &GpuSpec) -> (SimResult, u32) {
        // defect 1: single tiling strategy chosen for the mean group size
        let sid = tiling::select_single_for_batch(&load.counts);
        let s = CATALOG[sid];

        // defect 2: dynamic scheduling cost per tile
        let mode = MappingMode::DynamicOnDevice { groups: shape.experts };
        let pressure = load.counts.iter().filter(|&&c| c > 0).count() as f64
            * shape.weight_bytes() as f64;
        let decode = mode.decode_ns(spec, pressure);

        let mut tiles = Vec::new();
        for (e, &rows) in load.counts.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            tiles.extend(gemm_tiles(
                e as u32,
                rows,
                shape.d_ff,
                shape.d_model,
                s.tm,
                s.tn,
                shape.dtype(),
                decode,
            ));
        }

        // defect 3: gather copies before the kernel
        let host = Self::gather_copy_time_s(shape, load, spec)
            + mode.host_time_s(spec)
            + mode.launch_time_s(spec);
        let blocks = tiles.len() as u32;
        (wave::run_waves(&tiles, spec, host), blocks)
    }
}

impl Backend for GroupedGemm {
    fn name(&self) -> &'static str {
        "grouped GEMM (SOTA)"
    }

    fn execute(
        &mut self,
        plan: &ExecutionPlan,
        ctx: &mut ExecContext<'_>,
    ) -> Result<Outcome, ExecError> {
        let load = plan.expert_load();
        let (sim, blocks) = Self::simulate_load(&plan.shape(), &load, &ctx.spec);
        Ok(Outcome { backend: self.name(), blocks, sim: Some(sim), output: None, trace: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{ExecutionSession, SimBackend};
    use crate::moe::routing::LoadScenario;

    fn run_pair(load: &ExpertLoad) -> (Outcome, Outcome) {
        let shape = MoeShape::paper_table1();
        let grouped = ExecutionSession::new(shape)
            .gpu(GpuSpec::h800())
            .backend(GroupedGemm)
            .run(load)
            .unwrap();
        let ours = ExecutionSession::new(shape)
            .gpu(GpuSpec::h800())
            .backend(SimBackend::ours())
            .run(load)
            .unwrap();
        (grouped, ours)
    }

    #[test]
    fn single_tiling_wastes_compute_on_worst_case() {
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Worst.counts(&shape, 0);
        let (grouped, ours) = run_pair(&load);
        // mean-sized tiling (128 rows) on 56 single-token experts: >99% of
        // those tiles' tensor-core cycles are padding
        assert!(grouped.sim().padding_waste() > ours.sim().padding_waste());
        assert!(grouped.time_s() > ours.time_s());
    }

    #[test]
    fn gather_copy_costs_bandwidth() {
        let shape = MoeShape::paper_table1();
        let spec = GpuSpec::h800();
        let load = LoadScenario::Balanced.counts(&shape, 0);
        let t = GroupedGemm::gather_copy_time_s(&shape, &load, &spec);
        // 32768 rows x 3584 x 2B x2 = 470 MB -> ~140 us on 3.35 TB/s
        assert!(t > 50e-6 && t < 500e-6, "t = {t}");
    }

    #[test]
    fn balanced_case_close_to_ours_but_behind() {
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Balanced.counts(&shape, 0);
        let (grouped, ours) = run_pair(&load);
        assert!(grouped.time_s() > ours.time_s());
        assert!(grouped.time_s() < ours.time_s() * 1.6, "should be competitive when balanced");
    }
}
