//! Baseline MoE implementations the paper compares against (Section 2).
//!
//! All baselines implement [`crate::exec::Backend`] and run on the same
//! simulator and the same routing outcomes as our kernel, so comparisons
//! isolate the scheduling/batching strategy.  They derive the routing
//! outcome from the [`crate::moe::planner::ExecutionPlan`] they are handed
//! and then apply their *own* tiling/scheduling defects — the plan fixes
//! what work exists, the backend decides how badly it runs:
//!
//! * [`naive_loop`] — one kernel launch per expert (DeepSpeed-MoE style):
//!   per-launch overhead, no cross-expert overlap.
//! * [`grouped_gemm`] — the SOTA: single fused kernel, but one shared
//!   tiling strategy, on-device dynamic tile scheduling, and pre-gathered
//!   contiguous input copies (the Section 4.3 overhead).
//! * [`two_phase`] — the PPoPP'19 [10] framework: per-task tiling like
//!   ours, but a full per-block mapping array (H2D copy + poor locality).
//!
//! Our own kernel's backend is [`crate::exec::SimBackend::ours`]; the
//! comparison registry that iterates all four is
//! [`crate::exec::all_backends`].

pub mod grouped_gemm;
pub mod naive_loop;
pub mod two_phase;

pub use grouped_gemm::GroupedGemm;
pub use naive_loop::NaiveLoop;
pub use two_phase::TwoPhase;

#[cfg(test)]
mod tests {
    use crate::exec::{all_backends, ExecutionSession, SimBackend};
    use crate::moe::config::MoeShape;
    use crate::moe::routing::LoadScenario;
    use crate::sim::specs::GpuSpec;

    #[test]
    fn ours_beats_every_baseline_under_imbalance() {
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Worst.counts(&shape, 0);
        let ours = ExecutionSession::new(shape)
            .gpu(GpuSpec::h800())
            .run(&load)
            .unwrap()
            .time_s();
        for b in all_backends().into_iter().skip(1) {
            let mut s = ExecutionSession::new(shape).gpu(GpuSpec::h800()).boxed_backend(b);
            let r = s.run(&load).unwrap();
            assert!(
                r.time_s() >= ours * 0.999,
                "{} beat ours: {} vs {}",
                r.backend,
                r.time_s(),
                ours
            );
        }
    }

    #[test]
    fn balanced_case_everyone_within_2x_of_ours() {
        // With perfectly balanced load the fused approaches converge; only
        // the naive loop should lag badly.
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Balanced.counts(&shape, 0);
        let ours = ExecutionSession::new(shape)
            .gpu(GpuSpec::h20())
            .backend(SimBackend::ours())
            .run(&load)
            .unwrap()
            .time_s();
        let grouped = ExecutionSession::new(shape)
            .gpu(GpuSpec::h20())
            .backend(super::GroupedGemm)
            .run(&load)
            .unwrap()
            .time_s();
        assert!(grouped < ours * 2.0);
    }
}
