//! Baseline MoE implementations the paper compares against (Section 2).
//!
//! All baselines run on the same simulator and the same routing outcomes as
//! our kernel, so comparisons isolate the scheduling/batching strategy:
//!
//! * [`naive_loop`] — one kernel launch per expert (DeepSpeed-MoE style):
//!   per-launch overhead, no cross-expert overlap.
//! * [`grouped_gemm`] — the SOTA: single fused kernel, but one shared
//!   tiling strategy, on-device dynamic tile scheduling, and pre-gathered
//!   contiguous input copies (the Section 4.3 overhead).
//! * [`two_phase`] — the PPoPP'19 [10] framework: per-task tiling like
//!   ours, but a full per-block mapping array (H2D copy + poor locality).

pub mod grouped_gemm;
pub mod naive_loop;
pub mod two_phase;

use crate::moe::config::MoeShape;
use crate::moe::routing::ExpertLoad;
use crate::sim::specs::GpuSpec;
use crate::sim::trace::SimResult;

/// Common interface: simulate one MoE step for a routing outcome.
pub trait MoeImpl {
    fn name(&self) -> &'static str;
    fn simulate(&self, shape: &MoeShape, load: &ExpertLoad, spec: &GpuSpec) -> SimResult;
}

/// Our kernel, boxed behind the same trait for the comparison benches.
pub struct Ours;

impl MoeImpl for Ours {
    fn name(&self) -> &'static str {
        "static-batch (ours)"
    }

    fn simulate(&self, shape: &MoeShape, load: &ExpertLoad, spec: &GpuSpec) -> SimResult {
        let plan = crate::moe::planner::Planner::new(*shape).plan(load);
        crate::sim::kernel_sim::simulate_ours(&plan, spec)
    }
}

/// All implementations, ours first.
pub fn all_impls() -> Vec<Box<dyn MoeImpl>> {
    vec![
        Box::new(Ours),
        Box::new(grouped_gemm::GroupedGemm),
        Box::new(two_phase::TwoPhase),
        Box::new(naive_loop::NaiveLoop),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::routing::LoadScenario;

    #[test]
    fn ours_beats_every_baseline_under_imbalance() {
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Worst.counts(&shape, 0);
        let spec = GpuSpec::h800();
        let ours = Ours.simulate(&shape, &load, &spec);
        for b in all_impls().into_iter().skip(1) {
            let r = b.simulate(&shape, &load, &spec);
            assert!(
                r.time_s >= ours.time_s * 0.999,
                "{} beat ours: {} vs {}",
                b.name(),
                r.time_s,
                ours.time_s
            );
        }
    }

    #[test]
    fn balanced_case_everyone_within_2x_of_ours() {
        // With perfectly balanced load the fused approaches converge; only
        // the naive loop should lag badly.
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Balanced.counts(&shape, 0);
        let spec = GpuSpec::h20();
        let ours = Ours.simulate(&shape, &load, &spec);
        let grouped = grouped_gemm::GroupedGemm.simulate(&shape, &load, &spec);
        assert!(grouped.time_s < ours.time_s * 2.0);
    }
}
