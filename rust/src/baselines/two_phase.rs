//! Two-phase tiling/batching framework baseline (PPoPP'19 [10], Section 2.1).
//!
//! Like ours it precomputes the block→tile mapping on the host and supports
//! per-task tiling strategies; unlike ours the mapping is a *full array with
//! one entry per thread block*, so it pays:
//! * H2D copy proportional to the grid size every step, and
//! * one global-memory mapping read per block with poor locality (the
//!   entry is touched exactly once, so reuse comes only from cache lines).

use crate::exec::{Backend, ExecContext, ExecError, Outcome};
use crate::moe::planner::ExecutionPlan;
use crate::sim::kernel_sim::{operand_bytes, tiles_for_plan};
use crate::sim::overhead::MappingMode;
use crate::sim::specs::GpuSpec;
use crate::sim::trace::SimResult;
use crate::sim::wave;

pub struct TwoPhase;

impl TwoPhase {
    fn simulate_plan(plan: &ExecutionPlan, spec: &GpuSpec) -> SimResult {
        // same plan quality as ours (per-task tiling, ordering, σ-elision):
        // the delta is purely the mapping mechanism
        let blocks = plan.total_tiles() as usize;
        let mode = MappingMode::PerBlockArray { blocks };
        let decode = mode.decode_ns(spec, operand_bytes(plan));
        let tiles = tiles_for_plan(plan, |_| decode);
        let host = mode.host_time_s(spec) + mode.launch_time_s(spec);
        wave::run_waves(&tiles, spec, host)
    }
}

impl Backend for TwoPhase {
    fn name(&self) -> &'static str {
        "two-phase map array [10]"
    }

    fn execute(
        &mut self,
        plan: &ExecutionPlan,
        ctx: &mut ExecContext<'_>,
    ) -> Result<Outcome, ExecError> {
        let sim = Self::simulate_plan(plan, &ctx.spec);
        // two-phase runs the plan's exact grid (only the mapping mechanism
        // differs), so its dispatch sequence IS the plan's
        let trace = ctx.record_dispatch.then(|| crate::exec::mapping_trace(plan));
        Ok(Outcome {
            backend: self.name(),
            blocks: plan.total_tiles(),
            sim: Some(sim),
            output: None,
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{ExecutionSession, SimBackend};
    use crate::moe::config::MoeShape;
    use crate::moe::routing::LoadScenario;

    #[test]
    fn slower_than_ours_by_mapping_overhead_only() {
        let shape = MoeShape::paper_table1();
        for sc in [LoadScenario::Balanced, LoadScenario::Best, LoadScenario::Worst] {
            let load = sc.counts(&shape, 0);
            let ours = ExecutionSession::new(shape)
                .gpu(GpuSpec::h800())
                .backend(SimBackend::ours())
                .run(&load)
                .unwrap();
            let tp = ExecutionSession::new(shape)
                .gpu(GpuSpec::h800())
                .backend(TwoPhase)
                .run(&load)
                .unwrap();
            assert!(tp.time_s() >= ours.time_s(), "{sc:?}");
            // same tiling quality: padding waste identical
            assert!(
                (tp.sim().padding_waste() - ours.sim().padding_waste()).abs() < 1e-9,
                "{sc:?}"
            );
            // same grid: both execute the plan's tile count
            assert_eq!(tp.blocks, ours.blocks, "{sc:?}");
        }
    }

    #[test]
    fn h2d_grows_with_grid() {
        let spec = GpuSpec::h800();
        let small = MappingMode::PerBlockArray { blocks: 2560 }.host_time_s(&spec);
        let big = MappingMode::PerBlockArray { blocks: 1 << 20 }.host_time_s(&spec);
        assert!(big > small * 10.0);
    }
}
