//! Naive per-expert loop (DeepSpeed-MoE inference style, Section 2.2):
//! "a naïve way is to use a for loop to compute GEMMs one by one instead of
//! batching."  Each non-empty expert is its own kernel launch; empty
//! experts are skipped by the host loop (no mapping needed at all).

use crate::baselines::MoeImpl;
use crate::moe::config::MoeShape;
use crate::moe::routing::ExpertLoad;
use crate::moe::tiling::{self, CATALOG};
use crate::sim::cost::gemm_tiles;
use crate::sim::specs::GpuSpec;
use crate::sim::trace::SimResult;
use crate::sim::wave;

pub struct NaiveLoop;

impl MoeImpl for NaiveLoop {
    fn name(&self) -> &'static str {
        "naive per-expert loop"
    }

    fn simulate(&self, shape: &MoeShape, load: &ExpertLoad, spec: &GpuSpec) -> SimResult {
        // Each expert GEMM gets a well-chosen tiling (cuBLAS heuristics do
        // this per call) but runs alone: no wave can mix experts, so small
        // GEMMs underfill the device, and every launch pays latency.
        let mut launches = Vec::new();
        for (e, &rows) in load.counts.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            let s = CATALOG[tiling::select(rows)];
            launches.push(gemm_tiles(
                e as u32,
                rows,
                shape.d_ff,
                shape.d_model,
                s.tm,
                s.tn,
                shape.dtype(),
                0.0, // no mapping decode; the grid is the task
            ));
        }
        wave::run_serial_launches(&launches, spec, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::routing::LoadScenario;

    #[test]
    fn pays_launch_latency_per_expert() {
        let shape = MoeShape::paper_table1();
        let spec = GpuSpec::h800();
        // worst case: 64 launches, 56 of them tiny -> launch overhead is
        // 64 * 4 us = 256 us of pure serial latency
        let load = LoadScenario::Worst.counts(&shape, 0);
        let r = NaiveLoop.simulate(&shape, &load, &spec);
        assert!(r.time_s > 64.0 * spec.launch_us * 1e-6);
    }

    #[test]
    fn small_gemms_underfill_device() {
        let shape = MoeShape::paper_table1();
        let spec = GpuSpec::h800();
        let load = LoadScenario::Worst.counts(&shape, 0);
        let r = NaiveLoop.simulate(&shape, &load, &spec);
        // utilization collapses: single-token GEMMs run alone on the device
        assert!(r.peak_frac < 0.5, "peak {}", r.peak_frac);
    }

    #[test]
    fn skips_empty_experts() {
        let shape = MoeShape::paper_table1();
        let spec = GpuSpec::h20();
        let best = LoadScenario::Best.counts(&shape, 0);
        let r = NaiveLoop.simulate(&shape, &best, &spec);
        // only 8 launches worth of waves
        assert!(r.waves.len() >= 8);
        assert!(r.useful_flops > 0.0);
    }
}
