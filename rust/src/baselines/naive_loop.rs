//! Naive per-expert loop (DeepSpeed-MoE inference style, Section 2.2):
//! "a naïve way is to use a for loop to compute GEMMs one by one instead of
//! batching."  Each non-empty expert is its own kernel launch; empty
//! experts are skipped by the host loop (no mapping needed at all).

use crate::exec::{Backend, ExecContext, ExecError, Outcome};
use crate::moe::config::MoeShape;
use crate::moe::planner::ExecutionPlan;
use crate::moe::routing::ExpertLoad;
use crate::moe::tiling::{self, CATALOG};
use crate::sim::cost::gemm_tiles;
use crate::sim::specs::GpuSpec;
use crate::sim::trace::SimResult;
use crate::sim::wave;

pub struct NaiveLoop;

impl NaiveLoop {
    fn simulate_load(shape: &MoeShape, load: &ExpertLoad, spec: &GpuSpec) -> (SimResult, u32) {
        // Each expert GEMM gets a well-chosen tiling (cuBLAS heuristics do
        // this per call) but runs alone: no wave can mix experts, so small
        // GEMMs underfill the device, and every launch pays latency.
        let mut launches = Vec::new();
        let mut blocks = 0u32;
        for (e, &rows) in load.counts.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            let s = CATALOG[tiling::select(rows)];
            let tiles = gemm_tiles(
                e as u32,
                rows,
                shape.d_ff,
                shape.d_model,
                s.tm,
                s.tn,
                shape.dtype(),
                0.0, // no mapping decode; the grid is the task
            );
            blocks += tiles.len() as u32;
            launches.push(tiles);
        }
        (wave::run_serial_launches(&launches, spec, 0.0), blocks)
    }
}

impl Backend for NaiveLoop {
    fn name(&self) -> &'static str {
        "naive per-expert loop"
    }

    fn execute(
        &mut self,
        plan: &ExecutionPlan,
        ctx: &mut ExecContext<'_>,
    ) -> Result<Outcome, ExecError> {
        let load = plan.expert_load();
        let (sim, blocks) = Self::simulate_load(&plan.shape(), &load, &ctx.spec);
        Ok(Outcome { backend: self.name(), blocks, sim: Some(sim), output: None, trace: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecutionSession;
    use crate::moe::routing::LoadScenario;

    fn run(shape: MoeShape, load: &ExpertLoad, spec: GpuSpec) -> Outcome {
        ExecutionSession::new(shape).gpu(spec).backend(NaiveLoop).run(load).unwrap()
    }

    #[test]
    fn pays_launch_latency_per_expert() {
        let shape = MoeShape::paper_table1();
        let spec = GpuSpec::h800();
        // worst case: 64 launches, 56 of them tiny -> launch overhead is
        // 64 * 4 us = 256 us of pure serial latency
        let load = LoadScenario::Worst.counts(&shape, 0);
        let launch_us = spec.launch_us;
        let r = run(shape, &load, spec);
        assert!(r.time_s() > 64.0 * launch_us * 1e-6);
    }

    #[test]
    fn small_gemms_underfill_device() {
        let shape = MoeShape::paper_table1();
        let load = LoadScenario::Worst.counts(&shape, 0);
        let r = run(shape, &load, GpuSpec::h800());
        // utilization collapses: single-token GEMMs run alone on the device
        assert!(r.sim().peak_frac < 0.5, "peak {}", r.sim().peak_frac);
    }

    #[test]
    fn skips_empty_experts() {
        let shape = MoeShape::paper_table1();
        let best = LoadScenario::Best.counts(&shape, 0);
        let r = run(shape, &best, GpuSpec::h20());
        // only 8 launches worth of waves
        assert!(r.sim().waves.len() >= 8);
        assert!(r.sim().useful_flops > 0.0);
        assert!(r.blocks > 0);
    }
}
