//! [`Server`]: the backend-generic serving loop.
//!
//! Owns the admission queue, batch policy, metrics, and stop flag; drives
//! any [`StepExecutor`] with one `execute_step` call per formed batch —
//! requests are packed before execution and fanned back out after, so the
//! executor amortizes its per-dispatch overhead across the whole batch
//! (the serving-level mirror of the paper's kernel-level batching).
//!
//! The loop runs on the caller's thread ([`Server::serve`]); executors are
//! deliberately not required to be `Send` (the PJRT client is pinned to
//! its thread, and `ExecutionSession` holds an unsendable boxed backend).
//! Producers push into [`Server::queue`] from any thread; closing the
//! queue drains and stops the loop.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, FormedBatch};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::queue::AdmissionQueue;
use crate::coordinator::request::Response;
use crate::serve::{StepExecutor, StepInput};

/// Serving-core configuration (executor-independent knobs).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Batch formation policy.  `buckets` is overwritten with the
    /// executor's buckets at construction.
    pub policy: BatchPolicy,
    /// Admission queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Queue poll interval of the worker loop (shutdown latency bound).
    pub poll: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
            queue_capacity: 256,
            poll: Duration::from_millis(50),
        }
    }
}

/// The backend-generic serving core.  See module docs.
pub struct Server<E: StepExecutor> {
    queue: Arc<AdmissionQueue>,
    metrics: Arc<Metrics>,
    policy: BatchPolicy,
    poll: Duration,
    stop: Arc<AtomicBool>,
    executor: E,
}

impl<E: StepExecutor> Server<E> {
    /// Build a server around `executor`: adopts the executor's buckets and
    /// clamps the policy's token budget to its step capacity.
    pub fn new(cfg: ServerConfig, executor: E) -> Self {
        let mut policy = cfg.policy;
        let buckets = executor.buckets();
        if !buckets.is_empty() {
            policy.buckets = buckets;
        }
        if let Some(cap) = executor.max_step_tokens() {
            policy.max_tokens = policy.max_tokens.min(cap);
        }
        Server {
            queue: Arc::new(AdmissionQueue::new(cfg.queue_capacity)),
            metrics: Arc::new(Metrics::new()),
            policy,
            poll: cfg.poll,
            stop: Arc::new(AtomicBool::new(false)),
            executor,
        }
    }

    /// The admission queue (share with producer threads).
    pub fn queue(&self) -> Arc<AdmissionQueue> {
        Arc::clone(&self.queue)
    }

    /// The metrics sink (share with reporting threads).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Cooperative stop flag: set it (or close the queue) to end
    /// [`Server::serve`].
    pub fn stopper(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// The effective batch policy (buckets and budgets after adoption).
    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// The executor driving this server.
    pub fn executor(&self) -> &E {
        &self.executor
    }

    /// Mutable access to the executor (reconfiguration between runs).
    pub fn executor_mut(&mut self) -> &mut E {
        &mut self.executor
    }

    /// Serve until the queue is closed and drained, or the stop flag is
    /// set.  Runs on the calling thread; producers push into the queue
    /// from anywhere.
    pub fn serve(&mut self) {
        log::info!(
            "{} serving: buckets {:?}",
            self.executor.name(),
            self.policy.buckets
        );
        while !self.stop.load(Ordering::Relaxed) {
            let Some(first) = self.queue.pop(self.poll) else {
                if self.queue.is_closed() && self.queue.is_empty() {
                    break;
                }
                continue;
            };
            // form a batch: the popped request plus whatever is waiting
            let mut pending = vec![first];
            pending
                .extend(self.queue.drain_up_to(self.policy.max_requests.saturating_sub(1)));
            let (batches, rejected) = self.policy.form(pending);
            for r in rejected {
                self.metrics.record_error();
                self.metrics.record_tenant_error(r.tenant);
                let msg = format!("request of {} tokens exceeds largest bucket", r.tokens.len());
                let mut resp = Response::failed(r.id, msg);
                resp.tenant = r.tenant;
                let _ = r.respond.send(resp);
            }
            for batch in batches {
                self.step(batch);
            }
            self.sync_executor_metrics();
        }
        log::info!("{} stopped", self.executor.name());
    }

    /// Execute one formed batch: pack, dispatch once, fan responses out.
    fn step(&mut self, batch: FormedBatch) {
        let bucket = batch.bucket;
        let rows = batch.requests.len();
        let mut tokens = Vec::with_capacity(rows * bucket);
        for r in &batch.requests {
            tokens.extend(self.policy.pad(&r.tokens, bucket));
        }
        let t0 = Instant::now();
        let result = self
            .executor
            .execute_step(&StepInput { bucket, rows, tokens: &tokens })
            .and_then(|out| {
                if out.argmax.len() == rows * bucket {
                    Ok(out)
                } else {
                    Err(crate::exec::ExecError::Backend {
                        backend: self.executor.name(),
                        detail: format!(
                            "step returned {} argmax entries for a {rows}x{bucket} batch",
                            out.argmax.len()
                        ),
                    })
                }
            });
        match result {
            Ok(out) => {
                // per-batch exec metric: one executor dispatch per batch
                self.metrics.record_exec(t0.elapsed().as_secs_f64(), rows);
                if !out.expert_rows.is_empty() {
                    self.metrics.record_expert_rows(&out.expert_rows);
                }
                for (i, r) in batch.requests.into_iter().enumerate() {
                    // per-request error isolation: a row the executor
                    // reported failed gets its own error response, the
                    // rest of the batch still succeeds
                    if let Some((_, msg)) = out.failed.iter().find(|(row, _)| *row == i) {
                        self.metrics.record_error();
                        self.metrics.record_tenant_error(r.tenant);
                        let mut resp = Response::failed(r.id, msg.clone());
                        resp.tenant = r.tenant;
                        let _ = r.respond.send(resp);
                        continue;
                    }
                    let latency = r.enqueued.elapsed().as_secs_f64();
                    self.metrics.record_request(latency, r.tokens.len());
                    self.metrics.record_tenant_request(r.tenant, latency, None);
                    let row = &out.argmax[i * bucket..(i + 1) * bucket];
                    let _ = r.respond.send(Response {
                        id: r.id,
                        tenant: r.tenant,
                        argmax: row[..r.tokens.len()].to_vec(),
                        latency_s: latency,
                        bucket,
                        error: None,
                    });
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in batch.requests {
                    self.metrics.record_error();
                    self.metrics.record_tenant_error(r.tenant);
                    let mut resp = Response::failed(r.id, msg.clone());
                    resp.tenant = r.tenant;
                    let _ = r.respond.send(resp);
                }
            }
        }
    }

    /// Mirror the executor's cumulative counters (plan cache, sharding)
    /// into the metrics sink after each loop iteration.
    fn sync_executor_metrics(&self) {
        if let Some(s) = self.executor.cache_stats() {
            self.metrics.set_plan_cache(s.hits, s.misses);
        }
        if let Some(sh) = self.executor.sharding() {
            self.metrics.set_sharding(sh);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::exec::ExecError;
    use crate::serve::{StepExecutor, StepOutput};
    use std::sync::mpsc::{channel, Receiver};

    /// Echo executor: argmax[i] = token[i] + 1; fails whole steps or
    /// single rows when asked to.
    struct Echo {
        steps: Vec<(usize, usize)>,
        fail: bool,
        fail_row: Option<usize>,
    }

    impl StepExecutor for Echo {
        fn name(&self) -> &'static str {
            "echo"
        }

        fn buckets(&self) -> Vec<usize> {
            vec![4, 8]
        }

        fn max_step_tokens(&self) -> Option<usize> {
            Some(24)
        }

        fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
            if self.fail {
                return Err(ExecError::Backend { backend: "echo", detail: "boom".into() });
            }
            self.steps.push((step.bucket, step.rows));
            let failed = match self.fail_row {
                Some(row) if row < step.rows => vec![(row, "row boom".to_string())],
                _ => Vec::new(),
            };
            Ok(StepOutput {
                argmax: step.tokens.iter().map(|&t| t + 1).collect(),
                expert_rows: Vec::new(),
                failed,
                sim_time_s: None,
            })
        }
    }

    fn req(id: u64, tokens: Vec<i32>) -> (Request, Receiver<Response>) {
        let (tx, rx) = channel();
        (Request { id, tenant: 0, tokens, enqueued: Instant::now(), respond: tx }, rx)
    }

    fn server(fail: bool) -> Server<Echo> {
        let cfg = ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 4, max_tokens: 64 },
            queue_capacity: 32,
            poll: Duration::from_millis(1),
        };
        Server::new(cfg, Echo { steps: Vec::new(), fail, fail_row: None })
    }

    #[test]
    fn adopts_executor_buckets_and_clamps_token_budget() {
        let s = server(false);
        assert_eq!(s.policy().buckets, vec![4, 8]);
        // policy asked for 64 tokens/batch but the executor caps a step at
        // 24 — clamped at construction, not failed at serve time
        assert_eq!(s.policy().max_tokens, 24);
    }

    #[test]
    fn batches_execute_once_and_fan_out() {
        let mut s = server(false);
        let q = s.queue();
        let mut rxs = Vec::new();
        for id in 0..3u64 {
            let (r, rx) = req(id, vec![10 + id as i32, 20]);
            q.try_push(r);
            rxs.push(rx);
        }
        q.close();
        s.serve();
        // one packed step for the whole batch, not one per request
        assert_eq!(s.executor().steps, vec![(4, 3)]);
        for (id, rx) in rxs.iter().enumerate() {
            let resp = rx.try_recv().expect("response delivered");
            assert_eq!(resp.id, id as u64);
            assert!(resp.error.is_none());
            assert_eq!(resp.argmax, vec![10 + id as i32 + 1, 21]);
            assert_eq!(resp.bucket, 4);
        }
        let snap = s.metrics().snapshot();
        assert_eq!(snap.requests, 3);
        assert_eq!(snap.tokens, 6);
        assert!((snap.mean_batch - 3.0).abs() < 1e-9);
    }

    #[test]
    fn per_row_failure_only_fails_that_request() {
        let cfg = ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 4, max_tokens: 64 },
            queue_capacity: 32,
            poll: Duration::from_millis(1),
        };
        let mut s = Server::new(cfg, Echo { steps: Vec::new(), fail: false, fail_row: Some(1) });
        let q = s.queue();
        let mut rxs = Vec::new();
        for id in 0..3u64 {
            let (r, rx) = req(id, vec![5, 6]);
            q.try_push(r);
            rxs.push(rx);
        }
        q.close();
        s.serve();
        for (i, rx) in rxs.iter().enumerate() {
            let resp = rx.try_recv().expect("response delivered");
            if i == 1 {
                assert!(resp.error.as_deref().unwrap_or("").contains("row boom"));
            } else {
                assert!(resp.error.is_none());
                assert_eq!(resp.argmax, vec![6, 7]);
            }
        }
        let snap = s.metrics().snapshot();
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.errors, 1);
    }

    #[test]
    fn executor_failure_fails_every_request_in_the_batch() {
        let mut s = server(true);
        let q = s.queue();
        let (r0, rx0) = req(0, vec![1]);
        let (r1, rx1) = req(1, vec![2]);
        q.try_push(r0);
        q.try_push(r1);
        q.close();
        s.serve();
        for rx in [rx0, rx1] {
            let resp = rx.try_recv().expect("failure response delivered");
            assert!(resp.error.as_deref().unwrap_or("").contains("boom"));
        }
        assert_eq!(s.metrics().snapshot().errors, 2);
    }

    #[test]
    fn oversized_requests_rejected_without_execution() {
        let mut s = server(false);
        let q = s.queue();
        let (r, rx) = req(7, vec![0; 100]);
        q.try_push(r);
        q.close();
        s.serve();
        let resp = rx.try_recv().expect("rejection delivered");
        assert!(resp.error.as_deref().unwrap_or("").contains("exceeds largest bucket"));
        assert!(s.executor().steps.is_empty());
        assert_eq!(s.metrics().snapshot().errors, 1);
    }

    #[test]
    fn stop_flag_ends_the_loop() {
        let mut s = server(false);
        s.stopper().store(true, Ordering::Relaxed);
        s.serve(); // returns immediately despite the open queue
    }
}
