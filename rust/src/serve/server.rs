//! [`Server`]: the backend-generic pipelined serving front end.
//!
//! Owns the admission queue, batch policy, metrics, and stop flag; drives
//! any [`StepExecutor`] with one `execute_step` call per formed batch —
//! requests are packed before execution and fanned back out after, so the
//! executor amortizes its per-dispatch overhead across the whole batch
//! (the serving-level mirror of the paper's kernel-level batching).
//!
//! Producers submit through a cloneable [`ServeHandle`]: non-blocking
//! [`ServeHandle::try_submit`] surfaces backpressure as an explicit
//! [`SubmitError::Backpressure`], blocking [`ServeHandle::submit`] waits
//! for queue headroom.  Each submission returns a [`Ticket`] the caller
//! waits on for its own [`Response`].
//!
//! [`Server::serve`] runs three channel-staged stages so batch *formation*
//! for step N+1 overlaps batch *execution* of step N:
//!
//! ```text
//!   batcher thread          executor (caller's thread)   responder thread
//!   ┌──────────────┐  sync  ┌──────────────────┐  sync  ┌──────────────┐
//!   │ wakeup-driven│ channel│ execute_step per  │ channel│ fan results  │
//!   │ accumulation │ ─────▶ │ PackedBatch       │ ─────▶ │ back per     │
//!   │ + form + pack│ (depth)│ (not Send: PJRT   │ (depth)│ caller ticket│
//!   └──────────────┘        │ pinned here)      │        └──────────────┘
//!                           └──────────────────┘
//! ```
//!
//! Accumulation is wakeup-driven under a batch deadline: the batcher
//! blocks for a first request, then takes riders until the batch is full
//! (`BatchPolicy::max_requests`) or [`ServerConfig::deadline`] passes —
//! whichever first.  There is no poll interval; closing the queue (or a
//! [`Stopper`]) wakes every stage and the pipeline drains cleanly.
//!
//! Executors are deliberately not required to be `Send` (the PJRT client
//! is pinned to its thread, and `ExecutionSession` holds an unsendable
//! boxed backend), so the executor stage runs on the thread that calls
//! [`Server::serve`]; the batcher and responder are scoped threads joined
//! before `serve` returns.  [`ServerConfig::pipeline`]` = false` selects
//! the single-threaded reference loop instead — same accumulation, same
//! numerics, no overlap — which the determinism suite diffs against.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::queue::{AdmissionQueue, PushResult};
use crate::coordinator::request::{Request, Response};
use crate::serve::{StepExecutor, StepInput, StepOutput};

/// Bounded retry policy for transient step failures (see
/// [`crate::exec::ExecError::is_transient`]): a failed step is retried up
/// to `max_attempts` total attempts with deterministic linear backoff
/// (`backoff * attempt_number` between attempts).  Permanent failures are
/// never retried, and requests whose deadline passes between attempts are
/// expired out of the batch before it is re-run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total execution attempts per step (1 = no retry).
    pub max_attempts: u32,
    /// Base backoff between attempts; attempt `n` sleeps `backoff * n`.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 1, backoff: Duration::ZERO }
    }
}

/// Serving-core configuration (executor-independent knobs).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Batch formation policy.  `buckets` is overwritten with the
    /// executor's buckets at construction.
    pub policy: BatchPolicy,
    /// Admission queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Batch deadline: once a first request is in hand, the batcher waits
    /// at most this long for riders before sealing the step (max-batch OR
    /// deadline, whichever first).
    pub deadline: Duration,
    /// Pipeline depth: formed batches buffered between the batcher and
    /// executor stages (and executed steps between executor and
    /// responder).  Bounds memory and keeps backpressure honest.
    pub depth: usize,
    /// `true` (default) runs the three-stage pipeline; `false` runs the
    /// synchronous single-threaded reference loop (same accumulation and
    /// numerics, no formation/execution overlap).
    pub pipeline: bool,
    /// Default per-request deadline applied by [`ServeHandle`] submissions
    /// (`None` = requests wait indefinitely).  Expired requests are shed
    /// before execution and answered with [`Response::expired`] set.
    pub request_deadline: Option<Duration>,
    /// Retry policy for transient step failures.
    pub retry: RetryPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
            queue_capacity: 256,
            deadline: Duration::from_millis(2),
            depth: 2,
            pipeline: true,
            request_deadline: None,
            retry: RetryPolicy::default(),
        }
    }
}

/// Why a submission was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded admission queue is full — shed or retry (open-loop
    /// overload made visible instead of buffered without bound).
    Backpressure,
    /// The queue is closed: the server is draining or stopped.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure => write!(f, "admission queue full (backpressure)"),
            SubmitError::Closed => write!(f, "admission queue closed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// One submitted request's claim on its response.
pub struct Ticket {
    id: u64,
    rx: Receiver<Response>,
}

impl Ticket {
    /// The request id the server will answer with.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the response arrives.  If the server dropped the
    /// request without answering (abortive stop, panic), a synthesized
    /// failure response is returned — a ticket never hangs once the
    /// serving loop has exited, and never silently vanishes.
    pub fn wait(self) -> Response {
        self.rx
            .recv()
            .unwrap_or_else(|_| Response::failed(self.id, "request dropped by the server".into()))
    }

    /// Non-blocking probe: `None` while the request is still in flight.
    pub fn try_wait(&self) -> Option<Response> {
        match self.rx.try_recv() {
            Ok(resp) => Some(resp),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Some(Response::failed(self.id, "request dropped by the server".into()))
            }
        }
    }

    /// Bounded wait: `None` if no response arrives within `timeout`.  A
    /// timed-out wait consumes nothing — the ticket stays completable and
    /// a later [`Ticket::wait`]/[`Ticket::wait_timeout`] still receives
    /// the response (no double-take).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Response> {
        match self.rx.recv_timeout(timeout) {
            Ok(resp) => Some(resp),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                Some(Response::failed(self.id, "request dropped by the server".into()))
            }
        }
    }
}

/// Cloneable submission handle: the request-side face of a [`Server`].
/// Clones share the queue, metrics, and id sequence, so any number of
/// producer threads can submit concurrently.
#[derive(Clone)]
pub struct ServeHandle {
    queue: Arc<AdmissionQueue>,
    metrics: Arc<Metrics>,
    seq: Arc<AtomicU64>,
    /// Default per-request deadline ([`ServerConfig::request_deadline`]);
    /// [`ServeHandle::submit_with_deadline`] overrides it per request.
    default_deadline: Option<Duration>,
}

impl ServeHandle {
    /// Non-blocking submission for the untenanted default class.
    pub fn try_submit(&self, tokens: &[i32]) -> Result<Ticket, SubmitError> {
        self.try_submit_for(0, tokens)
    }

    /// Non-blocking submission: returns [`SubmitError::Backpressure`]
    /// exactly when the bounded queue is full.  Refusals are counted in
    /// [`Metrics`] (`rejected`), so driver-side shed accounting reconciles
    /// with the server's own counters.
    pub fn try_submit_for(&self, tenant: u32, tokens: &[i32]) -> Result<Ticket, SubmitError> {
        let (req, ticket) = self.request(tenant, tokens, self.default_deadline);
        match self.queue.try_push(req) {
            PushResult::Ok => Ok(ticket),
            PushResult::Full => {
                self.metrics.record_rejected();
                Err(SubmitError::Backpressure)
            }
            PushResult::Closed => {
                self.metrics.record_rejected();
                Err(SubmitError::Closed)
            }
        }
    }

    /// Blocking submission for the untenanted default class.
    pub fn submit(&self, tokens: &[i32]) -> Result<Ticket, SubmitError> {
        self.submit_for(0, tokens)
    }

    /// Blocking submission: waits for queue headroom (a completing step
    /// frees it) instead of shedding; fails only once the queue closes.
    pub fn submit_for(&self, tenant: u32, tokens: &[i32]) -> Result<Ticket, SubmitError> {
        let (req, ticket) = self.request(tenant, tokens, self.default_deadline);
        self.push_blocking(req).map(|()| ticket)
    }

    /// Blocking submission with an explicit per-request deadline
    /// (overriding [`ServerConfig::request_deadline`]): if `deadline`
    /// passes before the request executes, it is shed pre-execution and
    /// answered with [`Response::expired`] set.
    pub fn submit_with_deadline(
        &self,
        tokens: &[i32],
        deadline: Duration,
    ) -> Result<Ticket, SubmitError> {
        let (req, ticket) = self.request(0, tokens, Some(deadline));
        self.push_blocking(req).map(|()| ticket)
    }

    fn push_blocking(&self, req: Request) -> Result<(), SubmitError> {
        match self.queue.push(req) {
            PushResult::Ok => Ok(()),
            PushResult::Full => Err(SubmitError::Backpressure), // unreachable: push blocks
            PushResult::Closed => {
                self.metrics.record_rejected();
                Err(SubmitError::Closed)
            }
        }
    }

    /// Close the stream: in-flight work drains, further submissions fail
    /// with [`SubmitError::Closed`], and [`Server::serve`] returns once
    /// the queue is empty.
    pub fn close(&self) {
        self.queue.close();
        self.queue.wake_all();
    }

    /// Requests currently waiting in the admission queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    fn request(
        &self,
        tenant: u32,
        tokens: &[i32],
        deadline: Option<Duration>,
    ) -> (Request, Ticket) {
        let id = self.seq.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let now = Instant::now();
        let req = Request {
            id,
            tenant,
            tokens: tokens.to_vec(),
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            respond: tx,
        };
        (req, Ticket { id, rx })
    }
}

/// Cooperative shutdown: sets the stop flag, closes the queue (so blocked
/// producers fail fast instead of waiting on a queue nobody will drain),
/// and wakes every parked stage.  Cloneable; share with signal handlers.
///
/// `stop()` is abortive — requests still queued when the loop exits are
/// failed, not executed.  For a graceful drain, use [`ServeHandle::close`]
/// instead.
#[derive(Clone)]
pub struct Stopper {
    flag: Arc<AtomicBool>,
    queue: Arc<AdmissionQueue>,
}

impl Stopper {
    /// Request shutdown.  Idempotent.
    pub fn stop(&self) {
        self.flag.store(true, Ordering::Relaxed);
        self.queue.close();
        self.queue.wake_all();
    }

    /// True once [`Stopper::stop`] has been called.
    pub fn is_stopped(&self) -> bool {
        self.flag.load(Ordering::Relaxed)
    }
}

/// A sealed batch in flight between the batcher and executor stages:
/// `requests` padded row-major into `tokens` (`requests.len() * bucket`
/// ids), packed on the batcher thread so the executor only executes.
struct PackedBatch {
    bucket: usize,
    requests: Vec<Request>,
    tokens: Vec<i32>,
}

/// One executed step in flight between the executor and responder stages.
struct StepResult {
    bucket: usize,
    requests: Vec<Request>,
    outcome: Result<StepOutput, String>,
}

/// The backend-generic serving core.  See module docs.
pub struct Server<E: StepExecutor> {
    queue: Arc<AdmissionQueue>,
    metrics: Arc<Metrics>,
    policy: BatchPolicy,
    deadline: Duration,
    depth: usize,
    pipeline: bool,
    request_deadline: Option<Duration>,
    retry: RetryPolicy,
    stop: Arc<AtomicBool>,
    seq: Arc<AtomicU64>,
    executor: E,
}

impl<E: StepExecutor> Server<E> {
    /// Build a server around `executor`: adopts the executor's buckets and
    /// clamps the policy's token budget to its step capacity.
    pub fn new(cfg: ServerConfig, executor: E) -> Self {
        let mut policy = cfg.policy;
        let buckets = executor.buckets();
        if !buckets.is_empty() {
            policy.buckets = buckets;
        }
        if let Some(cap) = executor.max_step_tokens() {
            policy.max_tokens = policy.max_tokens.min(cap);
        }
        Server {
            queue: Arc::new(AdmissionQueue::new(cfg.queue_capacity)),
            metrics: Arc::new(Metrics::new()),
            policy,
            deadline: cfg.deadline,
            depth: cfg.depth.max(1),
            pipeline: cfg.pipeline,
            request_deadline: cfg.request_deadline,
            retry: cfg.retry,
            stop: Arc::new(AtomicBool::new(false)),
            seq: Arc::new(AtomicU64::new(0)),
            executor,
        }
    }

    /// A cloneable submission handle (share with producer threads).
    pub fn handle(&self) -> ServeHandle {
        ServeHandle {
            queue: Arc::clone(&self.queue),
            metrics: Arc::clone(&self.metrics),
            seq: Arc::clone(&self.seq),
            default_deadline: self.request_deadline,
        }
    }

    /// The admission queue (the layer below [`ServeHandle`]; the TCP
    /// front end and tests that manage their own ids push here directly).
    pub fn queue(&self) -> Arc<AdmissionQueue> {
        Arc::clone(&self.queue)
    }

    /// The metrics sink (share with reporting threads).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Cooperative abortive shutdown; see [`Stopper`].
    pub fn stopper(&self) -> Stopper {
        Stopper { flag: Arc::clone(&self.stop), queue: Arc::clone(&self.queue) }
    }

    /// The effective batch policy (buckets and budgets after adoption).
    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// The executor driving this server.
    pub fn executor(&self) -> &E {
        &self.executor
    }

    /// Mutable access to the executor (reconfiguration between runs).
    pub fn executor_mut(&mut self) -> &mut E {
        &mut self.executor
    }

    /// Serve until the queue is closed and drained, or a [`Stopper`]
    /// fires.  Runs the executor stage on the calling thread; the batcher
    /// and responder stages are scoped threads joined before returning.
    /// Every request admitted before shutdown is answered (executed on a
    /// graceful close, failed on an abortive stop) by the time this
    /// returns.
    pub fn serve(&mut self) {
        log::info!(
            "{} serving ({}): buckets {:?}",
            self.executor.name(),
            if self.pipeline { "pipelined" } else { "sync" },
            self.policy.buckets
        );
        if self.pipeline {
            self.serve_pipelined();
        } else {
            self.serve_sync();
        }
        // abortive stop can strand admitted requests: fail them so every
        // ticket resolves once serve has returned
        for r in self.queue.drain_up_to(usize::MAX) {
            reject(r, "server stopped before execution".into(), &self.metrics);
        }
        log::info!("{} stopped", self.executor.name());
    }

    /// The three-stage pipeline: batcher thread → executor (this thread)
    /// → responder thread, bounded `depth` deep on both channels.
    fn serve_pipelined(&mut self) {
        let (batch_tx, batch_rx) = sync_channel::<PackedBatch>(self.depth);
        let (done_tx, done_rx) = sync_channel::<StepResult>(self.depth);
        let queue = Arc::clone(&self.queue);
        let b_metrics = Arc::clone(&self.metrics);
        let r_metrics = Arc::clone(&self.metrics);
        let policy = self.policy.clone();
        let stop = Arc::clone(&self.stop);
        let deadline = self.deadline;
        std::thread::scope(|s| {
            // batcher: forms and packs step N+1 while step N executes
            s.spawn(move || {
                while let Some(pending) = accumulate(&queue, &policy, deadline, &stop) {
                    for packed in form_and_pack(pending, &policy, &b_metrics) {
                        b_metrics.pipeline_enter();
                        if batch_tx.send(packed).is_err() {
                            return; // executor stage gone
                        }
                    }
                }
                // batch_tx drops here: end-of-stream for the executor
            });
            // responder: fans results back to each caller's ticket
            s.spawn(move || {
                for done in done_rx {
                    respond(done, &r_metrics);
                }
            });
            // executor stage on the calling thread (StepExecutor is not
            // required to be Send — the PJRT client stays pinned here)
            for mut batch in batch_rx {
                let outcome = self.run_step(&mut batch);
                self.sync_executor_metrics();
                let PackedBatch { bucket, requests, .. } = batch;
                if done_tx.send(StepResult { bucket, requests, outcome }).is_err() {
                    // responder died: stop the batcher too, or the scope
                    // join below would wait on its blocked accumulate
                    self.stopper().stop();
                    break;
                }
            }
            drop(done_tx);
        });
    }

    /// The synchronous reference loop: identical accumulation, execution,
    /// and fan-out on one thread, with no overlap.  The determinism suite
    /// asserts the pipeline produces bitwise-identical responses to this.
    fn serve_sync(&mut self) {
        while let Some(pending) =
            accumulate(&self.queue, &self.policy, self.deadline, &self.stop)
        {
            for mut batch in form_and_pack(pending, &self.policy, &self.metrics) {
                self.metrics.pipeline_enter();
                let outcome = self.run_step(&mut batch);
                let PackedBatch { bucket, requests, .. } = batch;
                respond(StepResult { bucket, requests, outcome }, &self.metrics);
            }
            self.sync_executor_metrics();
        }
    }

    /// Execute one packed batch: dispatch, validate the output shape,
    /// record the per-batch exec metric.  Transient failures are retried
    /// per [`RetryPolicy`]: every failure is reported to the executor
    /// ([`StepExecutor::observe_error`], feeding circuit breakers), then
    /// the batch's still-live requests are re-formed (expired ones are
    /// answered and dropped — never re-planned) and the step re-runs after
    /// a deterministic linear backoff.  Permanent failures and exhausted
    /// retries fail the whole batch.
    fn run_step(&mut self, batch: &mut PackedBatch) -> Result<StepOutput, String> {
        let mut attempt: u32 = 0;
        loop {
            let rows = batch.requests.len();
            if rows == 0 {
                // every request expired while retrying: nothing to run
                return Ok(StepOutput {
                    argmax: Vec::new(),
                    expert_rows: Vec::new(),
                    failed: Vec::new(),
                    sim_time_s: None,
                });
            }
            let t0 = Instant::now();
            let result = self
                .executor
                .execute_step(&StepInput { bucket: batch.bucket, rows, tokens: &batch.tokens })
                .and_then(|out| {
                    if out.argmax.len() == rows * batch.bucket {
                        Ok(out)
                    } else {
                        Err(crate::exec::ExecError::backend(
                            self.executor.name(),
                            format!(
                                "step returned {} argmax entries for a {rows}x{} batch",
                                out.argmax.len(),
                                batch.bucket
                            ),
                        ))
                    }
                });
            match result {
                Ok(out) => {
                    // per-batch exec metric: one executor dispatch per batch
                    self.metrics.record_exec(t0.elapsed().as_secs_f64(), rows);
                    return Ok(out);
                }
                Err(e) => {
                    // every failure feeds the executor's breakers, retried
                    // or not — classification happens on the typed error,
                    // before it is flattened to a response string
                    self.executor.observe_error(&e);
                    attempt += 1;
                    if e.is_transient() && attempt < self.retry.max_attempts {
                        self.metrics.record_retry();
                        let backoff = self.retry.backoff * attempt;
                        if !backoff.is_zero() {
                            std::thread::sleep(backoff);
                        }
                        drop_expired(batch, &self.metrics);
                        continue;
                    }
                    return Err(e.to_string());
                }
            }
        }
    }

    /// Mirror the executor's cumulative counters (plan cache, sharding)
    /// into the metrics sink after each step.
    fn sync_executor_metrics(&self) {
        if let Some(s) = self.executor.cache_stats() {
            self.metrics.set_plan_cache(s.hits, s.misses);
        }
        if let Some(sh) = self.executor.sharding() {
            self.metrics.set_sharding(sh);
        }
    }
}

/// Accumulate one raw batch, wakeup-driven: block for a first request,
/// then take riders until the batch is full or the deadline passes —
/// whichever first.  `None` ends the stage (closed-and-drained or
/// stopped).
fn accumulate(
    queue: &AdmissionQueue,
    policy: &BatchPolicy,
    deadline: Duration,
    stop: &AtomicBool,
) -> Option<Vec<Request>> {
    let first = queue.pop_wait(stop)?;
    let seal = Instant::now() + deadline;
    let mut pending = vec![first];
    while pending.len() < policy.max_requests {
        let drained = queue.drain_up_to(policy.max_requests - pending.len());
        if !drained.is_empty() {
            pending.extend(drained);
            continue; // more may already be waiting
        }
        match queue.pop_until(seal, stop) {
            Some(r) => pending.push(r),
            None => break, // deadline, closed-and-drained, or stop
        }
    }
    Some(pending)
}

/// Form policy batches from accumulated requests, reject what fits no
/// bucket, pack the rest row-major, and record queue/form waits.  Requests
/// past their deadline are expired here — before formation, so they are
/// never planned or executed.
fn form_and_pack(
    pending: Vec<Request>,
    policy: &BatchPolicy,
    metrics: &Metrics,
) -> Vec<PackedBatch> {
    let formed_at = Instant::now();
    let (live, dead): (Vec<Request>, Vec<Request>) =
        pending.into_iter().partition(|r| !r.is_expired(formed_at));
    for r in dead {
        expire(r, metrics);
    }
    let (batches, rejected) = policy.form(live);
    for r in rejected {
        let msg = format!("request of {} tokens exceeds largest bucket", r.tokens.len());
        reject(r, msg, metrics);
    }
    batches
        .into_iter()
        .map(|b| {
            let bucket = b.bucket;
            let mut tokens = Vec::with_capacity(b.requests.len() * bucket);
            let mut oldest = formed_at;
            for r in &b.requests {
                tokens.extend(policy.pad(&r.tokens, bucket));
                metrics
                    .record_queue_wait(formed_at.duration_since(r.enqueued).as_secs_f64());
                oldest = oldest.min(r.enqueued);
            }
            // form wait: how long the batch's oldest member waited on
            // accumulation itself (seal time minus its arrival), the
            // latency cost of riding for a fuller batch
            metrics.record_form_wait(formed_at.duration_since(oldest).as_secs_f64());
            PackedBatch { bucket, requests: b.requests, tokens }
        })
        .collect()
}

/// Fail one request with `msg` (rejection, row failure, or abort).
fn reject(r: Request, msg: String, metrics: &Metrics) {
    metrics.record_error();
    metrics.record_tenant_error(r.tenant);
    let mut resp = Response::failed(r.id, msg);
    resp.tenant = r.tenant;
    let _ = r.respond.send(resp);
}

/// Shed one request whose deadline passed before execution.  Counted as
/// `expired` (not `errors`), answered with [`Response::expired`] set.
fn expire(r: Request, metrics: &Metrics) {
    metrics.record_expired();
    metrics.record_tenant_expired(r.tenant);
    let mut resp = Response::failed(r.id, "deadline expired before execution");
    resp.tenant = r.tenant;
    resp.expired = true;
    let _ = r.respond.send(resp);
}

/// Between retry attempts: expire any request whose deadline passed and
/// re-pack the survivors' rows (same order, same bucket), so the retried
/// step never executes dead work.
fn drop_expired(batch: &mut PackedBatch, metrics: &Metrics) {
    let now = Instant::now();
    if !batch.requests.iter().any(|r| r.is_expired(now)) {
        return;
    }
    let bucket = batch.bucket;
    let old_tokens = std::mem::take(&mut batch.tokens);
    let old_requests = std::mem::take(&mut batch.requests);
    batch.tokens.reserve(old_tokens.len());
    for (i, r) in old_requests.into_iter().enumerate() {
        if r.is_expired(now) {
            expire(r, metrics);
        } else {
            batch.tokens.extend_from_slice(&old_tokens[i * bucket..(i + 1) * bucket]);
            batch.requests.push(r);
        }
    }
}

/// Fan one executed step's results back per caller and close out its
/// pipeline slot.  A whole-step failure fails every request in the batch;
/// a per-row failure ([`StepOutput::failed`]) fails only that request.
fn respond(done: StepResult, metrics: &Metrics) {
    let StepResult { bucket, requests, outcome } = done;
    match outcome {
        Ok(out) => {
            if !out.expert_rows.is_empty() {
                metrics.record_expert_rows(&out.expert_rows);
            }
            for (i, r) in requests.into_iter().enumerate() {
                if let Some((_, msg)) = out.failed.iter().find(|(row, _)| *row == i) {
                    reject(r, msg.clone(), metrics);
                    continue;
                }
                let latency = r.enqueued.elapsed().as_secs_f64();
                metrics.record_request(latency, r.tokens.len());
                metrics.record_tenant_request(r.tenant, latency, None);
                let row = &out.argmax[i * bucket..(i + 1) * bucket];
                let _ = r.respond.send(Response {
                    id: r.id,
                    tenant: r.tenant,
                    argmax: row[..r.tokens.len()].to_vec(),
                    latency_s: latency,
                    bucket,
                    error: None,
                    expired: false,
                });
            }
        }
        Err(msg) => {
            for r in requests {
                reject(r, msg.clone(), metrics);
            }
        }
    }
    metrics.pipeline_exit();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecError;
    use std::sync::mpsc::Receiver;

    /// Echo executor: argmax[i] = token[i] + 1; fails whole steps or
    /// single rows when asked to.
    struct Echo {
        steps: Vec<(usize, usize)>,
        fail: bool,
        fail_row: Option<usize>,
    }

    impl StepExecutor for Echo {
        fn name(&self) -> &'static str {
            "echo"
        }

        fn buckets(&self) -> Vec<usize> {
            vec![4, 8]
        }

        fn max_step_tokens(&self) -> Option<usize> {
            Some(24)
        }

        fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
            if self.fail {
                return Err(ExecError::backend("echo", "boom"));
            }
            self.steps.push((step.bucket, step.rows));
            let failed = match self.fail_row {
                Some(row) if row < step.rows => vec![(row, "row boom".to_string())],
                _ => Vec::new(),
            };
            Ok(StepOutput {
                argmax: step.tokens.iter().map(|&t| t + 1).collect(),
                expert_rows: Vec::new(),
                failed,
                sim_time_s: None,
            })
        }
    }

    fn req(id: u64, tokens: Vec<i32>) -> (Request, Receiver<Response>) {
        let (tx, rx) = channel();
        let r = Request {
            id,
            tenant: 0,
            tokens,
            enqueued: Instant::now(),
            deadline: None,
            respond: tx,
        };
        (r, rx)
    }

    fn config(queue_capacity: usize) -> ServerConfig {
        ServerConfig {
            policy: BatchPolicy { buckets: Vec::new(), max_requests: 4, max_tokens: 64 },
            queue_capacity,
            ..ServerConfig::default()
        }
    }

    fn server(fail: bool) -> Server<Echo> {
        Server::new(config(32), Echo { steps: Vec::new(), fail, fail_row: None })
    }

    #[test]
    fn adopts_executor_buckets_and_clamps_token_budget() {
        let s = server(false);
        assert_eq!(s.policy().buckets, vec![4, 8]);
        // policy asked for 64 tokens/batch but the executor caps a step at
        // 24 — clamped at construction, not failed at serve time
        assert_eq!(s.policy().max_tokens, 24);
    }

    #[test]
    fn batches_execute_once_and_fan_out() {
        let mut s = server(false);
        let q = s.queue();
        let mut rxs = Vec::new();
        for id in 0..3u64 {
            let (r, rx) = req(id, vec![10 + id as i32, 20]);
            q.try_push(r);
            rxs.push(rx);
        }
        q.close();
        s.serve();
        // one packed step for the whole batch, not one per request
        assert_eq!(s.executor().steps, vec![(4, 3)]);
        for (id, rx) in rxs.iter().enumerate() {
            let resp = rx.try_recv().expect("response delivered");
            assert_eq!(resp.id, id as u64);
            assert!(resp.error.is_none());
            assert_eq!(resp.argmax, vec![10 + id as i32 + 1, 21]);
            assert_eq!(resp.bucket, 4);
        }
        let snap = s.metrics().snapshot();
        assert_eq!(snap.requests, 3);
        assert_eq!(snap.tokens, 6);
        assert!((snap.mean_batch - 3.0).abs() < 1e-9);
        // the step passed through the pipeline gauge and drained back out
        assert_eq!(snap.in_flight, 0);
        assert!(snap.max_in_flight >= 1);
    }

    #[test]
    fn handle_submits_roundtrip_with_sequential_ids() {
        let mut s = server(false);
        let h = s.handle();
        let h2 = h.clone(); // clones share the id sequence
        let t0 = h.try_submit(&[10, 20]).expect("admitted");
        let t1 = h2.try_submit(&[30]).expect("admitted");
        assert_eq!((t0.id(), t1.id()), (0, 1));
        assert!(t0.try_wait().is_none(), "still queued: no response yet");
        h.close();
        s.serve();
        let r0 = t0.wait();
        let r1 = t1.wait();
        assert_eq!((r0.id, r1.id), (0, 1));
        assert_eq!(r0.argmax, vec![11, 21]);
        assert_eq!(r1.argmax, vec![31]);
        assert!(r0.error.is_none() && r1.error.is_none());
    }

    #[test]
    fn try_submit_backpressure_exactly_at_capacity() {
        let s = Server::new(config(2), Echo { steps: Vec::new(), fail: false, fail_row: None });
        let h = s.handle();
        assert!(h.try_submit(&[1]).is_ok());
        assert!(h.try_submit(&[1]).is_ok());
        assert_eq!(h.pending(), 2);
        // the queue is exactly full: the next submission is backpressure
        assert_eq!(h.try_submit(&[1]).unwrap_err(), SubmitError::Backpressure);
        assert_eq!(s.metrics().snapshot().rejected, 1);
        // once closed, refusals are Closed, not Backpressure
        h.close();
        assert_eq!(h.try_submit(&[1]).unwrap_err(), SubmitError::Closed);
        assert_eq!(s.metrics().snapshot().rejected, 2);
    }

    #[test]
    fn per_row_failure_only_fails_that_request() {
        let mut s =
            Server::new(config(32), Echo { steps: Vec::new(), fail: false, fail_row: Some(1) });
        let q = s.queue();
        let mut rxs = Vec::new();
        for id in 0..3u64 {
            let (r, rx) = req(id, vec![5, 6]);
            q.try_push(r);
            rxs.push(rx);
        }
        q.close();
        s.serve();
        for (i, rx) in rxs.iter().enumerate() {
            let resp = rx.try_recv().expect("response delivered");
            if i == 1 {
                assert!(resp.error.as_deref().unwrap_or("").contains("row boom"));
            } else {
                assert!(resp.error.is_none());
                assert_eq!(resp.argmax, vec![6, 7]);
            }
        }
        let snap = s.metrics().snapshot();
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.errors, 1);
    }

    #[test]
    fn executor_failure_fails_every_request_in_the_batch() {
        let mut s = server(true);
        let q = s.queue();
        let (r0, rx0) = req(0, vec![1]);
        let (r1, rx1) = req(1, vec![2]);
        q.try_push(r0);
        q.try_push(r1);
        q.close();
        s.serve();
        for rx in [rx0, rx1] {
            let resp = rx.try_recv().expect("failure response delivered");
            assert!(resp.error.as_deref().unwrap_or("").contains("boom"));
        }
        assert_eq!(s.metrics().snapshot().errors, 2);
    }

    #[test]
    fn oversized_requests_rejected_without_execution() {
        let mut s = server(false);
        let q = s.queue();
        let (r, rx) = req(7, vec![0; 100]);
        q.try_push(r);
        q.close();
        s.serve();
        let resp = rx.try_recv().expect("rejection delivered");
        assert!(resp.error.as_deref().unwrap_or("").contains("exceeds largest bucket"));
        assert!(s.executor().steps.is_empty());
        assert_eq!(s.metrics().snapshot().errors, 1);
    }

    #[test]
    fn stopper_ends_the_loop_and_fails_stranded_requests() {
        let mut s = server(false);
        let h = s.handle();
        let ticket = h.try_submit(&[1]).expect("admitted");
        let stopper = s.stopper();
        assert!(!stopper.is_stopped());
        stopper.stop();
        assert!(stopper.is_stopped());
        s.serve(); // returns promptly: stop is abortive, nothing executes
        assert!(s.executor().steps.is_empty());
        // the stranded request is failed, not leaked — the ticket resolves
        let resp = ticket.wait();
        assert!(resp.error.as_deref().unwrap_or("").contains("stopped"));
        // and new submissions fail closed
        assert_eq!(h.try_submit(&[2]).unwrap_err(), SubmitError::Closed);
    }

    #[test]
    fn sync_mode_serves_identically_without_overlap() {
        let cfg = ServerConfig { pipeline: false, ..config(32) };
        let mut s = Server::new(cfg, Echo { steps: Vec::new(), fail: false, fail_row: None });
        let h = s.handle();
        let tickets: Vec<Ticket> =
            (0..3).map(|i| h.try_submit(&[i, i + 1]).expect("admitted")).collect();
        h.close();
        s.serve();
        assert_eq!(s.executor().steps, vec![(4, 3)]);
        for (i, t) in tickets.into_iter().enumerate() {
            let i = i as i32;
            assert_eq!(t.wait().argmax, vec![i + 1, i + 2]);
        }
        // one step at a time: the gauge's high-water mark stays at 1
        assert_eq!(s.metrics().snapshot().max_in_flight, 1);
    }

    /// Fails the next `failures_left` steps (transiently or permanently),
    /// then echoes like [`Echo`].
    struct Flaky {
        failures_left: u32,
        transient: bool,
        executions: usize,
    }

    impl StepExecutor for Flaky {
        fn name(&self) -> &'static str {
            "flaky"
        }

        fn buckets(&self) -> Vec<usize> {
            vec![4]
        }

        fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
            self.executions += 1;
            if self.failures_left > 0 {
                self.failures_left -= 1;
                return Err(if self.transient {
                    ExecError::Timeout { backend: "flaky", detail: "injected".into() }
                } else {
                    ExecError::backend("flaky", "injected")
                });
            }
            Ok(StepOutput {
                argmax: step.tokens.iter().map(|&t| t + 1).collect(),
                expert_rows: Vec::new(),
                failed: Vec::new(),
                sim_time_s: None,
            })
        }
    }

    #[test]
    fn wait_timeout_leaves_the_ticket_completable() {
        let mut s = server(false);
        let h = s.handle();
        let t = h.try_submit(&[1, 2]).expect("admitted");
        // nothing is serving yet: bounded waits time out...
        assert!(t.wait_timeout(Duration::from_millis(5)).is_none());
        assert!(t.wait_timeout(Duration::from_millis(5)).is_none());
        h.close();
        s.serve();
        // ...and take nothing: the same ticket still completes
        let resp = t.wait_timeout(Duration::from_secs(5)).expect("resolved after serve");
        assert!(resp.error.is_none());
        assert_eq!(resp.argmax, vec![2, 3]);
    }

    #[test]
    fn expired_requests_are_shed_before_execution() {
        let mut s = server(false);
        let h = s.handle();
        // already-passed deadline: must never reach the executor
        let dead = h.submit_with_deadline(&[1, 2], Duration::ZERO).expect("admitted");
        let live = h.try_submit(&[5]).expect("admitted");
        std::thread::sleep(Duration::from_millis(2));
        h.close();
        s.serve();
        let resp = dead.wait();
        assert!(resp.expired, "deadline shed is marked expired");
        assert!(resp.error.as_deref().unwrap_or("").contains("deadline expired"));
        assert!(live.wait().error.is_none());
        // only the live request was planned and executed
        assert_eq!(s.executor().steps, vec![(4, 1)]);
        let snap = s.metrics().snapshot();
        assert_eq!((snap.expired, snap.errors, snap.requests), (1, 0, 1));
    }

    #[test]
    fn default_request_deadline_applies_to_handle_submissions() {
        let cfg = ServerConfig { request_deadline: Some(Duration::ZERO), ..config(32) };
        let mut s = Server::new(cfg, Echo { steps: Vec::new(), fail: false, fail_row: None });
        let h = s.handle();
        let t = h.try_submit(&[1]).expect("admitted");
        std::thread::sleep(Duration::from_millis(2));
        h.close();
        s.serve();
        assert!(t.wait().expired);
        assert!(s.executor().steps.is_empty());
        assert_eq!(s.metrics().snapshot().expired, 1);
    }

    #[test]
    fn transient_step_failures_retry_to_success() {
        let cfg = ServerConfig {
            retry: RetryPolicy { max_attempts: 3, backoff: Duration::ZERO },
            ..config(32)
        };
        let mut s =
            Server::new(cfg, Flaky { failures_left: 2, transient: true, executions: 0 });
        let h = s.handle();
        let t0 = h.try_submit(&[1]).expect("admitted");
        let t1 = h.try_submit(&[2]).expect("admitted");
        h.close();
        s.serve();
        assert!(t0.wait().error.is_none());
        assert!(t1.wait().error.is_none());
        assert_eq!(s.executor().executions, 3, "two transient failures + one success");
        let snap = s.metrics().snapshot();
        assert_eq!((snap.retries, snap.errors, snap.requests), (2, 0, 2));
    }

    #[test]
    fn permanent_step_failures_are_never_retried() {
        let cfg = ServerConfig {
            retry: RetryPolicy { max_attempts: 3, backoff: Duration::ZERO },
            ..config(32)
        };
        let mut s =
            Server::new(cfg, Flaky { failures_left: 1, transient: false, executions: 0 });
        let h = s.handle();
        let t = h.try_submit(&[1]).expect("admitted");
        h.close();
        s.serve();
        assert!(t.wait().error.as_deref().unwrap_or("").contains("injected"));
        assert_eq!(s.executor().executions, 1, "permanent failure: exactly one attempt");
        let snap = s.metrics().snapshot();
        assert_eq!((snap.retries, snap.errors), (0, 1));
    }

    #[test]
    fn exhausted_retries_fail_the_batch() {
        let cfg = ServerConfig {
            retry: RetryPolicy { max_attempts: 2, backoff: Duration::ZERO },
            ..config(32)
        };
        let mut s =
            Server::new(cfg, Flaky { failures_left: 5, transient: true, executions: 0 });
        let h = s.handle();
        let t = h.try_submit(&[1]).expect("admitted");
        h.close();
        s.serve();
        assert!(t.wait().error.as_deref().unwrap_or("").contains("timed out"));
        assert_eq!(s.executor().executions, 2, "max_attempts bounds total attempts");
        assert_eq!(s.metrics().snapshot().retries, 1);
    }
}
