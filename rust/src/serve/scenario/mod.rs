//! Scenario layer: trace-driven multi-tenant serving with SLOs, overload
//! shedding, and shard fault injection — all on a virtual clock.
//!
//! The serving core answers "does the pipeline work"; this layer answers
//! the production questions on top of it, deterministically and without a
//! wall clock:
//!
//! * **Who sends what, when** — an [`ArrivalTrace`] composes Poisson,
//!   burst, diurnal, and recorded segments into one arrival process
//!   ([`trace`]).
//! * **Who matters more** — [`TenantClass`]es carry priority, traffic
//!   share, prompt mix, and a latency SLO ([`tenant`]); the
//!   priority-admission layer
//!   ([`crate::coordinator::queue::PriorityAdmission`]) sheds the lowest
//!   priority first under overload.
//! * **What breaks** — a [`FaultPlan`] schedules shard slowdowns, deaths,
//!   and recoveries ([`fault`]), applied through
//!   [`crate::serve::StepExecutor::apply_fault`].
//! * **What happened** — [`run_scenario`] drives it all and returns a
//!   [`ScenarioReport`] with conservation-checked totals, per-tenant SLO
//!   attainment and goodput, and re-shard/recovery accounting ([`runner`]).

pub mod fault;
pub mod runner;
pub mod tenant;
pub mod trace;

pub use fault::{FaultEvent, FaultKind, FaultPlan};
pub use runner::{run_scenario, ScenarioConfig, ScenarioReport, TenantReport};
pub use tenant::TenantClass;
pub use trace::{ArrivalTrace, TraceSegment};
