//! Fault plans: scheduled shard-level failures on the virtual clock.
//!
//! A [`FaultPlan`] is a time-sorted list of [`FaultEvent`]s.  The scenario
//! runner applies every event whose time has passed to the executor via
//! [`crate::serve::StepExecutor::apply_fault`]; the sharded executor
//! translates them into per-shard speed and liveness changes (and a forced
//! expert evacuation on [`FaultKind::Kill`]).  Executors without shard
//! structure ignore them.

/// What happens to the shard.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The shard keeps serving but `factor`x slower (stragglers, thermal
    /// throttling, a noisy neighbor).
    Slow {
        /// Kernel-time multiplier; 2.0 means twice as slow.
        factor: f64,
    },
    /// The shard dies: it serves nothing until a [`FaultKind::Recover`],
    /// and its experts are evacuated to the surviving shards.
    Kill,
    /// The shard returns at nominal speed.
    Recover,
}

/// One scheduled fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// Virtual time the fault strikes, seconds from scenario start.
    pub at_s: f64,
    /// Which shard (ignored by executors without that many shards).
    pub shard: usize,
    /// What happens.
    pub kind: FaultKind,
}

/// A time-sorted schedule of shard faults.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Build a plan; events are sorted by time (stably, so same-time events
    /// keep their given order).
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        FaultPlan { events }
    }

    /// The scheduled events, ascending by time.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Virtual time of the earliest fault, if any.
    pub fn first_at(&self) -> Option<f64> {
        self.events.first().map(|e| e.at_s)
    }

    /// True when nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_sorts_events_by_time() {
        let plan = FaultPlan::new(vec![
            FaultEvent { at_s: 0.6, shard: 1, kind: FaultKind::Recover },
            FaultEvent { at_s: 0.3, shard: 1, kind: FaultKind::Kill },
            FaultEvent { at_s: 0.4, shard: 0, kind: FaultKind::Slow { factor: 4.0 } },
        ]);
        let times: Vec<f64> = plan.events().iter().map(|e| e.at_s).collect();
        assert_eq!(times, vec![0.3, 0.4, 0.6]);
        assert_eq!(plan.first_at(), Some(0.3));
        assert!(!plan.is_empty());
        assert!(FaultPlan::default().is_empty());
        assert_eq!(FaultPlan::default().first_at(), None);
    }
}
