//! Arrival traces: composable request-arrival processes on a virtual clock.
//!
//! The traffic driver's single `rate_hz` knob models one steady Poisson
//! stream.  Real serving traffic is a composition: steady background load,
//! bursts (a retry storm, a cache stampede), diurnal swings, and recorded
//! production traces to replay.  An [`ArrivalTrace`] is a sequence of
//! [`TraceSegment`]s laid end to end; [`ArrivalTrace::arrivals`] expands it
//! into a sorted list of virtual arrival timestamps, deterministically from
//! a seed — the scenario runner consumes those timestamps without ever
//! touching the wall clock.

use crate::util::rng::Rng;

/// One piece of an arrival trace.  Segments are laid end to end: each
/// segment's arrivals are offset by the total duration of the segments
/// before it.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceSegment {
    /// Memoryless arrivals at a constant rate for `duration_s` seconds.
    Poisson {
        /// Mean arrival rate, requests per virtual second.
        rate_hz: f64,
        /// Segment length, virtual seconds.
        duration_s: f64,
    },
    /// Exactly `count` arrivals spread evenly over `duration_s` seconds
    /// (all at the segment start when `duration_s` is zero) — a retry
    /// storm or thundering herd.
    Burst {
        /// Number of arrivals.
        count: usize,
        /// Window the arrivals are spread over, virtual seconds.
        duration_s: f64,
    },
    /// A sinusoidal rate swing between `base_hz` and `peak_hz` with period
    /// `period_s`, sampled by thinning a Poisson process at the peak rate —
    /// the classic compressed-diurnal load shape.
    Diurnal {
        /// Trough arrival rate, requests per virtual second.
        base_hz: f64,
        /// Crest arrival rate, requests per virtual second.
        peak_hz: f64,
        /// Full swing period, virtual seconds.
        period_s: f64,
        /// Segment length, virtual seconds.
        duration_s: f64,
    },
    /// Replay of recorded arrival offsets (seconds from the segment start,
    /// need not be sorted).  The segment's duration is the largest offset.
    Recorded(Vec<f64>),
}

impl TraceSegment {
    /// Virtual seconds this segment occupies on the trace timeline.
    pub fn duration_s(&self) -> f64 {
        match self {
            TraceSegment::Poisson { duration_s, .. } => *duration_s,
            TraceSegment::Burst { duration_s, .. } => *duration_s,
            TraceSegment::Diurnal { duration_s, .. } => *duration_s,
            TraceSegment::Recorded(offsets) => offsets.iter().cloned().fold(0.0, f64::max),
        }
    }
}

/// A composable arrival trace: segments laid end to end on the virtual
/// timeline.  Build with the chained constructors:
///
/// ```
/// use staticbatch::serve::ArrivalTrace;
///
/// let trace = ArrivalTrace::new().burst(100, 0.0).poisson(200.0, 1.0);
/// let arrivals = trace.arrivals(7);
/// assert!(arrivals.len() >= 100);
/// assert!(arrivals.windows(2).all(|w| w[0] <= w[1]), "sorted");
/// assert_eq!(arrivals, trace.arrivals(7), "deterministic");
/// ```
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ArrivalTrace {
    /// The segments, in timeline order.
    pub segments: Vec<TraceSegment>,
}

impl ArrivalTrace {
    /// An empty trace (no arrivals).
    pub fn new() -> Self {
        ArrivalTrace { segments: Vec::new() }
    }

    /// Append a [`TraceSegment::Poisson`] segment.
    pub fn poisson(mut self, rate_hz: f64, duration_s: f64) -> Self {
        self.segments.push(TraceSegment::Poisson { rate_hz, duration_s });
        self
    }

    /// Append a [`TraceSegment::Burst`] segment.
    pub fn burst(mut self, count: usize, duration_s: f64) -> Self {
        self.segments.push(TraceSegment::Burst { count, duration_s });
        self
    }

    /// Append a [`TraceSegment::Diurnal`] segment.
    pub fn diurnal(mut self, base_hz: f64, peak_hz: f64, period_s: f64, duration_s: f64) -> Self {
        self.segments.push(TraceSegment::Diurnal { base_hz, peak_hz, period_s, duration_s });
        self
    }

    /// Append a [`TraceSegment::Recorded`] segment.
    pub fn recorded(mut self, offsets: Vec<f64>) -> Self {
        self.segments.push(TraceSegment::Recorded(offsets));
        self
    }

    /// Total virtual seconds the trace spans.
    pub fn duration_s(&self) -> f64 {
        self.segments.iter().map(|s| s.duration_s()).sum()
    }

    /// Expand the trace into sorted virtual arrival timestamps,
    /// deterministically from `seed`.
    pub fn arrivals(&self, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        let mut base = 0.0f64;
        for seg in &self.segments {
            match seg {
                TraceSegment::Poisson { rate_hz, duration_s } => {
                    if *rate_hz > 0.0 {
                        let mut t = base;
                        loop {
                            t += rng.exponential() / rate_hz;
                            if t >= base + duration_s {
                                break;
                            }
                            out.push(t);
                        }
                    }
                }
                TraceSegment::Burst { count, duration_s } => {
                    for i in 0..*count {
                        if *duration_s > 0.0 {
                            out.push(base + i as f64 * duration_s / *count as f64);
                        } else {
                            out.push(base);
                        }
                    }
                }
                TraceSegment::Diurnal { base_hz, peak_hz, period_s, duration_s } => {
                    let lam_max = base_hz.max(*peak_hz);
                    if lam_max > 0.0 {
                        let period = period_s.max(1e-9);
                        let mut t = base;
                        loop {
                            t += rng.exponential() / lam_max;
                            if t >= base + duration_s {
                                break;
                            }
                            let phase = 2.0 * std::f64::consts::PI * (t - base) / period;
                            let rate = base_hz + (peak_hz - base_hz) * 0.5 * (1.0 - phase.cos());
                            if rng.f64() * lam_max < rate {
                                out.push(t);
                            }
                        }
                    }
                }
                TraceSegment::Recorded(offsets) => {
                    out.extend(offsets.iter().filter(|&&o| o >= 0.0).map(|&o| base + o));
                }
            }
            base += seg.duration_s();
        }
        out.sort_by(f64::total_cmp);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_count_tracks_rate_and_is_deterministic() {
        let trace = ArrivalTrace::new().poisson(200.0, 1.0);
        let a = trace.arrivals(1);
        // Poisson(200): +-6 sigma is roughly [115, 285]; keep it loose
        assert!((100..320).contains(&a.len()), "{} arrivals", a.len());
        assert!(a.iter().all(|&t| (0.0..1.0).contains(&t)));
        assert_eq!(a, trace.arrivals(1));
        assert_ne!(a, trace.arrivals(2));
    }

    #[test]
    fn burst_spreads_evenly_and_zero_duration_is_instantaneous() {
        let spread = ArrivalTrace::new().burst(4, 2.0).arrivals(0);
        assert_eq!(spread, vec![0.0, 0.5, 1.0, 1.5]);
        let instant = ArrivalTrace::new().burst(3, 0.0).arrivals(0);
        assert_eq!(instant, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn diurnal_stays_in_window_and_between_base_and_peak_rates() {
        let trace = ArrivalTrace::new().diurnal(50.0, 400.0, 1.0, 2.0);
        let a = trace.arrivals(3);
        assert!(a.iter().all(|&t| (0.0..2.0).contains(&t)));
        // mean rate is (base + peak) / 2 = 225 Hz over 2 s -> ~450 arrivals
        assert!((250..700).contains(&a.len()), "{} arrivals", a.len());
    }

    #[test]
    fn segments_compose_end_to_end_and_sort() {
        let trace = ArrivalTrace::new().burst(2, 1.0).recorded(vec![0.75, 0.25]);
        assert_eq!(trace.duration_s(), 1.75);
        // burst at 0.0 / 0.5, recorded offsets rebased to segment start 1.0
        assert_eq!(trace.arrivals(0), vec![0.0, 0.5, 1.25, 1.75]);
    }

    #[test]
    fn empty_trace_has_no_arrivals() {
        assert!(ArrivalTrace::new().arrivals(0).is_empty());
        assert_eq!(ArrivalTrace::new().duration_s(), 0.0);
    }
}
