//! Tenant classes: who is sending the traffic, and what they were promised.
//!
//! A [`TenantClass`] bundles a priority (admission order and shed order), a
//! traffic share (how much of the arrival trace this class generates), a
//! prompt-length mix, a bounded queue lane, and a latency SLO.  The
//! scenario runner assigns each arrival to a class by share weight, threads
//! the class id through [`crate::coordinator::request::Request::tenant`],
//! and reports per-class latency, goodput, and SLO attainment.

/// One tenant class in a scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantClass {
    /// Display name for reports.
    pub name: String,
    /// Admission priority: higher is more important.  Under overload the
    /// admission layer sheds strictly-lower-priority work first.
    pub priority: u32,
    /// Relative share of the arrival trace this class generates (weights
    /// are normalized across classes; they need not sum to 1).
    pub share: f64,
    /// Prompt lengths this class draws from, uniformly.
    pub prompt_lengths: Vec<usize>,
    /// Bound on this class's own admission lane (requests queued at once).
    pub queue_capacity: usize,
    /// End-to-end latency SLO, virtual milliseconds (TTFT-style: arrival
    /// to completed step).  A completed request meets its SLO when its
    /// virtual latency is at or under this.
    pub slo_ms: f64,
}

impl TenantClass {
    /// A class with the given identity and the default traffic shape
    /// (prompt lengths 12/48, lane bound 64, 50 ms SLO).
    pub fn new(name: &str, priority: u32, share: f64) -> Self {
        TenantClass {
            name: name.to_string(),
            priority,
            share,
            prompt_lengths: vec![12, 48],
            queue_capacity: 64,
            slo_ms: 50.0,
        }
    }
}

impl Default for TenantClass {
    fn default() -> Self {
        TenantClass::new("tenant", 1, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructor_sets_identity_and_defaults() {
        let t = TenantClass::new("premium", 2, 0.3);
        assert_eq!((t.name.as_str(), t.priority), ("premium", 2));
        assert!((t.share - 0.3).abs() < 1e-12);
        assert!(t.queue_capacity > 0);
        assert!(t.slo_ms > 0.0);
        assert!(!t.prompt_lengths.is_empty());
    }
}
