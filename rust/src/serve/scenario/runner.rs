//! The scenario runner: an event-driven serving loop on a virtual clock.
//!
//! [`run_scenario`] drives any [`StepExecutor`] through a full multi-tenant
//! scenario without touching the wall clock: arrivals come from an
//! [`ArrivalTrace`], each is assigned to a [`TenantClass`] by share weight
//! and offered to a [`PriorityAdmission`] layer (bounded lanes, lowest
//! priority shed first), batches form off the priority queue, and the
//! clock advances by each step's simulated time
//! ([`crate::serve::StepOutput::sim_time_s`]).  Scheduled shard faults from
//! a [`FaultPlan`] are applied as their virtual time passes.  Because
//! nothing sleeps and nothing races, a scenario is exactly reproducible
//! from its seed — overload, shedding, fault, and recovery included.

use std::collections::HashSet;

use crate::coordinator::metrics::{Metrics, Snapshot, TenantStats};
use crate::coordinator::queue::{Admit, PriorityAdmission};
use crate::serve::{RetryPolicy, StepExecutor, StepInput};
use crate::util::rng::{zipf_weights, Rng};

use super::{ArrivalTrace, FaultEvent, FaultKind, FaultPlan, TenantClass};

/// Everything that defines one scenario run.
#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    /// The arrival process.
    pub trace: ArrivalTrace,
    /// Tenant classes; arrivals are split across them by share weight.
    /// Class `i` is threaded through metrics as tenant id `i + 1`.
    pub tenants: Vec<TenantClass>,
    /// Scheduled shard faults.
    pub faults: FaultPlan,
    /// Global bound on queued requests across all tenant lanes.
    pub queue_capacity: usize,
    /// Most requests packed into one batch.
    pub max_batch_requests: usize,
    /// Cap on arrivals taken from the trace; 0 means no cap.
    pub max_requests: usize,
    /// Virtual seconds charged per step when the executor reports no
    /// simulated time (e.g. numeric CPU executors).
    pub fallback_step_s: f64,
    /// Retry policy for transient step failures: a failed attempt charges
    /// `fallback_step_s` plus the policy's (linear) backoff in virtual
    /// time, expired requests are dropped from the batch, and the
    /// survivors re-execute.  The default (1 attempt) never retries.
    pub retry: RetryPolicy,
    /// Per-request deadline in virtual seconds from arrival; a queued or
    /// retried request older than this is expired — answered as a
    /// deadline shed, never executed.  `0.0` disables deadlines.
    pub request_deadline_s: f64,
    /// Token id range for generated prompts.
    pub vocab: usize,
    /// Zipf exponent for prompt token values.
    pub zipf_alpha: f64,
    /// Seed for arrivals, tenant assignment, and prompt contents.
    pub seed: u64,
}

impl Default for ScenarioConfig {
    /// The pinned two-tenant acceptance scenario: a 300-request opening
    /// burst plus one second of 400 Hz Poisson traffic, a premium tenant
    /// (priority 2, 30% share) over a batch tenant (priority 1, 70%), and
    /// shard 1 dying at t=0.3s and recovering at t=0.6s.
    fn default() -> Self {
        ScenarioConfig {
            trace: ArrivalTrace::new().burst(300, 0.0).poisson(400.0, 1.0),
            tenants: vec![
                TenantClass::new("premium", 2, 0.3),
                TenantClass::new("batch", 1, 0.7),
            ],
            faults: FaultPlan::new(vec![
                FaultEvent { at_s: 0.3, shard: 1, kind: FaultKind::Kill },
                FaultEvent { at_s: 0.6, shard: 1, kind: FaultKind::Recover },
            ]),
            queue_capacity: 64,
            max_batch_requests: 8,
            max_requests: 0,
            fallback_step_s: 0.002,
            retry: RetryPolicy::default(),
            request_deadline_s: 0.0,
            vocab: 1000,
            zipf_alpha: 1.2,
            seed: 1,
        }
    }
}

/// Per-tenant outcome of one scenario run.
#[derive(Clone, Debug)]
pub struct TenantReport {
    /// Tenant class name.
    pub name: String,
    /// Tenant priority.
    pub priority: u32,
    /// Arrivals assigned to this class (ok + failed + shed + expired).
    pub sent: u64,
    /// Requests completed without error.
    pub ok: u64,
    /// Requests that errored.
    pub failed: u64,
    /// Requests dropped by admission control.
    pub shed: u64,
    /// Requests whose deadline passed before execution.
    pub expired: u64,
    /// Median end-to-end virtual latency, milliseconds.
    pub p50_ms: f64,
    /// 99th-percentile end-to-end virtual latency, milliseconds.
    pub p99_ms: f64,
    /// Fraction of finished-or-dropped requests that met the SLO
    /// (sheds and errors count as misses).
    pub slo_attainment: f64,
    /// SLO-meeting completions per virtual second.
    pub goodput_rps: f64,
}

/// What one scenario run produced.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    /// Virtual seconds the scenario spanned.
    pub virtual_s: f64,
    /// Arrivals generated (= ok + failed + shed + expired; conservation
    /// holds by construction).
    pub sent: u64,
    /// Requests completed without error.
    pub ok: u64,
    /// Requests that errored.
    pub failed: u64,
    /// Requests dropped by admission control (lane-full + evictions).
    pub shed: u64,
    /// Requests whose deadline passed before execution (queued too long,
    /// or dropped from a batch between retry attempts).
    pub expired: u64,
    /// Transient step failures that were retried.
    pub retries: u64,
    /// Batches executed.
    pub steps: u64,
    /// Circuit-breaker quarantines (sharded executors only).
    pub breaker_trips: u64,
    /// Half-open probes that restored a quarantined shard.
    pub breaker_probes: u64,
    /// Steps executed while any shard was quarantined or dead.
    pub degraded_steps: u64,
    /// Expert re-shards over the whole run (sharded executors only).
    pub reshards: u64,
    /// Re-shards at or after the first fault struck.
    pub reshards_after_fault: u64,
    /// Virtual seconds from the first fault to the first re-shard after it,
    /// when both happened.
    pub recovery_s: Option<f64>,
    /// Per-tenant breakdowns, in [`ScenarioConfig::tenants`] order.
    pub tenants: Vec<TenantReport>,
    /// Full metrics snapshot (latency percentiles are virtual-clock; the
    /// wall-clock `elapsed_s` field is not meaningful for scenarios).
    pub snapshot: Snapshot,
}

impl ScenarioReport {
    /// Multi-line human summary (the `staticbatch scenario` output).
    pub fn render(&self) -> String {
        let mut s = format!(
            "scenario: virtual={:.3}s  sent={} ok={} failed={} shed={} expired={}  \
             steps={} retries={}\n\
             placement: reshards={} (after first fault: {})  recovery={}",
            self.virtual_s,
            self.sent,
            self.ok,
            self.failed,
            self.shed,
            self.expired,
            self.steps,
            self.retries,
            self.reshards,
            self.reshards_after_fault,
            match self.recovery_s {
                Some(r) => format!("{:.1}ms", r * 1e3),
                None => "-".to_string(),
            },
        );
        if self.breaker_trips + self.breaker_probes + self.degraded_steps > 0 {
            s.push_str(&format!(
                "\nbreakers: {} trips  {} probes  {} degraded steps",
                self.breaker_trips, self.breaker_probes, self.degraded_steps,
            ));
        }
        for t in &self.tenants {
            s.push_str(&format!(
                "\ntenant {} (prio {}): sent={} ok={} failed={} shed={} expired={}  \
                 p50={:.3}ms p99={:.3}ms  slo {:.1}%  goodput {:.1} req/s",
                t.name,
                t.priority,
                t.sent,
                t.ok,
                t.failed,
                t.shed,
                t.expired,
                t.p50_ms,
                t.p99_ms,
                t.slo_attainment * 100.0,
                t.goodput_rps,
            ));
        }
        s
    }
}

/// One queued request inside the scenario runner.
struct Item {
    arrival_s: f64,
    tenant: u32,
    tokens: Vec<i32>,
}

fn current_reshards<E: StepExecutor>(executor: &E) -> u64 {
    executor.sharding().map_or(0, |s| s.reshards)
}

/// Run one scenario against `executor`.  Single-threaded and fully
/// deterministic: the clock is virtual, advanced only by simulated step
/// times (or [`ScenarioConfig::fallback_step_s`]), and jumps forward to
/// the next arrival whenever the system drains idle.
pub fn run_scenario<E: StepExecutor>(executor: &mut E, cfg: &ScenarioConfig) -> ScenarioReport {
    assert!(!cfg.tenants.is_empty(), "at least one tenant class");
    let mut rng = Rng::new(cfg.seed);
    let mut times = cfg.trace.arrivals(cfg.seed ^ 0x5CEA_0001);
    if cfg.max_requests > 0 {
        times.truncate(cfg.max_requests);
    }
    // One distinct prompt per (tenant, length): popular prompts repeat, so
    // load signatures recur and the plan cache sees realistic hits.
    let token_w = zipf_weights(cfg.vocab.max(2), cfg.zipf_alpha);
    let pools: Vec<Vec<Vec<i32>>> = cfg
        .tenants
        .iter()
        .map(|t| {
            t.prompt_lengths
                .iter()
                .map(|&len| (0..len.max(1)).map(|_| rng.zipf(&token_w) as i32 + 1).collect())
                .collect()
        })
        .collect();
    let shares: Vec<f64> = cfg.tenants.iter().map(|t| t.share.max(0.0)).collect();
    let arrivals: Vec<(f64, usize, Vec<i32>)> = times
        .iter()
        .map(|&t| {
            let class = rng.zipf(&shares);
            let pool = &pools[class];
            (t, class, pool[rng.usize_below(pool.len())].clone())
        })
        .collect();

    let lanes: Vec<(u32, usize)> =
        cfg.tenants.iter().map(|t| (t.priority, t.queue_capacity.max(1))).collect();
    let mut pa: PriorityAdmission<Item> =
        PriorityAdmission::new(cfg.queue_capacity.max(1), &lanes);
    let metrics = Metrics::new();
    let buckets = executor.buckets();
    let step_cap = executor.max_step_tokens().unwrap_or(usize::MAX);
    let events = cfg.faults.events();

    let mut now = 0.0f64;
    let mut next = 0usize;
    let mut fi = 0usize;
    let (mut steps, mut ok, mut failed, mut shed) = (0u64, 0u64, 0u64, 0u64);
    let (mut expired, mut retries) = (0u64, 0u64);
    let base_reshards = current_reshards(executor);
    let base_breakers = executor
        .sharding()
        .map_or((0, 0, 0), |s| (s.breaker_trips, s.breaker_probes, s.degraded_steps));
    let mut first_fault: Option<f64> = None;
    let mut reshards_at_fault = 0u64;
    let mut recovery_s: Option<f64> = None;

    loop {
        // idle: jump the virtual clock to the next arrival
        if pa.is_empty() && next < arrivals.len() {
            now = now.max(arrivals[next].0);
        }
        // admit everything that has arrived by now
        while next < arrivals.len() && arrivals[next].0 <= now {
            let (t, class, ref tokens) = arrivals[next];
            next += 1;
            let tenant = class as u32 + 1;
            let item = Item { arrival_s: t, tenant, tokens: tokens.clone() };
            match pa.offer(class, item) {
                (Admit::Admitted, _) => {}
                (Admit::Evicted { victim }, _) => {
                    shed += 1;
                    metrics.record_tenant_shed(victim as u32 + 1);
                }
                (Admit::Shed, _) => {
                    shed += 1;
                    metrics.record_tenant_shed(tenant);
                }
            }
        }
        // apply faults whose virtual time has passed
        while fi < events.len() && events[fi].at_s <= now {
            if first_fault.is_none() {
                first_fault = Some(events[fi].at_s);
                reshards_at_fault = current_reshards(executor);
            }
            executor.apply_fault(&events[fi]);
            fi += 1;
        }
        if pa.is_empty() {
            if next >= arrivals.len() {
                break;
            }
            continue;
        }
        // form one batch: the highest-priority head picks the bucket,
        // riders that fit the bucket fill the remaining rows
        let (head_class, head) = pa.pop_front().expect("queue is non-empty");
        let past_deadline = |it: &Item, now: f64| {
            cfg.request_deadline_s > 0.0 && now - it.arrival_s > cfg.request_deadline_s
        };
        if past_deadline(&head, now) {
            expired += 1;
            metrics.record_expired();
            metrics.record_tenant_expired(head.tenant);
            continue;
        }
        let bucket = match buckets.iter().find(|&&b| b >= head.tokens.len()) {
            Some(&b) => b,
            None => {
                failed += 1;
                metrics.record_tenant_error(head.tenant);
                metrics.record_error();
                continue;
            }
        };
        let rows_cap = cfg.max_batch_requests.max(1).min((step_cap / bucket).max(1));
        let mut batch = vec![(head_class, head)];
        while batch.len() < rows_cap {
            match pa.pop_front_if(|it| it.tokens.len() <= bucket) {
                Some(rider) => batch.push(rider),
                None => break,
            }
        }
        // a rider may have waited out its deadline in the queue; shed it
        // now rather than spending a batch row on a dead request
        let (live, dead): (Vec<_>, Vec<_>) =
            batch.into_iter().partition(|(_, it)| !past_deadline(it, now));
        for (_, it) in dead {
            expired += 1;
            metrics.record_expired();
            metrics.record_tenant_expired(it.tenant);
        }
        let mut batch = live;
        if batch.is_empty() {
            continue;
        }
        // transient step failures retry (charging virtual backoff time and
        // re-shedding anything that expires while waiting); permanent
        // failures fail the whole batch
        let mut attempt = 0u32;
        let outcome = loop {
            let mut flat = Vec::with_capacity(batch.len() * bucket);
            for (_, it) in &batch {
                flat.extend_from_slice(&it.tokens);
                flat.resize(flat.len() + bucket - it.tokens.len(), 0);
            }
            let step = StepInput { bucket, rows: batch.len(), tokens: &flat };
            match executor.execute_step(&step) {
                Ok(out) => break Some(out),
                Err(e) => {
                    executor.observe_error(&e);
                    attempt += 1;
                    if e.is_transient() && attempt < cfg.retry.max_attempts {
                        retries += 1;
                        metrics.record_retry();
                        now += cfg.fallback_step_s
                            + cfg.retry.backoff.as_secs_f64() * attempt as f64;
                        let (live, dead): (Vec<_>, Vec<_>) =
                            batch.into_iter().partition(|(_, it)| !past_deadline(it, now));
                        for (_, it) in dead {
                            expired += 1;
                            metrics.record_expired();
                            metrics.record_tenant_expired(it.tenant);
                        }
                        batch = live;
                        if batch.is_empty() {
                            break None;
                        }
                        continue;
                    }
                    now += cfg.fallback_step_s;
                    for (_, it) in &batch {
                        failed += 1;
                        metrics.record_tenant_error(it.tenant);
                        metrics.record_error();
                    }
                    break None;
                }
            }
        };
        match outcome {
            Some(out) => {
                let dt = out.sim_time_s.unwrap_or(cfg.fallback_step_s).max(0.0);
                now += dt;
                steps += 1;
                metrics.record_exec(dt, batch.len());
                if !out.expert_rows.is_empty() {
                    metrics.record_expert_rows(&out.expert_rows);
                }
                if let Some(c) = executor.cache_stats() {
                    metrics.set_plan_cache(c.hits, c.misses);
                }
                if let Some(sh) = executor.sharding() {
                    metrics.set_sharding(sh);
                }
                let failed_rows: HashSet<usize> = out.failed.iter().map(|(r, _)| *r).collect();
                for (row, (class, it)) in batch.iter().enumerate() {
                    if failed_rows.contains(&row) {
                        failed += 1;
                        metrics.record_tenant_error(it.tenant);
                        metrics.record_error();
                    } else {
                        let latency = now - it.arrival_s;
                        let met = latency * 1e3 <= cfg.tenants[*class].slo_ms;
                        ok += 1;
                        metrics.record_request(latency, it.tokens.len());
                        metrics.record_tenant_request(it.tenant, latency, Some(met));
                    }
                }
            }
            // a permanent (or retry-exhausted) failure already failed the
            // batch inside the retry loop; a fully-expired batch needs
            // nothing more
            None => {
                if let Some(sh) = executor.sharding() {
                    metrics.set_sharding(sh);
                }
            }
        }
        if let (Some(f0), None) = (first_fault, recovery_s) {
            if current_reshards(executor) > reshards_at_fault {
                recovery_s = Some(now - f0);
            }
        }
    }

    debug_assert_eq!(arrivals.len() as u64, ok + failed + shed + expired, "conservation");
    let snapshot = metrics.snapshot();
    let virtual_s = now;
    let tenants = cfg
        .tenants
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let id = i as u32 + 1;
            let st = snapshot
                .tenants
                .iter()
                .find(|s| s.tenant == id)
                .cloned()
                .unwrap_or_else(|| TenantStats { tenant: id, ..TenantStats::default() });
            TenantReport {
                name: t.name.clone(),
                priority: t.priority,
                sent: st.requests + st.errors + st.shed + st.expired,
                ok: st.requests,
                failed: st.errors,
                shed: st.shed,
                expired: st.expired,
                p50_ms: st.latency_p50_ms,
                p99_ms: st.latency_p99_ms,
                slo_attainment: st.slo_attainment(),
                goodput_rps: st.goodput(virtual_s),
            }
        })
        .collect();
    let final_reshards = current_reshards(executor);
    let final_breakers = executor
        .sharding()
        .map_or((0, 0, 0), |s| (s.breaker_trips, s.breaker_probes, s.degraded_steps));
    ScenarioReport {
        virtual_s,
        sent: arrivals.len() as u64,
        ok,
        failed,
        shed,
        expired,
        retries,
        steps,
        breaker_trips: final_breakers.0 - base_breakers.0,
        breaker_probes: final_breakers.1 - base_breakers.1,
        degraded_steps: final_breakers.2 - base_breakers.2,
        reshards: final_reshards - base_reshards,
        reshards_after_fault: if first_fault.is_some() {
            final_reshards - reshards_at_fault
        } else {
            0
        },
        recovery_s,
        tenants,
        snapshot,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{
        PlacementKind, ShardedServeConfig, ShardedStepExecutor, SimServeConfig, SimStepExecutor,
    };

    fn two_tenant_burst(count: usize, queue: usize) -> ScenarioConfig {
        ScenarioConfig {
            trace: ArrivalTrace::new().burst(count, 0.0),
            tenants: vec![TenantClass::new("hi", 2, 0.3), TenantClass::new("lo", 1, 0.7)],
            faults: FaultPlan::default(),
            queue_capacity: queue,
            ..ScenarioConfig::default()
        }
    }

    fn sim_exec() -> SimStepExecutor {
        SimStepExecutor::new(SimServeConfig {
            buckets: vec![16, 64],
            max_tokens: 2048,
            numeric: false,
            ..SimServeConfig::default()
        })
    }

    #[test]
    fn burst_overload_conserves_and_orders_attainment_by_priority() {
        let mut ex = sim_exec();
        let r = run_scenario(&mut ex, &two_tenant_burst(200, 32));
        assert_eq!(r.sent, 200);
        assert_eq!(r.ok + r.failed + r.shed, r.sent, "conservation");
        assert_eq!(r.failed, 0);
        assert!(r.shed > 0, "a 200-burst must overflow a 32-slot queue");
        assert!(r.steps > 0);
        assert!(r.virtual_s > 0.0);
        let hi = &r.tenants[0];
        let lo = &r.tenants[1];
        assert_eq!(hi.sent + lo.sent, r.sent);
        assert!(
            hi.slo_attainment >= lo.slo_attainment,
            "hi {} < lo {}",
            hi.slo_attainment,
            lo.slo_attainment
        );
        assert!(hi.shed <= lo.shed, "low priority is shed first");
    }

    #[test]
    fn scenario_is_deterministic_for_a_seed() {
        let a = run_scenario(&mut sim_exec(), &two_tenant_burst(100, 32));
        let b = run_scenario(&mut sim_exec(), &two_tenant_burst(100, 32));
        assert_eq!(a.virtual_s, b.virtual_s);
        assert_eq!((a.ok, a.failed, a.shed, a.steps), (b.ok, b.failed, b.shed, b.steps));
        assert_eq!(a.tenants[0].p99_ms, b.tenants[0].p99_ms);
    }

    #[test]
    fn kill_fault_forces_a_reshard_and_recovery_is_reported() {
        let mut ex = ShardedStepExecutor::new(ShardedServeConfig {
            base: SimServeConfig {
                buckets: vec![16, 64],
                max_tokens: 2048,
                numeric: false,
                ..SimServeConfig::default()
            },
            ep: 2,
            placement: PlacementKind::Static,
            ..ShardedServeConfig::default()
        });
        let cfg = ScenarioConfig {
            trace: ArrivalTrace::new().burst(64, 0.0),
            faults: FaultPlan::new(vec![FaultEvent {
                at_s: 0.0,
                shard: 1,
                kind: FaultKind::Kill,
            }]),
            queue_capacity: 64,
            ..ScenarioConfig::default()
        };
        let r = run_scenario(&mut ex, &cfg);
        assert_eq!(r.ok + r.failed + r.shed, r.sent);
        assert!(r.reshards >= 1, "kill evacuation counts as a reshard");
        assert!(r.reshards_after_fault >= 1);
        assert!(r.recovery_s.is_some());
        assert!(!ex.live()[1], "no recover event was scheduled");
        assert!(ex.assignment().iter().all(|&s| s == 0));
    }

    #[test]
    fn report_renders_tenant_lines() {
        let r = run_scenario(&mut sim_exec(), &two_tenant_burst(40, 64));
        let s = r.render();
        assert!(s.contains("scenario: virtual="), "{s}");
        assert!(s.contains("tenant hi (prio 2):"), "{s}");
        assert!(s.contains("tenant lo (prio 1):"), "{s}");
        assert!(s.contains("slo "), "{s}");
    }

    #[test]
    fn oversized_prompts_fail_instead_of_wedging() {
        let mut ex = sim_exec();
        let cfg = ScenarioConfig {
            trace: ArrivalTrace::new().burst(5, 0.0),
            tenants: vec![TenantClass {
                prompt_lengths: vec![500], // larger than every bucket
                ..TenantClass::default()
            }],
            faults: FaultPlan::default(),
            queue_capacity: 8,
            ..ScenarioConfig::default()
        };
        let r = run_scenario(&mut ex, &cfg);
        assert_eq!((r.ok, r.failed), (0, 5));
        assert_eq!(r.ok + r.failed + r.shed, r.sent);
    }

    #[test]
    fn stale_queue_entries_expire_instead_of_executing() {
        let mut ex = sim_exec();
        let cfg = ScenarioConfig {
            trace: ArrivalTrace::new().burst(50, 0.0),
            tenants: vec![TenantClass::new("only", 1, 1.0)],
            faults: FaultPlan::default(),
            queue_capacity: 64,
            // every step costs 2ms of virtual time but the deadline is
            // 1ms: whatever the first batch leaves queued is already dead
            fallback_step_s: 0.002,
            request_deadline_s: 0.001,
            ..ScenarioConfig::default()
        };
        let r = run_scenario(&mut ex, &cfg);
        assert!(r.expired > 0, "queued remainder must expire");
        assert_eq!(r.failed, 0);
        assert_eq!(r.ok + r.failed + r.shed + r.expired, r.sent, "conservation");
        assert_eq!(r.tenants[0].expired, r.expired, "tenant view matches");
        let s = r.render();
        assert!(s.contains("expired="), "{s}");
    }

    /// Fails the first `failures` step attempts with a transient error.
    struct FlakyOnce {
        inner: SimStepExecutor,
        failures: u32,
    }

    impl StepExecutor for FlakyOnce {
        fn name(&self) -> &'static str {
            "flaky"
        }
        fn buckets(&self) -> Vec<usize> {
            self.inner.buckets()
        }
        fn max_step_tokens(&self) -> Option<usize> {
            self.inner.max_step_tokens()
        }
        fn execute_step(
            &mut self,
            step: &StepInput<'_>,
        ) -> Result<crate::serve::StepOutput, crate::exec::ExecError> {
            if self.failures > 0 {
                self.failures -= 1;
                return Err(crate::exec::ExecError::Timeout {
                    backend: "flaky",
                    detail: "injected".into(),
                });
            }
            self.inner.execute_step(step)
        }
    }

    #[test]
    fn transient_failures_retry_without_losing_requests() {
        let mut ex = FlakyOnce { inner: sim_exec(), failures: 2 };
        let cfg = ScenarioConfig {
            trace: ArrivalTrace::new().burst(20, 0.0),
            tenants: vec![TenantClass::new("only", 1, 1.0)],
            faults: FaultPlan::default(),
            queue_capacity: 64,
            retry: RetryPolicy {
                max_attempts: 4,
                backoff: std::time::Duration::from_millis(1),
            },
            ..ScenarioConfig::default()
        };
        let r = run_scenario(&mut ex, &cfg);
        assert_eq!(r.retries, 2, "both transient failures retried");
        assert_eq!(r.failed, 0, "retries absorb the faults");
        assert_eq!(r.ok, r.sent, "every request completes");
        assert!(r.render().contains("retries=2"), "{}", r.render());
    }

    #[test]
    fn exhausted_retries_fail_the_batch_in_scenarios() {
        let mut ex = FlakyOnce { inner: sim_exec(), failures: u32::MAX };
        let cfg = ScenarioConfig {
            trace: ArrivalTrace::new().burst(4, 0.0),
            tenants: vec![TenantClass::new("only", 1, 1.0)],
            faults: FaultPlan::default(),
            queue_capacity: 8,
            retry: RetryPolicy { max_attempts: 2, backoff: std::time::Duration::ZERO },
            ..ScenarioConfig::default()
        };
        let r = run_scenario(&mut ex, &cfg);
        assert_eq!(r.ok, 0);
        assert_eq!(r.failed, r.sent);
        assert_eq!(r.ok + r.failed + r.shed + r.expired, r.sent, "conservation");
    }
}
