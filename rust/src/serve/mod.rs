//! Backend-generic serving core.
//!
//! The L3 request path — admission queue → continuous batcher → plan cache
//! → execution → metrics → responses — used to live inside the PJRT-only
//! engine, invisible to the tier-1 suite.  This module owns that loop for
//! *any* execution surface:
//!
//! ```text
//!     ServeHandle clones (TCP / in-process producers)
//!        │ try_submit → Backpressure   submit → blocks
//!        ▼
//!   ┌────────────────┐   ┌─────────────────┐   ┌─────────────────┐
//!   │ AdmissionQueue │──▶│ batcher thread  │──▶│ executor stage  │──┐
//!   │ bounded,       │   │ wakeup-driven   │ s │ StepExecutor    │  │
//!   │ condvar wakeups│   │ accumulate until│ y │ (sim / sharded  │ sync
//!   └────────────────┘   │ max-batch OR    │ n │ / PJRT), pinned │ chan
//!                        │ deadline, then  │ c │ to the caller's │  │
//!                        │ BatchPolicy form│   │ thread          │  │
//!                        │ + pack          │   └─────────────────┘  │
//!                        └─────────────────┘                        ▼
//!                  step N+1 forms while step N executes   ┌─────────────────┐
//!                                                         │ responder thread│
//!     tickets ◀───────────────────────────────────────────│ fan out per     │
//!     (one Response each; Metrics: latency, exec, batch,  │ caller ticket   │
//!      queue/form waits, in-flight steps, plan cache)     └─────────────────┘
//! ```
//!
//! [`Server`] is generic over a small [`StepExecutor`] trait with four
//! instantiations: the default-features [`SimStepExecutor`] (routing +
//! [`PlanCache`] + [`crate::exec::ExecutionSession`]), the whole-layer
//! [`FusedStepExecutor`] (attention + prefill + routed FFN as one
//! heterogeneous plan), the expert-parallel
//! [`ShardedStepExecutor`] (per-shard sessions and plan-cache lanes, EP/TP
//! collectives, pluggable [`PlacementKind`]), and the PJRT engine
//! (`coordinator::engine::Engine`, feature `pjrt`) — so the whole pipeline
//! runs, and is load-tested, without XLA, artifacts, or a GPU.
//!
//! Implementing [`StepExecutor`] is all it takes to put a new execution
//! surface behind the serving loop; producers talk to it through cloneable
//! [`ServeHandle`]s and per-request [`Ticket`]s:
//!
//! ```
//! use staticbatch::exec::ExecError;
//! use staticbatch::serve::{Server, ServerConfig, StepExecutor, StepInput, StepOutput};
//!
//! /// Echoes every token incremented — the smallest possible executor.
//! struct Echo;
//!
//! impl StepExecutor for Echo {
//!     fn name(&self) -> &'static str {
//!         "echo"
//!     }
//!     fn buckets(&self) -> Vec<usize> {
//!         vec![4, 8]
//!     }
//!     fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
//!         Ok(StepOutput {
//!             argmax: step.tokens.iter().map(|&t| t + 1).collect(),
//!             expert_rows: Vec::new(),
//!             failed: Vec::new(),
//!             sim_time_s: None,
//!         })
//!     }
//! }
//!
//! let mut server = Server::new(ServerConfig::default(), Echo);
//! let handle = server.handle();
//! let ticket = handle.submit(&[1, 2, 3]).expect("queue open");
//! handle.close(); // end of stream: serve() drains, then returns
//! server.serve();
//! assert_eq!(ticket.wait().argmax, vec![2, 3, 4]);
//! ```

pub mod chaos;
pub mod driver;
pub mod fused_exec;
pub mod scenario;
pub mod server;
pub mod sharded;
pub mod sim_exec;

pub use crate::coordinator::metrics::ShardingStats;
pub use crate::moe::plan_cache::{CacheStats, PlanCache};
pub use chaos::{ChaosConfig, ChaosStats, ChaosStepExecutor, ShardDeath};
pub use driver::{run_traffic, TrafficConfig, TrafficReport};
pub use fused_exec::{FusedServeConfig, FusedStepExecutor};
pub use scenario::{
    run_scenario, ArrivalTrace, FaultEvent, FaultKind, FaultPlan, ScenarioConfig, ScenarioReport,
    TenantClass, TraceSegment,
};
pub use server::{
    RetryPolicy, ServeHandle, Server, ServerConfig, Stopper, SubmitError, Ticket,
};
pub use sharded::{PlacementKind, ShardedServeConfig, ShardedStepExecutor};
pub use sim_exec::{SimServeConfig, SimStepExecutor};

use crate::exec::ExecError;

/// One formed batch, packed for execution: `rows` requests padded to
/// `bucket` tokens each, row-major in `tokens` (`rows * bucket` ids).
pub struct StepInput<'a> {
    /// Sequence bucket every request in the batch was padded to.
    pub bucket: usize,
    /// Requests in the batch (one padded row each).
    pub rows: usize,
    /// Packed token ids, row-major, `rows * bucket` entries.
    pub tokens: &'a [i32],
}

/// What one executed step produced.
pub struct StepOutput {
    /// Per-position argmax, row-major, `rows * bucket` entries (the server
    /// slices each request's prefix back out).
    pub argmax: Vec<i32>,
    /// Per-expert routed row counts for this step, when the executor
    /// routes through an MoE layer (empty otherwise).
    pub expert_rows: Vec<i32>,
    /// Per-row failures `(row index, error)` for executors that dispatch
    /// rows independently (the PJRT LM path): listed rows carry
    /// placeholder argmax entries and the server fails only their
    /// requests, preserving per-request error isolation inside a batch.
    pub failed: Vec<(usize, String)>,
    /// Simulated seconds this step took on the modeled hardware, when the
    /// executor runs an accounting backend (`None` for pure-numeric or
    /// echo executors).  The scenario runner ([`scenario::run_scenario`])
    /// advances its virtual clock by this amount per step.
    pub sim_time_s: Option<f64>,
}

/// The execution step of the serving loop: everything between a formed
/// batch and its raw outputs.  Implementations own their runtime state
/// (compiled executables, sessions, caches) and are driven from the
/// server's worker loop — one call per batch, never per request.
pub trait StepExecutor {
    /// Display name for logs and reports.
    fn name(&self) -> &'static str;

    /// Sequence buckets this executor can serve, ascending.  The server
    /// adopts these as its batch policy's buckets.
    fn buckets(&self) -> Vec<usize>;

    /// Upper bound on padded tokens per step, when the executor has a
    /// fixed capacity; the server clamps its batch policy's token budget
    /// to it at construction so misconfiguration cannot surface as
    /// whole-batch runtime failures.
    fn max_step_tokens(&self) -> Option<usize> {
        None
    }

    /// Execute one formed batch.
    fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError>;

    /// Plan-cache counters, when the executor plans through a
    /// [`PlanCache`]; the server mirrors them into its metrics after every
    /// step.
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }

    /// Cumulative multi-shard (EP/TP) accounting, when the executor shards
    /// its work across lanes; the server mirrors it into its metrics after
    /// every step, like the plan-cache counters.
    fn sharding(&self) -> Option<ShardingStats> {
        None
    }

    /// Apply a scheduled shard fault (slowdown, death, recovery) from a
    /// [`scenario::FaultPlan`].  Executors without shard structure ignore
    /// faults; [`ShardedStepExecutor`] adjusts per-shard speed/liveness and
    /// evacuates experts off dead shards.
    fn apply_fault(&mut self, event: &FaultEvent) {
        let _ = event;
    }

    /// Report one step failure back to the executor — called by the
    /// serving loop on *every* failed `execute_step`, retried or not.
    /// [`ShardedStepExecutor`] feeds shard-attributed transient failures
    /// into its per-shard circuit breakers; executors without failure
    /// bookkeeping ignore it.
    fn observe_error(&mut self, err: &ExecError) {
        let _ = err;
    }

    /// Whether `shard` would participate in the next step (alive and
    /// holding experts).  Fault injectors use this so a shard-death fault
    /// only errors while work is actually scheduled on the dead shard —
    /// and stops erroring once placement evacuates it.  Executors without
    /// shard structure report every shard as in use.
    fn shard_in_use(&self, shard: usize) -> bool {
        let _ = shard;
        true
    }
}
