//! Backend-generic serving core.
//!
//! The L3 request path — admission queue → continuous batcher → plan cache
//! → execution → metrics → responses — used to live inside the PJRT-only
//! engine, invisible to the tier-1 suite.  This module owns that loop for
//! *any* execution surface:
//!
//! ```text
//!           requests (TCP / in-process)
//!                      │
//!          ┌───────────▼───────────┐
//!          │    AdmissionQueue     │  bounded, backpressure
//!          └───────────┬───────────┘
//!          ┌───────────▼───────────┐
//!          │      BatchPolicy      │  bucket + pack, FIFO per bucket
//!          └───────────┬───────────┘
//!          ┌───────────▼───────────┐
//!          │     StepExecutor      │  one call per formed batch:
//!          │  (sim / CPU / PJRT)   │  route → PlanCache → plan → execute
//!          └───────────┬───────────┘
//!          ┌───────────▼───────────┐
//!          │       Metrics         │  latency, exec, batch, plan cache
//!          └───────────┬───────────┘
//!                  responses
//! ```
//!
//! [`Server`] is generic over a small [`StepExecutor`] trait; the
//! PJRT engine (`coordinator::engine::Engine`, feature `pjrt`) and the
//! default-features [`SimStepExecutor`] (routing + [`PlanCache`] +
//! [`crate::exec::ExecutionSession`]) are the two instantiations, so the
//! whole pipeline runs — and is load-tested — without XLA, artifacts, or a
//! GPU.

pub mod driver;
pub mod server;
pub mod sim_exec;

pub use crate::moe::plan_cache::{CacheStats, PlanCache};
pub use driver::{run_traffic, TrafficConfig, TrafficReport};
pub use server::{Server, ServerConfig};
pub use sim_exec::{SimServeConfig, SimStepExecutor};

use crate::exec::ExecError;

/// One formed batch, packed for execution: `rows` requests padded to
/// `bucket` tokens each, row-major in `tokens` (`rows * bucket` ids).
pub struct StepInput<'a> {
    pub bucket: usize,
    pub rows: usize,
    pub tokens: &'a [i32],
}

/// What one executed step produced.
pub struct StepOutput {
    /// Per-position argmax, row-major, `rows * bucket` entries (the server
    /// slices each request's prefix back out).
    pub argmax: Vec<i32>,
    /// Per-expert routed row counts for this step, when the executor
    /// routes through an MoE layer (empty otherwise).
    pub expert_rows: Vec<i32>,
    /// Per-row failures `(row index, error)` for executors that dispatch
    /// rows independently (the PJRT LM path): listed rows carry
    /// placeholder argmax entries and the server fails only their
    /// requests, preserving per-request error isolation inside a batch.
    pub failed: Vec<(usize, String)>,
}

/// The execution step of the serving loop: everything between a formed
/// batch and its raw outputs.  Implementations own their runtime state
/// (compiled executables, sessions, caches) and are driven from the
/// server's worker loop — one call per batch, never per request.
pub trait StepExecutor {
    /// Display name for logs and reports.
    fn name(&self) -> &'static str;

    /// Sequence buckets this executor can serve, ascending.  The server
    /// adopts these as its batch policy's buckets.
    fn buckets(&self) -> Vec<usize>;

    /// Upper bound on padded tokens per step, when the executor has a
    /// fixed capacity; the server clamps its batch policy's token budget
    /// to it at construction so misconfiguration cannot surface as
    /// whole-batch runtime failures.
    fn max_step_tokens(&self) -> Option<usize> {
        None
    }

    /// Execute one formed batch.
    fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError>;

    /// Plan-cache counters, when the executor plans through a
    /// [`PlanCache`]; the server mirrors them into its metrics after every
    /// step.
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }
}
