//! [`ShardedStepExecutor`]: expert-parallel sharded serving.
//!
//! Paper Section 2.2: under EP/TP "the MoE computation is an irregular
//! workload from the perspective of each GPU" — each shard owns a subset of
//! experts, so a skewed route turns expert-load imbalance into *device*
//! imbalance.  This executor brings that regime into the serving core: each
//! formed batch is routed once (the same deterministic top-k as the
//! single-shard [`SimStepExecutor`](crate::serve::SimStepExecutor)), the
//! routed tokens are partitioned across an expert→shard placement, and every
//! shard plans + executes its sub-problem through its own
//! [`ExecutionSession`] with its own [`PlanCache`](crate::serve::PlanCache)
//! lane.  Simulated step latency is `max(shard kernel) + EP all-to-all +
//! TP all-reduce`, with collective costs charged from
//! [`crate::moe::parallel::ParallelConfig`].
//!
//! A shard's sub-problem is the *full* expert space masked to the experts it
//! owns: unowned experts appear as empty tasks, which is exactly the
//! irregularity the σ/TilePrefix machinery (Algorithm 4) elides — so the
//! per-shard planner exercises the paper's empty-task path on every step.
//!
//! Two [`PlacementKind`]s are built in (the GEM-style knob):
//!
//! * [`PlacementKind::Static`] — round-robin, expert `e` on shard `e % ep`.
//! * [`PlacementKind::Balanced`] — a decayed per-expert load histogram (the
//!   same counts [`crate::coordinator::metrics::Metrics`] accumulates as
//!   `expert_rows`) drives an LPT re-shard whenever the observed device
//!   imbalance crosses a threshold.  A re-shard takes effect from the
//!   *next* step — each step executes under the placement chosen from past
//!   load only, with no lookahead into the batch being served.
//!   Re-sharding changes per-shard load signatures, so it deliberately
//!   costs plan-cache misses — the migration cost load-aware placement
//!   systems pay.
//!
//! Numerics (when `numeric` is on) run per shard on
//! [`CpuBackend`](crate::exec::CpuBackend) and the shard outputs are summed
//! — the serving analog of the EP combine.  With `top_k == 1` each output
//! row has exactly one expert contribution, so sharded outputs are
//! bitwise-identical to the single-shard executor's (the integration test
//! pins this); with `top_k > 1` the combine order differs, which can move
//! outputs by float-addition reordering noise.  With `tp > 1` each lane
//! computes the leading `d_ff / tp` output columns (one TP rank's slice) and
//! the all-reduce is charged in time only.
//!
//! Fault injection: [`StepExecutor::apply_fault`] is implemented here.  A
//! [`FaultKind::Slow`] scales one shard's simulated kernel time (and repels
//! the balanced LPT, which weighs per-shard *finishing time*); a
//! [`FaultKind::Kill`] marks the shard dead and forcibly evacuates its
//! experts (a re-shard, under either placement policy — correctness, not
//! policy); [`FaultKind::Recover`] restores nominal speed and liveness.
//! Because every lane holds the full expert weight tensor, evacuation only
//! re-masks token indices — numerics are unaffected.

use crate::coordinator::metrics::ShardingStats;
use crate::exec::{
    Backend, CpuBackend, ExecContext, ExecError, ExecutionSession, NumericInputs, SimBackend,
};
use crate::moe::config::MoeShape;
use crate::moe::parallel::ParallelConfig;
use crate::moe::plan_cache::CacheStats;
use crate::moe::routing::ExpertLoad;
use crate::moe::token_index::TokenIndex;
use crate::serve::scenario::{FaultEvent, FaultKind};
use crate::serve::sim_exec::{
    argmax_row, embed_tokens, expert_weights, route_topk, synthetic_argmax, SimServeConfig,
};
use crate::serve::{StepExecutor, StepInput, StepOutput};
use crate::sim::specs::GpuSpec;
use crate::util::tensor::Tensor;

/// Which expert→shard placement policy the sharded executor runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementKind {
    /// Fixed round-robin: expert `e` lives on shard `e % ep` forever.
    Static,
    /// Load-aware: re-shard (LPT greedy over a decayed per-expert load
    /// histogram) when observed device imbalance crosses the threshold.
    Balanced,
}

impl PlacementKind {
    /// Parse a CLI name (`static` | `balanced`).
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "static" => Some(PlacementKind::Static),
            "balanced" => Some(PlacementKind::Balanced),
            _ => None,
        }
    }

    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            PlacementKind::Static => "static",
            PlacementKind::Balanced => "balanced",
        }
    }
}

/// Configuration of the sharded serving executor: the single-lane
/// [`SimServeConfig`] plus the parallel grid and placement knobs.
#[derive(Clone, Debug)]
pub struct ShardedServeConfig {
    /// Per-lane problem shape and serving knobs, shared with the
    /// single-shard executor (same route, same embedding, same weights).
    pub base: SimServeConfig,
    /// Expert-parallel ways (shard lanes).
    pub ep: usize,
    /// Tensor-parallel ways; must divide `base.d_ff`.
    pub tp: usize,
    /// Expert→shard placement policy.
    pub placement: PlacementKind,
    /// Re-shard when the decayed device-load imbalance (max/mean across
    /// shards) exceeds this; only the balanced placement acts on it.
    pub rebalance_threshold: f64,
    /// Per-step decay of the expert-load histogram, in `[0, 1)`; 0 reacts
    /// to the last step only, values near 1 average long horizons.
    pub decay: f64,
    /// Interconnect model (EP all-to-all, TP all-reduce).
    pub link_gbps: f64,
    /// Per-collective base latency, microseconds.
    pub coll_latency_us: f64,
    /// GPU spec each shard's kernel time is simulated on.
    pub gpu: GpuSpec,
    /// Circuit breaker: consecutive shard-attributed transient failures
    /// before the shard is quarantined (evacuated like a `Kill`).
    pub breaker_threshold: u32,
    /// Circuit breaker: successful steps a quarantined shard waits before
    /// a half-open probe restores it to placement for one trial step.
    pub breaker_probe_after: u64,
}

impl Default for ShardedServeConfig {
    fn default() -> Self {
        ShardedServeConfig {
            base: SimServeConfig::default(),
            ep: 2,
            tp: 1,
            placement: PlacementKind::Static,
            rebalance_threshold: 1.25,
            decay: 0.5,
            link_gbps: 200.0,
            coll_latency_us: 10.0,
            gpu: GpuSpec::h800(),
            breaker_threshold: 3,
            breaker_probe_after: 8,
        }
    }
}

/// Longest-processing-time greedy over heterogeneous shards: heaviest
/// expert first onto the shard where it *finishes* earliest, i.e. the one
/// minimizing `(shard load + expert load) / rate`.  A shard with rate `<= 0`
/// (dead) is excluded; with all rates equal this reduces to the classic
/// least-loaded rule.  Ties break toward the lower expert / shard index, so
/// the assignment is deterministic.  An all-zero histogram (no load observed
/// yet) falls back to round-robin over the live shards — the greedy would
/// otherwise pile every expert onto the first live shard.
fn lpt_assignment(hist: &[f64], rates: &[f64]) -> Vec<usize> {
    let live: Vec<usize> = (0..rates.len()).filter(|&s| rates[s] > 0.0).collect();
    assert!(!live.is_empty(), "at least one live shard");
    if hist.iter().sum::<f64>() <= 0.0 {
        return (0..hist.len()).map(|e| live[e % live.len()]).collect();
    }
    let mut order: Vec<usize> = (0..hist.len()).collect();
    order.sort_by(|&a, &b| {
        hist[b]
            .partial_cmp(&hist[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut load = vec![0.0f64; rates.len()];
    let mut assign = vec![0usize; hist.len()];
    for e in order {
        let mut best = live[0];
        for &s in &live[1..] {
            if (load[s] + hist[e]) / rates[s] < (load[best] + hist[e]) / rates[best] {
                best = s;
            }
        }
        assign[e] = best;
        load[best] += hist[e];
    }
    assign
}

/// The expert→shard placement state: current assignment plus the decayed
/// load histogram the balanced policy re-shards from, plus the fault state
/// (per-shard relative speed and liveness) injected via
/// [`StepExecutor::apply_fault`].
struct Placement {
    kind: PlacementKind,
    ep: usize,
    assign: Vec<usize>,
    hist: Vec<f64>,
    decay: f64,
    threshold: f64,
    reshards: u64,
    /// Relative throughput per shard: 1.0 nominal, `1/factor` while slowed.
    speed: Vec<f64>,
    /// Liveness per shard: a dead shard owns no experts and costs no time.
    live: Vec<bool>,
}

impl Placement {
    fn new(kind: PlacementKind, experts: usize, ep: usize, decay: f64, threshold: f64) -> Self {
        Placement {
            kind,
            ep,
            assign: (0..experts).map(|e| e % ep).collect(),
            hist: vec![0.0; experts],
            decay,
            threshold,
            reshards: 0,
            speed: vec![1.0; ep],
            live: vec![true; ep],
        }
    }

    /// Effective placement rate per shard: speed while live, zero when dead
    /// (which excludes the shard from the LPT entirely).
    fn rates(&self) -> Vec<f64> {
        (0..self.ep).map(|s| if self.live[s] { self.speed[s] } else { 0.0 }).collect()
    }

    /// Device-load imbalance of the decayed histogram under the current
    /// assignment: max over live shards / mean over live shards, with each
    /// shard's load scaled by its speed (a slowed shard looks proportionally
    /// hotter).  Idle live shards count — that is the whole point.
    fn imbalance(&self) -> f64 {
        let mut time = vec![0.0f64; self.ep];
        for (e, &s) in self.assign.iter().enumerate() {
            time[s] += self.hist[e];
        }
        for (t, sp) in time.iter_mut().zip(&self.speed) {
            *t /= sp.max(1e-6);
        }
        let live: Vec<f64> = (0..self.ep).filter(|&s| self.live[s]).map(|s| time[s]).collect();
        let total: f64 = live.iter().sum();
        if total <= 0.0 || live.is_empty() {
            return 1.0;
        }
        let max = live.iter().cloned().fold(0.0, f64::max);
        max * live.len() as f64 / total
    }

    /// Fold this step's routed counts into the histogram; the balanced
    /// policy re-shards if the observed imbalance crosses the threshold.
    fn observe(&mut self, counts: &[usize]) {
        for (h, &c) in self.hist.iter_mut().zip(counts) {
            *h = *h * self.decay + c as f64;
        }
        if self.kind == PlacementKind::Balanced && self.imbalance() > self.threshold {
            let next = lpt_assignment(&self.hist, &self.rates());
            if next != self.assign {
                self.assign = next;
                self.reshards += 1;
            }
        }
    }

    /// Set one shard's relative speed (clamped away from zero).
    fn set_speed(&mut self, shard: usize, speed: f64) {
        self.speed[shard] = speed.max(1e-6);
    }

    /// Mark a shard dead and forcibly evacuate its experts via LPT over the
    /// remaining live shards.  This is a correctness move, not a policy one,
    /// so it runs under *either* placement kind and counts as a re-shard.
    /// Killing the last live shard is refused (the event is ignored).
    fn kill(&mut self, shard: usize) {
        if !self.live[shard] {
            return;
        }
        if self.live.iter().filter(|&&l| l).count() <= 1 {
            return;
        }
        self.live[shard] = false;
        let next = lpt_assignment(&self.hist, &self.rates());
        if next != self.assign {
            self.assign = next;
            self.reshards += 1;
        }
    }

    /// Restore a shard to live at nominal speed.  Experts are not moved
    /// back eagerly: the balanced policy re-LPTs as soon as the recovered
    /// (idle) shard pushes imbalance past the threshold; a static placement
    /// keeps the evacuated assignment.
    fn revive(&mut self, shard: usize) {
        self.live[shard] = true;
        self.speed[shard] = 1.0;
    }

    /// Revive a shard AND forcibly re-LPT so it receives experts again
    /// immediately — the half-open probe needs the very next step to
    /// exercise the shard, not wait for imbalance to drift.  Counts as a
    /// re-shard when experts move.
    fn restore(&mut self, shard: usize) {
        self.revive(shard);
        let next = lpt_assignment(&self.hist, &self.rates());
        if next != self.assign {
            self.assign = next;
            self.reshards += 1;
        }
    }
}

/// Per-shard circuit-breaker state.  Closed → (threshold consecutive
/// transient failures) → Open (quarantined: evacuated from placement) →
/// (probe window of successful steps) → HalfOpen (restored for one trial
/// step) → Closed on success, back to Open on another failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BreakerState {
    Closed,
    Open { since_step: u64 },
    HalfOpen,
}

#[derive(Clone, Copy, Debug)]
struct Breaker {
    state: BreakerState,
    /// Consecutive shard-attributed transient failures while closed.
    consecutive: u32,
}

impl Default for Breaker {
    fn default() -> Self {
        Breaker { state: BreakerState::Closed, consecutive: 0 }
    }
}

/// The expert-parallel sharded [`StepExecutor`].  See module docs.
pub struct ShardedStepExecutor {
    cfg: ShardedServeConfig,
    /// Per-shard problem shape: full expert space, `d_ff / tp` columns.
    shard_shape: MoeShape,
    parallel: ParallelConfig,
    placement: Placement,
    /// One session (planner + plan-cache lane + backend) per EP shard.  In
    /// numeric mode each lane holds its `[experts, d_model, d_ff / tp]`
    /// weight slice from construction; only activations and routing are
    /// replaced per step.
    lanes: Vec<ExecutionSession>,
    stats: ShardingStats,
    steps: u64,
    /// One circuit breaker per EP shard, fed by
    /// [`StepExecutor::observe_error`].
    breakers: Vec<Breaker>,
}

impl ShardedStepExecutor {
    /// Build the shard lanes.  Panics on inconsistent configuration
    /// (no buckets, `top_k` out of range, `tp` not dividing `d_ff`).
    pub fn new(cfg: ShardedServeConfig) -> Self {
        assert!(cfg.ep >= 1 && cfg.tp >= 1, "ep and tp must be at least 1");
        assert!(!cfg.base.buckets.is_empty(), "at least one bucket");
        assert!(
            cfg.base.top_k >= 1 && cfg.base.top_k <= cfg.base.experts,
            "1 <= top_k <= experts"
        );
        assert!(cfg.base.d_ff % cfg.tp == 0, "tp must divide d_ff");
        assert!((0.0..1.0).contains(&cfg.decay), "decay must be in [0, 1)");
        let shard_shape = MoeShape {
            seq: cfg.base.max_tokens,
            d_model: cfg.base.d_model,
            d_ff: cfg.base.d_ff / cfg.tp,
            experts: cfg.base.experts,
            top_k: cfg.base.top_k,
            dtype_bytes: 4,
        };
        let b = &cfg.base;
        let full = expert_weights(b.experts, b.d_model, b.d_ff, b.seed);
        let weights = if cfg.tp == 1 {
            full
        } else {
            slice_columns(&full, b.experts, b.d_model, b.d_ff, shard_shape.d_ff)
        };
        // one worker pool shared by every lane (lanes execute one at a
        // time, so per-lane pools would just multiply idle threads)
        let pool = (cfg.base.threads > 1).then(|| {
            std::sync::Arc::new(crate::util::threadpool::ThreadPool::new(cfg.base.threads))
        });
        let lanes = (0..cfg.ep)
            .map(|_| {
                let mut session = ExecutionSession::new(shard_shape)
                    .gpu(cfg.gpu.clone())
                    .plan_cache(cfg.base.cache_capacity);
                if let Some(pool) = &pool {
                    session = session.thread_pool(std::sync::Arc::clone(pool));
                }
                if cfg.base.numeric {
                    // each lane holds its weight slice from construction
                    // (the serving analog of device-resident parameters);
                    // only activations/routing are replaced per step
                    session = session.backend(CpuBackend).inputs(NumericInputs {
                        tokens: Tensor::zeros(&[shard_shape.seq, shard_shape.d_model]),
                        weights: weights.clone(),
                        token_index: TokenIndex {
                            index: vec![Vec::new(); cfg.base.experts],
                        },
                        gates: vec![Vec::new(); cfg.base.experts],
                    });
                }
                session
            })
            .collect();
        let placement = Placement::new(
            cfg.placement,
            cfg.base.experts,
            cfg.ep,
            cfg.decay,
            cfg.rebalance_threshold,
        );
        let stats = ShardingStats {
            ep: cfg.ep,
            tp: cfg.tp,
            busy_s: vec![0.0; cfg.ep],
            shard_cache: vec![CacheStats::default(); cfg.ep],
            ..ShardingStats::default()
        };
        let parallel = ParallelConfig {
            ep: cfg.ep,
            tp: cfg.tp,
            link_gbps: cfg.link_gbps,
            coll_latency_us: cfg.coll_latency_us,
        };
        let breakers = vec![Breaker::default(); cfg.ep];
        ShardedStepExecutor { cfg, shard_shape, parallel, placement, lanes, stats, steps: 0, breakers }
    }

    /// Steps executed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// The cumulative multi-shard accounting (also mirrored into the
    /// server's metrics via [`StepExecutor::sharding`]).
    pub fn stats(&self) -> &ShardingStats {
        &self.stats
    }

    /// The current expert→shard assignment.
    pub fn assignment(&self) -> &[usize] {
        &self.placement.assign
    }

    /// The configured placement policy.
    pub fn placement_kind(&self) -> PlacementKind {
        self.cfg.placement
    }

    /// Per-shard liveness: `false` while a shard is killed.
    pub fn live(&self) -> &[bool] {
        &self.placement.live
    }

    /// Per-shard relative speed: 1.0 nominal, `1/factor` while slowed.
    pub fn speeds(&self) -> &[f64] {
        &self.placement.speed
    }

    /// Cumulative re-shard count (includes forced kill evacuations).
    pub fn reshards(&self) -> u64 {
        self.placement.reshards
    }

    /// Per-shard breaker engagement: `true` while a shard's breaker is
    /// open (quarantined) or half-open (probing).
    pub fn breaker_engaged(&self) -> Vec<bool> {
        self.breakers.iter().map(|b| b.state != BreakerState::Closed).collect()
    }

    /// Breaker bookkeeping after a successful step: a step that completed
    /// with a half-open shard in placement is a passed probe (close the
    /// breaker), a closed shard's consecutive-failure count resets, and a
    /// quarantined shard whose probe window has elapsed is restored to
    /// placement half-open — the *next* step exercises it.
    fn breakers_on_success(&mut self) {
        let degraded = self.breakers.iter().any(|b| b.state != BreakerState::Closed)
            || self.placement.live.iter().any(|&l| !l);
        if degraded {
            self.stats.degraded_steps += 1;
        }
        for shard in 0..self.cfg.ep {
            match self.breakers[shard].state {
                BreakerState::Closed => self.breakers[shard].consecutive = 0,
                BreakerState::HalfOpen => {
                    self.breakers[shard] = Breaker::default();
                }
                BreakerState::Open { since_step } => {
                    if self.steps.saturating_sub(since_step) >= self.cfg.breaker_probe_after {
                        self.stats.breaker_probes += 1;
                        self.placement.restore(shard);
                        self.stats.reshards = self.placement.reshards;
                        self.breakers[shard].state = BreakerState::HalfOpen;
                    }
                }
            }
        }
    }
}

/// Keep the leading `keep` of `d_ff` columns of every `[d_model, d_ff]`
/// expert plane (one TP rank's weight slice).
fn slice_columns(
    full: &Tensor,
    experts: usize,
    d_model: usize,
    d_ff: usize,
    keep: usize,
) -> Tensor {
    let mut data = Vec::with_capacity(experts * d_model * keep);
    for e in 0..experts {
        let plane = full.plane(e);
        for k in 0..d_model {
            data.extend_from_slice(&plane[k * d_ff..k * d_ff + keep]);
        }
    }
    Tensor::from_vec(&[experts, d_model, keep], data)
}

impl StepExecutor for ShardedStepExecutor {
    fn name(&self) -> &'static str {
        if self.cfg.base.numeric {
            "serve/sharded+cpu"
        } else {
            "serve/sharded"
        }
    }

    fn buckets(&self) -> Vec<usize> {
        self.cfg.base.buckets.clone()
    }

    fn max_step_tokens(&self) -> Option<usize> {
        Some(self.shard_shape.seq)
    }

    fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
        let total = step.rows * step.bucket;
        if total > self.shard_shape.seq {
            return Err(ExecError::PlanMismatch {
                backend: self.name(),
                detail: format!(
                    "batch of {total} tokens exceeds the shard capacity of {}",
                    self.shard_shape.seq
                ),
            });
        }
        debug_assert_eq!(step.tokens.len(), total);
        // one global route; the placement decides who owns each expert
        let (token_index, load) =
            route_topk(step.tokens, self.cfg.base.experts, self.cfg.base.top_k);
        // This step executes under the placement chosen from PAST load
        // only; observing this step's counts (and any re-shard it
        // triggers) takes effect from the next step — a real placement
        // system has no lookahead into the batch it is about to serve.
        let assign = self.placement.assign.clone();
        self.placement.observe(&load.counts);

        let embedded = self.cfg.base.numeric.then(|| {
            embed_tokens(
                step.tokens,
                self.shard_shape.seq,
                self.shard_shape.d_model,
                self.cfg.base.seed,
            )
        });
        let gate = 1.0 / self.cfg.base.top_k as f32;

        let mut kernel_s = vec![0.0f64; self.cfg.ep];
        let mut max_rows_in = 0usize;
        let mut combined: Option<Tensor> = None;
        let mut sim = SimBackend::ours();
        for shard in 0..self.cfg.ep {
            if !self.placement.live[shard] {
                // a killed shard was evacuated when the fault applied, so
                // it owns no experts; skip it outright for belt and braces
                continue;
            }
            // The shard's sub-problem: the full expert space masked to the
            // experts it owns.  Unowned experts are empty tasks — the
            // σ/TilePrefix machinery elides them per shard.
            let index: Vec<Vec<u32>> = token_index
                .index
                .iter()
                .enumerate()
                .map(|(e, rows)| if assign[e] == shard { rows.clone() } else { Vec::new() })
                .collect();
            let local = TokenIndex { index };
            let counts = local.counts();
            let rows_in: usize = counts.iter().sum();
            max_rows_in = max_rows_in.max(rows_in);
            if rows_in == 0 {
                continue;
            }
            let local_load = ExpertLoad { counts };
            let session = &mut self.lanes[shard];
            let plan = session.plan_shared(&local_load);
            // shard kernel time always comes from the accounting simulator
            // on the very plan the lane executes; host-side launch overhead
            // is excluded — it is paid per GPU, not a device-load signal
            let timing = sim.execute(plan.as_ref(), &mut ExecContext::new(self.cfg.gpu.clone()))?;
            let r = timing.sim();
            // a slowed shard stretches its kernel by the injected factor
            kernel_s[shard] = (r.time_s - r.host_time_s).max(0.0) / self.placement.speed[shard];
            if let Some(embedded) = &embedded {
                let gates: Vec<Vec<f32>> =
                    local.index.iter().map(|rows| vec![gate; rows.len()]).collect();
                // in-place input update: the lane's weights stay resident,
                // only activations and routing change per step
                let inputs = session.inputs_mut().expect("numeric lanes hold inputs");
                inputs.tokens = embedded.clone();
                inputs.token_index = local;
                inputs.gates = gates;
                let out = session.run_plan(&plan)?;
                let t = out.output.expect("cpu backend returns a tensor");
                combined = Some(match combined.take() {
                    None => t,
                    Some(mut acc) => {
                        // EP combine: shard partials sum per row
                        for (a, b) in acc.data.iter_mut().zip(&t.data) {
                            *a += b;
                        }
                        acc
                    }
                });
            }
        }

        let a2a = self.parallel.all_to_all_time_s(
            max_rows_in,
            self.shard_shape.d_model,
            self.shard_shape.dtype_bytes,
        );
        let ar = self.parallel.all_reduce_time_s(
            total,
            self.shard_shape.d_model,
            self.shard_shape.dtype_bytes,
        );
        let critical = kernel_s.iter().cloned().fold(0.0, f64::max);
        let mean = kernel_s.iter().sum::<f64>() / self.cfg.ep as f64;

        self.stats.steps += 1;
        for (b, k) in self.stats.busy_s.iter_mut().zip(&kernel_s) {
            *b += k;
        }
        self.stats.critical_s += critical;
        self.stats.collective_s += a2a + ar;
        self.stats.step_s += critical + a2a + ar;
        if mean > 0.0 {
            self.stats.imbalance_sum += critical / mean;
        }
        self.stats.reshards = self.placement.reshards;
        for (c, lane) in self.stats.shard_cache.iter_mut().zip(&self.lanes) {
            *c = lane.cache_stats().unwrap_or_default();
        }

        let argmax = match &combined {
            Some(t) => (0..total).map(|r| argmax_row(t.row(r))).collect(),
            None => step.tokens.iter().map(|&v| synthetic_argmax(v)).collect(),
        };
        self.steps += 1;
        self.breakers_on_success();
        Ok(StepOutput {
            argmax,
            expert_rows: load.counts.iter().map(|&c| c as i32).collect(),
            failed: Vec::new(),
            sim_time_s: Some(critical + a2a + ar),
        })
    }

    fn apply_fault(&mut self, event: &FaultEvent) {
        if event.shard >= self.cfg.ep {
            return;
        }
        match event.kind {
            FaultKind::Slow { factor } => {
                self.placement.set_speed(event.shard, 1.0 / factor.max(1e-6));
            }
            FaultKind::Kill => self.placement.kill(event.shard),
            FaultKind::Recover => self.placement.revive(event.shard),
        }
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        let mut agg = CacheStats::default();
        for lane in &self.lanes {
            if let Some(s) = lane.cache_stats() {
                agg.hits += s.hits;
                agg.misses += s.misses;
                agg.entries += s.entries;
            }
        }
        Some(agg)
    }

    fn sharding(&self) -> Option<ShardingStats> {
        Some(self.stats.clone())
    }

    /// Feed shard-attributed transient failures into the per-shard circuit
    /// breakers: `breaker_threshold` consecutive failures quarantine the
    /// shard (evacuation + forced re-shard, reusing the `Kill` machinery);
    /// a failure during a half-open probe re-quarantines it for another
    /// window.  Permanent and unattributed errors never move a breaker.
    fn observe_error(&mut self, err: &ExecError) {
        if !err.is_transient() {
            return;
        }
        let Some(shard) = err.shard() else { return };
        if shard >= self.cfg.ep {
            return;
        }
        match self.breakers[shard].state {
            BreakerState::Closed => {
                let b = &mut self.breakers[shard];
                b.consecutive = b.consecutive.saturating_add(1);
                if b.consecutive >= self.cfg.breaker_threshold {
                    let was_live = self.placement.live[shard];
                    self.placement.kill(shard);
                    // the kill can be refused (last live shard): only a
                    // real evacuation counts as a trip
                    if was_live && !self.placement.live[shard] {
                        self.stats.breaker_trips += 1;
                        self.stats.reshards = self.placement.reshards;
                        self.breakers[shard] =
                            Breaker { state: BreakerState::Open { since_step: self.steps }, consecutive: 0 };
                    }
                }
            }
            BreakerState::HalfOpen => {
                // failed probe: back into quarantine for another window
                self.placement.kill(shard);
                self.stats.reshards = self.placement.reshards;
                self.breakers[shard].state = BreakerState::Open { since_step: self.steps };
            }
            BreakerState::Open { .. } => {}
        }
    }

    /// A shard participates in the next step iff it is live and the
    /// current placement assigns it at least one expert — the signal fault
    /// injectors use to stop erroring once evacuation lands.
    fn shard_in_use(&self, shard: usize) -> bool {
        shard < self.cfg.ep
            && self.placement.live[shard]
            && self.placement.assign.contains(&shard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn base(numeric: bool, top_k: usize) -> SimServeConfig {
        SimServeConfig {
            buckets: vec![8, 16],
            max_tokens: 128,
            experts: 8,
            top_k,
            d_model: 8,
            d_ff: 12,
            cache_capacity: 8,
            numeric,
            threads: 1,
            seed: 3,
        }
    }

    fn step_tokens(bucket: usize, rows: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..rows * bucket).map(|_| rng.below(50) as i32).collect()
    }

    #[test]
    fn lpt_balances_a_skewed_histogram() {
        let hist = vec![8.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let assign = lpt_assignment(&hist, &[1.0, 1.0]);
        let s0: f64 = hist.iter().zip(&assign).filter(|(_, &s)| s == 0).map(|(h, _)| h).sum();
        let s1: f64 = hist.iter().zip(&assign).filter(|(_, &s)| s == 1).map(|(h, _)| h).sum();
        // the hot expert sits alone; everything else lands opposite it
        assert_eq!(s0.max(s1), 8.0);
        assert_eq!(s0.min(s1), 7.0);
    }

    #[test]
    fn static_placement_never_reshards() {
        let mut p = Placement::new(PlacementKind::Static, 8, 4, 0.5, 1.01);
        let before = p.assign.clone();
        for _ in 0..10 {
            p.observe(&[40, 1, 1, 1, 1, 1, 1, 1]);
        }
        assert_eq!(p.assign, before);
        assert_eq!(p.reshards, 0);
        assert!(p.imbalance() > 1.01, "skew observed: {}", p.imbalance());
    }

    #[test]
    fn balanced_placement_reshards_past_threshold() {
        let mut p = Placement::new(PlacementKind::Balanced, 8, 4, 0.5, 1.1);
        p.observe(&[40, 1, 1, 1, 1, 1, 1, 1]);
        assert_eq!(p.reshards, 1);
        // the hot expert must sit alone on its shard
        let hot = p.assign[0];
        assert!(p.assign[1..].iter().all(|&s| s != hot), "{:?}", p.assign);
    }

    #[test]
    fn kill_evacuates_the_dead_shard_and_counts_a_reshard() {
        let mut p = Placement::new(PlacementKind::Static, 8, 4, 0.5, 10.0);
        p.observe(&[1; 8]);
        assert_eq!(p.reshards, 0, "static placement never reshards on load");
        p.kill(1);
        assert_eq!(p.reshards, 1, "evacuation is a forced reshard");
        assert!(!p.live[1]);
        assert!(p.assign.iter().all(|&s| s != 1), "{:?}", p.assign);
        p.revive(1);
        assert!(p.live[1]);
        // revival alone does not move experts back under static placement
        assert!(p.assign.iter().all(|&s| s != 1), "{:?}", p.assign);
    }

    #[test]
    fn killing_the_last_live_shard_is_refused() {
        let mut p = Placement::new(PlacementKind::Static, 4, 2, 0.5, 10.0);
        p.kill(0);
        p.kill(1);
        assert!(p.live[1], "the last live shard must survive");
        assert!(p.assign.iter().all(|&s| s == 1), "{:?}", p.assign);
    }

    #[test]
    fn slowed_shard_repels_the_balanced_lpt() {
        let mut p = Placement::new(PlacementKind::Balanced, 8, 4, 0.5, 1.5);
        p.set_speed(0, 0.02); // 50x slower
        p.observe(&[1; 8]);
        assert_eq!(p.reshards, 1, "speed-scaled imbalance crosses the threshold");
        assert!(p.assign.iter().all(|&s| s != 0), "{:?}", p.assign);
    }

    #[test]
    fn executor_fault_kill_moves_experts_and_keeps_serving() {
        let mut ex = ShardedStepExecutor::new(ShardedServeConfig {
            base: base(false, 1),
            ep: 4,
            ..ShardedServeConfig::default()
        });
        let tokens = step_tokens(16, 4, 2);
        let s = StepInput { bucket: 16, rows: 4, tokens: &tokens };
        let before = ex.execute_step(&s).expect("pre-fault step");
        assert!(before.sim_time_s.expect("sharded steps report sim time") > 0.0);
        ex.apply_fault(&FaultEvent { at_s: 0.0, shard: 1, kind: FaultKind::Kill });
        assert!(!ex.live()[1]);
        assert_eq!(ex.reshards(), 1);
        assert!(ex.assignment().iter().all(|&sh| sh != 1));
        let after = ex.execute_step(&s).expect("post-fault step");
        assert_eq!(after.argmax, before.argmax, "accounting argmax ignores placement");
        ex.apply_fault(&FaultEvent { at_s: 0.0, shard: 1, kind: FaultKind::Recover });
        assert!(ex.live()[1]);
        assert!((ex.speeds()[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn accounting_step_produces_synthetic_argmax_and_stats() {
        let cfg = ShardedServeConfig {
            base: base(false, 2),
            ep: 4,
            ..ShardedServeConfig::default()
        };
        let mut ex = ShardedStepExecutor::new(cfg);
        let tokens = step_tokens(16, 4, 2);
        let out = ex
            .execute_step(&StepInput { bucket: 16, rows: 4, tokens: &tokens })
            .expect("sharded step");
        assert_eq!(out.argmax.len(), 64);
        assert_eq!(
            out.argmax,
            tokens.iter().map(|&v| synthetic_argmax(v)).collect::<Vec<_>>()
        );
        assert_eq!(out.expert_rows.iter().sum::<i32>(), 64 * 2);
        let s = ex.stats();
        assert_eq!(s.steps, 1);
        assert_eq!(s.busy_s.len(), 4);
        assert!(s.critical_s > 0.0);
        assert!(s.imbalance_ratio() >= 1.0);
        // ep > 1 pays all-to-all on every step
        assert!(s.collective_s > 0.0);
        assert_eq!(ex.steps(), 1);
    }

    #[test]
    fn repeated_steps_hit_per_shard_plan_caches() {
        let cfg = ShardedServeConfig {
            base: base(false, 2),
            ep: 2,
            ..ShardedServeConfig::default()
        };
        let mut ex = ShardedStepExecutor::new(cfg);
        let tokens = step_tokens(8, 3, 5);
        let s = StepInput { bucket: 8, rows: 3, tokens: &tokens };
        ex.execute_step(&s).expect("step 1");
        ex.execute_step(&s).expect("step 2");
        let agg = ex.cache_stats().expect("lanes cache plans");
        // each busy lane misses once then hits once
        assert_eq!(agg.hits, agg.misses);
        assert!(agg.hits > 0);
        let sh = ex.sharding().expect("sharded executor reports stats");
        assert_eq!(sh.shard_cache.len(), 2);
        assert_eq!(
            sh.shard_cache.iter().map(|c| c.hits + c.misses).sum::<u64>(),
            agg.hits + agg.misses
        );
    }

    #[test]
    fn tp_shrinks_columns_and_charges_allreduce() {
        let cfg = ShardedServeConfig {
            base: base(true, 2),
            ep: 1,
            tp: 2,
            ..ShardedServeConfig::default()
        };
        let mut ex = ShardedStepExecutor::new(cfg);
        assert_eq!(ex.shard_shape.d_ff, 6);
        let lane_weights_shape = ex.lanes[0]
            .inputs_mut()
            .expect("numeric lane holds inputs")
            .weights
            .shape
            .clone();
        assert_eq!(lane_weights_shape, vec![8, 8, 6]);
        let tokens = step_tokens(8, 2, 7);
        let out = ex
            .execute_step(&StepInput { bucket: 8, rows: 2, tokens: &tokens })
            .expect("tp step");
        // argmax over the local d_ff/tp slice
        assert!(out.argmax.iter().all(|&a| (0..6).contains(&a)));
        let s = ex.stats();
        assert!(s.collective_s > 0.0, "tp=2 must pay an all-reduce");
        // ep=1: no all-to-all, so the whole collective cost is the all-reduce
        assert_eq!(s.busy_s.len(), 1);
    }

    #[test]
    fn weight_slice_keeps_leading_columns() {
        let full = expert_weights(2, 3, 4, 9);
        let sliced = slice_columns(&full, 2, 3, 4, 2);
        for e in 0..2 {
            for k in 0..3 {
                for j in 0..2 {
                    assert_eq!(
                        sliced.plane(e)[k * 2 + j],
                        full.plane(e)[k * 4 + j],
                        "e={e} k={k} j={j}"
                    );
                }
            }
        }
    }

    fn down(shard: usize) -> ExecError {
        ExecError::ShardDown { backend: "chaos", shard, detail: "injected".into() }
    }

    fn breaker_exec(threshold: u32, probe_after: u64) -> ShardedStepExecutor {
        ShardedStepExecutor::new(ShardedServeConfig {
            base: base(false, 1),
            ep: 4,
            breaker_threshold: threshold,
            breaker_probe_after: probe_after,
            ..ShardedServeConfig::default()
        })
    }

    #[test]
    fn breaker_trips_after_consecutive_transient_failures() {
        let mut ex = breaker_exec(3, 8);
        ex.observe_error(&down(1));
        ex.observe_error(&down(1));
        assert!(ex.live()[1], "two failures stay under the threshold");
        assert_eq!(ex.stats().breaker_trips, 0);
        ex.observe_error(&down(1));
        assert!(!ex.live()[1], "third consecutive failure quarantines");
        assert!(ex.assignment().iter().all(|&s| s != 1), "evacuated: {:?}", ex.assignment());
        assert_eq!(ex.stats().breaker_trips, 1);
        assert_eq!(ex.reshards(), 1, "evacuation is a forced reshard");
        assert!(ex.breaker_engaged()[1]);
        assert!(!ex.shard_in_use(1));
    }

    #[test]
    fn successful_steps_reset_the_consecutive_failure_count() {
        let mut ex = breaker_exec(3, 8);
        let tokens = step_tokens(16, 4, 2);
        let s = StepInput { bucket: 16, rows: 4, tokens: &tokens };
        ex.observe_error(&down(2));
        ex.observe_error(&down(2));
        ex.execute_step(&s).expect("clean step");
        ex.observe_error(&down(2));
        ex.observe_error(&down(2));
        assert!(ex.live()[2], "non-consecutive failures never trip");
        assert_eq!(ex.stats().breaker_trips, 0);
    }

    #[test]
    fn probe_window_restores_the_shard_and_a_clean_probe_closes_the_breaker() {
        let mut ex = breaker_exec(1, 2);
        let tokens = step_tokens(16, 4, 2);
        let s = StepInput { bucket: 16, rows: 4, tokens: &tokens };
        ex.observe_error(&down(1));
        assert!(!ex.live()[1]);
        // two successful steps elapse the probe window...
        ex.execute_step(&s).expect("quarantined step 1");
        assert!(!ex.live()[1]);
        ex.execute_step(&s).expect("quarantined step 2");
        // ...issuing the half-open probe: live again AND holding experts
        assert_eq!(ex.stats().breaker_probes, 1);
        assert!(ex.live()[1]);
        assert!(ex.shard_in_use(1), "restore hands the probed shard experts back");
        assert!(ex.breaker_engaged()[1], "half-open until the probe step lands");
        // the probe step completes cleanly: breaker closes
        ex.execute_step(&s).expect("probe step");
        assert!(!ex.breaker_engaged()[1]);
        assert_eq!(ex.stats().degraded_steps, 3, "all three steps ran degraded");
        // later clean steps are not degraded
        ex.execute_step(&s).expect("healthy step");
        assert_eq!(ex.stats().degraded_steps, 3);
    }

    #[test]
    fn failed_probe_requarantines_for_another_window() {
        let mut ex = breaker_exec(1, 1);
        let tokens = step_tokens(16, 4, 2);
        let s = StepInput { bucket: 16, rows: 4, tokens: &tokens };
        ex.observe_error(&down(1));
        ex.execute_step(&s).expect("window step");
        assert_eq!(ex.stats().breaker_probes, 1);
        assert!(ex.live()[1], "half-open: restored for the trial");
        // the trial fails: straight back to quarantine, no threshold count
        ex.observe_error(&down(1));
        assert!(!ex.live()[1]);
        assert_eq!(ex.stats().breaker_trips, 1, "a failed probe is not a new trip");
        assert!(ex.breaker_engaged()[1]);
    }

    #[test]
    fn permanent_and_unattributed_errors_never_move_a_breaker() {
        let mut ex = breaker_exec(1, 8);
        for _ in 0..5 {
            // permanent: even shard-shaped detail must not trip anything
            ex.observe_error(&ExecError::backend("cpu", "worker pool failure"));
            // transient but unattributed: no shard to blame
            ex.observe_error(&ExecError::Timeout { backend: "sim", detail: "stall".into() });
            // out-of-range shard id: ignored
            ex.observe_error(&down(99));
        }
        assert!(ex.live().iter().all(|&l| l));
        assert_eq!(ex.stats().breaker_trips, 0);
        assert!(ex.breaker_engaged().iter().all(|&b| !b));
    }

    #[test]
    fn breaker_refuses_to_quarantine_the_last_live_shard() {
        let mut ex = ShardedStepExecutor::new(ShardedServeConfig {
            base: base(false, 1),
            ep: 2,
            breaker_threshold: 1,
            ..ShardedServeConfig::default()
        });
        ex.observe_error(&down(0));
        assert!(!ex.live()[0]);
        ex.observe_error(&down(1));
        assert!(ex.live()[1], "the last live shard must survive");
        assert_eq!(ex.stats().breaker_trips, 1, "refused kill is not a trip");
    }

    #[test]
    fn oversized_batch_is_a_typed_error() {
        let mut ex = ShardedStepExecutor::new(ShardedServeConfig {
            base: base(false, 2),
            ep: 2,
            ..ShardedServeConfig::default()
        });
        let tokens = vec![0; 10 * 16];
        let err = ex
            .execute_step(&StepInput { bucket: 16, rows: 10, tokens: &tokens })
            .unwrap_err();
        assert!(matches!(err, ExecError::PlanMismatch { .. }));
    }
}
