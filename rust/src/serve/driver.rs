//! Synthetic traffic driver for the serving core.
//!
//! Generates an open- or closed-loop request stream from a pool of
//! Zipf-valued prompts (popular queries repeat, like real serving traffic,
//! which is exactly what the plan cache exploits), submits it through a
//! cloned [`ServeHandle`](crate::serve::ServeHandle) from a producer
//! thread, runs the serving loop on
//! the calling thread, and reports latency percentiles, throughput, and
//! plan-cache behavior.  Shared by the `staticbatch serve-sim` subcommand,
//! the `serving` bench, and the load tests.

use std::time::{Duration, Instant};

use crate::coordinator::metrics::Snapshot;
use crate::moe::plan_cache::CacheStats;
use crate::serve::{Server, StepExecutor, Ticket};
use crate::util::rng::{zipf_weights, Rng};
use crate::util::stats::Samples;

/// Synthetic workload shape.
#[derive(Clone, Debug)]
pub struct TrafficConfig {
    /// Total requests to send.
    pub requests: usize,
    /// Open-loop arrival rate in requests/second; 0 = closed-loop burst
    /// (push as fast as admission allows).
    pub rate_hz: f64,
    /// Zipf exponent for token values *and* prompt popularity.
    pub zipf_alpha: f64,
    /// Token id range.
    pub vocab: usize,
    /// Distinct prompts in the pool (requests sample from these).
    pub distinct: usize,
    /// Prompt lengths, cycled over the pool (mixed-length traffic).
    pub lengths: Vec<usize>,
    /// Seed for prompt contents and popularity draws.
    pub seed: u64,
    /// Simulated-clock mode: the producer never sleeps (arrival times are
    /// virtual), and the reported `wall_s` becomes the virtual arrival
    /// horizon `requests / rate_hz` instead of elapsed wall time.  Makes
    /// rate-shaped runs deterministic and instant — benches and CI use it.
    pub sim_clock: bool,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            requests: 256,
            rate_hz: 0.0,
            zipf_alpha: 1.2,
            vocab: 1000,
            distinct: 8,
            lengths: vec![12, 48, 200],
            seed: 1,
            sim_clock: false,
        }
    }
}

/// What one traffic run produced.
#[derive(Clone, Debug)]
pub struct TrafficReport {
    /// Requests generated (admitted + rejected).
    pub sent: usize,
    /// Requests answered without error.
    pub ok: usize,
    /// Requests answered with an error (or never answered).
    pub failed: usize,
    /// Requests the bounded queue refused (backpressure).
    pub rejected: usize,
    /// Requests whose deadline passed before execution (a deadline shed,
    /// disjoint from `failed`).
    pub expired: usize,
    /// Wall-clock seconds of the serving loop (in sim-clock mode: the
    /// virtual arrival horizon, `requests / rate_hz`).
    pub wall_s: f64,
    /// Median end-to-end request latency, milliseconds.
    pub p50_ms: f64,
    /// 99th-percentile end-to-end request latency, milliseconds.
    pub p99_ms: f64,
    /// The executor's plan-cache counters at the end of the run.
    pub cache: Option<CacheStats>,
    /// The server's metrics snapshot at the end of the run.
    pub snapshot: Snapshot,
}

impl TrafficReport {
    /// Multi-line human summary (the serve-sim output).  Plan-cache
    /// hit/miss counters appear once, via the snapshot (the server mirrors
    /// the executor's cache stats into its metrics every loop iteration);
    /// the cache occupancy is the one field only [`CacheStats`] carries.
    pub fn render(&self) -> String {
        let mut s = format!(
            "sent={} ok={} failed={} rejected={} expired={}  wall={:.2}s ({:.1} req/s)\n\
             latency p50={:.3}ms p99={:.3}ms\n",
            self.sent,
            self.ok,
            self.failed,
            self.rejected,
            self.expired,
            self.wall_s,
            if self.wall_s > 0.0 { self.ok as f64 / self.wall_s } else { 0.0 },
            self.p50_ms,
            self.p99_ms,
        );
        s.push_str(&self.snapshot.render());
        s.push('\n');
        if let Some(c) = self.cache {
            s.push_str(&format!("plan cache entries: {}\n", c.entries));
        }
        s
    }
}

/// The prompt pool: `distinct` prompts with cycled lengths and
/// Zipf-distributed token values, plus Zipf popularity ranks so a few
/// prompts dominate the stream.
fn prompt_pool(cfg: &TrafficConfig, rng: &mut Rng) -> Vec<Vec<i32>> {
    let token_w = zipf_weights(cfg.vocab.max(2), cfg.zipf_alpha);
    (0..cfg.distinct.max(1))
        .map(|i| {
            let len = cfg.lengths[i % cfg.lengths.len()].max(1);
            (0..len).map(|_| rng.zipf(&token_w) as i32 + 1).collect()
        })
        .collect()
}

/// Drive `cfg` traffic through `server`: a producer thread submits through
/// a cloned handle, the serving loop runs on the calling thread until the
/// stream ends, then every ticket is collected.
pub fn run_traffic<E: StepExecutor>(server: &mut Server<E>, cfg: TrafficConfig) -> TrafficReport {
    let handle = server.handle();
    let cfg2 = cfg.clone();
    let producer = std::thread::spawn(move || {
        let mut rng = Rng::new(cfg2.seed);
        let pool = prompt_pool(&cfg2, &mut rng);
        let pop_w = zipf_weights(pool.len(), cfg2.zipf_alpha);
        let mut tickets: Vec<(usize, Ticket)> = Vec::new();
        let mut rejected = 0usize;
        let t0 = Instant::now();
        for i in 0..cfg2.requests {
            if cfg2.rate_hz > 0.0 && !cfg2.sim_clock {
                let due = t0 + Duration::from_secs_f64(i as f64 / cfg2.rate_hz);
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
            }
            let prompt = &pool[rng.zipf(&pop_w)];
            // open-loop: never block the arrival process; count sheds
            match handle.try_submit(prompt) {
                Ok(t) => tickets.push((prompt.len(), t)),
                Err(_) => rejected += 1,
            }
        }
        handle.close();
        (tickets, rejected)
    });

    let t0 = Instant::now();
    server.serve();
    let wall_s = if cfg.sim_clock && cfg.rate_hz > 0.0 {
        cfg.requests as f64 / cfg.rate_hz
    } else {
        t0.elapsed().as_secs_f64()
    };

    let (tickets, rejected) = producer.join().expect("producer thread");
    let sent = tickets.len() + rejected;
    let mut ok = 0usize;
    let mut failed = 0usize;
    let mut expired = 0usize;
    let mut lat = Samples::new();
    for (len, ticket) in tickets {
        // serve() has returned, so every admitted ticket is resolved:
        // wait() never blocks here
        let resp = ticket.wait();
        if resp.error.is_none() {
            debug_assert_eq!(resp.argmax.len(), len);
            lat.push(resp.latency_s * 1e3);
            ok += 1;
        } else if resp.expired {
            expired += 1;
        } else {
            failed += 1;
        }
    }
    debug_assert_eq!(ok + failed + expired + rejected, sent, "conservation");
    let (p50, p99) = if lat.is_empty() {
        (0.0, 0.0)
    } else {
        (lat.percentile(50.0), lat.percentile(99.0))
    };
    TrafficReport {
        sent,
        ok,
        failed,
        rejected,
        expired,
        wall_s,
        p50_ms: p50,
        p99_ms: p99,
        cache: server.executor().cache_stats(),
        snapshot: server.metrics().snapshot(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{ServerConfig, SimServeConfig, SimStepExecutor};

    #[test]
    fn burst_traffic_completes_and_reports() {
        let ex = SimStepExecutor::new(SimServeConfig {
            buckets: vec![16, 64, 256],
            max_tokens: 2048,
            numeric: false,
            ..SimServeConfig::default()
        });
        let mut server = Server::new(
            ServerConfig { queue_capacity: 512, ..ServerConfig::default() },
            ex,
        );
        let report = run_traffic(
            &mut server,
            TrafficConfig { requests: 48, ..TrafficConfig::default() },
        );
        assert_eq!(report.sent, 48);
        assert_eq!(report.ok + report.failed + report.rejected, 48);
        assert_eq!(report.failed, 0);
        assert_eq!(report.rejected, 0, "queue of 512 never fills on a 48-burst");
        let cache = report.cache.expect("sim executor has a plan cache");
        assert!(cache.hits + cache.misses > 0);
        assert!(report.render().contains("plan cache"));
    }

    #[test]
    fn sim_clock_skips_sleeps_and_reports_the_virtual_horizon() {
        let ex = SimStepExecutor::new(SimServeConfig {
            buckets: vec![16, 64, 256],
            max_tokens: 2048,
            numeric: false,
            ..SimServeConfig::default()
        });
        let mut server = Server::new(
            ServerConfig { queue_capacity: 512, ..ServerConfig::default() },
            ex,
        );
        // 48 requests at 2 req/s would sleep ~24 s of wall time without
        // sim_clock; the test finishing at all proves the sleeps are gone.
        let report = run_traffic(
            &mut server,
            TrafficConfig {
                requests: 48,
                rate_hz: 2.0,
                sim_clock: true,
                ..TrafficConfig::default()
            },
        );
        assert_eq!(report.sent, 48);
        assert_eq!(report.failed, 0);
        assert!((report.wall_s - 24.0).abs() < 1e-12, "virtual horizon, got {}", report.wall_s);
    }
}
