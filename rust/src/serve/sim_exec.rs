//! [`SimStepExecutor`]: the default-features MoE serving path.
//!
//! Each formed batch runs the full per-step pipeline the paper describes
//! for serving: a deterministic top-k route over the packed tokens, a plan
//! from the [`crate::moe::plan_cache::PlanCache`] (repeated load signatures
//! skip σ/TilePrefix reconstruction), and execution through one long-lived
//! [`ExecutionSession`] — [`crate::exec::CpuBackend`] for real numerics
//! (default) or the accounting [`crate::exec::SimBackend`] when only
//! scheduling behavior is under test.  No XLA, artifacts, or GPU anywhere,
//! so the whole request→queue→batch→plan→execute→respond pipeline is
//! exercised by `cargo test` and explorable via `staticbatch serve-sim`.

use crate::exec::{CpuBackend, ExecError, ExecutionSession, NumericInputs};
use crate::moe::config::MoeShape;
use crate::moe::plan_cache::CacheStats;
use crate::moe::routing::ExpertLoad;
use crate::moe::token_index::TokenIndex;
use crate::serve::{StepExecutor, StepInput, StepOutput};
use crate::util::rng::{Rng, SplitMix64};
use crate::util::tensor::Tensor;

/// Deterministic top-k route over packed token values: token `v` lands on
/// experts `(|v| + j * experts/top_k) mod experts` for `j in 0..top_k`, so
/// skewed token popularity (Zipf prompts) produces skewed expert load, and
/// equal token multisets produce equal load signatures — the property the
/// plan cache exploits.  Shared by [`SimStepExecutor`] and
/// [`crate::serve::ShardedStepExecutor`] so the sharded path routes exactly
/// like the single-shard path.
pub fn route_topk(tokens: &[i32], experts: usize, top_k: usize) -> (TokenIndex, ExpertLoad) {
    let mut pairs = Vec::with_capacity(tokens.len() * top_k);
    route_topk_into(tokens, experts, top_k, &mut pairs);
    let ti = TokenIndex::build(experts, &pairs);
    let load = ExpertLoad { counts: ti.counts() };
    (ti, load)
}

/// [`route_topk`]'s pair construction into a reusable buffer — the
/// zero-alloc per-step path ([`SimStepExecutor`] and the fused executor
/// keep one `pairs` buffer for the life of the server).
pub fn route_topk_into(tokens: &[i32], experts: usize, top_k: usize, pairs: &mut Vec<(u32, u32)>) {
    let stride = (experts / top_k).max(1);
    pairs.clear();
    pairs.reserve(tokens.len() * top_k);
    for (row, &v) in tokens.iter().enumerate() {
        let base = v.unsigned_abs() as usize;
        for j in 0..top_k {
            pairs.push((row as u32, ((base + j * stride) % experts) as u32));
        }
    }
}

/// Deterministic embedding of token values into `[seq, d_model]`
/// activations (rows past the batch stay zero).  Equal `(token, seed)`
/// pairs embed identically, so both serving executors see the same
/// activations for the same traffic.
pub fn embed_tokens(tokens: &[i32], seq: usize, d_model: usize, seed: u64) -> Tensor {
    let mut t = Tensor::zeros(&[seq, d_model]);
    embed_tokens_into(tokens, &mut t, seed);
    t
}

/// [`embed_tokens`] into an existing activation tensor: the first
/// `tokens.len()` rows are rewritten, the rest zeroed — so a long-lived
/// session's activation buffer is reused across steps instead of
/// reallocated (the zero-alloc per-step path).
pub fn embed_tokens_into(tokens: &[i32], t: &mut Tensor, seed: u64) {
    debug_assert!(tokens.len() <= t.shape[0]);
    for (r, &v) in tokens.iter().enumerate() {
        let mut sm = SplitMix64((v as i64 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ seed);
        for x in t.row_mut(r) {
            *x = (sm.next_u64() >> 40) as f32 / (1u64 << 24) as f32 - 0.5;
        }
    }
    for r in tokens.len()..t.shape[0] {
        t.row_mut(r).fill(0.0);
    }
}

/// The deterministic synthetic expert weights the serving executors
/// materialize once (`[experts, d_model, d_ff]`, the serving analog of
/// device-resident parameters).  Seeded, so single-shard and sharded
/// executors built from the same config hold bitwise-identical weights.
pub fn expert_weights(experts: usize, d_model: usize, d_ff: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    Tensor::randn(&[experts, d_model, d_ff], 0.1, &mut rng)
}

/// Synthetic next-token id for accounting-mode steps (no numerics ran):
/// a fixed mix of the input token value.
pub fn synthetic_argmax(v: i32) -> i32 {
    (v.wrapping_mul(31).wrapping_add(7)) & 0x7FFF
}

/// Argmax over one output row (first index wins ties).
pub(crate) fn argmax_row(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as i32
}

/// Configuration of the sim/CPU serving executor.
#[derive(Clone, Debug)]
pub struct SimServeConfig {
    /// Sequence buckets offered to the batcher, ascending.
    pub buckets: Vec<usize>,
    /// Token capacity of one formed batch (the session's `seq`); the batch
    /// policy's `max_tokens` must not exceed it.
    pub max_tokens: usize,
    /// Experts in the simulated MoE layer.
    pub experts: usize,
    /// Experts each token routes to.
    pub top_k: usize,
    /// Activation width.
    pub d_model: usize,
    /// Expert FFN width (output columns of each expert GEMM).
    pub d_ff: usize,
    /// LRU capacity of the plan cache.
    pub cache_capacity: usize,
    /// Real CPU numerics through the framework dispatch (true) or
    /// accounting-only simulation (false, faster).
    pub numeric: bool,
    /// Worker threads for the numeric backend.  1 = serial; more attach a
    /// shared [`crate::util::threadpool::ThreadPool`] to the session, with
    /// bitwise-identical outputs (parallelism is a wall-clock knob only).
    pub threads: usize,
    /// Seed for the synthetic expert weights and embeddings.
    pub seed: u64,
}

impl Default for SimServeConfig {
    fn default() -> Self {
        SimServeConfig {
            buckets: vec![16, 64, 256],
            max_tokens: 2048,
            experts: 16,
            top_k: 2,
            d_model: 32,
            d_ff: 64,
            cache_capacity: 128,
            numeric: true,
            threads: 1,
            seed: 0x5EED,
        }
    }
}

/// The sim/CPU-backed [`StepExecutor`].  See module docs.
pub struct SimStepExecutor {
    cfg: SimServeConfig,
    shape: MoeShape,
    /// The long-lived session.  In numeric mode it holds the synthetic
    /// expert weights from construction (the serving analog of
    /// device-resident parameters); only activations and routing are
    /// replaced per step.
    session: ExecutionSession,
    /// Reusable per-step routing-pair buffer (zero-alloc step path).
    pairs: Vec<(u32, u32)>,
    /// Reusable per-step expert load (its `counts` vector is refilled in
    /// place each step).
    load: ExpertLoad,
    steps: u64,
}

impl SimStepExecutor {
    /// Build the executor: one long-lived session (plan cache included)
    /// plus the synthetic expert weights.  Panics on inconsistent
    /// configuration (no buckets, `top_k` out of range).
    pub fn new(cfg: SimServeConfig) -> Self {
        assert!(!cfg.buckets.is_empty(), "at least one bucket");
        assert!(cfg.top_k >= 1 && cfg.top_k <= cfg.experts, "1 <= top_k <= experts");
        let shape = MoeShape {
            seq: cfg.max_tokens,
            d_model: cfg.d_model,
            d_ff: cfg.d_ff,
            experts: cfg.experts,
            top_k: cfg.top_k,
            dtype_bytes: 4,
        };
        let mut session =
            ExecutionSession::new(shape).plan_cache(cfg.cache_capacity).threads(cfg.threads);
        if cfg.numeric {
            session = session.backend(CpuBackend).inputs(NumericInputs {
                tokens: Tensor::zeros(&[shape.seq, shape.d_model]),
                weights: expert_weights(cfg.experts, cfg.d_model, cfg.d_ff, cfg.seed),
                token_index: TokenIndex { index: vec![Vec::new(); cfg.experts] },
                gates: vec![Vec::new(); cfg.experts],
            });
        }
        SimStepExecutor {
            cfg,
            shape,
            session,
            pairs: Vec::new(),
            load: ExpertLoad { counts: Vec::new() },
            steps: 0,
        }
    }

    /// The session's problem shape (`seq` is the step token capacity).
    pub fn shape(&self) -> MoeShape {
        self.shape
    }

    /// Steps executed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Route the packed tokens into the executor's reusable pair buffer
    /// and refill `self.load.counts` in place — [`route_topk`] without the
    /// per-step allocations.
    fn route_in_place(&mut self, tokens: &[i32]) {
        route_topk_into(tokens, self.cfg.experts, self.cfg.top_k, &mut self.pairs);
        self.load.counts.clear();
        self.load.counts.resize(self.cfg.experts, 0);
        for &(_, e) in &self.pairs {
            self.load.counts[e as usize] += 1;
        }
    }
}

impl StepExecutor for SimStepExecutor {
    fn name(&self) -> &'static str {
        if self.cfg.numeric {
            "serve/sim+cpu"
        } else {
            "serve/sim"
        }
    }

    fn buckets(&self) -> Vec<usize> {
        self.cfg.buckets.clone()
    }

    fn max_step_tokens(&self) -> Option<usize> {
        Some(self.shape.seq)
    }

    fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
        let total = step.rows * step.bucket;
        if total > self.shape.seq {
            return Err(ExecError::PlanMismatch {
                backend: self.name(),
                detail: format!(
                    "batch of {total} tokens exceeds the session capacity of {}",
                    self.shape.seq
                ),
            });
        }
        debug_assert_eq!(step.tokens.len(), total);
        self.route_in_place(step.tokens);
        if self.cfg.numeric {
            let gate = 1.0 / self.cfg.top_k as f32;
            let (experts, seed) = (self.cfg.experts, self.cfg.seed);
            let pairs = &self.pairs;
            // in-place input update: the weights set at construction stay
            // resident (like PjrtBackend::warm), and the activation
            // tensor, token-index lists, and gate vectors are rewritten
            // inside their existing buffers — steady-state steps allocate
            // nothing here (the perf bench pins the count)
            let inputs = self.session.inputs_mut().expect("numeric session holds inputs");
            embed_tokens_into(step.tokens, &mut inputs.tokens, seed);
            inputs.token_index.rebuild(experts, pairs);
            for (g, rows) in inputs.gates.iter_mut().zip(&inputs.token_index.index) {
                g.clear();
                g.resize(rows.len(), gate);
            }
        }
        let out = self.session.run(&self.load)?;
        let argmax = match &out.output {
            // real numerics: argmax of each token's combined [d_ff] output
            Some(t) => (0..total).map(|r| argmax_row(t.row(r))).collect(),
            // accounting backend: deterministic synthetic next-token ids
            None => step.tokens.iter().map(|&v| synthetic_argmax(v)).collect(),
        };
        self.steps += 1;
        Ok(StepOutput {
            argmax,
            expert_rows: self.load.counts.iter().map(|&c| c as i32).collect(),
            failed: Vec::new(),
            sim_time_s: out.sim.as_ref().map(|s| s.time_s),
        })
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        self.session.cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(numeric: bool) -> SimServeConfig {
        SimServeConfig {
            buckets: vec![8, 16],
            max_tokens: 64,
            experts: 8,
            top_k: 2,
            d_model: 8,
            d_ff: 12,
            cache_capacity: 8,
            numeric,
            threads: 1,
            seed: 3,
        }
    }

    fn step_tokens(bucket: usize, rows: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..rows * bucket).map(|_| rng.below(50) as i32).collect()
    }

    #[test]
    fn numeric_step_is_deterministic_and_hits_cache_on_repeat() {
        let mut ex = SimStepExecutor::new(tiny_cfg(true));
        let tokens = step_tokens(8, 3, 1);
        let s = StepInput { bucket: 8, rows: 3, tokens: &tokens };
        let a = ex.execute_step(&s).expect("step 1");
        let b = ex.execute_step(&s).expect("step 2");
        assert_eq!(a.argmax, b.argmax);
        assert_eq!(a.argmax.len(), 24);
        assert_eq!(a.expert_rows.iter().sum::<i32>(), 24 * 2);
        let stats = ex.cache_stats().expect("cache enabled");
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert_eq!(ex.steps(), 2);
    }

    #[test]
    fn accounting_mode_produces_synthetic_argmax() {
        let mut ex = SimStepExecutor::new(tiny_cfg(false));
        let tokens = step_tokens(16, 2, 2);
        let out = ex
            .execute_step(&StepInput { bucket: 16, rows: 2, tokens: &tokens })
            .expect("sim step");
        assert_eq!(out.argmax.len(), 32);
        assert!(out.argmax.iter().all(|&a| (0..=0x7FFF).contains(&a)));
    }

    #[test]
    fn equal_token_multisets_share_a_load_signature() {
        let cfg = tiny_cfg(false);
        let a = vec![3, 7, 3, 9];
        let b = vec![9, 3, 7, 3]; // same multiset, different order
        let (_, la) = route_topk(&a, cfg.experts, cfg.top_k);
        let (_, lb) = route_topk(&b, cfg.experts, cfg.top_k);
        assert_eq!(la.counts, lb.counts);
    }

    #[test]
    fn in_place_route_matches_the_allocating_router() {
        let mut ex = SimStepExecutor::new(tiny_cfg(false));
        let tokens = step_tokens(8, 2, 9);
        ex.route_in_place(&tokens);
        let (ti, load) = route_topk(&tokens, ex.cfg.experts, ex.cfg.top_k);
        assert_eq!(ex.load.counts, load.counts);
        let mut rebuilt = TokenIndex { index: vec![Vec::new(); ex.cfg.experts] };
        rebuilt.rebuild(ex.cfg.experts, &ex.pairs);
        assert_eq!(rebuilt, ti);
    }

    #[test]
    fn oversized_batch_is_a_typed_error() {
        let mut ex = SimStepExecutor::new(tiny_cfg(false));
        let tokens = vec![0; 5 * 16];
        let err = ex
            .execute_step(&StepInput { bucket: 16, rows: 5, tokens: &tokens })
            .unwrap_err();
        assert!(matches!(err, ExecError::PlanMismatch { .. }));
    }
}
