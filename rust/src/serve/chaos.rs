//! [`ChaosStepExecutor`]: seeded, deterministic fault injection at the
//! [`StepExecutor::execute_step`] boundary.
//!
//! Every other fault path in the stack is *cooperative* — a
//! [`crate::serve::scenario::FaultPlan`] tells the executor to degrade
//! itself via `apply_fault`.  Chaos is the adversarial complement: faults
//! arrive **as errors from the executor**, exactly the way a production
//! serving loop experiences them, so retry policies, deadline shedding,
//! and circuit breakers are testable under default features without any
//! cooperation from the backend.
//!
//! The wrapper injects, in priority order per call:
//!
//! 1. **Worker-panic passthrough** ([`ChaosConfig::panic_calls`]): a
//!    permanent [`ExecError::Backend`] whose structured source is
//!    [`PoolError::WorkerPanicked`] — the retry layer must refuse to
//!    retry it.
//! 2. **Persistent shard death** ([`ChaosConfig::shard_deaths`]): while a
//!    death window is active *and the inner executor still schedules work
//!    on that shard* ([`StepExecutor::shard_in_use`]), every call fails
//!    with a transient [`ExecError::ShardDown`].  Once placement
//!    evacuates the shard (circuit breaker trip), the injector goes
//!    quiet — and starts failing again if a half-open probe puts the
//!    shard back before the window ends.
//! 3. **Transient error bursts**: with probability
//!    [`ChaosConfig::transient_rate`] a burst of
//!    [`ChaosConfig::burst_len`] consecutive calls fails with
//!    [`ExecError::Timeout`].
//! 4. **Latency spikes**: a successful inner step's simulated time is
//!    multiplied by [`ChaosConfig::latency_factor`] with probability
//!    [`ChaosConfig::latency_rate`] (virtual-clock pressure without
//!    touching outputs).
//!
//! All injection state is driven by a seeded [`Rng`] and a call counter,
//! so a chaos schedule is a pure function of the configuration — the same
//! run replays bit-for-bit.  Injected failures never reach the inner
//! executor, which is what makes the chaos-vs-clean bitwise determinism
//! property testable: the inner executor sees exactly the successful
//! steps, in order.

use crate::coordinator::metrics::ShardingStats;
use crate::exec::ExecError;
use crate::moe::plan_cache::CacheStats;
use crate::serve::scenario::FaultEvent;
use crate::serve::{StepExecutor, StepInput, StepOutput};
use crate::util::rng::Rng;
use crate::util::threadpool::PoolError;

/// One persistent shard-death window, in chaos-call numbering: calls in
/// `[from_call, until_call)` fail with [`ExecError::ShardDown`] while the
/// inner executor still schedules work on `shard`.
#[derive(Clone, Debug)]
pub struct ShardDeath {
    /// The shard that dies.
    pub shard: usize,
    /// First `execute_step` call (0-based) the death affects.
    pub from_call: u64,
    /// First call no longer affected (`u64::MAX` = never recovers).
    pub until_call: u64,
}

/// Chaos-injection schedule knobs.  Everything is deterministic given the
/// seed; see module docs for the injection order.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// RNG seed driving the transient/latency draws.
    pub seed: u64,
    /// Per-call probability of starting a transient failure burst.
    pub transient_rate: f64,
    /// Consecutive calls a transient burst fails (>= 1).
    pub burst_len: u32,
    /// Per-successful-call probability of a latency spike.
    pub latency_rate: f64,
    /// Multiplier applied to `sim_time_s` on a latency spike.
    pub latency_factor: f64,
    /// Persistent shard-death windows.
    pub shard_deaths: Vec<ShardDeath>,
    /// Calls (0-based) that fail as a worker panic — a *permanent*
    /// [`ExecError::Backend`] with a [`PoolError::WorkerPanicked`] source.
    pub panic_calls: Vec<u64>,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0xC4A05,
            transient_rate: 0.0,
            burst_len: 1,
            latency_rate: 0.0,
            latency_factor: 4.0,
            shard_deaths: Vec::new(),
            panic_calls: Vec::new(),
        }
    }
}

/// What the injector did so far (all counters cumulative).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChaosStats {
    /// `execute_step` calls seen (including injected failures).
    pub calls: u64,
    /// Transient [`ExecError::Timeout`] failures injected.
    pub transient_injected: u64,
    /// [`ExecError::ShardDown`] failures injected.
    pub shard_down_injected: u64,
    /// Worker-panic (permanent) failures injected.
    pub panics_injected: u64,
    /// Successful steps whose simulated time was spiked.
    pub latency_spikes: u64,
}

/// A [`StepExecutor`] wrapper injecting seeded faults in front of `E`.
/// Delegates everything else — including [`StepExecutor::observe_error`],
/// so the inner executor's circuit breakers keep learning about failures
/// the server reports, injected or real.
pub struct ChaosStepExecutor<E> {
    inner: E,
    cfg: ChaosConfig,
    rng: Rng,
    burst_left: u32,
    stats: ChaosStats,
}

impl<E: StepExecutor> ChaosStepExecutor<E> {
    pub fn new(inner: E, cfg: ChaosConfig) -> Self {
        assert!(cfg.burst_len >= 1, "a burst is at least one failing call");
        let rng = Rng::new(cfg.seed);
        ChaosStepExecutor { inner, cfg, rng, burst_left: 0, stats: ChaosStats::default() }
    }

    /// The wrapped executor.
    pub fn inner(&self) -> &E {
        &self.inner
    }

    /// Mutable access to the wrapped executor.
    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.inner
    }

    /// Cumulative injection counters.
    pub fn stats(&self) -> ChaosStats {
        self.stats
    }
}

impl<E: StepExecutor> StepExecutor for ChaosStepExecutor<E> {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn buckets(&self) -> Vec<usize> {
        self.inner.buckets()
    }

    fn max_step_tokens(&self) -> Option<usize> {
        self.inner.max_step_tokens()
    }

    fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
        let call = self.stats.calls;
        self.stats.calls += 1;

        // 1. worker-panic passthrough: permanent, structured source intact
        if self.cfg.panic_calls.contains(&call) {
            self.stats.panics_injected += 1;
            return Err(ExecError::backend_caused(
                "chaos",
                format!("injected worker panic (call {call})"),
                PoolError::WorkerPanicked,
            ));
        }

        // 2. persistent shard death: fails only while the inner executor
        // still schedules work on the dead shard — evacuation silences it
        for d in &self.cfg.shard_deaths {
            if call >= d.from_call && call < d.until_call && self.inner.shard_in_use(d.shard) {
                self.stats.shard_down_injected += 1;
                return Err(ExecError::ShardDown {
                    backend: "chaos",
                    shard: d.shard,
                    detail: format!("injected shard death (call {call})"),
                });
            }
        }

        // 3. transient bursts
        if self.burst_left > 0 {
            self.burst_left -= 1;
            self.stats.transient_injected += 1;
            return Err(ExecError::Timeout {
                backend: "chaos",
                detail: format!("injected transient failure (call {call})"),
            });
        }
        if self.cfg.transient_rate > 0.0 && self.rng.f64() < self.cfg.transient_rate {
            self.burst_left = self.cfg.burst_len - 1;
            self.stats.transient_injected += 1;
            return Err(ExecError::Timeout {
                backend: "chaos",
                detail: format!("injected transient failure (call {call})"),
            });
        }

        // 4. real execution, optionally with a latency spike on top
        let mut out = self.inner.execute_step(step)?;
        if self.cfg.latency_rate > 0.0 && self.rng.f64() < self.cfg.latency_rate {
            if let Some(t) = out.sim_time_s.as_mut() {
                *t *= self.cfg.latency_factor;
                self.stats.latency_spikes += 1;
            }
        }
        Ok(out)
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        self.inner.cache_stats()
    }

    fn sharding(&self) -> Option<ShardingStats> {
        self.inner.sharding()
    }

    fn apply_fault(&mut self, event: &FaultEvent) {
        self.inner.apply_fault(event);
    }

    fn observe_error(&mut self, err: &ExecError) {
        self.inner.observe_error(err);
    }

    fn shard_in_use(&self, shard: usize) -> bool {
        self.inner.shard_in_use(shard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::error::Error;

    /// Minimal inner executor: echoes tokens + 1, reports a configurable
    /// shard-in-use set, counts real executions.
    struct Probe {
        executions: usize,
        in_use: Vec<bool>,
        sim_time_s: Option<f64>,
    }

    impl Default for Probe {
        fn default() -> Self {
            Probe { executions: 0, in_use: vec![true; 4], sim_time_s: Some(0.001) }
        }
    }

    impl StepExecutor for Probe {
        fn name(&self) -> &'static str {
            "probe"
        }

        fn buckets(&self) -> Vec<usize> {
            vec![4]
        }

        fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
            self.executions += 1;
            Ok(StepOutput {
                argmax: step.tokens.iter().map(|&t| t + 1).collect(),
                expert_rows: Vec::new(),
                failed: Vec::new(),
                sim_time_s: self.sim_time_s,
            })
        }

        fn shard_in_use(&self, shard: usize) -> bool {
            self.in_use.get(shard).copied().unwrap_or(false)
        }
    }

    fn run_schedule(cfg: ChaosConfig, calls: usize) -> Vec<bool> {
        let mut ex = ChaosStepExecutor::new(Probe::default(), cfg);
        let tokens = vec![1i32; 4];
        let step = StepInput { bucket: 4, rows: 1, tokens: &tokens };
        (0..calls).map(|_| ex.execute_step(&step).is_ok()).collect()
    }

    #[test]
    fn chaos_schedule_is_deterministic_in_the_seed() {
        let cfg = ChaosConfig { transient_rate: 0.3, burst_len: 2, ..ChaosConfig::default() };
        let a = run_schedule(cfg.clone(), 64);
        let b = run_schedule(cfg.clone(), 64);
        assert_eq!(a, b, "same seed, same schedule");
        assert!(a.iter().any(|&ok| !ok), "30% over 64 calls must inject something");
        let c = run_schedule(ChaosConfig { seed: 99, ..cfg }, 64);
        assert_ne!(a, c, "a different seed draws a different schedule");
    }

    #[test]
    fn bursts_fail_exactly_burst_len_consecutive_calls() {
        // rate 1.0: the first call starts a burst deterministically
        let cfg = ChaosConfig { transient_rate: 1.0, burst_len: 3, ..ChaosConfig::default() };
        let mut ex = ChaosStepExecutor::new(Probe::default(), cfg);
        let tokens = vec![1i32; 4];
        let step = StepInput { bucket: 4, rows: 1, tokens: &tokens };
        for i in 0..3 {
            let err = ex.execute_step(&step).unwrap_err();
            assert!(err.is_transient(), "burst call {i} is transient");
        }
        assert_eq!(ex.stats().transient_injected, 3);
        assert_eq!(ex.inner().executions, 0, "injected failures never reach the inner executor");
    }

    #[test]
    fn shard_death_respects_shard_in_use() {
        let cfg = ChaosConfig {
            shard_deaths: vec![ShardDeath { shard: 1, from_call: 0, until_call: u64::MAX }],
            ..ChaosConfig::default()
        };
        let mut ex = ChaosStepExecutor::new(Probe::default(), cfg);
        let tokens = vec![1i32; 4];
        let step = StepInput { bucket: 4, rows: 1, tokens: &tokens };
        let err = ex.execute_step(&step).unwrap_err();
        assert_eq!(err.shard(), Some(1));
        assert!(err.is_transient(), "shard death is transient: evacuation can clear it");
        // "placement evacuates" the shard: the injector goes quiet
        ex.inner_mut().in_use[1] = false;
        assert!(ex.execute_step(&step).is_ok());
        assert_eq!(ex.stats().shard_down_injected, 1);
        assert_eq!(ex.inner().executions, 1);
    }

    #[test]
    fn death_window_bounds_the_injection_in_call_numbering() {
        let cfg = ChaosConfig {
            shard_deaths: vec![ShardDeath { shard: 0, from_call: 1, until_call: 3 }],
            ..ChaosConfig::default()
        };
        let oks = {
            let mut ex = ChaosStepExecutor::new(Probe::default(), cfg);
            let tokens = vec![1i32; 4];
            let step = StepInput { bucket: 4, rows: 1, tokens: &tokens };
            (0..5).map(|_| ex.execute_step(&step).is_ok()).collect::<Vec<_>>()
        };
        assert_eq!(oks, vec![true, false, false, true, true]);
    }

    #[test]
    fn injected_panic_is_permanent_with_a_structured_source() {
        let cfg = ChaosConfig { panic_calls: vec![0], ..ChaosConfig::default() };
        let mut ex = ChaosStepExecutor::new(Probe::default(), cfg);
        let tokens = vec![1i32; 4];
        let err =
            ex.execute_step(&StepInput { bucket: 4, rows: 1, tokens: &tokens }).unwrap_err();
        assert!(!err.is_transient(), "a worker panic must never be retried");
        let src = err.source().expect("structured source");
        assert_eq!(*src.downcast_ref::<PoolError>().unwrap(), PoolError::WorkerPanicked);
        assert_eq!(ex.stats().panics_injected, 1);
    }

    #[test]
    fn latency_spike_scales_sim_time_without_touching_outputs() {
        let cfg = ChaosConfig {
            latency_rate: 1.0,
            latency_factor: 10.0,
            ..ChaosConfig::default()
        };
        let mut ex = ChaosStepExecutor::new(Probe::default(), cfg);
        let tokens = vec![5i32; 4];
        let out =
            ex.execute_step(&StepInput { bucket: 4, rows: 1, tokens: &tokens }).expect("ok");
        assert_eq!(out.argmax, vec![6; 4], "outputs untouched");
        assert!((out.sim_time_s.unwrap() - 0.010).abs() < 1e-12, "time scaled 10x");
        assert_eq!(ex.stats().latency_spikes, 1);
    }
}
