//! [`FusedStepExecutor`]: the whole-transformer-layer serving path.
//!
//! Where [`crate::serve::SimStepExecutor`] runs each formed batch through
//! the MoE expert-FFN workload alone, this executor plans the batch as one
//! [`crate::workload::transformer::FusedLayerWorkload`] step: every request
//! row becomes a sequence slot — freshly admitted prompts prefill in causal
//! chunks, established requests decode over their KV — and each slot's
//! attention output routes to `top_k` experts, all under **one** σ, one
//! TilePrefix, one launch.  The plan cache keys on the composite signature
//! (per-slot `(kind, kv span)` plus per-expert counts), so repeated traffic
//! skips planning exactly like the single-workload executors.
//!
//! The per-row prefill/decode split and KV spans derive deterministically
//! from the row's leading token id, so identical traffic produces identical
//! loads (cache hits) and identical numerics — and the executor never needs
//! request-lifecycle state the serving loop doesn't carry.
//!
//! Per-step buffers (routing pairs, sequence specs, expert counts, Q rows,
//! KV tensors, token-index lists, gate vectors) live for the life of the
//! executor and are rewritten in place each step — the zero-alloc step path
//! the `perf` bench measures.

use crate::exec::{CpuBackend, ExecError, ExecutionSession};
use crate::moe::config::MoeShape;
use crate::moe::plan_cache::CacheStats;
use crate::moe::token_index::TokenIndex;
use crate::serve::sim_exec::{argmax_row, expert_weights, route_topk_into, synthetic_argmax};
use crate::serve::{StepExecutor, StepInput, StepOutput};
use crate::util::rng::SplitMix64;
use crate::util::tensor::Tensor;
use crate::workload::ragged::RaggedInputs;
use crate::workload::transformer::{FusedInputs, FusedLayerWorkload, FusedLoad, SeqSpec};

/// Configuration of the fused transformer-layer serving executor.
#[derive(Clone, Debug)]
pub struct FusedServeConfig {
    /// Sequence buckets offered to the batcher, ascending.
    pub buckets: Vec<usize>,
    /// Sequence-slot capacity of one formed batch (the fused workload's
    /// `shape.seq`); at most this many requests ride one step.
    pub seq_slots: usize,
    /// Attention heads (must divide `d_model`).
    pub heads: usize,
    /// Experts in the routed FFN.
    pub experts: usize,
    /// Experts each slot's attention output routes to.
    pub top_k: usize,
    /// Activation width (`heads * head_dim`).
    pub d_model: usize,
    /// Expert FFN width.
    pub d_ff: usize,
    /// LRU capacity of the plan cache.
    pub cache_capacity: usize,
    /// Real CPU numerics through the fused dispatch (true) or
    /// accounting-only simulation (false — one simulated launch per step).
    pub numeric: bool,
    /// Worker threads for the numeric backend (bitwise-equal to serial).
    pub threads: usize,
    /// Seed for the synthetic expert weights, Q rows, and KV caches.
    pub seed: u64,
}

impl Default for FusedServeConfig {
    fn default() -> Self {
        FusedServeConfig {
            buckets: vec![16, 64, 256],
            seq_slots: 64,
            heads: 4,
            experts: 16,
            top_k: 2,
            d_model: 32,
            d_ff: 64,
            cache_capacity: 128,
            numeric: true,
            threads: 1,
            seed: 0x5EED,
        }
    }
}

/// What one request row is doing this step, derived deterministically from
/// its leading token id `v`: every fourth id (`|v| % 4 == 0`) is treated as
/// a freshly admitted prompt in chunked prefill, the rest decode over a KV
/// span spread across the KV chunk catalog.
pub fn row_spec(v: i32, bucket: usize) -> SeqSpec {
    let base = v.unsigned_abs() as usize;
    if base % 4 == 0 {
        SeqSpec::Prefill { len: bucket + base % 121 }
    } else {
        SeqSpec::Decode { kv_len: 1 + base % 257 }
    }
}

/// The fused-layer [`StepExecutor`].  See module docs.
pub struct FusedStepExecutor {
    cfg: FusedServeConfig,
    shape: MoeShape,
    session: ExecutionSession<FusedLayerWorkload>,
    /// Reusable per-step buffers (zero-alloc step path).
    row_tokens: Vec<i32>,
    pairs: Vec<(u32, u32)>,
    load: FusedLoad,
    steps: u64,
}

impl FusedStepExecutor {
    /// Build the executor: one long-lived fused session (plan cache
    /// included) plus the synthetic expert weights and empty KV slots.
    /// Panics on inconsistent configuration.
    pub fn new(cfg: FusedServeConfig) -> Self {
        assert!(!cfg.buckets.is_empty(), "at least one bucket");
        assert!(cfg.top_k >= 1 && cfg.top_k <= cfg.experts, "1 <= top_k <= experts");
        let shape = MoeShape {
            seq: cfg.seq_slots,
            d_model: cfg.d_model,
            d_ff: cfg.d_ff,
            experts: cfg.experts,
            top_k: cfg.top_k,
            dtype_bytes: 4,
        };
        let workload = FusedLayerWorkload::new(cfg.heads, shape);
        let mut session = ExecutionSession::for_workload(workload)
            .plan_cache(cfg.cache_capacity)
            .threads(cfg.threads);
        if cfg.numeric {
            session = session.backend(CpuBackend).inputs(FusedInputs {
                attn: RaggedInputs {
                    q: Tensor::zeros(&[cfg.seq_slots, cfg.d_model]),
                    keys: vec![Tensor::zeros(&[0, cfg.d_model]); cfg.seq_slots],
                    values: vec![Tensor::zeros(&[0, cfg.d_model]); cfg.seq_slots],
                },
                expert_weights: expert_weights(cfg.experts, cfg.d_model, cfg.d_ff, cfg.seed),
                token_index: TokenIndex { index: vec![Vec::new(); cfg.experts] },
                gates: vec![Vec::new(); cfg.experts],
            });
        }
        let load = FusedLoad {
            seqs: vec![SeqSpec::Empty; cfg.seq_slots],
            expert_counts: vec![0; cfg.experts],
        };
        FusedStepExecutor {
            cfg,
            shape,
            session,
            row_tokens: Vec::new(),
            pairs: Vec::new(),
            load,
            steps: 0,
        }
    }

    /// The session's problem shape (`seq` is the slot capacity).
    pub fn shape(&self) -> MoeShape {
        self.shape
    }

    /// Steps executed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Derive this step's fused load in place: one [`SeqSpec`] per request
    /// row (remaining slots [`SeqSpec::Empty`], σ-elided), and per-expert
    /// counts from routing each row's attention output.
    fn form_load(&mut self, step: &StepInput<'_>) {
        self.row_tokens.clear();
        self.row_tokens.extend((0..step.rows).map(|r| step.tokens[r * step.bucket]));
        self.load.seqs.clear();
        self.load
            .seqs
            .extend(self.row_tokens.iter().map(|&v| row_spec(v, step.bucket)));
        self.load.seqs.resize(self.cfg.seq_slots, SeqSpec::Empty);
        route_topk_into(&self.row_tokens, self.cfg.experts, self.cfg.top_k, &mut self.pairs);
        self.load.expert_counts.clear();
        self.load.expert_counts.resize(self.cfg.experts, 0);
        for &(_, e) in &self.pairs {
            self.load.expert_counts[e as usize] += 1;
        }
    }
}

/// Deterministic refill of one slot's KV tensor for a span of `kv` rows:
/// reallocates only when the span changed, rewrites in place otherwise.
fn refill_kv(t: &mut Tensor, kv: usize, width: usize, salt: u64, amp: f32) {
    if t.shape != [kv, width] {
        *t = Tensor::zeros(&[kv, width]);
    }
    let mut sm = SplitMix64(salt);
    for x in &mut t.data {
        *x = ((sm.next_u64() >> 40) as f32 / (1u64 << 24) as f32 - 0.5) * amp;
    }
}

impl StepExecutor for FusedStepExecutor {
    fn name(&self) -> &'static str {
        if self.cfg.numeric {
            "serve/fused+cpu"
        } else {
            "serve/fused"
        }
    }

    fn buckets(&self) -> Vec<usize> {
        self.cfg.buckets.clone()
    }

    fn max_step_tokens(&self) -> Option<usize> {
        // rows * bucket <= slots * min_bucket  ==>  rows <= slots
        let min_bucket = self.cfg.buckets.iter().copied().min().unwrap_or(1);
        Some(self.cfg.seq_slots * min_bucket)
    }

    fn execute_step(&mut self, step: &StepInput<'_>) -> Result<StepOutput, ExecError> {
        let total = step.rows * step.bucket;
        if step.rows > self.cfg.seq_slots {
            return Err(ExecError::PlanMismatch {
                backend: self.name(),
                detail: format!(
                    "batch of {} rows exceeds the {} sequence slots",
                    step.rows, self.cfg.seq_slots
                ),
            });
        }
        debug_assert_eq!(step.tokens.len(), total);
        self.form_load(step);
        if self.cfg.numeric {
            let gate = 1.0 / self.cfg.top_k as f32;
            let (experts, seed) = (self.cfg.experts, self.cfg.seed);
            let d_model = self.cfg.d_model;
            let (row_tokens, seqs, pairs) = (&self.row_tokens, &self.load.seqs, &self.pairs);
            let inputs = self.session.inputs_mut().expect("numeric session holds inputs");
            // Q row per active slot, seeded by the row's leading token id
            for (r, &v) in row_tokens.iter().enumerate() {
                let mut sm =
                    SplitMix64((v as i64 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ seed);
                for x in inputs.attn.q.row_mut(r) {
                    *x = (sm.next_u64() >> 40) as f32 / (1u64 << 24) as f32 - 0.5;
                }
            }
            // KV cache per slot, seeded by (slot, span, kind)
            for (s, spec) in seqs.iter().enumerate() {
                let kv = spec.kv_len();
                let salt = seed
                    ^ ((s as u64) << 32)
                    ^ ((kv as u64) << 4)
                    ^ match spec {
                        SeqSpec::Prefill { .. } => 2,
                        _ => 1,
                    };
                refill_kv(&mut inputs.attn.keys[s], kv, d_model, salt, 0.5);
                refill_kv(&mut inputs.attn.values[s], kv, d_model, salt.rotate_left(17), 1.0);
            }
            inputs.token_index.rebuild(experts, pairs);
            for (g, rows) in inputs.gates.iter_mut().zip(&inputs.token_index.index) {
                g.clear();
                g.resize(rows.len(), gate);
            }
        }
        let out = self.session.run(&self.load)?;
        let argmax = match &out.output {
            // real numerics: each request row's [d_ff] layer output, its
            // argmax replicated across the row's padded positions
            Some(t) => {
                let mut am = Vec::with_capacity(total);
                for r in 0..step.rows {
                    let a = argmax_row(t.row(r));
                    am.extend(std::iter::repeat(a).take(step.bucket));
                }
                am
            }
            // accounting backend: deterministic synthetic next-token ids
            None => step.tokens.iter().map(|&v| synthetic_argmax(v)).collect(),
        };
        self.steps += 1;
        Ok(StepOutput {
            argmax,
            expert_rows: self.load.expert_counts.iter().map(|&c| c as i32).collect(),
            failed: Vec::new(),
            sim_time_s: out.sim.as_ref().map(|s| s.time_s),
        })
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        self.session.cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_cfg(numeric: bool) -> FusedServeConfig {
        FusedServeConfig {
            buckets: vec![8, 16],
            seq_slots: 16,
            heads: 2,
            experts: 8,
            top_k: 2,
            d_model: 8,
            d_ff: 12,
            cache_capacity: 8,
            numeric,
            threads: 1,
            seed: 3,
        }
    }

    fn step_tokens(bucket: usize, rows: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..rows * bucket).map(|_| rng.below(50) as i32).collect()
    }

    #[test]
    fn numeric_step_is_deterministic_and_hits_cache_on_repeat() {
        let mut ex = FusedStepExecutor::new(tiny_cfg(true));
        let tokens = step_tokens(8, 3, 1);
        let s = StepInput { bucket: 8, rows: 3, tokens: &tokens };
        let a = ex.execute_step(&s).expect("step 1");
        let b = ex.execute_step(&s).expect("step 2");
        assert_eq!(a.argmax, b.argmax);
        assert_eq!(a.argmax.len(), 24);
        assert_eq!(a.expert_rows.iter().sum::<i32>(), 3 * 2);
        let stats = ex.cache_stats().expect("cache enabled");
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert_eq!(ex.steps(), 2);
    }

    #[test]
    fn traffic_mixes_prefill_and_decode_slots() {
        let mut ex = FusedStepExecutor::new(tiny_cfg(false));
        // leading ids 4 and 8 prefill; 3 and 7 decode
        let mut tokens = vec![0i32; 4 * 8];
        for (r, v) in [(0usize, 4i32), (1, 3), (2, 8), (3, 7)] {
            tokens[r * 8] = v;
        }
        ex.execute_step(&StepInput { bucket: 8, rows: 4, tokens: &tokens }).expect("sim step");
        let prefills =
            ex.load.seqs.iter().filter(|s| matches!(s, SeqSpec::Prefill { .. })).count();
        let decodes = ex.load.seqs.iter().filter(|s| matches!(s, SeqSpec::Decode { .. })).count();
        assert_eq!((prefills, decodes), (2, 2));
        assert_eq!(ex.load.seqs.len(), 16); // padded with σ-elided empties
    }

    #[test]
    fn accounting_mode_reports_sim_time_and_synthetic_argmax() {
        let mut ex = FusedStepExecutor::new(tiny_cfg(false));
        let tokens = step_tokens(16, 2, 2);
        let out = ex
            .execute_step(&StepInput { bucket: 16, rows: 2, tokens: &tokens })
            .expect("sim step");
        assert_eq!(out.argmax.len(), 32);
        assert!(out.sim_time_s.expect("accounting step is simulated") > 0.0);
    }

    #[test]
    fn oversized_batch_is_a_typed_error() {
        let mut ex = FusedStepExecutor::new(tiny_cfg(false));
        let tokens = vec![1; 17 * 8];
        let err = ex
            .execute_step(&StepInput { bucket: 8, rows: 17, tokens: &tokens })
            .unwrap_err();
        assert!(matches!(err, ExecError::PlanMismatch { .. }));
    }

    #[test]
    fn numeric_and_accounting_agree_on_expert_rows() {
        let tokens = step_tokens(8, 4, 5);
        let s = StepInput { bucket: 8, rows: 4, tokens: &tokens };
        let mut num = FusedStepExecutor::new(tiny_cfg(true));
        let mut sim = FusedStepExecutor::new(tiny_cfg(false));
        let a = num.execute_step(&s).expect("numeric");
        let b = sim.execute_step(&s).expect("sim");
        assert_eq!(a.expert_rows, b.expert_rows);
    }
}
