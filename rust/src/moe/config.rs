//! MoE problem shapes.

use crate::sim::cost::Dtype;

/// Shape of one MoE expert-GEMM batch: `seq` tokens, each routed to `top_k`
/// of `experts` experts; every expert weight is `[d_model, d_ff]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MoeShape {
    pub seq: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub experts: usize,
    pub top_k: usize,
    pub dtype_bytes: usize,
}

impl MoeShape {
    /// The paper's Section 5 default: seq 4096, weight [3584, 2560], 64
    /// experts, top-8, BF16.
    pub fn paper_table1() -> Self {
        MoeShape {
            seq: 4096,
            d_model: 3584,
            d_ff: 2560,
            experts: 64,
            top_k: 8,
            dtype_bytes: 2,
        }
    }

    /// The paper's footnote-1 setting for the H800 best case: "a much larger
    /// sequence length and weight shape" — we use 4x the sequence and the
    /// next-size-up weight so the 8 active GEMMs can saturate 989 TFLOPS.
    pub fn paper_table1_best_h800() -> Self {
        MoeShape {
            seq: 16384,
            d_model: 7168,
            d_ff: 4096,
            experts: 64,
            top_k: 8,
            dtype_bytes: 2,
        }
    }

    /// Small shape for fast tests.
    pub fn tiny() -> Self {
        MoeShape { seq: 64, d_model: 32, d_ff: 48, experts: 8, top_k: 2, dtype_bytes: 4 }
    }

    pub fn dtype(&self) -> Dtype {
        if self.dtype_bytes == 2 {
            Dtype::Bf16
        } else {
            Dtype::F32
        }
    }

    /// Total routed row-slots (Σ expert token counts).
    pub fn total_rows(&self) -> usize {
        self.seq * self.top_k
    }

    /// Useful FLOPs of the whole batch (independent of routing): every
    /// routed row multiplies a [d_model] vector by [d_model, d_ff].
    pub fn total_flops(&self) -> f64 {
        2.0 * self.total_rows() as f64 * self.d_model as f64 * self.d_ff as f64
    }

    /// Bytes of one expert's weight.
    pub fn weight_bytes(&self) -> usize {
        self.d_model * self.d_ff * self.dtype_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape_matches_section5() {
        let s = MoeShape::paper_table1();
        assert_eq!((s.seq, s.d_model, s.d_ff, s.experts, s.top_k), (4096, 3584, 2560, 64, 8));
        // 2 * 4096*8 * 3584 * 2560 = 601.3 GFLOP
        assert!((s.total_flops() - 6.013e11).abs() / 6.013e11 < 0.01);
        assert_eq!(s.weight_bytes(), 3584 * 2560 * 2);
    }

    #[test]
    fn dtype_mapping() {
        assert_eq!(MoeShape::paper_table1().dtype(), Dtype::Bf16);
        assert_eq!(MoeShape::tiny().dtype(), Dtype::F32);
    }
}
