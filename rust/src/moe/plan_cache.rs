//! The MoE instantiation of the workload-generic LRU plan cache.
//!
//! The cache itself lives in [`crate::workload::cache`]; here it is keyed
//! by [`MoeWorkload::signature`](crate::workload::Workload::signature) —
//! the normalized per-expert row counts, the canonical form of a routing
//! outcome (two routings with the same counts produce the same plan under
//! a fixed planner configuration).  Serving traffic repeats load shapes
//! constantly — popular prompts, padded batches of equal composition,
//! steady-state balanced routing — which is what makes the cache pay.

use crate::moe::planner::MoeWorkload;

pub use crate::workload::cache::CacheStats;

/// LRU cache from per-expert-count load signature to built MoE plan.
pub type PlanCache = crate::workload::cache::PlanCache<MoeWorkload>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::config::MoeShape;
    use crate::moe::planner::Planner;
    use crate::moe::routing::{ExpertLoad, LoadScenario};
    use std::sync::Arc;

    fn shape() -> MoeShape {
        MoeShape::tiny()
    }

    #[test]
    fn repeated_signature_hits_and_matches_fresh_plan() {
        let planner = Planner::new(shape());
        let mut cache = PlanCache::new(8);
        let load = LoadScenario::Zipf(1.2).counts(&shape(), 5);

        let first = cache.get_or_plan(&planner, &load);
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 1, entries: 1 });

        let second = cache.get_or_plan(&planner, &load);
        assert_eq!(cache.stats().hits, 1, "repeated signature must hit");
        // the hit returns the same Arc — planning was skipped, not redone
        assert!(Arc::ptr_eq(&first, &second));
        // and the cached plan is exactly what a fresh Planner::plan builds
        assert_eq!(*second, planner.plan(&load));
    }

    #[test]
    fn distinct_signatures_miss() {
        let planner = Planner::new(shape());
        let mut cache = PlanCache::new(8);
        for k in 0..4usize {
            // guaranteed-distinct signatures: hot expert load varies
            let mut counts = vec![1usize; shape().experts];
            counts[0] = 10 + k;
            cache.get_or_plan(&planner, &ExpertLoad { counts });
        }
        let s = cache.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 4);
        assert!((s.hit_rate() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let planner = Planner::new(shape());
        let mut cache = PlanCache::new(2);
        let a = LoadScenario::Balanced.counts(&shape(), 0);
        let b = LoadScenario::Best.counts(&shape(), 0);
        let c = LoadScenario::Worst.counts(&shape(), 0);

        cache.get_or_plan(&planner, &a);
        cache.get_or_plan(&planner, &b);
        cache.get_or_plan(&planner, &a); // refresh a; b is now LRU
        cache.get_or_plan(&planner, &c); // evicts b
        assert_eq!(cache.len(), 2);

        cache.get_or_plan(&planner, &a);
        assert_eq!(cache.stats().hits, 2, "a must still be resident");
        cache.get_or_plan(&planner, &b);
        assert_eq!(cache.stats().misses, 4, "b was evicted and re-planned");
    }

    #[test]
    fn capacity_pressure_never_exceeds_bound_and_counts_correctly() {
        // more distinct load signatures than capacity: occupancy must stay
        // at the bound and every lookup must be a counted miss
        let planner = Planner::new(shape());
        let mut cache = PlanCache::new(4);
        for k in 0..12usize {
            let mut counts = vec![1usize; shape().experts];
            counts[k % shape().experts] = 10 + k; // 12 distinct signatures
            cache.get_or_plan(&planner, &ExpertLoad { counts });
            assert!(cache.len() <= 4, "occupancy {} exceeds capacity", cache.len());
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 12, 4));
        assert!((s.hit_rate() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn sequential_scan_over_capacity_thrashes_in_lru_order() {
        // capacity 2, cycling a -> b -> c: LRU always evicts the signature
        // that comes next, so every single lookup misses (the classic
        // sequential-scan thrash) and the counters must show exactly that
        let planner = Planner::new(shape());
        let mut cache = PlanCache::new(2);
        let a = LoadScenario::Balanced.counts(&shape(), 0);
        let b = LoadScenario::Best.counts(&shape(), 0);
        let c = LoadScenario::Worst.counts(&shape(), 0);
        for _ in 0..3 {
            for load in [&a, &b, &c] {
                cache.get_or_plan(&planner, load);
            }
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 9, 2));
    }

    #[test]
    fn touch_refresh_under_pressure_protects_the_hot_signature() {
        // capacity 2 with a hot signature touched between cold inserts: the
        // hot entry must survive every eviction round
        let planner = Planner::new(shape());
        let mut cache = PlanCache::new(2);
        let hot = LoadScenario::Balanced.counts(&shape(), 0);
        cache.get_or_plan(&planner, &hot);
        for k in 0..5usize {
            let mut counts = vec![1usize; shape().experts];
            counts[0] = 100 + k; // distinct cold signatures
            cache.get_or_plan(&planner, &ExpertLoad { counts });
            cache.get_or_plan(&planner, &hot); // refresh: cold entry is LRU
        }
        let s = cache.stats();
        assert_eq!(s.hits, 5, "hot signature must stay resident throughout");
        assert_eq!(s.misses, 6, "initial hot insert + 5 distinct cold inserts");
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn clear_drops_entries_but_keeps_counters() {
        let planner = Planner::new(shape());
        let mut cache = PlanCache::new(4);
        let load = LoadScenario::Balanced.counts(&shape(), 0);
        cache.get_or_plan(&planner, &load);
        cache.get_or_plan(&planner, &load);
        cache.clear();
        assert!(cache.is_empty());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        cache.get_or_plan(&planner, &load);
        assert_eq!(cache.stats().misses, 2);
    }
}
