//! Tiling-strategy catalog and per-expert selection.
//!
//! "These GEMMs can be categorized into several pre-defined tiling
//! strategies. Generally speaking, GEMMs with large input and output sizes
//! prefer large tiles to improve computational intensity." (Section 4.)
//! Each strategy corresponds to one device function (`taskFunc_i`), so the
//! catalog is fixed at build time; selection is per task at plan time.

/// One pre-compiled tile shape (rows x cols of the output tile).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileStrategy {
    pub tm: usize,
    pub tn: usize,
}

/// The catalog, largest to smallest. Shapes follow the usual Hopper WGMMA
/// sweet spots; on the TPU side these are MXU-aligned (multiples of 8x128).
pub const CATALOG: &[TileStrategy] = &[
    TileStrategy { tm: 128, tn: 256 },
    TileStrategy { tm: 128, tn: 128 },
    TileStrategy { tm: 64, tn: 128 },
    TileStrategy { tm: 32, tn: 128 },
    TileStrategy { tm: 16, tn: 128 },
];

/// Index into [`CATALOG`].
pub type StrategyId = usize;

/// Pick the strategy for an expert GEMM of `m` rows: the largest tile whose
/// row dimension does not waste more than half its rows, falling back to
/// the smallest for skinny tasks.  This is the per-task selection the
/// framework enables and grouped GEMM (single strategy) cannot do.
pub fn select(m: usize) -> StrategyId {
    for (i, s) in CATALOG.iter().enumerate() {
        if m >= s.tm {
            return i;
        }
        // allow one partial tile if at least half full
        if m * 2 >= s.tm {
            return i;
        }
    }
    CATALOG.len() - 1
}

/// The single compromise strategy grouped GEMM would use for the whole
/// batch: sized for the *mean* task (the defect in Section 2.1 — too large
/// for skinny tasks, too small for fat ones).
pub fn select_single_for_batch(ms: &[usize]) -> StrategyId {
    let nonzero: Vec<usize> = ms.iter().copied().filter(|&m| m > 0).collect();
    if nonzero.is_empty() {
        return CATALOG.len() - 1;
    }
    let mean = nonzero.iter().sum::<usize>() / nonzero.len();
    select(mean)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_sorted_descending() {
        for w in CATALOG.windows(2) {
            assert!(w[0].tm * w[0].tn >= w[1].tm * w[1].tn);
        }
    }

    #[test]
    fn big_tasks_get_big_tiles() {
        assert_eq!(CATALOG[select(4096)], TileStrategy { tm: 128, tn: 256 });
        assert_eq!(CATALOG[select(512)], TileStrategy { tm: 128, tn: 256 });
    }

    #[test]
    fn skinny_tasks_get_small_tiles() {
        assert_eq!(CATALOG[select(1)].tm, 16);
        // 16 rows exactly half-fill a 32-row tile -> accepted by the
        // half-full rule (one partial tile beats two tiny ones)
        assert_eq!(CATALOG[select(16)].tm, 32);
        assert_eq!(CATALOG[select(15)].tm, 16);
        assert_eq!(CATALOG[select(33)].tm, 64);
    }

    #[test]
    fn half_full_tile_accepted() {
        // 64 rows: a 128-row tile would be exactly half full -> accepted
        assert_eq!(CATALOG[select(64)].tm, 128);
        // 63 rows: less than half of 128 -> next size down
        assert_eq!(CATALOG[select(63)].tm, 64);
    }

    #[test]
    fn single_strategy_uses_mean() {
        // mean of [4096 x8, 1 x56] = (32768+56)/64 = 512 -> big tile
        let mut ms = vec![4096usize; 8];
        ms.extend(vec![1usize; 56]);
        assert_eq!(CATALOG[select_single_for_batch(&ms)].tm, 128);
        // all-skinny batch -> small tile
        assert_eq!(CATALOG[select_single_for_batch(&[2, 3, 1])].tm, 16);
    }

    #[test]
    fn empty_batch_defaults_to_smallest() {
        assert_eq!(select_single_for_batch(&[0, 0]), CATALOG.len() - 1);
    }
}
