//! Kernel metadata builder: the Rust twin of `python/compile/metadata.py`.
//!
//! The AOT Pallas kernel consumes four int32 arrays per step:
//! `tile_prefix[E]`, `sigma[E]`, `token_ids[SP]`, `num_tiles[1]`.  The
//! serving engine builds them here (host side, per step, exactly the
//! paper's two-phase host work), with the same layout contract as the jnp
//! planner so one compiled executable serves every routing:
//!
//! * σ: non-empty experts first (in the chosen grid order), then empty
//!   experts — Algorithm 4's injection padded to a permutation.
//! * `tile_prefix`: inclusive prefix of per-non-empty-expert tile counts in
//!   σ order, tail repeating the total (Algorithm 1 + padding rule).
//! * `token_ids`: gather indices grouped by expert in σ order, each group
//!   padded to a tile_m multiple (padding rows point at token 0 and carry
//!   zero gate).

use crate::moe::ordering::OrderingStrategy;
use crate::moe::token_index::TokenIndex;

/// Static dims of one compiled kernel variant (mirror of Python `MoeDims`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelDims {
    pub seq: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub experts: usize,
    pub top_k: usize,
    pub tile_m: usize,
}

impl KernelDims {
    /// Static padded row bound — must equal Python `MoeDims.padded_rows`.
    pub fn padded_rows(&self) -> usize {
        let raw = self.seq * self.top_k + self.experts * self.tile_m;
        raw.div_ceil(self.tile_m) * self.tile_m
    }

    pub fn max_tiles(&self) -> usize {
        self.padded_rows() / self.tile_m
    }
}

/// The metadata tensors the kernel takes, plus the combine-side arrays.
#[derive(Clone, Debug)]
pub struct KernelMeta {
    pub tile_prefix: Vec<i32>, // [E]
    pub sigma: Vec<i32>,       // [E]
    pub token_ids: Vec<i32>,   // [SP]
    pub num_tiles: [i32; 1],
    /// Combine gate per packed row (0 on padding) — consumed host-side.
    pub gates_pad: Vec<f32>,   // [SP]
    /// Expert of each packed row (for host-side checks / debugging).
    pub row_expert: Vec<i32>,  // [SP], -1 on trailing padding
}

/// Build kernel metadata from token index arrays + gates.
///
/// `ordering` permutes the grid order of non-empty experts (Section 4.2);
/// the Python planner always uses Natural, and the contract allows any
/// permutation because the kernel reads experts through σ.
pub fn build(
    dims: &KernelDims,
    token_index: &TokenIndex,
    gates: &[Vec<f32>],
    ordering: OrderingStrategy,
) -> KernelMeta {
    let e = dims.experts;
    let t = dims.tile_m;
    let sp = dims.padded_rows();
    assert_eq!(token_index.index.len(), e);

    let nonempty: Vec<(u32, usize)> = token_index
        .index
        .iter()
        .enumerate()
        .filter(|(_, v)| !v.is_empty())
        .map(|(i, v)| (i as u32, v.len()))
        .collect();
    let ordered = ordering.order(&nonempty);

    // σ: ordered non-empty experts, then empty experts ascending
    let mut sigma: Vec<i32> = ordered.iter().map(|&x| x as i32).collect();
    for (i, v) in token_index.index.iter().enumerate() {
        if v.is_empty() {
            sigma.push(i as i32);
        }
    }
    debug_assert_eq!(sigma.len(), e);

    // inclusive tile prefix over σ order (empties contribute 0 => tail
    // repeats the total, the padding rule)
    let mut tile_prefix = Vec::with_capacity(e);
    let mut acc = 0i32;
    for &s in &sigma {
        let c = token_index.index[s as usize].len();
        acc += c.div_ceil(t) as i32;
        tile_prefix.push(acc);
    }
    let num_tiles = [acc];

    // packed rows
    let mut token_ids = vec![0i32; sp];
    let mut gates_pad = vec![0f32; sp];
    let mut row_expert = vec![-1i32; sp];
    let mut cursor = 0usize;
    for &s in sigma.iter().take(e) {
        let rows = &token_index.index[s as usize];
        if rows.is_empty() {
            continue;
        }
        let padded = rows.len().div_ceil(t) * t;
        assert!(cursor + padded <= sp, "static SP bound violated");
        for (pos, &tok) in rows.iter().enumerate() {
            token_ids[cursor + pos] = tok as i32;
            gates_pad[cursor + pos] = gates[s as usize][pos];
            row_expert[cursor + pos] = s;
        }
        // padding rows within the group still belong to the expert's tiles
        for pos in rows.len()..padded {
            row_expert[cursor + pos] = s;
        }
        cursor += padded;
    }

    KernelMeta { tile_prefix, sigma, token_ids, num_tiles, gates_pad, row_expert }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> KernelDims {
        KernelDims { seq: 16, d_model: 8, d_ff: 8, experts: 4, top_k: 2, tile_m: 4 }
    }

    fn index(counts: &[usize]) -> (TokenIndex, Vec<Vec<f32>>) {
        let mut pairs = Vec::new();
        let mut tok = 0u32;
        for (e, &c) in counts.iter().enumerate() {
            for _ in 0..c {
                pairs.push((tok % 16, e as u32));
                tok += 1;
            }
        }
        let ti = TokenIndex::build(counts.len(), &pairs);
        let gates: Vec<Vec<f32>> =
            ti.index.iter().map(|v| v.iter().map(|_| 0.5f32).collect()).collect();
        (ti, gates)
    }

    #[test]
    fn padded_rows_matches_python_formula() {
        // python: ceil((S*K + E*T)/T)*T
        let d = dims();
        assert_eq!(d.padded_rows(), 48);
        let d2 = KernelDims { seq: 8, d_model: 8, d_ff: 8, experts: 8, top_k: 1, tile_m: 64 };
        assert_eq!(d2.padded_rows(), 576); // ceil(520/64)*64
    }

    #[test]
    fn sigma_is_permutation_nonempty_first() {
        let (ti, gates) = index(&[3, 0, 5, 0]);
        let m = build(&dims(), &ti, &gates, OrderingStrategy::Natural);
        assert_eq!(m.sigma, vec![0, 2, 1, 3]);
        // tiles: ceil(3/4)=1, ceil(5/4)=2 -> prefix [1,3,3,3]
        assert_eq!(m.tile_prefix, vec![1, 3, 3, 3]);
        assert_eq!(m.num_tiles, [3]);
    }

    #[test]
    fn token_ids_grouped_and_padded() {
        let (ti, gates) = index(&[3, 0, 5, 0]);
        let m = build(&dims(), &ti, &gates, OrderingStrategy::Natural);
        // expert 0: rows 0..3 at offset 0, pad row 3; expert 2: rows at 4..9
        assert_eq!(&m.token_ids[..3], &[0, 1, 2]);
        assert_eq!(m.gates_pad[3], 0.0);
        assert_eq!(m.row_expert[3], 0); // pad row still inside expert 0's tile
        assert_eq!(&m.token_ids[4..9], &[3, 4, 5, 6, 7]);
        assert_eq!(m.row_expert[4], 2);
        // trailing region unused
        assert!(m.row_expert[12..].iter().all(|&x| x == -1));
    }

    #[test]
    fn ordering_permutes_sigma_prefix_consistently() {
        let (ti, gates) = index(&[8, 1, 0, 6]);
        let nat = build(&dims(), &ti, &gates, OrderingStrategy::Natural);
        let half = build(&dims(), &ti, &gates, OrderingStrategy::HalfInterval);
        // same totals, different order
        assert_eq!(nat.num_tiles, half.num_tiles);
        let mut a = nat.sigma.clone();
        let mut b = half.sigma.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        // gate mass preserved
        let mass: f32 = nat.gates_pad.iter().sum();
        let mass2: f32 = half.gates_pad.iter().sum();
        assert!((mass - mass2).abs() < 1e-5);
    }

    #[test]
    fn all_empty_is_valid() {
        let (ti, gates) = index(&[0, 0, 0, 0]);
        let m = build(&dims(), &ti, &gates, OrderingStrategy::Natural);
        assert_eq!(m.num_tiles, [0]);
        assert!(m.tile_prefix.iter().all(|&x| x == 0));
    }
}
