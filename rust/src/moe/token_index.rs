//! Per-expert token index arrays (paper Section 4.3).
//!
//! "We introduce a token index array for every expert, containing the
//! indices of the tokens routed to the expert. [...] Atomic operations are
//! used to scatter tokens into buckets corresponding to experts."
//!
//! This module reproduces the device-side construction with the same
//! atomic-scatter semantics (fetch-add cursors per bucket) and exposes the
//! byte-savings accounting the A5 ablation reports: with index arrays the
//! kernel gathers rows from the original token sequence; without them every
//! expert's input must be copied into a contiguous staging tensor first.

use std::sync::atomic::{AtomicU32, Ordering};

/// Token index arrays: `index[e]` lists the token ids routed to expert `e`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TokenIndex {
    pub index: Vec<Vec<u32>>,
}

impl TokenIndex {
    /// Sequential construction from (token, expert) routing pairs.
    pub fn build(num_experts: usize, pairs: &[(u32, u32)]) -> Self {
        let mut index = vec![Vec::new(); num_experts];
        for &(token, expert) in pairs {
            index[expert as usize].push(token);
        }
        TokenIndex { index }
    }

    /// Parallel construction with atomic bucket cursors — the radix-scatter
    /// the paper uses on device.  Two passes: count (histogram), then
    /// scatter with fetch-add cursors; safe to run from many threads.
    pub fn build_atomic(num_experts: usize, pairs: &[(u32, u32)]) -> Self {
        let mut counts = vec![0u32; num_experts];
        for &(_, e) in pairs {
            counts[e as usize] += 1;
        }
        let bufs: Vec<Vec<AtomicU32>> = counts
            .iter()
            .map(|&c| (0..c).map(|_| AtomicU32::new(u32::MAX)).collect())
            .collect();
        let cursors: Vec<AtomicU32> = (0..num_experts).map(|_| AtomicU32::new(0)).collect();
        // scatter (chunked across threads)
        std::thread::scope(|scope| {
            let n_threads = 4;
            let chunk = pairs.len().div_ceil(n_threads).max(1);
            for part in pairs.chunks(chunk) {
                let bufs = &bufs;
                let cursors = &cursors;
                scope.spawn(move || {
                    for &(token, e) in part {
                        let slot = cursors[e as usize].fetch_add(1, Ordering::Relaxed);
                        bufs[e as usize][slot as usize].store(token, Ordering::Relaxed);
                    }
                });
            }
        });
        let index = bufs
            .into_iter()
            .map(|b| b.into_iter().map(|a| a.into_inner()).collect())
            .collect();
        TokenIndex { index }
    }

    /// In-place [`TokenIndex::build`]: clear and refill the per-expert
    /// lists, reusing their capacity.  The zero-alloc per-step path of the
    /// serving executors — same result as `build`, no fresh `Vec`s once
    /// the lists reach steady-state size.
    pub fn rebuild(&mut self, num_experts: usize, pairs: &[(u32, u32)]) {
        self.index.resize(num_experts, Vec::new());
        for v in &mut self.index {
            v.clear();
        }
        for &(token, expert) in pairs {
            self.index[expert as usize].push(token);
        }
    }

    pub fn counts(&self) -> Vec<usize> {
        self.index.iter().map(|v| v.len()).collect()
    }

    /// Bytes the index arrays occupy (what ships instead of copies).
    pub fn index_bytes(&self) -> usize {
        4 * self.index.iter().map(|v| v.len()).sum::<usize>()
    }

    /// Bytes a grouped-GEMM style implementation would copy to build
    /// contiguous per-expert input tensors (the overhead Section 4.3
    /// eliminates): every routed row duplicates a full `d_model` vector.
    pub fn gather_copy_bytes(&self, d_model: usize, dtype_bytes: usize) -> usize {
        self.index.iter().map(|v| v.len()).sum::<usize>() * d_model * dtype_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pairs(n_tokens: u32, top_k: u32, experts: u32, seed: u64) -> Vec<(u32, u32)> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for t in 0..n_tokens {
            for _ in 0..top_k {
                out.push((t, rng.below(experts as u64) as u32));
            }
        }
        out
    }

    #[test]
    fn sequential_build_partitions_rows() {
        let p = pairs(100, 2, 8, 1);
        let ti = TokenIndex::build(8, &p);
        assert_eq!(ti.counts().iter().sum::<usize>(), 200);
        // every pair appears in its expert's list
        for &(tok, e) in &p {
            assert!(ti.index[e as usize].contains(&tok));
        }
    }

    #[test]
    fn atomic_build_matches_sequential_as_multiset() {
        let p = pairs(500, 4, 16, 3);
        let seq = TokenIndex::build(16, &p);
        let par = TokenIndex::build_atomic(16, &p);
        assert_eq!(seq.counts(), par.counts());
        for e in 0..16 {
            let mut a = seq.index[e].clone();
            let mut b = par.index[e].clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "expert {e}");
        }
        // no sentinel survived the scatter
        assert!(par.index.iter().flatten().all(|&t| t != u32::MAX));
    }

    #[test]
    fn copy_savings_scale_with_d_model() {
        let p = pairs(1000, 8, 64, 5);
        let ti = TokenIndex::build(64, &p);
        let idx = ti.index_bytes();
        let copies = ti.gather_copy_bytes(3584, 2);
        // 8000 rows: 32 KB of indices vs 57 MB of copies
        assert_eq!(idx, 4 * 8000);
        assert_eq!(copies, 8000 * 3584 * 2);
        assert!(copies > idx * 1000);
    }

    #[test]
    fn rebuild_matches_build_and_reuses_capacity() {
        let a = pairs(200, 2, 8, 11);
        let b = pairs(40, 2, 8, 12);
        let mut ti = TokenIndex::build(8, &a);
        let caps: Vec<usize> = ti.index.iter().map(|v| v.capacity()).collect();
        ti.rebuild(8, &b);
        assert_eq!(ti, TokenIndex::build(8, &b));
        // shrinking traffic keeps the grown capacity (no realloc next step)
        for (v, &c) in ti.index.iter().zip(&caps) {
            assert!(v.capacity() >= c);
        }
    }

    #[test]
    fn empty_expert_has_empty_list() {
        let ti = TokenIndex::build(4, &[(0, 1), (1, 1)]);
        assert_eq!(ti.counts(), vec![0, 2, 0, 0]);
    }
}
