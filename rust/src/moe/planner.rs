//! MoE as a [`Workload`]: routing counts → [`ExecutionPlan`].
//!
//! This is the host-side step the paper performs each inference iteration
//! after the token route: decide which experts are non-empty (σ), order them
//! (Section 4.2), pick a tiling strategy per expert (Section 4), and build
//! the compressed TilePrefix (Algorithm 1).  All of that machinery is the
//! workload-generic [`crate::workload::plan::Planner`]; this module
//! contributes [`MoeWorkload`] — the decomposition of an [`ExpertLoad`]
//! into per-expert GEMM tasks — and the MoE-specific plan accessors.  The
//! resulting plan is consumed by three different executors, all driving
//! identical mappings:
//!
//! * the GPU simulator ([`crate::sim::kernel_sim`]) for the paper's
//!   performance experiments,
//! * the CPU numeric executor ([`crate::moe::cpu_exec`]) for correctness,
//! * the serving engine, which converts it to the metadata tensors the AOT
//!   Pallas kernel takes (same arrays the jnp planner produces — the Python
//!   hypothesis suite and the Rust proptest suite pin both to Algorithm 1/4).

use crate::batching::task::{TaskDescriptor, TaskKind};
use crate::moe::config::MoeShape;
use crate::moe::routing::ExpertLoad;
use crate::moe::tiling::{self, StrategyId, CATALOG};
use crate::sim::cost::Dtype;
use crate::workload::Workload;

pub use crate::workload::plan::{Plan, Planner};

/// One expert's GEMM task in the plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpertTask {
    /// Real expert id.
    pub expert: u32,
    /// Tokens routed to this expert (GEMM M dim). 0 = empty.
    pub rows: usize,
    /// Index into the tiling catalog.
    pub strategy: StrategyId,
}

impl ExpertTask {
    /// The task descriptor under `shape`: tile geometry from the strategy
    /// catalog, GEMM dims from the shape — everything a dispatch table or
    /// mapping needs, derived without a planner.
    pub fn descriptor(&self, shape: &MoeShape) -> TaskDescriptor {
        let s = CATALOG[self.strategy];
        TaskDescriptor {
            kind: TaskKind::Gemm { strategy: self.strategy },
            rows: self.rows,
            cols: shape.d_ff,
            inner: shape.d_model,
            tile_rows: s.tm,
            tile_cols: s.tn,
        }
    }
}

/// The MoE expert-GEMM batch as a [`Workload`]: one task per expert, with
/// the per-expert tiling selection and the per-expert-count cache
/// signature the paper's application section describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MoeWorkload {
    pub shape: MoeShape,
}

impl MoeWorkload {
    pub fn new(shape: MoeShape) -> Self {
        MoeWorkload { shape }
    }
}

impl Workload for MoeWorkload {
    type Load = ExpertLoad;
    type Task = ExpertTask;
    type Inputs = crate::exec::backend::NumericInputs;

    fn name(&self) -> &'static str {
        "moe"
    }

    fn tasks(&self, load: &ExpertLoad, force_strategy: Option<StrategyId>) -> Vec<ExpertTask> {
        assert_eq!(load.counts.len(), self.shape.experts);
        load.counts
            .iter()
            .enumerate()
            .map(|(e, &rows)| {
                let strategy = force_strategy.unwrap_or_else(|| {
                    if rows > 0 {
                        tiling::select(rows)
                    } else {
                        CATALOG.len() - 1
                    }
                });
                ExpertTask { expert: e as u32, rows, strategy }
            })
            .collect()
    }

    fn descriptor(&self, task: &ExpertTask) -> TaskDescriptor {
        task.descriptor(&self.shape)
    }

    fn weight(&self, task: &ExpertTask) -> usize {
        task.rows
    }

    fn signature_into(&self, load: &ExpertLoad, out: &mut Vec<u64>) {
        out.clear();
        out.extend(load.counts.iter().map(|&c| c as u64));
    }

    fn dtype(&self) -> Dtype {
        self.shape.dtype()
    }

    fn operand_bytes(&self, tasks: &[ExpertTask]) -> f64 {
        // weights of the non-empty experts + the full routed token/output
        // traffic of the step (shape-derived, like the kernel staging does)
        let s = self.shape;
        let nonempty = tasks.iter().filter(|t| t.rows > 0).count();
        let weights = nonempty as f64 * s.weight_bytes() as f64;
        let tokens = (s.total_rows() * s.d_model * s.dtype_bytes) as f64;
        let outs = (s.total_rows() * s.d_ff * s.dtype_bytes) as f64;
        weights + tokens + outs
    }
}

/// The static batch plan for one MoE step.
pub type ExecutionPlan = Plan<MoeWorkload>;

impl Planner<MoeWorkload> {
    /// An MoE planner for `shape` (half-interval ordering, per-task tiling).
    pub fn new(shape: MoeShape) -> Self {
        Planner::for_workload(MoeWorkload::new(shape))
    }

    /// The MoE problem shape this planner plans for.
    pub fn shape(&self) -> MoeShape {
        self.workload().shape
    }
}

impl Plan<MoeWorkload> {
    /// The MoE problem shape this plan batches.
    pub fn shape(&self) -> MoeShape {
        self.workload.shape
    }

    /// Reconstruct the routing outcome this plan was built from (baseline
    /// backends re-plan it with their own tiling/scheduling defects).
    pub fn expert_load(&self) -> ExpertLoad {
        let mut counts = vec![0usize; self.workload.shape.experts];
        for t in &self.tasks {
            counts[t.expert as usize] = t.rows;
        }
        ExpertLoad { counts }
    }

    /// Metadata bytes shipped to the device per step (σ + prefix + token
    /// index arrays).
    pub fn metadata_bytes(&self) -> usize {
        self.two_stage.metadata_bytes() + 4 * self.workload.shape.total_rows()
    }

    /// Useful FLOPs in this plan.
    pub fn useful_flops(&self) -> f64 {
        let s = self.workload.shape;
        self.tasks
            .iter()
            .map(|t| 2.0 * t.rows as f64 * s.d_ff as f64 * s.d_model as f64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ordering::OrderingStrategy;
    use crate::moe::routing::LoadScenario;
    use crate::util::prop;

    fn shape() -> MoeShape {
        MoeShape::paper_table1()
    }

    #[test]
    fn balanced_plan_uses_big_tiles_everywhere() {
        let load = LoadScenario::Balanced.counts(&shape(), 0);
        let plan = Planner::new(shape()).plan(&load);
        assert_eq!(plan.num_nonempty(), 64);
        assert!(plan.tasks[..64].iter().all(|t| CATALOG[t.strategy].tm == 128));
        // 512 rows -> 4 m-tiles x (2560/256=10) n-tiles = 40 tiles/expert
        assert_eq!(plan.total_tiles(), 64 * 40);
    }

    #[test]
    fn best_plan_elides_empty_experts() {
        let load = LoadScenario::Best.counts(&shape(), 0);
        let plan = Planner::new(shape()).plan(&load);
        assert_eq!(plan.num_nonempty(), 8);
        // empty experts appended after the non-empty prefix
        assert!(plan.tasks[8..].iter().all(|t| t.rows == 0));
        // 4096 rows: 32 m-tiles x 10 n-tiles = 320 tiles x 8 experts
        assert_eq!(plan.total_tiles(), 8 * 320);
    }

    #[test]
    fn worst_plan_mixes_strategies() {
        let load = LoadScenario::Worst.counts(&shape(), 0);
        let plan = Planner::new(shape()).plan(&load);
        let strategies: std::collections::BTreeSet<usize> =
            plan.tasks.iter().filter(|t| t.rows > 0).map(|t| t.strategy).collect();
        assert!(strategies.len() >= 2, "should mix big and small tiles");
        // single-token experts get the smallest tile
        for t in plan.tasks.iter().filter(|t| t.rows == 1) {
            assert_eq!(CATALOG[t.strategy].tm, 16);
        }
    }

    #[test]
    fn forced_single_strategy_applies_everywhere() {
        let load = LoadScenario::Worst.counts(&shape(), 0);
        let plan = Planner::new(shape()).with_single_strategy(0).plan(&load);
        assert!(plan.tasks.iter().all(|t| t.strategy == 0));
    }

    #[test]
    fn ordering_changes_grid_order_not_content() {
        let load = LoadScenario::Zipf(1.5).counts(&shape(), 3);
        let a = Planner::new(shape()).with_ordering(OrderingStrategy::Natural).plan(&load);
        let b = Planner::new(shape()).with_ordering(OrderingStrategy::HalfInterval).plan(&load);
        assert_eq!(a.total_tiles(), b.total_tiles());
        let mut ea: Vec<u32> = a.tasks.iter().map(|t| t.expert).collect();
        let mut eb: Vec<u32> = b.tasks.iter().map(|t| t.expert).collect();
        ea.sort_unstable();
        eb.sort_unstable();
        assert_eq!(ea, eb);
    }

    #[test]
    fn useful_flops_independent_of_routing() {
        let s = shape();
        for sc in [LoadScenario::Balanced, LoadScenario::Best, LoadScenario::Worst] {
            let plan = Planner::new(s).plan(&sc.counts(&s, 0));
            assert!((plan.useful_flops() - s.total_flops()).abs() / s.total_flops() < 1e-12);
        }
    }

    #[test]
    fn planner_setters_are_the_only_mutation_path() {
        // the pre-0.3 stale-cache hole was direct field mutation; fields
        // are private now and the setters observably change the next plan
        let load = LoadScenario::Worst.counts(&shape(), 0);
        let mut p = Planner::new(shape());
        p.set_force_strategy(Some(0));
        assert_eq!(p.force_strategy(), Some(0));
        assert!(p.plan(&load).tasks.iter().all(|t| t.strategy == 0));
        p.set_force_strategy(None);
        p.set_ordering(OrderingStrategy::SortedDesc);
        assert_eq!(p.ordering(), OrderingStrategy::SortedDesc);
        let plan = p.plan(&load);
        // sorted-desc: row counts non-increasing over the non-empty prefix
        let rows: Vec<usize> =
            plan.tasks[..plan.num_nonempty()].iter().map(|t| t.rows).collect();
        assert!(rows.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn property_plan_covers_all_rows_without_duplicates() {
        prop::check(
            "planner-coverage",
            100,
            |g| {
                let e = 1 + g.rng.usize_below(64);
                let mut counts = vec![0usize; e];
                let rows = g.rng.usize_below(g.size * 64 + 1);
                for _ in 0..rows {
                    let i = g.rng.usize_below(e);
                    counts[i] += 1;
                }
                counts
            },
            |counts| {
                let e = counts.len();
                let shape = MoeShape {
                    seq: counts.iter().sum::<usize>().max(1),
                    d_model: 64,
                    d_ff: 256,
                    experts: e,
                    top_k: 1,
                    dtype_bytes: 2,
                };
                let load = ExpertLoad { counts: counts.clone() };
                let plan = Planner::new(shape).plan(&load);
                // every non-empty expert appears exactly once, with its rows
                let mut seen = std::collections::BTreeMap::new();
                for t in &plan.tasks {
                    if seen.insert(t.expert, t.rows).is_some() {
                        return Err(format!("expert {} duplicated", t.expert));
                    }
                }
                if seen.len() != e {
                    return Err(format!("expected {e} tasks, got {}", seen.len()));
                }
                for (ex, &c) in counts.iter().enumerate() {
                    if seen.get(&(ex as u32)) != Some(&c) {
                        return Err(format!("expert {ex} rows mismatch"));
                    }
                }
                // tile math: blocks from the mapping must cover each task's
                // descriptor tile count
                let desc = plan.descriptors();
                let mut per_task = vec![0u32; desc.len()];
                for b in 0..plan.total_tiles() {
                    per_task[plan.two_stage.map(b).task as usize] += 1;
                }
                for (i, d) in desc.iter().enumerate() {
                    if per_task[i] != d.num_tiles() as u32 {
                        return Err(format!("task {i} tiles {} != {}", per_task[i], d.num_tiles()));
                    }
                }
                Ok(())
            },
        );
    }
}
