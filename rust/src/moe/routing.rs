//! Expert-load scenarios and routing generation.
//!
//! The paper's Section 5 evaluates three named scenarios; real serving sees
//! a continuum of imbalance, which the zipf/dirichlet generators cover for
//! the sweep experiments.

use crate::moe::config::MoeShape;
use crate::util::rng::{zipf_weights, Rng};

/// A routing outcome: how many (token, slot) rows each expert received.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExpertLoad {
    pub counts: Vec<usize>,
}

impl ExpertLoad {
    pub fn total(&self) -> usize {
        self.counts.iter().sum()
    }

    pub fn num_empty(&self) -> usize {
        self.counts.iter().filter(|&&c| c == 0).count()
    }

    pub fn max(&self) -> usize {
        self.counts.iter().copied().max().unwrap_or(0)
    }

    /// Load-imbalance factor: max/mean over non-empty experts.
    pub fn imbalance(&self) -> f64 {
        let nonzero: Vec<usize> = self.counts.iter().copied().filter(|&c| c > 0).collect();
        if nonzero.is_empty() {
            return 0.0;
        }
        let mean = nonzero.iter().sum::<usize>() as f64 / nonzero.len() as f64;
        self.max() as f64 / mean
    }
}

/// Named load scenarios.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LoadScenario {
    /// Tokens averagely routed to all experts (paper case 1).
    Balanced,
    /// All tokens routed to the same `top_k` experts (paper case 2).
    Best,
    /// Nearly all tokens to the same `top_k` experts; every other expert
    /// receives exactly one token (paper case 3).
    Worst,
    /// Zipf-distributed expert popularity with exponent alpha.
    Zipf(f64),
    /// Dirichlet-distributed expert shares with concentration alpha
    /// (alpha -> inf = balanced; alpha < 1 = spiky).
    Dirichlet(f64),
}

impl LoadScenario {
    /// Generate per-expert row counts for a shape. Deterministic in `seed`.
    pub fn counts(&self, shape: &MoeShape, seed: u64) -> ExpertLoad {
        let e = shape.experts;
        let total = shape.total_rows();
        let mut counts = vec![0usize; e];
        match *self {
            LoadScenario::Balanced => {
                for i in 0..total {
                    counts[i % e] += 1;
                }
            }
            LoadScenario::Best => {
                // all rows on the first top_k experts, evenly
                for i in 0..total {
                    counts[i % shape.top_k] += 1;
                }
            }
            LoadScenario::Worst => {
                // one token on each non-hot expert, the rest on the hot k
                let cold = e - shape.top_k;
                for (j, c) in counts.iter_mut().enumerate().skip(shape.top_k).take(cold) {
                    let _ = j;
                    *c = 1;
                }
                let remaining = total - cold;
                for i in 0..remaining {
                    counts[i % shape.top_k] += 1;
                }
            }
            LoadScenario::Zipf(alpha) => {
                let mut rng = Rng::new(seed);
                let w = zipf_weights(e, alpha);
                // random expert popularity permutation so rank != index
                let mut perm: Vec<usize> = (0..e).collect();
                rng.shuffle(&mut perm);
                for _ in 0..total {
                    counts[perm[rng.zipf(&w)]] += 1;
                }
            }
            LoadScenario::Dirichlet(alpha) => {
                let mut rng = Rng::new(seed);
                let shares = rng.dirichlet(alpha, e);
                // multinomial via repeated categorical draws
                for _ in 0..total {
                    let mut u = rng.f64();
                    let mut chosen = e - 1;
                    for (i, &s) in shares.iter().enumerate() {
                        if u < s {
                            chosen = i;
                            break;
                        }
                        u -= s;
                    }
                    counts[chosen] += 1;
                }
            }
        }
        ExpertLoad { counts }
    }

    pub fn name(&self) -> String {
        match self {
            LoadScenario::Balanced => "balanced".into(),
            LoadScenario::Best => "best".into(),
            LoadScenario::Worst => "worst".into(),
            LoadScenario::Zipf(a) => format!("zipf({a})"),
            LoadScenario::Dirichlet(a) => format!("dirichlet({a})"),
        }
    }
}

/// Simulated top-k router over real token activations is on the Python side;
/// here we also provide a synthetic per-token assignment consistent with an
/// [`ExpertLoad`] for the CPU executor: round-robin filling of expert slots.
pub fn assignments_from_counts(load: &ExpertLoad, seed: u64) -> Vec<Vec<u32>> {
    // produce, per expert, the list of token row ids routed to it
    let mut rng = Rng::new(seed ^ 0xA55A);
    let total: usize = load.total();
    let mut rows: Vec<u32> = (0..total as u32).collect();
    rng.shuffle(&mut rows);
    let mut out = Vec::with_capacity(load.counts.len());
    let mut cursor = 0;
    for &c in &load.counts {
        out.push(rows[cursor..cursor + c].to_vec());
        cursor += c;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> MoeShape {
        MoeShape::paper_table1()
    }

    #[test]
    fn balanced_is_flat() {
        let load = LoadScenario::Balanced.counts(&shape(), 0);
        assert_eq!(load.total(), 4096 * 8);
        assert!(load.counts.iter().all(|&c| c == 512));
        assert_eq!(load.num_empty(), 0);
        assert!((load.imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn best_uses_only_k_experts() {
        let load = LoadScenario::Best.counts(&shape(), 0);
        assert_eq!(load.num_empty(), 64 - 8);
        assert_eq!(load.total(), 4096 * 8);
        assert!(load.counts[..8].iter().all(|&c| c == 4096));
    }

    #[test]
    fn worst_has_56_single_token_experts() {
        let load = LoadScenario::Worst.counts(&shape(), 0);
        assert_eq!(load.total(), 4096 * 8);
        assert_eq!(load.counts.iter().filter(|&&c| c == 1).count(), 56);
        assert_eq!(load.num_empty(), 0);
        assert!(load.counts[..8].iter().all(|&c| c >= 4089 / 2));
    }

    #[test]
    fn zipf_is_skewed_and_mass_conserving() {
        let load = LoadScenario::Zipf(1.2).counts(&shape(), 7);
        assert_eq!(load.total(), 4096 * 8);
        assert!(load.imbalance() > 2.0, "imbalance {}", load.imbalance());
    }

    #[test]
    fn dirichlet_spiky_vs_flat() {
        let spiky = LoadScenario::Dirichlet(0.1).counts(&shape(), 3);
        let flat = LoadScenario::Dirichlet(100.0).counts(&shape(), 3);
        assert!(spiky.imbalance() > flat.imbalance());
        assert_eq!(spiky.total(), flat.total());
    }

    #[test]
    fn scenarios_deterministic_in_seed() {
        let a = LoadScenario::Zipf(1.0).counts(&shape(), 42);
        let b = LoadScenario::Zipf(1.0).counts(&shape(), 42);
        assert_eq!(a, b);
    }

    #[test]
    fn assignments_partition_rows() {
        let s = MoeShape::tiny();
        let load = LoadScenario::Balanced.counts(&s, 0);
        let asg = assignments_from_counts(&load, 0);
        let mut all: Vec<u32> = asg.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..load.total() as u32).collect::<Vec<_>>());
        for (e, rows) in asg.iter().enumerate() {
            assert_eq!(rows.len(), load.counts[e]);
        }
    }
}
