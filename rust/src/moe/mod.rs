//! MoE-specific application of the batching framework (paper Section 4).
//!
//! * [`config`] — problem shapes, including the paper's Table 1 setting.
//! * [`routing`] — expert-load scenarios (balanced / best / worst / zipf /
//!   dirichlet) and a top-k router simulation.
//! * [`token_index`] — per-expert token index arrays (Section 4.3), built
//!   with the atomic-scatter semantics of radix bucketing.
//! * [`tiling`] — the tiling-strategy catalog + per-expert selection
//!   (different tasks in one batch get different strategies, the framework's
//!   headline capability).
//! * [`ordering`] — expert ordering strategies (Section 4.2): natural,
//!   alternating, half-interval, random, sorted.
//! * [`planner`] — [`planner::MoeWorkload`], the MoE instance of the
//!   workload-generic planning stack ([`crate::workload`]): one GEMM task
//!   per expert, per-expert tiling selection, per-expert-count cache
//!   signature.  [`planner::ExecutionPlan`] — σ over non-empty experts,
//!   ordering, TilePrefix — is the one artifact every executor consumes.
//! * [`plan_cache`] — the MoE instantiation of the workload-generic LRU
//!   plan cache ([`crate::workload::cache`]), so serving traffic that
//!   repeats load shapes skips the σ / TilePrefix reconstruction.
//! * [`cpu_exec`] — executes a plan numerically on CPU *through the
//!   framework dispatch*, validating mapping + gather correctness against
//!   the dense reference.

pub mod config;
pub mod cpu_exec;
pub mod kernel_meta;
pub mod ordering;
pub mod parallel;
pub mod plan_cache;
pub mod planner;
pub mod routing;
pub mod tiling;
pub mod token_index;
