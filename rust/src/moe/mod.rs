//! MoE-specific application of the batching framework (paper Section 4).
//!
//! * [`config`] — problem shapes, including the paper's Table 1 setting.
//! * [`routing`] — expert-load scenarios (balanced / best / worst / zipf /
//!   dirichlet) and a top-k router simulation.
//! * [`token_index`] — per-expert token index arrays (Section 4.3), built
//!   with the atomic-scatter semantics of radix bucketing.
//! * [`tiling`] — the tiling-strategy catalog + per-expert selection
//!   (different tasks in one batch get different strategies, the framework's
//!   headline capability).
//! * [`ordering`] — expert ordering strategies (Section 4.2): natural,
//!   alternating, half-interval, random, sorted.
//! * [`planner`] — builds the [`planner::ExecutionPlan`]: σ over non-empty
//!   experts, ordering, per-expert tiling, TilePrefix — the one artifact
//!   both the simulator and the CPU executor consume.
//! * [`plan_cache`] — LRU cache from normalized load signature to built
//!   plan, so serving traffic that repeats load shapes skips the σ /
//!   TilePrefix reconstruction.
//! * [`cpu_exec`] — executes a plan numerically on CPU *through the
//!   framework dispatch*, validating mapping + gather correctness against
//!   the dense reference.

pub mod config;
pub mod cpu_exec;
pub mod kernel_meta;
pub mod ordering;
pub mod parallel;
pub mod plan_cache;
pub mod planner;
pub mod routing;
pub mod tiling;
pub mod token_index;
