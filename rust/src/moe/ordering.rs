//! Expert ordering strategies (paper Section 4.2).
//!
//! "The basic idea is to interleave busy experts with non-busy experts so
//! that a wave of thread blocks optimally contains both compute-bound and
//! memory-bound tasks. [...] In practice, the half-interval strategy shows
//! better performance."  The optimal ordering is NP-hard (the paper leaves
//! it as future work); these are the heuristics it names plus controls.

use crate::util::rng::Rng;

/// Which order non-empty experts are laid out in the fused grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrderingStrategy {
    /// Expert-index order (the control).
    Natural,
    /// Busy experts sorted descending (worst mixing — busy tiles clump).
    SortedDesc,
    /// Strictly alternate busy / non-busy from the two ends of the sorted
    /// list (paper: "alternating busy and non-busy experts").
    Alternating,
    /// Place busy experts at half-interval positions: the busiest at slot 0,
    /// the next at the midpoint, recursively — spreading compute-bound tasks
    /// evenly across the grid (paper: "arranging busy experts in a
    /// half-interval manner"; the strategy it found best).
    HalfInterval,
    /// Uniform random permutation (control).
    Random(u64),
}

impl OrderingStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            OrderingStrategy::Natural => "natural",
            OrderingStrategy::SortedDesc => "sorted-desc",
            OrderingStrategy::Alternating => "alternating",
            OrderingStrategy::HalfInterval => "half-interval",
            OrderingStrategy::Random(_) => "random",
        }
    }

    /// Order the given (expert, rows) pairs; returns expert ids.
    /// Only call with non-empty experts (the planner filters first).
    pub fn order(&self, loads: &[(u32, usize)]) -> Vec<u32> {
        match *self {
            OrderingStrategy::Natural => loads.iter().map(|&(e, _)| e).collect(),
            OrderingStrategy::SortedDesc => {
                let mut v = loads.to_vec();
                v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                v.into_iter().map(|(e, _)| e).collect()
            }
            OrderingStrategy::Alternating => {
                let mut v = loads.to_vec();
                v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                let mut out = Vec::with_capacity(v.len());
                let (mut lo, mut hi) = (0usize, v.len());
                // take from the busy end and the idle end alternately
                let mut take_busy = true;
                while lo < hi {
                    if take_busy {
                        out.push(v[lo].0);
                        lo += 1;
                    } else {
                        hi -= 1;
                        out.push(v[hi].0);
                    }
                    take_busy = !take_busy;
                }
                out
            }
            OrderingStrategy::HalfInterval => {
                let mut v = loads.to_vec();
                v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                let n = v.len();
                let mut slots: Vec<Option<u32>> = vec![None; n];
                // visit slot offsets in bit-reversal order: 0, n/2, n/4,
                // 3n/4, ... — the "half-interval" recursive midpoint layout
                let order = bit_reversal_order(n);
                for (rank, slot) in order.into_iter().enumerate() {
                    slots[slot] = Some(v[rank].0);
                }
                slots.into_iter().map(|s| s.unwrap()).collect()
            }
            OrderingStrategy::Random(seed) => {
                let mut v: Vec<u32> = loads.iter().map(|&(e, _)| e).collect();
                Rng::new(seed).shuffle(&mut v);
                v
            }
        }
    }
}

/// Slot visit order by bit-reversed index, truncated to n (stable for any n).
fn bit_reversal_order(n: usize) -> Vec<usize> {
    if n == 0 {
        return Vec::new();
    }
    let bits = usize::BITS - (n - 1).leading_zeros().max(0);
    let bits = bits.max(1);
    let mut seen = vec![false; n];
    let mut out = Vec::with_capacity(n);
    for i in 0..(1usize << bits) {
        let r = reverse_bits(i, bits);
        if r < n && !seen[r] {
            seen[r] = true;
            out.push(r);
        }
    }
    // any slots missed (non-power-of-two n): append in order
    for (i, s) in seen.iter().enumerate() {
        if !s {
            out.push(i);
        }
    }
    out
}

fn reverse_bits(x: usize, bits: u32) -> usize {
    let mut r = 0usize;
    for b in 0..bits {
        if x & (1 << b) != 0 {
            r |= 1 << (bits - 1 - b);
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads() -> Vec<(u32, usize)> {
        // experts 0..7 with descending busyness 800, 400, 200, 100, 4, 3, 2, 1
        vec![
            (0, 800),
            (1, 400),
            (2, 200),
            (3, 100),
            (4, 4),
            (5, 3),
            (6, 2),
            (7, 1),
        ]
    }

    #[test]
    fn natural_preserves_input() {
        let o = OrderingStrategy::Natural.order(&loads());
        assert_eq!(o, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn sorted_desc_by_load() {
        let mut l = loads();
        l.reverse();
        let o = OrderingStrategy::SortedDesc.order(&l);
        assert_eq!(o, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn alternating_interleaves_ends() {
        let o = OrderingStrategy::Alternating.order(&loads());
        // busy, idle, busy, idle...
        assert_eq!(o, vec![0, 7, 1, 6, 2, 5, 3, 4]);
    }

    #[test]
    fn half_interval_spreads_busy() {
        let o = OrderingStrategy::HalfInterval.order(&loads());
        // busiest at 0, second-busiest at midpoint
        assert_eq!(o[0], 0);
        assert_eq!(o[4], 1);
        // all experts present exactly once
        let mut sorted = o.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<u32>>());
    }

    #[test]
    fn half_interval_non_power_of_two() {
        let l: Vec<(u32, usize)> = (0..7).map(|e| (e, 100 - e as usize)).collect();
        let o = OrderingStrategy::HalfInterval.order(&l);
        let mut sorted = o.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..7).collect::<Vec<u32>>());
    }

    #[test]
    fn random_is_permutation_and_seeded() {
        let a = OrderingStrategy::Random(9).order(&loads());
        let b = OrderingStrategy::Random(9).order(&loads());
        assert_eq!(a, b);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<u32>>());
    }

    #[test]
    fn single_expert_all_strategies() {
        let l = vec![(3u32, 42usize)];
        for s in [
            OrderingStrategy::Natural,
            OrderingStrategy::SortedDesc,
            OrderingStrategy::Alternating,
            OrderingStrategy::HalfInterval,
            OrderingStrategy::Random(1),
        ] {
            assert_eq!(s.order(&l), vec![3]);
        }
    }
}
