//! CPU numeric executor: runs an [`ExecutionPlan`] *through the framework
//! dispatch* (Algorithm 3/4) on real tensors.
//!
//! This is the end-to-end correctness oracle for the Rust side: the same
//! block→(task, tile) mappings the simulator charges costs for here produce
//! actual numbers, gathered through token index arrays exactly like the
//! Pallas kernel, and are checked against a dense reference.
//!
//! Call sites reach this through [`crate::exec::CpuBackend`]; the functions
//! here are the numeric core that backend wraps.

use crate::batching::dispatch::{DispatchError, DispatchRecord, DispatchTableBuilder};
use crate::batching::framework::StaticBatch;
use crate::batching::task::{TaskDescriptor, TaskKind};
use crate::exec::error::ExecError;
use crate::moe::planner::{ExecutionPlan, ExpertTask};
use crate::moe::tiling::CATALOG;
use crate::moe::token_index::TokenIndex;
use crate::util::tensor::{gathered_matmul_into, Tensor};
use crate::util::threadpool::ThreadPool;

/// Inputs of one MoE step on CPU.
pub struct MoeInputs<'a> {
    /// `[seq, d_model]` original token sequence (never copied).
    pub tokens: &'a Tensor,
    /// `[experts, d_model, d_ff]` expert weights.
    pub weights: &'a Tensor,
    /// Token index arrays per expert (Section 4.3).
    pub token_index: &'a TokenIndex,
    /// Combine gate per (expert, position) — aligned with `token_index`.
    pub gates: &'a [Vec<f32>],
}

struct ExecCtx<'a> {
    inputs: &'a MoeInputs<'a>,
    plan: &'a ExecutionPlan,
    /// packed per-expert output rows, grid order, no tile padding
    packed: Vec<f32>,
    /// packed-row offset of each task (grid order)
    offsets: Vec<usize>,
    /// blocks executed per strategy (for assertions / stats)
    dispatch_counts: Vec<usize>,
    /// per-block dispatch sequence, recorded when requested
    trace: Option<Vec<DispatchRecord>>,
    /// tile-local scratch, reused across blocks
    scratch: GemmScratch,
}

/// Scratch buffers for one GEMM tile, reused across tiles via
/// `clear` + `resize` — bitwise-identical to fresh zeroed allocations, so
/// reuse never changes numerics.
#[derive(Default)]
pub(crate) struct GemmScratch {
    /// tile-local `[rows, cols]` output
    local: Vec<f32>,
    /// column-sliced `[k, cols]` weight view
    wslice: Vec<f32>,
}

/// Run one GEMM tile of `task` into its task-relative packed `region`
/// (`[task.rows, d_ff]`, row-major).  The single numeric tile body shared
/// by the serial framework dispatch and [`execute_parallel`]: both visit a
/// task's tiles in ascending order and call this, so their packed regions
/// are bit-identical.
pub(crate) fn run_gemm_tile(
    inputs: &MoeInputs,
    task: &ExpertTask,
    desc: &TaskDescriptor,
    tile_idx: u32,
    region: &mut [f32],
    scratch: &mut GemmScratch,
) {
    let d_ff = desc.cols;
    let k = desc.inner;
    let tiles_n = desc.tiles_n() as u32;
    let (mi, ni) = (tile_idx / tiles_n, tile_idx % tiles_n);
    let row0 = mi as usize * desc.tile_rows;
    let col0 = ni as usize * desc.tile_cols;
    let rows = (task.rows - row0).min(desc.tile_rows);
    let cols = (d_ff - col0).min(desc.tile_cols);
    // gather indices for this tile's rows (token index array)
    let ids = &inputs.token_index.index[task.expert as usize][row0..row0 + rows];
    // weight plane slice [d_model, col0..col0+cols]
    let w = inputs.weights.plane(task.expert as usize);
    // tile-local output, then scatter into the packed region
    scratch.local.clear();
    scratch.local.resize(rows * cols, 0.0);
    // build a column-sliced weight view: w is [k, d_ff]; we need [k, cols]
    // starting at col0 — copy the slice once per tile (models the VMEM
    // block the Pallas kernel stages).
    scratch.wslice.clear();
    scratch.wslice.resize(k * cols, 0.0);
    for kk in 0..k {
        scratch.wslice[kk * cols..(kk + 1) * cols]
            .copy_from_slice(&w[kk * d_ff + col0..kk * d_ff + col0 + cols]);
    }
    gathered_matmul_into(inputs.tokens, ids, &scratch.wslice, cols, &mut scratch.local);
    for r in 0..rows {
        let dst = (row0 + r) * d_ff + col0;
        region[dst..dst + cols].copy_from_slice(&scratch.local[r * cols..(r + 1) * cols]);
    }
}

/// Grid-order gated combine: `out[token] += gate · packed_row`, reading
/// each task's packed rows from `regions[ti]` (`[task.rows, d_ff]`).
/// Shared by the serial and parallel executors — same traversal order,
/// same float additions, so the two paths agree bitwise.
fn combine_regions(plan: &ExecutionPlan, inputs: &MoeInputs, regions: &[&[f32]]) -> Tensor {
    combine_task_regions(&plan.tasks, plan.shape().seq, plan.shape().d_ff, inputs, regions)
}

/// The combine loop behind [`combine_regions`], parameterised on the expert
/// task slice so heterogeneous plans (fused transformer layer) can reuse it
/// on just their GEMM-phase tasks.  Walks tasks in the given (grid) order —
/// the float addition order, and therefore the bitwise result, is fully
/// determined by that order.
pub(crate) fn combine_task_regions(
    tasks: &[ExpertTask],
    seq: usize,
    d_ff: usize,
    inputs: &MoeInputs,
    regions: &[&[f32]],
) -> Tensor {
    let mut out = Tensor::zeros(&[seq, d_ff]);
    for (ti, task) in tasks.iter().enumerate() {
        let e = task.expert as usize;
        for (pos, &tok) in inputs.token_index.index[e].iter().enumerate() {
            let g = inputs.gates[e][pos];
            let src = &regions[ti][pos * d_ff..(pos + 1) * d_ff];
            let dst = out.row_mut(tok as usize);
            for (d, s) in dst.iter_mut().zip(src) {
                *d += g * s;
            }
        }
    }
    out
}

/// Execute the plan; returns `[seq, d_ff]` combined outputs.
///
/// Thin wrapper over [`execute_traced`] for call sites that don't need the
/// dispatch trace.  The dispatch table is built over the full tiling
/// catalog, so coverage of any planner-produced batch is guaranteed.
pub fn execute(plan: &ExecutionPlan, inputs: &MoeInputs) -> Tensor {
    let (out, _) = execute_traced(plan, inputs, false)
        .expect("dispatch table covers the whole tiling catalog");
    out
}

/// Execute the plan, optionally recording the per-block dispatch sequence.
///
/// Every tile goes through `StaticBatch::run` — block index → Algorithm 4
/// mapping → strategy-specific device function — so a mapping bug corrupts
/// numerics and the tests catch it.  The returned trace (when requested)
/// is the actually-dispatched sequence, which cross-backend tests compare
/// against the simulator's mapping decode.
pub fn execute_traced(
    plan: &ExecutionPlan,
    inputs: &MoeInputs,
    record_dispatch: bool,
) -> Result<(Tensor, Option<Vec<DispatchRecord>>), DispatchError> {
    let shape = plan.shape();
    let d_ff = shape.d_ff;

    // packed row offsets per task in grid order
    let mut offsets = Vec::with_capacity(plan.tasks.len());
    let mut acc = 0usize;
    for t in &plan.tasks {
        offsets.push(acc);
        acc += t.rows;
    }

    let mut builder: DispatchTableBuilder<ExecCtx> = DispatchTableBuilder::new();
    for (sid, _s) in CATALOG.iter().enumerate() {
        let kind = TaskKind::Gemm { strategy: sid };
        builder = builder.on(kind, move |ctx: &mut ExecCtx, desc, task_idx, tile_idx| {
            ctx.dispatch_counts[sid] += 1;
            if let Some(trace) = ctx.trace.as_mut() {
                trace.push(DispatchRecord { task: task_idx, tile: tile_idx, kind: desc.kind });
            }
            let task = ctx.plan.tasks[task_idx as usize];
            let d_ff = ctx.plan.shape().d_ff;
            let base = ctx.offsets[task_idx as usize];
            let region = &mut ctx.packed[base * d_ff..(base + task.rows) * d_ff];
            run_gemm_tile(ctx.inputs, &task, desc, tile_idx, region, &mut ctx.scratch);
        });
    }
    let batch = StaticBatch::try_new(plan.descriptors(), builder)?;

    let total_rows: usize = plan.tasks.iter().map(|t| t.rows).sum();
    let mut ctx = ExecCtx {
        inputs,
        plan,
        packed: vec![0.0; total_rows * d_ff],
        offsets,
        dispatch_counts: vec![0; CATALOG.len()],
        trace: record_dispatch.then(Vec::new),
        scratch: GemmScratch::default(),
    };
    let blocks = batch.run(&mut ctx);
    debug_assert_eq!(blocks, plan.total_tiles());

    let regions: Vec<&[f32]> = plan
        .tasks
        .iter()
        .enumerate()
        .map(|(ti, t)| &ctx.packed[ctx.offsets[ti] * d_ff..(ctx.offsets[ti] + t.rows) * d_ff])
        .collect();
    let out = combine_regions(plan, inputs, &regions);
    Ok((out, ctx.trace))
}

/// Execute the plan with per-task fan-out across `pool`'s workers.
///
/// Each worker job runs one chunk of tasks, visiting every task's tiles in
/// ascending order — exactly the order the serial grid walk visits them —
/// into an owned per-task region.  The combine then walks tasks in grid
/// order on the calling thread.  Identical tile bodies
/// ([`run_gemm_tile`]), identical per-task tile order, identical combine
/// order: the output is **bitwise-equal** to [`execute`], so parallelism
/// is purely a wall-clock knob.
///
/// A worker panic or pool shutdown surfaces as [`ExecError::Backend`]
/// instead of poisoning the calling thread, with the
/// [`crate::util::threadpool::PoolError`] preserved as the structured
/// error source — retry classification downcasts it rather than
/// string-matching, so a panic can never be mis-bucketed as transient.
pub fn execute_parallel(
    plan: &ExecutionPlan,
    inputs: &MoeInputs,
    pool: &ThreadPool,
) -> Result<Tensor, ExecError> {
    let d_ff = plan.shape().d_ff;
    let descs = plan.descriptors();
    let tasks = &plan.tasks;
    let descs_ref = &descs;
    let job = move |ti: usize| -> Vec<f32> {
        let task = tasks[ti];
        let desc = &descs_ref[ti];
        let mut region = vec![0.0f32; task.rows * d_ff];
        let mut scratch = GemmScratch::default();
        for tile in 0..desc.num_tiles() as u32 {
            run_gemm_tile(inputs, &task, desc, tile, &mut region, &mut scratch);
        }
        region
    };
    let indices: Vec<usize> = (0..plan.tasks.len()).collect();
    let chunk = pool.default_chunk(indices.len());
    let regions = pool
        .scoped_map_chunks(indices, chunk, job)
        .map_err(|e| ExecError::backend_caused("cpu", format!("worker pool: {e}"), e))?;
    let views: Vec<&[f32]> = regions.iter().map(|r| r.as_slice()).collect();
    Ok(combine_regions(plan, inputs, &views))
}

/// Dense reference: `out[t] = Σ_e gate(e,t) · tokens[t] @ W[e]` without any
/// packing, tiling, or mapping — the unambiguous oracle.
pub fn reference(inputs: &MoeInputs, seq: usize, d_model: usize, d_ff: usize) -> Tensor {
    let mut out = Tensor::zeros(&[seq, d_ff]);
    for (e, rows) in inputs.token_index.index.iter().enumerate() {
        let w = inputs.weights.plane(e);
        for (pos, &tok) in rows.iter().enumerate() {
            let g = inputs.gates[e][pos];
            let x = inputs.tokens.row(tok as usize);
            let dst = out.row_mut(tok as usize);
            for kk in 0..d_model {
                let a = x[kk] * g;
                if a == 0.0 {
                    continue;
                }
                let wrow = &w[kk * d_ff..(kk + 1) * d_ff];
                for j in 0..d_ff {
                    dst[j] += a * wrow[j];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::config::MoeShape;
    use crate::moe::ordering::OrderingStrategy;
    use crate::moe::planner::Planner;
    use crate::moe::routing::{ExpertLoad, LoadScenario};
    use crate::util::rng::Rng;

    fn setup(
        shape: MoeShape,
        load: &ExpertLoad,
        seed: u64,
    ) -> (Tensor, Tensor, TokenIndex, Vec<Vec<f32>>) {
        let mut rng = Rng::new(seed);
        let tokens = Tensor::randn(&[shape.seq, shape.d_model], 1.0, &mut rng);
        let weights = Tensor::randn(&[shape.experts, shape.d_model, shape.d_ff], 0.1, &mut rng);
        // routing pairs: token ids cycle over the sequence per expert count
        let mut pairs = Vec::new();
        for (e, &c) in load.counts.iter().enumerate() {
            for i in 0..c {
                let tok = rng.usize_below(shape.seq) as u32;
                let _ = i;
                pairs.push((tok, e as u32));
            }
        }
        let ti = TokenIndex::build(shape.experts, &pairs);
        let gates: Vec<Vec<f32>> = ti
            .index
            .iter()
            .map(|rows| rows.iter().map(|_| rng.f32() * 0.5 + 0.25).collect())
            .collect();
        (tokens, weights, ti, gates)
    }

    fn check(shape: MoeShape, load: &ExpertLoad, ordering: OrderingStrategy, seed: u64) {
        let (tokens, weights, ti, gates) = setup(shape, load, seed);
        let inputs = MoeInputs { tokens: &tokens, weights: &weights, token_index: &ti, gates: &gates };
        let plan = Planner::new(shape).with_ordering(ordering).plan(load);
        let got = execute(&plan, &inputs);
        let want = reference(&inputs, shape.seq, shape.d_model, shape.d_ff);
        let err = got.max_abs_diff(&want);
        assert!(err < 1e-3, "max abs err {err}");
    }

    #[test]
    fn random_load_matches_reference() {
        let shape = MoeShape::tiny();
        let load = LoadScenario::Dirichlet(1.0).counts(&shape, 3);
        check(shape, &load, OrderingStrategy::HalfInterval, 1);
    }

    #[test]
    fn empty_experts_handled() {
        let shape = MoeShape::tiny();
        let load = LoadScenario::Best.counts(&shape, 0);
        assert!(load.num_empty() > 0);
        check(shape, &load, OrderingStrategy::Natural, 2);
    }

    #[test]
    fn worst_case_mixed_strategies() {
        let shape = MoeShape { seq: 128, d_model: 24, d_ff: 40, experts: 16, top_k: 4, dtype_bytes: 4 };
        let load = LoadScenario::Worst.counts(&shape, 0);
        check(shape, &load, OrderingStrategy::HalfInterval, 3);
    }

    #[test]
    fn all_orderings_same_numerics() {
        let shape = MoeShape::tiny();
        let load = LoadScenario::Zipf(1.0).counts(&shape, 9);
        let (tokens, weights, ti, gates) = setup(shape, &load, 4);
        let inputs = MoeInputs { tokens: &tokens, weights: &weights, token_index: &ti, gates: &gates };
        let mut results = Vec::new();
        for ord in [
            OrderingStrategy::Natural,
            OrderingStrategy::Alternating,
            OrderingStrategy::HalfInterval,
            OrderingStrategy::SortedDesc,
            OrderingStrategy::Random(5),
        ] {
            let plan = Planner::new(shape).with_ordering(ord).plan(&load);
            results.push(execute(&plan, &inputs));
        }
        for r in &results[1..] {
            assert!(r.max_abs_diff(&results[0]) < 1e-4);
        }
    }

    #[test]
    fn zero_gate_contributes_nothing() {
        let shape = MoeShape::tiny();
        let load = LoadScenario::Balanced.counts(&shape, 0);
        let (tokens, weights, ti, mut gates) = setup(shape, &load, 5);
        // zero out one expert's gates entirely
        for g in &mut gates[2] {
            *g = 0.0;
        }
        let inputs = MoeInputs { tokens: &tokens, weights: &weights, token_index: &ti, gates: &gates };
        let plan = Planner::new(shape).plan(&load);
        let got = execute(&plan, &inputs);
        let want = reference(&inputs, shape.seq, shape.d_model, shape.d_ff);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let shape =
            MoeShape { seq: 96, d_model: 24, d_ff: 40, experts: 16, top_k: 4, dtype_bytes: 4 };
        let load = LoadScenario::Worst.counts(&shape, 0);
        let (tokens, weights, ti, gates) = setup(shape, &load, 8);
        let inputs =
            MoeInputs { tokens: &tokens, weights: &weights, token_index: &ti, gates: &gates };
        let plan = Planner::new(shape).plan(&load);
        let serial = execute(&plan, &inputs);
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            let par = execute_parallel(&plan, &inputs, &pool).unwrap();
            assert_eq!(serial.shape, par.shape);
            assert_eq!(serial.data, par.data, "threads={threads}");
        }
    }

    #[test]
    fn trace_matches_mapping_decode() {
        let shape = MoeShape::tiny();
        let load = LoadScenario::Zipf(1.2).counts(&shape, 6);
        let (tokens, weights, ti, gates) = setup(shape, &load, 6);
        let inputs = MoeInputs { tokens: &tokens, weights: &weights, token_index: &ti, gates: &gates };
        let plan = Planner::new(shape).plan(&load);
        let (_, trace) = execute_traced(&plan, &inputs, true).unwrap();
        let trace = trace.expect("requested");
        assert_eq!(trace.len() as u32, plan.total_tiles());
        let descs = plan.descriptors();
        for (block, r) in trace.iter().enumerate() {
            let m = plan.two_stage.map(block as u32);
            assert_eq!((r.task, r.tile), (m.task, m.tile));
            assert_eq!(r.kind, descs[m.task as usize].kind);
        }
    }
}
