//! Expert parallelism (EP) and tensor parallelism (TP) for the MoE layer
//! (paper Section 2.2).
//!
//! "TP splits each expert weight into several parts, and each GPU holds a
//! part of every expert weight.  In terms of EP, a subset of experts reside
//! on each GPU.  For both TP and EP with more than one expert per GPU, the
//! MoE computation is an irregular workload from the perspective of each
//! GPU [...] In practice, TP and EP can be combined."
//!
//! This module partitions a routing outcome across a `(ep, tp)` device
//! grid, produces the per-GPU [`MoeShape`]/[`ExpertLoad`] sub-problems that
//! the planner + simulator consume unchanged, and models the collective
//! costs each scheme pays (EP: all-to-all token exchange; TP: all-reduce of
//! partial outputs).  The multi-GPU step time is the slowest GPU plus its
//! collectives — which is how EP converts expert-load imbalance into
//! *device*-load imbalance, the effect the `multi_gpu` bench sweeps.

use crate::moe::config::MoeShape;
use crate::moe::routing::ExpertLoad;
use crate::sim::specs::GpuSpec;

/// A parallel configuration over `ep * tp` identical GPUs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParallelConfig {
    /// Expert-parallel ways: experts are sharded into `ep` groups.
    pub ep: usize,
    /// Tensor-parallel ways: every expert weight's d_ff is split `tp` ways.
    pub tp: usize,
    /// Interconnect bandwidth per GPU, GB/s (NVLink-class default).
    pub link_gbps: f64,
    /// Per-collective base latency, microseconds.
    pub coll_latency_us: f64,
}

impl ParallelConfig {
    /// An `ep x tp` grid with NVLink-class interconnect defaults.
    pub fn new(ep: usize, tp: usize) -> Self {
        ParallelConfig { ep, tp, link_gbps: 200.0, coll_latency_us: 10.0 }
    }

    /// Total GPUs in the grid.
    pub fn gpus(&self) -> usize {
        self.ep * self.tp
    }

    /// EP all-to-all time for one step: every rank sends/receives its share
    /// of routed rows (`d_model`-wide activations), and the exchange
    /// completes when the slowest rank's volume (`max_rows_in`) lands.
    /// Zero when `ep == 1`.  Shared by [`simulate`] and the serving-side
    /// [`crate::serve::ShardedStepExecutor`].
    pub fn all_to_all_time_s(
        &self,
        max_rows_in: usize,
        d_model: usize,
        dtype_bytes: usize,
    ) -> f64 {
        if self.ep == 1 {
            return 0.0;
        }
        let bytes = (max_rows_in * d_model * dtype_bytes) as f64;
        self.coll_latency_us * 1e-6 + bytes / (self.link_gbps * 1e9)
    }

    /// TP ring all-reduce of the layer output across the TP group:
    /// `2 (tp-1)/tp` of the `tokens x d_model` output volume.  Zero when
    /// `tp == 1`.
    pub fn all_reduce_time_s(&self, tokens: usize, d_model: usize, dtype_bytes: usize) -> f64 {
        if self.tp == 1 {
            return 0.0;
        }
        let bytes = (tokens * d_model * dtype_bytes) as f64;
        let factor = 2.0 * (self.tp - 1) as f64 / self.tp as f64;
        self.coll_latency_us * 1e-6 + bytes * factor / (self.link_gbps * 1e9)
    }
}

/// The per-GPU sub-problem for one EP rank (shared by its TP group).
#[derive(Clone, Debug)]
pub struct RankProblem {
    pub ep_rank: usize,
    pub shape: MoeShape,
    pub load: ExpertLoad,
    /// Rows this rank receives from other ranks (all-to-all volume in).
    pub rows_in: usize,
}

/// Result of simulating one multi-GPU MoE step.
#[derive(Clone, Debug)]
pub struct MultiGpuResult {
    pub step_time_s: f64,
    /// Slowest rank's kernel time.
    pub critical_kernel_s: f64,
    pub all_to_all_s: f64,
    pub all_reduce_s: f64,
    /// Kernel time per EP rank (device-load imbalance made visible).
    pub rank_kernel_s: Vec<f64>,
    /// Aggregate useful TFLOPS across the device grid.
    pub total_tflops: f64,
}

/// Shard a routing outcome over the EP dimension (contiguous expert blocks,
/// the standard placement) and shrink shapes over TP.
pub fn partition(shape: &MoeShape, load: &ExpertLoad, cfg: &ParallelConfig) -> Vec<RankProblem> {
    assert!(shape.experts % cfg.ep == 0, "experts must divide ep");
    assert!(shape.d_ff % cfg.tp == 0, "d_ff must divide tp");
    let per = shape.experts / cfg.ep;
    (0..cfg.ep)
        .map(|r| {
            let counts: Vec<usize> = load.counts[r * per..(r + 1) * per].to_vec();
            let rows_in: usize = counts.iter().sum();
            let sub_shape = MoeShape {
                // the rank's token buffer is whatever was routed to it
                seq: rows_in.max(1),
                d_model: shape.d_model,
                d_ff: shape.d_ff / cfg.tp,
                experts: per,
                top_k: 1, // rows are already expanded per (token, choice)
                dtype_bytes: shape.dtype_bytes,
            };
            RankProblem { ep_rank: r, shape: sub_shape, load: ExpertLoad { counts }, rows_in }
        })
        .collect()
}

/// All-to-all time for a partitioned step: limited by the slowest rank's
/// received volume.
fn all_to_all_s(shape: &MoeShape, ranks: &[RankProblem], cfg: &ParallelConfig) -> f64 {
    let max_rows = ranks.iter().map(|r| r.rows_in).max().unwrap_or(0);
    cfg.all_to_all_time_s(max_rows, shape.d_model, shape.dtype_bytes)
}

/// TP all-reduce of the layer output across the TP group.
fn all_reduce_s(shape: &MoeShape, cfg: &ParallelConfig) -> f64 {
    cfg.all_reduce_time_s(shape.seq, shape.d_model, shape.dtype_bytes)
}

/// Simulate one MoE step across the device grid: per-rank kernels through
/// the full planner + simulator, plus collectives.
pub fn simulate(
    shape: &MoeShape,
    load: &ExpertLoad,
    cfg: &ParallelConfig,
    spec: &GpuSpec,
) -> MultiGpuResult {
    let ranks = partition(shape, load, cfg);
    let mut rank_kernel_s = Vec::with_capacity(cfg.ep);
    let mut useful_flops = 0.0;
    let mut backend = crate::exec::SimBackend::ours();
    for rank in &ranks {
        if rank.rows_in == 0 {
            rank_kernel_s.push(0.0);
            continue;
        }
        let out = crate::exec::ExecutionSession::new(rank.shape)
            .gpu(spec.clone())
            .run_on(&mut backend, &rank.load)
            .expect("sim backend");
        useful_flops += out.sim().useful_flops;
        rank_kernel_s.push(out.time_s());
    }
    let critical = rank_kernel_s.iter().cloned().fold(0.0, f64::max);
    let a2a = all_to_all_s(shape, &ranks, cfg);
    let ar = all_reduce_s(shape, cfg);
    let step = critical + a2a + ar;
    MultiGpuResult {
        step_time_s: step,
        critical_kernel_s: critical,
        all_to_all_s: a2a,
        all_reduce_s: ar,
        rank_kernel_s,
        total_tflops: if step > 0.0 { useful_flops / step / 1e12 } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::routing::LoadScenario;

    fn shape() -> MoeShape {
        MoeShape::paper_table1()
    }

    #[test]
    fn partition_preserves_rows_and_shapes() {
        let load = LoadScenario::Balanced.counts(&shape(), 0);
        let cfg = ParallelConfig::new(4, 2);
        let ranks = partition(&shape(), &load, &cfg);
        assert_eq!(ranks.len(), 4);
        let total: usize = ranks.iter().map(|r| r.rows_in).sum();
        assert_eq!(total, shape().total_rows());
        for r in &ranks {
            assert_eq!(r.shape.experts, 16);
            assert_eq!(r.shape.d_ff, 1280); // 2560 / tp 2
        }
    }

    #[test]
    fn ep1_tp1_has_no_collectives() {
        let load = LoadScenario::Balanced.counts(&shape(), 0);
        let cfg = ParallelConfig::new(1, 1);
        let r = simulate(&shape(), &load, &cfg, &GpuSpec::h800());
        assert_eq!(r.all_to_all_s, 0.0);
        assert_eq!(r.all_reduce_s, 0.0);
        assert!(r.step_time_s > 0.0);
    }

    #[test]
    fn ep_scales_balanced_load() {
        let load = LoadScenario::Balanced.counts(&shape(), 0);
        let spec = GpuSpec::h800();
        let r1 = simulate(&shape(), &load, &ParallelConfig::new(1, 1), &spec);
        let r4 = simulate(&shape(), &load, &ParallelConfig::new(4, 1), &spec);
        // the kernel itself scales near-linearly...
        assert!(
            r1.critical_kernel_s / r4.critical_kernel_s > 3.0,
            "kernel speedup {}",
            r1.critical_kernel_s / r4.critical_kernel_s
        );
        // ...while the step is partially all-to-all bound (honest NVLink
        // math: 59 MB/rank at 200 GB/s rivals the sharded kernel time)
        assert!(r1.step_time_s / r4.step_time_s > 1.2);
        assert!(r4.all_to_all_s > 0.0);
    }

    #[test]
    fn ep_suffers_under_skew_more_than_single_gpu() {
        // Best case: all tokens on experts 0..8 -> EP rank 0 owns everything
        let load = LoadScenario::Best.counts(&shape(), 0);
        let spec = GpuSpec::h800();
        let r = simulate(&shape(), &load, &ParallelConfig::new(8, 1), &spec);
        // only one rank has work: no speedup from the other 7
        let busy_ranks = r.rank_kernel_s.iter().filter(|&&t| t > 0.0).count();
        assert_eq!(busy_ranks, 1);
        let t1 = simulate(&shape(), &load, &ParallelConfig::new(1, 1), &spec).step_time_s;
        assert!(r.step_time_s > t1 * 0.8, "EP gains almost nothing under total skew");
    }

    #[test]
    fn tp_splits_are_finer_grained_but_pay_allreduce() {
        let load = LoadScenario::Balanced.counts(&shape(), 0);
        let spec = GpuSpec::h800();
        let tp8 = simulate(&shape(), &load, &ParallelConfig::new(1, 8), &spec);
        assert!(tp8.all_reduce_s > 0.0);
        assert!(tp8.critical_kernel_s < simulate(&shape(), &load, &ParallelConfig::new(1, 1), &spec).critical_kernel_s);
    }

    #[test]
    #[should_panic(expected = "experts must divide")]
    fn invalid_partition_rejected() {
        let load = LoadScenario::Balanced.counts(&shape(), 0);
        partition(&shape(), &load, &ParallelConfig::new(7, 1));
    }

    #[test]
    fn public_collective_costs_match_simulated_step() {
        // the serving executor charges collectives through the public
        // methods; they must agree with what `simulate` charges internally
        let load = LoadScenario::Zipf(1.2).counts(&shape(), 3);
        let cfg = ParallelConfig::new(4, 2);
        let ranks = partition(&shape(), &load, &cfg);
        let max_rows = ranks.iter().map(|r| r.rows_in).max().unwrap();
        let r = simulate(&shape(), &load, &cfg, &GpuSpec::h800());
        let s = shape();
        assert_eq!(
            r.all_to_all_s,
            cfg.all_to_all_time_s(max_rows, s.d_model, s.dtype_bytes)
        );
        assert_eq!(r.all_reduce_s, cfg.all_reduce_time_s(s.seq, s.d_model, s.dtype_bytes));
        // degenerate grids pay nothing
        let single = ParallelConfig::new(1, 1);
        assert_eq!(single.all_to_all_time_s(1000, 64, 4), 0.0);
        assert_eq!(single.all_reduce_time_s(1000, 64, 4), 0.0);
    }
}
