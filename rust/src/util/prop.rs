//! Tiny property-testing driver (no proptest in the offline vendor set).
//!
//! `check(name, cases, gen, prop)` runs `prop` over `cases` generated inputs
//! drawn from a seeded [`Rng`]; on failure it re-runs the generator with a
//! "shrink ladder" of smaller size hints and reports the smallest failing
//! seed/size so the case can be reproduced with `reproduce()`.

use super::rng::Rng;

/// Generation context: seeded RNG plus a size hint that shrinking lowers.
pub struct GenCtx<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run a property `cases` times. Panics with a reproducer on failure.
pub fn check<T, G, P>(name: &str, cases: usize, mut generate: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut GenCtx) -> T,
    P: FnMut(&T) -> PropResult,
{
    let base_seed = 0x5EED_0000u64;
    for case in 0..cases {
        let seed = base_seed + case as u64;
        let size = 1 + case % 64; // ramp size with case index
        let mut rng = Rng::new(seed);
        let mut ctx = GenCtx { rng: &mut rng, size };
        let input = generate(&mut ctx);
        if let Err(msg) = prop(&input) {
            // shrink: retry the same seed at smaller sizes, keep the smallest failure
            let mut smallest: (usize, String, String) =
                (size, format!("{input:?}"), msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(seed);
                let mut ctx = GenCtx { rng: &mut rng, size: s };
                let cand = generate(&mut ctx);
                if let Err(m2) = prop(&cand) {
                    smallest = (s, format!("{cand:?}"), m2);
                }
                if s == 1 {
                    break;
                }
                s /= 2;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {}):\n  input: {}\n  error: {}",
                smallest.0, smallest.1, smallest.2
            );
        }
    }
}

/// Re-generate the input for a reported (seed, size) pair.
pub fn reproduce<T, G: FnMut(&mut GenCtx) -> T>(seed: u64, size: usize, mut generate: G) -> T {
    let mut rng = Rng::new(seed);
    let mut ctx = GenCtx { rng: &mut rng, size };
    generate(&mut ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            "sum-commutes",
            50,
            |g| (g.rng.below(100) as i64, g.rng.below(100) as i64),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_reproducer() {
        check(
            "always-fails",
            10,
            |g| g.rng.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn reproduce_matches_generation() {
        let a = reproduce(0x5EED_0001, 2, |g| g.rng.below(1000));
        let b = reproduce(0x5EED_0001, 2, |g| g.rng.below(1000));
        assert_eq!(a, b);
    }
}
