//! Fixed-size worker pool over std::thread + channels.
//!
//! Used by the parallel [`crate::exec::CpuBackend`] numerics (expert GEMMs,
//! ragged flash-decode), [`crate::batching::tile_prefix::build_parallel`],
//! and the parallel sweep drivers in the benches.  No async runtime is
//! available offline, and a simple pool is all the execution paths need.
//!
//! Failure model: a panicking job can never kill a worker (the worker
//! catches the unwind and keeps draining the queue) and never deadlock a
//! mapper — [`ThreadPool::map`] / [`ThreadPool::map_chunks`] return
//! [`PoolError::WorkerPanicked`] instead, which the execution layer
//! surfaces as a typed [`crate::exec::ExecError`] rather than poisoning
//! the serving loop.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Why the pool could not run (or finish) a set of jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolError {
    /// The pool's queue is gone (all workers exited) — submission failed.
    Shutdown,
    /// At least one job panicked; the surviving results were discarded so
    /// the caller never observes a partially-computed map.
    WorkerPanicked,
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::Shutdown => write!(f, "thread pool is shut down"),
            PoolError::WorkerPanicked => write!(f, "a pool worker job panicked"),
        }
    }
}

impl std::error::Error for PoolError {}

/// A fixed pool of worker threads executing boxed jobs FIFO.
pub struct ThreadPool {
    tx: Sender<Msg>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("sb-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            // a panicking job must not take the worker down
                            // with it: catch the unwind and keep draining
                            Ok(Msg::Run(job)) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles }
    }

    /// Submit a job; never blocks.  Errs only if the pool's workers are
    /// gone (shutdown raced with the submission) — the old
    /// `expect("pool alive")` panic path made that case take the *caller*
    /// down instead of reporting it.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), PoolError> {
        self.send_job(Box::new(f))
    }

    fn send_job(&self, job: Job) -> Result<(), PoolError> {
        self.tx.send(Msg::Run(job)).map_err(|_| PoolError::Shutdown)
    }

    /// Map `f` over `items` in parallel, preserving order.  One job (and
    /// one result message) per item — fine for coarse items; for many small
    /// ones use [`ThreadPool::map_chunks`] so per-task overhead doesn't eat
    /// the win.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Result<Vec<R>, PoolError>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.map_chunks(items, 1, f)
    }

    /// Chunked parallel map, preserving order: items are split into runs of
    /// up to `chunk` and each run is one boxed job + one channel message,
    /// so per-item dispatch overhead amortizes across the run.
    pub fn map_chunks<T, R, F>(
        &self,
        items: Vec<T>,
        chunk: usize,
        f: F,
    ) -> Result<Vec<R>, PoolError>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let chunk = chunk.max(1);
        let n = items.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let n_chunks = n.div_ceil(chunk);
        let (tx, rx) = channel::<(usize, std::thread::Result<Vec<R>>)>();
        let mut items = items;
        let mut submitted = 0usize;
        let mut submit_err = None;
        // split off chunks back-to-front so each job owns its items
        let mut runs: Vec<(usize, Vec<T>)> = Vec::with_capacity(n_chunks);
        for ci in (0..n_chunks).rev() {
            let run = items.split_off(ci * chunk);
            runs.push((ci, run));
        }
        for (ci, run) in runs.into_iter().rev() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            let job = move || {
                let r = catch_unwind(AssertUnwindSafe(|| {
                    run.into_iter().map(|t| f(t)).collect::<Vec<R>>()
                }));
                let _ = tx.send((ci, r));
            };
            match self.execute(job) {
                Ok(()) => submitted += 1,
                Err(e) => {
                    submit_err = Some(e);
                    break;
                }
            }
        }
        drop(tx);
        // drain until every submitted job reported (disconnect == all done),
        // so no job can still be running when we return
        let mut out: Vec<Option<Vec<R>>> = (0..n_chunks).map(|_| None).collect();
        let mut got = 0usize;
        let mut panicked = false;
        while let Ok((ci, res)) = rx.recv() {
            match res {
                Ok(v) => {
                    out[ci] = Some(v);
                    got += 1;
                }
                Err(_) => panicked = true,
            }
        }
        if let Some(e) = submit_err {
            return Err(e);
        }
        if panicked || got != submitted || submitted != n_chunks {
            return Err(PoolError::WorkerPanicked);
        }
        Ok(out.into_iter().flat_map(|o| o.expect("all chunks received")).collect())
    }

    /// [`ThreadPool::map_chunks`] for closures that *borrow* their
    /// environment (the backend hot path: jobs read the plan and input
    /// tensors by reference instead of `Arc`-wrapping or copying them).
    ///
    /// The `F: Copy` bound is what keeps this safe without `'static`: a
    /// closure is `Copy` exactly when it captures only `Copy` state —
    /// shared references and scalars — so neither the closure nor its
    /// captures have drop glue that could touch borrowed data after this
    /// call returns.  The call blocks until every submitted job has sent
    /// its result (channel disconnect), so no job is still executing
    /// borrowed state when the borrow ends.
    pub fn scoped_map_chunks<'env, T, R, F>(
        &self,
        items: Vec<T>,
        chunk: usize,
        f: F,
    ) -> Result<Vec<R>, PoolError>
    where
        T: Send + 'env,
        R: Send + 'env,
        F: Fn(T) -> R + Copy + Send + Sync + 'env,
    {
        let chunk = chunk.max(1);
        let n = items.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let n_chunks = n.div_ceil(chunk).max(1);
        let (tx, rx) = channel::<(usize, std::thread::Result<Vec<R>>)>();
        let mut items = items;
        let mut runs: Vec<(usize, Vec<T>)> = Vec::with_capacity(n_chunks);
        for ci in (0..n_chunks).rev() {
            let run = items.split_off(ci * chunk);
            runs.push((ci, run));
        }
        let mut submitted = 0usize;
        let mut submit_err = None;
        for (ci, run) in runs.into_iter().rev() {
            let tx = tx.clone();
            let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                let r = catch_unwind(AssertUnwindSafe(|| {
                    run.into_iter().map(f).collect::<Vec<R>>()
                }));
                let _ = tx.send((ci, r));
            });
            // SAFETY: the job is queued and run by this pool only; below we
            // block until the result channel disconnects, which happens only
            // after every submitted job has finished running and dropped its
            // Sender.  `F: Copy` (and `&T`/scalar captures generally) have
            // no drop glue, so nothing borrowed is touched after that point.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
            };
            match self.send_job(job) {
                Ok(()) => submitted += 1,
                Err(e) => {
                    submit_err = Some(e);
                    break;
                }
            }
        }
        drop(tx);
        let mut out: Vec<Option<Vec<R>>> = (0..n_chunks).map(|_| None).collect();
        let mut got = 0usize;
        let mut panicked = false;
        while let Ok((ci, res)) = rx.recv() {
            match res {
                Ok(v) => {
                    out[ci] = Some(v);
                    got += 1;
                }
                Err(_) => panicked = true,
            }
        }
        if let Some(e) = submit_err {
            return Err(e);
        }
        if panicked || got != submitted || submitted != n_chunks {
            return Err(PoolError::WorkerPanicked);
        }
        Ok(out.into_iter().flat_map(|o| o.expect("all chunks received")).collect())
    }

    /// The chunk size the parallel backends use: enough runs to keep every
    /// worker busy with a little slack for imbalance, never below one.
    pub fn default_chunk(&self, items: usize) -> usize {
        items.div_ceil(self.workers() * 2).max(1)
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            })
            .expect("pool alive");
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect::<Vec<i32>>(), |x| x * x).unwrap();
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<i32>>());
    }

    #[test]
    fn map_chunks_preserves_order_at_every_chunk_size() {
        let pool = ThreadPool::new(4);
        let want: Vec<i32> = (0..103).map(|x| x * 3 + 1).collect();
        for chunk in [1usize, 2, 7, 50, 103, 1000] {
            let out = pool
                .map_chunks((0..103).collect::<Vec<i32>>(), chunk, |x| x * 3 + 1)
                .unwrap();
            assert_eq!(out, want, "chunk={chunk}");
        }
    }

    #[test]
    fn scoped_map_chunks_borrows_the_environment() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let slice = &data[..];
        let out = pool
            .scoped_map_chunks((0..1000usize).collect(), 64, |i| slice[i] * 2)
            .unwrap();
        assert_eq!(out, (0..1000u64).map(|x| x * 2).collect::<Vec<u64>>());
    }

    #[test]
    fn panicking_job_surfaces_as_error_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let err = pool
            .map((0..16).collect::<Vec<i32>>(), |x| {
                if x == 7 {
                    panic!("boom");
                }
                x
            })
            .unwrap_err();
        assert_eq!(err, PoolError::WorkerPanicked);
        // workers caught the unwind: the pool keeps working afterwards
        let ok = pool.map(vec![1, 2, 3], |x| x + 1).unwrap();
        assert_eq!(ok, vec![2, 3, 4]);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)))
            .expect("pool alive");
        drop(pool); // must not hang or panic
    }
}
